"""Pure-jnp oracles for the L1 Pallas kernels.

These are the correctness references the pytest suite (and hypothesis shape
sweeps) compare the kernels against. They intentionally use the most naive
formulation so any cleverness in the kernels is checked against arithmetic
that is obviously right.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_ref(x: jax.Array, y: jax.Array) -> jax.Array:
    """f32[M,K] @ f32[K,N] -> f32[M,N]."""
    return jnp.matmul(x, y)


def cross_entropy_ref(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Per-sample softmax cross-entropy, numerically stable log-sum-exp."""
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, labels[:, None].astype(jnp.int32), axis=-1)[:, 0]
    return lse - picked


def cross_entropy_grad_ref(logits: jax.Array, labels: jax.Array, g: jax.Array) -> jax.Array:
    """d(sum(g * ce)) / dlogits = (softmax - onehot) * g."""
    probs = jax.nn.softmax(logits, axis=-1)
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=logits.dtype)
    return (probs - onehot) * g[:, None]
