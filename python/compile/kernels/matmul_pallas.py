"""L1 Pallas tiled matmul — the compute hot-spot of every model in the zoo.

TPU-shaped: the grid tiles (M, N, K) into VMEM-resident blocks sized for the
MXU systolic array (128x128 native; smaller tiles are used for the scaled-down
models so a block never exceeds the VMEM budget). The K axis is the innermost
grid dimension and revisits the same output block, accumulating partial
products in place — the BlockSpec index maps express the HBM<->VMEM schedule
that a CUDA implementation would express with threadblocks + shared memory.

`interpret=True` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so the kernel lowers to plain HLO. Real-TPU perf is estimated
analytically in DESIGN.md / EXPERIMENTS.md SSPerf from the VMEM footprint and
MXU utilization of the chosen block shapes.

A `jax.custom_vjp` wrapper makes the kernel differentiable (dA = g @ B^T,
dB = A^T @ g, both computed with the same tiled kernel) so the whole model
fwd/bwd lowers into one HLO module.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default block shapes. The K/N edges stay at 128 (MXU edge); the M edge is
# 512 after the SSPerf block sweep (EXPERIMENTS.md): M-rows stream through
# the systolic array, so a taller M block amortizes grid-step overhead 2.5x
# at 589 KiB VMEM/step (3.6% of a core), with zero utilization loss — the
# padding helper rounds every operand up so blocks evenly divide the padded
# problem, and `_block_dims` shrinks blocks for small problems.
DEFAULT_BM = 512
DEFAULT_BK = 128
DEFAULT_BN = 128


def _matmul_kernel(x_ref, y_ref, o_ref, *, nk: int):
    """One (i, j, k) grid step: o[i,j] += x[i,k] @ y[k,j].

    The output block is revisited for every k, so it doubles as the VMEM
    accumulator; it is zeroed on the first K-step and holds the finished
    tile after the last one.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _block_dims(m: int, k: int, n: int, bm: int, bk: int, bn: int):
    """Shrink blocks for problems smaller than one default tile."""
    return min(bm, _round_up(m, 8)), min(bk, _round_up(k, 8)), min(bn, _round_up(n, 8))


def matmul_raw(
    x: jax.Array,
    y: jax.Array,
    *,
    bm: int = DEFAULT_BM,
    bk: int = DEFAULT_BK,
    bn: int = DEFAULT_BN,
) -> jax.Array:
    """Tiled pallas matmul for f32[M,K] @ f32[K,N]; pads to block multiples."""
    if x.ndim != 2 or y.ndim != 2:
        raise ValueError(f"matmul_raw expects rank-2 operands, got {x.shape} @ {y.shape}")
    m, k = x.shape
    k2, n = y.shape
    if k != k2:
        raise ValueError(f"contraction mismatch: {x.shape} @ {y.shape}")
    bm, bk, bn = _block_dims(m, k, n, bm, bk, bn)
    mp, kp, np_ = _round_up(m, bm), _round_up(k, bk), _round_up(n, bn)
    xp = jnp.pad(x, ((0, mp - m), (0, kp - k))) if (mp, kp) != (m, k) else x
    yp = jnp.pad(y, ((0, kp - k), (0, np_ - n))) if (kp, np_) != (k, n) else y
    grid = (mp // bm, np_ // bn, kp // bk)
    out = pl.pallas_call(
        functools.partial(_matmul_kernel, nk=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(xp, yp)
    if (mp, np_) != (m, n):
        out = out[:m, :n]
    return out


@jax.custom_vjp
def matmul(x: jax.Array, y: jax.Array) -> jax.Array:
    """Differentiable tiled pallas matmul (f32[M,K] @ f32[K,N] -> f32[M,N])."""
    return matmul_raw(x, y)


def _matmul_fwd(x, y):
    return matmul_raw(x, y), (x, y)


def _matmul_bwd(res, g):
    x, y = res
    # Both cotangents reuse the tiled kernel so the backward pass stays on
    # the same MXU schedule as the forward pass.
    return matmul_raw(g, y.T), matmul_raw(x.T, g)


matmul.defvjp(_matmul_fwd, _matmul_bwd)


def vmem_footprint_bytes(bm: int = DEFAULT_BM, bk: int = DEFAULT_BK, bn: int = DEFAULT_BN) -> int:
    """Bytes of VMEM one grid step touches (x, y blocks + output accumulator).

    Used by the SSPerf analysis: must stay well under ~16 MiB/core.
    """
    return 4 * (bm * bk + bk * bn + bm * bn)


def mxu_utilization_estimate(m: int, k: int, n: int, *, bm: int = DEFAULT_BM,
                             bk: int = DEFAULT_BK, bn: int = DEFAULT_BN) -> float:
    """Fraction of MXU work that is useful (non-padding) for an MxKxN problem."""
    bm, bk, bn = _block_dims(m, k, n, bm, bk, bn)
    useful = m * k * n
    padded = _round_up(m, bm) * _round_up(k, bk) * _round_up(n, bn)
    return useful / padded
