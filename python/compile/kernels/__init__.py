"""L1: Pallas kernels for the compute hot-spots (tiled matmul, fused CE).

All kernels run under ``interpret=True`` so they lower to plain HLO the CPU
PJRT client can execute; see DESIGN.md (Hardware-Adaptation) for the TPU
mapping and EXPERIMENTS.md (Perf) for the VMEM/MXU analysis.
"""

from .fused_ce import cross_entropy
from .matmul_pallas import matmul, matmul_raw

__all__ = ["cross_entropy", "matmul", "matmul_raw"]
