"""L1 Pallas fused softmax-cross-entropy with normalization scale.

This is the loss-normalization hot path of the paper (Alg. 1 line 10-11):
per-sample CE losses are produced in one VMEM-resident pass (max, exp-sum,
log-sum-exp, label pick) instead of staging softmax intermediates to HBM the
way a chain of jnp ops would between kernel launches. The softmax
probabilities are kept as the VJP residual, so the backward pass is a second
single-pass kernel computing (probs - onehot(y)) * g.

Shapes: logits f32[B, C], labels int32[B]. The class axis is padded to a lane
multiple with -inf so padding classes get zero probability; the batch axis is
tiled by `bb` rows per grid step.

interpret=True as everywhere (CPU PJRT cannot run Mosaic custom-calls).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BB = 8  # batch rows per grid step
LANE = 128      # class-axis padding multiple (TPU lane width)

_NEG_INF = -1e30


def _ce_fwd_kernel(logits_ref, labels_ref, loss_ref, probs_ref, *, num_classes: int):
    """One batch tile: per-row LSE loss + softmax probs, all in VMEM."""
    logits = logits_ref[...]  # [bb, Cp]
    labels = labels_ref[...]  # [bb]
    row_max = jnp.max(logits, axis=-1, keepdims=True)
    shifted = logits - row_max
    exp = jnp.exp(shifted)
    denom = jnp.sum(exp, axis=-1, keepdims=True)
    probs = exp / denom
    lse = jnp.log(denom)[:, 0] + row_max[:, 0]
    cols = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1)
    onehot = (cols == labels[:, None]).astype(jnp.float32)
    picked = jnp.sum(logits * onehot, axis=-1)
    loss_ref[...] = lse - picked
    probs_ref[...] = probs


def _ce_bwd_kernel(probs_ref, labels_ref, g_ref, dlogits_ref):
    probs = probs_ref[...]
    labels = labels_ref[...]
    g = g_ref[...]
    cols = jax.lax.broadcasted_iota(jnp.int32, probs.shape, 1)
    onehot = (cols == labels[:, None]).astype(jnp.float32)
    dlogits_ref[...] = (probs - onehot) * g[:, None]


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _pad_class_axis(logits: jax.Array) -> jax.Array:
    c = logits.shape[-1]
    cp = _round_up(c, LANE)
    if cp == c:
        return logits
    return jnp.pad(logits, ((0, 0), (0, cp - c)), constant_values=_NEG_INF)


def _fwd_raw(logits: jax.Array, labels: jax.Array, *, bb: int = DEFAULT_BB):
    b, c = logits.shape
    lp = _pad_class_axis(logits)
    cp = lp.shape[-1]
    bb = min(bb, b)
    bp = _round_up(b, bb)
    if bp != b:
        lp = jnp.pad(lp, ((0, bp - b), (0, 0)))
        labels = jnp.pad(labels, (0, bp - b))
    grid = (bp // bb,)
    loss, probs = pl.pallas_call(
        functools.partial(_ce_fwd_kernel, num_classes=c),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, cp), lambda i: (i, 0)),
            pl.BlockSpec((bb,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((bb,), lambda i: (i,)),
            pl.BlockSpec((bb, cp), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bp,), jnp.float32),
            jax.ShapeDtypeStruct((bp, cp), jnp.float32),
        ],
        interpret=True,
    )(lp, labels)
    return loss[:b], probs, bp, cp


@jax.custom_vjp
def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Per-sample softmax cross-entropy: f32[B,C], int32[B] -> f32[B]."""
    loss, _, _, _ = _fwd_raw(logits, labels)
    return loss


def _ce_fwd(logits, labels):
    loss, probs, bp, cp = _fwd_raw(logits, labels)
    return loss, (probs, labels, logits.shape, bp, cp)


def _ce_bwd(res, g):
    probs, labels, (b, c), bp, cp = res
    bb = min(DEFAULT_BB, b)
    gp = jnp.pad(g, (0, bp - b)) if bp != b else g
    labp = jnp.pad(labels, (0, bp - b)) if bp != b else labels
    grid = (bp // bb,)
    dlogits = pl.pallas_call(
        _ce_bwd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, cp), lambda i: (i, 0)),
            pl.BlockSpec((bb,), lambda i: (i,)),
            pl.BlockSpec((bb,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((bb, cp), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((bp, cp), jnp.float32),
        interpret=True,
    )(probs, labp, gp)
    return dlogits[:b, :c], None


cross_entropy.defvjp(_ce_fwd, _ce_bwd)


def vmem_footprint_bytes(bb: int, num_classes: int) -> int:
    """Forward-pass VMEM bytes per grid step (logits tile + probs tile + rows)."""
    cp = _round_up(num_classes, LANE)
    return 4 * (2 * bb * cp + 3 * bb)
