"""Micro-Former: decoder-only transformer LM for the end-to-end driver.

The paper evaluates CNNs, but MBS is model-agnostic; the e2e example
(examples/e2e_transformer.rs) trains this causal LM for a few hundred steps
under a memory budget it could not fit natively, logging the loss curve
(EXPERIMENTS.md E2E). QKV/out projections and the MLP run on the pallas
tiled matmul; attention probability math stays in L2 jnp.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from . import common as cm


@dataclass(frozen=True)
class TransformerConfig:
    vocab: int = 512
    seq_len: int = 64
    d_model: int = 128
    n_heads: int = 4
    n_layers: int = 4
    d_ff: int = 512

    @property
    def name(self) -> str:
        return "microformer"


def _layer_init(key, cfg: TransformerConfig) -> dict:
    kq, kk, kv, ko, k1, k2 = jax.random.split(key, 6)
    d = cfg.d_model
    return {
        "ln1": cm.layernorm_init(d),
        "wq": cm.dense_init(kq, d, d),
        "wk": cm.dense_init(kk, d, d),
        "wv": cm.dense_init(kv, d, d),
        "wo": cm.dense_init(ko, d, d),
        "ln2": cm.layernorm_init(d),
        "ff1": cm.dense_init(k1, d, cfg.d_ff),
        "ff2": cm.dense_init(k2, cfg.d_ff, d),
    }


def _attention(p: dict, x: jax.Array, cfg: TransformerConfig) -> jax.Array:
    b, t, d = x.shape
    hn, hd = cfg.n_heads, d // cfg.n_heads
    q = cm.dense(p["wq"], x).reshape(b, t, hn, hd).transpose(0, 2, 1, 3)
    k = cm.dense(p["wk"], x).reshape(b, t, hn, hd).transpose(0, 2, 1, 3)
    v = cm.dense(p["wv"], x).reshape(b, t, hn, hd).transpose(0, 2, 1, 3)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(float(hd))
    mask = jnp.tril(jnp.ones((t, t), dtype=bool))
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, v)
    out = out.transpose(0, 2, 1, 3).reshape(b, t, d)
    return cm.dense(p["wo"], out)


def _layer_apply(p: dict, x: jax.Array, cfg: TransformerConfig) -> jax.Array:
    x = x + _attention(p, cm.layernorm(p["ln1"], x), cfg)
    h = cm.layernorm(p["ln2"], x)
    h = cm.dense(p["ff2"], jax.nn.gelu(cm.dense(p["ff1"], h)))
    return x + h


def init(key, cfg: TransformerConfig) -> dict:
    keys = jax.random.split(key, cfg.n_layers + 3)
    params = {
        "tok_emb": 0.02 * jax.random.normal(keys[0], (cfg.vocab, cfg.d_model)),
        "pos_emb": 0.02 * jax.random.normal(keys[1], (cfg.seq_len, cfg.d_model)),
        "ln_f": cm.layernorm_init(cfg.d_model),
    }
    for i in range(cfg.n_layers):
        params[f"layer{i}"] = _layer_init(keys[2 + i], cfg)
    return params


def apply(params: dict, tokens: jax.Array, cfg: TransformerConfig) -> jax.Array:
    """int32[B,T] -> next-token logits f32[B,T,vocab] (weight-tied head)."""
    h = params["tok_emb"][tokens] + params["pos_emb"][None, :, :]
    for i in range(cfg.n_layers):
        h = _layer_apply(params[f"layer{i}"], h, cfg)
    h = cm.layernorm(params["ln_f"], h)
    b, t, d = h.shape
    from ..kernels import matmul

    logits = matmul(h.reshape(b * t, d), params["tok_emb"].T)
    return logits.reshape(b, t, cfg.vocab)
