"""Micro-ResNet: scaled-down analogue of the paper's ResNet-50/101 baselines.

Basic residual blocks (3x3 conv, GN, ReLU) in three stages; ``depth`` selects
the stage repeat counts the way 50 vs 101 does in the paper. Downsampling
skips use 1x1 convs routed through the pallas matmul.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import jax
import jax.numpy as jnp

from . import common as cm


@dataclass(frozen=True)
class ResNetConfig:
    num_classes: int = 102
    stem_channels: int = 16
    stage_channels: Tuple[int, ...] = (16, 32, 64)
    blocks_per_stage: Tuple[int, ...] = (2, 2, 2)  # "18"-ish; (3,4,3) for "34"-ish

    @property
    def name(self) -> str:
        return f"microresnet{sum(self.blocks_per_stage) * 2 + 2}"


def _block_init(key, cin: int, cout: int) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "conv1": cm.conv_init(k1, 3, 3, cin, cout),
        "gn1": cm.groupnorm_init(cout),
        "conv2": cm.conv_init(k2, 3, 3, cout, cout),
        "gn2": cm.groupnorm_init(cout),
    }
    if cin != cout:
        p["proj"] = cm.conv1x1_init(k3, cin, cout)
    return p


def _block_apply(p: dict, x: jax.Array, stride: int) -> jax.Array:
    h = cm.conv(p["conv1"], x, stride=stride)
    h = cm.relu(cm.groupnorm(p["gn1"], h))
    h = cm.conv(p["conv2"], h)
    h = cm.groupnorm(p["gn2"], h)
    if "proj" in p:
        x = cm.conv1x1(p["proj"], x, stride=stride)
    elif stride != 1:
        x = x[:, ::stride, ::stride, :]
    return cm.relu(h + x)


def init(key, cfg: ResNetConfig) -> dict:
    keys = jax.random.split(key, 2 + sum(cfg.blocks_per_stage))
    params = {
        "stem": cm.conv_init(keys[0], 3, 3, 3, cfg.stem_channels),
        "stem_gn": cm.groupnorm_init(cfg.stem_channels),
        "head": cm.dense_init(keys[1], cfg.stage_channels[-1], cfg.num_classes),
    }
    ki = 2
    cin = cfg.stem_channels
    for si, (ch, nb) in enumerate(zip(cfg.stage_channels, cfg.blocks_per_stage)):
        for bi in range(nb):
            params[f"s{si}b{bi}"] = _block_init(keys[ki], cin if bi == 0 else ch, ch)
            ki += 1
        cin = ch
    return params


def apply(params: dict, x: jax.Array, cfg: ResNetConfig) -> jax.Array:
    """f32[B,H,W,3] -> logits f32[B,num_classes]."""
    h = cm.relu(cm.groupnorm(params["stem_gn"], cm.conv(params["stem"], x)))
    for si, nb in enumerate(cfg.blocks_per_stage):
        for bi in range(nb):
            stride = 2 if (bi == 0 and si > 0) else 1
            h = _block_apply(params[f"s{si}b{bi}"], h, stride)
    pooled = cm.global_avg_pool(h)
    return cm.dense(params["head"], pooled)
