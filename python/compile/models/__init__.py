"""L2 model zoo registry.

Each entry binds a model's init/apply to its task's loss + metric and its
paper-prescribed optimizer, giving the AOT exporter and the tests one
uniform interface:

    spec = MODELS["microresnet18"]
    params = spec.init(jax.random.key(0))
    out = spec.apply(params, x)          # logits / mask-logits
    per = spec.loss(out, y)              # f32[B]
    met = spec.metric(out, y, mask)      # f32[4]
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Tuple

import jax.numpy as jnp

from .. import losses
from . import amoeba, resnet, transformer, unet


@dataclass(frozen=True)
class ModelSpec:
    key: str
    task: str  # classification | segmentation | lm
    optimizer: str  # sgdm | adam
    init: Callable
    apply: Callable
    loss: Callable
    metric: Callable
    # (mu, image_size_or_seqlen) -> ((x_shape, x_dtype), (y_shape, y_dtype))
    io_shapes: Callable
    default_size: int  # default image size (px) or sequence length
    # paper-corresponding hyper defaults (section 4.2.4)
    hyper: Tuple[float, ...]


def _img_io(task: str):
    def io(mu: int, size: int):
        x = ((mu, size, size, 3), jnp.float32)
        if task == "classification":
            y = ((mu,), jnp.int32)
        else:
            y = ((mu, size, size, 1), jnp.float32)
        return x, y

    return io


def _lm_io(mu: int, seq: int):
    return ((mu, seq), jnp.int32), ((mu, seq), jnp.int32)


_resnet18_cfg = resnet.ResNetConfig(blocks_per_stage=(2, 2, 2))
_resnet34_cfg = resnet.ResNetConfig(blocks_per_stage=(3, 4, 3))
_amoeba_cfg = amoeba.AmoebaConfig()
_unet_cfg = unet.UNetConfig()
_tfm_cfg = transformer.TransformerConfig()

MODELS = {
    # ResNet-50 analogue: SGD lr=0.01 momentum=0.9 wd=5e-4 (section 4.2.4)
    "microresnet18": ModelSpec(
        key="microresnet18",
        task="classification",
        optimizer="sgdm",
        init=lambda k: resnet.init(k, _resnet18_cfg),
        apply=lambda p, x: resnet.apply(p, x, _resnet18_cfg),
        loss=losses.ce_per_sample,
        metric=losses.classification_metric,
        io_shapes=_img_io("classification"),
        default_size=16,
        hyper=(0.01, 0.9, 5e-4),
    ),
    # ResNet-101 analogue (deeper; same recipe)
    "microresnet34": ModelSpec(
        key="microresnet34",
        task="classification",
        optimizer="sgdm",
        init=lambda k: resnet.init(k, _resnet34_cfg),
        apply=lambda p, x: resnet.apply(p, x, _resnet34_cfg),
        loss=losses.ce_per_sample,
        metric=losses.classification_metric,
        io_shapes=_img_io("classification"),
        default_size=16,
        hyper=(0.01, 0.9, 5e-4),
    ),
    # AmoebaNet-D analogue: SGD lr=0.1 momentum=0.9 wd=1e-4, linear LR decay
    # (the decay schedule lives in the rust coordinator)
    "amoebacell": ModelSpec(
        key="amoebacell",
        task="classification",
        optimizer="sgdm",
        init=lambda k: amoeba.init(k, _amoeba_cfg),
        apply=lambda p, x: amoeba.apply(p, x, _amoeba_cfg),
        loss=losses.ce_per_sample,
        metric=losses.classification_metric,
        io_shapes=_img_io("classification"),
        default_size=24,
        hyper=(0.1, 0.9, 1e-4),
    ),
    # U-Net: Adam lr=0.01 wd=5e-4, BCE+Dice (section 4.2.4)
    "microunet": ModelSpec(
        key="microunet",
        task="segmentation",
        optimizer="adam",
        init=lambda k: unet.init(k, _unet_cfg),
        apply=lambda p, x: unet.apply(p, x, _unet_cfg),
        loss=losses.bce_dice_per_sample,
        metric=losses.segmentation_metric,
        io_shapes=_img_io("segmentation"),
        default_size=24,
        hyper=(0.01, 0.9, 0.999, 1e-8, 5e-4, 1.0),
    ),
    # e2e driver LM (Adam, standard LM recipe)
    "microformer": ModelSpec(
        key="microformer",
        task="lm",
        optimizer="adam",
        init=lambda k: transformer.init(k, _tfm_cfg),
        apply=lambda p, x: transformer.apply(p, x, _tfm_cfg),
        loss=losses.lm_ce_per_sample,
        metric=losses.lm_metric,
        io_shapes=_lm_io,
        default_size=_tfm_cfg.seq_len,
        hyper=(3e-4, 0.9, 0.999, 1e-8, 0.01, 1.0),
    ),
}

CONFIGS = {
    "microresnet18": _resnet18_cfg,
    "microresnet34": _resnet34_cfg,
    "amoebacell": _amoeba_cfg,
    "microunet": _unet_cfg,
    "microformer": _tfm_cfg,
}
