"""Micro-U-Net: scaled-down analogue of the paper's Carvana U-Net baseline.

Three-level encoder/decoder with skip connections and transpose-conv
upsampling. Output is single-channel mask logits (sigmoid applied in the
BCE+Dice loss, matching the paper's setup).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import jax
import jax.numpy as jnp

from . import common as cm


@dataclass(frozen=True)
class UNetConfig:
    channels: Tuple[int, ...] = (16, 32, 64)

    @property
    def name(self) -> str:
        return "microunet"


def _double_conv_init(key, cin: int, cout: int) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "conv1": cm.conv_init(k1, 3, 3, cin, cout),
        "gn1": cm.groupnorm_init(cout),
        "conv2": cm.conv_init(k2, 3, 3, cout, cout),
        "gn2": cm.groupnorm_init(cout),
    }


def _double_conv(p: dict, x: jax.Array) -> jax.Array:
    h = cm.relu(cm.groupnorm(p["gn1"], cm.conv(p["conv1"], x)))
    return cm.relu(cm.groupnorm(p["gn2"], cm.conv(p["conv2"], h)))


def init(key, cfg: UNetConfig) -> dict:
    chs = cfg.channels
    n_enc = len(chs)
    keys = jax.random.split(key, 2 * n_enc + 2 * (n_enc - 1) + 1)
    params: dict = {}
    cin = 3
    ki = 0
    for i, ch in enumerate(chs):
        params[f"enc{i}"] = _double_conv_init(keys[ki], cin, ch)
        ki += 1
        cin = ch
    params["mid"] = _double_conv_init(keys[ki], chs[-1], chs[-1])
    ki += 1
    for i in range(n_enc - 2, -1, -1):
        params[f"up{i}"] = cm.conv_transpose_init(keys[ki], 2, chs[i + 1], chs[i])
        ki += 1
        params[f"dec{i}"] = _double_conv_init(keys[ki], 2 * chs[i], chs[i])
        ki += 1
    params["out"] = cm.conv1x1_init(keys[ki], chs[0], 1)
    return params


def apply(params: dict, x: jax.Array, cfg: UNetConfig) -> jax.Array:
    """f32[B,H,W,3] -> mask logits f32[B,H,W,1]."""
    chs = cfg.channels
    n_enc = len(chs)
    skips = []
    h = x
    for i in range(n_enc):
        h = _double_conv(params[f"enc{i}"], h)
        if i < n_enc - 1:
            skips.append(h)
            h = cm.max_pool(h, 2)
    h = _double_conv(params["mid"], h)
    for i in range(n_enc - 2, -1, -1):
        h = cm.conv_transpose(params[f"up{i}"], h, stride=2)
        h = jnp.concatenate([h, skips[i]], axis=-1)
        h = _double_conv(params[f"dec{i}"], h)
    return cm.conv1x1(params["out"], h)
