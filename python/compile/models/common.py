"""Shared layer library for the L2 model zoo.

Conventions:
  * params are nested dicts of f32 arrays; flattening order is
    ``jax.tree_util.tree_flatten`` order (dicts sorted by key), and the AOT
    manifest records leaf names in exactly that order so the rust side can
    address leaves positionally.
  * images are NHWC; convs are HWIO.
  * dense layers and 1x1 convs route through the L1 pallas tiled matmul so
    the MXU-shaped kernel is on the hot path of every model.
  * normalization is GroupNorm, not BatchNorm: GN has no cross-sample
    statistics, so MBS gradient equivalence (DESIGN.md invariant 2) holds
    exactly. The BatchNorm caveat the paper glosses over is demonstrated in
    python/tests/test_grad_equivalence.py::test_batchnorm_breaks_equivalence.
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from ..kernels import matmul

Params = Dict[str, object]


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------

def he_normal(key, shape):
    """He-normal init; fan_in from all but the last axis."""
    fan_in = 1
    for d in shape[:-1]:
        fan_in *= d
    std = (2.0 / max(fan_in, 1)) ** 0.5
    return std * jax.random.normal(key, shape, dtype=jnp.float32)


def zeros(shape):
    return jnp.zeros(shape, dtype=jnp.float32)


def ones(shape):
    return jnp.ones(shape, dtype=jnp.float32)


# ---------------------------------------------------------------------------
# dense / conv layers
# ---------------------------------------------------------------------------

def dense_init(key, in_dim: int, out_dim: int) -> Params:
    return {"w": he_normal(key, (in_dim, out_dim)), "b": zeros((out_dim,))}


def dense(p: Params, x: jax.Array) -> jax.Array:
    """f32[..., in] -> f32[..., out] via the pallas tiled matmul."""
    lead = x.shape[:-1]
    flat = x.reshape((-1, x.shape[-1]))
    out = matmul(flat, p["w"]) + p["b"]
    return out.reshape(lead + (p["w"].shape[1],))


def conv_init(key, kh: int, kw: int, cin: int, cout: int) -> Params:
    return {"w": he_normal(key, (kh, kw, cin, cout)), "b": zeros((cout,))}


def conv(p: Params, x: jax.Array, stride: int = 1, padding: str = "SAME") -> jax.Array:
    """NHWC conv with HWIO weights (XLA conv; 3x3s stay in L2)."""
    out = jax.lax.conv_general_dilated(
        x,
        p["w"],
        window_strides=(stride, stride),
        padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return out + p["b"]


def conv1x1_init(key, cin: int, cout: int) -> Params:
    return dense_init(key, cin, cout)


def conv1x1(p: Params, x: jax.Array, stride: int = 1) -> jax.Array:
    """1x1 conv lowered onto the pallas matmul: [B,H,W,Cin] @ [Cin,Cout]."""
    if stride != 1:
        x = x[:, ::stride, ::stride, :]
    return dense(p, x)


def sep_conv_init(key, k: int, cin: int, cout: int) -> Params:
    """Depthwise k x k followed by pointwise 1x1 (AmoebaNet-style)."""
    kd, kp = jax.random.split(key)
    return {
        "dw": he_normal(kd, (k, k, 1, cin)),
        "pw": conv1x1_init(kp, cin, cout),
    }


def sep_conv(p: Params, x: jax.Array, stride: int = 1) -> jax.Array:
    cin = x.shape[-1]
    dw = jax.lax.conv_general_dilated(
        x,
        p["dw"],
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=cin,
    )
    return conv1x1(p["pw"], dw)


def conv_transpose_init(key, k: int, cin: int, cout: int) -> Params:
    return {"w": he_normal(key, (k, k, cin, cout)), "b": zeros((cout,))}


def conv_transpose(p: Params, x: jax.Array, stride: int = 2) -> jax.Array:
    """NHWC transpose conv for U-Net upsampling."""
    out = jax.lax.conv_transpose(
        x,
        p["w"],
        strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return out + p["b"]


# ---------------------------------------------------------------------------
# normalization / pooling / misc
# ---------------------------------------------------------------------------

def groupnorm_init(channels: int) -> Params:
    return {"scale": ones((channels,)), "bias": zeros((channels,))}


def groupnorm(p: Params, x: jax.Array, groups: int = 8, eps: float = 1e-5) -> jax.Array:
    """Per-sample GroupNorm over (H, W, C/groups) — no cross-sample stats."""
    b, h, w, c = x.shape
    g = min(groups, c)
    while c % g != 0:
        g -= 1
    xg = x.reshape(b, h, w, g, c // g)
    mean = jnp.mean(xg, axis=(1, 2, 4), keepdims=True)
    var = jnp.var(xg, axis=(1, 2, 4), keepdims=True)
    xg = (xg - mean) * jax.lax.rsqrt(var + eps)
    return xg.reshape(b, h, w, c) * p["scale"] + p["bias"]


def layernorm_init(dim: int) -> Params:
    return {"scale": ones((dim,)), "bias": zeros((dim,))}


def layernorm(p: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mean) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]


def avg_pool(x: jax.Array, k: int = 2, stride: int | None = None) -> jax.Array:
    stride = stride or k
    out = jax.lax.reduce_window(
        x, 0.0, jax.lax.add, (1, k, k, 1), (1, stride, stride, 1), "SAME"
    )
    return out / float(k * k)


def max_pool(x: jax.Array, k: int = 2, stride: int | None = None) -> jax.Array:
    stride = stride or k
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, k, k, 1), (1, stride, stride, 1), "SAME"
    )


def global_avg_pool(x: jax.Array) -> jax.Array:
    return jnp.mean(x, axis=(1, 2))


def relu(x: jax.Array) -> jax.Array:
    return jax.nn.relu(x)
