"""AmoebaCell: scaled-down analogue of the paper's AmoebaNet-D baseline.

AmoebaNet's evolved cells are multi-branch: separable convs, pooling branches
and skip connections feeding a concat + projection. We keep that topology
(which is what stresses activation memory, the quantity MBS trades against)
at micro scale: a stem conv followed by `num_cells` cells, each with four
branches -> concat -> 1x1 projection (pallas matmul) -> residual.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from . import common as cm


@dataclass(frozen=True)
class AmoebaConfig:
    num_classes: int = 102
    stem_channels: int = 24
    cell_channels: int = 24
    num_cells: int = 3

    @property
    def name(self) -> str:
        return "amoebacell"


def _cell_init(key, cin: int, ch: int) -> dict:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "sep3": cm.sep_conv_init(k1, 3, cin, ch),
        "sep5": cm.sep_conv_init(k2, 5, cin, ch),
        "pw": cm.conv1x1_init(k3, cin, ch),
        # concat of [sep3, sep5, pw, avgpool(cin)] -> project back to ch
        "proj": cm.conv1x1_init(k4, 3 * ch + cin, ch),
        "gn": cm.groupnorm_init(ch),
    }


def _cell_apply(p: dict, x: jax.Array, reduce: bool) -> jax.Array:
    stride = 2 if reduce else 1
    b1 = cm.sep_conv(p["sep3"], x, stride=stride)
    b2 = cm.sep_conv(p["sep5"], x, stride=stride)
    b3 = cm.conv1x1(p["pw"], x, stride=stride)
    b4 = cm.avg_pool(x, 3, stride=stride)
    h = jnp.concatenate([b1, b2, b3, b4], axis=-1)
    h = cm.conv1x1(p["proj"], h)
    h = cm.relu(cm.groupnorm(p["gn"], h))
    if not reduce and x.shape[-1] == h.shape[-1]:
        h = h + x
    return h


def init(key, cfg: AmoebaConfig) -> dict:
    keys = jax.random.split(key, 2 + cfg.num_cells)
    params = {
        "stem": cm.conv_init(keys[0], 3, 3, 3, cfg.stem_channels),
        "stem_gn": cm.groupnorm_init(cfg.stem_channels),
        "head": cm.dense_init(keys[1], cfg.cell_channels, cfg.num_classes),
    }
    cin = cfg.stem_channels
    for ci in range(cfg.num_cells):
        params[f"cell{ci}"] = _cell_init(keys[2 + ci], cin, cfg.cell_channels)
        cin = cfg.cell_channels
    return params


def apply(params: dict, x: jax.Array, cfg: AmoebaConfig) -> jax.Array:
    """f32[B,H,W,3] -> logits f32[B,num_classes]."""
    h = cm.relu(cm.groupnorm(params["stem_gn"], cm.conv(params["stem"], x)))
    for ci in range(cfg.num_cells):
        h = _cell_apply(params[f"cell{ci}"], h, reduce=(ci % 2 == 1))
    pooled = cm.global_avg_pool(h)
    return cm.dense(params["head"], pooled)
