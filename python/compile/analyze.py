"""L1/L2 performance analysis: XLA cost analysis per variant + Pallas
block-shape sweep (VMEM footprint / MXU utilization estimates).

Usage:  cd python && python -m compile.analyze [--models m1 m2]

This is the profiling half of the SSPerf deliverable for the build-time
layers: interpret=True wall-clock is CPU-numpy time and NOT a TPU proxy, so
L1 is evaluated structurally — does each candidate block shape fit VMEM,
and what fraction of MXU work is useful — while L2 is evaluated with XLA's
own cost model on the compiled executable (flops, bytes accessed, peak
memory, fusion quality).
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from . import shapes
from .aot import VARIANTS
from .kernels import matmul_pallas
from .model import MODELS, build_accum_step, init_params


def xla_cost(model_key: str, size: int, mu: int, seed: int = 0) -> dict:
    """Compile the accum step and read XLA's cost analysis."""
    spec = MODELS[model_key]
    params = init_params(spec, seed)
    accum = build_accum_step(spec)
    (x_shape, x_dtype), (y_shape, y_dtype) = spec.io_shapes(mu, size)
    args = (
        params,
        jax.tree_util.tree_map(jnp.zeros_like, params),
        jnp.zeros(x_shape, x_dtype),
        jnp.zeros(y_shape, y_dtype),
        jnp.ones((mu,), jnp.float32),
        jnp.array([1.0 / mu], jnp.float32),
    )
    compiled = jax.jit(accum).lower(*args).compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # older jax returns [dict]
        cost = cost[0]
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "intensity": float(cost.get("flops", 0.0))
        / max(float(cost.get("bytes accessed", 1.0)), 1.0),
    }


def block_sweep(m: int, k: int, n: int) -> list[dict]:
    """Evaluate candidate matmul block shapes for an MxKxN hot-spot."""
    rows = []
    for bm, bk, bn in [
        (32, 32, 32),
        (64, 64, 64),
        (128, 128, 128),
        (128, 256, 128),
        (256, 128, 256),
        (512, 512, 512),
    ]:
        vmem = matmul_pallas.vmem_footprint_bytes(bm, bk, bn)
        util = matmul_pallas.mxu_utilization_estimate(m, k, n, bm=bm, bk=bk, bn=bn)
        rows.append(
            {
                "block": f"{bm}x{bk}x{bn}",
                "vmem_kib": vmem / 1024,
                # budget: 16 MiB core / (fwd+bwd operand sets) / double
                # buffering -> ~2 MiB per in-flight block set
                "fits_vmem": vmem <= 2 * 2**20,
                "mxu_util": util,
            }
        )
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--models", nargs="*", default=None)
    args = ap.parse_args()

    print("== L2: XLA cost analysis of accum_step (per micro-batch) ==")
    print(f"{'variant':34s} {'GFLOP':>8s} {'MB moved':>9s} {'intensity':>9s}")
    for mk, size, mu in VARIANTS:
        if args.models and mk not in args.models:
            continue
        c = xla_cost(mk, size, mu)
        print(
            f"{mk + f'_s{size}_mu{mu}':34s} {c['flops']/1e9:8.3f} "
            f"{c['bytes']/1e6:9.2f} {c['intensity']:9.1f}"
        )

    print("\n== L1: pallas matmul block-shape sweep ==")
    # representative hot-spots: transformer ffn (512x128 @ 128x512 per token
    # block) and the unet 1x1 bottleneck
    for (m, k, n, label) in [
        (512, 128, 512, "microformer ffn (B*T=512)"),
        (1152, 64, 64, "microunet 1x1 (24x24x. @ mu8)"),
        (128, 128, 102, "classifier head"),
    ]:
        print(f"\n  hot-spot: {label}  ({m}x{k}x{n})")
        print(f"  {'block':16s} {'VMEM KiB':>9s} {'fits':>5s} {'MXU util':>9s}")
        for row in block_sweep(m, k, n):
            print(
                f"  {row['block']:16s} {row['vmem_kib']:9.0f} "
                f"{str(row['fits_vmem']):>5s} {row['mxu_util']:9.2%}"
            )


if __name__ == "__main__":
    main()
