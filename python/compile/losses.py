"""Task losses + metric vectors for the step functions.

Every loss returns a *per-sample* loss vector f32[B]; the accumulation step
multiplies by the sample mask and the normalization scale (Alg. 1), so one
exported executable serves every mini-batch size and both normalization
modes (paper 1/N_Smu vs exact 1/N_B).

Metrics are a fixed f32[4] vector so the rust side has one ABI for every
task; the manifest records the semantics:
  classification: [correct, valid, 0, 0]
  segmentation:   [intersection, union, 2*|A.B|, |A|+|B|]  (IoU + Dice parts)
  lm:             [correct_tokens, total_tokens, 0, 0]
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import cross_entropy


# ---------------------------------------------------------------------------
# classification (paper: cross-entropy, ResNet/AmoebaNet)
# ---------------------------------------------------------------------------

def ce_per_sample(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """f32[B,C], int32[B] -> f32[B] via the L1 fused pallas CE kernel."""
    return cross_entropy(logits, labels)


def classification_metric(logits: jax.Array, labels: jax.Array, mask: jax.Array) -> jax.Array:
    pred = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    correct = jnp.sum((pred == labels).astype(jnp.float32) * mask)
    return jnp.stack([correct, jnp.sum(mask), 0.0, 0.0])


# ---------------------------------------------------------------------------
# segmentation (paper: BCE + Dice, U-Net; eqs. 18-20)
# ---------------------------------------------------------------------------

def bce_dice_per_sample(logits: jax.Array, target: jax.Array) -> jax.Array:
    """f32[B,H,W,1] logits + f32[B,H,W,1] {0,1} masks -> f32[B].

    L_total = L_bce + L_dc, with L_dc = 1 - 2|A.B| / (|A|+|B|) computed on
    sigmoid probabilities (soft Dice), matching the paper's eq. 19-20.
    """
    b = logits.shape[0]
    lf = logits.reshape(b, -1)
    tf = target.reshape(b, -1)
    # stable BCE-with-logits, mean over pixels
    bce = jnp.mean(jnp.maximum(lf, 0.0) - lf * tf + jnp.log1p(jnp.exp(-jnp.abs(lf))), axis=-1)
    probs = jax.nn.sigmoid(lf)
    inter = jnp.sum(probs * tf, axis=-1)
    denom = jnp.sum(probs, axis=-1) + jnp.sum(tf, axis=-1)
    dice = 1.0 - (2.0 * inter + 1.0) / (denom + 1.0)
    return bce + dice


def segmentation_metric(logits: jax.Array, target: jax.Array, mask: jax.Array) -> jax.Array:
    """Hard IoU + Dice component sums at threshold logit>0 (prob>0.5)."""
    b = logits.shape[0]
    pred = (logits.reshape(b, -1) > 0.0).astype(jnp.float32)
    tf = target.reshape(b, -1)
    inter = jnp.sum(pred * tf, axis=-1) * mask
    union = (jnp.sum(jnp.maximum(pred, tf), axis=-1)) * mask
    dice_num = 2.0 * jnp.sum(pred * tf, axis=-1) * mask
    dice_den = (jnp.sum(pred, axis=-1) + jnp.sum(tf, axis=-1)) * mask
    return jnp.stack([jnp.sum(inter), jnp.sum(union), jnp.sum(dice_num), jnp.sum(dice_den)])


# ---------------------------------------------------------------------------
# language modelling (e2e driver)
# ---------------------------------------------------------------------------

def lm_ce_per_sample(logits: jax.Array, targets: jax.Array) -> jax.Array:
    """f32[B,T,V], int32[B,T] -> f32[B] (mean next-token CE per sequence)."""
    b, t, v = logits.shape
    per_tok = cross_entropy(logits.reshape(b * t, v), targets.reshape(b * t))
    return jnp.mean(per_tok.reshape(b, t), axis=-1)


def lm_metric(logits: jax.Array, targets: jax.Array, mask: jax.Array) -> jax.Array:
    pred = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    per_seq = jnp.sum((pred == targets).astype(jnp.float32), axis=-1)
    t = logits.shape[1]
    correct = jnp.sum(per_seq * mask)
    total = jnp.sum(mask) * t
    return jnp.stack([correct, total, 0.0, 0.0])
