"""Param-tree flattening + activation-memory estimation for the manifest.

The rust memory model (rust/src/memory/) reproduces the paper's capacity
arithmetic: a step fits iff resident_state + activation_bytes(batch) <=
capacity. The activation estimate is derived here from the jaxpr of the
model's value_and_grad step: every intermediate whose leading axis equals the
batch size is counted as batch-proportional (it must be live for the backward
pass), everything else as constant overhead. That mirrors what an eager
framework (the paper's PyTorch) keeps resident between forward and backward.
"""

from __future__ import annotations

from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def flatten_params(params) -> Tuple[List[str], List[jax.Array]]:
    """Deterministic (tree_flatten) order with dotted path names."""
    leaves_with_path = jax.tree_util.tree_flatten_with_path(params)[0]
    names, leaves = [], []
    for path, leaf in leaves_with_path:
        parts = []
        for p in path:
            if isinstance(p, jax.tree_util.DictKey):
                parts.append(str(p.key))
            else:
                parts.append(str(p))
        names.append(".".join(parts))
        leaves.append(leaf)
    return names, leaves


def unflatten_like(params, leaves):
    treedef = jax.tree_util.tree_structure(params)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def param_bytes(params) -> int:
    return sum(int(np.prod(l.shape)) * 4 for l in jax.tree_util.tree_leaves(params))


def dump_params(params, path: str) -> List[dict]:
    """Concatenate all leaves (f32 little-endian) into one .bin; return index."""
    names, leaves = flatten_params(params)
    index = []
    offset = 0
    with open(path, "wb") as f:
        for name, leaf in zip(names, leaves):
            arr = np.asarray(leaf, dtype="<f4")
            f.write(arr.tobytes())
            index.append(
                {
                    "name": name,
                    "shape": list(arr.shape),
                    "offset": offset,
                    "elems": int(arr.size),
                }
            )
            offset += arr.size * 4
    return index


def activation_bytes(fn, *example_args, batch: int) -> Tuple[int, int]:
    """(bytes_per_sample, fixed_bytes) from the jaxpr of `fn`.

    Sums sizes of every intermediate value; those with leading dim == batch
    are attributed per-sample, the rest to the fixed pool. Conservative in
    the same direction as eager-mode residency (no rematerialization).
    """
    jaxpr = jax.make_jaxpr(fn)(*example_args)
    per_batch_elems = 0
    fixed_elems = 0

    def visit(jp):
        nonlocal per_batch_elems, fixed_elems
        for eqn in jp.eqns:
            for sub in eqn.params.values():
                if isinstance(sub, jax.extend.core.ClosedJaxpr):
                    visit(sub.jaxpr)
                elif isinstance(sub, jax.extend.core.Jaxpr):
                    visit(sub)
            for var in eqn.outvars:
                aval = var.aval
                if not hasattr(aval, "shape"):
                    continue
                n = int(np.prod(aval.shape)) if aval.shape else 1
                # batch-proportional if the leading axis is the batch or a
                # flattened multiple of it (e.g. [B*T, d] after a reshape)
                if aval.shape and aval.shape[0] >= batch and aval.shape[0] % batch == 0:
                    per_batch_elems += n
                else:
                    fixed_elems += n

    visit(jaxpr.jaxpr)
    return (per_batch_elems * 4) // batch, fixed_elems * 4
