"""Param-tree flattening + activation-memory estimation for the manifest.

The rust memory model (rust/src/memory/) reproduces the paper's capacity
arithmetic: a step fits iff resident_state + activation_bytes(batch) <=
capacity. The activation estimate is derived here from the jaxpr of the
model's value_and_grad step: every intermediate whose leading axis equals the
batch size is counted as batch-proportional (it must be live for the backward
pass), everything else as constant overhead. That mirrors what an eager
framework (the paper's PyTorch) keeps resident between forward and backward.
"""

from __future__ import annotations

from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def flatten_params(params) -> Tuple[List[str], List[jax.Array]]:
    """Deterministic (tree_flatten) order with dotted path names."""
    leaves_with_path = jax.tree_util.tree_flatten_with_path(params)[0]
    names, leaves = [], []
    for path, leaf in leaves_with_path:
        parts = []
        for p in path:
            if isinstance(p, jax.tree_util.DictKey):
                parts.append(str(p.key))
            else:
                parts.append(str(p))
        names.append(".".join(parts))
        leaves.append(leaf)
    return names, leaves


def unflatten_like(params, leaves):
    treedef = jax.tree_util.tree_structure(params)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def param_bytes(params) -> int:
    return sum(int(np.prod(l.shape)) * 4 for l in jax.tree_util.tree_leaves(params))


def param_index(params) -> List[dict]:
    """The params-bin index (name/shape/offset/elems) WITHOUT writing the
    .bin — the metadata-only export path (manifest-drift CI) uses this so
    the rust memory model can be fed real footprints with no artifacts on
    disk. dump_params builds its index through this same function, so the
    two can never drift."""
    names, leaves = flatten_params(params)
    index = []
    offset = 0
    for name, leaf in zip(names, leaves):
        shape = list(np.shape(leaf))
        elems = int(np.prod(shape)) if shape else 1
        index.append({"name": name, "shape": shape, "offset": offset, "elems": elems})
        offset += elems * 4
    return index


def dump_params(params, path: str) -> List[dict]:
    """Concatenate all leaves (f32 little-endian) into one .bin; return the
    param_index (same leaf order, so offsets match what was written)."""
    _, leaves = flatten_params(params)
    with open(path, "wb") as f:
        for leaf in leaves:
            f.write(np.asarray(leaf, dtype="<f4").tobytes())
    return param_index(params)


def activation_bytes(fn, *example_args, batch: int) -> Tuple[int, int]:
    """(bytes_per_sample, fixed_bytes) from the jaxpr of `fn`.

    Sums sizes of every intermediate value; those with leading dim == batch
    are attributed per-sample, the rest to the fixed pool. Conservative in
    the same direction as eager-mode residency (no rematerialization).
    """
    jaxpr = jax.make_jaxpr(fn)(*example_args)
    per_batch_elems = 0
    fixed_elems = 0

    # jax >= 0.5 exposes the jaxpr classes under jax.extend.core; older
    # versions (this image ships 0.4.x) keep them in jax.core — resolve
    # once so the export runs on both
    try:
        _core = jax.extend.core
    except AttributeError:
        _core = jax.core

    def visit(jp):
        nonlocal per_batch_elems, fixed_elems
        for eqn in jp.eqns:
            for sub in eqn.params.values():
                if isinstance(sub, _core.ClosedJaxpr):
                    visit(sub.jaxpr)
                elif isinstance(sub, _core.Jaxpr):
                    visit(sub)
            for var in eqn.outvars:
                aval = var.aval
                if not hasattr(aval, "shape"):
                    continue
                n = int(np.prod(aval.shape)) if aval.shape else 1
                # batch-proportional if the leading axis is the batch or a
                # flattened multiple of it (e.g. [B*T, d] after a reshape)
                if aval.shape and aval.shape[0] >= batch and aval.shape[0] % batch == 0:
                    per_batch_elems += n
                else:
                    fixed_elems += n

    visit(jaxpr.jaxpr)
    return (per_batch_elems * 4) // batch, fixed_elems * 4
