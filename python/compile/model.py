"""L2 step-function builders: the units the AOT exporter lowers to HLO.

Three executables per (model x mu-size) variant + one per model:

  accum_step(params, acc, x, y, mask, scale)
      -> (loss_sum, metric[4], acc')
    One micro-batch of Alg. 1: forward, per-sample loss, loss normalization
    (multiply by `scale`), backward, gradient accumulation — all inside XLA,
    so the rust hot loop never sees a gradient. `mask` zeroes padded tail
    samples; `scale` carries the normalization mode:
        paper mode  (eq. 14): scale = 1 / (N_Smu * n_actual_in_ubatch)
        exact mode           : scale = 1 / N_B
    Both reduce to the same executable — the policy lives in rust
    (coordinator/accumulator.rs).

  eval_step(params, x, y, mask) -> (loss_sum, metric[4])

  apply (per model): optimizer update, see optim.py.

`baseline` (w/o MBS) training is accum_step with N_Smu = 1 and scale =
1/N_B — the identical math the paper's native mini-batch run performs,
which is what makes the with/without-MBS comparison apples-to-apples.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import optim
from .models import MODELS, ModelSpec


def build_accum_step(spec: ModelSpec):
    def accum_step(params, acc, x, y, mask, scale):
        def loss_fn(p):
            out = spec.apply(p, x)
            per = spec.loss(out, y)
            loss_sum = jnp.sum(per * mask)
            return scale[0] * loss_sum, (loss_sum, spec.metric(out, y, mask))

        (_, (loss_sum, metric)), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        acc2 = jax.tree_util.tree_map(jnp.add, acc, grads)
        return loss_sum, metric, acc2

    return accum_step


def build_eval_step(spec: ModelSpec):
    def eval_step(params, x, y, mask):
        out = spec.apply(params, x)
        per = spec.loss(out, y)
        return jnp.sum(per * mask), spec.metric(out, y, mask)

    return eval_step


def build_apply(spec: ModelSpec):
    kind = spec.optimizer
    info = optim.OPTIMIZERS[kind]
    if kind == "sgdm":

        def apply_fn(params, acc, mom, hyper):
            return optim.sgdm_apply(params, acc, mom, hyper)

    elif kind == "adam":

        def apply_fn(params, acc, m, v, hyper):
            return optim.adam_apply(params, acc, m, v, hyper)

    else:  # pragma: no cover - registry is closed
        raise ValueError(f"unknown optimizer {kind}")
    return apply_fn, info


def init_params(spec: ModelSpec, seed: int = 0):
    return spec.init(jax.random.key(seed))


__all__ = ["MODELS", "build_accum_step", "build_eval_step", "build_apply", "init_params"]
