"""Build-time compile package: L2 JAX models + L1 Pallas kernels + AOT export.

Nothing in this package is imported at runtime; ``python -m compile.aot``
produces ``artifacts/`` (HLO text + manifest + initial params) and the rust
binary is self-contained afterwards.
"""
