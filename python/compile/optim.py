"""Optimizer apply functions, exported as standalone HLO executables.

The rust coordinator owns optimizer *state lifecycle* (allocation, threading
through execute_b calls); the *math* lives here so it lowers into XLA next to
the model. Each apply takes (params, acc, slots..., hyper) and returns
(params', slots'..., acc_zero) — returning a zeroed accumulator keeps the
entire update on-device: no host round-trip is needed between mini-batches.

Semantics follow PyTorch (the paper's substrate):
  SGD+momentum:  g += wd*p ; v = m*v + g ; p -= lr*v
  Adam (classic L2 decay): g += wd*p ; m,v EMA ; p -= lr*mhat/(sqrt(vhat)+eps)

Hyper-parameters arrive as a small f32 vector so one executable serves every
schedule (the LR scheduler lives in rust, per the AmoebaNet linear-decay
setup in the paper's section 4.2.4). Duplicate sub-expressions between the
per-output tree_maps are CSE'd by XLA, so each executable computes the
update once.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

SGDM_HYPER = ["lr", "momentum", "weight_decay"]
ADAM_HYPER = ["lr", "beta1", "beta2", "eps", "weight_decay", "step"]

_tmap = jax.tree_util.tree_map


def sgdm_apply(params, acc, mom, hyper):
    """(params, acc, mom, f32[3]) -> (params', mom', acc_zero)."""
    lr, m, wd = hyper[0], hyper[1], hyper[2]

    def new_v(p, g, v):
        return m * v + (g + wd * p)

    mom2 = _tmap(new_v, params, acc, mom)
    params2 = _tmap(lambda p, v2: p - lr * v2, params, mom2)
    acc0 = _tmap(jnp.zeros_like, acc)
    return params2, mom2, acc0


def adam_apply(params, acc, m, v, hyper):
    """(params, acc, m, v, f32[6]) -> (params', m', v', acc_zero)."""
    lr, b1, b2, eps, wd, t = (hyper[0], hyper[1], hyper[2], hyper[3], hyper[4], hyper[5])
    bc1 = 1.0 - jnp.power(b1, t)
    bc2 = 1.0 - jnp.power(b2, t)

    m2 = _tmap(lambda p, g, mi: b1 * mi + (1.0 - b1) * (g + wd * p), params, acc, m)
    v2 = _tmap(lambda p, g, vi: b2 * vi + (1.0 - b2) * (g + wd * p) ** 2, params, acc, v)
    params2 = _tmap(
        lambda p, mi2, vi2: p - lr * (mi2 / bc1) / (jnp.sqrt(vi2 / bc2) + eps),
        params,
        m2,
        v2,
    )
    acc0 = _tmap(jnp.zeros_like, acc)
    return params2, m2, v2, acc0


OPTIMIZERS = {
    "sgdm": {"slots": 1, "hyper": SGDM_HYPER, "apply": sgdm_apply},
    "adam": {"slots": 2, "hyper": ADAM_HYPER, "apply": adam_apply},
}
