"""AOT exporter: lowers every (model x size x mu) variant to HLO text.

Usage:  cd python && python -m compile.aot --out-dir ../artifacts

Produces:
  artifacts/<model>_s<size>_mu<mu>.accum.hlo.txt
  artifacts/<model>_s<size>_mu<mu>.eval.hlo.txt
  artifacts/<model>.apply.hlo.txt
  artifacts/<model>.params.bin          (f32 LE, leaves in tree order)
  artifacts/manifest.json               (shapes, offsets, memory estimates)

Interchange is HLO *text*: the image's xla_extension 0.5.1 rejects jax>=0.5
serialized HloModuleProto (64-bit instruction ids), but its text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import argparse
import json
import os
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import optim, shapes
from .model import MODELS, build_accum_step, build_apply, build_eval_step, init_params

# (model, image-size-or-seqlen, mu). The mu values give: the paper's
# "half-native" mu for the Fig.3 / T3 comparisons and the "native max" mu
# used for every large-batch row of T4/T5 (section 4.3.2: "the maximum size
# that can compute on GPU").
VARIANTS: List[Tuple[str, int, int]] = [
    ("microresnet18", 16, 8),
    ("microresnet18", 16, 16),
    ("microresnet18", 32, 16),  # Table 1 high-res point
    ("microresnet34", 16, 4),
    ("microresnet34", 16, 8),
    ("amoebacell", 24, 16),
    ("amoebacell", 24, 32),
    ("microunet", 24, 8),
    ("microunet", 24, 16),
    ("microunet", 48, 16),  # Table 1 high-res point
    ("microformer", 64, 4),
    ("microformer", 64, 8),
]

DTYPE_NAMES = {jnp.float32.dtype: "f32", jnp.int32.dtype: "i32"}


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _abstract(tree):
    return jax.tree_util.tree_map(lambda l: _sds(l.shape, l.dtype), tree)


def export_model(
    model_key: str, out_dir: str, seed: int, quiet: bool, metadata_only: bool = False
) -> dict:
    """Export one model's artifacts + manifest entry.

    With metadata_only=True nothing is lowered or written except the
    manifest entry itself: the param index and the activation-memory
    estimates are still computed (both are pure tracing/array arithmetic),
    which is exactly what `mbs frontier --dry-run --model ...` needs to
    classify the REAL models' memory frontier — the manifest-drift CI job
    runs this per push without paying for XLA lowering.
    """
    spec = MODELS[model_key]
    params = init_params(spec, seed)
    names, leaves = shapes.flatten_params(params)
    pbin = f"{model_key}.params.bin"
    if metadata_only:
        index = shapes.param_index(params)
    else:
        index = shapes.dump_params(params, os.path.join(out_dir, pbin))
    pbytes = shapes.param_bytes(params)

    info = optim.OPTIMIZERS[spec.optimizer]
    apply_fn, _ = build_apply(spec)
    aparams = _abstract(params)
    hyper = _sds((len(info["hyper"]),), jnp.float32)
    slot_args = [aparams] * info["slots"]
    apply_name = f"{model_key}.apply.hlo.txt"
    if not metadata_only:
        lowered = jax.jit(apply_fn).lower(aparams, aparams, *slot_args, hyper)
        with open(os.path.join(out_dir, apply_name), "w") as f:
            f.write(to_hlo_text(lowered))
        if not quiet:
            print(f"  apply   -> {apply_name}")

    entry = {
        "task": spec.task,
        "optimizer": {
            "kind": spec.optimizer,
            "slots": info["slots"],
            "hyper_names": info["hyper"],
            "hyper_defaults": list(spec.hyper),
        },
        "params_bin": pbin,
        "param_leaves": index,
        "param_bytes": pbytes,
        "apply_hlo": apply_name,
        "metric_semantics": spec.task,
        "default_size": spec.default_size,
        "variants": [],
    }

    accum = build_accum_step(spec)
    eval_step = build_eval_step(spec)
    for mk, size, mu in VARIANTS:
        if mk != model_key:
            continue
        (x_shape, x_dtype), (y_shape, y_dtype) = spec.io_shapes(mu, size)
        x = _sds(x_shape, x_dtype)
        y = _sds(y_shape, y_dtype)
        mask = _sds((mu,), jnp.float32)
        scale = _sds((1,), jnp.float32)

        tag = f"{model_key}_s{size}_mu{mu}"
        accum_name = f"{tag}.accum.hlo.txt"
        eval_name = f"{tag}.eval.hlo.txt"
        if not metadata_only:
            acc_lowered = jax.jit(accum).lower(aparams, aparams, x, y, mask, scale)
            with open(os.path.join(out_dir, accum_name), "w") as f:
                f.write(to_hlo_text(acc_lowered))
            ev_lowered = jax.jit(eval_step).lower(aparams, x, y, mask)
            with open(os.path.join(out_dir, eval_name), "w") as f:
                f.write(to_hlo_text(ev_lowered))

        # activation residency estimate for the rust memory model, from the
        # jaxpr of the fwd+bwd step (see shapes.py docstring)
        def step_for_mem(p, xx, yy, mm, ss):
            def lf(q):
                out = spec.apply(q, xx)
                return ss[0] * jnp.sum(spec.loss(out, yy) * mm)

            return jax.value_and_grad(lf)(p)

        per_sample, fixed = shapes.activation_bytes(
            step_for_mem, aparams, x, y, mask, scale, batch=mu
        )
        entry["variants"].append(
            {
                "mu": mu,
                "size": size,
                "x_shape": list(x_shape),
                "x_dtype": DTYPE_NAMES[jnp.dtype(x_dtype)],
                "y_shape": list(y_shape),
                "y_dtype": DTYPE_NAMES[jnp.dtype(y_dtype)],
                "accum_hlo": accum_name,
                "eval_hlo": eval_name,
                "activation_bytes_per_sample": per_sample,
                "fixed_bytes": fixed,
            }
        )
        if not quiet:
            print(
                f"  variant -> {tag}: act/sample={per_sample/1e3:.1f}KB"
                f" fixed={fixed/1e6:.2f}MB"
            )
    return entry


def export_variant(model_key: str, size: int, mu: int, out_dir: str, quiet: bool) -> None:
    """Lower exactly one (model, size, mu) variant's accum/eval pair.

    The on-demand path behind the rust artifact manager
    (`rust/src/runtime/artifacts.rs`): the manifest metadata for an
    arbitrary mu is derived rust-side (shapes re-lead, memory estimates are
    per-sample), so only the two HLO payloads are produced here — the
    manifest on disk is left untouched.
    """
    spec = MODELS[model_key]
    params = init_params(spec, 0)
    aparams = _abstract(params)
    accum = build_accum_step(spec)
    eval_step = build_eval_step(spec)
    (x_shape, x_dtype), (y_shape, y_dtype) = spec.io_shapes(mu, size)
    x = _sds(x_shape, x_dtype)
    y = _sds(y_shape, y_dtype)
    mask = _sds((mu,), jnp.float32)
    scale = _sds((1,), jnp.float32)
    tag = f"{model_key}_s{size}_mu{mu}"
    acc_lowered = jax.jit(accum).lower(aparams, aparams, x, y, mask, scale)
    with open(os.path.join(out_dir, f"{tag}.accum.hlo.txt"), "w") as f:
        f.write(to_hlo_text(acc_lowered))
    ev_lowered = jax.jit(eval_step).lower(aparams, x, y, mask)
    with open(os.path.join(out_dir, f"{tag}.eval.hlo.txt"), "w") as f:
        f.write(to_hlo_text(ev_lowered))
    if not quiet:
        print(f"  variant -> {tag} (on demand)")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--models", nargs="*", default=None, help="subset of model keys")
    ap.add_argument("--quiet", action="store_true")
    ap.add_argument(
        "--metadata-only",
        action="store_true",
        help="write manifest.json only (param index + memory estimates; no HLO "
        "lowering, no params.bin) — feeds `mbs frontier --dry-run --model` so "
        "CI catches manifest-footprint drift without a full export",
    )
    ap.add_argument(
        "--variant",
        action="append",
        default=None,
        metavar="MODEL:SIZE:MU",
        help="lower exactly this variant's accum/eval HLO pair and exit "
        "without touching manifest.json (the rust artifact manager's "
        "on-demand compile path); repeatable",
    )
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    if args.variant:
        for spec_str in args.variant:
            try:
                model_key, size_s, mu_s = spec_str.split(":")
                size, mu = int(size_s), int(mu_s)
            except ValueError:
                ap.error(f"--variant wants MODEL:SIZE:MU, got {spec_str!r}")
            if model_key not in MODELS:
                ap.error(f"--variant: unknown model {model_key!r}")
            if not args.quiet:
                print(f"[aot] {model_key} s{size} mu{mu} (single variant)")
            export_variant(model_key, size, mu, args.out_dir, args.quiet)
        return

    model_keys = args.models or sorted({mk for mk, _, _ in VARIANTS})
    manifest = {"version": 1, "seed": args.seed, "models": {}}
    for mk in model_keys:
        if not args.quiet:
            print(f"[aot] {mk}" + (" (metadata only)" if args.metadata_only else ""))
        manifest["models"][mk] = export_model(
            mk, args.out_dir, args.seed, args.quiet, metadata_only=args.metadata_only
        )

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    if not args.quiet:
        print(f"[aot] wrote {os.path.join(args.out_dir, 'manifest.json')}")


if __name__ == "__main__":
    main()
