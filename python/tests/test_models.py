"""L2 model zoo: shapes, dtypes, finiteness, parameter accounting."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import shapes
from compile.model import MODELS, init_params


@pytest.fixture(scope="module")
def rng():
    return jax.random.key(42)


CLASSIFIERS = ["microresnet18", "microresnet34", "amoebacell"]


@pytest.mark.parametrize("key", CLASSIFIERS)
def test_classifier_output_shape(key, rng):
    spec = MODELS[key]
    params = init_params(spec, seed=1)
    b, s = 2, spec.default_size
    x = jax.random.normal(rng, (b, s, s, 3), dtype=jnp.float32)
    logits = spec.apply(params, x)
    assert logits.shape == (b, 102)
    assert logits.dtype == jnp.float32
    assert np.all(np.isfinite(np.asarray(logits)))


def test_unet_output_shape(rng):
    spec = MODELS["microunet"]
    params = init_params(spec, seed=1)
    x = jax.random.normal(rng, (2, 24, 24, 3), dtype=jnp.float32)
    out = spec.apply(params, x)
    assert out.shape == (2, 24, 24, 1)
    assert np.all(np.isfinite(np.asarray(out)))


def test_unet_handles_other_resolutions(rng):
    spec = MODELS["microunet"]
    params = init_params(spec, seed=1)
    x = jax.random.normal(rng, (1, 48, 48, 3), dtype=jnp.float32)
    assert spec.apply(params, x).shape == (1, 48, 48, 1)


def test_transformer_output_shape(rng):
    spec = MODELS["microformer"]
    params = init_params(spec, seed=1)
    tokens = jax.random.randint(rng, (2, 64), 0, 512, dtype=jnp.int32)
    logits = spec.apply(params, tokens)
    assert logits.shape == (2, 64, 512)


def test_transformer_is_causal(rng):
    """Changing a future token must not change past logits."""
    spec = MODELS["microformer"]
    params = init_params(spec, seed=1)
    t1 = jax.random.randint(rng, (1, 64), 0, 512, dtype=jnp.int32)
    t2 = t1.at[0, 63].set((t1[0, 63] + 1) % 512)
    l1 = spec.apply(params, t1)
    l2 = spec.apply(params, t2)
    np.testing.assert_allclose(l1[0, :63], l2[0, :63], rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("key", list(MODELS))
def test_param_flatten_roundtrip(key):
    spec = MODELS[key]
    params = init_params(spec, seed=0)
    names, leaves = shapes.flatten_params(params)
    assert len(names) == len(leaves) == len(set(names))
    rebuilt = shapes.unflatten_like(params, leaves)
    for a, b in zip(jax.tree_util.tree_leaves(params), jax.tree_util.tree_leaves(rebuilt)):
        np.testing.assert_array_equal(a, b)


@pytest.mark.parametrize("key", list(MODELS))
def test_param_bytes_positive_and_consistent(key):
    spec = MODELS[key]
    params = init_params(spec, seed=0)
    pb = shapes.param_bytes(params)
    _, leaves = shapes.flatten_params(params)
    assert pb == sum(l.size * 4 for l in leaves)
    assert pb > 10_000  # not a degenerate model


def test_init_deterministic():
    a = init_params(MODELS["microresnet18"], seed=7)
    b = init_params(MODELS["microresnet18"], seed=7)
    for x, y in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)):
        np.testing.assert_array_equal(x, y)


def test_init_seed_sensitivity():
    a = init_params(MODELS["microresnet18"], seed=7)
    b = init_params(MODELS["microresnet18"], seed=8)
    diffs = [
        float(jnp.max(jnp.abs(x - y)))
        for x, y in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b))
    ]
    assert max(diffs) > 0.0


def test_dump_params_roundtrip(tmp_path):
    spec = MODELS["microresnet18"]
    params = init_params(spec, seed=3)
    path = tmp_path / "p.bin"
    index = shapes.dump_params(params, str(path))
    raw = np.fromfile(path, dtype="<f4")
    names, leaves = shapes.flatten_params(params)
    assert [e["name"] for e in index] == names
    for entry, leaf in zip(index, leaves):
        start = entry["offset"] // 4
        seg = raw[start : start + entry["elems"]].reshape(entry["shape"])
        np.testing.assert_array_equal(seg, np.asarray(leaf))


def test_activation_bytes_scales_with_resolution():
    spec = MODELS["microresnet18"]
    params = init_params(spec, seed=0)

    def make(size):
        x = jax.ShapeDtypeStruct((4, size, size, 3), jnp.float32)
        y = jax.ShapeDtypeStruct((4,), jnp.int32)

        def f(p, xx, yy):
            return jnp.sum(spec.loss(spec.apply(p, xx), yy))

        return shapes.activation_bytes(f, params, x, y, batch=4)[0]

    small, large = make(16), make(32)
    assert large > 2.5 * small  # ~4x pixels => ~4x activations
