"""Optimizer apply functions vs hand-written numpy references (PyTorch
semantics, matching the paper's substrate)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import optim


def _tree(seed, shapes):
    ks = jax.random.split(jax.random.key(seed), len(shapes))
    return {f"p{i}": jax.random.normal(k, s, dtype=jnp.float32) for i, (k, s) in enumerate(zip(ks, shapes))}


SHAPES = [(3, 4), (7,), (2, 2, 2)]


def test_sgdm_matches_pytorch_semantics():
    params = _tree(0, SHAPES)
    grads = _tree(1, SHAPES)
    mom = _tree(2, SHAPES)
    lr, m, wd = 0.01, 0.9, 5e-4
    hyper = jnp.array([lr, m, wd], jnp.float32)
    p2, v2, acc0 = optim.sgdm_apply(params, grads, mom, hyper)
    for k in params:
        g = np.asarray(grads[k]) + wd * np.asarray(params[k])
        v_ref = m * np.asarray(mom[k]) + g
        p_ref = np.asarray(params[k]) - lr * v_ref
        np.testing.assert_allclose(v2[k], v_ref, rtol=1e-6)
        np.testing.assert_allclose(p2[k], p_ref, rtol=1e-6)
        np.testing.assert_array_equal(acc0[k], np.zeros_like(p_ref))


def test_sgdm_zero_momentum_is_plain_sgd():
    params = _tree(3, SHAPES)
    grads = _tree(4, SHAPES)
    mom = jax.tree_util.tree_map(jnp.zeros_like, params)
    hyper = jnp.array([0.1, 0.0, 0.0], jnp.float32)
    p2, _, _ = optim.sgdm_apply(params, grads, mom, hyper)
    for k in params:
        np.testing.assert_allclose(
            p2[k], np.asarray(params[k]) - 0.1 * np.asarray(grads[k]), rtol=1e-6
        )


def test_adam_matches_reference():
    params = _tree(5, SHAPES)
    grads = _tree(6, SHAPES)
    m = _tree(7, SHAPES)
    m = jax.tree_util.tree_map(lambda x: 0.1 * x, m)
    v = jax.tree_util.tree_map(lambda x: jnp.abs(x) * 0.01, _tree(8, SHAPES))
    lr, b1, b2, eps, wd, t = 1e-3, 0.9, 0.999, 1e-8, 0.01, 3.0
    hyper = jnp.array([lr, b1, b2, eps, wd, t], jnp.float32)
    p2, m2, v2, acc0 = optim.adam_apply(params, grads, m, v, hyper)
    for k in params:
        g = np.asarray(grads[k]) + wd * np.asarray(params[k])
        m_ref = b1 * np.asarray(m[k]) + (1 - b1) * g
        v_ref = b2 * np.asarray(v[k]) + (1 - b2) * g * g
        mhat = m_ref / (1 - b1**t)
        vhat = v_ref / (1 - b2**t)
        p_ref = np.asarray(params[k]) - lr * mhat / (np.sqrt(vhat) + eps)
        np.testing.assert_allclose(m2[k], m_ref, rtol=1e-5)
        np.testing.assert_allclose(v2[k], v_ref, rtol=1e-5)
        np.testing.assert_allclose(p2[k], p_ref, rtol=1e-5)
        np.testing.assert_array_equal(acc0[k], 0.0 * np.asarray(params[k]))


def test_adam_first_step_bias_correction():
    """From zero moments at t=1, the fully-corrected update is exactly lr
    (sign-SGD-like); without correction it would be ~3.16x lr here."""
    params = {"w": jnp.ones((4,), jnp.float32)}
    grads = {"w": jnp.full((4,), 0.5, jnp.float32)}
    zeros = {"w": jnp.zeros((4,), jnp.float32)}
    hyper = jnp.array([1e-3, 0.9, 0.999, 1e-8, 0.0, 1.0], jnp.float32)
    p2, _, _, _ = optim.adam_apply(params, grads, zeros, zeros, hyper)
    step = float(jnp.max(jnp.abs(p2["w"] - params["w"])))
    assert step == pytest.approx(1e-3, rel=1e-3)


def test_registry_slots():
    assert optim.OPTIMIZERS["sgdm"]["slots"] == 1
    assert optim.OPTIMIZERS["adam"]["slots"] == 2
    assert optim.OPTIMIZERS["sgdm"]["hyper"] == ["lr", "momentum", "weight_decay"]
    assert optim.OPTIMIZERS["adam"]["hyper"][-1] == "step"
