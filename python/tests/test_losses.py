"""Loss + metric vector semantics (the fixed f32[4] ABI the rust side reads)."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import losses


def test_ce_per_sample_shape():
    logits = jax.random.normal(jax.random.key(0), (6, 102), dtype=jnp.float32)
    labels = jnp.arange(6, dtype=jnp.int32)
    per = losses.ce_per_sample(logits, labels)
    assert per.shape == (6,)
    assert float(jnp.min(per)) > 0.0


def test_classification_metric_counts_correct():
    logits = jnp.array(
        [[10.0, 0.0, 0.0], [0.0, 10.0, 0.0], [0.0, 0.0, 10.0], [10.0, 0.0, 0.0]], jnp.float32
    )
    labels = jnp.array([0, 1, 0, 0], jnp.int32)  # 3 correct
    mask = jnp.ones((4,), jnp.float32)
    m = losses.classification_metric(logits, labels, mask)
    np.testing.assert_allclose(m, [3.0, 4.0, 0.0, 0.0])


def test_classification_metric_respects_mask():
    logits = jnp.eye(4, dtype=jnp.float32) * 10.0
    labels = jnp.arange(4, dtype=jnp.int32)  # all correct
    mask = jnp.array([1.0, 1.0, 0.0, 0.0], jnp.float32)
    m = losses.classification_metric(logits, labels, mask)
    np.testing.assert_allclose(m, [2.0, 2.0, 0.0, 0.0])


def test_bce_dice_perfect_prediction_low_loss():
    target = (jax.random.uniform(jax.random.key(1), (2, 8, 8, 1)) > 0.5).astype(jnp.float32)
    logits = (target * 2 - 1) * 20.0  # confident correct logits
    per = losses.bce_dice_per_sample(logits, target)
    assert per.shape == (2,)
    assert float(jnp.max(per)) < 0.05


def test_bce_dice_wrong_prediction_high_loss():
    target = jnp.ones((1, 8, 8, 1), jnp.float32)
    logits = -20.0 * jnp.ones((1, 8, 8, 1), jnp.float32)
    per = losses.bce_dice_per_sample(logits, target)
    assert float(per[0]) > 10.0


def test_segmentation_metric_iou_dice_components():
    # pred mask: logit>0. 2x2 image, pred = [[1,1],[0,0]], target = [[1,0],[1,0]]
    logits = jnp.array([[[[1.0], [1.0]], [[-1.0], [-1.0]]]], jnp.float32)
    target = jnp.array([[[[1.0], [0.0]], [[1.0], [0.0]]]], jnp.float32)
    mask = jnp.ones((1,), jnp.float32)
    m = losses.segmentation_metric(logits, target, mask)
    # inter=1, union=3, dice_num=2*1, dice_den=2+2
    np.testing.assert_allclose(m, [1.0, 3.0, 2.0, 4.0])


def test_lm_loss_and_metric():
    b, t, v = 2, 5, 16
    logits = jnp.zeros((b, t, v), jnp.float32)
    logits = logits.at[:, :, 3].set(10.0)  # always predicts token 3
    targets = jnp.full((b, t), 3, jnp.int32)
    per = losses.lm_ce_per_sample(logits, targets)
    assert per.shape == (b,)
    assert float(jnp.max(per)) < 1e-3
    m = losses.lm_metric(logits, targets, jnp.ones((b,), jnp.float32))
    np.testing.assert_allclose(m, [b * t, b * t, 0.0, 0.0])


def test_lm_metric_masked():
    b, t, v = 3, 4, 8
    logits = jnp.zeros((b, t, v), jnp.float32).at[:, :, 0].set(5.0)
    targets = jnp.zeros((b, t), jnp.int32)
    mask = jnp.array([1.0, 0.0, 1.0], jnp.float32)
    m = losses.lm_metric(logits, targets, mask)
    np.testing.assert_allclose(m, [2 * t, 2 * t, 0.0, 0.0])
