"""L1 kernel correctness: pallas vs pure-jnp oracle (ref.py).

Hypothesis sweeps shapes (including non-block-multiple raggedness) — the
CORE correctness signal for the kernels that every exported HLO embeds.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import cross_entropy, matmul, matmul_raw
from compile.kernels import fused_ce, matmul_pallas
from compile.kernels.ref import (
    cross_entropy_grad_ref,
    cross_entropy_ref,
    matmul_ref,
)

settings.register_profile("kernels", max_examples=25, deadline=None)
settings.load_profile("kernels")


def _rand(key, shape):
    return jax.random.normal(jax.random.key(key), shape, dtype=jnp.float32)


# ---------------------------------------------------------------------------
# matmul
# ---------------------------------------------------------------------------

@given(
    m=st.integers(1, 90),
    k=st.integers(1, 90),
    n=st.integers(1, 90),
    seed=st.integers(0, 2**16),
)
def test_matmul_matches_ref(m, k, n, seed):
    kx, ky = jax.random.split(jax.random.key(seed))
    x = jax.random.normal(kx, (m, k), dtype=jnp.float32)
    y = jax.random.normal(ky, (k, n), dtype=jnp.float32)
    np.testing.assert_allclose(matmul_raw(x, y), matmul_ref(x, y), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("shape", [(128, 128, 128), (256, 128, 64), (33, 65, 17)])
def test_matmul_block_boundaries(shape):
    m, k, n = shape
    x = _rand(0, (m, k))
    y = _rand(1, (k, n))
    np.testing.assert_allclose(matmul_raw(x, y), matmul_ref(x, y), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("bm,bk,bn", [(16, 16, 16), (32, 64, 16), (128, 128, 128)])
def test_matmul_block_shape_invariance(bm, bk, bn):
    x = _rand(2, (70, 50))
    y = _rand(3, (50, 40))
    got = matmul_raw(x, y, bm=bm, bk=bk, bn=bn)
    np.testing.assert_allclose(got, matmul_ref(x, y), rtol=1e-4, atol=1e-4)


def test_matmul_grad_matches_ref():
    x = _rand(4, (24, 40))
    y = _rand(5, (40, 12))

    def f(x, y):
        return jnp.sum(jnp.sin(matmul(x, y)))

    def f_ref(x, y):
        return jnp.sum(jnp.sin(x @ y))

    gx, gy = jax.grad(f, argnums=(0, 1))(x, y)
    gx_r, gy_r = jax.grad(f_ref, argnums=(0, 1))(x, y)
    np.testing.assert_allclose(gx, gx_r, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(gy, gy_r, rtol=1e-4, atol=1e-4)


def test_matmul_rejects_bad_shapes():
    with pytest.raises(ValueError):
        matmul_raw(jnp.zeros((2, 3)), jnp.zeros((4, 5)))
    with pytest.raises(ValueError):
        matmul_raw(jnp.zeros((2, 3, 4)), jnp.zeros((4, 5)))


def test_matmul_vmem_footprint_under_budget():
    # default blocks must fit comfortably in one TPU core's ~16MiB VMEM
    assert matmul_pallas.vmem_footprint_bytes() <= 16 * 2**20 // 4


def test_mxu_utilization_estimate():
    assert matmul_pallas.mxu_utilization_estimate(128, 128, 128) == 1.0
    assert matmul_pallas.mxu_utilization_estimate(129, 128, 128) < 1.0


# ---------------------------------------------------------------------------
# fused cross-entropy
# ---------------------------------------------------------------------------

@given(
    b=st.integers(1, 40),
    c=st.integers(2, 200),
    seed=st.integers(0, 2**16),
)
def test_ce_matches_ref(b, c, seed):
    kl, ky = jax.random.split(jax.random.key(seed))
    logits = 5.0 * jax.random.normal(kl, (b, c), dtype=jnp.float32)
    labels = jax.random.randint(ky, (b,), 0, c, dtype=jnp.int32)
    np.testing.assert_allclose(
        cross_entropy(logits, labels), cross_entropy_ref(logits, labels), rtol=1e-4, atol=1e-4
    )


def test_ce_extreme_logits_stable():
    logits = jnp.array([[1000.0, -1000.0, 0.0], [-1000.0, 1000.0, 500.0]], jnp.float32)
    labels = jnp.array([0, 1], jnp.int32)
    got = cross_entropy(logits, labels)
    assert np.all(np.isfinite(np.asarray(got)))
    np.testing.assert_allclose(got, cross_entropy_ref(logits, labels), rtol=1e-4, atol=1e-4)


@given(b=st.integers(1, 24), c=st.integers(2, 150), seed=st.integers(0, 2**16))
def test_ce_grad_matches_ref(b, c, seed):
    kl, ky, kg = jax.random.split(jax.random.key(seed), 3)
    logits = jax.random.normal(kl, (b, c), dtype=jnp.float32)
    labels = jax.random.randint(ky, (b,), 0, c, dtype=jnp.int32)
    g = jax.random.normal(kg, (b,), dtype=jnp.float32)

    dlogits = jax.grad(lambda l: jnp.sum(cross_entropy(l, labels) * g))(logits)
    ref = cross_entropy_grad_ref(logits, labels, g)
    np.testing.assert_allclose(dlogits, ref, rtol=1e-4, atol=1e-4)


def test_ce_padding_classes_get_zero_grad():
    # classes are padded to LANE multiples with -inf; gradient w.r.t. real
    # logits must be unaffected by padding
    b, c = 4, 7
    logits = _rand(6, (b, c))
    labels = jnp.array([0, 1, 2, 3], jnp.int32)
    d = jax.grad(lambda l: jnp.sum(cross_entropy(l, labels)))(logits)
    ref = cross_entropy_grad_ref(logits, labels, jnp.ones((b,), jnp.float32))
    np.testing.assert_allclose(d, ref, rtol=1e-4, atol=1e-4)
    assert d.shape == (b, c)


def test_ce_vmem_footprint():
    assert fused_ce.vmem_footprint_bytes(8, 102) == 4 * (2 * 8 * 128 + 3 * 8)
