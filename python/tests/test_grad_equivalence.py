"""The paper's core invariant (section 3.4, eqs. 14-17): accumulated
loss-normalized micro-batch gradients equal the full mini-batch gradient.

Verified here in pure JAX for every model; the same invariant is re-verified
through the rust runtime on the exported HLO in rust/tests/. Also includes
the BatchNorm counterexample the paper glosses over (cross-sample statistics
break exact equivalence), documenting why the zoo uses GroupNorm.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.model import MODELS, build_accum_step, init_params

settings.register_profile("equiv", max_examples=8, deadline=None)
settings.load_profile("equiv")


def _make_batch(spec, n, size, seed):
    kx, ky = jax.random.split(jax.random.key(seed))
    (xs, xdt), (ys, ydt) = spec.io_shapes(n, size)
    if xdt == jnp.int32:
        x = jax.random.randint(kx, xs, 0, 512, dtype=jnp.int32)
    else:
        x = jax.random.normal(kx, xs, dtype=jnp.float32)
    if ydt == jnp.int32:
        hi = 512 if spec.task == "lm" else 102
        y = jax.random.randint(ky, ys, 0, hi, dtype=jnp.int32)
    else:
        y = (jax.random.uniform(ky, ys) > 0.5).astype(jnp.float32)
    return x, y


def _full_batch_grad(spec, params, x, y):
    n = x.shape[0]

    def lf(p):
        per = spec.loss(spec.apply(p, x), y)
        return jnp.mean(per)

    return jax.grad(lf)(params)


def _mbs_grad(spec, params, x, y, mu, mode):
    """Run the exported accum_step semantics over micro-batch slices."""
    n = x.shape[0]
    accum = build_accum_step(spec)
    acc = jax.tree_util.tree_map(jnp.zeros_like, params)
    n_smu = -(-n // mu)
    for j in range(n_smu):
        lo, hi = j * mu, min((j + 1) * mu, n)
        actual = hi - lo
        # pad ragged tail to the static mu shape, mask the padding
        idx = jnp.arange(mu)
        src = jnp.clip(lo + idx, 0, n - 1)
        xj = x[src]
        yj = y[src]
        mask = (idx < actual).astype(jnp.float32)
        if mode == "exact":
            scale = jnp.array([1.0 / n], jnp.float32)
        else:  # paper (eq. 14): mean over the micro-batch, then 1/N_Smu
            scale = jnp.array([1.0 / (n_smu * actual)], jnp.float32)
        _, _, acc = accum(params, acc, xj, yj, mask, scale)
    return acc


# microformer's positional table is fixed to seq_len=64
SMALL_SIZE = {"microresnet18": 8, "microresnet34": 8, "amoebacell": 8, "microunet": 8, "microformer": 64}


@pytest.mark.parametrize("key", list(MODELS))
def test_even_split_equivalence_both_modes(key):
    spec = MODELS[key]
    params = init_params(spec, seed=0)
    size = SMALL_SIZE[key]
    x, y = _make_batch(spec, 8, size, seed=1)
    ref = _full_batch_grad(spec, params, x, y)
    for mode in ("exact", "paper"):
        acc = _mbs_grad(spec, params, x, y, mu=4, mode=mode)
        for a, r in zip(jax.tree_util.tree_leaves(acc), jax.tree_util.tree_leaves(ref)):
            np.testing.assert_allclose(a, r, rtol=2e-4, atol=2e-5)


@given(n=st.integers(3, 12), mu=st.integers(1, 8), seed=st.integers(0, 100))
def test_exact_mode_equivalence_ragged(n, mu, seed):
    """exact mode (scale=1/N_B + tail mask) is equivalent for ANY (N_B, mu)."""
    spec = MODELS["microresnet18"]
    params = init_params(spec, seed=0)
    x, y = _make_batch(spec, n, 8, seed=seed)
    ref = _full_batch_grad(spec, params, x, y)
    acc = _mbs_grad(spec, params, x, y, mu=mu, mode="exact")
    for a, r in zip(jax.tree_util.tree_leaves(acc), jax.tree_util.tree_leaves(ref)):
        np.testing.assert_allclose(a, r, rtol=3e-4, atol=3e-5)


def test_paper_mode_biased_on_ragged_tail():
    """Paper mode (eq. 14) weights the ragged tail's samples more — the bias
    the A1 ablation quantifies. With N_B=6, mu=4 the tail has 2 samples that
    get weight 1/(2*2) vs 1/(2*4) for the rest, so gradients differ."""
    spec = MODELS["microresnet18"]
    params = init_params(spec, seed=0)
    x, y = _make_batch(spec, 6, 8, seed=3)
    ref = _full_batch_grad(spec, params, x, y)
    acc = _mbs_grad(spec, params, x, y, mu=4, mode="paper")
    max_rel = 0.0
    for a, r in zip(jax.tree_util.tree_leaves(acc), jax.tree_util.tree_leaves(ref)):
        denom = np.maximum(np.abs(np.asarray(r)), 1e-8)
        max_rel = max(max_rel, float(np.max(np.abs(np.asarray(a - r)) / denom)))
    assert max_rel > 1e-3  # visibly biased, unlike exact mode


def test_loss_normalization_is_required():
    """Without the 1/N_Smu scale (plain accumulation), the gradient is
    N_Smu x too large — eq. 13's inequality."""
    spec = MODELS["microresnet18"]
    params = init_params(spec, seed=0)
    x, y = _make_batch(spec, 8, 8, seed=5)
    ref = _full_batch_grad(spec, params, x, y)
    accum = build_accum_step(spec)
    acc = jax.tree_util.tree_map(jnp.zeros_like, params)
    for j in range(2):
        xj, yj = x[j * 4 : (j + 1) * 4], y[j * 4 : (j + 1) * 4]
        mask = jnp.ones((4,), jnp.float32)
        scale = jnp.array([1.0 / 4.0], jnp.float32)  # mean, but NO 1/N_Smu
        _, _, acc = accum(params, acc, xj, yj, mask, scale)
    ratios = []
    for a, r in zip(jax.tree_util.tree_leaves(acc), jax.tree_util.tree_leaves(ref)):
        r = np.asarray(r)
        big = np.abs(r) > 1e-4
        if big.any():
            ratios.append(float(np.median(np.asarray(a)[big] / r[big])))
    assert np.isclose(np.median(ratios), 2.0, rtol=0.05)  # N_Smu = 2


def test_batchnorm_breaks_equivalence():
    """Train-mode BatchNorm statistics couple samples across the batch, so
    micro-batching changes the function itself — not just the gradient
    schedule. This is why the zoo normalizes with GroupNorm."""

    tgt = jax.random.normal(jax.random.key(2), (8, 4), dtype=jnp.float32)

    def bn_net(p, x, t):  # toy net with batch statistics + per-sample target
        h = jax.nn.tanh(x @ p["w"])
        mean = jnp.mean(h, axis=0, keepdims=True)
        var = jnp.var(h, axis=0, keepdims=True)
        h = (h - mean) / jnp.sqrt(var + 1e-5)
        return jnp.mean((h - t) ** 2, axis=-1)

    key = jax.random.key(0)
    p = {"w": jax.random.normal(key, (6, 4), dtype=jnp.float32)}
    x = jax.random.normal(jax.random.key(1), (8, 6), dtype=jnp.float32)

    full = jax.grad(lambda q: jnp.mean(bn_net(q, x, tgt)))(p)["w"]
    acc = jnp.zeros_like(p["w"])
    for xh, th in ((x[:4], tgt[:4]), (x[4:], tgt[4:])):
        acc += jax.grad(lambda q: jnp.mean(bn_net(q, xh, th)) / 2.0)(p)["w"]
    assert float(jnp.max(jnp.abs(acc - full))) > 1e-3
