"""Analysis tooling: XLA cost model access + block sweep sanity."""

from compile import analyze
from compile.kernels import matmul_pallas


def test_xla_cost_positive_and_scales_with_mu():
    small = analyze.xla_cost("microresnet18", 16, 8)
    large = analyze.xla_cost("microresnet18", 16, 16)
    assert small["flops"] > 1e6
    assert large["flops"] > 1.5 * small["flops"]  # ~2x work per step
    assert small["bytes"] > 0
    assert small["intensity"] > 0


def test_block_sweep_structure():
    rows = analyze.block_sweep(512, 128, 512)
    assert len(rows) == 6
    by_block = {r["block"]: r for r in rows}
    # default 128^3 must fit VMEM with good utilization on aligned shapes
    assert by_block["128x128x128"]["fits_vmem"]
    assert by_block["128x128x128"]["mxu_util"] == 1.0
    # monster blocks exceed the VMEM budget
    assert not by_block["512x512x512"]["fits_vmem"]


def test_vmem_monotone_in_block_size():
    assert matmul_pallas.vmem_footprint_bytes(64, 64, 64) < matmul_pallas.vmem_footprint_bytes(
        128, 128, 128
    )
