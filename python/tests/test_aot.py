"""AOT exporter output: manifest structure + HLO text loadability.

These tests run against the already-built ../artifacts (skipped if `make
artifacts` has not run) plus a from-scratch export of the smallest model
into a tmpdir to exercise the exporter itself.
"""

import json
import os

import numpy as np
import pytest

from compile import aot
from compile.model import MODELS

ART = os.path.join(os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__)))), "artifacts")


def _manifest():
    path = os.path.join(ART, "manifest.json")
    if not os.path.exists(path):
        pytest.skip("artifacts not built (run `make artifacts`)")
    with open(path) as f:
        return json.load(f)


def test_manifest_covers_all_variants():
    man = _manifest()
    listed = {(mk, v["size"], v["mu"]) for mk, e in man["models"].items() for v in e["variants"]}
    assert listed == set(aot.VARIANTS)


def test_manifest_files_exist_and_nonempty():
    man = _manifest()
    for mk, e in man["models"].items():
        for fname in [e["params_bin"], e["apply_hlo"]] + [
            v[k] for v in e["variants"] for k in ("accum_hlo", "eval_hlo")
        ]:
            path = os.path.join(ART, fname)
            assert os.path.exists(path), fname
            assert os.path.getsize(path) > 1000, fname


def test_manifest_param_accounting():
    man = _manifest()
    for mk, e in man["models"].items():
        total = sum(le["elems"] for le in e["param_leaves"]) * 4
        assert total == e["param_bytes"]
        assert os.path.getsize(os.path.join(ART, e["params_bin"])) == total
        # offsets are contiguous and ordered
        off = 0
        for le in e["param_leaves"]:
            assert le["offset"] == off
            assert le["elems"] == int(np.prod(le["shape"])) if le["shape"] else 1
            off += le["elems"] * 4


def test_manifest_optimizer_matches_registry():
    man = _manifest()
    for mk, e in man["models"].items():
        assert e["optimizer"]["kind"] == MODELS[mk].optimizer
        assert len(e["optimizer"]["hyper_defaults"]) == len(e["optimizer"]["hyper_names"])


def test_hlo_text_is_parseable_hlo():
    man = _manifest()
    e = man["models"]["microresnet18"]
    with open(os.path.join(ART, e["variants"][0]["accum_hlo"])) as f:
        text = f.read()
    assert text.startswith("HloModule")
    assert "ENTRY" in text


def test_activation_estimates_monotone_in_resolution():
    man = _manifest()
    rn = man["models"]["microresnet18"]["variants"]
    by_size = {(v["size"], v["mu"]): v["activation_bytes_per_sample"] for v in rn}
    assert by_size[(32, 16)] > 2.5 * by_size[(16, 16)]


def test_export_smallest_model_roundtrip(tmp_path):
    entry = aot.export_model("microresnet18", str(tmp_path), seed=0, quiet=True)
    assert entry["task"] == "classification"
    assert len(entry["variants"]) == 3
    for v in entry["variants"]:
        assert (tmp_path / v["accum_hlo"]).exists()
        assert v["activation_bytes_per_sample"] > 0
