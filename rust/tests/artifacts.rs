//! Artifact-manager contract tests against the deterministic
//! `MockCompiler` backend — the compile-in-the-loop cache proven with no
//! compiled artifacts, no python, no PJRT (tier-1, never skipped; see
//! rust/docs/TESTING.md). Covers the ISSUE acceptance criteria:
//! coalescing (8 concurrent fetches of one uncached variant → exactly 1
//! backend compile, byte-identical handles, no leaked `.tmp` files even
//! across a panicking backend) and corruption recovery (bit-flip /
//! truncate → checksum detection, eviction, transparent recompile; a
//! structured non-panic error when the backend also fails).

mod common;

use std::sync::Arc;
use std::time::Duration;

use mbs::error::MbsError;
use mbs::runtime::{
    ArtifactHandle, ArtifactManager, CompiledArtifact, CompilerBackend, FaultPlan, MockCompiler,
    VariantKey,
};

fn key(mu: usize) -> VariantKey {
    VariantKey { model: "microresnet18".into(), size: 16, mu, overlap: false }
}

const FINGERPRINT: u64 = 0x00c0_ffee;

fn teardown(mgr: &ArtifactManager) {
    std::fs::remove_dir_all(mgr.dir()).ok();
}

#[test]
fn eight_concurrent_fetches_coalesce_to_one_compile() {
    // the headline: N threads race for one uncached variant; the latency
    // window guarantees they overlap the leader's in-flight compile
    let backend = Arc::new(MockCompiler::new().with_latency(Duration::from_millis(150)));
    let mgr = common::manager_with("coalesce", backend.clone(), 8);
    const N: usize = 8;

    let handles: Vec<ArtifactHandle> = std::thread::scope(|s| {
        let threads: Vec<_> = (0..N)
            .map(|_| {
                let mgr = mgr.clone();
                s.spawn(move || mgr.fetch(&key(8), FINGERPRINT).expect("coalesced fetch"))
            })
            .collect();
        threads.into_iter().map(|t| t.join().expect("no fetch panics")).collect()
    });

    assert_eq!(backend.compiles(), 1, "exactly one backend compile for {N} racing fetches");
    let stats = mgr.stats();
    assert_eq!(stats.compiles, 1);
    assert_eq!(stats.compile_errors, 0);
    // every fetch is accounted: 1 leader compile; each other fetch lands
    // a disk hit (having waited — coalesced — or arrived after the fact)
    assert_eq!(stats.hits + stats.compiles, N as u64, "fetch accounting: {stats:?}");
    assert!(stats.coalesced <= stats.hits, "waiters are a subset of hits: {stats:?}");

    // all handles byte-identical, and equal to the deterministic render
    let expect_accum = MockCompiler::render(&key(8), "accum");
    let expect_eval = MockCompiler::render(&key(8), "eval");
    for h in &handles {
        assert_eq!(*h.accum_hlo, expect_accum, "accum payload diverged");
        assert_eq!(*h.eval_hlo, expect_eval, "eval payload diverged");
        assert_eq!(h.digest, key(8).digest(FINGERPRINT));
        assert!(h.accum_path.exists() && h.eval_path.exists());
    }
    assert!(
        common::tmp_files(mgr.dir()).is_empty(),
        "write-tmp-then-rename must leave no .tmp files"
    );
    teardown(&mgr);
}

/// Backend that sleeps, then panics — the leader dies mid-compile.
struct PanickingCompiler {
    delay: Duration,
}

impl CompilerBackend for PanickingCompiler {
    fn compile(&self, _key: &VariantKey) -> mbs::error::Result<CompiledArtifact> {
        std::thread::sleep(self.delay);
        panic!("compiler backend died mid-compile");
    }

    fn name(&self) -> &'static str {
        "panicking"
    }
}

#[test]
fn leader_panic_frees_waiters_and_leaks_no_tmp_files() {
    let backend = Arc::new(PanickingCompiler { delay: Duration::from_millis(400) });
    let mgr = common::manager_with("panic", backend, 8);

    let (leader, waiter) = std::thread::scope(|s| {
        let m1 = mgr.clone();
        let leader = s.spawn(move || m1.fetch(&key(8), FINGERPRINT));
        // give the leader a comfortable head start into its 400 ms sleep
        // so this fetch coalesces onto it rather than leading itself
        std::thread::sleep(Duration::from_millis(100));
        let m2 = mgr.clone();
        let waiter = s.spawn(move || m2.fetch(&key(8), FINGERPRINT));
        (leader.join(), waiter.join())
    });

    assert!(leader.is_err(), "the leader thread itself panicked");
    match waiter {
        // the common case: the waiter coalesced, the RAII guard recorded
        // the aborted compile, and the waiter got a structured error
        Ok(Err(MbsError::Compile { key: k, reason })) => {
            assert!(k.contains("microresnet18"), "error names the variant: {k}");
            assert!(reason.contains("aborted"), "error names the abort: {reason}");
        }
        // the timing-race case: the waiter arrived late, led its own
        // compile, and panicked identically — still no hang, no tmp leak
        Err(_) => {}
        other => panic!("waiter must fail structurally or panic as leader, got {other:?}"),
    }
    assert_eq!(mgr.cached_entries(), 0, "nothing may be cached after a panic");
    assert!(
        common::tmp_files(mgr.dir()).is_empty(),
        "a panicked compile must leak no .tmp files"
    );
    teardown(&mgr);
}

#[test]
fn bit_flip_is_detected_evicted_and_recompiled_transparently() {
    let (mgr, backend) = common::mock_manager("bitflip", 8);
    let first = mgr.fetch(&key(8), FINGERPRINT).expect("cold fetch");

    // flip one bit in the cached accum payload
    let mut bytes = std::fs::read(&first.accum_path).unwrap();
    bytes[7] ^= 0x40;
    std::fs::write(&first.accum_path, &bytes).unwrap();

    let again = mgr.fetch(&key(8), FINGERPRINT).expect("corruption must be invisible to callers");
    assert_eq!(*again.accum_hlo, MockCompiler::render(&key(8), "accum"), "payload restored");
    assert_eq!(backend.compiles(), 2, "recompile after eviction");
    let stats = mgr.stats();
    assert_eq!(stats.corrupt_evictions, 1, "the flipped entry was evicted: {stats:?}");
    // and the restored entry is a clean hit from here on
    mgr.fetch(&key(8), FINGERPRINT).unwrap();
    assert_eq!(backend.compiles(), 2);
    assert!(common::tmp_files(mgr.dir()).is_empty());
    teardown(&mgr);
}

#[test]
fn truncation_is_detected_evicted_and_recompiled_transparently() {
    let (mgr, backend) = common::mock_manager("trunc", 8);
    let first = mgr.fetch(&key(8), FINGERPRINT).expect("cold fetch");

    // truncate the eval payload (a crashed writer / torn copy)
    let bytes = std::fs::read(&first.eval_path).unwrap();
    std::fs::write(&first.eval_path, &bytes[..bytes.len() / 2]).unwrap();

    let again = mgr.fetch(&key(8), FINGERPRINT).expect("truncation must be invisible to callers");
    assert_eq!(*again.eval_hlo, MockCompiler::render(&key(8), "eval"));
    assert_eq!(backend.compiles(), 2);
    assert_eq!(mgr.stats().corrupt_evictions, 1);
    teardown(&mgr);
}

#[test]
fn corrupt_metadata_is_evicted_and_recompiled() {
    let (mgr, backend) = common::mock_manager("meta", 8);
    let first = mgr.fetch(&key(8), FINGERPRINT).expect("cold fetch");
    let meta = first.accum_path.with_file_name(format!(
        "{:016x}.meta.json",
        key(8).digest(FINGERPRINT)
    ));
    assert!(meta.exists(), "metadata file must sit next to the payloads");
    std::fs::write(&meta, "{\"magic\": \"not-an-artifact\"}").unwrap();

    mgr.fetch(&key(8), FINGERPRINT).expect("bad metadata must be invisible to callers");
    assert_eq!(backend.compiles(), 2);
    assert_eq!(mgr.stats().corrupt_evictions, 1);
    teardown(&mgr);
}

#[test]
fn corruption_with_failing_backend_surfaces_structured_error() {
    // entry corrupted AND the backend cannot recompile: the caller gets
    // the structured compile error — never a panic, never the corrupt bytes
    let plan = FaultPlan::parse(
        // attempt 0 is the successful cold compile; attempt 1 (the
        // post-corruption recompile) is the injected failure
        r#"{"faults": [{"job": "compiler", "kind": "compile", "at-step": 1}]}"#,
    )
    .unwrap();
    let backend = Arc::new(MockCompiler::new().with_faults(plan.hooks_for("compiler")));
    let mgr = common::manager_with("corrupt-fail", backend.clone(), 8);

    let first = mgr.fetch(&key(8), FINGERPRINT).expect("cold fetch");
    let mut bytes = std::fs::read(&first.accum_path).unwrap();
    bytes[3] ^= 0x01;
    std::fs::write(&first.accum_path, &bytes).unwrap();

    let err = mgr.fetch(&key(8), FINGERPRINT).expect_err("backend failure must surface");
    match &err {
        MbsError::Compile { key: k, reason } => {
            assert!(k.contains("microresnet18"), "{k}");
            assert!(reason.contains("injected"), "{reason}");
        }
        other => panic!("want MbsError::Compile, got {other:?}"),
    }
    assert!(!err.recoverable(), "compile failure is deterministic, stays fatal");
    let stats = mgr.stats();
    assert_eq!(stats.corrupt_evictions, 1);
    assert_eq!(stats.compile_errors, 1);
    // the fault budget is spent: the next fetch recovers end-to-end
    let healed = mgr.fetch(&key(8), FINGERPRINT).expect("retry after transient backend fault");
    assert_eq!(*healed.accum_hlo, MockCompiler::render(&key(8), "accum"));
    assert_eq!(backend.compiles(), 3);
    assert!(common::tmp_files(mgr.dir()).is_empty());
    teardown(&mgr);
}

#[test]
fn distinct_variants_and_fingerprints_do_not_collide() {
    let (mgr, backend) = common::mock_manager("distinct", 8);
    let h8 = mgr.fetch(&key(8), FINGERPRINT).unwrap();
    let h4 = mgr.fetch(&key(4), FINGERPRINT).unwrap();
    assert_ne!(h8.digest, h4.digest);
    assert_ne!(h8.accum_hlo, h4.accum_hlo, "payloads are per-variant");
    // a re-export that changes the manifest fingerprint invalidates the
    // cached entry without any explicit flush: same key, new digest
    let h8b = mgr.fetch(&key(8), FINGERPRINT + 1).unwrap();
    assert_ne!(h8.digest, h8b.digest);
    assert_eq!(backend.compiles(), 3, "three distinct content addresses, three compiles");
    assert_eq!(mgr.stats().hits, 0);
    teardown(&mgr);
}

#[test]
fn warm_restart_adopts_the_cache_from_a_previous_manager() {
    // process-restart story: a new manager over the same dir serves hits
    // from the previous one's entries (checksums re-validated per fetch)
    let dir = common::cache_dir("restart");
    let backend = Arc::new(MockCompiler::new());
    {
        let mgr = ArtifactManager::new(&dir, backend.clone(), 8).unwrap();
        mgr.fetch(&key(8), FINGERPRINT).unwrap();
        mgr.fetch(&key(4), FINGERPRINT).unwrap();
    }
    let mgr = ArtifactManager::new(&dir, backend.clone(), 8).unwrap();
    assert_eq!(mgr.cached_entries(), 2, "both entries adopted");
    mgr.fetch(&key(8), FINGERPRINT).unwrap();
    mgr.fetch(&key(4), FINGERPRINT).unwrap();
    assert_eq!(backend.compiles(), 2, "warm restart: zero recompiles");
    assert_eq!(mgr.stats().hits, 2);
    teardown(&mgr);
}

#[test]
fn lru_bound_holds_under_many_variants() {
    let (mgr, backend) = common::mock_manager("lru-many", 3);
    for mu in 1..=9usize {
        mgr.fetch(&key(mu), FINGERPRINT).unwrap();
    }
    assert_eq!(mgr.cached_entries(), 3, "bound holds");
    assert_eq!(mgr.stats().evictions, 6);
    // the three most recent survive; older ones recompile
    mgr.fetch(&key(9), FINGERPRINT).unwrap();
    assert_eq!(backend.compiles(), 9, "mu=9 was resident");
    mgr.fetch(&key(1), FINGERPRINT).unwrap();
    assert_eq!(backend.compiles(), 10, "mu=1 was evicted long ago");
    // on-disk file count matches the bound: 3 files per entry
    let files = std::fs::read_dir(mgr.dir()).unwrap().count();
    assert_eq!(files, 9, "3 entries x (meta + accum + eval)");
    teardown(&mgr);
}
