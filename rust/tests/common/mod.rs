//! Shared helpers for integration tests: engine construction + artifact
//! gating (tests no-op when `make artifacts` has not been run).

use std::path::PathBuf;

use mbs::{Engine, Manifest};

pub fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

pub fn engine() -> Option<Engine> {
    let dir = artifacts_dir()?;
    Some(Engine::new(Manifest::load(dir).expect("manifest parses")).expect("engine"))
}

/// Max |a-b| over two leaf vectors.
pub fn max_abs_diff(a: &[Vec<f32>], b: &[Vec<f32>]) -> f32 {
    let mut m = 0f32;
    for (x, y) in a.iter().zip(b) {
        assert_eq!(x.len(), y.len(), "leaf size mismatch");
        for (u, v) in x.iter().zip(y) {
            m = m.max((u - v).abs());
        }
    }
    m
}

/// Max |a-b| / (|b| + eps) over two leaf vectors.
pub fn max_rel_diff(a: &[Vec<f32>], b: &[Vec<f32>], eps: f32) -> f32 {
    let mut m = 0f32;
    for (x, y) in a.iter().zip(b) {
        for (u, v) in x.iter().zip(y) {
            m = m.max((u - v).abs() / (v.abs() + eps));
        }
    }
    m
}
