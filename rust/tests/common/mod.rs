//! Shared helpers for integration tests: engine construction + artifact
//! gating.
//!
//! The PJRT/artifact-dependent integration tests run when compiled
//! artifacts are available: either auto-detected at the default
//! `rust/artifacts` directory, or named explicitly via the
//! `MBS_ARTIFACTS` environment variable (`1` for the default location, or
//! a path). On a clean checkout (`cargo test -q` without `make artifacts`)
//! they skip with a message instead of failing. The full gating story —
//! which tests skip, how to export variants, every `MBS_ARTIFACTS` value —
//! is documented in `rust/docs/TESTING.md`.

#![allow(dead_code)] // each integration test binary uses a subset of these

use std::path::PathBuf;
use std::sync::Arc;

use mbs::runtime::{ArtifactManager, CompilerBackend, MockCompiler};
use mbs::{Engine, Manifest};

pub fn artifacts_dir() -> Option<PathBuf> {
    let default_dir = || PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let dir = match std::env::var("MBS_ARTIFACTS") {
        // no opt-in/override: auto-detect the default location
        Err(_) => default_dir(),
        Ok(v) if v.is_empty() || v == "1" || v == "true" => default_dir(),
        // explicit opt-out, not a directory literally named "0"
        Ok(v) if v == "0" || v == "false" => {
            eprintln!(
                "skipping artifact-dependent test: MBS_ARTIFACTS={v} (opt-out) — \
                 see rust/docs/TESTING.md"
            );
            return None;
        }
        Ok(path) => PathBuf::from(path),
    };
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!(
            "skipping artifact-dependent test: no manifest.json under {} \
             (run `make artifacts` first, or point MBS_ARTIFACTS at an artifact dir \
             — see rust/docs/TESTING.md)",
            dir.display()
        );
        None
    }
}

pub fn engine() -> Option<Engine> {
    let dir = artifacts_dir()?;
    Some(Engine::new(Manifest::load(dir).expect("manifest parses")).expect("engine"))
}

/// A unique temp directory for one test's artifact cache, cleared of any
/// previous run's leftovers. Callers remove it when done.
pub fn cache_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("mbs-it-cache-{tag}-{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    dir
}

/// Mock-backed artifact manager over a fresh temp cache dir: the whole
/// cache contract (coalescing, eviction, corruption recovery) is provable
/// with no compiled artifacts and no python — the tier-1 replacement for
/// the `MBS_ARTIFACTS`-gated variant-resolution paths.
pub fn mock_manager(tag: &str, max_entries: usize) -> (ArtifactManager, Arc<MockCompiler>) {
    let backend = Arc::new(MockCompiler::new());
    let mgr = ArtifactManager::new(cache_dir(tag), backend.clone(), max_entries)
        .expect("artifact manager over temp dir");
    (mgr, backend)
}

/// Same, with a caller-supplied backend (latency / fault injection).
pub fn manager_with(
    tag: &str,
    backend: Arc<dyn CompilerBackend>,
    max_entries: usize,
) -> ArtifactManager {
    ArtifactManager::new(cache_dir(tag), backend, max_entries)
        .expect("artifact manager over temp dir")
}

/// Any `.tmp` siblings the write-tmp-then-rename discipline would leak on
/// a crashed or panicked store (must always be empty after a fetch,
/// successful or not).
pub fn tmp_files(dir: &std::path::Path) -> Vec<String> {
    let Ok(entries) = std::fs::read_dir(dir) else { return Vec::new() };
    entries
        .filter_map(|e| e.ok())
        .map(|e| e.file_name().to_string_lossy().into_owned())
        .filter(|n| n.ends_with(".tmp"))
        .collect()
}

/// Max |a-b| over two leaf vectors.
pub fn max_abs_diff(a: &[Vec<f32>], b: &[Vec<f32>]) -> f32 {
    let mut m = 0f32;
    for (x, y) in a.iter().zip(b) {
        assert_eq!(x.len(), y.len(), "leaf size mismatch");
        for (u, v) in x.iter().zip(y) {
            m = m.max((u - v).abs());
        }
    }
    m
}

/// Max |a-b| / (|b| + eps) over two leaf vectors.
pub fn max_rel_diff(a: &[Vec<f32>], b: &[Vec<f32>], eps: f32) -> f32 {
    let mut m = 0f32;
    for (x, y) in a.iter().zip(b) {
        for (u, v) in x.iter().zip(y) {
            m = m.max((u - v).abs() / (v.abs() + eps));
        }
    }
    m
}
