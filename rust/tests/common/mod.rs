//! Shared helpers for integration tests: engine construction + artifact
//! gating.
//!
//! The PJRT/artifact-dependent integration tests run when compiled
//! artifacts are available: either auto-detected at the default
//! `rust/artifacts` directory, or named explicitly via the
//! `MBS_ARTIFACTS` environment variable (`1` for the default location, or
//! a path). On a clean checkout (`cargo test -q` without `make artifacts`)
//! they skip with a message instead of failing. The full gating story —
//! which tests skip, how to export variants, every `MBS_ARTIFACTS` value —
//! is documented in `rust/docs/TESTING.md`.

#![allow(dead_code)] // each integration test binary uses a subset of these

use std::path::PathBuf;

use mbs::{Engine, Manifest};

pub fn artifacts_dir() -> Option<PathBuf> {
    let default_dir = || PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let dir = match std::env::var("MBS_ARTIFACTS") {
        // no opt-in/override: auto-detect the default location
        Err(_) => default_dir(),
        Ok(v) if v.is_empty() || v == "1" || v == "true" => default_dir(),
        // explicit opt-out, not a directory literally named "0"
        Ok(v) if v == "0" || v == "false" => {
            eprintln!(
                "skipping artifact-dependent test: MBS_ARTIFACTS={v} (opt-out) — \
                 see rust/docs/TESTING.md"
            );
            return None;
        }
        Ok(path) => PathBuf::from(path),
    };
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!(
            "skipping artifact-dependent test: no manifest.json under {} \
             (run `make artifacts` first, or point MBS_ARTIFACTS at an artifact dir \
             — see rust/docs/TESTING.md)",
            dir.display()
        );
        None
    }
}

pub fn engine() -> Option<Engine> {
    let dir = artifacts_dir()?;
    Some(Engine::new(Manifest::load(dir).expect("manifest parses")).expect("engine"))
}

/// Max |a-b| over two leaf vectors.
pub fn max_abs_diff(a: &[Vec<f32>], b: &[Vec<f32>]) -> f32 {
    let mut m = 0f32;
    for (x, y) in a.iter().zip(b) {
        assert_eq!(x.len(), y.len(), "leaf size mismatch");
        for (u, v) in x.iter().zip(y) {
            m = m.max((u - v).abs());
        }
    }
    m
}

/// Max |a-b| / (|b| + eps) over two leaf vectors.
pub fn max_rel_diff(a: &[Vec<f32>], b: &[Vec<f32>], eps: f32) -> f32 {
    let mut m = 0f32;
    for (x, y) in a.iter().zip(b) {
        for (u, v) in x.iter().zip(y) {
            m = m.max((u - v).abs() / (v.abs() + eps));
        }
    }
    m
}
