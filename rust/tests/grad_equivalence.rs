//! DESIGN.md invariant 2, end-to-end through the real runtime: the
//! accumulated loss-normalized micro-batch gradient computed by the
//! exported HLO equals the single-step full-batch gradient.
//!
//! This is the rust-side twin of python/tests/test_grad_equivalence.py —
//! here it additionally covers the manifest, the params.bin upload, the
//! PJRT execution path and the coordinator's scale arithmetic.

mod common;

use std::sync::Arc;

use mbs::coordinator::{NormalizationMode, SplitPlan};
use mbs::data::{loader, Dataset, SynthFlowers};

#[test]
fn mbs_accumulated_grad_equals_native_grad() {
    let Some(mut engine) = common::engine() else { return };
    // native step: batch 16 in one mu=16 call
    let mut native = engine.load_model("microresnet18", 16, 16).expect("load native");
    // mbs: same 16 samples as two mu=8 micro-batches
    let mut mbs = engine.load_model("microresnet18", 16, 8).expect("load mbs");

    let ds: Arc<dyn Dataset> = Arc::new(SynthFlowers::new(16, 102, 64, 7));
    let indices: Vec<usize> = (0..16).collect();

    let full = loader::assemble(ds.as_ref(), &indices, 16, 0);
    native.accum_step(&full, 1.0 / 16.0).expect("native step");
    let ref_grads = native.acc_to_host().expect("download native acc");

    let plan = SplitPlan::new(16, 8);
    for j in 0..plan.n_smu() {
        let mb = loader::assemble(ds.as_ref(), &indices, 8, j);
        let scale = NormalizationMode::Paper.scale(&plan, j);
        mbs.accum_step(&mb, scale).expect("mbs step");
    }
    let mbs_grads = mbs.acc_to_host().expect("download mbs acc");

    assert_eq!(ref_grads.len(), mbs_grads.len());
    let rel = common::max_rel_diff(&mbs_grads, &ref_grads, 1e-6);
    assert!(rel < 5e-3, "accumulated grad differs from native: max rel {rel}");
    let abs = common::max_abs_diff(&mbs_grads, &ref_grads);
    assert!(abs < 1e-4, "max abs {abs}");
}

#[test]
fn exact_mode_handles_ragged_tail() {
    let Some(mut engine) = common::engine() else { return };
    let mut native = engine.load_model("microresnet18", 16, 16).expect("load native");
    let mut mbs = engine.load_model("microresnet18", 16, 8).expect("load mbs");

    let ds: Arc<dyn Dataset> = Arc::new(SynthFlowers::new(16, 102, 64, 11));
    // ragged: N_B = 13, mu = 8 -> micro-batches of 8 and 5
    let indices: Vec<usize> = (0..13).collect();

    let full = loader::assemble(ds.as_ref(), &indices, 16, 0);
    native.accum_step(&full, 1.0 / 13.0).expect("native step");
    let ref_grads = native.acc_to_host().unwrap();

    let plan = SplitPlan::new(13, 8);
    for j in 0..plan.n_smu() {
        let mb = loader::assemble(ds.as_ref(), &indices, 8, j);
        let scale = NormalizationMode::Exact.scale(&plan, j);
        mbs.accum_step(&mb, scale).expect("mbs step");
    }
    let mbs_grads = mbs.acc_to_host().unwrap();
    let rel = common::max_rel_diff(&mbs_grads, &ref_grads, 1e-6);
    assert!(rel < 5e-3, "exact-mode ragged grad mismatch: max rel {rel}");
}

#[test]
fn paper_mode_biased_on_ragged_tail_but_none_mode_worse() {
    let Some(mut engine) = common::engine() else { return };
    let mut native = engine.load_model("microresnet18", 16, 16).expect("load");
    let ds: Arc<dyn Dataset> = Arc::new(SynthFlowers::new(16, 102, 64, 13));
    let indices: Vec<usize> = (0..12).collect();

    let full = loader::assemble(ds.as_ref(), &indices, 16, 0);
    native.accum_step(&full, 1.0 / 12.0).unwrap();
    let ref_grads = native.acc_to_host().unwrap();

    let run_mode = |engine: &mut mbs::Engine, mode: NormalizationMode| -> Vec<Vec<f32>> {
        let mut rt = engine.load_model("microresnet18", 16, 8).unwrap();
        let plan = SplitPlan::new(12, 8); // ranges 8 + 4 (ragged)
        for j in 0..plan.n_smu() {
            let mb = loader::assemble(ds.as_ref(), &indices, 8, j);
            rt.accum_step(&mb, mode.scale(&plan, j)).unwrap();
        }
        rt.acc_to_host().unwrap()
    };

    let exact = run_mode(&mut engine, NormalizationMode::Exact);
    let paper = run_mode(&mut engine, NormalizationMode::Paper);
    let none = run_mode(&mut engine, NormalizationMode::None);

    let d_exact = common::max_abs_diff(&exact, &ref_grads);
    let d_paper = common::max_abs_diff(&paper, &ref_grads);
    let d_none = common::max_abs_diff(&none, &ref_grads);
    // exact ~ 0; paper visibly biased on the ragged tail; none (eq. 13,
    // no normalization) much worse than both
    assert!(d_exact < 1e-4, "exact should match: {d_exact}");
    assert!(d_paper > d_exact * 5.0, "paper bias not visible: {d_paper} vs {d_exact}");
    assert!(d_none > d_paper, "unnormalized should be worst: {d_none} vs {d_paper}");
}

#[test]
fn accumulator_resets_after_apply() {
    let Some(mut engine) = common::engine() else { return };
    let mut rt = engine.load_model("microresnet18", 16, 8).expect("load");
    let ds: Arc<dyn Dataset> = Arc::new(SynthFlowers::new(16, 102, 64, 3));
    let indices: Vec<usize> = (0..8).collect();
    let mb = loader::assemble(ds.as_ref(), &indices, 8, 0);
    rt.accum_step(&mb, 1.0 / 8.0).unwrap();
    let before = rt.acc_to_host().unwrap();
    assert!(before.iter().flatten().any(|&v| v != 0.0), "grad all zero?");
    rt.apply(&rt.default_hyper()).unwrap();
    let after = rt.acc_to_host().unwrap();
    assert!(after.iter().flatten().all(|&v| v == 0.0), "acc not zeroed by apply");
    assert_eq!(rt.updates, 1);
}

#[test]
fn apply_changes_params_in_gradient_direction() {
    let Some(mut engine) = common::engine() else { return };
    let mut rt = engine.load_model("microresnet18", 16, 8).expect("load");
    let p0 = rt.params_to_host().unwrap();
    let ds: Arc<dyn Dataset> = Arc::new(SynthFlowers::new(16, 102, 64, 5));
    let indices: Vec<usize> = (0..8).collect();
    let mb = loader::assemble(ds.as_ref(), &indices, 8, 0);
    rt.accum_step(&mb, 1.0 / 8.0).unwrap();
    rt.apply(&rt.default_hyper()).unwrap();
    let p1 = rt.params_to_host().unwrap();
    let moved = common::max_abs_diff(&p0, &p1);
    assert!(moved > 0.0, "params did not move");
    assert!(moved < 1.0, "params exploded: {moved}");
}
