//! Multi-tenant interleaving tests (the identity oracle is artifact-gated,
//! see rust/docs/TESTING.md): each job's `TrainReport` under `train_jobs`
//! must be bit-identical to the same configuration's solo `train` run —
//! the round-robin only interleaves *whose* micro-step runs next, never
//! what any job computes — plus shared-arena accounting and the
//! artifact-free dry-run admission path.

mod common;

use std::time::Duration;

use mbs::coordinator::frontier::{classify_set, synthetic_entry, SetFeasibility};
use mbs::coordinator::tenancy::{
    plan_admission, resident_claim, staged_slot_bytes, transient_bytes, AdmissionOutcome,
    AdmissionRequest, JobSpec,
};
use mbs::memory::{Footprint, MIB};
use mbs::{JobSet, MicroBatchSpec, TrainConfig};

/// The acceptance scenario: two heterogeneous jobs (classification +
/// segmentation) on a capacity their combined native footprints exceed.
fn heterogeneous_set(engine: &mbs::Engine) -> (JobSet, u64) {
    let rn = engine.manifest().model("microresnet18").unwrap().clone();
    let un = engine.manifest().model("microunet").unwrap().clone();
    let fp_rn = Footprint::from_manifest(&rn, rn.variant(16, 8).unwrap());
    let fp_un = Footprint::from_manifest(&un, un.variant(24, 8).unwrap());
    // capacity: both resident reservations plus one 8-sample transient of
    // headroom — enough to admit both as MBS streams, far below what the
    // two native steps need at once
    let claim = resident_claim(&rn, 16).unwrap() + resident_claim(&un, 24).unwrap();
    let transient = transient_bytes(&fp_rn, 8, 24, 16, false)
        .max(transient_bytes(&fp_un, 8, 16, 8, false));
    let capacity = claim + transient;
    assert!(
        fp_rn.step_bytes(24) + fp_un.step_bytes(16) > capacity,
        "fixture must make the combined native footprints exceed the shared capacity"
    );
    let cls = TrainConfig::builder("microresnet18")
        .batch(24)
        .epochs(2)
        .dataset_len(48)
        .eval_len(16)
        .seed(3)
        .overlap(false)
        .build();
    let seg = TrainConfig::builder("microunet")
        .size(24)
        .batch(16)
        .epochs(2)
        .dataset_len(32)
        .eval_len(8)
        .seed(5)
        .overlap(false)
        .build();
    let set = JobSet {
        capacity_mib: None,
        jobs: vec![
            JobSpec { name: "cls".into(), task: None, cfg: cls },
            JobSpec { name: "seg".into(), task: None, cfg: seg },
        ],
    };
    (set, capacity)
}

/// The async-lane variant: both jobs keep the upload lane on, and the
/// shared capacity additionally funds BOTH durable staged input slots —
/// the cross-tenant *sum* the admission planner now prices.
fn heterogeneous_set_async(engine: &mbs::Engine) -> (JobSet, u64) {
    let (mut set, _) = heterogeneous_set(engine);
    for job in &mut set.jobs {
        job.cfg.overlap = true;
    }
    let rn = engine.manifest().model("microresnet18").unwrap().clone();
    let un = engine.manifest().model("microunet").unwrap().clone();
    let fp_rn = Footprint::from_manifest(&rn, rn.variant(16, 8).unwrap());
    let fp_un = Footprint::from_manifest(&un, un.variant(24, 8).unwrap());
    let claim = resident_claim(&rn, 16).unwrap() + resident_claim(&un, 24).unwrap();
    let transient = transient_bytes(&fp_rn, 8, 24, 16, true)
        .max(transient_bytes(&fp_un, 8, 16, 8, true));
    let capacity = claim
        + transient
        + staged_slot_bytes(&fp_rn, 8, 24, 16)
        + staged_slot_bytes(&fp_un, 8, 16, 8);
    (set, capacity)
}

#[test]
fn async_lane_jobs_bit_identical_to_solo_and_wall_overlap_measured() {
    // the async-lane oracle at set level: two tenants, each with its own
    // upload lane and a warm ping-pong slot that stays staged across the
    // other tenant's turns — per-job results still bit-identical to solo
    // runs, and the lane's thread timestamps still land inside execute
    // windows despite the interleaving
    let Some(mut engine) = common::engine() else { return };
    let (set, capacity) = heterogeneous_set_async(&engine);
    let report = mbs::train_jobs(&mut engine, &set, capacity).expect("async interleaved run");
    assert_eq!(report.admitted(), 2, "both async jobs must be admitted: {:?}", report.jobs);
    assert!(report.arena_peak_bytes <= report.capacity_bytes);

    for (job, spec) in report.jobs.iter().zip(&set.jobs) {
        let shared = job.report.as_ref().expect("admitted jobs carry a report");
        // admission priced this tenant's durable staged slot
        match &job.admission {
            AdmissionOutcome::Admitted { staged_bytes, .. } => {
                assert!(*staged_bytes > 0, "job {}: async tenant with free staged slot", job.name);
            }
            other => panic!("job {} not admitted: {other:?}", job.name),
        }
        // the wall-clock evidence survives multi-tenancy
        assert!(shared.overlap, "job {} lost its lane mode", job.name);
        assert!(shared.stages.upload_hidden > Duration::ZERO, "job {}", job.name);
        assert!(
            shared.stages.upload_concurrent > Duration::ZERO,
            "job {}: lane never staged during an execute window: {:?}",
            job.name,
            shared.stages
        );

        // solo arm: identical config (lane on), admitted mu pinned, roomy
        // device — bit identity is structural now that solo IS a JobExec
        let mut solo_cfg = spec.cfg.clone();
        solo_cfg.mu = MicroBatchSpec::Fixed(shared.mu);
        solo_cfg.capacity_mib = Some(capacity.div_ceil(MIB) + 16);
        let solo = mbs::train(&mut engine, &solo_cfg).expect("solo async run");
        assert_eq!(shared.mu, solo.mu, "job {}", job.name);
        assert_eq!(shared.updates, solo.updates, "job {}", job.name);
        for (a, b) in shared.train_epochs.iter().zip(&solo.train_epochs) {
            assert_eq!(
                a.mean_loss.to_bits(),
                b.mean_loss.to_bits(),
                "job {} epoch {} train loss diverged under the async lane",
                job.name,
                a.epoch
            );
            assert_eq!(a.primary_metric.to_bits(), b.primary_metric.to_bits());
            assert_eq!(a.micro_steps, b.micro_steps);
        }
        for (a, b) in shared.eval_epochs.iter().zip(&solo.eval_epochs) {
            assert_eq!(a.mean_loss.to_bits(), b.mean_loss.to_bits(), "job {}", job.name);
        }
        assert_eq!(
            shared.final_eval.mean_loss.to_bits(),
            solo.final_eval.mean_loss.to_bits()
        );
    }
}

#[test]
fn per_job_reports_bit_identical_to_solo_runs() {
    // THE oracle (mirrors PR 4's overlap oracle): run two heterogeneous
    // jobs interleaved in one arena, then rerun each alone with its
    // admitted mu pinned — every loss and metric must match bit for bit
    let Some(mut engine) = common::engine() else { return };
    let (set, capacity) = heterogeneous_set(&engine);
    let report = mbs::train_jobs(&mut engine, &set, capacity).expect("interleaved run");
    assert_eq!(report.admitted(), 2, "both jobs must be admitted: {:?}", report.jobs);
    assert!(report.arena_peak_bytes <= report.capacity_bytes);
    assert!(report.aggregate_items_per_sec() > 0.0);

    for (job, spec) in report.jobs.iter().zip(&set.jobs) {
        let shared = job.report.as_ref().expect("admitted jobs carry a report");
        // the solo arm: the identical configuration alone on a roomy
        // device, micro-batch pinned to what the arena admitted
        let mut solo_cfg = spec.cfg.clone();
        solo_cfg.mu = MicroBatchSpec::Fixed(shared.mu);
        solo_cfg.capacity_mib = Some(capacity.div_ceil(MIB) + 16);
        let solo = mbs::train(&mut engine, &solo_cfg).expect("solo run");

        assert_eq!(shared.mu, solo.mu, "job {}", job.name);
        assert_eq!(shared.updates, solo.updates, "job {}", job.name);
        assert_eq!(shared.train_epochs.len(), solo.train_epochs.len());
        for (a, b) in shared.train_epochs.iter().zip(&solo.train_epochs) {
            assert_eq!(
                a.mean_loss.to_bits(),
                b.mean_loss.to_bits(),
                "job {} epoch {} train loss diverged: {} vs {}",
                job.name,
                a.epoch,
                a.mean_loss,
                b.mean_loss
            );
            assert_eq!(a.primary_metric.to_bits(), b.primary_metric.to_bits());
            assert_eq!(a.samples, b.samples);
            assert_eq!(a.micro_steps, b.micro_steps);
            assert_eq!(a.updates, b.updates);
        }
        assert_eq!(shared.eval_epochs.len(), solo.eval_epochs.len());
        for (a, b) in shared.eval_epochs.iter().zip(&solo.eval_epochs) {
            assert_eq!(
                a.mean_loss.to_bits(),
                b.mean_loss.to_bits(),
                "job {} eval loss diverged",
                job.name
            );
            assert_eq!(a.primary_metric.to_bits(), b.primary_metric.to_bits());
            assert_eq!(a.samples, b.samples);
        }
        assert_eq!(
            shared.final_eval.mean_loss.to_bits(),
            solo.final_eval.mean_loss.to_bits()
        );
    }
}

#[test]
fn arena_accounting_holds_reservations_and_transients() {
    // every job's sub-ledger peak is its durable reservation plus at most
    // one step's transient, and the cross-job peak never exceeds capacity
    let Some(mut engine) = common::engine() else { return };
    let (set, capacity) = heterogeneous_set(&engine);
    let rn = engine.manifest().model("microresnet18").unwrap().clone();
    let un = engine.manifest().model("microunet").unwrap().clone();
    let claims = [resident_claim(&rn, 16).unwrap(), resident_claim(&un, 24).unwrap()];
    let report = mbs::train_jobs(&mut engine, &set, capacity).expect("interleaved run");
    assert!(report.arena_peak_bytes <= report.capacity_bytes);
    for (job, claim) in report.jobs.iter().zip(claims) {
        let r = job.report.as_ref().expect("admitted");
        assert!(
            r.ledger_peak_bytes > claim,
            "job {} never charged a step beyond its reservation",
            job.name
        );
        assert!(r.ledger_peak_bytes <= capacity);
        // the admission arithmetic carried through to the run
        match &job.admission {
            AdmissionOutcome::Admitted { resident_claim_bytes, resolution, .. } => {
                assert_eq!(*resident_claim_bytes, claim);
                assert_eq!(resolution.mu, r.mu);
            }
            other => panic!("job {} not admitted: {other:?}", job.name),
        }
    }
    // the two tenants together peaked above what either holds alone
    // (both reservations were resident simultaneously)
    assert!(report.arena_peak_bytes >= claims[0] + claims[1]);
}

#[test]
fn dry_run_admission_with_synthetic_tasks_is_artifact_free() {
    // the `mbs jobs --dry-run` path end to end, no artifacts: spec JSON ->
    // synthetic entries -> deterministic admission -> set classification
    let set = JobSet::from_json_str(
        r#"{
            "capacity_mib": 4,
            "jobs": [
                {"name": "cls", "task": "classification", "batch": 64, "seed": 1},
                {"name": "seg", "task": "segmentation", "batch": 32, "seed": 2}
            ]
        }"#,
    )
    .unwrap();
    let requests: Vec<AdmissionRequest> = set
        .jobs
        .iter()
        .map(|s| {
            AdmissionRequest::from_spec(s, synthetic_entry(s.task.as_deref().unwrap()).unwrap())
        })
        .collect();
    let capacity = set.capacity_mib.unwrap() * MIB;
    let verdicts = plan_admission(&requests, capacity);
    assert!(
        verdicts.iter().all(|v| v.outcome.is_admitted()),
        "both synthetic jobs fit 4 MiB: {verdicts:?}"
    );
    // co-residency costs capacity: each job's shared mu never exceeds its
    // solo mu, and at least one shrank (1 MiB resident each leaves the
    // transients 2 MiB to share)
    for v in &verdicts {
        let AdmissionOutcome::Admitted { resolution, solo_mu, .. } = &v.outcome else {
            unreachable!("checked admitted above");
        };
        assert!(resolution.mu <= *solo_mu);
    }
    assert_eq!(classify_set(&requests, capacity), SetFeasibility::CoResidentMbs);
    // a device that only fits the two residents hosts neither stream
    assert_eq!(classify_set(&requests, 2 * MIB), SetFeasibility::Reject);
    // determinism: replaying the same spec yields the same verdicts
    let replay = plan_admission(&requests, capacity);
    for (a, b) in verdicts.iter().zip(&replay) {
        assert_eq!(a.outcome.mu(), b.outcome.mu());
        assert_eq!(a.outcome.label(), b.outcome.label());
    }
}

#[test]
fn train_jobs_rejects_synthetic_specs() {
    // training needs real models: a task-shaped job is a config error,
    // not a crash deep in the engine
    let Some(mut engine) = common::engine() else { return };
    let set = JobSet::from_json_str(
        r#"{"capacity_mib": 4, "jobs": [{"name": "x", "task": "classification"}]}"#,
    )
    .unwrap();
    let err = mbs::train_jobs(&mut engine, &set, 4 * MIB).unwrap_err();
    assert!(err.to_string().contains("synthetic"), "{err}");
}
