//! Fault-injection + recovery tests (artifact-gated, see
//! rust/docs/TESTING.md): the headline oracle is that a run which faults
//! and recovers — checkpoint at the last update boundary, residency
//! released, mu re-planned, replay from the checkpoint — produces a final
//! `TrainReport` bit-identical to the fault-free run. Plus graceful
//! degradation (a retry-exhausted job is evicted while its sibling
//! finishes) and the `--checkpoint` / `--resume` round trip.

mod common;

use std::path::PathBuf;

use mbs::coordinator::frontier::synthetic_entry;
use mbs::coordinator::planner::{auto_mu, auto_mu_transient};
use mbs::coordinator::{plan_admission, AdmissionRequest, JobOutcome};
use mbs::memory::MIB;
use mbs::runtime::{FaultPlan, VariantKey};
use mbs::{MicroBatchSpec, TrainConfig};

/// Write a fault spec to a unique temp file and return its path.
fn fault_spec(tag: &str, body: &str) -> PathBuf {
    let path =
        std::env::temp_dir().join(format!("mbs-faults-{}-{tag}.json", std::process::id()));
    std::fs::write(&path, body).expect("write fault spec");
    path
}

/// A small solo configuration (mirrors the jobs.rs fixture scale).
fn solo_cfg(overlap: bool) -> TrainConfig {
    TrainConfig::builder("microresnet18")
        .batch(24)
        .epochs(2)
        .dataset_len(48)
        .eval_len(16)
        .seed(3)
        .overlap(overlap)
        .build()
}

/// Assert two TrainReports agree bit-for-bit on everything deterministic.
fn assert_reports_identical(a: &mbs::TrainReport, b: &mbs::TrainReport, what: &str) {
    assert_eq!(a.mu, b.mu, "{what}: mu");
    assert_eq!(a.updates, b.updates, "{what}: updates");
    assert_eq!(a.train_epochs.len(), b.train_epochs.len(), "{what}");
    for (x, y) in a.train_epochs.iter().zip(&b.train_epochs) {
        assert_eq!(
            x.mean_loss.to_bits(),
            y.mean_loss.to_bits(),
            "{what}: epoch {} train loss diverged: {} vs {}",
            x.epoch,
            x.mean_loss,
            y.mean_loss
        );
        assert_eq!(x.primary_metric.to_bits(), y.primary_metric.to_bits(), "{what}");
        assert_eq!(x.micro_steps, y.micro_steps, "{what}");
        assert_eq!(x.updates, y.updates, "{what}");
    }
    for (x, y) in a.eval_epochs.iter().zip(&b.eval_epochs) {
        assert_eq!(x.mean_loss.to_bits(), y.mean_loss.to_bits(), "{what}: eval");
    }
    assert_eq!(
        a.final_eval.mean_loss.to_bits(),
        b.final_eval.mean_loss.to_bits(),
        "{what}: final eval"
    );
    assert_eq!(
        a.final_eval.primary_metric.to_bits(),
        b.final_eval.primary_metric.to_bits(),
        "{what}: final metric"
    );
}

/// Recovery's re-plan chain at the artifact layer, with no artifacts and
/// no PJRT (tier-1, never skipped): a shrunken post-fault budget
/// re-plans a smaller mu, and the re-planned variant is *fetchable* — the
/// artifact manager compiles it on demand — instead of recovery failing
/// on a missing export. Replaying the original mu afterwards is a pure
/// cache hit, mirroring `JobExec::recover` → `adopt_resolution` →
/// `Engine::load_model`.
#[test]
fn replanned_mu_fetches_fresh_variant_instead_of_failing() {
    let entry = synthetic_entry("classification").unwrap();
    // healthy plan at 4 MiB — the documented fixture point (mu = 32)
    let healthy = auto_mu(&entry, 16, 1024, 0, 4 * MIB, false).unwrap();
    assert_eq!(healthy.mu, 32, "fixture anchor moved");
    // post-fault re-plan against a much tighter *transient* budget — the
    // exact query JobExec::recover step 4 runs after releasing residency
    let replanned = auto_mu_transient(&entry, 16, 1024, 0, MIB, false)
        .expect("the re-plan itself must fit the shrunken budget");
    assert!(replanned.mu <= healthy.mu, "pressure can never grow mu");

    let (mgr, backend) = common::mock_manager("replan", 8);
    let fingerprint = entry.fingerprint();
    let key = |mu: usize| VariantKey {
        model: entry.name.clone(),
        size: 16,
        mu,
        overlap: false,
    };
    mgr.fetch(&key(healthy.mu), fingerprint).expect("healthy variant");
    mgr.fetch(&key(replanned.mu), fingerprint).expect("re-planned variant compiles on demand");
    let distinct = if replanned.mu == healthy.mu { 1 } else { 2 };
    assert_eq!(backend.compiles() as usize, distinct);
    // the replay path re-fetches what it already has: zero new compiles
    mgr.fetch(&key(healthy.mu), fingerprint).unwrap();
    mgr.fetch(&key(replanned.mu), fingerprint).unwrap();
    assert_eq!(backend.compiles() as usize, distinct, "replay must be all cache hits");
    std::fs::remove_dir_all(mgr.dir()).ok();
}

/// Admission may pin a mu that was never exported: `plan_admission`
/// derives the variant (synthetic exports are powers of two — 12 is not
/// one) and the manager compiles it on demand. Before the artifact
/// manager this was a manifest error at admission time.
#[test]
fn admission_accepts_unexported_pinned_mu_and_manager_compiles_it() {
    let entry = synthetic_entry("classification").unwrap();
    let req = AdmissionRequest {
        name: "pinned".into(),
        entry: entry.clone(),
        size: 16,
        batch: 24,
        eval_len: 0,
        mu: MicroBatchSpec::Fixed(12),
        overlap: false,
    };
    let verdicts = plan_admission(&[req], 16 * MIB);
    assert_eq!(verdicts.len(), 1);
    assert!(
        verdicts[0].outcome.is_admitted(),
        "unexported pinned mu must admit on memory grounds: {:?}",
        verdicts[0].outcome
    );
    assert_eq!(verdicts[0].outcome.mu(), Some(12));

    let (mgr, backend) = common::mock_manager("pinned-mu", 4);
    let key = VariantKey { model: entry.name.clone(), size: 16, mu: 12, overlap: false };
    let handle = mgr.fetch(&key, entry.fingerprint()).expect("derived variant compiles");
    assert_eq!(backend.compiles(), 1);
    assert!(handle.accum_path.exists());
    std::fs::remove_dir_all(mgr.dir()).ok();
}

#[test]
fn solo_step_fault_recovery_is_bit_identical() {
    // THE oracle: inject a transient step failure mid-epoch; the recovery
    // state machine checkpoints, releases, re-plans and replays — and the
    // final report must be indistinguishable from the fault-free run
    let Some(mut engine) = common::engine() else { return };
    let clean = mbs::train(&mut engine, &solo_cfg(false)).expect("fault-free run");

    let spec = fault_spec(
        "solo-step",
        r#"{"seed": 7, "max_retries": 3,
            "faults": [{"job": "*", "kind": "step", "at-step": 3}]}"#,
    );
    let mut cfg = solo_cfg(false);
    cfg.faults = Some(spec.to_string_lossy().into_owned());
    let faulted = mbs::train(&mut engine, &cfg).expect("faulted run must recover");
    assert_reports_identical(&clean, &faulted, "step-fault recovery");
    std::fs::remove_file(&spec).ok();
}

#[test]
fn solo_arena_and_lane_faults_recover_bit_identical() {
    // the other two injection layers: a refused arena charge (structured
    // OOM, the shrink-mu pressure path) and an upload-lane staging error
    // (async mode only) — same oracle, same recovery machinery
    let Some(mut engine) = common::engine() else { return };
    for (tag, overlap, body) in [
        (
            "solo-arena",
            false,
            r#"{"seed": 7, "faults": [{"job": "*", "kind": "arena", "at-step": 5}]}"#,
        ),
        (
            "solo-lane",
            true,
            r#"{"seed": 7, "faults": [{"job": "*", "kind": "lane", "at-step": 2}]}"#,
        ),
    ] {
        let clean = mbs::train(&mut engine, &solo_cfg(overlap)).expect("fault-free run");
        let spec = fault_spec(tag, body);
        let mut cfg = solo_cfg(overlap);
        cfg.faults = Some(spec.to_string_lossy().into_owned());
        let faulted = mbs::train(&mut engine, &cfg).expect("faulted run must recover");
        assert_reports_identical(&clean, &faulted, tag);
        std::fs::remove_file(&spec).ok();
    }
}

/// The jobs.rs heterogeneous fixture, rebuilt here (serial lanes).
fn heterogeneous_set(engine: &mbs::Engine) -> (mbs::JobSet, u64) {
    use mbs::coordinator::tenancy::{resident_claim, transient_bytes, JobSpec};
    use mbs::memory::Footprint;
    let rn = engine.manifest().model("microresnet18").unwrap().clone();
    let un = engine.manifest().model("microunet").unwrap().clone();
    let fp_rn = Footprint::from_manifest(&rn, rn.variant(16, 8).unwrap());
    let fp_un = Footprint::from_manifest(&un, un.variant(24, 8).unwrap());
    let claim = resident_claim(&rn, 16).unwrap() + resident_claim(&un, 24).unwrap();
    let transient = transient_bytes(&fp_rn, 8, 24, 16, false)
        .max(transient_bytes(&fp_un, 8, 16, 8, false));
    let capacity = claim + transient;
    let cls = TrainConfig::builder("microresnet18")
        .batch(24)
        .epochs(2)
        .dataset_len(48)
        .eval_len(16)
        .seed(3)
        .overlap(false)
        .build();
    let seg = TrainConfig::builder("microunet")
        .size(24)
        .batch(16)
        .epochs(2)
        .dataset_len(32)
        .eval_len(8)
        .seed(5)
        .overlap(false)
        .build();
    let set = mbs::JobSet {
        capacity_mib: None,
        jobs: vec![
            JobSpec { name: "cls".into(), task: None, cfg: cls },
            JobSpec { name: "seg".into(), task: None, cfg: seg },
        ],
    };
    (set, capacity)
}

#[test]
fn jobs_recovery_identity_and_counters() {
    // multi-tenant arm of the oracle: fault one tenant of the shared
    // arena; after recovery both jobs' reports must match the fault-free
    // interleaved run bit for bit, and the fault counters must attribute
    // the injection to the right job
    let Some(mut engine) = common::engine() else { return };
    let (set, capacity) = heterogeneous_set(&engine);
    let clean = mbs::train_jobs(&mut engine, &set, capacity).expect("fault-free jobs run");

    let plan = FaultPlan::parse(
        r#"{"seed": 11, "max_retries": 3,
            "faults": [{"job": "cls", "kind": "step", "at-step": 4}]}"#,
    )
    .unwrap();
    let faulted = mbs::train_jobs_faulted(&mut engine, &set, capacity, Some(&plan))
        .expect("faulted jobs run must recover");
    assert!(faulted.arena_peak_bytes <= faulted.capacity_bytes);

    for (a, b) in clean.jobs.iter().zip(&faulted.jobs) {
        assert_eq!(b.outcome, JobOutcome::Completed, "job {}: {:?}", b.name, b.error);
        let (ra, rb) = (a.report.as_ref().unwrap(), b.report.as_ref().unwrap());
        assert_reports_identical(ra, rb, &format!("jobs recovery, job {}", a.name));
    }
    let cls = &faulted.jobs[0];
    assert_eq!(cls.faults_injected, 1, "the cls step fault must have fired");
    assert_eq!(cls.retries, 1);
    assert_eq!(cls.recovered, 1);
    let seg = &faulted.jobs[1];
    assert_eq!(seg.faults_injected, 0, "seg had no fault entries");
    assert_eq!(seg.recovered, 0);
}

#[test]
fn retry_exhaustion_evicts_job_while_sibling_completes() {
    // graceful degradation: a job whose faults outlast its retry budget is
    // marked failed — structured OOM arithmetic in its error — and its
    // residency frees so the surviving tenant still finishes, identical to
    // running without the doomed sibling's interference
    let Some(mut engine) = common::engine() else { return };
    let (set, capacity) = heterogeneous_set(&engine);

    let plan = FaultPlan::parse(
        r#"{"seed": 13, "max_retries": 2,
            "faults": [{"job": "cls", "kind": "arena", "prob": 1.0, "times": 50}]}"#,
    )
    .unwrap();
    let report = mbs::train_jobs_faulted(&mut engine, &set, capacity, Some(&plan))
        .expect("the set run itself must not abort");

    let cls = &report.jobs[0];
    assert_eq!(cls.outcome, JobOutcome::Failed, "cls must exhaust its retries");
    assert!(cls.report.is_none(), "an evicted job carries no report");
    let err = cls.error.as_ref().expect("failed jobs record their terminal error");
    assert!(err.contains("injected fault"), "structured fault context lost: {err}");
    assert!(cls.retries >= 2, "both retries must have been consumed: {}", cls.retries);

    let seg = &report.jobs[1];
    assert_eq!(seg.outcome, JobOutcome::Completed, "survivor: {:?}", seg.error);
    let r = seg.report.as_ref().expect("survivor carries a report");
    assert!(r.updates > 0);
    assert!(report.arena_peak_bytes <= report.capacity_bytes);
}

#[test]
fn checkpoint_then_resume_matches_uninterrupted_run() {
    // preempt/resume: train 1 epoch and checkpoint, then resume a 2-epoch
    // schedule from it — the resumed run replays exactly epoch 1 and its
    // final eval is bit-identical to the uninterrupted 2-epoch run
    let Some(mut engine) = common::engine() else { return };
    let stem = std::env::temp_dir().join(format!("mbs-resume-{}", std::process::id()));
    let stem_s = stem.to_string_lossy().into_owned();

    let full = mbs::train(&mut engine, &solo_cfg(false)).expect("uninterrupted run");

    let mut first = solo_cfg(false);
    first.epochs = 1;
    first.checkpoint = Some(stem_s.clone());
    let half = mbs::train(&mut engine, &first).expect("first epoch + checkpoint");
    assert_eq!(half.train_epochs.len(), 1);

    let mut resumed_cfg = solo_cfg(false);
    resumed_cfg.resume = Some(stem_s.clone());
    let resumed = mbs::train(&mut engine, &resumed_cfg).expect("resumed run");
    // only the remaining epoch is replayed...
    assert_eq!(resumed.train_epochs.len(), 1, "resume must skip the completed epoch");
    // ...and it is the SAME epoch 1 the uninterrupted run saw
    let (a, b) = (&full.train_epochs[1], &resumed.train_epochs[0]);
    assert_eq!(a.mean_loss.to_bits(), b.mean_loss.to_bits(), "epoch 1 loss diverged");
    assert_eq!(a.micro_steps, b.micro_steps);
    assert_eq!(
        full.final_eval.mean_loss.to_bits(),
        resumed.final_eval.mean_loss.to_bits(),
        "final eval diverged after resume"
    );
    std::fs::remove_file(stem.with_extension("bin")).ok();
    std::fs::remove_file(stem.with_extension("json")).ok();
}

#[test]
fn resume_mid_epoch_skips_consumed_updates() {
    // the partial-epoch path: a checkpoint whose update counter is not an
    // epoch multiple resumes inside the epoch, consuming the already-done
    // updates from the stream before training restarts. The update counter
    // is metadata (not covered by the payload checksum), so a doctored
    // counter stands in for a mid-epoch save.
    let Some(mut engine) = common::engine() else { return };
    let stem = std::env::temp_dir().join(format!("mbs-midresume-{}", std::process::id()));
    let stem_s = stem.to_string_lossy().into_owned();

    let mut first = solo_cfg(false);
    first.epochs = 1;
    first.checkpoint = Some(stem_s.clone());
    let one = mbs::train(&mut engine, &first).expect("one-epoch run");
    let per_epoch = one.train_epochs[0].updates;
    assert!(per_epoch >= 2, "fixture needs >= 2 updates per epoch, got {per_epoch}");

    // rewind the counter to mid-epoch: 1 update into epoch 0
    let meta_path = stem.with_extension("json");
    let meta = std::fs::read_to_string(&meta_path).unwrap();
    let doctored =
        meta.replace(&format!("\"updates\": {per_epoch}"), "\"updates\": 1");
    assert_ne!(doctored, meta, "update counter not found in checkpoint metadata");
    std::fs::write(&meta_path, doctored).unwrap();

    let mut resumed_cfg = solo_cfg(false);
    resumed_cfg.epochs = 1;
    resumed_cfg.resume = Some(stem_s.clone());
    let resumed = mbs::train(&mut engine, &resumed_cfg).expect("mid-epoch resume");
    assert_eq!(resumed.train_epochs.len(), 1);
    // `updates` is cumulative (rt.updates at epoch end): resuming from 1
    // must land on the same total; the skipped mini-batch shows up as the
    // missing micro-steps (the fixture's batches are uniform, so one
    // update's worth divides evenly)
    assert_eq!(
        resumed.train_epochs[0].updates, per_epoch,
        "the resumed epoch must land on the full run's cumulative update count"
    );
    let full_steps = one.train_epochs[0].micro_steps;
    let per_update = full_steps / per_epoch as usize;
    assert_eq!(
        resumed.train_epochs[0].micro_steps,
        full_steps - per_update,
        "exactly one update's micro-steps must have been skipped"
    );
    std::fs::remove_file(stem.with_extension("bin")).ok();
    std::fs::remove_file(&meta_path).ok();
}

#[test]
fn checkpoint_every_writes_periodic_checkpoints() {
    // --checkpoint-every N: the stem must exist (and validate) after the
    // run; a pinned-mu rerun resumed from the final checkpoint does no
    // further training (schedule already complete) but still evals
    let Some(mut engine) = common::engine() else { return };
    let stem = std::env::temp_dir().join(format!("mbs-periodic-{}", std::process::id()));
    let stem_s = stem.to_string_lossy().into_owned();

    let mut cfg = solo_cfg(false);
    cfg.mu = MicroBatchSpec::Fixed(8);
    cfg.checkpoint = Some(stem_s.clone());
    cfg.checkpoint_every = Some(1);
    let report = mbs::train(&mut engine, &cfg).expect("checkpointed run");
    assert!(stem.with_extension("bin").exists(), "missing checkpoint payload");
    assert!(stem.with_extension("json").exists(), "missing checkpoint metadata");

    let mut resume_cfg = cfg.clone();
    resume_cfg.checkpoint = None;
    resume_cfg.checkpoint_every = None;
    resume_cfg.resume = Some(stem_s);
    let resumed = mbs::train(&mut engine, &resume_cfg).expect("resume from final state");
    assert!(resumed.train_epochs.is_empty(), "schedule was already complete");
    assert_eq!(
        report.final_eval.mean_loss.to_bits(),
        resumed.final_eval.mean_loss.to_bits(),
        "final-state resume must evaluate the identical parameters"
    );
    std::fs::remove_file(stem.with_extension("bin")).ok();
    std::fs::remove_file(stem.with_extension("json")).ok();
}

#[test]
fn corrupt_and_truncated_resume_checkpoints_are_structured_errors() {
    // a damaged --resume checkpoint must surface as a structured MbsError
    // from the validated reader (runtime/checkpoint.rs), never a panic or
    // a silent resume from garbage state
    let Some(mut engine) = common::engine() else { return };
    let stem = std::env::temp_dir().join(format!("mbs-corrupt-resume-{}", std::process::id()));
    let stem_s = stem.to_string_lossy().into_owned();

    let mut first = solo_cfg(false);
    first.epochs = 1;
    first.checkpoint = Some(stem_s.clone());
    mbs::train(&mut engine, &first).expect("checkpointed run");
    let bin = stem.with_extension("bin");
    let meta = stem.with_extension("json");
    let good_bin = std::fs::read(&bin).expect("payload written");
    let good_meta = std::fs::read(&meta).expect("metadata written");

    let mut resume_cfg = solo_cfg(false);
    resume_cfg.resume = Some(stem_s.clone());

    // truncated payload: the length/checksum validation must reject it
    std::fs::write(&bin, &good_bin[..good_bin.len() / 2]).unwrap();
    let err = mbs::train(&mut engine, &resume_cfg)
        .expect_err("truncated checkpoint payload must fail the resume");
    assert!(!err.to_string().is_empty());
    assert!(!err.recoverable(), "a damaged checkpoint is not a transient fault: {err:?}");

    // corrupt payload bytes at full length: the checksum must catch it
    let mut flipped = good_bin.clone();
    let mid = flipped.len() / 2;
    flipped[mid] ^= 0xff;
    std::fs::write(&bin, &flipped).unwrap();
    let err = mbs::train(&mut engine, &resume_cfg)
        .expect_err("bit-flipped checkpoint payload must fail the resume");
    assert!(!err.to_string().is_empty());

    // garbage metadata: the json side of the pair is validated too
    std::fs::write(&bin, &good_bin).unwrap();
    std::fs::write(&meta, b"{ this is not a checkpoint").unwrap();
    let err = mbs::train(&mut engine, &resume_cfg)
        .expect_err("garbage checkpoint metadata must fail the resume");
    assert!(!err.to_string().is_empty());

    // restore the pair: the resume works again, proving the failures above
    // were the corruption and nothing else
    std::fs::write(&meta, &good_meta).unwrap();
    mbs::train(&mut engine, &resume_cfg).expect("intact checkpoint resumes");
    std::fs::remove_file(&bin).ok();
    std::fs::remove_file(&meta).ok();
}

#[test]
fn stall_conversion_recovery_is_bit_identical() {
    // the watchdog contract end to end: an injected wall-clock stall that
    // outruns its deadline is converted into a recoverable Deadline fault,
    // and the recovery replay lands bit-identical to the clean run — on
    // both hang surfaces (upload-lane recv for async jobs, the executor
    // step for serial jobs)
    let Some(mut engine) = common::engine() else { return };
    for (tag, overlap) in [("stall-lane", true), ("stall-step", false)] {
        let clean = mbs::train(&mut engine, &solo_cfg(overlap)).expect("fault-free run");
        let spec = fault_spec(
            tag,
            r#"{"seed": 7, "max_retries": 3,
                "watchdog": {"lane-recv-ms": 150, "step-ms": 150,
                             "compile-ms": 5000, "checkpoint-ms": 5000},
                "faults": [{"job": "*", "kind": "stall", "at-step": 2, "stall-ms": 450}]}"#,
        );
        let mut cfg = solo_cfg(overlap);
        cfg.faults = Some(spec.to_string_lossy().into_owned());
        let faulted =
            mbs::train(&mut engine, &cfg).expect("stalled run must convert and recover");
        assert_reports_identical(&clean, &faulted, tag);
        std::fs::remove_file(&spec).ok();
    }
}

#[test]
fn checkpoint_fault_recovery_is_bit_identical() {
    // the torn-write shape: the checkpoint fault fires AFTER the atomic
    // snapshot save, so the on-disk snapshot is valid and current and the
    // recovery it triggers replays the phase bit-identically
    let Some(mut engine) = common::engine() else { return };
    let clean = mbs::train(&mut engine, &solo_cfg(false)).expect("fault-free run");
    let spec = fault_spec(
        "ckpt-fault",
        r#"{"seed": 7, "max_retries": 3,
            "faults": [{"job": "*", "kind": "checkpoint", "at-step": 1}]}"#,
    );
    let mut cfg = solo_cfg(false);
    cfg.faults = Some(spec.to_string_lossy().into_owned());
    let faulted = mbs::train(&mut engine, &cfg).expect("checkpoint fault must recover");
    assert_reports_identical(&clean, &faulted, "checkpoint-fault recovery");
    std::fs::remove_file(&spec).ok();
}

#[test]
fn compile_fault_at_materialize_evicts_job_while_sibling_completes() {
    // the compile/artifact seam: a fault injected at the engine's variant
    // resolve kills the job being materialized as a structured eviction;
    // the sibling still trains to completion
    let Some(mut engine) = common::engine() else { return };
    let (set, capacity) = heterogeneous_set(&engine);

    let plan = FaultPlan::parse(
        r#"{"seed": 7, "max_retries": 3,
            "faults": [{"job": "*", "kind": "compile", "at-step": 0}]}"#,
    )
    .unwrap();
    let report = mbs::train_jobs_faulted(&mut engine, &set, capacity, Some(&plan))
        .expect("the set run itself must not abort");
    assert_eq!(engine.compile_faults_injected(), 1, "the resolve fault must have fired");

    let cls = &report.jobs[0];
    assert_eq!(cls.outcome, JobOutcome::Failed, "first materialize hits resolve attempt 0");
    let err = cls.error.as_ref().expect("evicted jobs record their terminal error");
    assert!(err.contains("injected"), "structured fault context lost: {err}");

    let seg = &report.jobs[1];
    assert_eq!(seg.outcome, JobOutcome::Completed, "survivor: {:?}", seg.error);
    assert!(seg.report.as_ref().expect("survivor carries a report").updates > 0);
}
