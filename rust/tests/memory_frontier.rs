//! DESIGN.md invariant 3 end-to-end: the native baseline trains exactly up
//! to the capacity frontier and fails ("Failed" cells) beyond it, while MBS
//! trains any mini-batch whose micro-batch fits — the paper's headline.

mod common;

use mbs::memory::{Footprint, MemoryModel};
use mbs::{MbsError, MicroBatchSpec, TrainConfig};

fn capacity_for(engine: &mbs::Engine, model: &str, size: usize, mu: usize, native_max: usize) -> u64 {
    let entry = engine.manifest().model(model).unwrap();
    let variant = entry.variant(size, mu).unwrap();
    let fp = Footprint::from_manifest(entry, variant);
    MemoryModel::capacity_for_native_max(&fp, native_max)
}

#[test]
fn native_fails_beyond_frontier_mbs_succeeds() {
    let Some(mut engine) = common::engine() else { return };
    // capacity chosen so the native max batch is exactly 16 (paper table 2)
    let cap = capacity_for(&engine, "microresnet18", 16, 16, 16);

    let mk = |batch: usize, use_mbs: bool| {
        let mut c = TrainConfig::builder("microresnet18")
            .mu(16)
            .batch(batch)
            .epochs(1)
            .dataset_len(max_of(batch, 32))
            .eval_len(16)
            .skip_eval()
            // this test pins capacity exactly at the SERIAL frontier; the
            // overlapped pipeline's extra input slot has its own admission
            // tests (tests/overlap.rs, planner unit tests)
            .overlap(false)
            .build();
        c.capacity_mib = None; // set bytes directly below
        c.use_mbs = use_mbs;
        (c, cap)
    };

    // batch 16 trains both ways
    for use_mbs in [false, true] {
        let (mut cfg, cap) = mk(16, use_mbs);
        cfg.capacity_mib = Some(cap.div_ceil(1 << 20));
        let r = mbs::train(&mut engine, &cfg);
        assert!(r.is_ok(), "batch 16 use_mbs={use_mbs} should train: {:?}", r.err());
    }

    // batch 64: native fails with a structured OOM, MBS trains
    let (mut cfg, _) = mk(64, false);
    cfg.capacity_mib = Some(cap / (1 << 20)); // round DOWN so 64 can't sneak in
    match mbs::train(&mut engine, &cfg) {
        Err(MbsError::Oom { needed_bytes, capacity_bytes, .. }) => {
            assert!(needed_bytes > capacity_bytes);
        }
        other => panic!("expected OOM, got {other:?}"),
    }
    let (mut cfg, _) = mk(64, true);
    cfg.capacity_mib = Some(cap / (1 << 20));
    let r = mbs::train(&mut engine, &cfg).expect("MBS batch 64 should train");
    assert_eq!(r.batch, 64);
    assert!(r.updates > 0);
}

fn max_of(a: usize, b: usize) -> usize {
    a.max(b)
}

#[test]
fn auto_mu_trains_where_native_fails() {
    // the paper's actual algorithm: the user names only batch + capacity;
    // the planner derives mu from the memory remaining after the model is
    // resident, and trains where the native baseline OOMs
    let Some(mut engine) = common::engine() else { return };
    let cap = capacity_for(&engine, "microresnet18", 16, 8, 8); // native max 8
    let cap_mib = cap.div_ceil(1 << 20);

    let mut auto_cfg = TrainConfig::builder("microresnet18")
        .batch(64)
        .epochs(1)
        .dataset_len(64)
        .skip_eval()
        .build();
    assert_eq!(auto_cfg.mu, MicroBatchSpec::Auto, "auto is the default");
    auto_cfg.capacity_mib = Some(cap_mib);
    let r = mbs::train(&mut engine, &auto_cfg).expect("auto-mu run should fit");
    assert!(r.mu >= 1, "chosen mu must be reported");
    assert!(r.updates > 0);
    // the plan honors the admission arithmetic it was derived from
    let entry = engine.manifest().model("microresnet18").unwrap();
    let variant = entry.variant(16, r.mu).unwrap();
    let fp = Footprint::from_manifest(entry, variant);
    assert!(fp.step_bytes(r.mu) <= cap_mib * (1 << 20));

    // same batch + capacity natively: structured OOM (the "Failed" cell)
    let mut native = auto_cfg.clone();
    native.use_mbs = false;
    match mbs::train(&mut engine, &native) {
        Err(e) if e.is_oom() => {}
        other => panic!("expected native OOM, got {other:?}"),
    }
}

#[test]
fn resident_state_too_big_fails_before_any_step() {
    let Some(mut engine) = common::engine() else { return };
    let mut cfg = TrainConfig::builder("microresnet18")
        .mu(8)
        .batch(8)
        .epochs(1)
        .dataset_len(16)
        .skip_eval()
        .build();
    cfg.capacity_mib = Some(1); // smaller than params+grads+momentum+fixed
    match mbs::train(&mut engine, &cfg) {
        Err(e) if e.is_oom() => {}
        other => panic!("expected resident OOM, got {other:?}"),
    }
}

#[test]
fn oom_error_carries_arithmetic() {
    let Some(mut engine) = common::engine() else { return };
    let mut cfg = TrainConfig::builder("microresnet18")
        .mu(16)
        .batch(512)
        .epochs(1)
        .dataset_len(512)
        .skip_eval()
        .build();
    cfg.use_mbs = false;
    cfg.capacity_mib = Some(64);
    match mbs::train(&mut engine, &cfg) {
        Err(MbsError::Oom { needed_bytes, available_bytes, capacity_bytes, context }) => {
            assert!(needed_bytes > capacity_bytes);
            assert!(available_bytes < capacity_bytes);
            assert!(context.contains("512"), "context should name the batch: {context}");
        }
        other => panic!("expected OOM, got {other:?}"),
    }
}

#[test]
fn mbs_depends_only_on_mu_not_batch() {
    let Some(mut engine) = common::engine() else { return };
    let cap = capacity_for(&engine, "microresnet18", 16, 8, 8);
    for batch in [8usize, 64, 256] {
        let mut cfg = TrainConfig::builder("microresnet18")
            .mu(8)
            .batch(batch)
            .epochs(1)
            .dataset_len(batch.max(16))
            .skip_eval()
            // capacity sits exactly at the serial mu=8 frontier
            .overlap(false)
            .build();
        cfg.capacity_mib = Some(cap.div_ceil(1 << 20));
        let r = mbs::train(&mut engine, &cfg);
        assert!(r.is_ok(), "MBS batch {batch} should fit: {:?}", r.err());
    }
}
