//! End-to-end integration: every model trains and improves over random
//! initialization; MBS and native arms match when both fit; evaluation and
//! reporting plumbing works.

mod common;

use mbs::coordinator::NormalizationMode;
use mbs::TrainConfig;

#[test]
fn every_model_trains_one_epoch() {
    let Some(mut engine) = common::engine() else { return };
    let models: Vec<String> = engine.manifest().models.keys().cloned().collect();
    for model in models {
        let entry = engine.manifest().model(&model).unwrap().clone();
        let v = &entry.variants[0];
        let (size, mu) = (v.size, v.mu);
        let cfg = TrainConfig::builder(&model)
            .size(size)
            .mu(mu)
            .batch(2 * mu)
            .epochs(1)
            .dataset_len(4 * mu)
            .eval_len(mu)
            .build();
        let r = mbs::train(&mut engine, &cfg)
            .unwrap_or_else(|e| panic!("{model} failed to train: {e}"));
        assert!(r.final_eval.mean_loss.is_finite(), "{model}: non-finite loss");
        assert!(r.updates >= 2, "{model}: expected updates");
        assert_eq!(r.train_epochs.len(), 1);
    }
}

#[test]
fn mbs_and_native_equal_loss_when_both_fit() {
    // with batch <= native max, the two arms are the same arithmetic on the
    // same data: per-epoch mean losses must agree to fp tolerance
    let Some(mut engine) = common::engine() else { return };
    let base = TrainConfig::builder("microresnet18")
        .mu(16)
        .batch(16)
        .epochs(2)
        .dataset_len(64)
        .eval_len(32)
        .seed(3)
        .norm(NormalizationMode::Paper);
    let mbs_report = mbs::train(&mut engine, &base.build()).expect("mbs arm");
    let native_cfg = {
        let mut c = TrainConfig::builder("microresnet18")
            .mu(16)
            .batch(16)
            .epochs(2)
            .dataset_len(64)
            .eval_len(32)
            .seed(3)
            .build();
        c.use_mbs = false;
        c
    };
    let native_report = mbs::train(&mut engine, &native_cfg).expect("native arm");
    for (a, b) in mbs_report.train_epochs.iter().zip(&native_report.train_epochs) {
        let d = (a.mean_loss - b.mean_loss).abs();
        assert!(d < 1e-4, "epoch {} loss differs: {} vs {}", a.epoch, a.mean_loss, b.mean_loss);
    }
    assert!(
        (mbs_report.final_eval.primary_metric - native_report.final_eval.primary_metric).abs()
            < 1e-6
    );
}

#[test]
fn loss_decreases_over_training() {
    let Some(mut engine) = common::engine() else { return };
    let cfg = TrainConfig::builder("microunet")
        .size(24)
        .mu(8)
        .batch(16)
        .epochs(3)
        .dataset_len(64)
        .eval_len(16)
        .seed(0)
        .build();
    let r = mbs::train(&mut engine, &cfg).expect("train");
    let first = r.train_epochs.first().unwrap().mean_loss;
    let last = r.train_epochs.last().unwrap().mean_loss;
    assert!(
        last < first,
        "U-Net loss should drop over 3 epochs: {first} -> {last}"
    );
}

#[test]
fn report_fields_consistent() {
    let Some(mut engine) = common::engine() else { return };
    let cfg = TrainConfig::builder("microresnet18")
        .mu(8)
        .batch(24) // ragged: 24 = 8*3
        .epochs(2)
        .dataset_len(50) // ragged epoch too: 50 = 24+24+2
        .eval_len(20)
        .norm(NormalizationMode::Exact)
        .build();
    let r = mbs::train(&mut engine, &cfg).expect("train");
    // 3 mini-batches/epoch * 2 epochs
    assert_eq!(r.updates, 6);
    // every sample visited once per epoch
    assert_eq!(r.train_epochs[0].samples, 50);
    // micro-steps: 24->3, 24->3, 2->1 = 7 per epoch
    assert_eq!(r.train_epochs[0].micro_steps, 7);
    assert_eq!(r.eval_epochs.len(), 2);
    assert_eq!(r.final_eval.samples, 20);
    assert!(r.epoch_wall_mean.as_secs_f64() > 0.0);
}

#[test]
fn eval_stats_identical_across_streaming_policies() {
    // ROADMAP follow-up: eval now routes through the configured streaming
    // policy — the double-buffered sweep must produce exactly the stats the
    // synchronous one does (same items, same order, same accumulation)
    let Some(mut engine) = common::engine() else { return };
    use mbs::coordinator::{evaluate_pooled, StreamingPolicy};
    use mbs::data::{BufPool, Dataset, SynthFlowers};
    use mbs::metrics::MetricKind;
    use std::sync::Arc;
    let mut rt = engine.load_model("microresnet18", 16, 8).expect("load");
    let ds: Arc<dyn Dataset> = Arc::new(SynthFlowers::new(16, 102, 40, 7));
    // repeat-eval callers hold ONE warmed pool and go through
    // evaluate_pooled (ROADMAP PR 4 follow-up): both sweeps circulate the
    // same host buffers instead of re-warming a fresh pool per call
    let pool = Arc::new(BufPool::for_prefetch(2));
    pool.warm(BufPool::buffers_for(2), ds.as_ref(), 8);
    let sync = evaluate_pooled(
        &mut rt,
        MetricKind::Classification,
        &ds,
        0,
        StreamingPolicy::Synchronous,
        0,
        &pool,
    )
    .expect("sync eval");
    let buffered = evaluate_pooled(
        &mut rt,
        MetricKind::Classification,
        &ds,
        0,
        StreamingPolicy::DoubleBuffered,
        2,
        &pool,
    )
    .expect("buffered eval");
    assert_eq!(sync.mean_loss, buffered.mean_loss, "eval loss diverged across policies");
    assert_eq!(sync.primary_metric, buffered.primary_metric);
    assert_eq!(sync.samples, buffered.samples);
    assert_eq!(sync.micro_steps, buffered.micro_steps);
    // the shared pool served every lease of both sweeps without allocating
    let stats = pool.stats();
    assert_eq!(stats.allocs, 0, "repeat-eval allocated host buffers: {stats:?}");
    assert_eq!(stats.hits, stats.leases);
}

#[test]
fn pooled_run_is_allocation_free_and_instrumented() {
    // the tentpole invariant end-to-end: a warmed pool serves every lease
    // of a full training run (hit rate 1.0, zero cold allocations), and the
    // stage timers actually attribute time to the pipeline
    let Some(mut engine) = common::engine() else { return };
    let cfg = TrainConfig::builder("microresnet18")
        .mu(8)
        .batch(24)
        .epochs(2)
        .dataset_len(48)
        .eval_len(16)
        .build();
    let r = mbs::train(&mut engine, &cfg).expect("train");
    assert_eq!(r.pool.allocs, 0, "hot path allocated host buffers: {:?}", r.pool);
    assert!(r.pool.leases > 0);
    assert_eq!(r.pool.hits, r.pool.leases, "every lease must be a pool hit");
    assert!((r.pool.hit_rate() - 1.0).abs() < 1e-12);
    assert!(r.stages.execute > std::time::Duration::ZERO, "execute stage untimed");
    assert!(r.stages.assemble > std::time::Duration::ZERO, "assemble stage untimed");
    assert!(r.train_epochs.iter().all(|e| e.stages.upload > std::time::Duration::ZERO));
}

#[test]
fn eval_is_side_effect_free() {
    let Some(mut engine) = common::engine() else { return };
    let mut rt = engine.load_model("microresnet18", 16, 8).expect("load");
    use mbs::data::{loader, Dataset, SynthFlowers};
    use std::sync::Arc;
    let ds: Arc<dyn Dataset> = Arc::new(SynthFlowers::new(16, 102, 32, 1));
    let indices: Vec<usize> = (0..8).collect();
    let mb = loader::assemble(ds.as_ref(), &indices, 8, 0);
    let p0 = rt.params_to_host().unwrap();
    let e1 = rt.eval_step(&mb).unwrap();
    let e2 = rt.eval_step(&mb).unwrap();
    assert_eq!(e1, e2, "eval must be deterministic");
    let p1 = rt.params_to_host().unwrap();
    assert_eq!(common::max_abs_diff(&p0, &p1), 0.0, "eval must not touch params");
    let acc = rt.acc_to_host().unwrap();
    assert!(acc.iter().flatten().all(|&v| v == 0.0), "eval must not touch acc");
}
