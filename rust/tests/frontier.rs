//! Integration tests for the frontier sweep + shared bench schema, through
//! the public API only. These are planner-level (pure capacity arithmetic)
//! and run on a clean checkout — no compiled artifacts needed, never
//! skipped (see rust/docs/TESTING.md). The `--time-all` variant-resolution
//! path is covered here too, against the mock-backed artifact manager
//! instead of compiled artifacts.

mod common;

use mbs::coordinator::frontier::{synthetic_entry, Feasibility, FrontierGrid};
use mbs::coordinator::planner::auto_mu;
use mbs::memory::MIB;
use mbs::metrics::bench_report;
use mbs::runtime::VariantKey;
use mbs::util::json::Json;

/// The documented dry-run default grid produces all three classes and a
/// frontier (boundary) that grows with capacity.
#[test]
fn dry_run_grid_reproduces_headline_shape() {
    let entry = synthetic_entry("classification").unwrap();
    let capacities: Vec<u64> = [1u64, 2, 4, 8].iter().map(|&m| m * MIB).collect();
    let batches = [8usize, 32, 64, 128, 256];
    let grid = FrontierGrid::sweep(&entry, 16, 0, &capacities, &batches, false).unwrap();
    assert_eq!(grid.points.len(), 20);

    let class = |c_mib: u64, b: usize| {
        grid.points
            .iter()
            .find(|p| p.capacity_bytes == c_mib * MIB && p.batch == b)
            .map(|p| p.feasibility)
            .unwrap()
    };
    // 1 MiB: the resident state alone fills the device — everything OOMs
    for &b in &batches {
        assert!(!class(1, b).is_feasible(), "1 MiB batch {b} must OOM");
    }
    // the paper's headline cell: a batch 32x beyond mu streams at 2 MiB
    assert!(matches!(class(2, 256), Feasibility::Mbs { .. }));
    // 8 MiB: small batches are native, huge ones still stream
    assert!(matches!(class(8, 8), Feasibility::Native { .. }));
    assert!(matches!(class(8, 256), Feasibility::Mbs { .. }));

    // monotone frontier: the largest feasible batch never shrinks as
    // capacity grows, and feasibility is downward-closed in batch
    let mut prev_best = 0usize;
    for &c in &capacities {
        let best = grid
            .points
            .iter()
            .filter(|p| p.capacity_bytes == c && p.feasibility.is_feasible())
            .map(|p| p.batch)
            .max()
            .unwrap_or(0);
        assert!(best >= prev_best, "frontier shrank at capacity {c}");
        prev_best = best;
        for &b in &batches {
            if b < best {
                assert!(
                    class(c / MIB, b).is_feasible(),
                    "batch {b} < feasible {best} but infeasible at {c}"
                );
            }
        }
    }
}

/// BENCH_frontier.json validates against the documented shared schema:
/// envelope keys, axes, and one grid entry per point with class-specific
/// fields.
#[test]
fn frontier_report_matches_documented_schema() {
    let entry = synthetic_entry("segmentation").unwrap();
    let capacities: Vec<u64> = [2u64, 8].iter().map(|&m| m * MIB).collect();
    let batches = [8usize, 128];
    let grid = FrontierGrid::sweep(&entry, 16, 0, &capacities, &batches, false).unwrap();
    let parsed = Json::parse(&grid.to_report(true).to_json()).unwrap();

    assert_eq!(parsed.get("bench").and_then(Json::as_str), Some("frontier"));
    assert_eq!(parsed.get("mode").and_then(Json::as_str), Some("dry-run"));
    assert_eq!(parsed.get("overlap").and_then(Json::as_str), Some("off"));
    assert_eq!(parsed.get("model").and_then(Json::as_str), Some("synthetic-segmentation"));
    assert_eq!(
        parsed.get("capacities_mib").and_then(Json::as_arr).map(|a| a.len()),
        Some(2)
    );
    let points = parsed.get("grid").and_then(Json::as_arr).unwrap();
    assert_eq!(points.len(), 4);
    for p in points {
        let class = p.get("class").and_then(Json::as_str).unwrap();
        assert!(p.get("capacity_mib").and_then(Json::as_f64).is_some());
        assert!(p.get("batch").and_then(Json::as_u64).is_some());
        match class {
            "native" | "mbs" => {
                assert!(p.get("mu").and_then(Json::as_u64).unwrap() > 0);
                assert!(p.get("n_smu").and_then(Json::as_u64).unwrap() > 0);
            }
            "oom" => {
                assert!(p.get("needed_bytes").and_then(Json::as_u64).unwrap() > 0);
            }
            other => panic!("unknown class {other}"),
        }
    }
}

/// Overlap pricing shifts the frontier inward but never outward: every
/// point feasible with the pipeline's second input slot charged is also
/// feasible without it, and the planned mu never grows — while the grid
/// still produces MBS cells (the headline region survives the stricter
/// budget).
#[test]
fn overlap_priced_grid_is_a_subset_of_the_serial_one() {
    let entry = synthetic_entry("classification").unwrap();
    let capacities: Vec<u64> = [1u64, 2, 4, 8].iter().map(|&m| m * MIB).collect();
    let batches = [8usize, 32, 64, 128, 256];
    let serial = FrontierGrid::sweep(&entry, 16, 0, &capacities, &batches, false).unwrap();
    let overlapped = FrontierGrid::sweep(&entry, 16, 0, &capacities, &batches, true).unwrap();
    assert!(overlapped.overlap && !serial.overlap);
    assert_eq!(serial.points.len(), overlapped.points.len());
    for (s, o) in serial.points.iter().zip(&overlapped.points) {
        assert_eq!((s.capacity_bytes, s.batch), (o.capacity_bytes, o.batch));
        if o.feasibility.is_feasible() {
            assert!(
                s.feasibility.is_feasible(),
                "({}, {}) feasible WITH overlap but not without",
                o.capacity_bytes,
                o.batch
            );
            let (smu, omu) = (s.feasibility.mu().unwrap(), o.feasibility.mu().unwrap());
            assert!(
                omu <= smu,
                "overlap grew mu {smu} -> {omu} at ({}, {})",
                o.capacity_bytes,
                o.batch
            );
        }
    }
    assert!(
        overlapped.points.iter().any(|p| matches!(p.feasibility, Feasibility::Mbs { .. })),
        "the MBS region must survive overlap pricing"
    );
    // the overlap grid's feasible region is what --time-all would sweep
    assert!(overlapped.feasible_points().len() <= serial.feasible_points().len());
}

/// The `--time-all` resolution story with no artifacts anywhere: every
/// feasible sweep point's planned variant resolves through the artifact
/// manager — compiled on demand by the mock backend on the cold sweep
/// (one compile per distinct mu, thanks to content addressing), served
/// entirely from cache on the warm one. This is the same planner → key →
/// fetch chain `mbs frontier --time-all` drives, minus PJRT.
#[test]
fn time_all_feasible_points_resolve_through_the_artifact_manager() {
    let entry = synthetic_entry("classification").unwrap();
    let capacities: Vec<u64> = [1u64, 2, 4, 8].iter().map(|&m| m * MIB).collect();
    let batches = [8usize, 32, 64, 128, 256];
    let grid = FrontierGrid::sweep(&entry, 16, 0, &capacities, &batches, false).unwrap();
    let feasible = grid.feasible_points();
    assert!(!feasible.is_empty(), "fixture must have a feasible region");

    let (mgr, backend) = common::mock_manager("frontier-sweep", 32);
    let fingerprint = entry.fingerprint();
    let mut planned_mus = std::collections::BTreeSet::new();
    for &(capacity, batch) in &feasible {
        let res = auto_mu(&entry, 16, batch, 0, capacity, false)
            .expect("a point classified feasible must plan");
        planned_mus.insert(res.mu);
        let key =
            VariantKey { model: entry.name.clone(), size: 16, mu: res.mu, overlap: false };
        let handle = mgr.fetch(&key, fingerprint).expect("sweep point resolves on demand");
        assert!(handle.accum_path.exists() && handle.eval_path.exists());
    }
    assert_eq!(
        backend.compiles() as usize,
        planned_mus.len(),
        "cold sweep: one compile per distinct planned mu, the rest coalesce into hits"
    );

    // the warm sweep — a re-run over the same grid — compiles nothing
    let cold_compiles = mgr.stats().compiles;
    for &(capacity, batch) in &feasible {
        let res = auto_mu(&entry, 16, batch, 0, capacity, false).unwrap();
        let key =
            VariantKey { model: entry.name.clone(), size: 16, mu: res.mu, overlap: false };
        mgr.fetch(&key, fingerprint).expect("warm sweep point");
    }
    let warm = mgr.stats();
    assert_eq!(warm.compiles, cold_compiles, "warm sweep must be all cache hits");
    assert!(warm.hits >= feasible.len() as u64);
    std::fs::remove_dir_all(mgr.dir()).ok();
}

/// The --compare trend check over real report files: a throughput drop
/// beyond the threshold is flagged, a small wobble is not.
#[test]
fn compare_files_flags_real_regressions() {
    let dir = std::env::temp_dir().join(format!("mbs-frontier-it-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let prev = dir.join("prev.json");
    let cur = dir.join("cur.json");
    let report = |items_per_sec: f64| {
        format!(
            "{{\"bench\": \"streaming\", \"mode\": \"assemble-only\", \
              \"pooled_items_per_sec\": {items_per_sec}, \"assemble_mean_ms\": 1.0}}"
        )
    };
    std::fs::write(&prev, report(1000.0)).unwrap();
    std::fs::write(&cur, report(700.0)).unwrap();
    let outcome = bench_report::compare_files(
        prev.to_str().unwrap(),
        cur.to_str().unwrap(),
        0.2,
    )
    .unwrap()
    .expect("matching envelopes must compare");
    assert_eq!(outcome.regressions(), 1, "a 30% drop beyond a 20% threshold regresses");

    std::fs::write(&cur, report(950.0)).unwrap();
    let outcome = bench_report::compare_files(
        prev.to_str().unwrap(),
        cur.to_str().unwrap(),
        0.2,
    )
    .unwrap()
    .unwrap();
    assert_eq!(outcome.regressions(), 0, "a 5% wobble is within threshold");
    std::fs::remove_dir_all(&dir).ok();
}
