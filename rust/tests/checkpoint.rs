//! Checkpoint save/restore: a resumed run must continue bit-identically.

mod common;

use std::sync::Arc;

use mbs::data::{loader, Dataset, SynthFlowers};

fn step(rt: &mut mbs::runtime::ModelRuntime, ds: &Arc<dyn Dataset>, seed_idx: usize) -> f32 {
    let indices: Vec<usize> = (seed_idx..seed_idx + 8).collect();
    let mb = loader::assemble(ds.as_ref(), &indices, 8, 0);
    let out = rt.accum_step(&mb, 1.0 / 8.0).unwrap();
    rt.apply(&rt.default_hyper()).unwrap();
    out.loss_sum
}

#[test]
fn save_restore_roundtrip_continues_identically() {
    let Some(mut engine) = common::engine() else { return };
    let ds: Arc<dyn Dataset> = Arc::new(SynthFlowers::new(16, 102, 128, 9));
    let dir = std::env::temp_dir().join(format!("mbs-ckpt-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("state");

    // run A: 3 updates, checkpoint, 2 more updates
    let mut a = engine.load_model("microresnet18", 16, 8).unwrap();
    for i in 0..3 {
        step(&mut a, &ds, i * 8);
    }
    a.save_checkpoint(&path).unwrap();
    let continue_a: Vec<f32> = (3..5).map(|i| step(&mut a, &ds, i * 8)).collect();

    // run B: fresh runtime, restore, same 2 updates
    let mut b = engine.load_model("microresnet18", 16, 8).unwrap();
    b.load_checkpoint(&path).unwrap();
    assert_eq!(b.updates, 3);
    let continue_b: Vec<f32> = (3..5).map(|i| step(&mut b, &ds, i * 8)).collect();

    assert_eq!(continue_a, continue_b, "resumed run must continue bit-identically");

    // params equal afterwards too
    let pa = a.params_to_host().unwrap();
    let pb = b.params_to_host().unwrap();
    assert_eq!(common::max_abs_diff(&pa, &pb), 0.0);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn restore_rejects_wrong_model_and_corruption() {
    let Some(mut engine) = common::engine() else { return };
    let dir = std::env::temp_dir().join(format!("mbs-ckpt2-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("state");

    let rn = engine.load_model("microresnet18", 16, 8).unwrap();
    rn.save_checkpoint(&path).unwrap();

    // wrong model
    let mut unet = engine.load_model("microunet", 24, 8).unwrap();
    assert!(unet.load_checkpoint(&path).is_err());

    // truncated bin
    let bin_path = path.with_extension("bin");
    let bytes = std::fs::read(&bin_path).unwrap();
    std::fs::write(&bin_path, &bytes[..bytes.len() / 2]).unwrap();
    let mut rn2 = engine.load_model("microresnet18", 16, 8).unwrap();
    assert!(rn2.load_checkpoint(&path).is_err());

    // bad magic
    std::fs::write(path.with_extension("json"), "{\"magic\": \"nope\"}").unwrap();
    assert!(rn2.load_checkpoint(&path).is_err());
    std::fs::remove_dir_all(&dir).ok();
}
