//! DESIGN.md invariant 4: same seed => identical loss sequences, across
//! runs and across streaming policies; different seeds diverge.

mod common;

use mbs::coordinator::StreamingPolicy;
use mbs::TrainConfig;

fn run(engine: &mut mbs::Engine, seed: u64, streaming: StreamingPolicy) -> Vec<f64> {
    let cfg = TrainConfig::builder("microresnet18")
        .mu(8)
        .batch(16)
        .epochs(2)
        .dataset_len(48)
        .eval_len(16)
        .seed(seed)
        .streaming(streaming)
        .build();
    let report = mbs::train(engine, &cfg).expect("train");
    report.train_epochs.iter().map(|e| e.mean_loss).collect()
}

#[test]
fn same_seed_bit_identical() {
    let Some(mut engine) = common::engine() else { return };
    let a = run(&mut engine, 42, StreamingPolicy::DoubleBuffered);
    let b = run(&mut engine, 42, StreamingPolicy::DoubleBuffered);
    assert_eq!(a, b, "same seed must give identical loss sequence");
}

#[test]
fn streaming_policy_does_not_change_math() {
    let Some(mut engine) = common::engine() else { return };
    let a = run(&mut engine, 7, StreamingPolicy::DoubleBuffered);
    let b = run(&mut engine, 7, StreamingPolicy::Synchronous);
    assert_eq!(a, b, "double-buffering must be a pure latency optimization");
}

#[test]
fn different_seeds_diverge() {
    let Some(mut engine) = common::engine() else { return };
    let a = run(&mut engine, 1, StreamingPolicy::DoubleBuffered);
    let b = run(&mut engine, 2, StreamingPolicy::DoubleBuffered);
    assert_ne!(a, b, "different seeds should see different data");
}
