//! Overlapped-pipeline integration tests (artifact-gated, see
//! rust/docs/TESTING.md): the overlap identity oracle — the async upload
//! lane (`--overlap on`) must reproduce the serial path (`--overlap off`)
//! bit for bit, because both modes run the identical device-op sequence
//! and only move where the host-side staging work happens — plus the
//! wall-clock oracle (`upload_concurrent` measured from lane-thread
//! timestamps must be strictly positive), dirty-slot reuse identity,
//! ledger residency accounting, and the lane's zero-lease-leak guarantee
//! under an early epoch abort.

mod common;

use std::sync::Arc;
use std::time::Duration;

use mbs::coordinator::{stream_epoch, NormalizationMode, Planner, StreamingPolicy};
use mbs::data::{loader, BufPool, Dataset, EpochPlan, SynthFlowers};
use mbs::memory::Footprint;
use mbs::runtime::{LaneJob, UploadLane};
use mbs::TrainConfig;

fn base_cfg(overlap: bool) -> TrainConfig {
    TrainConfig::builder("microresnet18")
        .mu(8)
        .batch(24) // 3 accumulation steps per mini-batch
        .epochs(2)
        .dataset_len(50) // ragged epoch: 24 + 24 + 2
        .eval_len(16)
        .seed(7)
        .overlap(overlap)
        .build()
}

#[test]
fn train_report_identical_between_overlap_modes() {
    // the overlap identity oracle: same seeds, same plans, same device-op
    // order => every loss and metric matches exactly, epoch by epoch
    let Some(mut engine) = common::engine() else { return };
    let serial = mbs::train(&mut engine, &base_cfg(false)).expect("serial arm");
    let overlapped = mbs::train(&mut engine, &base_cfg(true)).expect("overlap arm");
    assert_eq!(serial.mu, overlapped.mu);
    assert_eq!(serial.updates, overlapped.updates);
    assert_eq!(serial.train_epochs.len(), overlapped.train_epochs.len());
    for (s, o) in serial.train_epochs.iter().zip(&overlapped.train_epochs) {
        assert_eq!(
            s.mean_loss.to_bits(),
            o.mean_loss.to_bits(),
            "epoch {} train loss diverged: {} vs {}",
            s.epoch,
            s.mean_loss,
            o.mean_loss
        );
        assert_eq!(s.primary_metric.to_bits(), o.primary_metric.to_bits());
        assert_eq!(s.samples, o.samples);
        assert_eq!(s.micro_steps, o.micro_steps);
    }
    for (s, o) in serial.eval_epochs.iter().zip(&overlapped.eval_epochs) {
        assert_eq!(s.mean_loss.to_bits(), o.mean_loss.to_bits(), "eval loss diverged");
        assert_eq!(s.primary_metric.to_bits(), o.primary_metric.to_bits());
    }
    assert_eq!(
        serial.final_eval.mean_loss.to_bits(),
        overlapped.final_eval.mean_loss.to_bits()
    );
    // and the instrumentation tells the two modes apart: only the overlap
    // run hides upload time behind execution
    assert_eq!(serial.stages.upload_hidden, std::time::Duration::ZERO);
    assert!(!serial.overlap && overlapped.overlap);
    assert!(
        overlapped.stages.upload_hidden > std::time::Duration::ZERO,
        "overlap run hid no upload time: {:?}",
        overlapped.stages
    );
    assert!(overlapped.stages.upload_hidden <= overlapped.stages.upload);
    assert!(overlapped.stages.overlap_efficiency() > 0.0);
    // the WALL-CLOCK oracle: the serial arm has no lane thread, so it can
    // measure no concurrent upload; the async arm's lane timestamps must
    // put real time inside the engine's execute windows — structural
    // hiding (upload_hidden) is not accepted as evidence here
    assert_eq!(serial.stages.upload_concurrent, Duration::ZERO);
    assert!(
        overlapped.stages.upload_concurrent > Duration::ZERO,
        "async lane staged nothing during an execute window: {:?}",
        overlapped.stages
    );
    assert!(overlapped.stages.upload_concurrent <= overlapped.stages.upload);
    assert!(overlapped.stages.wall_overlap_efficiency() > 0.0);
    assert_eq!(serial.stages.wall_overlap_efficiency(), 0.0);
}

#[test]
fn ledger_peak_carries_exactly_one_extra_input_slot() {
    // mid-pipeline residency accounting: the overlapped run's high-water
    // mark is the serial one plus precisely the second staged input slot
    // (Footprint::overlap_bytes of the clamped micro-batch), and both stay
    // within the admitted capacity
    let Some(mut engine) = common::engine() else { return };
    let entry = engine.manifest().model("microresnet18").unwrap().clone();
    let variant = entry.variant(16, 8).unwrap().clone();
    let fp = Footprint::from_manifest(&entry, &variant);
    let serial = mbs::train(&mut engine, &base_cfg(false)).expect("serial arm");
    let overlapped = mbs::train(&mut engine, &base_cfg(true)).expect("overlap arm");
    assert!(serial.ledger_peak_bytes <= serial.capacity_bytes);
    assert!(overlapped.ledger_peak_bytes <= overlapped.capacity_bytes);
    assert_eq!(serial.ledger_peak_bytes, fp.step_bytes(8));
    assert_eq!(
        overlapped.ledger_peak_bytes,
        serial.ledger_peak_bytes + fp.overlap_bytes(8),
        "overlap peak must be serial peak + one staged input slot"
    );
}

#[test]
fn dirty_slot_reuse_reproduces_serial_outputs() {
    // the ping-pong reuses each device slot every other step; a slot dirty
    // with an older micro-batch's buffers must reproduce the serial path
    // exactly once restaged (>= 3 steps so slot 0 is reused, ragged tail
    // included)
    let Some(mut engine) = common::engine() else { return };
    let mut rt = engine.load_model("microresnet18", 16, 8).expect("load");
    let ds: Arc<dyn Dataset> = Arc::new(SynthFlowers::new(16, 102, 32, 1));
    let indices: Vec<usize> = (0..20).collect(); // 8 + 8 + 4 (ragged)
    let mbs_list: Vec<_> =
        (0..3).map(|j| loader::assemble(ds.as_ref(), &indices, 8, j)).collect();
    // serial oracle (eval is side-effect free, so same runtime is fine)
    let serial: Vec<_> =
        mbs_list.iter().map(|mb| rt.eval_step(mb).expect("serial eval")).collect();
    // overlapped pipeline over the same micro-batches
    rt.set_overlap(true);
    let before = rt.timers();
    let mut pipelined = Vec::new();
    rt.stage_inputs(&mbs_list[0], None).expect("stage 0");
    for mb in &mbs_list[1..] {
        rt.stage_inputs(mb, None).expect("stage ahead");
        pipelined.push(rt.eval_staged().expect("staged eval"));
    }
    pipelined.push(rt.eval_staged().expect("drain"));
    assert_eq!(rt.staged_len(), 0, "pipeline must drain");
    assert_eq!(serial, pipelined, "dirty slot reuse changed step outputs");
    // both slots carried uploads, and the lookahead stages were hidden
    let [s0, s1] = rt.slot_upload_times();
    assert!(s0 > std::time::Duration::ZERO && s1 > std::time::Duration::ZERO);
    let delta = rt.timers().minus(&before);
    assert!(delta.upload_hidden > std::time::Duration::ZERO);
    rt.set_overlap(false);
}

#[test]
fn serial_mode_rejects_a_second_staged_micro_batch() {
    // with overlap off the runtime enforces the one-live-slot invariant
    // the byte-identity oracle depends on
    let Some(mut engine) = common::engine() else { return };
    let mut rt = engine.load_model("microresnet18", 16, 8).expect("load");
    let ds: Arc<dyn Dataset> = Arc::new(SynthFlowers::new(16, 102, 16, 1));
    let indices: Vec<usize> = (0..16).collect();
    let mb = loader::assemble(ds.as_ref(), &indices, 8, 0);
    rt.stage_inputs(&mb, None).expect("first stage");
    let err = rt.stage_inputs(&mb, None).expect_err("second stage must fail");
    assert!(err.to_string().contains("input slots full"), "{err}");
    // the serial fused step also refuses while something is staged
    let err = rt.eval_step(&mb).expect_err("fused step with staged slot must fail");
    assert!(err.to_string().contains("eval_step"), "{err}");
    rt.eval_staged().expect("draining the staged slot still works");
    assert_eq!(rt.staged_len(), 0);
}

#[test]
fn lane_early_abort_returns_every_pool_lease() {
    // host-only (no artifacts): abort an epoch halfway with staging work
    // still queued in the lane — submitted originals the worker has not
    // copied yet AND staged completions nobody will recv — and require
    // the shutdown drain to balance the pool's books exactly
    let ds: Arc<dyn Dataset> = Arc::new(SynthFlowers::new(8, 16, 64, 1));
    let pool = Arc::new(BufPool::for_prefetch(2));
    pool.warm(BufPool::buffers_for(2) + UploadLane::extra_buffers(2), ds.as_ref(), 8);
    let planner = Planner::new(8, false, NormalizationMode::Paper);
    let plan = EpochPlan::new(64, 16, 0, 0);
    {
        let mut lane = UploadLane::spawn(pool.clone(), 2, "overlap-test").expect("spawn lane");
        let mut seq = 0u64;
        for (i, item) in stream_epoch(
            StreamingPolicy::Synchronous,
            ds.clone(),
            plan,
            planner.clone(),
            2,
            pool.clone(),
        )
        .enumerate()
        {
            lane.submit(LaneJob { seq, mb: item.mb, scale: Some(1.0), fault: None, stall: None })
                .expect("submit");
            seq += 1;
            if i == 2 {
                // consume one completion so the abort also covers a
                // mid-flight staged slot already handed back
                let staged = lane.recv().expect("staged");
                pool.give(staged.mb);
            }
            if i >= 4 {
                break; // early abort: the rest of the epoch never runs
            }
        }
        assert!(seq >= 5, "fixture must abort with staging work in flight");
        // lane drops here with queued jobs and unconsumed completions
    }
    let s = pool.stats();
    assert_eq!(s.leases, s.returns, "early abort leaked pool leases: {s:?}");
}

#[test]
fn prefetch_auto_reports_a_tuned_value() {
    // --prefetch auto must settle on a positive depth within the N_Smu cap
    // and leave the identity intact (tuning moves host staging only)
    let Some(mut engine) = common::engine() else { return };
    let mut cfg = base_cfg(true);
    cfg.prefetch_auto = true;
    let report = mbs::train(&mut engine, &cfg).expect("auto-prefetch run");
    assert!(report.prefetch >= 1, "tuned prefetch must stay positive");
    // cap: 2 * ceil(batch/mu) = 6 for batch 24, mu 8
    assert!(report.prefetch <= 6, "tuned prefetch {} beyond cap", report.prefetch);
    let fixed = mbs::train(&mut engine, &base_cfg(true)).expect("fixed-prefetch run");
    for (a, b) in report.train_epochs.iter().zip(&fixed.train_epochs) {
        assert_eq!(a.mean_loss.to_bits(), b.mean_loss.to_bits(), "tuning changed arithmetic");
    }
    // the pool was sized for the tuning cap: still allocation-free
    assert_eq!(report.pool.allocs, 0, "auto-prefetch run allocated: {:?}", report.pool);
}
