//! `mbs chaos` fault-space sweep tests (see rust/docs/TESTING.md).
//!
//! Tier-1 (artifact-free): the committed smoke spec enumerates a
//! non-trivial sweep and every generated one-entry fault plan survives a
//! round-trip through the on-disk fault-spec parser — exactly what CI's
//! `mbs chaos --dry-run` exercises.
//!
//! Artifact-gated: the full sweep over the committed train-smoke spec.
//! The two invariants the whole PR exists for: `hung == 0` (every
//! injected stall outruns its watchdog deadline 3x, so the watchdog MUST
//! convert it into a recoverable fault) and `diverged == 0` (every run
//! that completes is bit-identical to the fault-free baseline).

mod common;

use std::path::PathBuf;

use mbs::coordinator::chaos::{self, ChaosCfg, Injection, Verdict};
use mbs::memory::MIB;
use mbs::JobSet;

fn spec(name: &str) -> String {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("specs")
        .join(name)
        .to_string_lossy()
        .into_owned()
}

#[test]
fn smoke_spec_enumerates_a_nontrivial_sweep() {
    let set = JobSet::load(&spec("jobs-smoke.json")).expect("committed smoke spec parses");
    let cfg = ChaosCfg::default();
    let points = chaos::enumerate(&set, &cfg.steps);
    // at minimum: step + arena at every enumerated step for every job,
    // plus one engine-global compile point per job
    assert!(
        points.len() >= set.jobs.len() * (2 * cfg.steps.len() + 1),
        "sweep too small: {} points for {} jobs",
        points.len(),
        set.jobs.len()
    );
    let compile = points.iter().filter(|p| p.injection == Injection::Compile).count();
    assert_eq!(compile, set.jobs.len(), "one compile point per materialize");
    assert!(
        points.iter().all(|p| p.injection != Injection::Compile || p.job == "*"),
        "compile points are engine-global (wildcard job)"
    );
    // every job draws faults on at least one hang surface: that is what
    // makes the sweep a watchdog test, not just a fault test
    for job in &set.jobs {
        assert!(
            points.iter().any(|p| p.job == job.name
                && matches!(
                    p.injection,
                    Injection::StallLane | Injection::StallStep | Injection::StallCheckpoint
                )),
            "job '{}' has no stall point",
            job.name
        );
    }
}

#[test]
fn every_smoke_spec_plan_round_trips_through_the_fault_spec_parser() {
    // the dry-run contract: each generated plan is a legal spec file a
    // user could have committed, nothing lost in serialization
    let set = JobSet::load(&spec("jobs-smoke.json")).expect("committed smoke spec parses");
    let cfg = ChaosCfg::default();
    for point in chaos::enumerate(&set, &cfg.steps) {
        chaos::validate_point(&point, &cfg).unwrap_or_else(|e| {
            panic!("point ({}, {}, {}): {e}", point.job, point.injection.name(), point.at)
        });
    }
}

#[test]
fn full_sweep_over_train_smoke_spec_has_zero_hung_and_zero_diverged() {
    // the capstone: every (job, surface, step) point over the committed
    // train-smoke spec either stays clean, recovers bit-identically, or
    // degrades into a structured eviction — nothing hangs, nothing drifts
    let Some(mut engine) = common::engine() else { return };
    let set =
        JobSet::load(&spec("jobs-train-smoke.json")).expect("committed train spec parses");
    let capacity = set.capacity_mib.expect("train-smoke spec pins capacity") * MIB;
    let cfg = ChaosCfg { deadline_ms: 200, steps: vec![0, 3], seed: 7 };
    let report = chaos::run_sweep(&mut engine, &set, capacity, &cfg).expect("sweep runs");

    let totals = report.totals();
    assert_eq!(totals.hung, 0, "a hung point means the watchdog failed to convert a stall");
    assert_eq!(totals.diverged, 0, "a diverged point breaks the recovery identity oracle");
    assert!(report.fired_points() > 0, "a smoke sweep that fires nothing proves nothing");
    assert!(totals.recovered > 0, "step/arena/stall points must recover");
    assert!(report.recovered_fraction() > 0.0);

    // attempt 0 exists on every axis, so every at=0 point must fire —
    // stalls included, which is the hang-to-fault conversion itself
    for p in &report.points {
        if p.point.at == 0 {
            assert_ne!(
                p.verdict,
                Verdict::Clean,
                "({}, {}, 0) never fired",
                p.point.job,
                p.point.injection.name()
            );
        }
    }
}
