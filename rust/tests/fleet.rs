//! Fleet tests: the artifact-free dry-run surface (spec parsing, the
//! committed smoke spec's placement, the frontier's device-count axis)
//! plus the artifact-gated headline oracle — a data-parallel
//! [`train_fleet`](mbs::coordinator::train_fleet) run's combined
//! `TrainReport` must be **bit-identical** (`f64::to_bits`) to the solo
//! `train` run of the same configuration at the fleet's min per-device
//! capacity. Gating follows rust/docs/TESTING.md.

mod common;

use std::collections::BTreeSet;
use std::path::PathBuf;

use mbs::coordinator::frontier::{synthetic_entry, DeviceAxis};
use mbs::coordinator::tenancy::{transient_bytes, AdmissionRequest};
use mbs::coordinator::{plan_placement, train_fleet};
use mbs::memory::{FleetSpec, Footprint, MIB};
use mbs::util::json::Json;
use mbs::{JobSet, MicroBatchSpec, TrainConfig};

// ---------------------------------------------------------------------
// dry-run surface: no artifacts needed
// ---------------------------------------------------------------------

#[test]
fn device_spec_parsing_forms() {
    let bare = FleetSpec::parse("4,2,2").expect("bare list");
    assert_eq!(bare.len(), 3);
    assert_eq!(bare.devices[0].name, "dev0");
    assert_eq!(bare.devices[0].capacity_bytes, 4 * MIB);
    assert_eq!(bare.min_capacity(), 2 * MIB);
    assert_eq!(bare.total_capacity(), 8 * MIB);

    let named = FleetSpec::parse("gpu0=4, gpu1=2").expect("named list");
    assert_eq!(named.devices[1].name, "gpu1");
    assert_eq!(named.devices[1].capacity_bytes, 2 * MIB);

    assert!(FleetSpec::parse("").is_err(), "empty list must be rejected");
    assert!(FleetSpec::parse("a=1,a=2").is_err(), "duplicate names must be rejected");

    let uniform = FleetSpec::uniform(3, MIB);
    assert_eq!(uniform.len(), 3);
    assert_eq!(uniform.devices[2].name, "dev2");
}

/// The committed CI smoke spec must keep parsing as BOTH a fleet spec
/// (its `devices` array) and a job set (its `jobs` array), and its
/// placement must genuinely exercise multi-device spreading — otherwise
/// the `fleet` CI job degenerates to a single-device test.
#[test]
fn committed_fleet_smoke_spec_parses_and_places_across_devices() {
    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("specs/fleet-smoke.json");
    let text = std::fs::read_to_string(&path).expect("committed spec readable");
    let fleet = FleetSpec::from_json(&Json::parse(&text).expect("valid json"))
        .expect("devices array parses");
    assert_eq!(fleet.len(), 3);
    assert_eq!(fleet.devices[0].name, "gpu0");
    assert!(
        fleet.devices[0].capacity_bytes > fleet.devices[1].capacity_bytes,
        "smoke fleet must be heterogeneous"
    );

    let set = JobSet::from_json_str(&text).expect("jobs array parses");
    let requests: Vec<AdmissionRequest> = set
        .jobs
        .iter()
        .map(|s| {
            let task = s.task.as_deref().expect("smoke jobs are synthetic");
            AdmissionRequest::from_spec(s, synthetic_entry(task).expect("known task"))
        })
        .collect();
    let plan = plan_placement(&requests, &fleet);
    assert_eq!(plan.placements.len(), requests.len());
    assert!(plan.placed() >= 2, "smoke spec must place at least two jobs");
    let used: BTreeSet<&str> =
        plan.placements.iter().filter_map(|p| p.device.as_deref()).collect();
    assert!(used.len() >= 2, "placement must spread across devices, got {used:?}");
    // every assigned device exists in the spec
    for p in &plan.placements {
        if let Some(d) = &p.device {
            assert!(fleet.devices.iter().any(|dev| &dev.name == d), "unknown device {d}");
        }
    }
    // determinism: same inputs, same assignment
    let again = plan_placement(&requests, &fleet);
    for (a, b) in plan.placements.iter().zip(&again.placements) {
        assert_eq!(a.name, b.name);
        assert_eq!(a.device, b.device);
        assert_eq!(a.label(), b.label());
    }
}

/// The device-count axis is monotone: more devices shrink the per-device
/// share (`ceil(batch / devices)`), so the largest feasible — and largest
/// native — global batch can only grow with the device count.
#[test]
fn device_axis_is_monotone_in_device_count_for_every_task() {
    for task in ["classification", "segmentation", "lm"] {
        let entry = synthetic_entry(task).expect("synthetic task");
        let axis = DeviceAxis::sweep(
            &entry,
            entry.default_size,
            0,
            &[2 * MIB, 8 * MIB],
            &[1, 2, 4, 8],
            &[8, 32, 64, 128, 256],
            true,
        )
        .expect("axis sweep");
        for &cap in &axis.capacities_bytes {
            let mut per_count: Vec<_> =
                axis.points.iter().filter(|p| p.capacity_bytes == cap).collect();
            per_count.sort_by_key(|p| p.devices);
            for w in per_count.windows(2) {
                assert!(
                    w[1].max_feasible_batch.unwrap_or(0)
                        >= w[0].max_feasible_batch.unwrap_or(0),
                    "task {task}: feasible frontier shrank with more devices: {w:?}"
                );
                assert!(
                    w[1].max_native_batch.unwrap_or(0) >= w[0].max_native_batch.unwrap_or(0),
                    "task {task}: native frontier shrank with more devices: {w:?}"
                );
            }
        }
    }
}

// ---------------------------------------------------------------------
// artifact-gated: the fleet-identity oracle
// ---------------------------------------------------------------------

/// A tight per-device capacity for the oracle runs: the resident state
/// plus one mu=8 transient at batch 24 / eval 16 — forces the MBS regime
/// (the global batch's native step cannot fit).
fn oracle_capacity(engine: &mbs::Engine, overlap: bool) -> u64 {
    let entry = engine.manifest().model("microresnet18").unwrap().clone();
    let fp = Footprint::from_manifest(&entry, entry.variant(16, 8).unwrap());
    fp.resident_bytes() + transient_bytes(&fp, 8, 24, 16, overlap)
}

fn oracle_cfg(overlap: bool) -> TrainConfig {
    TrainConfig::builder("microresnet18")
        .batch(24)
        .epochs(2)
        .dataset_len(48)
        .eval_len(16)
        .seed(3)
        .overlap(overlap)
        .build()
}

/// Assert every numeric stat of the two reports matches bit for bit.
fn assert_bit_identical(fleet: &mbs::TrainReport, solo: &mbs::TrainReport, label: &str) {
    assert_eq!(fleet.mu, solo.mu, "{label}: mu");
    assert_eq!(fleet.updates, solo.updates, "{label}: updates");
    assert_eq!(fleet.train_epochs.len(), solo.train_epochs.len(), "{label}");
    for (a, b) in fleet.train_epochs.iter().zip(&solo.train_epochs) {
        assert_eq!(
            a.mean_loss.to_bits(),
            b.mean_loss.to_bits(),
            "{label}: epoch {} train loss diverged: {} vs {}",
            a.epoch,
            a.mean_loss,
            b.mean_loss
        );
        assert_eq!(a.primary_metric.to_bits(), b.primary_metric.to_bits(), "{label}");
        assert_eq!(a.samples, b.samples, "{label}");
        assert_eq!(a.micro_steps, b.micro_steps, "{label}");
        assert_eq!(a.updates, b.updates, "{label}");
    }
    assert_eq!(fleet.eval_epochs.len(), solo.eval_epochs.len(), "{label}");
    for (a, b) in fleet.eval_epochs.iter().zip(&solo.eval_epochs) {
        assert_eq!(a.mean_loss.to_bits(), b.mean_loss.to_bits(), "{label}: eval loss");
        assert_eq!(a.primary_metric.to_bits(), b.primary_metric.to_bits(), "{label}");
        assert_eq!(a.samples, b.samples, "{label}");
    }
    assert_eq!(
        fleet.final_eval.mean_loss.to_bits(),
        solo.final_eval.mean_loss.to_bits(),
        "{label}: final eval"
    );
    assert_eq!(
        fleet.final_eval.primary_metric.to_bits(),
        solo.final_eval.primary_metric.to_bits(),
        "{label}: final metric"
    );
}

/// THE oracle: a 2-device data-parallel run (serial pipeline) must be
/// bit-identical to the solo run with the fleet's mu pinned — sharding
/// only moves *where* memory is charged, never what the runtime computes.
#[test]
fn fleet_report_bit_identical_to_solo() {
    let Some(mut engine) = common::engine() else { return };
    let capacity = oracle_capacity(&engine, false);
    let spec = FleetSpec::uniform(2, capacity);
    let cfg = oracle_cfg(false);
    let fr = train_fleet(&mut engine, &cfg, &spec).expect("fleet run");
    assert_eq!(fr.devices.len(), 2);

    // every device actually worked, was charged within its own capacity,
    // and the shares add up to the whole run
    let total_micro: u64 = fr.devices.iter().map(|d| d.micro_steps).sum();
    let total_samples: u64 = fr.devices.iter().map(|d| d.samples).sum();
    let expect_micro: u64 = fr
        .report
        .train_epochs
        .iter()
        .chain(&fr.report.eval_epochs)
        .map(|e| e.micro_steps as u64)
        .sum();
    let expect_samples: u64 = fr
        .report
        .train_epochs
        .iter()
        .chain(&fr.report.eval_epochs)
        .map(|e| e.samples as u64)
        .sum();
    assert_eq!(total_micro, expect_micro, "device micro-step shares must partition the run");
    assert_eq!(total_samples, expect_samples, "device sample shares must partition the run");
    for d in &fr.devices {
        assert!(d.micro_steps > 0, "device {} idled for the whole run", d.name);
        assert!(
            d.ledger_peak_bytes <= d.capacity_bytes,
            "device {} peak {} exceeds its capacity {}",
            d.name,
            d.ledger_peak_bytes,
            d.capacity_bytes
        );
    }

    // the solo arm: identical configuration, the fleet's mu pinned, on a
    // roomy single device
    let mut solo_cfg = cfg.clone();
    solo_cfg.mu = MicroBatchSpec::Fixed(fr.report.mu);
    solo_cfg.capacity_mib = Some(capacity.div_ceil(MIB) + 16);
    let solo = mbs::train(&mut engine, &solo_cfg).expect("solo run");
    assert_bit_identical(&fr.report, &solo, "serial 2-device fleet");
}

/// The async-lane variant: per-device upload lanes, global-order
/// completion — the wall-clock overlap machinery must not cost a single
/// bit either.
#[test]
fn async_fleet_bit_identical_to_solo() {
    let Some(mut engine) = common::engine() else { return };
    let capacity = oracle_capacity(&engine, true);
    let spec = FleetSpec::uniform(2, capacity);
    let cfg = oracle_cfg(true);
    let fr = train_fleet(&mut engine, &cfg, &spec).expect("async fleet run");
    assert!(fr.report.overlap, "fleet run lost its lane mode");
    for d in &fr.devices {
        assert!(d.micro_steps > 0, "device {} idled", d.name);
        assert!(d.ledger_peak_bytes <= d.capacity_bytes, "device {} over capacity", d.name);
    }

    let mut solo_cfg = cfg.clone();
    solo_cfg.mu = MicroBatchSpec::Fixed(fr.report.mu);
    solo_cfg.capacity_mib = Some(capacity.div_ceil(MIB) + 16);
    let solo = mbs::train(&mut engine, &solo_cfg).expect("solo async run");
    assert_bit_identical(&fr.report, &solo, "async 2-device fleet");
}

/// Degenerate fleet: ONE device at an MiB-aligned capacity must match the
/// solo run at the same capacity under `Auto` mu on both sides — not just
/// the same losses, the same planner decision.
#[test]
fn single_device_fleet_matches_solo_at_equal_capacity() {
    let Some(mut engine) = common::engine() else { return };
    let capacity_mib = oracle_capacity(&engine, false).div_ceil(MIB);
    let spec = FleetSpec::uniform(1, capacity_mib * MIB);
    let cfg = oracle_cfg(false);
    let fr = train_fleet(&mut engine, &cfg, &spec).expect("1-device fleet run");
    assert_eq!(fr.devices.len(), 1);

    let mut solo_cfg = cfg.clone();
    solo_cfg.capacity_mib = Some(capacity_mib);
    let solo = mbs::train(&mut engine, &solo_cfg).expect("solo run");
    assert_eq!(fr.report.mu, solo.mu, "Auto resolution must agree at equal capacity");
    assert_bit_identical(&fr.report, &solo, "1-device fleet");
}
