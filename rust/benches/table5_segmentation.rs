//! Table 5: U-Net IoU and training time across mini-batch sizes beyond the
//! memory limit (paper: native max 16, MBS up to 1024 with IoU peaking at
//! an interior batch, 128).

mod common;

use mbs::metrics::Table;
use mbs::{MbsError, Result, TrainConfig};

fn main() -> Result<()> {
    let mut engine = common::engine()?;
    let epochs = common::scale(3);
    let seeds = [0u64, 1, 2];
    let (model, size, mu, native_max) = ("microunet", 24usize, 16usize, 16usize);
    let cap = common::capacity_mib_for(&engine, model, size, mu, native_max)?;

    let mut table = Table::new(&[
        "batch", "mu", "IoU w/o MBS (%)", "IoU w/ MBS (%)", "time w/o (s)", "time w/ (s)",
    ]);
    for batch in [16usize, 32, 64, 128, 256] {
        let mut cells = vec![batch.to_string(), mu.to_string()];
        let mut times = vec!["Failed".to_string(), "-".to_string()];
        for (slot, use_mbs) in [(0usize, false), (1usize, true)] {
            let mut cfg = TrainConfig::builder(model)
                .size(size)
                .mu(mu)
                .batch(batch)
                .epochs(epochs)
                .dataset_len(common::scale(192).max(batch))
                .eval_len(common::scale(48))
                .capacity_mib(cap)
                .build();
            cfg.use_mbs = use_mbs;
            match common::run_seeds(&mut engine, &cfg, &seeds) {
                Ok((metrics, walls)) => {
                    cells.push(common::pm(&metrics));
                    times[slot] = common::pm(&walls);
                }
                Err(MbsError::Oom { .. }) => cells.push("Failed".into()),
                Err(e) => return Err(e),
            }
        }
        cells.push(times[0].clone());
        cells.push(times[1].clone());
        table.row(&cells);
    }
    println!("TABLE 5 — {model} (size {size}, capacity {cap} MiB, native max {native_max}):\n");
    println!("{}", table.render());
    println!(
        "\npaper shape: w/o MBS fails past 16; w/ MBS all batches train; IoU peaks at\n\
         an interior batch; epoch time grows mildly with batch."
    );
    Ok(())
}
