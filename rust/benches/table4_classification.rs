//! Table 4: accuracy and training time for mini-batches far beyond the
//! memory frontier — the paper's main table. For each classification model
//! the capacity is set so the native max equals the paper's table-2 value
//! (scaled), every larger batch shows `Failed` without MBS, and MBS trains
//! them all with bounded epoch-time overhead.

mod common;

use mbs::metrics::Table;
use mbs::{MbsError, Result, TrainConfig};

fn main() -> Result<()> {
    let mut engine = common::engine()?;
    let epochs = common::scale(2);
    let seeds = [0u64, 1, 2];

    // (model, size, native-max mini, mu used by MBS for the big rows, batches)
    let setups = [
        ("microresnet18", 16usize, 16usize, 16usize, vec![16usize, 32, 64, 128, 256, 512]),
        ("microresnet34", 16, 8, 8, vec![8, 16, 32, 64, 128, 256]),
        ("amoebacell", 24, 32, 32, vec![32, 64, 128, 256]),
    ];

    for (model, size, native_max, mu, batches) in setups {
        let cap = common::capacity_mib_for(&engine, model, size, mu, native_max)?;
        let mut table = Table::new(&[
            "batch", "mu", "acc w/o MBS (%)", "acc w/ MBS (%)", "time w/o (s)", "time w/ (s)",
        ]);
        for &batch in &batches {
            let mut cells = vec![batch.to_string(), mu.min(batch).to_string()];
            let mut times = vec!["Failed".to_string(), "-".to_string()];
            for (slot, use_mbs) in [(0usize, false), (1usize, true)] {
                let mut cfg = TrainConfig::builder(model)
                    .size(size)
                    .mu(mu)
                    .batch(batch)
                    .epochs(epochs)
                    .dataset_len(common::scale(256).max(batch))
                    .eval_len(common::scale(64))
                    .capacity_mib(cap)
                    .build();
                cfg.use_mbs = use_mbs;
                match common::run_seeds(&mut engine, &cfg, &seeds) {
                    Ok((metrics, walls)) => {
                        cells.push(common::pm(&metrics));
                        times[slot] = common::pm(&walls);
                    }
                    Err(MbsError::Oom { .. }) => cells.push("Failed".into()),
                    Err(e) => return Err(e),
                }
            }
            cells.push(times[0].clone());
            cells.push(times[1].clone());
            table.row(&cells);
        }
        println!(
            "TABLE 4 — {model} (size {size}, capacity {cap} MiB, native max {native_max}):\n"
        );
        println!("{}", table.render());
        println!();
    }
    println!(
        "paper shape targets: (i) 'Failed' everywhere above the native max w/o MBS;\n\
         (ii) MBS trains every batch; (iii) per-epoch time roughly flat in batch\n\
         (same total samples), small overhead vs native at the shared point."
    );
    Ok(())
}
