//! Shared harness for the table/figure benches (criterion is unavailable
//! offline; these are `harness = false` binaries that print the same rows
//! the paper reports).

#![allow(dead_code)]

use mbs::memory::{Footprint, MemoryModel, MIB};
use mbs::{Engine, Manifest, Result, TrainConfig};

pub fn engine() -> Result<Engine> {
    Engine::new(Manifest::load(artifacts())?)
}

pub fn artifacts() -> String {
    std::env::var("MBS_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string())
}

/// Scale factor for bench workloads: MBS_BENCH_SCALE=2 doubles dataset
/// sizes/epochs (slower, tighter error bars); 0.5 halves them.
pub fn scale(n: usize) -> usize {
    let s: f64 = std::env::var("MBS_BENCH_SCALE")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1.0);
    ((n as f64 * s).round() as usize).max(1)
}

/// Capacity (MiB) that makes `native_max` the largest native batch for the
/// given variant — how bench configs translate the paper's RTX-3090
/// frontier to the micro models.
pub fn capacity_mib_for(
    engine: &Engine,
    model: &str,
    size: usize,
    mu: usize,
    native_max: usize,
) -> Result<u64> {
    let entry = engine.manifest().model(model)?;
    let variant = entry.variant(size, mu)?;
    let fp = Footprint::from_manifest(entry, variant);
    Ok(MemoryModel::capacity_for_native_max(&fp, native_max).div_ceil(MIB))
}

/// Mean +- std formatted like the paper's tables.
pub fn pm(xs: &[f64]) -> String {
    let (m, s) = mbs::util::stats::mean_std(xs);
    format!("{m:.2} +-{s:.2}")
}

/// Run one config across seeds; returns (best metric %, epoch secs) samples.
pub fn run_seeds(
    engine: &mut Engine,
    base: &TrainConfig,
    seeds: &[u64],
) -> Result<(Vec<f64>, Vec<f64>)> {
    let mut metrics = Vec::new();
    let mut walls = Vec::new();
    for &seed in seeds {
        let mut cfg = base.clone();
        cfg.seed = seed;
        let r = mbs::train(engine, &cfg)?;
        metrics.push(100.0 * r.best_metric());
        walls.push(r.epoch_wall_mean.as_secs_f64());
    }
    Ok((metrics, walls))
}
