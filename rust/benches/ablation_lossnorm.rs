//! A1 ablation (ours): loss-normalization modes on ragged tails.
//!
//! Paper eq. 14 normalizes each micro-batch's *mean* loss by 1/N_Smu, which
//! silently over-weights the samples of a short final micro-batch. The
//! `exact` mode (sum-loss x 1/N_B) fixes this. This bench quantifies (a)
//! the gradient deviation of each mode from the true mini-batch gradient,
//! measured through the real HLO runtime, and (b) the end-metric effect of
//! training with each mode on a deliberately ragged configuration.

mod common;

use std::sync::Arc;

use mbs::coordinator::{NormalizationMode, SplitPlan};
use mbs::data::{loader, Dataset, SynthFlowers};
use mbs::metrics::Table;
use mbs::{Result, TrainConfig};

fn grad_deviation(engine: &mut mbs::Engine, mode: NormalizationMode) -> Result<f64> {
    // N_B = 12, mu = 8 -> ranges 8 + 4 (ragged)
    let ds: Arc<dyn Dataset> = Arc::new(SynthFlowers::new(16, 102, 64, 13));
    let indices: Vec<usize> = (0..12).collect();

    let mut native = engine.load_model("microresnet18", 16, 16)?;
    let full = loader::assemble(ds.as_ref(), &indices, 16, 0);
    native.accum_step(&full, 1.0 / 12.0)?;
    let reference = native.acc_to_host()?;

    let mut rt = engine.load_model("microresnet18", 16, 8)?;
    let plan = SplitPlan::new(12, 8);
    for j in 0..plan.n_smu() {
        let mb = loader::assemble(ds.as_ref(), &indices, 8, j);
        rt.accum_step(&mb, mode.scale(&plan, j))?;
    }
    let got = rt.acc_to_host()?;

    let mut num = 0f64;
    let mut den = 0f64;
    for (a, b) in got.iter().zip(&reference) {
        for (x, y) in a.iter().zip(b) {
            num += ((x - y) as f64).powi(2);
            den += (*y as f64).powi(2);
        }
    }
    Ok((num / den.max(1e-30)).sqrt())
}

fn main() -> Result<()> {
    let mut engine = common::engine()?;
    let epochs = common::scale(3);

    let mut table = Table::new(&[
        "norm mode", "rel grad deviation (ragged)", "final acc (%) ragged training",
    ]);
    for mode in [NormalizationMode::Exact, NormalizationMode::Paper, NormalizationMode::None] {
        let dev = grad_deviation(&mut engine, mode)?;
        // ragged everywhere: batch 24 with mu 16 -> micro-batches 16 + 8
        let cfg = TrainConfig::builder("microresnet18")
            .mu(16)
            .batch(24)
            .epochs(epochs)
            .dataset_len(common::scale(240))
            .eval_len(common::scale(64))
            .norm(mode)
            .build();
        let r = mbs::train(&mut engine, &cfg)?;
        table.row(&[
            mode.name().to_string(),
            format!("{dev:.2e}"),
            format!("{:.2}", 100.0 * r.best_metric()),
        ]);
    }
    println!("ABLATION A1 — loss normalization on ragged tails (N_B % mu != 0):\n");
    println!("{}", table.render());
    println!(
        "\nreading: exact ~ 0 deviation; paper deviates on ragged tails (eq. 14's\n\
         hidden assumption of equal micro-batches); none (plain accumulation, eq. 13)\n\
         deviates by ~N_Smu and trains with an effectively N_Smu-times larger LR."
    );
    Ok(())
}
