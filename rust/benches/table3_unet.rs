//! Table 3: U-Net IoU with vs without MBS at the native-max mini-batch
//! (paper: 95.48 +-0.13 w/o vs 95.45 +-0.26 w/ — statistically identical).

mod common;

use mbs::metrics::Table;
use mbs::{Result, TrainConfig};

fn main() -> Result<()> {
    let mut engine = common::engine()?;
    let epochs = common::scale(4);
    let seeds = [0u64, 1, 2];

    let mut table = Table::new(&["metric", "w/o MBS", "w/ MBS"]);
    let mut row = vec!["IoU (%)".to_string()];
    let gap;
    let mut means = Vec::new();
    for use_mbs in [false, true] {
        let mut cfg = TrainConfig::builder("microunet")
            .size(24)
            .mu(if use_mbs { 8 } else { 16 })
            .batch(16)
            .epochs(epochs)
            .dataset_len(common::scale(192))
            .eval_len(common::scale(48))
            .build();
        cfg.use_mbs = use_mbs;
        let (metrics, _) = common::run_seeds(&mut engine, &cfg, &seeds)?;
        let (m, _) = mbs::util::stats::mean_std(&metrics);
        means.push(m);
        row.push(common::pm(&metrics));
    }
    gap = (means[0] - means[1]).abs();
    table.row(&row);
    println!("TABLE 3 (shape reproduction): U-Net, mini 16 / mu 8, 3 seeds\n");
    println!("{}", table.render());
    println!("\n|w/o - w/| = {gap:.2} pp (paper: 0.03 pp — the arms must be comparable)");
    Ok(())
}
