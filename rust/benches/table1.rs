//! Table 1: the effect of batch size and image size.
//!
//! Paper: ResNet-50 @ {32^2, 224^2} x batch {2, 16} on Flower-102;
//!        U-Net @ {96^2, 384^2} x batch {2, 16} on Carvana.
//! Here:  microresnet18 @ {16^2, 32^2}; microunet @ {24^2, 48^2} on the
//!        synthetic stand-ins. Shape target: accuracy/IoU increase with
//!        both batch size and resolution.

mod common;

use mbs::metrics::Table;
use mbs::{Result, TrainConfig};

fn main() -> Result<()> {
    let mut engine = common::engine()?;
    let epochs = common::scale(3);
    let seeds = [0u64, 1, 2];

    let mut table = Table::new(&["model", "image", "batch 2", "batch 16"]);
    for (model, sizes, mu) in [
        ("microresnet18", [16usize, 32], 16usize),
        ("microunet", [24, 48], 16),
    ] {
        for size in sizes {
            let mut cells = vec![model.to_string(), format!("{size}x{size}")];
            for batch in [2usize, 16] {
                // mu=16 executable serves both: batch 2 runs padded+masked
                let cfg = TrainConfig::builder(model)
                    .size(size)
                    .mu(mu)
                    .batch(batch)
                    .epochs(epochs)
                    .dataset_len(common::scale(192))
                    .eval_len(common::scale(64))
                    .build();
                // both batch sizes fit natively in the paper's table 1; we
                // run them through MBS with mu = batch (single micro-batch,
                // identical math) for uniformity
                let (metrics, _) = common::run_seeds(&mut engine, &cfg, &seeds)?;
                cells.push(common::pm(&metrics));
            }
            table.row(&cells);
        }
    }
    println!("TABLE 1 (shape reproduction): max metric (%), 3 seeds\n");
    println!("{}", table.render());
    println!(
        "\npaper shape: larger batch > smaller batch at high res; higher res > low res.\n\
         (paper: ResNet 83.74 vs 61.86 / 62.10 vs 48.66; U-Net 95.62 vs 93.61 ...)"
    );
    Ok(())
}
