//! A2 ablation (ours): double-buffered vs synchronous streaming.
//!
//! The stream-based pipeline's job (paper section 3.1) is to hide host-side
//! batch assembly behind device execution. This bench measures epoch
//! wall-clock under both policies on an assembly-heavy workload (the
//! high-resolution U-Net variant, whose per-pixel procedural generation is
//! the most expensive assemble in the repo) and reports the overlap gain.

mod common;

use mbs::coordinator::StreamingPolicy;
use mbs::metrics::Table;
use mbs::{Result, TrainConfig};

fn main() -> Result<()> {
    let mut engine = common::engine()?;
    let epochs = common::scale(2);

    let mut table = Table::new(&["workload", "sync epoch (s)", "double-buffered epoch (s)", "gain"]);
    for (model, size, mu) in [
        ("microunet", 48usize, 16usize),   // assembly-heavy (48x48 gen)
        ("microresnet18", 16, 16),         // compute-dominated
    ] {
        let mut walls = Vec::new();
        for policy in [StreamingPolicy::Synchronous, StreamingPolicy::DoubleBuffered] {
            let cfg = TrainConfig::builder(model)
                .size(size)
                .mu(mu)
                .batch(4 * mu)
                .epochs(epochs)
                .dataset_len(common::scale(128))
                .eval_len(16)
                .streaming(policy)
                .skip_eval()
                .build();
            let r = mbs::train(&mut engine, &cfg)?;
            walls.push(r.epoch_wall_mean.as_secs_f64());
        }
        table.row(&[
            format!("{model} s{size}"),
            format!("{:.3}", walls[0]),
            format!("{:.3}", walls[1]),
            format!("{:+.1}%", 100.0 * (walls[0] - walls[1]) / walls[0]),
        ]);
    }
    println!("ABLATION A2 — streaming policy (overlap assembly with execution):\n");
    println!("{}", table.render());
    println!("\nreading: overlap pays where assembly is expensive; both policies compute\nbit-identical results (tests/determinism.rs).");
    Ok(())
}
