//! Figure 3: loss / accuracy vs epoch, with vs without MBS, for the
//! classification models. The paper's claim: the curves coincide — MBS with
//! loss normalization trains the same way native mini-batch training does.
//!
//! Emits the per-epoch series as CSV (fig3_<model>.csv) and prints a
//! divergence summary.

mod common;

use mbs::metrics::CurveWriter;
use mbs::{Result, TrainConfig};

fn main() -> Result<()> {
    let mut engine = common::engine()?;
    let epochs = common::scale(5);

    for (model, size, mini, mu) in [
        ("microresnet18", 16usize, 16usize, 8usize),
        ("microresnet34", 16, 8, 4),
        ("amoebacell", 24, 32, 16),
    ] {
        let mut writer = CurveWriter::default();
        let mut max_loss_gap = 0f64;
        let mut final_metrics = Vec::new();
        for use_mbs in [false, true] {
            // native arm computes mini in one step (needs the mu=mini
            // variant); MBS arm streams mini as mini/mu micro-batches
            let mut cfg = TrainConfig::builder(model)
                .size(size)
                .mu(if use_mbs { mu } else { mini })
                .batch(mini)
                .epochs(epochs)
                .dataset_len(common::scale(256))
                .eval_len(common::scale(64))
                .seed(0)
                .build();
            cfg.use_mbs = use_mbs;
            let r = mbs::train(&mut engine, &cfg)?;
            let series = if use_mbs { "mbs" } else { "native" };
            for (t, e) in r.train_epochs.iter().zip(&r.eval_epochs) {
                writer.push(&format!("{series}-train"), t.clone());
                writer.push(&format!("{series}-eval"), e.clone());
            }
            final_metrics.push(r.final_eval.primary_metric);
            if use_mbs {
                // compare against the native series recorded just before
            }
        }
        // loss-gap check: reload CSV rows is overkill; recompute quickly
        let csv = writer.to_csv();
        let mut native_loss = Vec::new();
        let mut mbs_loss = Vec::new();
        for line in csv.lines().skip(1) {
            let f: Vec<&str> = line.split(',').collect();
            if f[0] == "native-train" {
                native_loss.push(f[2].parse::<f64>().unwrap());
            }
            if f[0] == "mbs-train" {
                mbs_loss.push(f[2].parse::<f64>().unwrap());
            }
        }
        for (a, b) in native_loss.iter().zip(&mbs_loss) {
            max_loss_gap = max_loss_gap.max((a - b).abs());
        }
        let path = format!("fig3_{model}.csv");
        writer.write_file(std::path::Path::new(&path))?;
        println!(
            "FIG 3 {model}: max per-epoch train-loss gap (native vs MBS) = {max_loss_gap:.5}; \
             final eval metric native {:.4} vs mbs {:.4}; series -> {path}",
            final_metrics[0], final_metrics[1]
        );
    }
    println!("\npaper shape: the curves for w/ and w/o MBS are 'very similar' (sec 4.3.1).");
    Ok(())
}
