//! Hot-path microbenchmarks (feeds EXPERIMENTS.md SSPerf): per-stage
//! latency of the micro-batch step across models —
//!   assemble:      host-side generation + padding, fresh allocation per
//!                  call (the pre-pool baseline)
//!   assemble_into: the pooled steady-state path — same work into a
//!                  recycled staging buffer, zero allocations
//!   accum:         upload x/y/mask/scale + execute fwd/bwd + state swap
//!   apply:         optimizer update executable
//!   eval:          forward-only executable
//!   eval sweep:    a full repeat-eval pass through `evaluate_pooled` —
//!                  the caller-owned-pool entry point, so the loop pays
//!                  zero per-call pool warm-up (ROADMAP PR 4 follow-up)
//! plus the L3-only overhead (splitter + scale arithmetic), which must be
//! noise-level compared to the XLA work, and a host-only synchronous-vs-
//! lane staging arm (per micro-batch size) that quantifies what the
//! dedicated upload-lane thread buys — the narrative behind
//! `wall_overlap_efficiency` in `BENCH_streaming.json`.

mod common;

use std::sync::Arc;
use std::time::Instant;

use mbs::coordinator::datasets_for;
use mbs::coordinator::{evaluate_pooled, NormalizationMode, SplitPlan, StreamingPolicy};
use mbs::data::{loader, Buf, BufPool, Dataset, MicroBatchHost};
use mbs::metrics::{MetricKind, Table};
use mbs::runtime::{LaneJob, StagedBatch, UploadLane};
use mbs::{Result, TrainConfig};

fn bench<F: FnMut() -> Result<()>>(iters: usize, mut f: F) -> Result<f64> {
    // warmup
    f()?;
    let t0 = Instant::now();
    for _ in 0..iters {
        f()?;
    }
    Ok(t0.elapsed().as_secs_f64() / iters as f64 * 1e3)
}

/// A stand-in for the engine's upload+execute window: touches every input
/// byte, so its cost scales with `mu` the way the device step's does.
fn fake_execute(mb: &MicroBatchHost) -> f32 {
    let x: f32 = match &mb.x {
        Buf::F32(v) => v.iter().sum(),
        Buf::I32(v) => v.iter().map(|&i| i as f32).sum(),
    };
    x + mb.mask.iter().sum::<f32>()
}

/// Host-only staging comparison (no artifacts needed): the same pinned-
/// staging copy per micro-batch, first serialized (stage, then consume),
/// then pipelined through the upload-lane thread (consume step `j-1`
/// while the lane stages `j`). The per-step delta is the wall-clock time
/// the async lane hides — what `wall_overlap_efficiency` reports on the
/// real pipeline.
fn lane_staging_comparison(iters: usize) -> Result<()> {
    let cfg = TrainConfig::builder("staging-bench").build();
    let mut table =
        Table::new(&["mu", "serial stage+consume (ms)", "lane pipelined (ms)", "speedup"]);
    for mu in [2usize, 4, 8, 16, 32] {
        let (ds, _eval): (Arc<dyn Dataset>, Arc<dyn Dataset>) =
            datasets_for("classification", 16, &cfg)?;
        let indices: Vec<usize> = (0..mu).collect();
        let n_steps = 24usize;
        let pool = Arc::new(BufPool::bounded(UploadLane::extra_buffers(2) + 4));
        pool.warm(UploadLane::extra_buffers(2) + 4, ds.as_ref(), mu);
        let mut sink = 0f32;

        // serial arm: every step stages through the lane, then consumes —
        // identical copy work, zero pipelining
        let mut lane = UploadLane::spawn(pool.clone(), 2, "bench")?;
        let mut seq = 0u64;
        let t_serial = bench(iters, || {
            for j in 0..n_steps {
                let mut mb = pool.lease();
                loader::assemble_into(&mut mb, ds.as_ref(), &indices, mu, 0);
                mb.j = j;
                lane.submit(LaneJob { seq, mb, scale: None, fault: None, stall: None })?;
                seq += 1;
                let staged = lane.recv()?;
                sink += fake_execute(&staged.mb);
                pool.give(staged.mb);
            }
            Ok(())
        })?;
        drop(lane);

        // pipelined arm: consume step j-1 while the lane stages step j
        let mut lane = UploadLane::spawn(pool.clone(), 2, "bench")?;
        let t_lane = bench(iters, || {
            let mut pending: Option<StagedBatch> = None;
            for j in 0..n_steps {
                let mut mb = pool.lease();
                loader::assemble_into(&mut mb, ds.as_ref(), &indices, mu, 0);
                mb.j = j;
                lane.submit(LaneJob { seq, mb, scale: None, fault: None, stall: None })?;
                seq += 1;
                if let Some(prev) = pending.take() {
                    sink += fake_execute(&prev.mb);
                    pool.give(prev.mb);
                }
                pending = Some(lane.recv()?);
            }
            if let Some(prev) = pending.take() {
                sink += fake_execute(&prev.mb);
                pool.give(prev.mb);
            }
            Ok(())
        })?;
        drop(lane);
        std::hint::black_box(sink);

        table.row(&[
            mu.to_string(),
            format!("{t_serial:.3}"),
            format!("{t_lane:.3}"),
            format!("{:.2}x", if t_lane > 0.0 { t_serial / t_lane } else { 0.0 }),
        ]);
    }
    println!(
        "STAGING — synchronous vs upload-lane pinned staging, {iters} iters of 24 \
         micro-batches\n(host-only; the pipelined column overlaps the copy with the \
         consumer, which is what\nwall_overlap_efficiency measures on the real device \
         pipeline):\n"
    );
    println!("{}", table.render());
    Ok(())
}

fn main() -> Result<()> {
    // host-only arm first: runs (and is useful) even without artifacts
    lane_staging_comparison(common::scale(10))?;
    println!();

    let mut engine = common::engine()?;
    let iters = common::scale(10);

    let mut table = Table::new(&[
        "model", "mu", "assemble (ms)", "assemble_into (ms)", "accum (ms)", "apply (ms)",
        "eval (ms)", "eval sweep (ms)",
    ]);
    let setups = [
        ("microresnet18", 16usize, 8usize),
        ("microresnet18", 16, 16),
        ("microresnet34", 16, 8),
        ("amoebacell", 24, 16),
        ("microunet", 24, 8),
        ("microunet", 48, 16),
        ("microformer", 64, 8),
    ];
    for (model, size, mu) in setups {
        let entry = engine.manifest().model(model)?.clone();
        let mut cfg = TrainConfig::builder(model).build();
        cfg.eval_len = 32; // a small but multi-micro-step repeat-eval set
        let (ds, eval_ds): (Arc<dyn Dataset>, Arc<dyn Dataset>) =
            datasets_for(&entry.task, size, &cfg)?;
        let indices: Vec<usize> = (0..mu).collect();

        let t_assemble = bench(iters, || {
            let mb = loader::assemble(ds.as_ref(), &indices, mu, 0);
            std::hint::black_box(&mb);
            Ok(())
        })?;

        // the pooled steady-state path: same assembly into a recycled
        // staging buffer — the delta vs `assemble` is what BufPool saves
        let mut staging = loader::assemble(ds.as_ref(), &indices, mu, 0);
        let t_assemble_into = bench(iters, || {
            loader::assemble_into(&mut staging, ds.as_ref(), &indices, mu, 0);
            std::hint::black_box(&staging);
            Ok(())
        })?;

        let mut rt = engine.load_model(model, size, mu)?;
        let mb = loader::assemble(ds.as_ref(), &indices, mu, 0);
        let plan = SplitPlan::new(mu, mu);
        let scale = NormalizationMode::Paper.scale(&plan, 0);

        let t_accum = bench(iters, || rt.accum_step(&mb, scale).map(|_| ()))?;
        let t_apply = bench(iters, || rt.apply(&rt.default_hyper()))?;
        let t_eval = bench(iters, || rt.eval_step(&mb).map(|_| ()))?;

        // repeat-eval through the caller-owned pool: one warm-up outside
        // the loop, every iteration reuses the same staging buffers
        let kind = MetricKind::parse(&entry.metric_semantics)?;
        let pool = Arc::new(BufPool::for_prefetch(2));
        pool.warm(BufPool::buffers_for(2), eval_ds.as_ref(), mu);
        let t_eval_sweep = bench(iters, || {
            evaluate_pooled(
                &mut rt,
                kind,
                &eval_ds,
                0,
                StreamingPolicy::Synchronous,
                0,
                &pool,
            )
            .map(|_| ())
        })?;

        table.row(&[
            model.to_string(),
            mu.to_string(),
            format!("{t_assemble:.2}"),
            format!("{t_assemble_into:.2}"),
            format!("{t_accum:.2}"),
            format!("{t_apply:.2}"),
            format!("{t_eval:.2}"),
            format!("{t_eval_sweep:.2}"),
        ]);
    }
    println!("MICROBENCH — per-stage hot-path latency ({iters} iters, state: see below):\n");
    println!("{}", table.render());

    // L3 bookkeeping cost: splitter + scale for a large epoch, no XLA
    let t0 = Instant::now();
    let mut sink = 0f32;
    let reps = 10_000usize;
    for i in 0..reps {
        let plan = SplitPlan::new(1024 + (i % 7), 16);
        for j in 0..plan.n_smu() {
            sink += NormalizationMode::Paper.scale(&plan, j);
        }
    }
    let l3_ns = t0.elapsed().as_nanos() as f64 / reps as f64;
    println!(
        "\nL3 bookkeeping (split + normalize, N_B=1024): {l3_ns:.0} ns per mini-batch\n\
         (sink {sink:.1}) — vs milliseconds per XLA step: coordinator is not the bottleneck."
    );
    Ok(())
}
