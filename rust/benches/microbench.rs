//! Hot-path microbenchmarks (feeds EXPERIMENTS.md SSPerf): per-stage
//! latency of the micro-batch step across models —
//!   assemble:      host-side generation + padding, fresh allocation per
//!                  call (the pre-pool baseline)
//!   assemble_into: the pooled steady-state path — same work into a
//!                  recycled staging buffer, zero allocations
//!   accum:         upload x/y/mask/scale + execute fwd/bwd + state swap
//!   apply:         optimizer update executable
//!   eval:          forward-only executable
//!   eval sweep:    a full repeat-eval pass through `evaluate_pooled` —
//!                  the caller-owned-pool entry point, so the loop pays
//!                  zero per-call pool warm-up (ROADMAP PR 4 follow-up)
//! plus the L3-only overhead (splitter + scale arithmetic), which must be
//! noise-level compared to the XLA work.

mod common;

use std::sync::Arc;
use std::time::Instant;

use mbs::coordinator::datasets_for;
use mbs::coordinator::{evaluate_pooled, NormalizationMode, SplitPlan, StreamingPolicy};
use mbs::data::{loader, BufPool, Dataset};
use mbs::metrics::{MetricKind, Table};
use mbs::{Result, TrainConfig};

fn bench<F: FnMut() -> Result<()>>(iters: usize, mut f: F) -> Result<f64> {
    // warmup
    f()?;
    let t0 = Instant::now();
    for _ in 0..iters {
        f()?;
    }
    Ok(t0.elapsed().as_secs_f64() / iters as f64 * 1e3)
}

fn main() -> Result<()> {
    let mut engine = common::engine()?;
    let iters = common::scale(10);

    let mut table = Table::new(&[
        "model", "mu", "assemble (ms)", "assemble_into (ms)", "accum (ms)", "apply (ms)",
        "eval (ms)", "eval sweep (ms)",
    ]);
    let setups = [
        ("microresnet18", 16usize, 8usize),
        ("microresnet18", 16, 16),
        ("microresnet34", 16, 8),
        ("amoebacell", 24, 16),
        ("microunet", 24, 8),
        ("microunet", 48, 16),
        ("microformer", 64, 8),
    ];
    for (model, size, mu) in setups {
        let entry = engine.manifest().model(model)?.clone();
        let mut cfg = TrainConfig::builder(model).build();
        cfg.eval_len = 32; // a small but multi-micro-step repeat-eval set
        let (ds, eval_ds): (Arc<dyn Dataset>, Arc<dyn Dataset>) =
            datasets_for(&entry.task, size, &cfg)?;
        let indices: Vec<usize> = (0..mu).collect();

        let t_assemble = bench(iters, || {
            let mb = loader::assemble(ds.as_ref(), &indices, mu, 0);
            std::hint::black_box(&mb);
            Ok(())
        })?;

        // the pooled steady-state path: same assembly into a recycled
        // staging buffer — the delta vs `assemble` is what BufPool saves
        let mut staging = loader::assemble(ds.as_ref(), &indices, mu, 0);
        let t_assemble_into = bench(iters, || {
            loader::assemble_into(&mut staging, ds.as_ref(), &indices, mu, 0);
            std::hint::black_box(&staging);
            Ok(())
        })?;

        let mut rt = engine.load_model(model, size, mu)?;
        let mb = loader::assemble(ds.as_ref(), &indices, mu, 0);
        let plan = SplitPlan::new(mu, mu);
        let scale = NormalizationMode::Paper.scale(&plan, 0);

        let t_accum = bench(iters, || rt.accum_step(&mb, scale).map(|_| ()))?;
        let t_apply = bench(iters, || rt.apply(&rt.default_hyper()))?;
        let t_eval = bench(iters, || rt.eval_step(&mb).map(|_| ()))?;

        // repeat-eval through the caller-owned pool: one warm-up outside
        // the loop, every iteration reuses the same staging buffers
        let kind = MetricKind::parse(&entry.metric_semantics)?;
        let pool = Arc::new(BufPool::for_prefetch(2));
        pool.warm(BufPool::buffers_for(2), eval_ds.as_ref(), mu);
        let t_eval_sweep = bench(iters, || {
            evaluate_pooled(
                &mut rt,
                kind,
                &eval_ds,
                0,
                StreamingPolicy::Synchronous,
                0,
                &pool,
            )
            .map(|_| ())
        })?;

        table.row(&[
            model.to_string(),
            mu.to_string(),
            format!("{t_assemble:.2}"),
            format!("{t_assemble_into:.2}"),
            format!("{t_accum:.2}"),
            format!("{t_apply:.2}"),
            format!("{t_eval:.2}"),
            format!("{t_eval_sweep:.2}"),
        ]);
    }
    println!("MICROBENCH — per-stage hot-path latency ({iters} iters, state: see below):\n");
    println!("{}", table.render());

    // L3 bookkeeping cost: splitter + scale for a large epoch, no XLA
    let t0 = Instant::now();
    let mut sink = 0f32;
    let reps = 10_000usize;
    for i in 0..reps {
        let plan = SplitPlan::new(1024 + (i % 7), 16);
        for j in 0..plan.n_smu() {
            sink += NormalizationMode::Paper.scale(&plan, j);
        }
    }
    let l3_ns = t0.elapsed().as_nanos() as f64 / reps as f64;
    println!(
        "\nL3 bookkeeping (split + normalize, N_B=1024): {l3_ns:.0} ns per mini-batch\n\
         (sink {sink:.1}) — vs milliseconds per XLA step: coordinator is not the bottleneck."
    );
    Ok(())
}
