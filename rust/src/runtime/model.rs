//! Device-resident training state + the three executables of one variant.
//!
//! Call sequence per mini-batch (paper fig. 2):
//!   for each micro-batch j:   accum_step(mb_j, scale_j)   (steps 2-4)
//!   then:                     apply(hyper)                (step 5)
//!
//! Inputs flow through two persistent ping-ponged device slots
//! ([`ModelRuntime::stage_inputs`] → `accum_staged`/`eval_staged`). In the
//! serial mode the two calls are fused back into `accum_step`/`eval_step`
//! (one slot live at a time — the byte-identity oracle); with
//! [`ModelRuntime::set_overlap`] the runtime accepts a second staged
//! micro-batch while one is in flight, which is how the coordinator's
//! overlapped pipeline hides the upload stage behind execution. Upload
//! time spent staging while another step was in flight is attributed to
//! `StageTimers::upload_hidden` (a subset of `upload`).
//!
//! ABI (fixed by python/compile/model.py):
//!   accum:  inputs  [params.., acc.., x, y, mask, scale[1]]
//!           outputs (loss_sum, metric[4], acc'..)
//!   eval:   inputs  [params.., x, y, mask]   outputs (loss_sum, metric[4])
//!   apply:  inputs  [params.., acc.., slot0.., slot1.., hyper[k]]
//!           outputs (params'.., slot'.., acc_zero..)
//!
//! PJRT may return a tuple-rooted result either as flattened per-output
//! buffers or as one tuple buffer depending on client version; both are
//! handled (`OutputMode`), detected on the first call. In `Flat` mode the
//! training state never leaves the device; in `Tupled` mode leaves are
//! round-tripped through host literals (slower, still correct).

use std::collections::BTreeMap;
use std::rc::Rc;
use std::time::{Duration, Instant};

use crate::data::MicroBatchHost;
use crate::error::{MbsError, Result};
use crate::manifest::{Manifest, ModelEntry, Variant};
use crate::metrics::StageTimers;

use super::buffers;

/// Scalar results of one accumulation / eval step.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StepOutput {
    /// Masked sum of per-sample losses over the micro-batch.
    pub loss_sum: f32,
    /// Task-dependent metric sums (see `metrics::MetricKind`).
    pub metric: [f32; 4],
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum OutputMode {
    Unknown,
    /// outputs[0] is one buffer per tuple element (state stays on device)
    Flat,
    /// outputs[0] is a single tuple buffer (host round-trip per step)
    Tupled,
}

/// One of the two persistent ping-ponged device input slots the pipeline
/// stages uploads into ([`ModelRuntime::stage_inputs`]). A slot is *live*
/// from staging until its step executes; the overlapped pipeline keeps up
/// to two live at once (the ledger prices that second residency as
/// `Footprint::overlap_bytes`).
#[derive(Default)]
struct InputSlot {
    x: Option<xla::PjRtBuffer>,
    y: Option<xla::PjRtBuffer>,
    /// `Some` only for ragged tails; `None` means the cached all-ones
    /// device mask applies.
    tail_mask: Option<xla::PjRtBuffer>,
    /// Bit pattern of the staged loss-normalization scale; `None` when the
    /// slot was staged for eval (no scale).
    scale_bits: Option<u32>,
    /// Cumulative upload wall time into this slot (per-slot timer).
    upload: Duration,
}

impl InputSlot {
    /// Drop the staged device buffers (the step consumed them); the slot
    /// struct itself persists and is re-staged on the next ping-pong turn.
    fn release(&mut self) {
        self.x = None;
        self.y = None;
        self.tail_mask = None;
        self.scale_bits = None;
    }
}

/// Device-resident training state + compiled executables for one
/// (model, size, mu) variant. Built by `Engine::load_model`.
pub struct ModelRuntime {
    client: xla::PjRtClient,
    /// The manifest entry this runtime executes.
    pub entry: ModelEntry,
    /// The exported variant (static shapes) this runtime executes.
    pub variant: Variant,
    accum_exe: Rc<xla::PjRtLoadedExecutable>,
    eval_exe: Rc<xla::PjRtLoadedExecutable>,
    apply_exe: Rc<xla::PjRtLoadedExecutable>,
    /// Parameter leaves, device-resident.
    params: Vec<xla::PjRtBuffer>,
    /// Gradient accumulator leaves.
    acc: Vec<xla::PjRtBuffer>,
    /// Optimizer slots, slot-major: slots[s][leaf].
    slots: Vec<Vec<xla::PjRtBuffer>>,
    n_leaves: usize,
    mode: OutputMode,
    /// Count of accum steps since last apply (diagnostic).
    pending_micro_steps: usize,
    /// Total optimizer updates applied.
    pub updates: u64,
    /// Device-resident all-ones sample mask (`[mu]`), uploaded once: every
    /// full micro-batch reuses it, so only ragged tails re-upload a mask.
    ones_mask: Option<xla::PjRtBuffer>,
    /// Device-resident `[1]` loss-normalization scales, memoized by bit
    /// pattern — a run uses only a handful of distinct scales, so each is
    /// uploaded exactly once.
    scale_cache: BTreeMap<u32, xla::PjRtBuffer>,
    /// Cumulative per-stage wall time (upload / execute / download /
    /// apply); the epoch executor snapshots deltas per epoch.
    timers: StageTimers,
    /// Wall-clock window of the most recent device execution (accum /
    /// eval / apply). The trainer intersects upload-lane staging windows
    /// with it to attribute `StageTimers::upload_concurrent`.
    last_exec_window: Option<(Instant, Instant)>,
    /// The two ping-ponged device input slots.
    input_slots: [InputSlot; 2],
    /// Index of the next slot to execute (FIFO head of the staged queue).
    slot_head: usize,
    /// Staged-but-not-executed micro-batches (0..=2; >1 only with overlap).
    slot_staged: usize,
    /// Overlapped pipeline mode: accept a second staged micro-batch while
    /// one is in flight. Off = the serial byte-identity oracle.
    overlap: bool,
    /// Diagnostic owner label — the model key by default; the multi-job
    /// executor sets the job name, so a multi-tenant pipeline misuse
    /// error names its tenant.
    label: String,
}

impl ModelRuntime {
    #[allow(clippy::too_many_arguments)]
    pub(super) fn new(
        client: xla::PjRtClient,
        entry: ModelEntry,
        variant: Variant,
        accum_exe: Rc<xla::PjRtLoadedExecutable>,
        eval_exe: Rc<xla::PjRtLoadedExecutable>,
        apply_exe: Rc<xla::PjRtLoadedExecutable>,
        manifest: &Manifest,
    ) -> Result<ModelRuntime> {
        let bin = std::fs::read(manifest.path(&entry.params_bin))?;
        if bin.len() as u64 != entry.param_bytes {
            return Err(MbsError::Manifest(format!(
                "{}: params bin is {} bytes, manifest says {}",
                entry.name,
                bin.len(),
                entry.param_bytes
            )));
        }
        let mut params = Vec::with_capacity(entry.param_leaves.len());
        let mut host_leaf = Vec::new();
        for leaf in &entry.param_leaves {
            host_leaf.clear();
            host_leaf.reserve(leaf.elems);
            // decode the leaf in 4-byte windows rather than byte-at-a-time
            let bytes = &bin[leaf.offset..leaf.offset + leaf.elems * 4];
            host_leaf.extend(
                bytes
                    .chunks_exact(4)
                    .map(|w| f32::from_le_bytes([w[0], w[1], w[2], w[3]])),
            );
            let dims = if leaf.shape.is_empty() { vec![1] } else { leaf.shape.clone() };
            params.push(buffers::upload_f32(&client, &host_leaf, &dims)?);
        }
        let n_leaves = params.len();
        let zeros = |client: &xla::PjRtClient| -> Result<Vec<xla::PjRtBuffer>> {
            entry
                .param_leaves
                .iter()
                .map(|leaf| {
                    let dims = if leaf.shape.is_empty() { vec![1] } else { leaf.shape.clone() };
                    buffers::upload_f32(client, &vec![0.0f32; leaf.elems], &dims)
                })
                .collect()
        };
        let acc = zeros(&client)?;
        let slots = (0..entry.optimizer.slots)
            .map(|_| zeros(&client))
            .collect::<Result<Vec<_>>>()?;
        let label = entry.name.clone();
        Ok(ModelRuntime {
            client,
            entry,
            variant,
            accum_exe,
            eval_exe,
            apply_exe,
            params,
            acc,
            slots,
            n_leaves,
            mode: OutputMode::Unknown,
            pending_micro_steps: 0,
            updates: 0,
            ones_mask: None,
            scale_cache: BTreeMap::new(),
            timers: StageTimers::default(),
            last_exec_window: None,
            input_slots: [InputSlot::default(), InputSlot::default()],
            slot_head: 0,
            slot_staged: 0,
            overlap: false,
            label,
        })
    }

    /// Set the diagnostic owner label (job name in multi-tenant runs).
    pub fn set_label(&mut self, label: &str) {
        self.label = label.to_string();
    }

    /// The diagnostic owner label (defaults to the model key).
    pub fn label(&self) -> &str {
        &self.label
    }

    /// Parameter leaf count.
    pub fn n_leaves(&self) -> usize {
        self.n_leaves
    }

    /// Accumulation steps since the last optimizer update (diagnostic).
    pub fn pending_micro_steps(&self) -> usize {
        self.pending_micro_steps
    }

    /// Is this micro-batch's mask the all-ones constant the cached device
    /// buffer represents? True for every full (non-tail) micro-batch.
    fn mask_is_all_ones(&self, mb: &MicroBatchHost) -> bool {
        mb.actual == self.variant.mu && mb.mask.iter().all(|&m| m == 1.0)
    }

    /// Upload the device-resident all-ones mask once.
    fn ensure_ones_mask(&mut self) -> Result<()> {
        if self.ones_mask.is_none() {
            let ones = vec![1.0f32; self.variant.mu];
            self.ones_mask =
                Some(buffers::upload_f32(&self.client, &ones, &[self.variant.mu])?);
        }
        Ok(())
    }

    /// Upload the `[1]` scale buffer for this bit pattern once.
    fn ensure_scale(&mut self, scale: f32) -> Result<()> {
        let key = scale.to_bits();
        if !self.scale_cache.contains_key(&key) {
            let buf = buffers::upload_f32(&self.client, &[scale], &[1])?;
            self.scale_cache.insert(key, buf);
        }
        Ok(())
    }

    /// Distinct loss-normalization scales resident on the device.
    pub fn cached_scales(&self) -> usize {
        self.scale_cache.len()
    }

    /// Enable/disable the overlapped pipeline: with overlap on the runtime
    /// accepts a second staged micro-batch while one is in flight (and
    /// attributes that staging time to `StageTimers::upload_hidden`).
    /// Off (the default) enforces at most one live slot — the serial
    /// byte-identity oracle `--overlap off` runs against.
    pub fn set_overlap(&mut self, on: bool) {
        self.overlap = on;
    }

    /// Is the overlapped pipeline mode enabled?
    pub fn overlap(&self) -> bool {
        self.overlap
    }

    /// Staged-but-not-executed micro-batches (0, 1, or — overlap only — 2).
    pub fn staged_len(&self) -> usize {
        self.slot_staged
    }

    /// Cumulative upload wall time per ping-pong slot (per-slot timers; in
    /// steady-state overlap both slots carry roughly half the uploads).
    pub fn slot_upload_times(&self) -> [Duration; 2] {
        [self.input_slots[0].upload, self.input_slots[1].upload]
    }

    /// Upload one micro-batch's inputs into the idle ping-pong slot: x and
    /// y always, the mask only for ragged tails (the cached all-ones device
    /// mask covers full micro-batches), and — for accumulation steps — the
    /// memoized `[1]` scale for `scale`. With another micro-batch already
    /// staged (overlap mode), the upload time is also attributed to
    /// `StageTimers::upload_hidden`: it is the work the pipeline hides
    /// behind the in-flight step's execution.
    pub fn stage_inputs(&mut self, mb: &MicroBatchHost, scale: Option<f32>) -> Result<()> {
        if mb.mask.len() != self.variant.mu {
            return Err(MbsError::Runtime(format!(
                "micro-batch mask len {} != mu {}",
                mb.mask.len(),
                self.variant.mu
            )));
        }
        let cap = if self.overlap { 2 } else { 1 };
        if self.slot_staged >= cap {
            return Err(MbsError::Runtime(format!(
                "{}: input slots full: {} micro-batch(es) already staged (overlap={})",
                self.label, self.slot_staged, self.overlap
            )));
        }
        let t0 = Instant::now();
        if let Some(s) = scale {
            self.ensure_scale(s)?;
        }
        let full = self.mask_is_all_ones(mb);
        if full {
            self.ensure_ones_mask()?;
        }
        let x = buffers::upload_buf(&self.client, &mb.x, &self.variant.x_shape)?;
        let y = buffers::upload_buf(&self.client, &mb.y, &self.variant.y_shape)?;
        let tail_mask = if full {
            None
        } else {
            Some(buffers::upload_f32(&self.client, &mb.mask, &[self.variant.mu])?)
        };
        let elapsed = t0.elapsed();
        let hidden = self.slot_staged > 0;
        let idx = (self.slot_head + self.slot_staged) % 2;
        let slot = &mut self.input_slots[idx];
        slot.x = Some(x);
        slot.y = Some(y);
        slot.tail_mask = tail_mask;
        slot.scale_bits = scale.map(f32::to_bits);
        slot.upload += elapsed;
        self.slot_staged += 1;
        self.timers.upload += elapsed;
        if hidden {
            self.timers.upload_hidden += elapsed;
        }
        Ok(())
    }

    /// Run the accumulation step (fwd + bwd + grad accumulate) of the
    /// oldest staged micro-batch, releasing its slot. The slot must have
    /// been staged with a scale ([`ModelRuntime::stage_inputs`]).
    pub fn accum_staged(&mut self) -> Result<StepOutput> {
        if self.slot_staged == 0 {
            return Err(MbsError::Runtime("no staged micro-batch to execute".into()));
        }
        let idx = self.slot_head;
        let scale_bits = self.input_slots[idx].scale_bits.ok_or_else(|| {
            MbsError::Runtime("staged micro-batch carries no scale (staged for eval?)".into())
        })?;
        let missing = || MbsError::Runtime("staged slot lost its input buffers".into());
        let mut args: Vec<&xla::PjRtBuffer> =
            Vec::with_capacity(2 * self.n_leaves + 4);
        args.extend(self.params.iter());
        args.extend(self.acc.iter());
        let slot = &self.input_slots[idx];
        args.push(slot.x.as_ref().ok_or_else(missing)?);
        args.push(slot.y.as_ref().ok_or_else(missing)?);
        args.push(match &slot.tail_mask {
            Some(m) => m,
            None => self.ones_mask.as_ref().expect("ensured by stage_inputs"),
        });
        args.push(self.scale_cache.get(&scale_bits).expect("ensured by stage_inputs"));
        let t_execute = Instant::now();
        let mut outs = self.accum_exe.execute_b(&args)?;
        let execute_elapsed = t_execute.elapsed();
        let t_download = Instant::now();
        let replica = outs
            .first_mut()
            .ok_or_else(|| MbsError::Runtime("no replica outputs".into()))?;
        let expected = 2 + self.n_leaves;
        self.resolve_mode(replica.len(), expected)?;
        let out = match self.mode {
            OutputMode::Flat => {
                let loss_sum = buffers::download_scalar(&replica[0])?;
                let metric_v = buffers::download_f32(&replica[1], 4)?;
                // new accumulator leaves replace the old device buffers
                self.acc = replica.drain(2..).collect();
                StepOutput { loss_sum, metric: [metric_v[0], metric_v[1], metric_v[2], metric_v[3]] }
            }
            OutputMode::Tupled => {
                let lit = replica[0].to_literal_sync()?;
                let mut parts = lit
                    .to_tuple()
                    .map_err(|e| MbsError::Runtime(format!("untuple failed: {e}")))?;
                if parts.len() != expected {
                    return Err(MbsError::Runtime(format!(
                        "tuple arity {} != expected {expected}",
                        parts.len()
                    )));
                }
                let acc_lits = parts.split_off(2);
                let loss_sum = parts[0].to_vec::<f32>()?[0];
                let mv = parts[1].to_vec::<f32>()?;
                self.acc = acc_lits
                    .iter()
                    .zip(&self.entry.param_leaves)
                    .map(|(l, leaf)| {
                        let host = l.to_vec::<f32>()?;
                        let dims =
                            if leaf.shape.is_empty() { vec![1] } else { leaf.shape.clone() };
                        buffers::upload_f32(&self.client, &host, &dims)
                    })
                    .collect::<Result<Vec<_>>>()?;
                StepOutput { loss_sum, metric: [mv[0], mv[1], mv[2], mv[3]] }
            }
            OutputMode::Unknown => unreachable!(),
        };
        self.timers.execute += execute_elapsed;
        self.timers.download += t_download.elapsed();
        self.last_exec_window = Some((t_execute, t_execute + execute_elapsed));
        self.pending_micro_steps += 1;
        self.release_head_slot();
        Ok(out)
    }

    /// Evaluate the oldest staged micro-batch (forward only, no gradients),
    /// releasing its slot.
    pub fn eval_staged(&mut self) -> Result<StepOutput> {
        if self.slot_staged == 0 {
            return Err(MbsError::Runtime("no staged micro-batch to execute".into()));
        }
        let idx = self.slot_head;
        let missing = || MbsError::Runtime("staged slot lost its input buffers".into());
        let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(self.n_leaves + 3);
        args.extend(self.params.iter());
        let slot = &self.input_slots[idx];
        args.push(slot.x.as_ref().ok_or_else(missing)?);
        args.push(slot.y.as_ref().ok_or_else(missing)?);
        args.push(match &slot.tail_mask {
            Some(m) => m,
            None => self.ones_mask.as_ref().expect("ensured by stage_inputs"),
        });
        let t_execute = Instant::now();
        let mut outs = self.eval_exe.execute_b(&args)?;
        let execute_elapsed = t_execute.elapsed();
        let t_download = Instant::now();
        let replica = outs
            .first_mut()
            .ok_or_else(|| MbsError::Runtime("no replica outputs".into()))?;
        let out = if replica.len() == 2 {
            let loss_sum = buffers::download_scalar(&replica[0])?;
            let mv = buffers::download_f32(&replica[1], 4)?;
            StepOutput { loss_sum, metric: [mv[0], mv[1], mv[2], mv[3]] }
        } else {
            let lit = replica[0].to_literal_sync()?;
            let parts = lit
                .to_tuple()
                .map_err(|e| MbsError::Runtime(format!("untuple failed: {e}")))?;
            let loss_sum = parts[0].to_vec::<f32>()?[0];
            let mv = parts[1].to_vec::<f32>()?;
            StepOutput { loss_sum, metric: [mv[0], mv[1], mv[2], mv[3]] }
        };
        self.timers.execute += execute_elapsed;
        self.timers.download += t_download.elapsed();
        self.last_exec_window = Some((t_execute, t_execute + execute_elapsed));
        self.release_head_slot();
        Ok(out)
    }

    /// Release the head slot after its step executed: the device input
    /// buffers drop (matching the ledger's free) and the ping-pong advances.
    fn release_head_slot(&mut self) {
        let idx = self.slot_head;
        self.input_slots[idx].release();
        self.slot_head = (idx + 1) % 2;
        self.slot_staged -= 1;
    }

    /// Abandon every staged-but-unexecuted micro-batch and reset the
    /// ping-pong to its initial state (recovery quiesce: a faulted job
    /// drains its pipeline before replaying from a checkpoint, so no
    /// stale input pairs with a replayed step). The per-slot upload
    /// timers are preserved — wall time was genuinely spent.
    pub fn reset_pipeline(&mut self) {
        self.input_slots[0].release();
        self.input_slots[1].release();
        self.slot_head = 0;
        self.slot_staged = 0;
    }

    /// Run one micro-batch accumulation step (fwd + bwd + grad accumulate):
    /// the serial stage-then-execute fusion, one slot live at a time.
    /// `scale` is the loss-normalization factor chosen by the coordinator.
    pub fn accum_step(&mut self, mb: &MicroBatchHost, scale: f32) -> Result<StepOutput> {
        self.check_no_staged("accum_step")?;
        self.stage_inputs(mb, Some(scale))?;
        self.accum_staged()
    }

    /// Evaluate one (padded, masked) micro-batch without touching gradients
    /// (the serial stage-then-execute fusion).
    pub fn eval_step(&mut self, mb: &MicroBatchHost) -> Result<StepOutput> {
        self.check_no_staged("eval_step")?;
        self.stage_inputs(mb, None)?;
        self.eval_staged()
    }

    /// The serial fused steps would execute the *oldest* staged slot, so
    /// mixing them with an in-flight pipeline would mispair inputs; refuse
    /// loudly instead.
    fn check_no_staged(&self, what: &str) -> Result<()> {
        if self.slot_staged > 0 {
            return Err(MbsError::Runtime(format!(
                "{}: {what} called with {} staged micro-batch(es) in flight — drain the \
                 pipeline (accum_staged/eval_staged) first",
                self.label, self.slot_staged
            )));
        }
        Ok(())
    }

    /// Apply the optimizer update from the accumulated gradient, then reset
    /// the accumulator (the zeroed accumulator comes back from the same
    /// executable, so the whole update is one device-side call).
    pub fn apply(&mut self, hyper: &[f32]) -> Result<()> {
        let t_apply = Instant::now();
        let expected_hyper = self.entry.optimizer.hyper_names.len();
        if hyper.len() != expected_hyper {
            return Err(MbsError::Runtime(format!(
                "{} hyper values given, optimizer {} needs {expected_hyper}",
                hyper.len(),
                self.entry.optimizer.kind
            )));
        }
        let hyper_buf = buffers::upload_f32(&self.client, hyper, &[hyper.len()])?;
        let n_slots = self.slots.len();
        let mut args: Vec<&xla::PjRtBuffer> =
            Vec::with_capacity((2 + n_slots) * self.n_leaves + 1);
        args.extend(self.params.iter());
        args.extend(self.acc.iter());
        for slot in &self.slots {
            args.extend(slot.iter());
        }
        args.push(&hyper_buf);
        let mut outs = self.apply_exe.execute_b(&args)?;
        let replica = outs
            .first_mut()
            .ok_or_else(|| MbsError::Runtime("no replica outputs".into()))?;
        let expected = (2 + n_slots) * self.n_leaves;
        if replica.len() == expected {
            let mut it = replica.drain(..);
            self.params = it.by_ref().take(self.n_leaves).collect();
            for s in 0..n_slots {
                self.slots[s] = it.by_ref().take(self.n_leaves).collect();
            }
            self.acc = it.collect();
        } else if replica.len() == 1 {
            let lit = replica[0].to_literal_sync()?;
            let parts = lit
                .to_tuple()
                .map_err(|e| MbsError::Runtime(format!("untuple failed: {e}")))?;
            if parts.len() != expected {
                return Err(MbsError::Runtime(format!(
                    "apply tuple arity {} != {expected}",
                    parts.len()
                )));
            }
            let upload = |lits: &[xla::Literal],
                          leaves: &[crate::manifest::ParamLeaf],
                          client: &xla::PjRtClient|
             -> Result<Vec<xla::PjRtBuffer>> {
                lits.iter()
                    .zip(leaves)
                    .map(|(l, leaf)| {
                        let host = l.to_vec::<f32>()?;
                        let dims =
                            if leaf.shape.is_empty() { vec![1] } else { leaf.shape.clone() };
                        buffers::upload_f32(client, &host, &dims)
                    })
                    .collect()
            };
            let n = self.n_leaves;
            self.params = upload(&parts[0..n], &self.entry.param_leaves, &self.client)?;
            for s in 0..n_slots {
                self.slots[s] =
                    upload(&parts[(1 + s) * n..(2 + s) * n], &self.entry.param_leaves, &self.client)?;
            }
            self.acc = upload(
                &parts[(1 + n_slots) * n..(2 + n_slots) * n],
                &self.entry.param_leaves,
                &self.client,
            )?;
        } else {
            return Err(MbsError::Runtime(format!(
                "apply returned {} outputs, expected {expected} or 1",
                replica.len()
            )));
        }
        self.pending_micro_steps = 0;
        self.updates += 1;
        let apply_elapsed = t_apply.elapsed();
        self.timers.apply += apply_elapsed;
        self.last_exec_window = Some((t_apply, t_apply + apply_elapsed));
        Ok(())
    }

    /// Snapshot of the cumulative per-stage timers (monotonic; take deltas
    /// across two snapshots to attribute an epoch's time).
    pub fn timers(&self) -> StageTimers {
        self.timers
    }

    /// Absorb an upload-lane staging window `[started, finished)` measured
    /// on the lane thread: its full duration joins `StageTimers::upload`
    /// (pinned staging is part of the upload path), and its intersection
    /// with the most recent device-execution window — real wall-clock
    /// concurrency, not pipeline structure — joins
    /// `StageTimers::upload_concurrent`. Pairing against only the latest
    /// execute window slightly undercounts a window that spanned several
    /// executions; the metric stays a strict lower bound on the true
    /// overlap, which is the honest direction to err in.
    pub fn credit_lane_window(&mut self, started: Instant, finished: Instant) {
        self.timers.upload += finished.saturating_duration_since(started);
        if let Some((exec_start, exec_end)) = self.last_exec_window {
            let lo = started.max(exec_start);
            let hi = finished.min(exec_end);
            self.timers.upload_concurrent += hi.saturating_duration_since(lo);
        }
    }

    /// Download current parameter leaves (for checkpoints / tests).
    pub fn params_to_host(&self) -> Result<Vec<Vec<f32>>> {
        self.params
            .iter()
            .zip(&self.entry.param_leaves)
            .map(|(b, leaf)| buffers::download_f32(b, leaf.elems.max(1)))
            .collect()
    }

    /// The PJRT client owning this runtime's buffers.
    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }

    /// Download optimizer slot leaves (slot-major), for checkpoints.
    pub fn slots_to_host(&self) -> Result<Vec<Vec<Vec<f32>>>> {
        self.slots
            .iter()
            .map(|slot| {
                slot.iter()
                    .zip(&self.entry.param_leaves)
                    .map(|(b, leaf)| buffers::download_f32(b, leaf.elems.max(1)))
                    .collect()
            })
            .collect()
    }

    /// Replace the device-resident training state (checkpoint restore).
    pub(super) fn restore_state(
        &mut self,
        params: Vec<xla::PjRtBuffer>,
        slots: Vec<Vec<xla::PjRtBuffer>>,
        updates: u64,
    ) {
        debug_assert_eq!(params.len(), self.n_leaves);
        self.params = params;
        self.slots = slots;
        self.updates = updates;
    }

    /// Download current accumulator leaves (used by the grad-equivalence
    /// integration test).
    pub fn acc_to_host(&self) -> Result<Vec<Vec<f32>>> {
        self.acc
            .iter()
            .zip(&self.entry.param_leaves)
            .map(|(b, leaf)| buffers::download_f32(b, leaf.elems.max(1)))
            .collect()
    }

    /// Reset the gradient accumulator to zeros (host upload; only used when
    /// abandoning a mini-batch, the normal path gets zeros from `apply`).
    pub fn zero_acc(&mut self) -> Result<()> {
        self.acc = self
            .entry
            .param_leaves
            .iter()
            .map(|leaf| {
                let dims = if leaf.shape.is_empty() { vec![1] } else { leaf.shape.clone() };
                buffers::upload_f32(&self.client, &vec![0.0f32; leaf.elems], &dims)
            })
            .collect::<Result<Vec<_>>>()?;
        self.pending_micro_steps = 0;
        Ok(())
    }

    /// Which output convention the PJRT client uses (after the first step).
    pub fn output_mode_name(&self) -> &'static str {
        match self.mode {
            OutputMode::Unknown => "unknown",
            OutputMode::Flat => "flat (device-resident state)",
            OutputMode::Tupled => "tupled (host round-trip)",
        }
    }

    fn resolve_mode(&mut self, got: usize, expected: usize) -> Result<()> {
        let detected = if got == expected {
            OutputMode::Flat
        } else if got == 1 {
            OutputMode::Tupled
        } else {
            return Err(MbsError::Runtime(format!(
                "accum returned {got} outputs, expected {expected} or 1"
            )));
        };
        if self.mode == OutputMode::Unknown {
            self.mode = detected;
        } else if self.mode != detected {
            return Err(MbsError::Runtime("inconsistent PJRT output convention".into()));
        }
        Ok(())
    }

    /// Default hyper-parameter vector from the manifest.
    pub fn default_hyper(&self) -> Vec<f32> {
        self.entry.optimizer.hyper_defaults.clone()
    }
}
