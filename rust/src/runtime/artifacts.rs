//! Compile-in-the-loop executable artifact manager.
//!
//! Everything downstream of export used to be frozen: executables were
//! per-(model, size, mu) artifacts baked at `compile.aot` time, so
//! admission could never propose a mu that was not exported and
//! `mbs frontier` could only time variants that happened to exist on
//! disk. This module makes requesting a variant a cheap, cached,
//! concurrent-safe operation:
//!
//! ```text
//!            fetch(VariantKey, manifest fingerprint)
//!                            |
//!              digest = FNV-1a(canonical key | fingerprint)
//!                            v
//!   <cache>/<digest>.meta.json          hit? -> checksum-validate
//!   <cache>/<digest>.accum.hlo.txt            |  corrupt -> evict,
//!   <cache>/<digest>.eval.hlo.txt             |  fall through to compile
//!                            |
//!             miss: in-flight already? -> wait (coalesce)
//!                   else lead: CompilerBackend::compile
//!                            |
//!              write tmp -> rename (payloads, then meta)
//!                   LRU-evict beyond max_entries
//! ```
//!
//! Design points:
//!  * **Content addressing**: the cache key is the FNV-1a digest
//!    ([`crate::util::hash::fnv1a64`]) of the canonical variant key plus
//!    the manifest entry's metadata fingerprint
//!    ([`crate::manifest::ModelEntry::fingerprint`]) — re-exporting a
//!    model with a different parameter layout invalidates its cached
//!    executables without any explicit flush.
//!  * **Coalescing**: concurrent fetches of one uncached variant elect a
//!    single leader; the rest wait on a condvar and read the leader's
//!    result from disk (compile count == 1). A leader that fails records
//!    the error so its waiters surface the same structured
//!    [`MbsError::Compile`] without re-compiling; a *later* fresh fetch
//!    retries. A leader that panics releases its claim via an RAII guard,
//!    so waiters are never stranded and no `.tmp` files leak.
//!  * **Crash safety / corruption**: entries mirror
//!    [`crate::runtime::checkpoint`] — payloads land via
//!    write-tmp-then-rename, then the metadata JSON (magic, canonical
//!    key, byte lengths, per-payload FNV-1a checksums) that vouches for
//!    them. A bit-flipped or truncated entry fails validation on hit, is
//!    evicted, and is transparently recompiled.
//!  * **Bounded size**: an LRU list over on-disk entries; inserting
//!    beyond `max_entries` evicts the least-recently-used entry's files.
//!    Handles pin payload *bytes* in memory, never files — callers that
//!    read by path (the PJRT compile) do so immediately after fetch.
//!  * **Backends**: the [`CompilerBackend`] trait keeps the python
//!    exporter ([`PythonAotCompiler`], `python -m compile.aot --variant`)
//!    behind the same seam as the deterministic [`MockCompiler`], so the
//!    whole cache contract is proven in tier-1 tests with no artifacts.

use std::collections::{HashMap, HashSet};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use crate::error::{MbsError, Result};
use crate::runtime::faults::{FaultHooks, FaultKind};
use crate::util::hash::fnv1a64;
use crate::util::json::Json;

const MAGIC: &str = "mbs-artifact-v1";

/// Default bound on cached variant entries per manager.
pub const DEFAULT_MAX_ENTRIES: usize = 32;

/// Canonical identity of one requested executable variant.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct VariantKey {
    /// Manifest model name.
    pub model: String,
    /// Image size (px) or sequence length.
    pub size: usize,
    /// Static micro-batch size.
    pub mu: usize,
    /// Overlapped-pipeline specialization flag. Exported HLO is
    /// overlap-agnostic today, but the flag is part of the cache identity
    /// so an overlap-specialized export can land without a format break.
    pub overlap: bool,
}

impl VariantKey {
    /// The canonical string form hashed into the cache digest and echoed
    /// in errors: `model:sSIZE:muMU:overlap|serial`.
    pub fn canonical(&self) -> String {
        format!(
            "{}:s{}:mu{}:{}",
            self.model,
            self.size,
            self.mu,
            if self.overlap { "overlap" } else { "serial" }
        )
    }

    /// Content address of this key under a manifest fingerprint
    /// ([`crate::manifest::ModelEntry::fingerprint`]).
    pub fn digest(&self, manifest_fingerprint: u64) -> u64 {
        fnv1a64(format!("{}|{manifest_fingerprint:016x}", self.canonical()).as_bytes())
    }
}

/// What one backend compile produces: the HLO text payload pair.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CompiledArtifact {
    /// HLO text of the gradient-accumulation step.
    pub accum_hlo: Vec<u8>,
    /// HLO text of the forward-only eval step.
    pub eval_hlo: Vec<u8>,
}

/// The compile seam: the python AOT exporter and the deterministic test
/// mock sit behind the same trait, so every consumer of the artifact
/// manager is testable without python, jax, or artifacts.
pub trait CompilerBackend: Send + Sync {
    /// Produce the HLO payload pair for `key`. Must be deterministic per
    /// key for the cache's byte-identity contract to hold.
    fn compile(&self, key: &VariantKey) -> Result<CompiledArtifact>;

    /// Backend label for diagnostics.
    fn name(&self) -> &'static str;
}

/// A checked-out cache entry. Payload bytes are pinned in memory (shared,
/// immutable); the paths point at the on-disk entry, which a later LRU
/// eviction may remove — read promptly or use the bytes.
#[derive(Debug, Clone)]
pub struct ArtifactHandle {
    /// The requested variant.
    pub key: VariantKey,
    /// Content address the entry is stored under.
    pub digest: u64,
    /// On-disk path of the accum-step HLO text.
    pub accum_path: PathBuf,
    /// On-disk path of the eval-step HLO text.
    pub eval_path: PathBuf,
    /// Accum-step HLO text.
    pub accum_hlo: Arc<Vec<u8>>,
    /// Eval-step HLO text.
    pub eval_hlo: Arc<Vec<u8>>,
}

/// Point-in-time counters of one manager (all monotonic).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ArtifactStats {
    /// Fetches served from a validated on-disk entry.
    pub hits: u64,
    /// Fetches that led a backend compile (== backend invocations that
    /// were attempted, successful or not).
    pub compiles: u64,
    /// Fetches that waited on another thread's in-flight compile.
    pub coalesced: u64,
    /// Entries evicted by the LRU bound.
    pub evictions: u64,
    /// Entries evicted because checksum validation failed on hit.
    pub corrupt_evictions: u64,
    /// Backend compiles that returned an error.
    pub compile_errors: u64,
}

impl ArtifactStats {
    /// Fraction of fetches served from cache (hits / (hits + compiles));
    /// 1.0 for an idle manager so warm-cache gates read naturally.
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.compiles;
        if total == 0 {
            1.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

#[derive(Default)]
struct State {
    /// Digests a leader is currently compiling.
    in_flight: HashSet<u64>,
    /// Last leader error per digest, surfaced to that compile's waiters;
    /// cleared when a fresh fetch retries the digest.
    failed: HashMap<u64, String>,
    /// On-disk entries, least-recently-used first.
    lru: Vec<u64>,
}

struct Inner {
    dir: PathBuf,
    backend: Arc<dyn CompilerBackend>,
    max_entries: usize,
    state: Mutex<State>,
    cond: Condvar,
    hits: AtomicU64,
    compiles: AtomicU64,
    coalesced: AtomicU64,
    evictions: AtomicU64,
    corrupt_evictions: AtomicU64,
    compile_errors: AtomicU64,
}

/// Content-addressed, coalescing, bounded executable artifact cache.
/// Cloning shares the manager (`Arc` inside); every method takes `&self`,
/// so one manager can serve concurrent tenants.
#[derive(Clone)]
pub struct ArtifactManager {
    inner: Arc<Inner>,
}

impl std::fmt::Debug for ArtifactManager {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ArtifactManager")
            .field("dir", &self.inner.dir)
            .field("backend", &self.inner.backend.name())
            .field("max_entries", &self.inner.max_entries)
            .finish()
    }
}

/// Releases a leader's in-flight claim even if the compile panics, so
/// waiters are woken (with a recorded failure) instead of stranded.
struct InFlightGuard<'a> {
    inner: &'a Inner,
    digest: u64,
    armed: bool,
}

impl Drop for InFlightGuard<'_> {
    fn drop(&mut self) {
        if !self.armed {
            return;
        }
        let mut state = lock_state(&self.inner.state);
        state.in_flight.remove(&self.digest);
        state
            .failed
            .insert(self.digest, "compile aborted (leader panicked or was dropped)".into());
        self.inner.cond.notify_all();
    }
}

/// Poison-tolerant lock: a panicking test backend must not wedge every
/// other thread's fetch (the state it guards is repaired by the guard's
/// failure bookkeeping, never left half-written across a panic point).
fn lock_state(m: &Mutex<State>) -> MutexGuard<'_, State> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// Write `bytes` to `<final>.tmp` then rename into place (the
/// checkpoint.rs crash-safety primitive). The tmp sibling is removed on
/// any write failure, so error paths leak nothing.
fn write_atomic(final_path: &Path, bytes: &[u8]) -> Result<()> {
    let mut tmp = final_path.as_os_str().to_owned();
    tmp.push(".tmp");
    let tmp = PathBuf::from(tmp);
    if let Err(e) = std::fs::write(&tmp, bytes) {
        std::fs::remove_file(&tmp).ok();
        return Err(e.into());
    }
    if let Err(e) = std::fs::rename(&tmp, final_path) {
        std::fs::remove_file(&tmp).ok();
        return Err(e.into());
    }
    Ok(())
}

impl ArtifactManager {
    /// A manager rooted at `dir` (created if absent) over `backend`,
    /// keeping at most `max_entries` entries on disk.
    pub fn new(
        dir: impl AsRef<Path>,
        backend: Arc<dyn CompilerBackend>,
        max_entries: usize,
    ) -> Result<ArtifactManager> {
        let dir = dir.as_ref().to_path_buf();
        std::fs::create_dir_all(&dir)?;
        let max_entries = max_entries.max(1);
        let manager = ArtifactManager {
            inner: Arc::new(Inner {
                dir,
                backend,
                max_entries,
                state: Mutex::new(State::default()),
                cond: Condvar::new(),
                hits: AtomicU64::new(0),
                compiles: AtomicU64::new(0),
                coalesced: AtomicU64::new(0),
                evictions: AtomicU64::new(0),
                corrupt_evictions: AtomicU64::new(0),
                compile_errors: AtomicU64::new(0),
            }),
        };
        manager.adopt_existing_entries()?;
        Ok(manager)
    }

    /// The cache directory this manager owns.
    pub fn dir(&self) -> &Path {
        &self.inner.dir
    }

    /// The backend label (diagnostics).
    pub fn backend_name(&self) -> &'static str {
        self.inner.backend.name()
    }

    /// Snapshot the manager's counters.
    pub fn stats(&self) -> ArtifactStats {
        let i = &self.inner;
        ArtifactStats {
            hits: i.hits.load(Ordering::Relaxed),
            compiles: i.compiles.load(Ordering::Relaxed),
            coalesced: i.coalesced.load(Ordering::Relaxed),
            evictions: i.evictions.load(Ordering::Relaxed),
            corrupt_evictions: i.corrupt_evictions.load(Ordering::Relaxed),
            compile_errors: i.compile_errors.load(Ordering::Relaxed),
        }
    }

    /// Entries currently on disk (diagnostics / tests).
    pub fn cached_entries(&self) -> usize {
        lock_state(&self.inner.state).lru.len()
    }

    /// Resolve `key` to an executable handle: validated cache hit, or a
    /// (coalesced) backend compile. `manifest_fingerprint` is the model
    /// entry's metadata digest — part of the content address, so stale
    /// entries from an older export can never be served.
    pub fn fetch(&self, key: &VariantKey, manifest_fingerprint: u64) -> Result<ArtifactHandle> {
        let digest = key.digest(manifest_fingerprint);
        let inner = &self.inner;
        let mut waited = false;
        let mut state = lock_state(&inner.state);
        while state.in_flight.contains(&digest) {
            if !waited {
                inner.coalesced.fetch_add(1, Ordering::Relaxed);
                waited = true;
            }
            state = inner
                .cond
                .wait(state)
                .unwrap_or_else(std::sync::PoisonError::into_inner);
        }
        // try the on-disk entry under the lock (validation races nothing:
        // eviction and insertion both hold the same lock)
        match self.validate_on_disk(digest, key) {
            Ok(Some(handle)) => {
                touch_lru(&mut state.lru, digest);
                inner.hits.fetch_add(1, Ordering::Relaxed);
                return Ok(handle);
            }
            Ok(None) => {}
            Err(reason) => {
                // corrupt or truncated: evict the entry and recompile —
                // the caller never sees the corruption
                self.remove_entry_files(digest);
                state.lru.retain(|d| *d != digest);
                inner.corrupt_evictions.fetch_add(1, Ordering::Relaxed);
                eprintln!(
                    "[mbs] artifacts: evicting corrupt cache entry {digest:016x} \
                     for {} ({reason})",
                    key.canonical()
                );
            }
        }
        // a waiter whose leader failed surfaces the same structured error
        // instead of stampeding the backend; a later fresh fetch retries
        if waited {
            if let Some(reason) = state.failed.get(&digest) {
                return Err(MbsError::Compile { key: key.canonical(), reason: reason.clone() });
            }
        }
        state.failed.remove(&digest);
        state.in_flight.insert(digest);
        drop(state);

        let mut guard = InFlightGuard { inner, digest, armed: true };
        inner.compiles.fetch_add(1, Ordering::Relaxed);
        let outcome = inner.backend.compile(key).and_then(|artifact| {
            self.store(digest, key, &artifact)?;
            Ok(artifact)
        });
        guard.armed = false;
        let mut state = lock_state(&inner.state);
        state.in_flight.remove(&digest);
        let result = match outcome {
            Ok(artifact) => {
                touch_lru(&mut state.lru, digest);
                while state.lru.len() > inner.max_entries {
                    let victim = state.lru.remove(0);
                    self.remove_entry_files(victim);
                    inner.evictions.fetch_add(1, Ordering::Relaxed);
                }
                Ok(ArtifactHandle {
                    key: key.clone(),
                    digest,
                    accum_path: self.accum_path(digest),
                    eval_path: self.eval_path(digest),
                    accum_hlo: Arc::new(artifact.accum_hlo),
                    eval_hlo: Arc::new(artifact.eval_hlo),
                })
            }
            Err(e) => {
                state.failed.insert(digest, e.to_string());
                inner.compile_errors.fetch_add(1, Ordering::Relaxed);
                Err(e)
            }
        };
        inner.cond.notify_all();
        result
    }

    fn meta_path(&self, digest: u64) -> PathBuf {
        self.inner.dir.join(format!("{digest:016x}.meta.json"))
    }

    fn accum_path(&self, digest: u64) -> PathBuf {
        self.inner.dir.join(format!("{digest:016x}.accum.hlo.txt"))
    }

    fn eval_path(&self, digest: u64) -> PathBuf {
        self.inner.dir.join(format!("{digest:016x}.eval.hlo.txt"))
    }

    /// Load + checksum-validate the on-disk entry for `digest`.
    /// `Ok(None)` = not cached; `Err(reason)` = present but corrupt.
    fn validate_on_disk(
        &self,
        digest: u64,
        key: &VariantKey,
    ) -> std::result::Result<Option<ArtifactHandle>, String> {
        let meta_path = self.meta_path(digest);
        let meta_text = match std::fs::read_to_string(&meta_path) {
            Ok(t) => t,
            // no metadata = no entry (a crash between payload and meta
            // renames leaves payload orphans, overwritten on recompile)
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
            Err(e) => return Err(format!("unreadable metadata: {e}")),
        };
        let meta = Json::parse(&meta_text).map_err(|e| format!("metadata: {e}"))?;
        let get_str = |k: &str| meta.get(k).and_then(Json::as_str).unwrap_or_default().to_string();
        let get_u64 = |k: &str| meta.get(k).and_then(Json::as_u64).unwrap_or(u64::MAX);
        if get_str("magic") != MAGIC {
            return Err("not an mbs artifact entry".into());
        }
        if get_str("key") != key.canonical() {
            return Err(format!(
                "entry is for '{}', requested '{}' (digest collision or stale entry)",
                get_str("key"),
                key.canonical()
            ));
        }
        let read_payload = |path: &Path, len_key: &str, sum_key: &str| {
            let bytes = std::fs::read(path)
                .map_err(|e| format!("unreadable payload {}: {e}", path.display()))?;
            if bytes.len() as u64 != get_u64(len_key) {
                return Err(format!(
                    "payload {} is {} bytes, metadata says {}",
                    path.display(),
                    bytes.len(),
                    get_u64(len_key)
                ));
            }
            let recorded = u64::from_str_radix(&get_str(sum_key), 16)
                .map_err(|_| format!("malformed checksum '{}'", get_str(sum_key)))?;
            let actual = fnv1a64(&bytes);
            if recorded != actual {
                return Err(format!(
                    "payload {} checksum mismatch: metadata says {recorded:016x}, \
                     payload hashes to {actual:016x} (corrupt or truncated entry)",
                    path.display()
                ));
            }
            Ok(bytes)
        };
        let accum = read_payload(&self.accum_path(digest), "accum_bytes", "accum_checksum")?;
        let eval = read_payload(&self.eval_path(digest), "eval_bytes", "eval_checksum")?;
        Ok(Some(ArtifactHandle {
            key: key.clone(),
            digest,
            accum_path: self.accum_path(digest),
            eval_path: self.eval_path(digest),
            accum_hlo: Arc::new(accum),
            eval_hlo: Arc::new(eval),
        }))
    }

    /// Persist a compiled artifact: payloads first (tmp → rename), then
    /// the metadata that vouches for them — a crash mid-store leaves at
    /// worst payload orphans that the next compile overwrites, never a
    /// metadata file pointing at half-written payloads.
    fn store(&self, digest: u64, key: &VariantKey, artifact: &CompiledArtifact) -> Result<()> {
        write_atomic(&self.accum_path(digest), &artifact.accum_hlo)?;
        write_atomic(&self.eval_path(digest), &artifact.eval_hlo)?;
        let meta = format!(
            "{{\"magic\": \"{MAGIC}\", \"key\": \"{}\", \"backend\": \"{}\", \
             \"accum_bytes\": {}, \"accum_checksum\": \"{:016x}\", \
             \"eval_bytes\": {}, \"eval_checksum\": \"{:016x}\"}}",
            key.canonical(),
            self.inner.backend.name(),
            artifact.accum_hlo.len(),
            fnv1a64(&artifact.accum_hlo),
            artifact.eval_hlo.len(),
            fnv1a64(&artifact.eval_hlo),
        );
        write_atomic(&self.meta_path(digest), meta.as_bytes())
    }

    fn remove_entry_files(&self, digest: u64) {
        // meta first: with it gone the entry no longer exists, whatever
        // happens to the payload removals
        std::fs::remove_file(self.meta_path(digest)).ok();
        std::fs::remove_file(self.accum_path(digest)).ok();
        std::fs::remove_file(self.eval_path(digest)).ok();
    }

    /// Re-adopt entries a previous process left in the cache dir (their
    /// digests, from the metadata file names) so the LRU bound covers
    /// them; validation still happens per fetch.
    fn adopt_existing_entries(&self) -> Result<()> {
        let mut state = lock_state(&self.inner.state);
        for entry in std::fs::read_dir(&self.inner.dir)? {
            let name = entry?.file_name();
            let name = name.to_string_lossy();
            if let Some(hex) = name.strip_suffix(".meta.json") {
                if let Ok(digest) = u64::from_str_radix(hex, 16) {
                    if !state.lru.contains(&digest) {
                        state.lru.push(digest);
                    }
                }
            }
        }
        Ok(())
    }
}

/// Move `digest` to the most-recently-used end.
fn touch_lru(lru: &mut Vec<u64>, digest: u64) {
    lru.retain(|d| *d != digest);
    lru.push(digest);
}

// ---------------------------------------------------------------------------
// Backends
// ---------------------------------------------------------------------------

/// Deterministic in-process compiler for the tier-1 test harness:
/// configurable latency, failure injection through the existing
/// [`FaultPlan`](crate::runtime::faults::FaultPlan) machinery (a
/// [`FaultKind::Compile`] entry fires per compile *attempt*), and
/// compile-count accounting. Payloads are a pure function of the key, so
/// coalesced and repeated fetches are byte-identical by construction.
pub struct MockCompiler {
    latency: Duration,
    hooks: Mutex<FaultHooks>,
    compiles: AtomicU64,
}

impl Default for MockCompiler {
    fn default() -> Self {
        MockCompiler::new()
    }
}

impl MockCompiler {
    /// A mock that always succeeds instantly.
    pub fn new() -> MockCompiler {
        MockCompiler {
            latency: Duration::ZERO,
            hooks: Mutex::new(FaultHooks::none()),
            compiles: AtomicU64::new(0),
        }
    }

    /// Sleep this long per compile (coalescing tests need a window in
    /// which concurrent fetches can pile up).
    pub fn with_latency(mut self, latency: Duration) -> MockCompiler {
        self.latency = latency;
        self
    }

    /// Inject failures: a [`FaultKind::Compile`] hook entry firing at
    /// compile attempt `n` (0-based, counted across all keys) turns that
    /// compile into a structured [`MbsError::Compile`].
    pub fn with_faults(mut self, hooks: FaultHooks) -> MockCompiler {
        self.hooks = Mutex::new(hooks);
        self
    }

    /// Backend compiles attempted so far (the coalescing oracle).
    pub fn compiles(&self) -> u64 {
        self.compiles.load(Ordering::Relaxed)
    }

    /// The deterministic payload for `key` — exposed so tests can assert
    /// byte identity against an independent rendering.
    pub fn render(key: &VariantKey, role: &str) -> Vec<u8> {
        let canon = key.canonical();
        format!(
            "HloModule mock_{role}_{}_s{}_mu{} // {canon} digest={:016x}\n\
             ROOT tuple.0 = () tuple()\n",
            key.model,
            key.size,
            key.mu,
            fnv1a64(format!("{role}|{canon}").as_bytes())
        )
        .into_bytes()
    }
}

impl CompilerBackend for MockCompiler {
    fn compile(&self, key: &VariantKey) -> Result<CompiledArtifact> {
        let attempt = self.compiles.fetch_add(1, Ordering::SeqCst);
        if !self.latency.is_zero() {
            std::thread::sleep(self.latency);
        }
        let note = lock_hooks(&self.hooks).check(FaultKind::Compile, attempt);
        if let Some(note) = note {
            return Err(MbsError::Compile {
                key: key.canonical(),
                reason: format!("injected: {note}"),
            });
        }
        Ok(CompiledArtifact {
            accum_hlo: MockCompiler::render(key, "accum"),
            eval_hlo: MockCompiler::render(key, "eval"),
        })
    }

    fn name(&self) -> &'static str {
        "mock"
    }
}

fn lock_hooks(m: &Mutex<FaultHooks>) -> MutexGuard<'_, FaultHooks> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// The real backend: shells out to `python -m compile.aot --variant
/// MODEL:SIZE:MU` in a scratch directory, with a wall-clock timeout
/// (exceeding it kills the subprocess and yields the *recoverable*
/// [`MbsError::CompileTimeout`]), and reads back the two HLO text files
/// the exporter names by convention.
pub struct PythonAotCompiler {
    python: String,
    compile_dir: PathBuf,
    scratch_dir: PathBuf,
    timeout: Duration,
}

impl PythonAotCompiler {
    /// Backend invoking `python` (e.g. `"python3"`) with the `compile`
    /// package importable from `compile_dir`, writing its intermediate
    /// exports under `scratch_dir`.
    pub fn new(
        python: impl Into<String>,
        compile_dir: impl AsRef<Path>,
        scratch_dir: impl AsRef<Path>,
    ) -> PythonAotCompiler {
        PythonAotCompiler {
            python: python.into(),
            compile_dir: compile_dir.as_ref().to_path_buf(),
            scratch_dir: scratch_dir.as_ref().to_path_buf(),
            timeout: Duration::from_secs(600),
        }
    }

    /// The conventional layout for an engine over `<repo>/rust/artifacts`:
    /// the python package lives at `<repo>/python`, overridable with
    /// `MBS_COMPILE_DIR`; the interpreter defaults to `python3`,
    /// overridable with `MBS_PYTHON`.
    pub fn for_manifest_dir(manifest_dir: &Path, scratch_dir: &Path) -> PythonAotCompiler {
        let compile_dir = std::env::var("MBS_COMPILE_DIR").map(PathBuf::from).unwrap_or_else(
            |_| {
                let candidates =
                    [manifest_dir.join("../../python"), manifest_dir.join("../python")];
                candidates
                    .iter()
                    .find(|p| p.join("compile").join("aot.py").exists())
                    .cloned()
                    .unwrap_or_else(|| candidates[0].clone())
            },
        );
        let python = std::env::var("MBS_PYTHON").unwrap_or_else(|_| "python3".into());
        PythonAotCompiler::new(python, compile_dir, scratch_dir)
    }

    /// Override the wall-clock compile budget.
    pub fn with_timeout(mut self, timeout: Duration) -> PythonAotCompiler {
        self.timeout = timeout;
        self
    }

    /// Wait for `child` up to the timeout, killing it on expiry.
    fn wait_with_timeout(
        &self,
        mut child: std::process::Child,
        key: &VariantKey,
    ) -> Result<std::process::ExitStatus> {
        let start = Instant::now();
        loop {
            if let Some(status) = child.try_wait()? {
                return Ok(status);
            }
            if start.elapsed() >= self.timeout {
                child.kill().ok();
                child.wait().ok();
                return Err(MbsError::CompileTimeout {
                    key: key.canonical(),
                    waited_ms: start.elapsed().as_millis() as u64,
                });
            }
            std::thread::sleep(Duration::from_millis(50));
        }
    }
}

impl CompilerBackend for PythonAotCompiler {
    fn compile(&self, key: &VariantKey) -> Result<CompiledArtifact> {
        let scratch = self.scratch_dir.join(format!(
            "pyaot-{}-{:016x}",
            std::process::id(),
            key.digest(0)
        ));
        std::fs::create_dir_all(&scratch)?;
        let run = || -> Result<CompiledArtifact> {
            let mut child = std::process::Command::new(&self.python)
                .args(["-m", "compile.aot", "--quiet", "--variant"])
                .arg(format!("{}:{}:{}", key.model, key.size, key.mu))
                .arg("--out-dir")
                .arg(&scratch)
                .current_dir(&self.compile_dir)
                .stdout(std::process::Stdio::null())
                .stderr(std::process::Stdio::piped())
                .spawn()
                .map_err(|e| MbsError::Compile {
                    key: key.canonical(),
                    reason: format!("cannot spawn {}: {e}", self.python),
                })?;
            // drain stderr on a thread so a chatty exporter can't fill the
            // pipe and deadlock against the wait loop
            let reader = child.stderr.take().map(|mut pipe| {
                std::thread::spawn(move || {
                    use std::io::Read;
                    let mut buf = String::new();
                    pipe.read_to_string(&mut buf).ok();
                    buf
                })
            });
            let status = self.wait_with_timeout(child, key)?;
            let err_text = reader.and_then(|r| r.join().ok()).unwrap_or_default();
            if !status.success() {
                let tail: Vec<&str> = err_text.lines().rev().take(5).collect();
                let tail = tail.into_iter().rev().collect::<Vec<_>>().join(" | ");
                return Err(MbsError::Compile {
                    key: key.canonical(),
                    reason: format!("exporter exited with {status}: {tail}"),
                });
            }
            let tag = format!("{}_s{}_mu{}", key.model, key.size, key.mu);
            let read = |suffix: &str| -> Result<Vec<u8>> {
                let path = scratch.join(format!("{tag}.{suffix}.hlo.txt"));
                std::fs::read(&path).map_err(|e| MbsError::Compile {
                    key: key.canonical(),
                    reason: format!("exporter produced no {}: {e}", path.display()),
                })
            };
            Ok(CompiledArtifact { accum_hlo: read("accum")?, eval_hlo: read("eval")? })
        };
        let out = run();
        std::fs::remove_dir_all(&scratch).ok();
        out
    }

    fn name(&self) -> &'static str {
        "python-aot"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::faults::FaultPlan;

    fn key(mu: usize) -> VariantKey {
        VariantKey { model: "microresnet18".into(), size: 16, mu, overlap: false }
    }

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("mbs-art-{tag}-{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        dir
    }

    fn mock_manager(tag: &str, max_entries: usize) -> (ArtifactManager, Arc<MockCompiler>) {
        let backend = Arc::new(MockCompiler::new());
        let mgr = ArtifactManager::new(tmp_dir(tag), backend.clone(), max_entries).unwrap();
        (mgr, backend)
    }

    #[test]
    fn canonical_key_and_digest_are_stable() {
        let k = key(8);
        assert_eq!(k.canonical(), "microresnet18:s16:mu8:serial");
        assert_eq!(k.digest(7), k.digest(7));
        assert_ne!(k.digest(7), k.digest(8), "manifest fingerprint is part of the address");
        assert_ne!(
            k.digest(7),
            VariantKey { overlap: true, ..k.clone() }.digest(7),
            "overlap flag is part of the address"
        );
    }

    #[test]
    fn miss_compiles_then_hits_from_disk() {
        let (mgr, backend) = mock_manager("hit", 8);
        let h1 = mgr.fetch(&key(8), 1).unwrap();
        assert_eq!(backend.compiles(), 1);
        assert!(h1.accum_path.exists() && h1.eval_path.exists());
        let h2 = mgr.fetch(&key(8), 1).unwrap();
        assert_eq!(backend.compiles(), 1, "second fetch must be a cache hit");
        assert_eq!(h1.accum_hlo, h2.accum_hlo);
        assert_eq!(h1.eval_hlo, h2.eval_hlo);
        assert_eq!(*h1.accum_hlo, MockCompiler::render(&key(8), "accum"));
        let stats = mgr.stats();
        assert_eq!((stats.compiles, stats.hits), (1, 1));
        std::fs::remove_dir_all(mgr.dir()).ok();
    }

    #[test]
    fn lru_bound_evicts_oldest_and_recompiles() {
        let (mgr, backend) = mock_manager("lru", 2);
        mgr.fetch(&key(1), 1).unwrap();
        mgr.fetch(&key(2), 1).unwrap();
        mgr.fetch(&key(1), 1).unwrap(); // touch: mu=1 is now most recent
        mgr.fetch(&key(4), 1).unwrap(); // evicts mu=2, the LRU entry
        assert_eq!(mgr.cached_entries(), 2);
        assert_eq!(mgr.stats().evictions, 1);
        let before = backend.compiles();
        mgr.fetch(&key(1), 1).unwrap();
        assert_eq!(backend.compiles(), before, "mu=1 must have survived");
        mgr.fetch(&key(2), 1).unwrap();
        assert_eq!(backend.compiles(), before + 1, "mu=2 was evicted, recompiles");
        std::fs::remove_dir_all(mgr.dir()).ok();
    }

    #[test]
    fn manager_adopts_entries_from_a_previous_process() {
        let dir = tmp_dir("adopt");
        let backend = Arc::new(MockCompiler::new());
        {
            let mgr = ArtifactManager::new(&dir, backend.clone(), 8).unwrap();
            mgr.fetch(&key(8), 1).unwrap();
        }
        let mgr = ArtifactManager::new(&dir, backend.clone(), 8).unwrap();
        assert_eq!(mgr.cached_entries(), 1, "previous process's entry adopted");
        mgr.fetch(&key(8), 1).unwrap();
        assert_eq!(backend.compiles(), 1, "adopted entry serves the hit");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn injected_compile_failure_is_structured_and_retryable() {
        let plan = FaultPlan::parse(
            r#"{"faults": [{"job": "compiler", "kind": "compile", "at-step": 0}]}"#,
        )
        .unwrap();
        let backend = Arc::new(MockCompiler::new().with_faults(plan.hooks_for("compiler")));
        let mgr = ArtifactManager::new(tmp_dir("fault"), backend.clone(), 8).unwrap();
        let err = mgr.fetch(&key(8), 1).unwrap_err();
        assert!(
            matches!(err, MbsError::Compile { .. }),
            "want structured compile error, got {err:?}"
        );
        assert!(!err.recoverable(), "a failed compile is deterministic");
        assert_eq!(mgr.stats().compile_errors, 1);
        // the fault budget is spent: a fresh fetch retries and succeeds
        mgr.fetch(&key(8), 1).unwrap();
        assert_eq!(backend.compiles(), 2);
        std::fs::remove_dir_all(mgr.dir()).ok();
    }

    #[test]
    fn timeout_error_is_recoverable() {
        let err = MbsError::CompileTimeout { key: key(8).canonical(), waited_ms: 5 };
        assert!(err.recoverable(), "a stuck backend may succeed on retry");
        assert!(err.to_string().contains("compile timeout"));
    }

    #[test]
    fn python_backend_times_out_and_kills() {
        // `sleep` stands in for a wedged exporter: spawn succeeds, the
        // deadline passes, the child is killed, and the structured
        // timeout error names the variant
        let scratch = tmp_dir("timeout");
        let backend = PythonAotCompiler::new("sleep", "/tmp", &scratch)
            .with_timeout(Duration::from_millis(100));
        // "sleep -m compile.aot ..." exits immediately with a usage error
        // on some systems; accept either structured outcome, never a hang
        let t0 = Instant::now();
        let out = backend.compile(&key(8));
        assert!(t0.elapsed() < Duration::from_secs(30));
        match out {
            Err(MbsError::CompileTimeout { key: k, .. }) => {
                assert!(k.contains("microresnet18"));
            }
            Err(MbsError::Compile { .. }) => {}
            other => panic!("want a structured compile/timeout error, got {other:?}"),
        }
        std::fs::remove_dir_all(&scratch).ok();
    }
}
