//! Host <-> device transfer helpers around the `xla` crate.

use crate::data::Buf;
use crate::error::{MbsError, Result};

/// Upload a flat f32 host slice as a device buffer with `dims`.
pub fn upload_f32(
    client: &xla::PjRtClient,
    data: &[f32],
    dims: &[usize],
) -> Result<xla::PjRtBuffer> {
    Ok(client.buffer_from_host_buffer(data, dims, None)?)
}

/// Upload a flat i32 host slice as a device buffer with `dims`.
pub fn upload_i32(
    client: &xla::PjRtClient,
    data: &[i32],
    dims: &[usize],
) -> Result<xla::PjRtBuffer> {
    Ok(client.buffer_from_host_buffer(data, dims, None)?)
}

/// Upload either flavour of [`Buf`].
pub fn upload_buf(
    client: &xla::PjRtClient,
    data: &Buf,
    dims: &[usize],
) -> Result<xla::PjRtBuffer> {
    match data {
        Buf::F32(v) => upload_f32(client, v, dims),
        Buf::I32(v) => upload_i32(client, v, dims),
    }
}

/// Download a device buffer to a host f32 vector (blocking).
///
/// Goes through `to_literal_sync` + `to_vec` — this PJRT build (TFRT CPU,
/// xla_extension 0.5.1) does not implement `CopyRawToHost`.
pub fn download_f32(buf: &xla::PjRtBuffer, elems: usize) -> Result<Vec<f32>> {
    let lit = buf.to_literal_sync()?;
    let v = lit.to_vec::<f32>()?;
    if v.len() != elems {
        return Err(MbsError::Runtime(format!(
            "downloaded {} elements, expected {elems}",
            v.len()
        )));
    }
    Ok(v)
}

/// Download a rank-0 or single-element buffer as a scalar.
pub fn download_scalar(buf: &xla::PjRtBuffer) -> Result<f32> {
    let v = download_f32(buf, 1)?;
    v.first().copied().ok_or_else(|| MbsError::Runtime("empty scalar buffer".into()))
}

/// Element count of a device buffer from its on-device shape.
pub fn element_count(buf: &xla::PjRtBuffer) -> Result<usize> {
    let shape = buf.on_device_shape()?;
    let arr = xla::ArrayShape::try_from(&shape)
        .map_err(|e| MbsError::Runtime(format!("non-array buffer shape: {e}")))?;
    Ok(arr.element_count())
}
