//! Wall-clock watchdog: deadlines on every blocking surface.
//!
//! The recovery state machine (`coordinator/trainer.rs`) survives
//! *failures* — a step that errors, an arena claim that OOMs, a lane
//! completion that reports a fault. What it could not survive before
//! this module is a *hang*: a lane `recv` that never returns, a wedged
//! micro-step, a checkpoint write stuck in the filesystem. A hung
//! tenant holds its arena reservation forever and deadlocks every
//! co-resident job.
//!
//! The watchdog converts hangs into faults. Each blocking surface gets
//! a wall-clock deadline ([`Deadlines`]); when a surface's elapsed time
//! exceeds its deadline, the caller receives a *recoverable*
//! [`MbsError::Deadline`] instead of blocking forever. From there the
//! ordinary quiesce → release → re-plan → replay machinery takes over:
//! the tenant is recovered from its phase-start snapshot, or — after
//! retry exhaustion — cleanly evicted with its reservation released.
//!
//! Two enforcement styles, by surface shape:
//!
//! * **Pre-emptive** — the upload lane's `recv` is a channel wait, so
//!   the deadline is enforced *inside* the wait
//!   ([`UploadLane::recv_deadline`](crate::runtime::upload_lane::UploadLane::recv_deadline)):
//!   the caller genuinely unblocks when the deadline expires, even if
//!   the worker thread is wedged.
//! * **Post-hoc** — micro-step execute, compile fetch, and checkpoint
//!   save/load run on the caller's own thread, so the watchdog measures
//!   the elapsed wall clock around the call ([`Watchdog::observe`]) and
//!   converts an over-deadline completion into the same fault. A
//!   genuinely-never-returning device call cannot be interrupted from
//!   safe Rust; what this catches is the realistic failure shape — a
//!   stall that eventually returns (page-cache pressure, a loaded
//!   machine, an injected delay) — while keeping the enforcement
//!   deterministic and thread-free.
//!
//! Defaults are generous (minutes): production runs should never trip
//! them. Chaos sweeps (`mbs chaos`) shrink them via the fault plan's
//! `watchdog` object so injected stalls trip the deadline in
//! milliseconds, proving the conversion end-to-end.

use std::time::Duration;

use crate::error::MbsError;

/// A watched blocking surface. Every place the executor can block on
/// something outside its own control is enumerated here.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Surface {
    /// The upload lane's `done.recv()` — waiting for the staging thread
    /// to hand back a staged batch.
    LaneRecv,
    /// One micro-step execute on the device.
    Step,
    /// A compile/artifact fetch through `Engine::resolve_variant`.
    Compile,
    /// Writing a phase-start snapshot or user checkpoint.
    CheckpointSave,
    /// Reading + validating + restoring a checkpoint.
    CheckpointLoad,
}

impl Surface {
    /// Stable surface name used in [`MbsError::Deadline`] and chaos
    /// reports.
    pub fn name(self) -> &'static str {
        match self {
            Surface::LaneRecv => "lane-recv",
            Surface::Step => "step",
            Surface::Compile => "compile",
            Surface::CheckpointSave => "checkpoint-save",
            Surface::CheckpointLoad => "checkpoint-load",
        }
    }
}

/// Per-surface wall-clock deadlines. Save and load share the
/// `checkpoint` budget — both are bounded file-IO over the same pair of
/// files.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Deadlines {
    /// Deadline for one upload-lane `recv` ([`Surface::LaneRecv`]).
    pub lane_recv: Duration,
    /// Deadline for one micro-step execute ([`Surface::Step`]).
    pub step: Duration,
    /// Deadline for one variant resolve ([`Surface::Compile`]) — has to
    /// cover a cold AOT compile, so it is the largest default.
    pub compile: Duration,
    /// Deadline for one checkpoint save or load
    /// ([`Surface::CheckpointSave`] / [`Surface::CheckpointLoad`]).
    pub checkpoint: Duration,
}

impl Default for Deadlines {
    /// Generous production defaults: a healthy run never comes near
    /// them, so the watchdog is always-on without a flag.
    fn default() -> Self {
        Deadlines {
            lane_recv: Duration::from_secs(120),
            step: Duration::from_secs(600),
            compile: Duration::from_secs(1800),
            checkpoint: Duration::from_secs(300),
        }
    }
}

impl Deadlines {
    /// Uniform deadlines across every surface — what `mbs chaos` uses
    /// to make injected stalls trip in milliseconds.
    pub fn uniform(d: Duration) -> Self {
        Deadlines { lane_recv: d, step: d, compile: d, checkpoint: d }
    }

    /// The deadline governing `surface`.
    pub fn for_surface(&self, surface: Surface) -> Duration {
        match surface {
            Surface::LaneRecv => self.lane_recv,
            Surface::Step => self.step,
            Surface::Compile => self.compile,
            Surface::CheckpointSave | Surface::CheckpointLoad => self.checkpoint,
        }
    }
}

/// The watchdog itself: a [`Deadlines`] table plus the conversion from
/// an expired wait into the recoverable [`MbsError::Deadline`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Watchdog {
    deadlines: Deadlines,
}

impl Watchdog {
    /// A watchdog enforcing `deadlines`.
    pub fn new(deadlines: Deadlines) -> Self {
        Watchdog { deadlines }
    }

    /// The deadline governing `surface`.
    pub fn deadline(&self, surface: Surface) -> Duration {
        self.deadlines.for_surface(surface)
    }

    /// Build the recoverable deadline fault for an expired wait on
    /// `surface` after `elapsed` of wall clock.
    pub fn expired(&self, surface: Surface, elapsed: Duration) -> MbsError {
        MbsError::Deadline {
            surface: surface.name().to_string(),
            elapsed_ms: elapsed.as_millis() as u64,
        }
    }

    /// Post-hoc check: `Ok(())` when `elapsed` is within `surface`'s
    /// deadline, the recoverable deadline fault otherwise. Used around
    /// same-thread blocking calls (step execute, compile, checkpoint
    /// IO) where the wait cannot be pre-empted.
    pub fn observe(&self, surface: Surface, elapsed: Duration) -> Result<(), MbsError> {
        if elapsed > self.deadline(surface) {
            Err(self.expired(surface, elapsed))
        } else {
            Ok(())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn surface_names_are_stable() {
        assert_eq!(Surface::LaneRecv.name(), "lane-recv");
        assert_eq!(Surface::Step.name(), "step");
        assert_eq!(Surface::Compile.name(), "compile");
        assert_eq!(Surface::CheckpointSave.name(), "checkpoint-save");
        assert_eq!(Surface::CheckpointLoad.name(), "checkpoint-load");
    }

    #[test]
    fn default_deadlines_are_generous_and_surface_mapped() {
        let d = Deadlines::default();
        assert!(d.lane_recv >= Duration::from_secs(60));
        assert!(d.compile >= d.step);
        assert_eq!(d.for_surface(Surface::CheckpointSave), d.checkpoint);
        assert_eq!(d.for_surface(Surface::CheckpointLoad), d.checkpoint);
        assert_eq!(d.for_surface(Surface::LaneRecv), d.lane_recv);
    }

    #[test]
    fn observe_converts_expiry_into_recoverable_deadline_fault() {
        let wd = Watchdog::new(Deadlines::uniform(Duration::from_millis(10)));
        assert!(wd.observe(Surface::Step, Duration::from_millis(5)).is_ok());
        let err = wd
            .observe(Surface::Step, Duration::from_millis(25))
            .expect_err("25ms > 10ms deadline must expire");
        assert!(err.recoverable(), "deadline faults must be recoverable: {err}");
        match err {
            MbsError::Deadline { surface, elapsed_ms } => {
                assert_eq!(surface, "step");
                assert_eq!(elapsed_ms, 25);
            }
            other => panic!("expected Deadline, got {other}"),
        }
    }

    #[test]
    fn uniform_deadlines_cover_every_surface() {
        let wd = Watchdog::new(Deadlines::uniform(Duration::from_millis(7)));
        for s in [
            Surface::LaneRecv,
            Surface::Step,
            Surface::Compile,
            Surface::CheckpointSave,
            Surface::CheckpointLoad,
        ] {
            assert_eq!(wd.deadline(s), Duration::from_millis(7));
            assert!(wd.observe(s, Duration::from_millis(8)).is_err());
        }
    }
}
