//! Checkpointing: save / restore the full training state (params +
//! optimizer slots + update counter) so long MBS runs can resume — and so
//! the recovery state machine ([`crate::coordinator::trainer`]) can
//! replay a faulted job from its last update boundary.
//!
//! Format: `<path>.bin` — little-endian f32 leaves in manifest order,
//! params first, then each optimizer slot; `<path>.json` — metadata
//! (model, leaf count, update counter, FNV-1a payload checksum, magic)
//! validated on load.
//!
//! Crash safety: both files are written to a `.tmp` sibling and renamed
//! into place (bin first, then the metadata that vouches for it), so a
//! crash mid-save leaves either the previous checkpoint intact or a
//! `.tmp` orphan — never a metadata file pointing at a half-written
//! payload. The checksum catches the remaining corruption modes (partial
//! storage writes, bit flips): a corrupt or truncated checkpoint fails
//! with a structured [`MbsError::Runtime`] instead of restoring garbage
//! parameters.

use std::path::Path;

use crate::error::{MbsError, Result};
use crate::manifest::ModelEntry;
use crate::util::hash::fnv1a64;
use crate::util::json::Json;

use super::buffers;
use super::model::ModelRuntime;

const MAGIC: &str = "mbs-checkpoint-v1";

/// Validated checkpoint metadata (the pure part of
/// [`ModelRuntime::load_checkpoint`], split out so the error paths are
/// testable without artifacts or a device).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CheckpointMeta {
    /// Optimizer update counter at save time.
    pub updates: u64,
    /// Optimizer slot groups in the payload (after the params group).
    pub n_slots: usize,
}

/// Render the metadata JSON for a checkpoint payload.
fn render_meta(
    entry_name: &str,
    n_leaves: usize,
    n_slots: usize,
    updates: u64,
    bin: &[u8],
) -> String {
    format!(
        "{{\"magic\": \"{MAGIC}\", \"model\": \"{entry_name}\", \"n_leaves\": {n_leaves}, \
         \"slots\": {n_slots}, \"updates\": {updates}, \"bytes\": {}, \"checksum\": \"{:016x}\"}}",
        bin.len(),
        fnv1a64(bin)
    )
}

/// Validate checkpoint metadata + payload against a manifest entry:
/// magic, model identity, optimizer slot count, byte length (both the
/// recorded and the entry-derived expectation), and the FNV-1a payload
/// checksum. Every failure is a structured [`MbsError::Runtime`].
pub fn validate_checkpoint(
    meta_text: &str,
    bin: &[u8],
    entry: &ModelEntry,
) -> Result<CheckpointMeta> {
    let meta = Json::parse(meta_text)
        .map_err(|e| MbsError::Runtime(format!("checkpoint metadata: {e}")))?;
    let get_str = |k: &str| meta.get(k).and_then(Json::as_str).unwrap_or_default().to_string();
    let get_u64 = |k: &str| meta.get(k).and_then(Json::as_u64).unwrap_or(0);
    if get_str("magic") != MAGIC {
        return Err(MbsError::Runtime("not an mbs checkpoint".into()));
    }
    if get_str("model") != entry.name {
        return Err(MbsError::Runtime(format!(
            "checkpoint is for model '{}', runtime is '{}'",
            get_str("model"),
            entry.name
        )));
    }
    let n_slots = get_u64("slots") as usize;
    if n_slots != entry.optimizer.slots {
        return Err(MbsError::Runtime("optimizer slot count mismatch".into()));
    }
    let expected = (1 + n_slots) as u64 * entry.param_bytes;
    if bin.len() as u64 != expected || get_u64("bytes") != bin.len() as u64 {
        return Err(MbsError::Runtime(format!(
            "checkpoint is {} bytes, expected {expected}",
            bin.len()
        )));
    }
    let recorded = get_str("checksum");
    let recorded = u64::from_str_radix(&recorded, 16).map_err(|_| {
        MbsError::Runtime(format!(
            "checkpoint metadata checksum '{recorded}' is missing or malformed"
        ))
    })?;
    let actual = fnv1a64(bin);
    if recorded != actual {
        return Err(MbsError::Runtime(format!(
            "checkpoint payload checksum mismatch: metadata says {recorded:016x}, \
             payload hashes to {actual:016x} (corrupt or truncated checkpoint)"
        )));
    }
    Ok(CheckpointMeta { updates: get_u64("updates"), n_slots })
}

/// Read the checkpoint pair at `path` (`<path>.json` + `<path>.bin`)
/// and validate it against `entry` ([`validate_checkpoint`]): the pure
/// file half of [`ModelRuntime::load_checkpoint`], split out so corrupt,
/// truncated, and torn `--resume` checkpoints are testable — structured
/// errors, never panics — without artifacts or a device. Missing files
/// surface as [`MbsError::Io`]; every validation failure as
/// [`MbsError::Runtime`].
pub fn read_and_validate(path: &Path, entry: &ModelEntry) -> Result<(CheckpointMeta, Vec<u8>)> {
    let meta_text = std::fs::read_to_string(path.with_extension("json"))?;
    let bin = std::fs::read(path.with_extension("bin"))?;
    let meta = validate_checkpoint(&meta_text, &bin, entry)?;
    Ok((meta, bin))
}

/// Write `bytes` to `<final>.tmp` then rename into place — the
/// crash-safety primitive both checkpoint files go through.
fn write_atomic(final_path: &Path, bytes: &[u8]) -> Result<()> {
    let mut tmp = final_path.as_os_str().to_owned();
    tmp.push(".tmp");
    let tmp = std::path::PathBuf::from(tmp);
    std::fs::write(&tmp, bytes)?;
    std::fs::rename(&tmp, final_path)?;
    Ok(())
}

impl ModelRuntime {
    /// Serialize params + optimizer slots to `<path>.bin` / `<path>.json`.
    /// Each file lands via write-tmp-then-rename; the payload checksum in
    /// the metadata is validated on load.
    pub fn save_checkpoint(&self, path: &Path) -> Result<()> {
        let params = self.params_to_host()?;
        let slots = self.slots_to_host()?;
        let mut bin: Vec<u8> = Vec::new();
        for group in std::iter::once(&params).chain(slots.iter()) {
            for leaf in group {
                for v in leaf {
                    bin.extend_from_slice(&v.to_le_bytes());
                }
            }
        }
        // payload first, then the metadata that vouches for it: a crash
        // between the two renames leaves a stale-metadata window only if
        // an older checkpoint existed, and its checksum then refers to the
        // old payload — caught on load, never silently restored
        write_atomic(&path.with_extension("bin"), &bin)?;
        let meta = render_meta(&self.entry.name, self.n_leaves(), slots.len(), self.updates, &bin);
        write_atomic(&path.with_extension("json"), meta.as_bytes())?;
        Ok(())
    }

    /// Restore a checkpoint written by [`save_checkpoint`]; validates
    /// model identity, sizes, and the payload checksum
    /// ([`validate_checkpoint`]). The gradient accumulator is reset to
    /// zero (a checkpoint boundary is always an update boundary).
    pub fn load_checkpoint(&mut self, path: &Path) -> Result<()> {
        let (meta, bin) = read_and_validate(path, &self.entry)?;

        let client = self.client().clone();
        let mut offset = 0usize;
        let read_group = |offset: &mut usize| -> Result<Vec<xla::PjRtBuffer>> {
            self.entry
                .param_leaves
                .iter()
                .map(|leaf| {
                    let mut host = Vec::with_capacity(leaf.elems);
                    for i in 0..leaf.elems {
                        let b = *offset + i * 4;
                        host.push(f32::from_le_bytes([
                            bin[b],
                            bin[b + 1],
                            bin[b + 2],
                            bin[b + 3],
                        ]));
                    }
                    *offset += leaf.elems * 4;
                    let dims = if leaf.shape.is_empty() { vec![1] } else { leaf.shape.clone() };
                    buffers::upload_f32(&client, &host, &dims)
                })
                .collect()
        };
        let params = read_group(&mut offset)?;
        let mut slots = Vec::with_capacity(meta.n_slots);
        for _ in 0..meta.n_slots {
            slots.push(read_group(&mut offset)?);
        }
        self.restore_state(params, slots, meta.updates);
        self.zero_acc()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    // the device-facing round trip is exercised end-to-end by
    // rust/tests/checkpoint.rs and the checkpoint/resume tests in
    // rust/tests/recovery.rs (both need artifacts); the validation error
    // paths below run everywhere via a synthetic manifest entry
    use super::*;
    use crate::coordinator::frontier::synthetic_entry;

    fn entry() -> ModelEntry {
        synthetic_entry("classification").unwrap()
    }

    /// A well-formed (meta, bin) pair for the synthetic entry.
    fn good_pair(entry: &ModelEntry) -> (String, Vec<u8>) {
        let n_slots = entry.optimizer.slots;
        let bin = vec![0u8; ((1 + n_slots) as u64 * entry.param_bytes) as usize];
        let meta = render_meta(&entry.name, entry.param_leaves.len(), n_slots, 42, &bin);
        (meta, bin)
    }

    #[test]
    fn valid_pair_passes_and_reports_updates() {
        let entry = entry();
        let (meta, bin) = good_pair(&entry);
        let ok = validate_checkpoint(&meta, &bin, &entry).unwrap();
        assert_eq!(ok.updates, 42);
        assert_eq!(ok.n_slots, entry.optimizer.slots);
    }

    #[test]
    fn magic_mismatch_rejected() {
        let entry = entry();
        let (_, bin) = good_pair(&entry);
        let err = validate_checkpoint(r#"{"magic": "nope"}"#, &bin, &entry).unwrap_err();
        assert!(err.to_string().contains("not an mbs checkpoint"), "{err}");
        // unparseable metadata is structured too, not a panic
        assert!(validate_checkpoint("not json", &bin, &entry).is_err());
    }

    #[test]
    fn model_mismatch_rejected() {
        let entry = entry();
        let (meta, bin) = good_pair(&entry);
        let wrong = meta.replace(&format!("\"model\": \"{}\"", entry.name), "\"model\": \"other\"");
        let err = validate_checkpoint(&wrong, &bin, &entry).unwrap_err();
        assert!(err.to_string().contains("for model 'other'"), "{err}");
    }

    #[test]
    fn slot_count_mismatch_rejected() {
        let entry = entry();
        let (meta, bin) = good_pair(&entry);
        let wrong = meta.replace(
            &format!("\"slots\": {}", entry.optimizer.slots),
            &format!("\"slots\": {}", entry.optimizer.slots + 1),
        );
        let err = validate_checkpoint(&wrong, &bin, &entry).unwrap_err();
        assert!(err.to_string().contains("slot count mismatch"), "{err}");
    }

    #[test]
    fn truncated_payload_rejected_by_length() {
        let entry = entry();
        let (meta, bin) = good_pair(&entry);
        let err = validate_checkpoint(&meta, &bin[..bin.len() / 2], &entry).unwrap_err();
        assert!(err.to_string().contains("bytes, expected"), "{err}");
    }

    #[test]
    fn corrupted_payload_rejected_by_checksum() {
        let entry = entry();
        let (meta, mut bin) = good_pair(&entry);
        // same length, one flipped bit: only the checksum can catch this
        bin[17] ^= 0x40;
        let err = validate_checkpoint(&meta, &bin, &entry).unwrap_err();
        assert!(err.to_string().contains("checksum mismatch"), "{err}");
    }

    #[test]
    fn missing_checksum_rejected() {
        let entry = entry();
        let n_slots = entry.optimizer.slots;
        let bin = vec![0u8; ((1 + n_slots) as u64 * entry.param_bytes) as usize];
        // a pre-checksum metadata shape (no "checksum" key at all)
        let legacy = format!(
            "{{\"magic\": \"{MAGIC}\", \"model\": \"{}\", \"n_leaves\": {}, \
             \"slots\": {n_slots}, \"updates\": 7, \"bytes\": {}}}",
            entry.name,
            entry.param_leaves.len(),
            bin.len()
        );
        let err = validate_checkpoint(&legacy, &bin, &entry).unwrap_err();
        assert!(err.to_string().contains("missing or malformed"), "{err}");
    }

    /// Write a `(meta, bin)` pair to disk as `<stem>.json`/`<stem>.bin`
    /// under a unique temp stem, returning the stem path.
    fn write_pair(tag: &str, meta: &str, bin: &[u8]) -> std::path::PathBuf {
        let stem = std::env::temp_dir()
            .join(format!("mbs-ckpt-file-{tag}-{}", std::process::id()));
        std::fs::write(stem.with_extension("json"), meta).unwrap();
        std::fs::write(stem.with_extension("bin"), bin).unwrap();
        stem
    }

    fn cleanup(stem: &Path) {
        std::fs::remove_file(stem.with_extension("json")).ok();
        std::fs::remove_file(stem.with_extension("bin")).ok();
    }

    #[test]
    fn read_and_validate_round_trips_a_good_pair() {
        let entry = entry();
        let (meta, bin) = good_pair(&entry);
        let stem = write_pair("good", &meta, &bin);
        let (ok, read_bin) = read_and_validate(&stem, &entry).unwrap();
        assert_eq!(ok.updates, 42);
        assert_eq!(read_bin, bin);
        cleanup(&stem);
    }

    #[test]
    fn bad_magic_on_disk_is_a_structured_error_not_a_panic() {
        let entry = entry();
        let (_, bin) = good_pair(&entry);
        let stem = write_pair("magic", r#"{"magic": "nope"}"#, &bin);
        let err = read_and_validate(&stem, &entry).unwrap_err();
        assert!(matches!(err, MbsError::Runtime(_)), "{err:?}");
        assert!(err.to_string().contains("not an mbs checkpoint"), "{err}");
        cleanup(&stem);
    }

    #[test]
    fn flipped_payload_byte_on_disk_fails_the_checksum() {
        let entry = entry();
        let (meta, mut bin) = good_pair(&entry);
        bin[9] ^= 0x08; // same length, one flipped bit
        let stem = write_pair("corrupt", &meta, &bin);
        let err = read_and_validate(&stem, &entry).unwrap_err();
        assert!(err.to_string().contains("checksum mismatch"), "{err}");
        cleanup(&stem);
    }

    #[test]
    fn torn_metadata_on_disk_is_a_structured_error() {
        let entry = entry();
        let (meta, bin) = good_pair(&entry);
        // a torn write: the metadata JSON is cut mid-document
        let stem = write_pair("torn", &meta[..meta.len() / 2], &bin);
        let err = read_and_validate(&stem, &entry).unwrap_err();
        assert!(matches!(err, MbsError::Runtime(_)), "{err:?}");
        cleanup(&stem);
    }

    #[test]
    fn truncated_payload_on_disk_is_rejected_by_length() {
        let entry = entry();
        let (meta, bin) = good_pair(&entry);
        let stem = write_pair("trunc", &meta, &bin[..bin.len() - 7]);
        let err = read_and_validate(&stem, &entry).unwrap_err();
        assert!(err.to_string().contains("bytes, expected"), "{err}");
        cleanup(&stem);
    }

    #[test]
    fn missing_files_surface_as_io_errors() {
        let entry = entry();
        let stem = std::env::temp_dir()
            .join(format!("mbs-ckpt-file-missing-{}", std::process::id()));
        let err = read_and_validate(&stem, &entry).unwrap_err();
        assert!(matches!(err, MbsError::Io(_)), "{err:?}");
    }

    #[test]
    fn write_atomic_replaces_and_leaves_no_tmp() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("mbs-ckpt-atomic-{}.bin", std::process::id()));
        write_atomic(&path, b"first").unwrap();
        write_atomic(&path, b"second").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"second");
        let mut tmp = path.as_os_str().to_owned();
        tmp.push(".tmp");
        assert!(!std::path::Path::new(&tmp).exists(), "tmp must be renamed away");
        std::fs::remove_file(&path).ok();
    }
}
