//! Checkpointing: save / restore the full training state (params +
//! optimizer slots + update counter) so long MBS runs can resume.
//!
//! Format: `<path>.bin` — little-endian f32 leaves in manifest order,
//! params first, then each optimizer slot; `<path>.json` — metadata
//! (model, leaf count, update counter, magic) validated on load.

use std::path::Path;

use crate::error::{MbsError, Result};
use crate::util::json::Json;

use super::buffers;
use super::model::ModelRuntime;

const MAGIC: &str = "mbs-checkpoint-v1";

impl ModelRuntime {
    /// Serialize params + optimizer slots to `<path>.bin` / `<path>.json`.
    pub fn save_checkpoint(&self, path: &Path) -> Result<()> {
        let params = self.params_to_host()?;
        let slots = self.slots_to_host()?;
        let mut bin: Vec<u8> = Vec::new();
        for group in std::iter::once(&params).chain(slots.iter()) {
            for leaf in group {
                for v in leaf {
                    bin.extend_from_slice(&v.to_le_bytes());
                }
            }
        }
        std::fs::write(path.with_extension("bin"), &bin)?;
        let meta = format!(
            "{{\"magic\": \"{MAGIC}\", \"model\": \"{}\", \"n_leaves\": {}, \"slots\": {}, \"updates\": {}, \"bytes\": {}}}",
            self.entry.name,
            self.n_leaves(),
            slots.len(),
            self.updates,
            bin.len()
        );
        std::fs::write(path.with_extension("json"), meta)?;
        Ok(())
    }

    /// Restore a checkpoint written by [`save_checkpoint`]; validates model
    /// identity and sizes. The gradient accumulator is reset to zero (a
    /// checkpoint boundary is always an update boundary).
    pub fn load_checkpoint(&mut self, path: &Path) -> Result<()> {
        let meta_text = std::fs::read_to_string(path.with_extension("json"))?;
        let meta = Json::parse(&meta_text)?;
        let get_str = |k: &str| meta.get(k).and_then(Json::as_str).unwrap_or_default().to_string();
        let get_u64 = |k: &str| meta.get(k).and_then(Json::as_u64).unwrap_or(0);
        if get_str("magic") != MAGIC {
            return Err(MbsError::Runtime("not an mbs checkpoint".into()));
        }
        if get_str("model") != self.entry.name {
            return Err(MbsError::Runtime(format!(
                "checkpoint is for model '{}', runtime is '{}'",
                get_str("model"),
                self.entry.name
            )));
        }
        let n_slots = get_u64("slots") as usize;
        if n_slots != self.entry.optimizer.slots {
            return Err(MbsError::Runtime("optimizer slot count mismatch".into()));
        }
        let bin = std::fs::read(path.with_extension("bin"))?;
        let expected = (1 + n_slots) as u64 * self.entry.param_bytes;
        if bin.len() as u64 != expected || get_u64("bytes") != bin.len() as u64 {
            return Err(MbsError::Runtime(format!(
                "checkpoint is {} bytes, expected {expected}",
                bin.len()
            )));
        }

        let client = self.client().clone();
        let mut offset = 0usize;
        let read_group = |offset: &mut usize| -> Result<Vec<xla::PjRtBuffer>> {
            self.entry
                .param_leaves
                .iter()
                .map(|leaf| {
                    let mut host = Vec::with_capacity(leaf.elems);
                    for i in 0..leaf.elems {
                        let b = *offset + i * 4;
                        host.push(f32::from_le_bytes([
                            bin[b],
                            bin[b + 1],
                            bin[b + 2],
                            bin[b + 3],
                        ]));
                    }
                    *offset += leaf.elems * 4;
                    let dims = if leaf.shape.is_empty() { vec![1] } else { leaf.shape.clone() };
                    buffers::upload_f32(&client, &host, &dims)
                })
                .collect()
        };
        let params = read_group(&mut offset)?;
        let mut slots = Vec::with_capacity(n_slots);
        for _ in 0..n_slots {
            slots.push(read_group(&mut offset)?);
        }
        self.restore_state(params, slots, get_u64("updates"));
        self.zero_acc()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    // exercised end-to-end in rust/tests/checkpoint.rs (needs artifacts)
}
