//! Dedicated upload-lane thread: the host-side half of `upload` runs off
//! the engine thread, genuinely concurrent with device execution.
//!
//! `PjRtClient` is `Rc`-backed (not `Send`), so the PJRT placement call
//! itself must stay on the engine thread (runtime/mod.rs design points).
//! What *can* leave that thread — and is what a CUDA-style async copy
//! engine spends its time on — is pinned staging: copying the assembled
//! micro-batch out of the streamer's pageable lease into a dedicated
//! upload-ready buffer, plus the shape/mask validation the placement would
//! otherwise do. [`UploadLane`] owns exactly that work on a worker thread
//! ("mbs-upload-lane"), fed by a bounded channel of leased
//! [`MicroBatchHost`] buffers and handing back [`StagedBatch`] completion
//! tokens. Two real effects follow:
//!
//!  * the streamer's lease returns to the [`BufPool`] the moment the copy
//!    finishes, so host assembly is never paced by device execution, and
//!  * each completion carries the `Instant` window the lane was busy in —
//!    the trainer intersects it with the engine's execute windows to
//!    measure `upload_concurrent`, the *wall-clock* (not structural)
//!    overlap that `wall_overlap_efficiency` reports.
//!
//! Safety contract (mirrors coordinator/streamer.rs): dropping the lane
//! disconnects the job channel first, the worker drains what is queued —
//! returning every leased buffer to the pool — and is then joined, so an
//! early epoch abort can neither hang nor leak a lease. A staging error
//! recycles the offending lease on the worker and reaches the consumer as
//! the `Err` of the completion that would have carried the slot — labeled
//! with the owning job's name (the same tenant-naming contract the arena
//! uses), so a multi-tenant failure names its tenant.
//!
//! Fault injection: a [`LaneJob`] may carry an injected staging fault
//! ([`crate::runtime::faults`]); the worker recycles the lease and reports
//! it like any staging error, but the consumer's `recv` maps it to the
//! *recoverable* [`MbsError::Fault`] — genuine staging errors stay
//! [`MbsError::Runtime`] (deterministic, fatal).
//!
//! Hang conversion: a [`LaneJob`] may also carry an injected *stall* — the
//! worker sleeps that long before touching the job, simulating a wedged
//! staging thread. Nothing errors on the worker side; instead the consumer
//! calls [`UploadLane::recv_deadline`] (the watchdog-governed wait,
//! `runtime/watchdog.rs`), which unblocks when the deadline expires and
//! surfaces the *recoverable* [`MbsError::Deadline`] — the arena reclaims
//! the tenant instead of freezing behind its `recv`. The worker's eventual
//! completion for the stalled job is consumed by the lane teardown drain
//! (recovery respawns the lane), so no lease leaks.

use std::sync::mpsc;
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use crate::data::{Buf, BufPool, MicroBatchHost};
use crate::error::{MbsError, Result};

/// One staging request: an assembled micro-batch leased from the shared
/// pool, plus the loss-normalization scale that travels with it.
#[derive(Debug)]
pub struct LaneJob {
    /// Submission sequence number, echoed in the completion (the lane is
    /// FIFO; this is the cross-check and the error-message anchor).
    pub seq: u64,
    /// The assembled micro-batch (pool lease; the lane returns it).
    pub mb: MicroBatchHost,
    /// Loss-normalization scale for this micro-batch (`None` for eval).
    pub scale: Option<f32>,
    /// Injected staging fault for this micro-batch (deterministic fault
    /// injection): the worker fails the job with this note instead of
    /// staging it, and `recv` surfaces a recoverable
    /// [`MbsError::Fault`]. `None` (the normal case) stages as usual.
    pub fault: Option<String>,
    /// Injected stall (deterministic hang injection): the worker sleeps
    /// this long before processing the job, simulating wedged staging.
    /// Not an error by itself — the consumer's
    /// [`UploadLane::recv_deadline`] converts the overdue wait into a
    /// recoverable [`MbsError::Deadline`]. `None` is the normal case.
    pub stall: Option<Duration>,
}

/// A staged micro-batch handed back by the lane, ready for the engine
/// thread's PJRT placement. The consumer gives `mb` back to the pool once
/// the upload is done — it is a pool lease like any other.
#[derive(Debug)]
pub struct StagedBatch {
    /// The submission's sequence number.
    pub seq: u64,
    /// The lane's upload-ready staging copy (byte-identical to the
    /// submitted micro-batch).
    pub mb: MicroBatchHost,
    /// The scale submitted with the job, passed through untouched.
    pub scale: Option<f32>,
    /// When the lane thread began staging this micro-batch.
    pub started: Instant,
    /// When the lane thread finished staging this micro-batch.
    pub finished: Instant,
}

/// What the worker sends back per job: the staged slot, or the staging
/// error that consumed it (the lease is already back in the pool).
#[derive(Debug)]
struct Completion {
    seq: u64,
    result: std::result::Result<StagedBatch, StagingError>,
}

/// A worker-side staging failure: the message plus whether it was an
/// injected fault (recoverable) or a genuine validation error (fatal).
#[derive(Debug)]
struct StagingError {
    msg: String,
    injected: bool,
}

/// Handle to the upload-lane worker thread. Submissions and completions
/// are FIFO over bounded channels of `depth`; dropping the handle shuts
/// the worker down cleanly (see module docs).
#[derive(Debug)]
pub struct UploadLane {
    /// `Some` until dropped; taken (disconnecting the worker) before the
    /// join in `Drop`.
    jobs: Option<mpsc::SyncSender<LaneJob>>,
    /// Completion channel; taken on drop so a worker parked on a full
    /// `send` errors out instead of deadlocking the join.
    done: Option<mpsc::Receiver<Completion>>,
    /// The worker thread, joined on drop.
    handle: Option<thread::JoinHandle<()>>,
    /// The shared staging pool (to recycle a job the worker never saw).
    pool: Arc<BufPool>,
    /// Owning job's name, prefixed onto every lane error (the tenant-
    /// naming contract the arena's OOM contexts follow).
    label: String,
}

impl UploadLane {
    /// Extra [`BufPool`] buffers one lane adds to a pipeline's working set
    /// beyond the streamer's own: up to `depth` originals parked in the
    /// job channel plus one being copied, and up to `depth` staging copies
    /// parked in the completion channel plus one held by the consumer.
    /// Warm (and retain) this many more to keep the hot path allocation-free.
    pub const fn extra_buffers(depth: usize) -> usize {
        2 * depth + 2
    }

    /// Spawn the lane worker over channels bounded at `depth` (clamped to
    /// at least 1). Staging copies are leased from — and every buffer is
    /// eventually returned to — `pool`. `label` names the owning job in
    /// every error this lane surfaces. Spawn failure (thread exhaustion)
    /// is a structured error, not a panic — a recovering job re-spawning
    /// its lane must never take the whole arena down.
    pub fn spawn(pool: Arc<BufPool>, depth: usize, label: &str) -> Result<UploadLane> {
        let depth = depth.max(1);
        let (jobs_tx, jobs_rx) = mpsc::sync_channel::<LaneJob>(depth);
        let (done_tx, done_rx) = mpsc::sync_channel::<Completion>(depth);
        let worker_pool = pool.clone();
        let handle = thread::Builder::new()
            .name("mbs-upload-lane".into())
            .spawn(move || {
                // once the consumer is gone there is no one to stage for:
                // keep draining, but only to return leases to the pool
                let mut draining = false;
                while let Ok(LaneJob { seq, mb, scale, fault, stall }) = jobs_rx.recv() {
                    if draining {
                        worker_pool.give(mb);
                        continue;
                    }
                    // injected hang: wedge the worker *before* the staging
                    // window opens, so the stall is a genuine dead wait the
                    // consumer's deadline must catch (not credited staging
                    // time in the `started..finished` overlap window)
                    if let Some(d) = stall {
                        thread::sleep(d);
                    }
                    let started = Instant::now();
                    let result = if let Some(note) = fault {
                        worker_pool.give(mb); // a fault never leaks the lease
                        Err(StagingError { msg: note, injected: true })
                    } else {
                        match validate(&mb) {
                            Err(msg) => {
                                worker_pool.give(mb); // nor does an error
                                Err(StagingError { msg, injected: false })
                            }
                            Ok(()) => {
                                let mut staged = worker_pool.lease();
                                stage_copy(&mut staged, &mb);
                                // the original re-enters circulation immediately:
                                // assembly is no longer paced by the device
                                worker_pool.give(mb);
                                Ok(staged)
                            }
                        }
                    };
                    let finished = Instant::now();
                    let completion = Completion {
                        seq,
                        result: result
                            .map(|mb| StagedBatch { seq, mb, scale, started, finished }),
                    };
                    if let Err(mpsc::SendError(c)) = done_tx.send(completion) {
                        // consumer dropped early: recycle the staged copy
                        // and fall into drain-only mode
                        if let Ok(staged) = c.result {
                            worker_pool.give(staged.mb);
                        }
                        draining = true;
                    }
                }
            })
            .map_err(|e| {
                MbsError::Runtime(format!("{label}: spawning upload-lane thread failed: {e}"))
            })?;
        Ok(UploadLane {
            jobs: Some(jobs_tx),
            done: Some(done_rx),
            handle: Some(handle),
            pool,
            label: label.to_string(),
        })
    }

    /// Queue a micro-batch for staging. Blocks once `depth` jobs are
    /// already queued (the channel *is* the staging-memory backpressure).
    /// If the worker has died the lease is returned to the pool and the
    /// error is reported here rather than at the next `recv`.
    pub fn submit(&mut self, job: LaneJob) -> Result<()> {
        let jobs = self.jobs.as_ref().ok_or_else(|| {
            MbsError::Runtime(format!("{}: upload lane already shut down", self.label))
        })?;
        if let Err(mpsc::SendError(job)) = jobs.send(job) {
            self.pool.give(job.mb);
            return Err(MbsError::Runtime(format!(
                "{}: upload lane worker disconnected before accepting a job",
                self.label
            )));
        }
        Ok(())
    }

    /// Receive the next completed staging in submission order, blocking
    /// until the worker finishes it. A staging failure surfaces here, on
    /// the step that would have consumed the slot.
    pub fn recv(&mut self) -> Result<StagedBatch> {
        let done = self.done.as_ref().ok_or_else(|| {
            MbsError::Runtime(format!("{}: upload lane already shut down", self.label))
        })?;
        match done.recv() {
            Ok(completion) => complete(&self.label, completion),
            Err(_) => Err(worker_exited(&self.label)),
        }
    }

    /// [`UploadLane::recv`] with a wall-clock deadline: the watchdog-
    /// governed wait. When the worker completes in time this is `recv`;
    /// when the deadline expires first, the caller genuinely unblocks —
    /// even if the worker is wedged mid-stall — with the *recoverable*
    /// [`MbsError::Deadline`], and the recovery state machine tears this
    /// lane down (draining the late completion's lease) and respawns it.
    pub fn recv_deadline(&mut self, deadline: Duration) -> Result<StagedBatch> {
        let done = self.done.as_ref().ok_or_else(|| {
            MbsError::Runtime(format!("{}: upload lane already shut down", self.label))
        })?;
        match done.recv_timeout(deadline) {
            Ok(completion) => complete(&self.label, completion),
            Err(mpsc::RecvTimeoutError::Timeout) => Err(MbsError::Deadline {
                surface: "lane-recv".to_string(),
                elapsed_ms: deadline.as_millis() as u64,
            }),
            Err(mpsc::RecvTimeoutError::Disconnected) => Err(worker_exited(&self.label)),
        }
    }
}

/// Map a worker completion to the consumer-facing result (shared by
/// [`UploadLane::recv`] and [`UploadLane::recv_deadline`]).
fn complete(label: &str, completion: Completion) -> Result<StagedBatch> {
    match completion {
        Completion { result: Ok(staged), .. } => Ok(staged),
        Completion { seq, result: Err(e) } => {
            let msg =
                format!("{label}: upload lane: staging micro-batch {seq} failed: {}", e.msg);
            // injected faults are transient by construction — the
            // recovery state machine retries them; genuine staging
            // errors would replay identically, so they stay fatal
            Err(if e.injected { MbsError::Fault(msg) } else { MbsError::Runtime(msg) })
        }
    }
}

fn worker_exited(label: &str) -> MbsError {
    MbsError::Runtime(format!(
        "{label}: upload lane worker exited before completing a staged micro-batch"
    ))
}

impl Drop for UploadLane {
    fn drop(&mut self) {
        // Drop the job sender FIRST: the worker's recv loop drains whatever
        // is queued (returning every lease) and exits; drop the completion
        // receiver so a worker parked on a full `send` errors out instead
        // of deadlocking; only then join.
        drop(self.jobs.take());
        drop(self.done.take());
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// The consistency checks device placement would otherwise fail on,
/// surfaced as a staging error instead of a mid-step panic.
fn validate(mb: &MicroBatchHost) -> std::result::Result<(), String> {
    if mb.actual > mb.mask.len() {
        return Err(format!(
            "micro-batch claims {} live samples but carries a {}-sample mask",
            mb.actual,
            mb.mask.len()
        ));
    }
    for (k, &m) in mb.mask.iter().enumerate() {
        let want = if k < mb.actual { 1.0 } else { 0.0 };
        if m != want {
            return Err(format!(
                "mask[{k}] = {m} disagrees with {} live samples",
                mb.actual
            ));
        }
    }
    if !mb.mask.is_empty() {
        if mb.x.len() % mb.mask.len() != 0 {
            return Err(format!(
                "x carries {} elements, not a multiple of the {}-sample mask",
                mb.x.len(),
                mb.mask.len()
            ));
        }
        if mb.y.len() % mb.mask.len() != 0 {
            return Err(format!(
                "y carries {} elements, not a multiple of the {}-sample mask",
                mb.y.len(),
                mb.mask.len()
            ));
        }
    }
    Ok(())
}

/// Byte-identical pinned-staging copy, reusing the destination lease's
/// capacity (allocation-free once the pool is warm).
fn stage_copy(dst: &mut MicroBatchHost, src: &MicroBatchHost) {
    copy_buf(&mut dst.x, &src.x);
    copy_buf(&mut dst.y, &src.y);
    dst.mask.clear();
    dst.mask.extend_from_slice(&src.mask);
    dst.actual = src.actual;
    dst.j = src.j;
}

fn copy_buf(dst: &mut Buf, src: &Buf) {
    match (&mut *dst, src) {
        (Buf::F32(d), Buf::F32(s)) => {
            d.clear();
            d.extend_from_slice(s);
        }
        (Buf::I32(d), Buf::I32(s)) => {
            d.clear();
            d.extend_from_slice(s);
        }
        // dtype changed between leases (pool buffers are shape-agnostic)
        (d, s) => *d = s.clone(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{loader, Dataset, SynthFlowers};

    fn assembled(ds: &dyn Dataset, n: usize, mu: usize) -> Vec<MicroBatchHost> {
        let indices: Vec<usize> = (0..n).collect();
        let splits = n.div_ceil(mu);
        (0..splits).map(|j| loader::assemble(ds, &indices, mu, j)).collect()
    }

    #[test]
    fn staged_copies_are_byte_identical_and_fifo() {
        let ds = SynthFlowers::new(8, 10, 40, 1);
        let pool = Arc::new(BufPool::bounded(16));
        let mut lane = UploadLane::spawn(pool.clone(), 2, "test-job").unwrap();
        let originals = assembled(&ds, 20, 8); // 8 + 8 + 4 (ragged tail)
        for (seq, mb) in originals.iter().enumerate() {
            lane.submit(LaneJob { seq: seq as u64, mb: mb.clone(), scale: Some(0.25), fault: None, stall: None })
                .unwrap();
        }
        for (seq, original) in originals.iter().enumerate() {
            let staged = lane.recv().expect("staging succeeds");
            assert_eq!(staged.seq, seq as u64, "lane must be FIFO");
            assert_eq!(staged.scale, Some(0.25));
            assert_eq!(staged.mb.x, original.x);
            assert_eq!(staged.mb.y, original.y);
            assert_eq!(staged.mb.mask, original.mask);
            assert_eq!(staged.mb.actual, original.actual);
            assert_eq!(staged.mb.j, original.j);
            assert!(staged.finished >= staged.started);
            pool.give(staged.mb);
        }
        drop(lane);
        // every lease the lane took is back: submitted originals + staged
        // copies all went through `give`
        let s = pool.stats();
        assert_eq!(s.returns, 2 * originals.len() as u64);
        assert_eq!(s.leases, originals.len() as u64, "one staging lease per job");
    }

    #[test]
    fn shutdown_on_drop_drains_queued_jobs_without_leaking() {
        let ds = SynthFlowers::new(8, 10, 64, 1);
        let pool = Arc::new(BufPool::bounded(32));
        let mut lane = UploadLane::spawn(pool.clone(), 1, "test-job").unwrap();
        // submit more than the channel depth so some jobs are still queued
        // (and the worker may be parked on a full completion send)
        let originals = assembled(&ds, 64, 8);
        let n = originals.len() as u64;
        for (seq, mb) in originals.into_iter().enumerate() {
            lane.submit(LaneJob { seq: seq as u64, mb, scale: None, fault: None, stall: None }).unwrap();
        }
        drop(lane); // must join, not hang, with completions never consumed
        let s = pool.stats();
        // zero-leak invariant: everything the lane leased or was handed
        // came back through the return channel
        assert_eq!(s.returns, n + s.leases, "leaked a lease across shutdown");
    }

    #[test]
    fn staging_error_propagates_and_recycles_the_lease() {
        let pool = Arc::new(BufPool::bounded(4));
        let mut lane = UploadLane::spawn(pool.clone(), 1, "test-job").unwrap();
        // a corrupt micro-batch: claims more live samples than its mask
        let corrupt = MicroBatchHost {
            x: Buf::F32(vec![0.0; 8]),
            y: Buf::I32(vec![0; 2]),
            mask: vec![1.0, 1.0],
            actual: 5,
            j: 0,
        };
        lane.submit(LaneJob { seq: 7, mb: corrupt, scale: None, fault: None, stall: None }).unwrap();
        let err = lane.recv().expect_err("corrupt batch must fail staging");
        let msg = err.to_string();
        assert!(msg.contains("micro-batch 7"), "{msg}");
        assert!(msg.contains("5 live samples"), "{msg}");
        // the lease went back to the pool despite the error
        assert_eq!(pool.stats().returns, 1);
        assert_eq!(pool.retained(), 1);
        // the lane is still alive and stages good batches afterwards
        let ds = SynthFlowers::new(8, 10, 8, 1);
        let good = assembled(&ds, 8, 8).remove(0);
        lane.submit(LaneJob { seq: 8, mb: good, scale: None, fault: None, stall: None }).unwrap();
        let staged = lane.recv().expect("lane survives an error");
        assert_eq!(staged.seq, 8);
        pool.give(staged.mb);
    }

    #[test]
    fn injected_fault_is_recoverable_and_labeled_with_the_tenant() {
        let ds = SynthFlowers::new(8, 10, 8, 1);
        let pool = Arc::new(BufPool::bounded(4));
        let mut lane = UploadLane::spawn(pool.clone(), 1, "job-cls").unwrap();
        let good = assembled(&ds, 8, 8).remove(0);
        lane.submit(LaneJob {
            seq: 3,
            mb: good,
            scale: Some(0.5),
            fault: Some("lane fault for job 'job-cls' at attempt 3".into()),
            stall: None,
        })
        .unwrap();
        let err = lane.recv().expect_err("injected fault must fail the completion");
        assert!(err.recoverable(), "injected lane faults must be retryable: {err}");
        assert!(matches!(err, MbsError::Fault(_)), "{err:?}");
        let msg = err.to_string();
        assert!(msg.contains("job-cls:"), "tenant label missing: {msg}");
        assert!(msg.contains("micro-batch 3"), "{msg}");
        // the lease went back despite the fault, and the lane survives
        assert_eq!(pool.stats().returns, 1);
        let again = assembled(&ds, 8, 8).remove(0);
        lane.submit(LaneJob { seq: 4, mb: again, scale: None, fault: None, stall: None }).unwrap();
        let staged = lane.recv().expect("lane survives an injected fault");
        assert_eq!(staged.seq, 4);
        pool.give(staged.mb);
    }

    #[test]
    fn genuine_staging_error_is_not_recoverable() {
        let pool = Arc::new(BufPool::bounded(4));
        let mut lane = UploadLane::spawn(pool, 1, "job-seg").unwrap();
        let corrupt = MicroBatchHost {
            x: Buf::F32(vec![0.0; 8]),
            y: Buf::I32(vec![0; 2]),
            mask: vec![1.0, 1.0],
            actual: 5,
            j: 0,
        };
        lane.submit(LaneJob { seq: 0, mb: corrupt, scale: None, fault: None, stall: None }).unwrap();
        let err = lane.recv().expect_err("corrupt batch fails");
        assert!(!err.recoverable(), "validation errors are deterministic: {err}");
        assert!(err.to_string().contains("job-seg:"), "{err}");
    }

    #[test]
    fn mask_padding_mismatch_is_a_staging_error() {
        let pool = Arc::new(BufPool::bounded(4));
        let mut lane = UploadLane::spawn(pool, 1, "test-job").unwrap();
        let bad_mask = MicroBatchHost {
            x: Buf::F32(vec![0.0; 8]),
            y: Buf::I32(vec![0; 4]),
            mask: vec![1.0, 0.0, 1.0, 0.0], // hole in the live prefix
            actual: 2,
            j: 0,
        };
        lane.submit(LaneJob { seq: 0, mb: bad_mask, scale: None, fault: None, stall: None }).unwrap();
        let msg = lane.recv().expect_err("mask hole must fail").to_string();
        assert!(msg.contains("mask[1]"), "{msg}");
    }

    #[test]
    fn injected_stall_trips_recv_deadline_with_a_recoverable_fault() {
        let ds = SynthFlowers::new(8, 10, 8, 1);
        let pool = Arc::new(BufPool::bounded(4));
        let mut lane = UploadLane::spawn(pool.clone(), 1, "job-cls").unwrap();
        let good = assembled(&ds, 8, 8).remove(0);
        lane.submit(LaneJob {
            seq: 0,
            mb: good,
            scale: None,
            fault: None,
            // wedge the worker well past the consumer's deadline
            stall: Some(Duration::from_millis(400)),
        })
        .unwrap();
        let err = lane
            .recv_deadline(Duration::from_millis(30))
            .expect_err("a 400ms stall must trip a 30ms deadline");
        assert!(err.recoverable(), "deadline expiries must be retryable: {err}");
        match &err {
            MbsError::Deadline { surface, elapsed_ms } => {
                assert_eq!(surface, "lane-recv");
                assert_eq!(*elapsed_ms, 30);
            }
            other => panic!("expected Deadline, got {other}"),
        }
        // recovery drops the lane (joining the wedged worker once its
        // sleep ends); the shutdown drain keeps the zero-leak invariant
        drop(lane);
        let s = pool.stats();
        assert_eq!(s.leases, s.returns, "stalled shutdown leaked leases: {s:?}");
    }

    #[test]
    fn recv_deadline_passes_through_when_the_worker_is_healthy() {
        let ds = SynthFlowers::new(8, 10, 8, 1);
        let pool = Arc::new(BufPool::bounded(4));
        let mut lane = UploadLane::spawn(pool.clone(), 1, "job-cls").unwrap();
        let good = assembled(&ds, 8, 8).remove(0);
        lane.submit(LaneJob { seq: 5, mb: good, scale: Some(0.5), fault: None, stall: None })
            .unwrap();
        // generous deadline: behaves exactly like recv
        let staged = lane.recv_deadline(Duration::from_secs(30)).expect("healthy lane");
        assert_eq!(staged.seq, 5);
        assert_eq!(staged.scale, Some(0.5));
        pool.give(staged.mb);
        // injected *faults* still surface as Fault (not Deadline) here
        let again = assembled(&ds, 8, 8).remove(0);
        lane.submit(LaneJob {
            seq: 6,
            mb: again,
            scale: None,
            fault: Some("lane fault for job 'job-cls' at attempt 6".into()),
            stall: None,
        })
        .unwrap();
        let err = lane.recv_deadline(Duration::from_secs(30)).expect_err("fault surfaces");
        assert!(matches!(err, MbsError::Fault(_)), "{err:?}");
    }

    #[test]
    fn threaded_stress_many_short_epochs() {
        // shake out lane races: many short lane lifetimes over one shared
        // pool, every epoch asserting the zero-leak invariant
        let ds = SynthFlowers::new(4, 10, 24, 1);
        let pool = Arc::new(BufPool::bounded(UploadLane::extra_buffers(2) + 4));
        pool.warm(UploadLane::extra_buffers(2) + 4, &ds, 4);
        for epoch in 0..50 {
            let mut lane = UploadLane::spawn(pool.clone(), 2, "test-job").unwrap();
            let mbs_list = assembled(&ds, 24, 4);
            let n = mbs_list.len();
            for (seq, mb) in mbs_list.into_iter().enumerate() {
                let mut leased = pool.lease();
                stage_copy(&mut leased, &mb);
                lane.submit(LaneJob { seq: seq as u64, mb: leased, scale: None, fault: None, stall: None })
                    .unwrap();
                // consume every other completion promptly; leave the rest
                // queued so some epochs drop the lane with a full channel
                if seq % 2 == 0 {
                    let staged = lane.recv().unwrap();
                    pool.give(staged.mb);
                }
            }
            if epoch % 3 == 0 {
                // drain fully on some epochs
                for _ in 0..n / 2 {
                    let staged = lane.recv().unwrap();
                    pool.give(staged.mb);
                }
            }
            drop(lane);
            // per-epoch zero-leak: the lane's shutdown drain returned every
            // outstanding buffer, so takes and gives balance exactly
            let s = pool.stats();
            assert_eq!(s.leases, s.returns, "epoch {epoch} leaked leases: {s:?}");
        }
        // global zero-leak: every lease across all epochs was returned
        let s = pool.stats();
        assert_eq!(s.leases, s.returns, "stress run leaked leases: {s:?}");
    }
}
