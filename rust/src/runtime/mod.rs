//! PJRT runtime: loads the AOT HLO artifacts and runs them on the CPU
//! client with device-resident training state.
//!
//! Design points (DESIGN.md "Key runtime design decisions"):
//!  * `PjRtClient` is `Rc`-backed (not `Send`): everything XLA-facing lives
//!    on the thread that created the [`Engine`]. The streaming overlap is
//!    achieved by doing *host-side* batch assembly on worker threads
//!    (coordinator/streamer.rs) while this thread executes.
//!  * Params, the gradient accumulator, and optimizer slots stay on the
//!    device as `PjRtBuffer`s and are threaded through `execute_b` calls;
//!    the per-micro-batch hot path uploads only x/y/mask/scale and
//!    downloads only two scalars (loss_sum) + a 4-vector (metrics).

pub mod artifacts;
pub mod buffers;
pub mod checkpoint;
pub mod faults;
pub mod model;
pub mod upload_lane;
pub mod watchdog;

pub use artifacts::{
    ArtifactHandle, ArtifactManager, ArtifactStats, CompiledArtifact, CompilerBackend,
    MockCompiler, PythonAotCompiler, VariantKey,
};
pub use faults::{FaultHooks, FaultKind, FaultPlan, FaultSpec, StallSurface, Trigger};
pub use model::{ModelRuntime, StepOutput};
pub use upload_lane::{LaneJob, StagedBatch, UploadLane};
pub use watchdog::{Deadlines, Surface, Watchdog};

use std::collections::HashMap;
use std::sync::Arc;

use crate::error::{MbsError, Result};
use crate::manifest::{Manifest, ModelEntry, Variant};

/// Owns the PJRT client and a cache of compiled executables.
pub struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
    exe_cache: HashMap<String, std::rc::Rc<xla::PjRtLoadedExecutable>>,
    /// Lazily-constructed executable artifact manager for variants the
    /// export did not bake (see [`artifacts`]). `None` until the first
    /// unexported variant is requested or a backend is injected.
    artifacts: Option<ArtifactManager>,
    /// Armed `compile`-kind fault hooks (`--faults` plans reach the
    /// compile/artifact seam through here). Checked at the top of
    /// [`Engine::resolve_variant`] — the one chokepoint every variant
    /// resolution passes through, exported or compiled — so the
    /// injection fires even when the cache never misses.
    compile_faults: FaultHooks,
    /// Monotonic count of [`Engine::resolve_variant`] calls, the attempt
    /// axis for `at-step` compile-fault triggers.
    compile_attempts: u64,
}

impl Engine {
    /// CPU PJRT client over the given artifact directory.
    pub fn new(manifest: Manifest) -> Result<Engine> {
        let client = xla::PjRtClient::cpu()?;
        Ok(Engine {
            client,
            manifest,
            exe_cache: HashMap::new(),
            artifacts: None,
            compile_faults: FaultHooks::none(),
            compile_attempts: 0,
        })
    }

    /// Arm `compile`-kind fault hooks against [`Engine::resolve_variant`].
    /// Each resolve draws one attempt; a firing hook surfaces as a
    /// *recoverable* [`MbsError::Fault`] so the recovery state machine
    /// (or `mbs chaos`) can replay the load.
    pub fn arm_compile_faults(&mut self, hooks: FaultHooks) {
        self.compile_faults = hooks;
        self.compile_attempts = 0;
    }

    /// Disarm any armed compile-fault hooks (back to the clean engine).
    /// Runs that take no fault plan call this so hooks never leak across
    /// chaos sweep points sharing one engine.
    pub fn disarm_compile_faults(&mut self) {
        self.compile_faults = FaultHooks::none();
        self.compile_attempts = 0;
    }

    /// How many compile faults the armed hooks have injected so far.
    pub fn compile_faults_injected(&self) -> u64 {
        self.compile_faults.injected()
    }

    /// The manifest this engine serves artifacts from.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// The underlying PJRT client.
    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }

    /// PJRT platform name (e.g. "cpu").
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO text artifact (cached by file name).
    pub fn load_executable(&mut self, file: &str) -> Result<std::rc::Rc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.exe_cache.get(file) {
            return Ok(exe.clone());
        }
        let path = self.manifest.path(file);
        let path_str = path
            .to_str()
            .ok_or_else(|| MbsError::Runtime(format!("non-utf8 path {path:?}")))?;
        let proto = xla::HloModuleProto::from_text_file(path_str)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = std::rc::Rc::new(self.client.compile(&comp)?);
        self.exe_cache.insert(file.to_string(), exe.clone());
        Ok(exe)
    }

    /// Number of compiled executables held in the cache.
    pub fn cached_executables(&self) -> usize {
        self.exe_cache.len()
    }

    /// Build a [`ModelRuntime`] for `(model, size, mu)`: resolves the
    /// variant through the artifact manager (exported HLO on disk, cache
    /// hit, or on-demand compile), compiles accum / eval / apply
    /// executables, and uploads initial params + zeroed accumulator +
    /// optimizer slots. Any mu is loadable, not just exported ones —
    /// recovery's re-planned mu and admission's proposals land here.
    pub fn load_model(&mut self, model: &str, size: usize, mu: usize) -> Result<ModelRuntime> {
        let entry: ModelEntry = self.manifest.model(model)?.clone();
        let variant: Variant = self.resolve_variant(&entry, size, mu)?;
        let accum = self.load_executable(&variant.accum_hlo)?;
        let eval = self.load_executable(&variant.eval_hlo)?;
        let apply = self.load_executable(&entry.apply_hlo)?;
        ModelRuntime::new(self.client.clone(), entry, variant, accum, eval, apply, &self.manifest)
    }

    /// Resolve `(size, mu)` for `entry` to a [`Variant`] whose HLO paths
    /// are loadable: an exported variant whose files exist is used as-is;
    /// anything else is derived metadata-side
    /// ([`ModelEntry::derive_variant`]) with its HLO payload pair fetched
    /// through the [`ArtifactManager`] (cache hit or backend compile),
    /// the variant's paths rewritten to the cache entry. Absolute cache
    /// paths pass through [`Manifest::path`] unchanged (`Path::join` with
    /// an absolute path yields that path).
    pub fn resolve_variant(
        &mut self,
        entry: &ModelEntry,
        size: usize,
        mu: usize,
    ) -> Result<Variant> {
        let attempt = self.compile_attempts;
        self.compile_attempts += 1;
        if let Some(note) = self.compile_faults.check(FaultKind::Compile, attempt) {
            return Err(MbsError::Fault(format!(
                "{note} (resolving {}:s{size}:mu{mu})",
                entry.name
            )));
        }
        if let Ok(v) = entry.variant(size, mu) {
            if self.manifest.path(&v.accum_hlo).exists() && self.manifest.path(&v.eval_hlo).exists()
            {
                return Ok(v.clone());
            }
        }
        let mut variant = entry.derive_variant(size, mu)?;
        let key = VariantKey { model: entry.name.clone(), size, mu, overlap: false };
        let fingerprint = entry.fingerprint();
        let handle = self.artifact_manager()?.fetch(&key, fingerprint)?;
        variant.accum_hlo = handle
            .accum_path
            .to_str()
            .ok_or_else(|| MbsError::Runtime(format!("non-utf8 path {:?}", handle.accum_path)))?
            .to_string();
        variant.eval_hlo = handle
            .eval_path
            .to_str()
            .ok_or_else(|| MbsError::Runtime(format!("non-utf8 path {:?}", handle.eval_path)))?
            .to_string();
        Ok(variant)
    }

    /// The engine's artifact manager, constructing the default one on
    /// first use: cache at `<artifact-dir>/cache`, python AOT backend
    /// (`python3 -m compile.aot --variant`, overridable via `MBS_PYTHON` /
    /// `MBS_COMPILE_DIR`).
    pub fn artifact_manager(&mut self) -> Result<&ArtifactManager> {
        if self.artifacts.is_none() {
            let cache_dir = self.manifest.dir.join("cache");
            let backend =
                PythonAotCompiler::for_manifest_dir(&self.manifest.dir, &cache_dir.join("scratch"));
            self.artifacts = Some(ArtifactManager::new(
                cache_dir,
                Arc::new(backend),
                artifacts::DEFAULT_MAX_ENTRIES,
            )?);
        }
        Ok(self.artifacts.as_ref().expect("just constructed"))
    }

    /// Replace the compile backend (tests inject [`MockCompiler`]; a
    /// shared manager from another engine can be installed too, since
    /// managers clone shallowly).
    pub fn set_artifact_manager(&mut self, manager: ArtifactManager) {
        self.artifacts = Some(manager);
    }

    /// Counters of the artifact manager, if one has been constructed.
    pub fn artifact_stats(&self) -> Option<ArtifactStats> {
        self.artifacts.as_ref().map(ArtifactManager::stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn engine() -> Option<Engine> {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            return None;
        }
        Some(Engine::new(Manifest::load(dir).unwrap()).unwrap())
    }

    #[test]
    fn executable_cache_hits() {
        let Some(mut e) = engine() else { return };
        let entry = e.manifest().model("microresnet18").unwrap().clone();
        let file = entry.variants[0].eval_hlo.clone();
        e.load_executable(&file).unwrap();
        assert_eq!(e.cached_executables(), 1);
        e.load_executable(&file).unwrap();
        assert_eq!(e.cached_executables(), 1);
    }

    #[test]
    fn missing_artifact_is_error() {
        let Some(mut e) = engine() else { return };
        assert!(e.load_executable("nope.hlo.txt").is_err());
    }
}
