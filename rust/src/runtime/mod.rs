//! PJRT runtime: loads the AOT HLO artifacts and runs them on the CPU
//! client with device-resident training state.
//!
//! Design points (DESIGN.md "Key runtime design decisions"):
//!  * `PjRtClient` is `Rc`-backed (not `Send`): everything XLA-facing lives
//!    on the thread that created the [`Engine`]. The streaming overlap is
//!    achieved by doing *host-side* batch assembly on worker threads
//!    (coordinator/streamer.rs) while this thread executes.
//!  * Params, the gradient accumulator, and optimizer slots stay on the
//!    device as `PjRtBuffer`s and are threaded through `execute_b` calls;
//!    the per-micro-batch hot path uploads only x/y/mask/scale and
//!    downloads only two scalars (loss_sum) + a 4-vector (metrics).

pub mod buffers;
pub mod checkpoint;
pub mod faults;
pub mod model;
pub mod upload_lane;

pub use faults::{FaultHooks, FaultKind, FaultPlan};
pub use model::{ModelRuntime, StepOutput};
pub use upload_lane::{LaneJob, StagedBatch, UploadLane};

use std::collections::HashMap;

use crate::error::{MbsError, Result};
use crate::manifest::{Manifest, ModelEntry, Variant};

/// Owns the PJRT client and a cache of compiled executables.
pub struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
    exe_cache: HashMap<String, std::rc::Rc<xla::PjRtLoadedExecutable>>,
}

impl Engine {
    /// CPU PJRT client over the given artifact directory.
    pub fn new(manifest: Manifest) -> Result<Engine> {
        let client = xla::PjRtClient::cpu()?;
        Ok(Engine { client, manifest, exe_cache: HashMap::new() })
    }

    /// The manifest this engine serves artifacts from.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// The underlying PJRT client.
    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }

    /// PJRT platform name (e.g. "cpu").
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO text artifact (cached by file name).
    pub fn load_executable(&mut self, file: &str) -> Result<std::rc::Rc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.exe_cache.get(file) {
            return Ok(exe.clone());
        }
        let path = self.manifest.path(file);
        let path_str = path
            .to_str()
            .ok_or_else(|| MbsError::Runtime(format!("non-utf8 path {path:?}")))?;
        let proto = xla::HloModuleProto::from_text_file(path_str)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = std::rc::Rc::new(self.client.compile(&comp)?);
        self.exe_cache.insert(file.to_string(), exe.clone());
        Ok(exe)
    }

    /// Number of compiled executables held in the cache.
    pub fn cached_executables(&self) -> usize {
        self.exe_cache.len()
    }

    /// Build a [`ModelRuntime`] for `(model, size, mu)`: compiles accum /
    /// eval / apply executables and uploads initial params + zeroed
    /// accumulator + optimizer slots.
    pub fn load_model(&mut self, model: &str, size: usize, mu: usize) -> Result<ModelRuntime> {
        let entry: ModelEntry = self.manifest.model(model)?.clone();
        let variant: Variant = entry.variant(size, mu)?.clone();
        let accum = self.load_executable(&variant.accum_hlo)?;
        let eval = self.load_executable(&variant.eval_hlo)?;
        let apply = self.load_executable(&entry.apply_hlo)?;
        ModelRuntime::new(self.client.clone(), entry, variant, accum, eval, apply, &self.manifest)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn engine() -> Option<Engine> {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            return None;
        }
        Some(Engine::new(Manifest::load(dir).unwrap()).unwrap())
    }

    #[test]
    fn executable_cache_hits() {
        let Some(mut e) = engine() else { return };
        let entry = e.manifest().model("microresnet18").unwrap().clone();
        let file = entry.variants[0].eval_hlo.clone();
        e.load_executable(&file).unwrap();
        assert_eq!(e.cached_executables(), 1);
        e.load_executable(&file).unwrap();
        assert_eq!(e.cached_executables(), 1);
    }

    #[test]
    fn missing_artifact_is_error() {
        let Some(mut e) = engine() else { return };
        assert!(e.load_executable("nope.hlo.txt").is_err());
    }
}
