//! Seeded, deterministic fault injection for the recovery state machine.
//!
//! A [`FaultPlan`] (`--faults spec.json`) names transient failures to
//! inject at chosen `(job, step)` points so the checkpoint → release →
//! re-plan → replay recovery path ([`crate::coordinator::trainer`]) can be
//! exercised — and its bit-identity oracle proven — without a flaky
//! device. Six [`FaultKind`]s cover the layers a real tenancy fault
//! enters through:
//!
//!   - [`FaultKind::Arena`]: arms the shared [`Arena`](crate::memory::Arena)
//!     so the job's *next* charge fails with the structured
//!     [`MbsError::Oom`](crate::error::MbsError::Oom) arithmetic — the
//!     memory-pressure path, exercising shrink-mu re-planning;
//!   - [`FaultKind::Lane`]: the upload-lane worker reports a staging
//!     failure for one micro-batch (surfaced at the consuming `recv` with
//!     the job label, like every lane error);
//!   - [`FaultKind::Step`]: the job loop fails before the device step —
//!     the generic transient (a poisoned execution, a lost device);
//!   - [`FaultKind::Stall`]: a seeded wall-clock *delay* (`"stall-ms"`)
//!     on a watched surface (`"surface"`: lane | step | checkpoint) — the
//!     hang shape. Nothing errors by itself; the
//!     [`Watchdog`](crate::runtime::watchdog::Watchdog) must convert the
//!     stalled wait into a recoverable deadline fault, which is exactly
//!     what `mbs chaos` proves;
//!   - [`FaultKind::Compile`]: the engine's variant-resolve chokepoint
//!     fails (routes the plan into the PR 8 compile/artifact seam);
//!   - [`FaultKind::Checkpoint`]: the snapshot path reports a torn
//!     write / corrupt read against the FNV-checksummed checkpoint pair.
//!
//! Determinism contract: a fault entry triggers either at an exact 0-based
//! work-item attempt (`"at-step": n`) or by a seeded hash-Bernoulli draw
//! (`"prob": p`, via [`crate::util::hash::fnv1a64`] over
//! `"{seed}:{job}:{kind}:{attempt}"`). Attempt numbers count every work
//! item a job *attempts*, monotonically across recoveries — a replayed
//! step gets a fresh attempt number, so an `at-step` entry never re-fires
//! during its own replay and `times` (default 1) bounds prob entries.

use std::collections::BTreeMap;
use std::time::Duration;

use crate::error::{MbsError, Result};
use crate::runtime::watchdog::Deadlines;
use crate::util::hash::{fnv1a64, fraction};
use crate::util::json::Json;

/// Which layer an injected fault enters through.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// Arm the job's next arena charge to fail with structured OOM.
    Arena,
    /// Fail staging one micro-batch on the upload lane.
    Lane,
    /// Fail the job loop before a device step (generic transient).
    Step,
    /// Inject a wall-clock delay on a watched surface (the hang shape —
    /// only the watchdog turns it into an error).
    Stall,
    /// Fail the engine's variant resolve (compile/artifact seam).
    Compile,
    /// Fail the checkpoint save path after the snapshot write.
    Checkpoint,
}

impl FaultKind {
    fn parse(s: &str) -> Option<FaultKind> {
        match s.to_ascii_lowercase().as_str() {
            "arena" => Some(FaultKind::Arena),
            "lane" => Some(FaultKind::Lane),
            "step" => Some(FaultKind::Step),
            "stall" => Some(FaultKind::Stall),
            "compile" => Some(FaultKind::Compile),
            "checkpoint" => Some(FaultKind::Checkpoint),
            _ => None,
        }
    }

    fn name(&self) -> &'static str {
        match self {
            FaultKind::Arena => "arena",
            FaultKind::Lane => "lane",
            FaultKind::Step => "step",
            FaultKind::Stall => "stall",
            FaultKind::Compile => "compile",
            FaultKind::Checkpoint => "checkpoint",
        }
    }
}

/// Which watched surface a [`FaultKind::Stall`] entry delays.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum StallSurface {
    /// Match any work-item surface (lane or step) — the default, so a
    /// bare stall entry wedges whichever path the job actually uses.
    #[default]
    Auto,
    /// Delay the upload-lane worker before it stages the micro-batch
    /// (trips the consumer's `recv` deadline).
    Lane,
    /// Delay on the executor thread before the device step.
    Step,
    /// Delay the checkpoint save inside its watched window.
    Checkpoint,
}

impl StallSurface {
    fn parse(s: &str) -> Option<StallSurface> {
        match s.to_ascii_lowercase().as_str() {
            "auto" => Some(StallSurface::Auto),
            "lane" => Some(StallSurface::Lane),
            "step" => Some(StallSurface::Step),
            "checkpoint" => Some(StallSurface::Checkpoint),
            _ => None,
        }
    }

    /// Does an entry targeting `self` delay a draw at `at`? `Auto`
    /// covers the work-item surfaces (lane, step) but not checkpoint —
    /// checkpoint stalls are opt-in because they fire outside the
    /// per-item attempt axis.
    fn matches(self, at: StallSurface) -> bool {
        self == at || (self == StallSurface::Auto && matches!(at, StallSurface::Lane | StallSurface::Step))
    }
}

/// When a fault entry fires.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Trigger {
    /// Fire at exactly this 0-based work-item attempt.
    AtStep(u64),
    /// Fire per attempt with this probability (seeded hash-Bernoulli).
    Prob(f64),
}

/// One fault entry of a plan: which job(s), which layer, when, how often.
#[derive(Debug, Clone)]
pub struct FaultSpec {
    /// Job name the entry applies to; `"*"` matches every job.
    pub job: String,
    /// Which layer the fault enters through.
    pub kind: FaultKind,
    /// When it fires.
    pub trigger: Trigger,
    /// Maximum firings per job (default 1; prob entries need a bound or a
    /// job could never finish).
    pub times: u64,
    /// For [`FaultKind::Stall`]: how long the injected delay runs,
    /// milliseconds (`"stall-ms"`, default 50). Ignored by other kinds.
    pub stall_ms: u64,
    /// For [`FaultKind::Stall`]: which surface is delayed (`"surface"`,
    /// default `auto`). Ignored by other kinds.
    pub surface: StallSurface,
}

/// A parsed fault-injection plan (`--faults spec.json`).
#[derive(Debug, Clone)]
pub struct FaultPlan {
    /// Seed mixed into every probability draw.
    pub seed: u64,
    /// Recovery attempts per job before it is marked failed (default 3).
    pub max_retries: u32,
    /// Base backoff between retries, milliseconds (default 0). The
    /// executor scales it by the retry ordinal and adds a seeded jitter
    /// so co-resident tenants don't re-claim the arena in lockstep.
    pub backoff_ms: u64,
    /// Watchdog deadline overrides (`"watchdog"` object, optional).
    /// `None` leaves the generous [`Deadlines::default`] in force; chaos
    /// sweeps shrink them so injected stalls trip in milliseconds.
    pub watchdog: Option<Deadlines>,
    /// The fault entries, in spec order.
    pub specs: Vec<FaultSpec>,
}

impl FaultPlan {
    /// Parse a plan from JSON text. Schema:
    ///
    /// ```json
    /// {
    ///   "seed": 7, "max_retries": 3, "backoff_ms": 0,
    ///   "watchdog": {"step-ms": 250, "lane-recv-ms": 250},
    ///   "faults": [
    ///     {"job": "*", "kind": "step", "at-step": 3},
    ///     {"job": "cls", "kind": "arena", "prob": 0.05, "times": 2},
    ///     {"job": "seg", "kind": "stall", "at-step": 1,
    ///      "surface": "lane", "stall-ms": 750}
    ///   ]
    /// }
    /// ```
    ///
    /// Exactly one of `at-step` / `prob` per entry; unknown kinds,
    /// unknown stall surfaces, and out-of-range probabilities are config
    /// errors. The optional `watchdog` object overrides per-surface
    /// deadlines (`lane-recv-ms`, `step-ms`, `compile-ms`,
    /// `checkpoint-ms`; underscore spellings accepted; omitted keys keep
    /// their generous defaults).
    pub fn parse(text: &str) -> Result<FaultPlan> {
        let bad = |msg: String| MbsError::Config(format!("faults spec: {msg}"));
        let doc = Json::parse(text).map_err(|e| bad(e.to_string()))?;
        let seed = doc.get("seed").and_then(Json::as_u64).unwrap_or(0);
        let max_retries = doc
            .get("max_retries")
            .or_else(|| doc.get("max-retries"))
            .and_then(Json::as_u64)
            .unwrap_or(3) as u32;
        let backoff_ms = doc
            .get("backoff_ms")
            .or_else(|| doc.get("backoff-ms"))
            .and_then(Json::as_u64)
            .unwrap_or(0);
        let watchdog = match doc.get("watchdog") {
            None => None,
            Some(w) => {
                let ms = |dashed: &str, snake: &str, default: Duration| {
                    w.get(dashed)
                        .or_else(|| w.get(snake))
                        .and_then(Json::as_u64)
                        .map(Duration::from_millis)
                        .unwrap_or(default)
                };
                let d = Deadlines::default();
                Some(Deadlines {
                    lane_recv: ms("lane-recv-ms", "lane_recv_ms", d.lane_recv),
                    step: ms("step-ms", "step_ms", d.step),
                    compile: ms("compile-ms", "compile_ms", d.compile),
                    checkpoint: ms("checkpoint-ms", "checkpoint_ms", d.checkpoint),
                })
            }
        };
        let entries = doc
            .get("faults")
            .and_then(Json::as_arr)
            .ok_or_else(|| bad("missing 'faults' array".into()))?;
        let mut specs = Vec::with_capacity(entries.len());
        for (i, e) in entries.iter().enumerate() {
            let job = e
                .get("job")
                .and_then(Json::as_str)
                .ok_or_else(|| bad(format!("fault #{i}: missing 'job'")))?
                .to_string();
            let kind_s = e
                .get("kind")
                .and_then(Json::as_str)
                .ok_or_else(|| bad(format!("fault #{i}: missing 'kind'")))?;
            let kind = FaultKind::parse(kind_s).ok_or_else(|| {
                bad(format!(
                    "fault #{i}: unknown kind '{kind_s}' \
                     (want arena | lane | step | stall | compile | checkpoint)"
                ))
            })?;
            let at = e.get("at-step").or_else(|| e.get("at_step")).and_then(Json::as_u64);
            let prob = e.get("prob").and_then(Json::as_f64);
            let trigger = match (at, prob) {
                (Some(n), None) => Trigger::AtStep(n),
                (None, Some(p)) if (0.0..=1.0).contains(&p) => Trigger::Prob(p),
                (None, Some(p)) => {
                    return Err(bad(format!("fault #{i}: prob {p} outside [0, 1]")))
                }
                _ => {
                    return Err(bad(format!(
                        "fault #{i}: exactly one of 'at-step' / 'prob' required"
                    )))
                }
            };
            let times = e.get("times").and_then(Json::as_u64).unwrap_or(1);
            if times == 0 {
                return Err(bad(format!("fault #{i}: times must be positive")));
            }
            let stall_ms = e
                .get("stall-ms")
                .or_else(|| e.get("stall_ms"))
                .and_then(Json::as_u64)
                .unwrap_or(50);
            let surface = match e.get("surface").and_then(Json::as_str) {
                None => StallSurface::Auto,
                Some(s) => StallSurface::parse(s).ok_or_else(|| {
                    bad(format!(
                        "fault #{i}: unknown surface '{s}' \
                         (want auto | lane | step | checkpoint)"
                    ))
                })?,
            };
            specs.push(FaultSpec { job, kind, trigger, times, stall_ms, surface });
        }
        Ok(FaultPlan { seed, max_retries, backoff_ms, watchdog, specs })
    }

    /// Load a plan from a JSON file.
    pub fn load(path: &str) -> Result<FaultPlan> {
        FaultPlan::parse(&std::fs::read_to_string(path)?)
    }

    /// The per-job hook view: the entries matching `job` (by name or
    /// `"*"`), each with its own firing budget. Sibling jobs' hooks are
    /// independent — a wildcard entry fires up to `times` per job.
    pub fn hooks_for(&self, job: &str) -> FaultHooks {
        let entries = self
            .specs
            .iter()
            .filter(|s| s.job == "*" || s.job == job)
            .map(Armed::from_spec)
            .collect();
        FaultHooks { seed: self.seed, job: job.to_string(), entries, injected: 0 }
    }

    /// The engine-side hook view: every [`FaultKind::Compile`] entry of
    /// the plan, regardless of its `job` field, armed under the
    /// pseudo-job `"compiler"`. The engine (and its variant-resolve
    /// chokepoint) is shared across tenants, so compile faults cannot be
    /// attributed to one job at the seam — whichever tenant's resolve
    /// draws the armed attempt takes the fault and recovers.
    pub fn compile_hooks(&self) -> FaultHooks {
        let entries = self
            .specs
            .iter()
            .filter(|s| s.kind == FaultKind::Compile)
            .map(Armed::from_spec)
            .collect();
        FaultHooks { seed: self.seed, job: "compiler".to_string(), entries, injected: 0 }
    }

    /// Does the plan carry any [`FaultKind::Compile`] entries (i.e.
    /// should the engine arm [`FaultPlan::compile_hooks`])?
    pub fn has_compile_entries(&self) -> bool {
        self.specs.iter().any(|s| s.kind == FaultKind::Compile)
    }

    /// How many plan entries apply to `job` (dry-run attribution).
    pub fn entries_for(&self, job: &str) -> usize {
        self.specs.iter().filter(|s| s.job == "*" || s.job == job).count()
    }
}

#[derive(Debug, Clone)]
struct Armed {
    kind: FaultKind,
    trigger: Trigger,
    remaining: u64,
    stall_ms: u64,
    surface: StallSurface,
}

impl Armed {
    fn from_spec(s: &FaultSpec) -> Armed {
        Armed {
            kind: s.kind,
            trigger: s.trigger,
            remaining: s.times,
            stall_ms: s.stall_ms,
            surface: s.surface,
        }
    }

    /// Does this entry's trigger fire at `attempt`? (Budget and
    /// kind/surface matching are the caller's business.)
    fn fires(&self, seed: u64, job: &str, attempt: u64) -> bool {
        match self.trigger {
            Trigger::AtStep(n) => n == attempt,
            Trigger::Prob(p) => {
                let key = format!("{seed}:{job}:{}:{attempt}", self.kind.name());
                fraction(fnv1a64(key.as_bytes())) < p
            }
        }
    }
}

/// One job's live view of a [`FaultPlan`]: the executor consults it once
/// per work-item attempt and per layer. Default ([`FaultHooks::none`]) is
/// empty — every check is a cheap no-op.
#[derive(Debug, Clone, Default)]
pub struct FaultHooks {
    seed: u64,
    job: String,
    entries: Vec<Armed>,
    injected: u64,
}

impl FaultHooks {
    /// Hooks that never fire (no `--faults` plan configured).
    pub fn none() -> FaultHooks {
        FaultHooks::default()
    }

    /// Does this job have any fault entries at all?
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Should a `kind` fault fire at work-item `attempt`? Consumes one
    /// firing from the first matching armed entry and returns the
    /// diagnostic note to thread into the error. [`FaultKind::Stall`]
    /// entries never fire here — they inject *delays*, not errors; draw
    /// them with [`FaultHooks::check_stall`].
    pub fn check(&mut self, kind: FaultKind, attempt: u64) -> Option<String> {
        if kind == FaultKind::Stall {
            return None;
        }
        let (seed, job) = (self.seed, self.job.clone());
        for entry in self.entries.iter_mut() {
            if entry.kind != kind || entry.remaining == 0 {
                continue;
            }
            if entry.fires(seed, &job, attempt) {
                entry.remaining -= 1;
                self.injected += 1;
                return Some(format!(
                    "{} fault for job '{}' at attempt {attempt}",
                    kind.name(),
                    self.job
                ));
            }
        }
        None
    }

    /// Should a [`FaultKind::Stall`] entry delay surface `at` for
    /// work-item `attempt`? Consumes one firing from the first matching
    /// armed stall entry and returns the injected delay. The caller
    /// sleeps (or tells the lane worker to sleep) for that long inside a
    /// watchdog-observed window — the stall itself is not an error; the
    /// watchdog converting it into [`MbsError::Deadline`] is the
    /// behavior under test.
    ///
    /// [`MbsError::Deadline`]: crate::error::MbsError::Deadline
    pub fn check_stall(&mut self, at: StallSurface, attempt: u64) -> Option<Duration> {
        let (seed, job) = (self.seed, self.job.clone());
        for entry in self.entries.iter_mut() {
            if entry.kind != FaultKind::Stall
                || entry.remaining == 0
                || !entry.surface.matches(at)
            {
                continue;
            }
            if entry.fires(seed, &job, attempt) {
                entry.remaining -= 1;
                self.injected += 1;
                return Some(Duration::from_millis(entry.stall_ms));
            }
        }
        None
    }

    /// Total faults this job's hooks have fired so far.
    pub fn injected(&self) -> u64 {
        self.injected
    }

    /// Remaining firing budget per kind (diagnostics / tests).
    pub fn remaining(&self) -> BTreeMap<&'static str, u64> {
        let mut out = BTreeMap::new();
        for e in &self.entries {
            *out.entry(e.kind.name()).or_default() += e.remaining;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SPEC: &str = r#"{
        "seed": 7, "max_retries": 2, "backoff_ms": 0,
        "faults": [
            {"job": "*", "kind": "step", "at-step": 3},
            {"job": "cls", "kind": "arena", "prob": 0.5, "times": 2},
            {"job": "seg", "kind": "lane", "at-step": 0}
        ]
    }"#;

    #[test]
    fn parse_full_spec() {
        let plan = FaultPlan::parse(SPEC).unwrap();
        assert_eq!(plan.seed, 7);
        assert_eq!(plan.max_retries, 2);
        assert_eq!(plan.backoff_ms, 0);
        assert_eq!(plan.specs.len(), 3);
        assert_eq!(plan.specs[0].job, "*");
        assert_eq!(plan.specs[0].kind, FaultKind::Step);
        assert_eq!(plan.specs[0].trigger, Trigger::AtStep(3));
        assert_eq!(plan.specs[1].times, 2);
        // attribution: the wildcard applies to both, the named ones to one
        assert_eq!(plan.entries_for("cls"), 2);
        assert_eq!(plan.entries_for("seg"), 2);
        assert_eq!(plan.entries_for("other"), 1);
    }

    #[test]
    fn parse_rejects_malformed_entries() {
        let bad = |s: &str| FaultPlan::parse(s).unwrap_err().to_string();
        assert!(bad(r#"{"faults": [{"job": "a", "kind": "bogus", "at-step": 0}]}"#)
            .contains("unknown kind"));
        assert!(bad(r#"{"faults": [{"job": "a", "kind": "step"}]}"#)
            .contains("exactly one of"));
        assert!(bad(
            r#"{"faults": [{"job": "a", "kind": "step", "at-step": 0, "prob": 0.5}]}"#
        )
        .contains("exactly one of"));
        assert!(bad(r#"{"faults": [{"job": "a", "kind": "step", "prob": 1.5}]}"#)
            .contains("outside"));
        assert!(bad(r#"{"faults": [{"job": "a", "kind": "step", "at-step": 1, "times": 0}]}"#)
            .contains("times must be positive"));
        assert!(bad(r#"{"seed": 1}"#).contains("missing 'faults'"));
        assert!(FaultPlan::parse("not json").is_err());
    }

    #[test]
    fn at_step_fires_exactly_once_at_its_attempt() {
        let plan = FaultPlan::parse(SPEC).unwrap();
        let mut hooks = plan.hooks_for("anyjob");
        assert!(hooks.check(FaultKind::Step, 0).is_none());
        assert!(hooks.check(FaultKind::Step, 2).is_none());
        // wrong kind never matches
        assert!(hooks.check(FaultKind::Arena, 3).is_none());
        let note = hooks.check(FaultKind::Step, 3).expect("fires at attempt 3");
        assert!(note.contains("step fault"), "{note}");
        assert!(note.contains("anyjob"), "{note}");
        // budget exhausted: a replayed attempt 3 cannot re-fire
        assert!(hooks.check(FaultKind::Step, 3).is_none());
        assert_eq!(hooks.injected(), 1);
    }

    #[test]
    fn prob_draws_are_deterministic_and_bounded_by_times() {
        let plan = FaultPlan::parse(SPEC).unwrap();
        let fire = |hooks: &mut FaultHooks| {
            (0..200).filter(|&a| hooks.check(FaultKind::Arena, a).is_some()).count()
        };
        let mut a = plan.hooks_for("cls");
        let mut b = plan.hooks_for("cls");
        let fired_a: Vec<u64> =
            (0..200).filter(|&i| a.check(FaultKind::Arena, i + 1000).is_some()).collect();
        let fired_b: Vec<u64> =
            (0..200).filter(|&i| b.check(FaultKind::Arena, i + 1000).is_some()).collect();
        assert_eq!(fired_a, fired_b, "same seed, same job: same draws");
        assert_eq!(fired_a.len(), 2, "times caps prob firings");
        // a different seed moves the draws
        let mut other_seed = FaultPlan { seed: 999, ..plan.clone() }.hooks_for("cls");
        let _ = fire(&mut other_seed); // deterministic, just different
        // a job the arena entry doesn't name never fires it
        let mut seg = plan.hooks_for("seg");
        assert_eq!(
            (0..200).filter(|&a| seg.check(FaultKind::Arena, a).is_some()).count(),
            0
        );
    }

    #[test]
    fn none_hooks_never_fire() {
        let mut hooks = FaultHooks::none();
        assert!(hooks.is_empty());
        for a in 0..50 {
            assert!(hooks.check(FaultKind::Step, a).is_none());
            assert!(hooks.check(FaultKind::Arena, a).is_none());
            assert!(hooks.check(FaultKind::Lane, a).is_none());
            assert!(hooks.check(FaultKind::Compile, a).is_none());
            assert!(hooks.check(FaultKind::Checkpoint, a).is_none());
            assert!(hooks.check_stall(StallSurface::Lane, a).is_none());
            assert!(hooks.check_stall(StallSurface::Step, a).is_none());
            assert!(hooks.check_stall(StallSurface::Checkpoint, a).is_none());
        }
        assert_eq!(hooks.injected(), 0);
    }

    #[test]
    fn defaults_fill_in() {
        let plan = FaultPlan::parse(r#"{"faults": []}"#).unwrap();
        assert_eq!(plan.seed, 0);
        assert_eq!(plan.max_retries, 3);
        assert_eq!(plan.backoff_ms, 0);
        assert!(plan.watchdog.is_none());
        assert!(plan.hooks_for("x").is_empty());
        assert!(!plan.has_compile_entries());
        assert!(plan.compile_hooks().is_empty());
    }

    #[test]
    fn stall_entries_delay_their_surface_and_never_error() {
        let plan = FaultPlan::parse(
            r#"{"faults": [
                {"job": "j", "kind": "stall", "at-step": 2,
                 "surface": "lane", "stall-ms": 750},
                {"job": "j", "kind": "stall", "at-step": 4,
                 "surface": "checkpoint"}
            ]}"#,
        )
        .unwrap();
        let mut hooks = plan.hooks_for("j");
        // stall entries are invisible to the error-injection path
        assert!(hooks.check(FaultKind::Stall, 2).is_none());
        assert!(hooks.check(FaultKind::Step, 2).is_none());
        // wrong surface never matches; checkpoint is opt-in (not Auto)
        assert!(hooks.check_stall(StallSurface::Step, 2).is_none());
        assert!(hooks.check_stall(StallSurface::Checkpoint, 2).is_none());
        let d = hooks.check_stall(StallSurface::Lane, 2).expect("lane stall at 2");
        assert_eq!(d, Duration::from_millis(750));
        // budget of 1: the replayed attempt does not re-stall
        assert!(hooks.check_stall(StallSurface::Lane, 2).is_none());
        // default stall-ms fills in
        let d = hooks.check_stall(StallSurface::Checkpoint, 4).expect("ckpt stall at 4");
        assert_eq!(d, Duration::from_millis(50));
        assert_eq!(hooks.injected(), 2);
    }

    #[test]
    fn auto_surface_matches_lane_and_step_but_not_checkpoint() {
        let spec = r#"{"faults": [{"job": "*", "kind": "stall", "at-step": 1}]}"#;
        let plan = FaultPlan::parse(spec).unwrap();
        assert_eq!(plan.specs[0].surface, StallSurface::Auto);
        let mut on_lane = plan.hooks_for("a");
        assert!(on_lane.check_stall(StallSurface::Lane, 1).is_some());
        let mut on_step = plan.hooks_for("a");
        assert!(on_step.check_stall(StallSurface::Step, 1).is_some());
        let mut on_ckpt = plan.hooks_for("a");
        assert!(on_ckpt.check_stall(StallSurface::Checkpoint, 1).is_none());
    }

    #[test]
    fn parse_rejects_unknown_surface() {
        let err = FaultPlan::parse(
            r#"{"faults": [{"job": "a", "kind": "stall", "at-step": 0, "surface": "disk"}]}"#,
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("unknown surface"), "{err}");
    }

    #[test]
    fn watchdog_overrides_parse_with_defaults_for_omitted_keys() {
        let plan = FaultPlan::parse(
            r#"{"watchdog": {"step-ms": 250, "lane_recv_ms": 100}, "faults": []}"#,
        )
        .unwrap();
        let d = plan.watchdog.expect("watchdog object present");
        assert_eq!(d.step, Duration::from_millis(250));
        assert_eq!(d.lane_recv, Duration::from_millis(100));
        // omitted keys keep the generous defaults
        let defaults = Deadlines::default();
        assert_eq!(d.compile, defaults.compile);
        assert_eq!(d.checkpoint, defaults.checkpoint);
    }

    #[test]
    fn compile_hooks_collect_every_compile_entry_across_jobs() {
        let plan = FaultPlan::parse(
            r#"{"faults": [
                {"job": "a", "kind": "compile", "at-step": 1},
                {"job": "b", "kind": "compile", "at-step": 3},
                {"job": "a", "kind": "step", "at-step": 0}
            ]}"#,
        )
        .unwrap();
        assert!(plan.has_compile_entries());
        let mut hooks = plan.compile_hooks();
        // both compile entries armed, the step entry excluded
        assert!(hooks.check(FaultKind::Step, 0).is_none());
        assert!(hooks.check(FaultKind::Compile, 0).is_none());
        assert!(hooks.check(FaultKind::Compile, 1).is_some());
        assert!(hooks.check(FaultKind::Compile, 3).is_some());
        assert_eq!(hooks.injected(), 2);
    }
}
