//! Seeded, deterministic fault injection for the recovery state machine.
//!
//! A [`FaultPlan`] (`--faults spec.json`) names transient failures to
//! inject at chosen `(job, step)` points so the checkpoint → release →
//! re-plan → replay recovery path ([`crate::coordinator::trainer`]) can be
//! exercised — and its bit-identity oracle proven — without a flaky
//! device. Three [`FaultKind`]s cover the layers a real tenancy fault
//! enters through:
//!
//!   - [`FaultKind::Arena`]: arms the shared [`Arena`](crate::memory::Arena)
//!     so the job's *next* charge fails with the structured
//!     [`MbsError::Oom`](crate::error::MbsError::Oom) arithmetic — the
//!     memory-pressure path, exercising shrink-mu re-planning;
//!   - [`FaultKind::Lane`]: the upload-lane worker reports a staging
//!     failure for one micro-batch (surfaced at the consuming `recv` with
//!     the job label, like every lane error);
//!   - [`FaultKind::Step`]: the job loop fails before the device step —
//!     the generic transient (a poisoned execution, a lost device).
//!
//! Determinism contract: a fault entry triggers either at an exact 0-based
//! work-item attempt (`"at-step": n`) or by a seeded hash-Bernoulli draw
//! (`"prob": p`, via [`crate::util::hash::fnv1a64`] over
//! `"{seed}:{job}:{kind}:{attempt}"`). Attempt numbers count every work
//! item a job *attempts*, monotonically across recoveries — a replayed
//! step gets a fresh attempt number, so an `at-step` entry never re-fires
//! during its own replay and `times` (default 1) bounds prob entries.

use std::collections::BTreeMap;

use crate::error::{MbsError, Result};
use crate::util::hash::{fnv1a64, fraction};
use crate::util::json::Json;

/// Which layer an injected fault enters through.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// Arm the job's next arena charge to fail with structured OOM.
    Arena,
    /// Fail staging one micro-batch on the upload lane.
    Lane,
    /// Fail the job loop before a device step (generic transient).
    Step,
}

impl FaultKind {
    fn parse(s: &str) -> Option<FaultKind> {
        match s.to_ascii_lowercase().as_str() {
            "arena" => Some(FaultKind::Arena),
            "lane" => Some(FaultKind::Lane),
            "step" => Some(FaultKind::Step),
            _ => None,
        }
    }

    fn name(&self) -> &'static str {
        match self {
            FaultKind::Arena => "arena",
            FaultKind::Lane => "lane",
            FaultKind::Step => "step",
        }
    }
}

/// When a fault entry fires.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Trigger {
    /// Fire at exactly this 0-based work-item attempt.
    AtStep(u64),
    /// Fire per attempt with this probability (seeded hash-Bernoulli).
    Prob(f64),
}

/// One fault entry of a plan: which job(s), which layer, when, how often.
#[derive(Debug, Clone)]
pub struct FaultSpec {
    /// Job name the entry applies to; `"*"` matches every job.
    pub job: String,
    /// Which layer the fault enters through.
    pub kind: FaultKind,
    /// When it fires.
    pub trigger: Trigger,
    /// Maximum firings per job (default 1; prob entries need a bound or a
    /// job could never finish).
    pub times: u64,
}

/// A parsed fault-injection plan (`--faults spec.json`).
#[derive(Debug, Clone)]
pub struct FaultPlan {
    /// Seed mixed into every probability draw.
    pub seed: u64,
    /// Recovery attempts per job before it is marked failed (default 3).
    pub max_retries: u32,
    /// Per-job linear backoff between retries, milliseconds (default 0).
    pub backoff_ms: u64,
    /// The fault entries, in spec order.
    pub specs: Vec<FaultSpec>,
}

impl FaultPlan {
    /// Parse a plan from JSON text. Schema:
    ///
    /// ```json
    /// {
    ///   "seed": 7, "max_retries": 3, "backoff_ms": 0,
    ///   "faults": [
    ///     {"job": "*", "kind": "step", "at-step": 3},
    ///     {"job": "cls", "kind": "arena", "prob": 0.05, "times": 2}
    ///   ]
    /// }
    /// ```
    ///
    /// Exactly one of `at-step` / `prob` per entry; unknown kinds and
    /// out-of-range probabilities are config errors.
    pub fn parse(text: &str) -> Result<FaultPlan> {
        let bad = |msg: String| MbsError::Config(format!("faults spec: {msg}"));
        let doc = Json::parse(text).map_err(|e| bad(e.to_string()))?;
        let seed = doc.get("seed").and_then(Json::as_u64).unwrap_or(0);
        let max_retries = doc
            .get("max_retries")
            .or_else(|| doc.get("max-retries"))
            .and_then(Json::as_u64)
            .unwrap_or(3) as u32;
        let backoff_ms = doc
            .get("backoff_ms")
            .or_else(|| doc.get("backoff-ms"))
            .and_then(Json::as_u64)
            .unwrap_or(0);
        let entries = doc
            .get("faults")
            .and_then(Json::as_arr)
            .ok_or_else(|| bad("missing 'faults' array".into()))?;
        let mut specs = Vec::with_capacity(entries.len());
        for (i, e) in entries.iter().enumerate() {
            let job = e
                .get("job")
                .and_then(Json::as_str)
                .ok_or_else(|| bad(format!("fault #{i}: missing 'job'")))?
                .to_string();
            let kind_s = e
                .get("kind")
                .and_then(Json::as_str)
                .ok_or_else(|| bad(format!("fault #{i}: missing 'kind'")))?;
            let kind = FaultKind::parse(kind_s).ok_or_else(|| {
                bad(format!(
                    "fault #{i}: unknown kind '{kind_s}' (want arena | lane | step)"
                ))
            })?;
            let at = e.get("at-step").or_else(|| e.get("at_step")).and_then(Json::as_u64);
            let prob = e.get("prob").and_then(Json::as_f64);
            let trigger = match (at, prob) {
                (Some(n), None) => Trigger::AtStep(n),
                (None, Some(p)) if (0.0..=1.0).contains(&p) => Trigger::Prob(p),
                (None, Some(p)) => {
                    return Err(bad(format!("fault #{i}: prob {p} outside [0, 1]")))
                }
                _ => {
                    return Err(bad(format!(
                        "fault #{i}: exactly one of 'at-step' / 'prob' required"
                    )))
                }
            };
            let times = e.get("times").and_then(Json::as_u64).unwrap_or(1);
            if times == 0 {
                return Err(bad(format!("fault #{i}: times must be positive")));
            }
            specs.push(FaultSpec { job, kind, trigger, times });
        }
        Ok(FaultPlan { seed, max_retries, backoff_ms, specs })
    }

    /// Load a plan from a JSON file.
    pub fn load(path: &str) -> Result<FaultPlan> {
        FaultPlan::parse(&std::fs::read_to_string(path)?)
    }

    /// The per-job hook view: the entries matching `job` (by name or
    /// `"*"`), each with its own firing budget. Sibling jobs' hooks are
    /// independent — a wildcard entry fires up to `times` per job.
    pub fn hooks_for(&self, job: &str) -> FaultHooks {
        let entries = self
            .specs
            .iter()
            .filter(|s| s.job == "*" || s.job == job)
            .map(|s| Armed { kind: s.kind, trigger: s.trigger, remaining: s.times })
            .collect();
        FaultHooks { seed: self.seed, job: job.to_string(), entries, injected: 0 }
    }

    /// How many plan entries apply to `job` (dry-run attribution).
    pub fn entries_for(&self, job: &str) -> usize {
        self.specs.iter().filter(|s| s.job == "*" || s.job == job).count()
    }
}

#[derive(Debug, Clone)]
struct Armed {
    kind: FaultKind,
    trigger: Trigger,
    remaining: u64,
}

/// One job's live view of a [`FaultPlan`]: the executor consults it once
/// per work-item attempt and per layer. Default ([`FaultHooks::none`]) is
/// empty — every check is a cheap no-op.
#[derive(Debug, Clone, Default)]
pub struct FaultHooks {
    seed: u64,
    job: String,
    entries: Vec<Armed>,
    injected: u64,
}

impl FaultHooks {
    /// Hooks that never fire (no `--faults` plan configured).
    pub fn none() -> FaultHooks {
        FaultHooks::default()
    }

    /// Does this job have any fault entries at all?
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Should a `kind` fault fire at work-item `attempt`? Consumes one
    /// firing from the first matching armed entry and returns the
    /// diagnostic note to thread into the error.
    pub fn check(&mut self, kind: FaultKind, attempt: u64) -> Option<String> {
        for entry in self.entries.iter_mut() {
            if entry.kind != kind || entry.remaining == 0 {
                continue;
            }
            let fires = match entry.trigger {
                Trigger::AtStep(n) => n == attempt,
                Trigger::Prob(p) => {
                    let key =
                        format!("{}:{}:{}:{attempt}", self.seed, self.job, kind.name());
                    fraction(fnv1a64(key.as_bytes())) < p
                }
            };
            if fires {
                entry.remaining -= 1;
                self.injected += 1;
                return Some(format!(
                    "{} fault for job '{}' at attempt {attempt}",
                    kind.name(),
                    self.job
                ));
            }
        }
        None
    }

    /// Total faults this job's hooks have fired so far.
    pub fn injected(&self) -> u64 {
        self.injected
    }

    /// Remaining firing budget per kind (diagnostics / tests).
    pub fn remaining(&self) -> BTreeMap<&'static str, u64> {
        let mut out = BTreeMap::new();
        for e in &self.entries {
            *out.entry(e.kind.name()).or_default() += e.remaining;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SPEC: &str = r#"{
        "seed": 7, "max_retries": 2, "backoff_ms": 0,
        "faults": [
            {"job": "*", "kind": "step", "at-step": 3},
            {"job": "cls", "kind": "arena", "prob": 0.5, "times": 2},
            {"job": "seg", "kind": "lane", "at-step": 0}
        ]
    }"#;

    #[test]
    fn parse_full_spec() {
        let plan = FaultPlan::parse(SPEC).unwrap();
        assert_eq!(plan.seed, 7);
        assert_eq!(plan.max_retries, 2);
        assert_eq!(plan.backoff_ms, 0);
        assert_eq!(plan.specs.len(), 3);
        assert_eq!(plan.specs[0].job, "*");
        assert_eq!(plan.specs[0].kind, FaultKind::Step);
        assert_eq!(plan.specs[0].trigger, Trigger::AtStep(3));
        assert_eq!(plan.specs[1].times, 2);
        // attribution: the wildcard applies to both, the named ones to one
        assert_eq!(plan.entries_for("cls"), 2);
        assert_eq!(plan.entries_for("seg"), 2);
        assert_eq!(plan.entries_for("other"), 1);
    }

    #[test]
    fn parse_rejects_malformed_entries() {
        let bad = |s: &str| FaultPlan::parse(s).unwrap_err().to_string();
        assert!(bad(r#"{"faults": [{"job": "a", "kind": "bogus", "at-step": 0}]}"#)
            .contains("unknown kind"));
        assert!(bad(r#"{"faults": [{"job": "a", "kind": "step"}]}"#)
            .contains("exactly one of"));
        assert!(bad(
            r#"{"faults": [{"job": "a", "kind": "step", "at-step": 0, "prob": 0.5}]}"#
        )
        .contains("exactly one of"));
        assert!(bad(r#"{"faults": [{"job": "a", "kind": "step", "prob": 1.5}]}"#)
            .contains("outside"));
        assert!(bad(r#"{"faults": [{"job": "a", "kind": "step", "at-step": 1, "times": 0}]}"#)
            .contains("times must be positive"));
        assert!(bad(r#"{"seed": 1}"#).contains("missing 'faults'"));
        assert!(FaultPlan::parse("not json").is_err());
    }

    #[test]
    fn at_step_fires_exactly_once_at_its_attempt() {
        let plan = FaultPlan::parse(SPEC).unwrap();
        let mut hooks = plan.hooks_for("anyjob");
        assert!(hooks.check(FaultKind::Step, 0).is_none());
        assert!(hooks.check(FaultKind::Step, 2).is_none());
        // wrong kind never matches
        assert!(hooks.check(FaultKind::Arena, 3).is_none());
        let note = hooks.check(FaultKind::Step, 3).expect("fires at attempt 3");
        assert!(note.contains("step fault"), "{note}");
        assert!(note.contains("anyjob"), "{note}");
        // budget exhausted: a replayed attempt 3 cannot re-fire
        assert!(hooks.check(FaultKind::Step, 3).is_none());
        assert_eq!(hooks.injected(), 1);
    }

    #[test]
    fn prob_draws_are_deterministic_and_bounded_by_times() {
        let plan = FaultPlan::parse(SPEC).unwrap();
        let fire = |hooks: &mut FaultHooks| {
            (0..200).filter(|&a| hooks.check(FaultKind::Arena, a).is_some()).count()
        };
        let mut a = plan.hooks_for("cls");
        let mut b = plan.hooks_for("cls");
        let fired_a: Vec<u64> =
            (0..200).filter(|&i| a.check(FaultKind::Arena, i + 1000).is_some()).collect();
        let fired_b: Vec<u64> =
            (0..200).filter(|&i| b.check(FaultKind::Arena, i + 1000).is_some()).collect();
        assert_eq!(fired_a, fired_b, "same seed, same job: same draws");
        assert_eq!(fired_a.len(), 2, "times caps prob firings");
        // a different seed moves the draws
        let mut other_seed = FaultPlan { seed: 999, ..plan.clone() }.hooks_for("cls");
        let _ = fire(&mut other_seed); // deterministic, just different
        // a job the arena entry doesn't name never fires it
        let mut seg = plan.hooks_for("seg");
        assert_eq!(
            (0..200).filter(|&a| seg.check(FaultKind::Arena, a).is_some()).count(),
            0
        );
    }

    #[test]
    fn none_hooks_never_fire() {
        let mut hooks = FaultHooks::none();
        assert!(hooks.is_empty());
        for a in 0..50 {
            assert!(hooks.check(FaultKind::Step, a).is_none());
            assert!(hooks.check(FaultKind::Arena, a).is_none());
            assert!(hooks.check(FaultKind::Lane, a).is_none());
        }
        assert_eq!(hooks.injected(), 0);
    }

    #[test]
    fn defaults_fill_in() {
        let plan = FaultPlan::parse(r#"{"faults": []}"#).unwrap();
        assert_eq!(plan.seed, 0);
        assert_eq!(plan.max_retries, 3);
        assert_eq!(plan.backoff_ms, 0);
        assert!(plan.hooks_for("x").is_empty());
    }
}
