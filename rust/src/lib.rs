//! # Micro-Batch Streaming (MBS)
//!
//! Production-oriented reproduction of *"Enabling Large Batch Size Training
//! for DNN Models Beyond the Memory Limit While Maintaining Performance"*
//! (Piao, Synn, Park, Kim — IEEE Access 2023; preprint title "Micro Batch
//! Streaming"), as a three-layer rust + JAX + Pallas stack:
//!
//!  * **L3 (this crate)** — the rust coordinator: the memory-driven
//!    micro-batch planner (paper Alg. 1), the stream-based pipeline, the
//!    single plan-driven epoch executor, loss normalization policy, the
//!    simulated device-memory model/ledger that reproduces the paper's OOM
//!    frontier, and the synthetic datasets.
//!  * **L2** — JAX model zoo (`python/compile/models/`), lowered AOT to HLO
//!    text and executed here via the PJRT CPU client ([`runtime`]).
//!  * **L1** — Pallas kernels (tiled MXU matmul, fused CE) embedded in the
//!    L2 HLO.
//!
//! A walk through the data path (synth loaders → [`data::BufPool`] lease →
//! streamer → plan-driven epoch executor → ledger/runtime → metrics) lives
//! in `rust/docs/ARCHITECTURE.md`; the artifact-gated test story in
//! `rust/docs/TESTING.md`.
//!
//! Quickstart (after `make artifacts`): the micro-batch size defaults to
//! [`MicroBatchSpec::Auto`], so the planner derives the largest exported
//! `mu` that fits the memory remaining after the model is resident — the
//! paper's core algorithm. No hand-tuned `mu` required:
//!
//! ```no_run
//! use mbs::prelude::*;
//!
//! let manifest = Manifest::load("artifacts").unwrap();
//! let mut engine = Engine::new(manifest).unwrap();
//! let config = TrainConfig::builder("microresnet18")
//!     .batch(128)        // far beyond what 96 MiB holds natively
//!     .epochs(2)
//!     .capacity_mib(96)  // mu is derived from this, not guessed
//!     .build();
//! let report = train(&mut engine, &config).unwrap();
//! println!(
//!     "planned mu {}: final accuracy {:.2}%",
//!     report.mu,
//!     100.0 * report.final_eval.primary_metric
//! );
//! ```
//!
//! Pin a specific exported variant with `.mu(16)` (ablations, benches), or
//! ask for the old behaviour on the CLI with `--mu 16` vs `--mu auto`.
//!
//! The planner is also grid-callable without training: the
//! [`coordinator::frontier`] module sweeps a capacity × batch grid and
//! classifies every point as Native / MBS(mu) / OOM — the paper's headline
//! figure as an instrument. This needs no compiled artifacts:
//!
//! ```
//! use mbs::coordinator::frontier::{synthetic_entry, FrontierGrid};
//! use mbs::memory::MIB;
//!
//! let entry = synthetic_entry("classification").unwrap();
//! let grid = FrontierGrid::sweep(
//!     &entry,
//!     16,                      // image size
//!     0,                       // no eval occupancy
//!     &[2 * MIB, 8 * MIB],     // simulated device capacities
//!     &[8, 64, 256],           // global batch sizes
//!     true,                    // price the overlapped pipeline's residency
//! )
//! .unwrap();
//! assert_eq!(grid.points.len(), 6);
//! println!("{}", grid.render_table().render());
//! ```
//!
//! (`mbs frontier --capacities 2,8 --batches 8,64,256 --dry-run` is the CLI
//! spelling; it also emits a `BENCH_frontier.json` artifact.)

#![warn(missing_docs)]

pub mod config;
pub mod coordinator;
pub mod data;
pub mod error;
pub mod manifest;
pub mod memory;
pub mod metrics;
pub mod runtime;
pub mod util;

pub use config::{MicroBatchSpec, TrainConfig};
pub use coordinator::{
    train, train_jobs, train_jobs_faulted, ExecutionPlan, Feasibility, FrontierGrid, JobOutcome,
    JobSet, JobSpec, JobsReport, NormalizationMode, Planner, SetFeasibility, TrainReport,
};
pub use error::{MbsError, Result};
pub use manifest::Manifest;
pub use runtime::Engine;

/// Convenience re-exports for examples and benches.
pub mod prelude {
    pub use crate::config::{MicroBatchSpec, TrainConfig};
    pub use crate::coordinator::{
        train, train_jobs, train_jobs_faulted, ExecutionPlan, Feasibility, FrontierGrid,
        JobOutcome, JobSet, JobSpec, JobsReport, NormalizationMode, Planner, SetFeasibility,
        TrainReport,
    };
    pub use crate::data::{BufPool, Dataset, PoolStats, SynthCarvana, SynthFlowers, SynthText};
    pub use crate::error::{MbsError, Result};
    pub use crate::manifest::Manifest;
    pub use crate::memory::{Arena, Footprint, MemoryModel, MIB};
    pub use crate::metrics::{EpochStats, StageTimers};
    pub use crate::runtime::Engine;
}
