//! # Micro-Batch Streaming (MBS)
//!
//! Production-oriented reproduction of *"Enabling Large Batch Size Training
//! for DNN Models Beyond the Memory Limit While Maintaining Performance"*
//! (Piao, Synn, Park, Kim — IEEE Access 2023; preprint title "Micro Batch
//! Streaming"), as a three-layer rust + JAX + Pallas stack:
//!
//!  * **L3 (this crate)** — the rust coordinator: mini->micro batch
//!    splitting (paper Alg. 1), the stream-based pipeline, loss
//!    normalization policy, gradient-accumulation lifecycle, the simulated
//!    device-memory model that reproduces the paper's OOM frontier, and the
//!    synthetic datasets.
//!  * **L2** — JAX model zoo (`python/compile/models/`), lowered AOT to HLO
//!    text and executed here via the PJRT CPU client ([`runtime`]).
//!  * **L1** — Pallas kernels (tiled MXU matmul, fused CE) embedded in the
//!    L2 HLO.
//!
//! Quickstart (after `make artifacts`):
//!
//! ```no_run
//! use mbs::prelude::*;
//!
//! let manifest = Manifest::load("artifacts").unwrap();
//! let mut engine = Engine::new(manifest).unwrap();
//! let config = TrainConfig::builder("microresnet18")
//!     .batch(128)
//!     .mu(16)
//!     .epochs(2)
//!     .capacity_mib(96)
//!     .build();
//! let report = train(&mut engine, &config).unwrap();
//! println!("final accuracy {:.2}%", 100.0 * report.final_eval.primary_metric);
//! ```

pub mod config;
pub mod coordinator;
pub mod data;
pub mod error;
pub mod manifest;
pub mod memory;
pub mod metrics;
pub mod runtime;
pub mod util;

pub use config::TrainConfig;
pub use coordinator::{train, NormalizationMode, TrainReport};
pub use error::{MbsError, Result};
pub use manifest::Manifest;
pub use runtime::Engine;

/// Convenience re-exports for examples and benches.
pub mod prelude {
    pub use crate::config::TrainConfig;
    pub use crate::coordinator::{train, NormalizationMode, TrainReport};
    pub use crate::data::{Dataset, SynthCarvana, SynthFlowers, SynthText};
    pub use crate::error::{MbsError, Result};
    pub use crate::manifest::Manifest;
    pub use crate::memory::{Footprint, MemoryModel, MIB};
    pub use crate::metrics::EpochStats;
    pub use crate::runtime::Engine;
}
