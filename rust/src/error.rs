//! Library error type.

use thiserror::Error;

/// Everything that can go wrong across the MBS stack.
#[derive(Error, Debug)]
pub enum MbsError {
    /// The simulated device cannot fit the requested step — this is the
    /// paper's "Failed" table cell. Carries the arithmetic so reports can
    /// show *why* it failed.
    #[error("device OOM: need {needed_bytes} B but only {available_bytes} B of {capacity_bytes} B available ({context})")]
    Oom {
        /// Bytes the rejected request would have needed in total.
        needed_bytes: u64,
        /// Bytes still available beyond the resident state.
        available_bytes: u64,
        /// Total simulated device capacity.
        capacity_bytes: u64,
        /// What was being admitted ("native step N_B=64", "eval step …").
        context: String,
    },

    /// Malformed or inconsistent artifact manifest.
    #[error("manifest error: {0}")]
    Manifest(String),

    /// Invalid run configuration (CLI flags, config file, builder).
    #[error("config error: {0}")]
    Config(String),

    /// Dataset construction or assembly failure.
    #[error("data error: {0}")]
    Data(String),

    /// PJRT/XLA execution failure or protocol mismatch.
    #[error("runtime error: {0}")]
    Runtime(String),

    /// A deterministic injected fault (fault-injection plans,
    /// `--faults spec.json`). Always transient by construction: the
    /// recovery state machine treats it as retryable, unlike
    /// [`MbsError::Runtime`] which signals a genuine defect.
    #[error("injected fault: {0}")]
    Fault(String),

    /// The artifact manager's compiler backend failed to produce an
    /// executable for a requested variant (`runtime/artifacts.rs`).
    /// Deterministic by contract — re-running the same export would fail
    /// identically — so it stays fatal, unlike [`MbsError::CompileTimeout`].
    #[error("compile error for variant {key}: {reason}")]
    Compile {
        /// Canonical variant key (`model:sSIZE:muMU:overlap`).
        key: String,
        /// Backend diagnostic (exit status, missing output file, …).
        reason: String,
    },

    /// The compiler backend exceeded its wall-clock budget. Transient by
    /// contract (a loaded machine, a wedged subprocess): the recovery
    /// state machine may retry it.
    #[error("compile timeout for variant {key}: gave up after {waited_ms} ms")]
    CompileTimeout {
        /// Canonical variant key (`model:sSIZE:muMU:overlap`).
        key: String,
        /// Milliseconds waited before giving up.
        waited_ms: u64,
    },

    /// A wall-clock watchdog deadline expired on a blocking surface
    /// (`runtime/watchdog.rs`): a stalled lane `recv`, a wedged
    /// micro-step, a compile fetch or checkpoint write that never
    /// returned. Always transient by construction — the hang is
    /// *converted* into a fault precisely so the recovery state machine
    /// can quiesce, release, and replay instead of freezing the arena.
    #[error("deadline expired on {surface} after {elapsed_ms} ms (watchdog)")]
    Deadline {
        /// Watched surface name (`lane-recv`, `step`, `compile`,
        /// `checkpoint-save`, `checkpoint-load`).
        surface: String,
        /// Milliseconds elapsed when the watchdog fired.
        elapsed_ms: u64,
    },

    /// Filesystem error (artifacts, checkpoints, reports).
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
}

impl From<xla::Error> for MbsError {
    fn from(e: xla::Error) -> Self {
        MbsError::Runtime(e.to_string())
    }
}

impl From<crate::util::json::JsonError> for MbsError {
    fn from(e: crate::util::json::JsonError) -> Self {
        MbsError::Manifest(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, MbsError>;

impl MbsError {
    /// Is this the structured device-OOM error (a paper "Failed" cell)?
    pub fn is_oom(&self) -> bool {
        matches!(self, MbsError::Oom { .. })
    }

    /// May a job-level retry (checkpoint → release → re-plan → replay)
    /// clear this error? True for memory pressure ([`MbsError::Oom`] —
    /// shrinking mu against the freed transient budget can fit the step),
    /// for injected transients ([`MbsError::Fault`]), and for compile
    /// timeouts ([`MbsError::CompileTimeout`] — a stuck backend may
    /// succeed on retry), and for watchdog expiries ([`MbsError::Deadline`]
    /// — a hang converted to a fault so the arena can reclaim the tenant).
    /// Config, manifest, data, IO, runtime-protocol, and compile-failure
    /// errors are deterministic: replaying them would fail identically, so
    /// they stay fatal.
    pub fn recoverable(&self) -> bool {
        matches!(
            self,
            MbsError::Oom { .. }
                | MbsError::Fault(_)
                | MbsError::CompileTimeout { .. }
                | MbsError::Deadline { .. }
        )
    }
}
