//! Library error type.

use thiserror::Error;

#[derive(Error, Debug)]
pub enum MbsError {
    /// The simulated device cannot fit the requested step — this is the
    /// paper's "Failed" table cell. Carries the arithmetic so reports can
    /// show *why* it failed.
    #[error("device OOM: need {needed_bytes} B but only {available_bytes} B of {capacity_bytes} B available ({context})")]
    Oom {
        needed_bytes: u64,
        available_bytes: u64,
        capacity_bytes: u64,
        context: String,
    },

    #[error("manifest error: {0}")]
    Manifest(String),

    #[error("config error: {0}")]
    Config(String),

    #[error("data error: {0}")]
    Data(String),

    #[error("runtime error: {0}")]
    Runtime(String),

    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
}

impl From<xla::Error> for MbsError {
    fn from(e: xla::Error) -> Self {
        MbsError::Runtime(e.to_string())
    }
}

impl From<crate::util::json::JsonError> for MbsError {
    fn from(e: crate::util::json::JsonError) -> Self {
        MbsError::Manifest(e.to_string())
    }
}

pub type Result<T> = std::result::Result<T, MbsError>;

impl MbsError {
    pub fn is_oom(&self) -> bool {
        matches!(self, MbsError::Oom { .. })
    }
}
