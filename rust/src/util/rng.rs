//! Deterministic PRNG (no `rand` offline): SplitMix64 seeding a
//! xoshiro256++ core, plus the distributions the data generators need.
//!
//! Determinism is a tested invariant (DESIGN.md invariant 4): the same seed
//! must produce the same dataset, the same shuffle, and therefore the same
//! loss sequence on every run.

/// xoshiro256++ PRNG with SplitMix64 seeding.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// A generator seeded deterministically from `seed`.
    pub fn new(seed: u64) -> Self {
        // SplitMix64 to expand the seed into the full state
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    /// Derive an independent stream (e.g. per-epoch, per-sample).
    pub fn fork(&self, stream: u64) -> Self {
        Rng::new(self.s[0] ^ stream.wrapping_mul(0xA24BAED4963EE407).wrapping_add(1))
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform in [lo, hi).
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Uniform integer in [0, n). Rejection-free Lemire reduction.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// [`below`](Rng::below) for usize bounds.
    pub fn usize_below(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.f32().max(1e-12);
        let u2 = self.f32();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos()
    }

    /// Fisher-Yates in-place shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.usize_below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(4);
        let mut seen = [false; 7];
        for _ in 0..1_000 {
            seen[r.usize_below(7)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments_sane() {
        let mut r = Rng::new(5);
        let n = 20_000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.08, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(6);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn fork_streams_independent() {
        let base = Rng::new(9);
        let mut a = base.fork(0);
        let mut b = base.fork(1);
        assert_ne!(a.next_u64(), b.next_u64());
        // and reproducible
        let mut a2 = base.fork(0);
        assert_eq!(Rng::fork(&base, 0).next_u64(), a2.next_u64());
    }
}
