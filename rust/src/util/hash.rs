//! FNV-1a 64-bit hashing (offline build: hand-rolled, no external crates).
//!
//! Two consumers, both needing *stable* (cross-run, cross-platform) hashes
//! rather than HashMap-grade ones:
//!   - checkpoint payload checksums ([`crate::runtime::checkpoint`]): the
//!     metadata records the FNV-1a digest of the `.bin` payload so a
//!     truncated or bit-flipped checkpoint fails structurally on load
//!     instead of restoring garbage parameters;
//!   - deterministic fault sampling ([`crate::runtime::faults`]): a
//!     probability-triggered fault fires iff the digest of
//!     `"{seed}:{job}:{kind}:{attempt}"` falls below the threshold, so the
//!     same plan replays the same faults on every run.

/// The FNV-1a 64-bit digest of `bytes`.
///
/// ```
/// use mbs::util::hash::fnv1a64;
/// assert_eq!(fnv1a64(b""), 0xcbf29ce484222325);
/// assert_eq!(fnv1a64(b"a"), 0xaf63dc4c8601ec8c);
/// ```
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    const OFFSET: u64 = 0xcbf29ce484222325;
    const PRIME: u64 = 0x100000001b3;
    let mut h = OFFSET;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(PRIME);
    }
    h
}

/// Map a digest to a uniform fraction in `[0, 1)` for threshold
/// comparisons against a probability.
///
/// FNV-1a's avalanche is weak for short inputs — keys differing only in
/// a trailing counter produce digests whose *high* bits barely move — so
/// the digest is first run through the splitmix64 finalizer (a bijective
/// xorshift-multiply mixer) before the top 53 bits (the full f64
/// mantissa) are taken. Without the finalizer, per-entry fault draws
/// degenerate to all-or-nothing across attempts.
pub fn fraction(digest: u64) -> f64 {
    let mut z = digest;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
    z ^= z >> 31;
    (z >> 11) as f64 / (1u64 << 53) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // reference vectors from the FNV spec's test suite
        assert_eq!(fnv1a64(b""), 0xcbf29ce484222325);
        assert_eq!(fnv1a64(b"a"), 0xaf63dc4c8601ec8c);
        assert_eq!(fnv1a64(b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn fraction_in_unit_interval() {
        for digest in [0u64, 1, u64::MAX, 0xcbf29ce484222325] {
            let f = fraction(digest);
            assert!((0.0..1.0).contains(&f), "fraction({digest}) = {f}");
        }
        assert_eq!(fraction(0), 0.0);
    }

    #[test]
    fn fraction_decorrelates_counter_keys() {
        // the property the fault sampler depends on: digests of keys that
        // differ only in a trailing counter must land on both sides of a
        // 0.5 threshold, not cluster (FNV-1a's raw high bits cluster)
        let draws = (0..200)
            .filter(|a| fraction(fnv1a64(format!("7:cls:arena:{a}").as_bytes())) < 0.5)
            .count();
        assert!((60..140).contains(&draws), "biased draws: {draws}/200 below 0.5");
    }

    #[test]
    fn single_bit_flips_change_the_digest() {
        let base = fnv1a64(b"checkpoint payload");
        let mut flipped = b"checkpoint payload".to_vec();
        flipped[3] ^= 1;
        assert_ne!(base, fnv1a64(&flipped));
        // truncation changes it too (the checksum's whole job)
        assert_ne!(base, fnv1a64(&b"checkpoint payload"[..8]));
    }
}
