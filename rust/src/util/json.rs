//! Minimal JSON parser for the artifact manifest.
//!
//! The build environment is offline (no serde_json), so this module provides
//! the small subset of JSON we need: objects, arrays, strings, numbers,
//! booleans, null, with full escape handling for strings and the usual
//! whitespace rules. It is a strict recursive-descent parser — malformed
//! input yields a positioned error rather than a panic.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number (stored as f64).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object (key order not preserved).
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset context.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    /// Byte offset of the failure in the input.
    pub offset: usize,
    /// What went wrong.
    pub message: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    /// Parse a complete JSON document (trailing whitespace allowed only).
    pub fn parse(src: &str) -> Result<Json, JsonError> {
        let mut p = Parser { src: src.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.src.len() {
            return Err(p.err("trailing characters after document"));
        }
        Ok(v)
    }

    /// The object map, or `None` for non-objects.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// The array elements, or `None` for non-arrays.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// The string contents, or `None` for non-strings.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The number, or `None` for non-numbers.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// The number as a non-negative integer (rejects fractions/negatives).
    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().filter(|n| *n >= 0.0 && n.fract() == 0.0).map(|n| n as u64)
    }

    /// Object field lookup; `None` for non-objects or missing keys.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|m| m.get(key))
    }
}

struct Parser<'a> {
    src: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { offset: self.pos, message: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.src.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.keyword("true", Json::Bool(true)),
            Some(b'f') => self.keyword("false", Json::Bool(false)),
            Some(b'n') => self.keyword("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(_) => Err(self.err("unexpected character")),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn keyword(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.src[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("invalid literal, expected '{word}'")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}' in object")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']' in array")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{0008}'),
                    Some(b'f') => out.push('\u{000C}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // surrogate pair handling
                        let ch = if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("unpaired surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            char::from_u32(c).ok_or_else(|| self.err("invalid codepoint"))?
                        } else {
                            char::from_u32(cp).ok_or_else(|| self.err("invalid codepoint"))?
                        };
                        out.push(ch);
                    }
                    _ => return Err(self.err("invalid escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("control character in string")),
                Some(c) => {
                    // re-assemble UTF-8 multibyte sequences byte-wise
                    let start = self.pos - 1;
                    let len = utf8_len(c).ok_or_else(|| self.err("invalid utf-8"))?;
                    self.pos = start + len;
                    if self.pos > self.src.len() {
                        return Err(self.err("truncated utf-8"));
                    }
                    let s = std::str::from_utf8(&self.src[start..self.pos])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    out.push_str(s);
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("truncated \\u escape"))?;
            let d = (c as char).to_digit(16).ok_or_else(|| self.err("invalid hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.src[start..self.pos]).unwrap();
        text.parse::<f64>().map(Json::Num).map_err(|_| self.err("invalid number"))
    }
}

fn utf8_len(first: u8) -> Option<usize> {
    match first {
        0x00..=0x7F => Some(1),
        0xC0..=0xDF => Some(2),
        0xE0..=0xEF => Some(3),
        0xF0..=0xF7 => Some(4),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" -12.5e2 ").unwrap(), Json::Num(-1250.0));
        assert_eq!(Json::parse(r#""hi""#).unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(), Some("c"));
        assert_eq!(v.get("d"), Some(&Json::Null));
    }

    #[test]
    fn parses_escapes() {
        let v = Json::parse(r#""a\n\t\"\\Aé""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\n\t\"\\A\u{e9}");
    }

    #[test]
    fn parses_surrogate_pair() {
        let v = Json::parse(r#""😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "\u{1F600}");
    }

    #[test]
    fn parses_utf8_passthrough() {
        let v = Json::parse("\"caf\u{e9} \u{2603}\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "caf\u{e9} \u{2603}");
    }

    #[test]
    fn rejects_malformed() {
        for bad in ["", "{", "[1,", "{\"a\":}", "tru", "1.2.3", "\"\\x\"", "[1] x"] {
            assert!(Json::parse(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn u64_accessor_rejects_fractions_and_negatives() {
        assert_eq!(Json::parse("3").unwrap().as_u64(), Some(3));
        assert_eq!(Json::parse("3.5").unwrap().as_u64(), None);
        assert_eq!(Json::parse("-3").unwrap().as_u64(), None);
    }

    #[test]
    fn roundtrips_manifest_shapes() {
        let doc = r#"{"models": {"m": {"variants": [{"mu": 8, "x_shape": [8, 16, 16, 3]}]}}}"#;
        let v = Json::parse(doc).unwrap();
        let mu = v.get("models").unwrap().get("m").unwrap().get("variants").unwrap().as_arr().unwrap()[0]
            .get("mu")
            .unwrap()
            .as_u64();
        assert_eq!(mu, Some(8));
    }
}
