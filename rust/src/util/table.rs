//! Fixed-width terminal table renderer.
//!
//! One table helper shared by every CLI surface that prints aligned rows —
//! `mbs sweep`, `mbs frontier`, `mbs inspect` and the `--compare` trend
//! report all render through [`Table`] instead of hand-formatting columns.

use std::fmt::Write as _;

/// Fixed-width table printer (mirrors the paper tables).
///
/// ```
/// use mbs::util::table::Table;
///
/// let mut t = Table::new(&["batch", "w/ MBS"]);
/// t.row(&["128".to_string(), "88.9%".to_string()]);
/// let rendered = t.render();
/// assert!(rendered.starts_with("| batch |"));
/// ```
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// Start a table with the given column headers.
    pub fn new(header: &[&str]) -> Table {
        Table { header: header.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    /// Append one row; panics if the cell count does not match the header.
    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "row arity");
        self.rows.push(cells.to_vec());
    }

    /// Render with every column padded to its widest cell.
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for c in 0..ncol {
                widths[c] = widths[c].max(row[c].len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::from("|");
            for (c, cell) in cells.iter().enumerate() {
                let _ = write!(line, " {:width$} |", cell, width = widths[c]);
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.header, &widths));
        let mut sep = String::from("|");
        for w in &widths {
            let _ = write!(sep, "{}|", "-".repeat(w + 2));
        }
        sep.push('\n');
        out.push_str(&sep);
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["model", "acc"]);
        t.row(&["microresnet18".into(), "88.9".into()]);
        t.row(&["x".into(), "7".into()]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert_eq!(lines[0].len(), lines[2].len());
        assert_eq!(lines[1].len(), lines[3].len());
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn rejects_mismatched_row() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["only-one".into()]);
    }

    #[test]
    fn wide_cells_grow_columns() {
        let mut t = Table::new(&["k"]);
        t.row(&["a-much-wider-cell".into()]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines[0].len(), lines[1].len());
        assert!(lines[0].len() >= "a-much-wider-cell".len());
    }
}
