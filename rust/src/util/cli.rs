//! Minimal CLI argument parser (no clap offline).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, positional args
//! and subcommands. Unknown flags are an error so typos do not silently run
//! a default experiment.

use std::collections::BTreeMap;

/// Parsed command line: subcommand, positional args, `--key value` flags.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// First non-flag token.
    pub subcommand: Option<String>,
    /// Remaining non-flag tokens, in order.
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    known: Vec<String>,
}

impl Args {
    /// Parse `argv[1..]`; the first non-flag token becomes the subcommand.
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Args, String> {
        let mut args = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    args.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    args.flags.insert(stripped.to_string(), v);
                } else {
                    args.flags.insert(stripped.to_string(), "true".to_string());
                }
            } else if args.subcommand.is_none() {
                args.subcommand = Some(tok);
            } else {
                args.positional.push(tok);
            }
        }
        Ok(args)
    }

    /// Declare a flag as known (for `check_unknown`).
    pub fn declare(&mut self, keys: &[&str]) -> &mut Self {
        self.known.extend(keys.iter().map(|s| s.to_string()));
        self
    }

    /// Error on any flag that was never declared.
    pub fn check_unknown(&self) -> Result<(), String> {
        for k in self.flags.keys() {
            if !self.known.iter().any(|kk| kk == k) {
                return Err(format!("unknown flag --{k} (known: {})", self.known.join(", ")));
            }
        }
        Ok(())
    }

    /// Raw flag value, if present.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    /// Raw flag value or a default.
    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    /// Parse a flag value; `Ok(None)` when absent, `Err` on a bad value.
    pub fn get_parse<T: std::str::FromStr>(&self, key: &str) -> Result<Option<T>, String> {
        match self.get(key) {
            None => Ok(None),
            Some(v) => v
                .parse::<T>()
                .map(Some)
                .map_err(|_| format!("--{key}: cannot parse {v:?}")),
        }
    }

    /// Parse a flag value, falling back to `default` when absent.
    pub fn get_parse_or<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T, String> {
        Ok(self.get_parse(key)?.unwrap_or(default))
    }

    /// Was the flag given at all?
    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    /// Boolean flag: present (bare `--flag` parses as "true") and not
    /// explicitly "false"/"0".
    pub fn get_bool(&self, key: &str) -> bool {
        match self.get(key) {
            None => false,
            Some(v) => v != "false" && v != "0",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(toks: &[&str]) -> Args {
        Args::parse(toks.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse(&["train", "--model", "microresnet18", "--batch=128", "--verbose"]);
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.get("model"), Some("microresnet18"));
        assert_eq!(a.get("batch"), Some("128"));
        assert_eq!(a.get("verbose"), Some("true"));
    }

    #[test]
    fn typed_access() {
        let a = parse(&["x", "--n", "42", "--f", "1.5"]);
        assert_eq!(a.get_parse::<u32>("n").unwrap(), Some(42));
        assert_eq!(a.get_parse_or::<f64>("f", 0.0).unwrap(), 1.5);
        assert_eq!(a.get_parse_or::<u32>("missing", 7).unwrap(), 7);
        assert!(a.get_parse::<u32>("f").is_err());
    }

    #[test]
    fn unknown_flag_detection() {
        let mut a = parse(&["x", "--good", "1", "--bad", "2"]);
        a.declare(&["good"]);
        assert!(a.check_unknown().is_err());
        a.declare(&["bad"]);
        assert!(a.check_unknown().is_ok());
    }

    #[test]
    fn bool_flags() {
        let a = parse(&["x", "--on", "--off=false", "--zero", "0", "--named=yes"]);
        assert!(a.get_bool("on"));
        assert!(!a.get_bool("off"));
        assert!(!a.get_bool("zero"));
        assert!(a.get_bool("named"));
        assert!(!a.get_bool("absent"));
    }

    #[test]
    fn positional_args() {
        let a = parse(&["run", "one", "two"]);
        assert_eq!(a.positional, vec!["one", "two"]);
    }

    #[test]
    fn boolean_flag_before_subcommand_consumes_nothing() {
        let a = parse(&["--dry-run", "train"]);
        // "train" is consumed as the value of --dry-run per `--key value`
        // convention; callers that want pure booleans should use --key=true.
        assert_eq!(a.get("dry-run"), Some("train"));
    }
}
