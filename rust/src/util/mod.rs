//! In-tree substrates for crates the offline build cannot fetch:
//! JSON (serde_json), CLI (clap), PRNG (rand), property testing (proptest),
//! plus small stats helpers and the shared terminal-table renderer.

pub mod cli;
pub mod hash;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod table;
