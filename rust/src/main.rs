//! `mbs` — Micro-Batch Streaming CLI (leader entrypoint).
//!
//! Subcommands:
//!   train    train one configuration (MBS or native baseline), print report
//!   sweep    batch-size sweep at fixed capacity (one table-4/5 row block)
//!   inspect  show manifest variants, footprints and native-max batches
//!   info     platform / artifact summary

use std::process::ExitCode;

use mbs::coordinator::train;
use mbs::memory::{Footprint, MIB};
use mbs::metrics::Table;
use mbs::util::cli::Args;
use mbs::{Engine, Manifest, MbsError, TrainConfig};

fn main() -> ExitCode {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = match Args::parse(argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            return ExitCode::FAILURE;
        }
    };
    let result = match args.subcommand.as_deref() {
        Some("train") => cmd_train(&args),
        Some("sweep") => cmd_sweep(&args),
        Some("inspect") => cmd_inspect(&args),
        Some("info") => cmd_info(&args),
        _ => {
            print_usage();
            Ok(())
        }
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

fn print_usage() {
    println!(
        "mbs — Micro-Batch Streaming (IEEE Access 2023 reproduction)

USAGE: mbs <subcommand> [flags]

  train    --model <key> [--batch N] [--mu N|auto] [--epochs N] [--capacity-mib N]
           [--mbs true|false] [--norm paper|exact|none]
           [--streaming double-buffered|sync] [--size N] [--seed N]
           [--dataset-len N] [--eval-len N] [--lr F] [--lr-decay F]
           [--config file.cfg] [--artifacts dir] [--csv out.csv]
  sweep    --model <key> --batches 16,32,64 [same flags as train]
  inspect  [--artifacts dir]           variants, footprints, native max batch
  info     [--artifacts dir]           platform + artifact summary
"
    );
}

fn artifacts_dir(args: &Args) -> String {
    args.get_or("artifacts", "artifacts").to_string()
}

fn build_config(args: &Args) -> Result<TrainConfig, MbsError> {
    let model = args
        .get("model")
        .ok_or_else(|| MbsError::Config("--model is required".into()))?;
    let mut cfg = TrainConfig::default_for(model);
    if let Some(path) = args.get("config") {
        cfg.load_file(path)?;
    }
    cfg.apply_args(args)?;
    Ok(cfg)
}

fn cmd_train(args: &Args) -> Result<(), MbsError> {
    let cfg = build_config(args)?;
    let manifest = Manifest::load(artifacts_dir(args))?;
    let mut engine = Engine::new(manifest)?;
    println!(
        "[mbs] training {} batch={} mu={} mbs={} norm={} streaming={}",
        cfg.model,
        cfg.batch,
        cfg.mu,
        cfg.use_mbs,
        cfg.norm_mode.name(),
        cfg.streaming.name()
    );
    match train(&mut engine, &cfg) {
        Ok(report) => {
            let mut curves = mbs::metrics::CurveWriter::default();
            for (t, e) in report.train_epochs.iter().zip(report.eval_epochs.iter()) {
                println!(
                    "  epoch {:>3}  train loss {:.4}  eval loss {:.4}  eval metric {:.4}  ({:.2}s)",
                    t.epoch, t.mean_loss, e.mean_loss, e.primary_metric, t.wall.as_secs_f64()
                );
                curves.push("train", t.clone());
                curves.push("eval", e.clone());
            }
            println!(
                "[mbs] done: best metric {:.4}  updates {}  epoch wall {:.2}s  state {}",
                report.best_metric(),
                report.updates,
                report.epoch_wall_mean.as_secs_f64(),
                report.output_mode
            );
            if cfg.mu.is_auto() {
                println!("[mbs] planner chose mu={} (paper Alg. 1)", report.mu);
            }
            println!(
                "[mbs] device: capacity {:.1} MiB, native max batch {}",
                report.capacity_bytes as f64 / MIB as f64,
                report.native_max_batch
            );
            if let Some(path) = args.get("csv") {
                curves.write_file(std::path::Path::new(path))?;
                println!("[mbs] wrote {path}");
            }
            Ok(())
        }
        Err(e) if e.is_oom() => {
            println!("[mbs] FAILED (the paper's table cell): {e}");
            Err(e)
        }
        Err(e) => Err(e),
    }
}

fn cmd_sweep(args: &Args) -> Result<(), MbsError> {
    let cfg0 = build_config(args)?;
    let batches: Vec<usize> = args
        .get_or("batches", "16,32,64,128")
        .split(',')
        .map(|s| s.trim().parse().map_err(|_| MbsError::Config(format!("bad batch '{s}'"))))
        .collect::<Result<_, _>>()?;
    let manifest = Manifest::load(artifacts_dir(args))?;
    let mut engine = Engine::new(manifest)?;
    let mut table = Table::new(&["batch", "mu", "w/o MBS", "w/ MBS", "time w/o", "time w/"]);
    for &batch in &batches {
        // mu column: the MBS arm's resolved micro-batch (planner-derived
        // under the Auto default); "-" until that arm reports it
        let mut row = vec![batch.to_string(), "-".to_string()];
        for use_mbs in [false, true] {
            let mut cfg = cfg0.clone();
            cfg.batch = batch;
            cfg.use_mbs = use_mbs;
            match train(&mut engine, &cfg) {
                Ok(r) => {
                    if use_mbs {
                        row[1] = r.mu.to_string();
                    }
                    row.insert(
                        if use_mbs { 3 } else { 2 },
                        format!("{:.2}%", 100.0 * r.best_metric()),
                    );
                }
                Err(e) if e.is_oom() => {
                    row.insert(if use_mbs { 3 } else { 2 }, "Failed".into())
                }
                // the native arm can also fail because no exported
                // executable covers the batch (a Config error, not OOM) —
                // that's still a "Failed" table cell, not a sweep abort;
                // genuine config mistakes surface on the MBS arm
                Err(MbsError::Config(_)) if !use_mbs => {
                    row.insert(2, "Failed".into())
                }
                Err(e) => return Err(e),
            }
        }
        // timing columns re-run quickly with skip_eval? keep simple: dash
        row.push("-".into());
        row.push("-".into());
        table.row(&row);
    }
    println!("{}", table.render());
    Ok(())
}

fn cmd_inspect(args: &Args) -> Result<(), MbsError> {
    let manifest = Manifest::load(artifacts_dir(args))?;
    let mut table = Table::new(&[
        "model", "task", "opt", "size", "mu", "params (KiB)", "act/sample (KiB)",
        "resident (MiB)", "step(mu) (MiB)",
    ]);
    for entry in manifest.models.values() {
        for v in &entry.variants {
            let fp = Footprint::from_manifest(entry, v);
            table.row(&[
                entry.name.clone(),
                entry.task.clone(),
                entry.optimizer.kind.clone(),
                v.size.to_string(),
                v.mu.to_string(),
                format!("{:.0}", entry.param_bytes as f64 / 1024.0),
                format!("{:.0}", v.activation_bytes_per_sample as f64 / 1024.0),
                format!("{:.1}", fp.resident_bytes() as f64 / MIB as f64),
                format!("{:.1}", fp.step_bytes(v.mu) as f64 / MIB as f64),
            ]);
        }
    }
    println!("{}", table.render());
    println!("(paper table 2 mapping: mini-batch = largest exported mu, u-batch = mini/2)");
    Ok(())
}

fn cmd_info(args: &Args) -> Result<(), MbsError> {
    let manifest = Manifest::load(artifacts_dir(args))?;
    let engine = Engine::new(manifest)?;
    println!("platform: {}", engine.platform());
    println!("models:   {}", engine.manifest().models.len());
    let variants: usize = engine.manifest().models.values().map(|m| m.variants.len()).sum();
    println!("variants: {variants}");
    Ok(())
}
