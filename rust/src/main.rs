//! `mbs` — Micro-Batch Streaming CLI (leader entrypoint).
//!
//! Subcommands:
//!   train     train one configuration (MBS or native baseline), print report
//!   sweep     batch-size sweep at fixed capacity (one table-4/5 row block)
//!   frontier  capacity×batch feasibility grid -> table + BENCH_frontier.json
//!   fleet     multi-device placement + data-parallel streaming -> BENCH_fleet.json
//!   jobs      multi-tenant job set sharing one capacity -> table + BENCH_jobs.json
//!   chaos     exhaustive fault-space sweep over a job set -> BENCH_chaos.json
//!   bench     streaming hot-path benchmark -> machine-readable JSON
//!   inspect   show manifest variants, footprints and native-max batches
//!   info      platform / artifact summary

use std::process::ExitCode;
use std::sync::Arc;
use std::time::{Duration, Instant};

use mbs::coordinator::tenancy::{self, AdmissionOutcome, AdmissionRequest, JobAdmission};
use mbs::coordinator::{
    chaos, datasets_for, frontier, plan_placement, stream_epoch, train, train_fleet,
    train_jobs_faulted, DeviceReport, JobOutcome, JobsReport, NormalizationMode, Planner,
    ShardPlan, SplitPlan, StreamingPolicy,
};
use mbs::data::{loader, BufPool, Dataset, EpochPlan};
use mbs::memory::{Arena, FleetSpec, Footprint, MIB};
use mbs::util::json::Json;
use mbs::metrics::bench_report::{self, BenchReport, JsonValue};
use mbs::metrics::Table;
use mbs::runtime::{ArtifactManager, FaultPlan, MockCompiler, VariantKey};
use mbs::util::cli::Args;
use mbs::{Engine, JobSet, Manifest, MbsError, MicroBatchSpec, TrainConfig, TrainReport};

fn main() -> ExitCode {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = match Args::parse(argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            return ExitCode::FAILURE;
        }
    };
    let result = match args.subcommand.as_deref() {
        Some("train") => cmd_train(&args),
        Some("sweep") => cmd_sweep(&args),
        Some("frontier") => cmd_frontier(&args),
        Some("fleet") => cmd_fleet(&args),
        Some("jobs") => cmd_jobs(&args),
        Some("chaos") => cmd_chaos(&args),
        Some("bench") => cmd_bench(&args),
        Some("inspect") => cmd_inspect(&args),
        Some("info") => cmd_info(&args),
        _ => {
            print_usage();
            Ok(())
        }
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

fn print_usage() {
    println!(
        "mbs — Micro-Batch Streaming (IEEE Access 2023 reproduction)

USAGE: mbs <subcommand> [flags]

  train    --model <key> [--batch N] [--mu N|auto] [--epochs N] [--capacity-mib N]
           [--mbs true|false] [--norm paper|exact|none]
           [--streaming double-buffered|sync] [--overlap on|off|async|serial]
           [--prefetch N|auto] [--size N] [--seed N]
           [--dataset-len N] [--eval-len N] [--lr F] [--lr-decay F]
           [--checkpoint stem] [--checkpoint-every N] [--resume stem]
           [--faults spec.json]
           [--config file.cfg] [--artifacts dir] [--csv out.csv]
           --overlap on (default; alias: async) stages micro-batch j+1 on a
           dedicated upload-lane thread while j executes, so upload time is
           hidden in real wall clock; off (alias: serial) is the inline
           byte-identity oracle. --prefetch auto tunes the window per
           epoch from the stage timers. --checkpoint writes <stem>.bin +
           <stem>.json at the end (and every N updates with
           --checkpoint-every); --resume restores one before training.
           --faults arms the seeded deterministic fault injector: faulted
           runs checkpoint, release residency, re-plan mu, and replay —
           final report bit-identical to the fault-free run.
  sweep    --model <key> --batches 16,32,64 [same flags as train]
  frontier --capacities 1,2,4,8 --batches 8,32,64,128,256 [--dry-run=true]
           [--model <key> | --task classification|segmentation|lm]
           [--size N] [--eval-len N] [--overlap on|off] [--epochs N]
           [--dataset-len N] [--time-all=true]
           [--out BENCH_frontier.json] [--artifacts dir]
           classify every (capacity MiB x batch) point as native / MBS(mu) /
           OOM via the planner (pricing overlap residency unless
           --overlap off); without --dry-run, short timed epochs run along
           the feasibility boundary — or, with --time-all, over every
           feasible point (the full throughput surface) — needs --model +
           artifacts. --device-counts 1,2,4 adds the data-parallel axis:
           the largest feasible global batch per device count (per-device
           share = ceil(batch / devices)), emitted as the report's
           device_axis array.
  fleet    --devices 4,2,2|gpu0=4,gpu1=2 (MiB) | --spec fleet.json
           [--dry-run=true] [--out BENCH_fleet.json]
           [--compare prev.json] [--compare-threshold F] [--compare-strict=true]
           multi-device data parallelism over named arenas. --dry-run
           needs no artifacts: it bin-packs the spec's jobs across the
           devices (first-fit-decreasing, admission as the per-device
           oracle) and measures shard-assembly scaling — one worker
           thread per device, each with a thread-local named arena,
           assembling its contiguous ShardPlan block — vs a solo arm:
           [--task T] [--size N] [--batch N] [--mu N] [--dataset-len N]
           [--epochs N] [--seed N] [--prefetch N] [--min-speedup F]
           (exit non-zero when aggregate/solo < F). Without --dry-run,
           --model trains data-parallel through train_fleet (needs
           artifacts); the combined report is bit-identical to the solo
           run at the fleet's min device capacity.
           fleet_scaling_efficiency = aggregate / (devices x solo) is
           trend-tracked by --compare.
  jobs     --spec jobs.json [--capacity-mib N] [--dry-run=true]
           [--faults spec.json] [--out BENCH_jobs.json] [--artifacts dir]
           [--compare prev.json] [--compare-threshold F] [--compare-strict=true]
           run a multi-tenant job set against ONE shared capacity: the
           admission planner admits / shrinks-mu / rejects each job in
           spec order (pricing every async-lane job's durable staged input
           slot — the SUM across tenants), then a round-robin executor
           interleaves one micro-step per job per turn (per-job reports
           bit-identical to solo runs). --dry-run prints the admission
           table only — jobs naming a \"task\" use synthetic models, no
           artifacts needed. --compare trend-gates aggregate_items_per_sec
           and wall_overlap_efficiency against a previous BENCH_jobs.json.
           --faults spec.json injects seeded deterministic faults (arena /
           lane / step) per job: faulted jobs checkpoint + recover with
           bounded retries, retry-exhausted jobs are evicted while the
           survivors finish (per-job outcome / faults_injected / retries /
           recovered land in BENCH_jobs.json; in --dry-run the spec is
           validated and faults_planned reported, no artifacts needed).
           Exits non-zero when any job's outcome is failed — scripts and
           CI key off the exit code, not the table.
  chaos    --spec jobs.json [--capacity-mib N] [--dry-run=true]
           [--deadline-ms N] [--steps 0,3] [--seed N]
           [--out BENCH_chaos.json] [--artifacts dir]
           [--compare prev.json] [--compare-threshold F] [--compare-strict=true]
           exhaustive fault-space sweep: enumerate every (job, surface,
           step) injection point the fault-plan schema can express — step /
           arena / lane / compile / checkpoint faults plus wall-clock
           stalls on the lane, step and checkpoint surfaces — then run the
           set once per point under short watchdog deadlines and classify
           each run against a fault-free baseline. Recovered runs must be
           bit-identical (f64::to_bits fingerprint), evictions must be
           structured, and hung must be ZERO by construction: every
           injected stall outruns its deadline 3x, so the watchdog
           converts it into a recoverable fault. --dry-run round-trips
           every generated plan through the fault-spec parser, no
           artifacts needed. --compare trend-gates recovered_fraction.
           Exits non-zero if any point hangs or diverges.
  bench    --model <key> [same flags as train] [--out BENCH_streaming.json]
           [--compare prev.json] [--compare-threshold F] [--compare-strict=true]
           full streaming hot-path benchmark (items/sec, per-stage means,
           pool hit rate, overlap efficiency) -> machine-readable JSON;
           with --assemble-only it needs no compiled artifacts:
           --task classification|segmentation|lm
           [--size N] [--batch N] [--mu N] [--prefetch N] [--dataset-len N]
           [--epochs N] [--seed N] [--overlap on|off]
  inspect  [--artifacts dir]           variants, footprints, native max batch
  info     [--artifacts dir]           platform + artifact summary
"
    );
}

fn artifacts_dir(args: &Args) -> String {
    args.get_or("artifacts", "artifacts").to_string()
}

fn build_config(args: &Args) -> Result<TrainConfig, MbsError> {
    let model = args
        .get("model")
        .ok_or_else(|| MbsError::Config("--model is required".into()))?;
    let mut cfg = TrainConfig::default_for(model);
    if let Some(path) = args.get("config") {
        cfg.load_file(path)?;
    }
    cfg.apply_args(args)?;
    Ok(cfg)
}

/// Parse a `--key a,b,c` integer list.
fn parse_list<T: std::str::FromStr>(raw: &str, key: &str) -> Result<Vec<T>, MbsError> {
    raw.split(',')
        .map(|s| {
            s.trim()
                .parse()
                .map_err(|_| MbsError::Config(format!("bad {key} entry '{s}'")))
        })
        .collect()
}

fn cmd_train(args: &Args) -> Result<(), MbsError> {
    let cfg = build_config(args)?;
    let manifest = Manifest::load(artifacts_dir(args))?;
    let mut engine = Engine::new(manifest)?;
    println!(
        "[mbs] training {} batch={} mu={} mbs={} norm={} streaming={}",
        cfg.model,
        cfg.batch,
        cfg.mu,
        cfg.use_mbs,
        cfg.norm_mode.name(),
        cfg.streaming.name()
    );
    match train(&mut engine, &cfg) {
        Ok(report) => {
            let mut curves = mbs::metrics::CurveWriter::default();
            for (t, e) in report.train_epochs.iter().zip(report.eval_epochs.iter()) {
                println!(
                    "  epoch {:>3}  train loss {:.4}  eval loss {:.4}  eval metric {:.4}  ({:.2}s)",
                    t.epoch, t.mean_loss, e.mean_loss, e.primary_metric, t.wall.as_secs_f64()
                );
                curves.push("train", t.clone());
                curves.push("eval", e.clone());
            }
            println!(
                "[mbs] done: best metric {:.4}  updates {}  epoch wall {:.2}s  state {}",
                report.best_metric(),
                report.updates,
                report.epoch_wall_mean.as_secs_f64(),
                report.output_mode
            );
            if cfg.mu.is_auto() {
                println!("[mbs] planner chose mu={} (paper Alg. 1)", report.mu);
            }
            if report.overlap {
                println!(
                    "[mbs] overlap: {:.0}% of upload time hidden behind execution",
                    100.0 * report.stages.overlap_efficiency()
                );
                println!(
                    "[mbs] lane: {:.0}% of upload wall time measured inside execute windows",
                    100.0 * report.stages.wall_overlap_efficiency()
                );
            }
            if cfg.prefetch_auto {
                println!("[mbs] prefetch auto settled on {}", report.prefetch);
            }
            println!(
                "[mbs] device: capacity {:.1} MiB, native max batch {}, peak residency {:.1} MiB",
                report.capacity_bytes as f64 / MIB as f64,
                report.native_max_batch,
                report.ledger_peak_bytes as f64 / MIB as f64
            );
            if let Some(path) = args.get("csv") {
                curves.write_file(std::path::Path::new(path))?;
                println!("[mbs] wrote {path}");
            }
            Ok(())
        }
        Err(e) if e.is_oom() => {
            println!("[mbs] FAILED (the paper's table cell): {e}");
            Err(e)
        }
        Err(e) => Err(e),
    }
}

fn cmd_sweep(args: &Args) -> Result<(), MbsError> {
    let cfg0 = build_config(args)?;
    let batches: Vec<usize> = parse_list(args.get_or("batches", "16,32,64,128"), "--batches")?;
    let manifest = Manifest::load(artifacts_dir(args))?;
    let mut engine = Engine::new(manifest)?;
    let mut table = Table::new(&["batch", "mu", "w/o MBS", "w/ MBS", "time w/o", "time w/"]);
    for &batch in &batches {
        // mu column: the MBS arm's resolved micro-batch (planner-derived
        // under the Auto default); "-" until that arm reports it
        let mut row = vec![batch.to_string(), "-".to_string()];
        // paper "training time" columns: mean wall-clock per epoch per arm
        let mut times = ["-".to_string(), "-".to_string()];
        for use_mbs in [false, true] {
            let mut cfg = cfg0.clone();
            cfg.batch = batch;
            cfg.use_mbs = use_mbs;
            match train(&mut engine, &cfg) {
                Ok(r) => {
                    if use_mbs {
                        row[1] = r.mu.to_string();
                    }
                    row.insert(
                        if use_mbs { 3 } else { 2 },
                        format!("{:.2}%", 100.0 * r.best_metric()),
                    );
                    times[use_mbs as usize] =
                        format!("{:.2}s", r.epoch_wall_mean.as_secs_f64());
                }
                Err(e) if e.is_oom() => {
                    row.insert(if use_mbs { 3 } else { 2 }, "Failed".into())
                }
                // the native arm can also fail because no exported
                // executable covers the batch (a Config error, not OOM) —
                // that's still a "Failed" table cell, not a sweep abort;
                // genuine config mistakes surface on the MBS arm
                Err(MbsError::Config(_)) if !use_mbs => {
                    row.insert(2, "Failed".into())
                }
                Err(e) => return Err(e),
            }
        }
        let [time_native, time_mbs] = times;
        row.push(time_native);
        row.push(time_mbs);
        table.row(&row);
    }
    println!("{}", table.render());
    Ok(())
}

/// `frontier` — classify a (capacity MiB × batch) grid via the planner and
/// emit an aligned table plus `BENCH_frontier.json` (shared bench schema).
///
/// Dry-run mode is planner-only: with `--model` it classifies against the
/// real manifest metadata (artifacts' manifest.json, no compiled
/// executables needed); without it, a synthetic `--task` model entry is
/// used, so the subcommand runs on a clean checkout — CI's smoke job.
/// Classification prices the overlapped pipeline's in-flight input slot
/// unless `--overlap off`. Without `--dry-run`, short timed epochs run
/// along the feasibility boundary (the largest feasible batch per
/// capacity) — or over every feasible point with `--time-all`, producing
/// the full throughput surface — and attach measured items/sec +
/// per-stage means to those grid points; that path trains for real and
/// therefore needs `--model` and compiled artifacts.
fn cmd_frontier(args: &Args) -> Result<(), MbsError> {
    let dry_run = args.get_bool("dry-run");
    let time_all = args.get_bool("time-all");
    if dry_run && time_all {
        return Err(MbsError::Config(
            "--time-all runs timed epochs, which --dry-run skips; drop one of the flags".into(),
        ));
    }
    let out = args.get_or("out", "BENCH_frontier.json").to_string();
    let capacities_mib: Vec<u64> =
        parse_list(args.get_or("capacities", "1,2,4,8"), "--capacities")?;
    let batches: Vec<usize> =
        parse_list(args.get_or("batches", "8,32,64,128,256"), "--batches")?;
    let eval_len: usize = args.get_parse_or("eval-len", 0).map_err(MbsError::Config)?;
    let overlap = parse_overlap_flag(args)?;
    if capacities_mib.contains(&0) {
        return Err(MbsError::Config("--capacities must be positive MiB values".into()));
    }
    let capacities_bytes: Vec<u64> = capacities_mib.iter().map(|&m| m * MIB).collect();

    // model resolution: --model classifies the real manifest entry;
    // otherwise a synthetic task-shaped entry (no artifacts at all)
    let (entry, manifest) = match args.get("model") {
        Some(model) => {
            let manifest = Manifest::load(artifacts_dir(args))?;
            let entry = manifest.model(model)?.clone();
            (entry, Some(manifest))
        }
        None => {
            if !dry_run {
                return Err(MbsError::Config(
                    "frontier timed runs need --model (and compiled artifacts); \
                     add --dry-run=true for the planner-only sweep"
                        .into(),
                ));
            }
            (frontier::synthetic_entry(args.get_or("task", "classification"))?, None)
        }
    };
    let size = match args.get_parse("size").map_err(MbsError::Config)? {
        Some(s) => s,
        None => entry.default_size,
    };
    println!(
        "[mbs] frontier: model={} size={size} capacities(MiB)={capacities_mib:?} \
         batches={batches:?} dry_run={dry_run} overlap={}",
        entry.name,
        if overlap { "on" } else { "off" }
    );
    let mut grid = frontier::FrontierGrid::sweep(
        &entry,
        size,
        eval_len,
        &capacities_bytes,
        &batches,
        overlap,
    )?;

    if !dry_run {
        let manifest = manifest.expect("--model checked above");
        let mut engine = Engine::new(manifest)?;
        let epochs: usize = args.get_parse_or("epochs", 1).map_err(MbsError::Config)?;
        let dataset_len: usize =
            args.get_parse_or("dataset-len", 256).map_err(MbsError::Config)?;
        // --time-all fills the whole feasible region (the fig.-3-style
        // throughput surface); the default pays only for the boundary
        let targets = if time_all { grid.feasible_points() } else { grid.boundary() };
        let scope = if time_all { "feasible point" } else { "boundary point" };
        for (capacity_bytes, batch) in targets {
            let mut cfg = TrainConfig::default_for(&entry.name);
            cfg.size = Some(size);
            cfg.batch = batch;
            cfg.epochs = epochs;
            cfg.dataset_len = dataset_len;
            cfg.eval_len = eval_len;
            cfg.skip_eval = true;
            cfg.mu = MicroBatchSpec::Auto;
            cfg.overlap = overlap;
            cfg.capacity_mib = Some(capacity_bytes / MIB);
            println!(
                "[mbs] frontier: timing {scope} capacity={} MiB batch={batch}",
                capacity_bytes / MIB
            );
            match train(&mut engine, &cfg) {
                Ok(report) => {
                    if let Some(p) = grid.point_mut(capacity_bytes, batch) {
                        p.timing = Some(boundary_timing(&report));
                    }
                }
                // classification said feasible; a runtime refusal (e.g. a
                // compile failure from the artifact manager's backend —
                // unexported variants now compile on demand instead of
                // being missing) downgrades to an untimed point rather
                // than aborting the sweep
                Err(e) => eprintln!(
                    "[mbs] frontier: timed run failed at capacity={} MiB batch={batch}: {e}",
                    capacity_bytes / MIB
                ),
            }
        }
        if let Some(stats) = engine.artifact_stats() {
            println!(
                "[mbs] frontier: artifact cache — {} compiled on demand, {} hits, \
                 {} coalesced, {} evicted ({} corrupt)",
                stats.compiles,
                stats.hits,
                stats.coalesced,
                stats.evictions,
                stats.corrupt_evictions
            );
        }
    }

    println!("{}", grid.render_table().render());
    println!(
        "(native = whole batch in one step; mu=K xN = MBS with N accumulation steps; \
         OOM = paper's Failed cell)"
    );
    let mut rep = grid.to_report(dry_run);
    if !dry_run {
        rep.str_field("timed_scope", if time_all { "all" } else { "boundary" });
    }
    // --device-counts 1,2,4: the data-parallel axis — for each capacity and
    // device count, the largest global batch whose per-device share
    // (ceil(batch / devices)) still classifies feasible on one device
    if let Some(raw) = args.get("device-counts") {
        let counts: Vec<usize> = parse_list(raw, "--device-counts")?;
        let axis = frontier::DeviceAxis::sweep(
            &entry,
            size,
            eval_len,
            &capacities_bytes,
            &counts,
            &batches,
            overlap,
        )?;
        println!("{}", axis.render_table().render());
        println!(
            "(device axis: per-device share = ceil(batch / devices); a batch is feasible \
             when the share classifies native or MBS on one device of that capacity)"
        );
        rep.field("device_axis", axis.to_json_value());
    }
    rep.write(&out)?;
    println!("[mbs] wrote {out}");
    Ok(())
}

/// Parse the shared `--overlap on|off` flag (default on). The lane-mode
/// spellings are accepted everywhere the switch is: `async` (dedicated
/// upload-lane staging thread) == `on`, `serial` (inline oracle) == `off`.
fn parse_overlap_flag(args: &Args) -> Result<bool, MbsError> {
    let raw = args.get_or("overlap", "on");
    match raw.to_ascii_lowercase().as_str() {
        "async" => Ok(true),
        "serial" => Ok(false),
        other => mbs::config::parse_on_off(other).ok_or_else(|| {
            MbsError::Config(format!("--overlap: expected on|off|async|serial, got {raw:?}"))
        }),
    }
}

/// Summarize a timed boundary run for the frontier report.
fn boundary_timing(report: &TrainReport) -> frontier::BoundaryTiming {
    let micro_steps: u64 = report.train_epochs.iter().map(|e| e.micro_steps as u64).sum();
    let samples: u64 = report.train_epochs.iter().map(|e| e.samples as u64).sum();
    let train_wall: f64 = report.train_epochs.iter().map(|e| e.wall.as_secs_f64()).sum();
    frontier::BoundaryTiming {
        items_per_sec: if train_wall > 0.0 { samples as f64 / train_wall } else { 0.0 },
        epoch_wall_mean_s: report.epoch_wall_mean.as_secs_f64(),
        micro_steps,
        updates: report.updates,
        stages: report.stages,
        pool: report.pool,
    }
}

/// `fleet` — multi-device data parallelism over named arenas. Dry-run
/// needs no artifacts: it bin-packs the spec's jobs across the devices
/// (first-fit-decreasing with tenancy as the per-device oracle) and
/// measures shard-assembly scaling — one worker thread per device, each
/// owning a thread-local named [`Arena`] and staging pool, assembling its
/// contiguous [`ShardPlan`] block of every mini-batch — against a
/// one-worker solo arm. Full mode trains data-parallel through
/// [`train_fleet`] (combined report bit-identical to the solo run at the
/// fleet's min device capacity). Both emit `BENCH_fleet.json`;
/// `fleet_scaling_efficiency = aggregate / (devices × solo)` is the
/// trend key `--compare` gates.
fn cmd_fleet(args: &Args) -> Result<(), MbsError> {
    let dry_run = args.get_bool("dry-run");
    let out = args.get_or("out", "BENCH_fleet.json").to_string();
    let spec_path = args.get("spec");
    let fleet = match (args.get("devices"), spec_path) {
        (Some(list), _) => FleetSpec::parse(list)?,
        (None, Some(path)) => {
            FleetSpec::from_json(&Json::parse(&std::fs::read_to_string(path)?)?)?
        }
        (None, None) => {
            return Err(MbsError::Config(
                "fleet needs --devices 4,2,2 (MiB capacities) or --spec fleet.json".into(),
            ))
        }
    };
    let roster: Vec<String> = fleet
        .devices
        .iter()
        .map(|d| format!("{}={} MiB", d.name, d.capacity_bytes / MIB))
        .collect();
    println!("[mbs] fleet: {} device(s) — {}", fleet.len(), roster.join(", "));

    if dry_run {
        return fleet_dry_run(args, &fleet, spec_path, &out);
    }

    // full mode: data-parallel training through the shared runtime —
    // per-device arenas and upload lanes, global-order execution
    let cfg = build_config(args)?;
    let manifest = Manifest::load(artifacts_dir(args))?;
    let mut engine = Engine::new(manifest)?;
    let fr = train_fleet(&mut engine, &cfg, &fleet)?;
    let r = &fr.report;
    let mut table =
        Table::new(&["device", "capacity (MiB)", "micro steps", "samples", "peak (MiB)"]);
    for d in &fr.devices {
        table.row(&[
            d.name.clone(),
            (d.capacity_bytes / MIB).to_string(),
            d.micro_steps.to_string(),
            d.samples.to_string(),
            format!("{:.2}", d.ledger_peak_bytes as f64 / MIB as f64),
        ]);
    }
    println!("{}", table.render());
    let t = boundary_timing(r);
    println!(
        "[mbs] fleet: batch={} mu={} updates={} — {:.1} items/sec, best metric {:.4}",
        r.batch,
        r.mu,
        r.updates,
        t.items_per_sec,
        r.best_metric()
    );
    let mut rep = BenchReport::new("fleet", "train");
    rep.uint("devices", fr.devices.len() as u64)
        .str_field("model", &r.model)
        .uint("batch", r.batch as u64)
        .uint("mu", r.mu as u64)
        .uint("updates", r.updates)
        .num("items_per_sec", t.items_per_sec, 3)
        .num("best_metric", r.best_metric(), 6)
        .num("wall_overlap_efficiency", r.stages.wall_overlap_efficiency(), 4)
        .field("fleet", fleet_devices_value(&fr.devices));
    rep.write(&out)?;
    println!("[mbs] wrote {out}");
    trend_compare(args, &out)
}

/// The per-device rows of a train-mode `BENCH_fleet.json`.
fn fleet_devices_value(devices: &[DeviceReport]) -> JsonValue {
    JsonValue::Arr(
        devices
            .iter()
            .map(|d| {
                let mut j = JsonValue::obj();
                j.push("name", JsonValue::Str(d.name.clone()));
                j.push("capacity_mib", JsonValue::UInt(d.capacity_bytes / MIB));
                j.push("micro_steps", JsonValue::UInt(d.micro_steps));
                j.push("samples", JsonValue::UInt(d.samples));
                j.push(
                    "ledger_peak_mib",
                    JsonValue::fixed(d.ledger_peak_bytes as f64 / MIB as f64, 3),
                );
                j
            })
            .collect(),
    )
}

/// The fleet dry-run: placement over the spec's jobs (if present), then
/// the two-arm shard-assembly scaling measurement.
fn fleet_dry_run(
    args: &Args,
    fleet: &FleetSpec,
    spec_path: Option<&str>,
    out: &str,
) -> Result<(), MbsError> {
    // placement: bin-pack the spec's jobs across the devices (specs with a
    // "devices" array only skip straight to the scaling bench)
    let placement_value = match spec_path {
        Some(path) if Json::parse(&std::fs::read_to_string(path)?)?.get("jobs").is_some() => {
            Some(fleet_placement_dry_run(args, fleet, path)?)
        }
        _ => None,
    };

    let task = args.get_or("task", "classification").to_string();
    let size: usize = bench_flag(args, "size", 16)?;
    let batch: usize = bench_flag(args, "batch", 64)?;
    let mu: usize = bench_flag(args, "mu", 8)?;
    let dataset_len: usize = bench_flag(args, "dataset-len", 8192)?;
    let epochs: usize = bench_flag(args, "epochs", 3)?;
    let seed: u64 = bench_flag(args, "seed", 0)?;
    let prefetch: usize = bench_flag(args, "prefetch", 2)?;
    let min_speedup: f64 = bench_flag(args, "min-speedup", 0.0)?;
    if batch == 0 || mu == 0 || dataset_len == 0 || epochs == 0 {
        return Err(MbsError::Config(
            "fleet bench needs positive batch, mu, dataset-len and epochs".into(),
        ));
    }
    let mut cfg = TrainConfig::default_for("fleet-bench");
    cfg.dataset_len = dataset_len;
    cfg.eval_len = 0;
    cfg.seed = seed;
    let (ds, _eval): (Arc<dyn Dataset>, _) = datasets_for(&task, size, &cfg)?;
    let devices = fleet.len();
    println!(
        "[mbs] fleet: assembly scaling, task={task} size={size} batch={batch} mu={mu} \
         dataset-len={dataset_len} epochs={epochs} workers={devices}"
    );

    let solo_secs =
        fleet_assembly_arm(&ds, fleet, 1, batch, mu, dataset_len, epochs, seed, prefetch)?;
    let fleet_secs =
        fleet_assembly_arm(&ds, fleet, devices, batch, mu, dataset_len, epochs, seed, prefetch)?;
    let total_items = (dataset_len * epochs) as f64;
    let rate = |secs: f64| if secs > 0.0 { total_items / secs } else { 0.0 };
    let solo_rate = rate(solo_secs);
    let aggregate_rate = rate(fleet_secs);
    let speedup = if solo_rate > 0.0 { aggregate_rate / solo_rate } else { 0.0 };
    let efficiency = if devices > 0 { speedup / devices as f64 } else { 0.0 };
    println!(
        "[mbs] fleet: solo {solo_rate:.1} items/sec, {devices} worker(s) \
         {aggregate_rate:.1} items/sec — speedup {speedup:.2}x, scaling efficiency \
         {efficiency:.3}"
    );

    let mut rep = BenchReport::new("fleet", "dry-run");
    rep.uint("devices", devices as u64)
        .uint("total_capacity_mib", fleet.total_capacity() / MIB)
        .str_field("task", &task)
        .uint("size", size as u64)
        .uint("batch", batch as u64)
        .uint("mu", mu as u64)
        .uint("dataset_len", dataset_len as u64)
        .uint("epochs", epochs as u64)
        .num("solo_items_per_sec", solo_rate, 3)
        .num("aggregate_items_per_sec", aggregate_rate, 3)
        .num("speedup", speedup, 4)
        // the trend key: aggregate over (devices x solo), both co-measured
        // in this process — a drop means device parallelism stopped paying
        .num("fleet_scaling_efficiency", efficiency, 4)
        .field(
            "fleet",
            JsonValue::Arr(
                fleet
                    .devices
                    .iter()
                    .map(|d| {
                        let mut j = JsonValue::obj();
                        j.push("name", JsonValue::Str(d.name.clone()));
                        j.push("capacity_mib", JsonValue::UInt(d.capacity_bytes / MIB));
                        j
                    })
                    .collect(),
            ),
        );
    if let Some(p) = placement_value {
        rep.field("placement", p);
    }
    rep.write(out)?;
    println!("[mbs] wrote {out}");
    trend_compare(args, out)?;
    if speedup < min_speedup {
        return Err(MbsError::Runtime(format!(
            "fleet assembly speedup {speedup:.3}x is below --min-speedup {min_speedup:.3}x"
        )));
    }
    Ok(())
}

/// Placement dry-run: admit the spec's jobs across the fleet's devices and
/// print the per-job verdict table (device column included). Jobs naming a
/// `"task"` use the synthetic stand-in models (no artifacts); jobs naming
/// a `"model"` classify against the real manifest metadata.
fn fleet_placement_dry_run(
    args: &Args,
    fleet: &FleetSpec,
    spec_path: &str,
) -> Result<JsonValue, MbsError> {
    let set = JobSet::load(spec_path)?;
    let manifest = if set.jobs.iter().any(|j| j.task.is_none()) {
        Some(Manifest::load(artifacts_dir(args))?)
    } else {
        None
    };
    let mut requests = Vec::with_capacity(set.jobs.len());
    for spec in &set.jobs {
        let entry = match &spec.task {
            Some(task) => frontier::synthetic_entry(task)?,
            None => manifest
                .as_ref()
                .expect("loaded above: some job names a model")
                .model(&spec.cfg.model)?
                .clone(),
        };
        requests.push(AdmissionRequest::from_spec(spec, entry));
    }
    let plan = plan_placement(&requests, fleet);

    let mut table =
        Table::new(&["job", "model", "batch", "device", "admission", "mu", "n_smu"]);
    let mut rows = Vec::with_capacity(requests.len());
    for (req, p) in requests.iter().zip(&plan.placements) {
        let mut j = JsonValue::obj();
        j.push("name", JsonValue::Str(p.name.clone()));
        j.push("model", JsonValue::Str(req.entry.name.clone()));
        j.push("batch", JsonValue::UInt(req.batch as u64));
        j.push("admission", JsonValue::Str(p.label().to_string()));
        match (&p.device, &p.outcome) {
            (Some(dev), AdmissionOutcome::Admitted { resolution, .. }) => {
                table.row(&[
                    p.name.clone(),
                    req.entry.name.clone(),
                    req.batch.to_string(),
                    dev.clone(),
                    p.label().to_string(),
                    resolution.mu.to_string(),
                    req.batch.div_ceil(resolution.mu).to_string(),
                ]);
                j.push("device", JsonValue::Str(dev.clone()));
                j.push("mu", JsonValue::UInt(resolution.mu as u64));
                j.push(
                    "n_smu",
                    JsonValue::UInt(req.batch.div_ceil(resolution.mu) as u64),
                );
            }
            _ => {
                table.row(&[
                    p.name.clone(),
                    req.entry.name.clone(),
                    req.batch.to_string(),
                    "-".into(),
                    "reject".into(),
                    "-".into(),
                    "-".into(),
                ]);
                if let AdmissionOutcome::Rejected { reason } = &p.outcome {
                    println!("[mbs] fleet: '{}' rejected: {reason}", p.name);
                    j.push("reason", JsonValue::Str(reason.clone()));
                }
            }
        }
        rows.push(j);
    }
    println!("{}", table.render());
    println!(
        "[mbs] fleet: {} of {} jobs placed across {} device(s)",
        plan.placed(),
        requests.len(),
        fleet.len()
    );
    Ok(JsonValue::Arr(rows))
}

/// One arm of the fleet assembly bench: `threads` workers, each owning a
/// thread-local named [`Arena`] (arenas are `Rc`-backed and never cross
/// threads — the fleet is memory-accounting parallelism) and its own warm
/// staging pool, assembling its contiguous [`ShardPlan`] block of every
/// mini-batch. Both arms perform the identical total work (every
/// micro-batch of every mini-batch of every epoch, assembled exactly
/// once); the return value is the arm's makespan in seconds.
#[allow(clippy::too_many_arguments)]
fn fleet_assembly_arm(
    ds: &Arc<dyn Dataset>,
    fleet: &FleetSpec,
    threads: usize,
    batch: usize,
    mu: usize,
    dataset_len: usize,
    epochs: usize,
    seed: u64,
    prefetch: usize,
) -> Result<f64, MbsError> {
    let t0 = Instant::now();
    let results: Vec<Result<(), MbsError>> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..threads)
            .map(|d| {
                let ds = ds.clone();
                let dev = fleet.devices[d % fleet.devices.len()].clone();
                s.spawn(move || -> Result<(), MbsError> {
                    let mut ledger =
                        Arena::named(&dev.name, dev.capacity_bytes).tenant("assembly");
                    let pool = BufPool::for_prefetch(prefetch);
                    pool.warm(BufPool::buffers_for(prefetch), ds.as_ref(), mu);
                    // staged-input pricing: mu samples of f32-sized x + y
                    let staged = (mu * (ds.x_elems() + ds.y_elems()) * 4) as u64;
                    for epoch in 0..epochs {
                        let plan = EpochPlan::new(dataset_len, batch, seed, epoch as u64);
                        for b in 0..plan.num_batches() {
                            let indices = plan.batch_indices(b);
                            let split = SplitPlan::new(indices.len(), mu);
                            let (lo, hi) = ShardPlan::new(split.n_smu(), threads).block(d);
                            for j in lo..hi {
                                let mut mb = pool.lease();
                                let a = ledger.alloc("staged shard", staged)?;
                                loader::assemble_into(&mut mb, ds.as_ref(), indices, mu, j);
                                std::hint::black_box(&mb);
                                ledger.free(a)?;
                                pool.give(mb);
                            }
                        }
                    }
                    Ok(())
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("fleet assembly worker panicked"))
            .collect()
    });
    for r in results {
        r?;
    }
    Ok(t0.elapsed().as_secs_f64())
}

/// `jobs` — multi-tenant device sharing: admit a job set against one
/// shared `--capacity-mib` (admit / shrink-mu / reject per job, in spec
/// order) and, unless `--dry-run`, run the admitted jobs through the
/// round-robin interleaved executor. Emits a per-job table plus
/// `BENCH_jobs.json` (shared bench schema; the aggregate throughput key
/// `aggregate_items_per_sec` is trend-tracked by `mbs bench --compare`).
///
/// Dry-run mode is admission-only arithmetic: jobs naming a `"task"` use
/// the synthetic stand-in models (clean checkout — CI's smoke), jobs
/// naming a `"model"` classify against the real manifest metadata.
/// Training mode needs compiled artifacts for every job's model.
fn cmd_jobs(args: &Args) -> Result<(), MbsError> {
    let spec_path = args
        .get("spec")
        .ok_or_else(|| MbsError::Config("--spec jobs.json is required".into()))?;
    let dry_run = args.get_bool("dry-run");
    let out = args.get_or("out", "BENCH_jobs.json").to_string();
    let mut set = JobSet::load(spec_path)?;
    if let Some(mib) = args.get_parse::<u64>("capacity-mib").map_err(MbsError::Config)? {
        set.capacity_mib = Some(mib);
    }
    let capacity_mib = set.capacity_mib.ok_or_else(|| {
        MbsError::Config(
            "no shared capacity: set 'capacity_mib' in the spec or pass --capacity-mib".into(),
        )
    })?;
    if capacity_mib == 0 {
        return Err(MbsError::Config("capacity must be positive MiB".into()));
    }
    let capacity_bytes = capacity_mib * MIB;
    println!(
        "[mbs] jobs: {} job(s) sharing {capacity_mib} MiB (spec {spec_path}, dry_run={dry_run})",
        set.jobs.len()
    );

    // a fault spec arms the deterministic-injection recovery state machine
    // (train mode) or annotates the admission plan (dry-run)
    let plan = match args.get("faults") {
        Some(path) => Some(FaultPlan::load(path)?),
        None => None,
    };

    if dry_run {
        return jobs_dry_run(args, &set, capacity_bytes, &out, plan.as_ref());
    }

    // train for real: every job must name a manifest model
    let manifest = Manifest::load(artifacts_dir(args))?;
    let mut engine = Engine::new(manifest)?;
    let report = train_jobs_faulted(&mut engine, &set, capacity_bytes, plan.as_ref())?;
    // the acceptance invariant, restated at the top level: the arena
    // refuses any charge that would exceed capacity, so the recorded
    // cross-job peak must sit within it
    assert!(
        report.arena_peak_bytes <= report.capacity_bytes,
        "cross-job ledger peak {} exceeded capacity {}",
        report.arena_peak_bytes,
        report.capacity_bytes
    );

    let mut table = Table::new(&[
        "job", "model", "batch", "admission", "outcome", "mu", "n_smu", "items/sec",
        "best metric", "updates",
    ]);
    for job in &report.jobs {
        match (&job.report, &job.admission) {
            (Some(r), AdmissionOutcome::Admitted { .. }) => {
                let t = boundary_timing(r);
                table.row(&[
                    job.name.clone(),
                    r.model.clone(),
                    r.batch.to_string(),
                    job.admission.label().to_string(),
                    job.outcome.as_str().to_string(),
                    r.mu.to_string(),
                    r.batch.div_ceil(r.mu).to_string(),
                    format!("{:.1}", t.items_per_sec),
                    format!("{:.4}", r.best_metric()),
                    r.updates.to_string(),
                ]);
            }
            // no report: rejected at admission, or admitted but evicted
            // after exhausting its recovery retries (outcome = failed)
            _ => {
                table.row(&[
                    job.name.clone(),
                    "-".into(),
                    "-".into(),
                    job.admission.label().to_string(),
                    job.outcome.as_str().to_string(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                ]);
                if let AdmissionOutcome::Rejected { reason } = &job.admission {
                    println!("[mbs] jobs: '{}' rejected: {reason}", job.name);
                }
                if let Some(err) = &job.error {
                    println!("[mbs] jobs: '{}' failed: {err}", job.name);
                }
            }
        }
    }
    println!("{}", table.render());
    println!(
        "[mbs] jobs: {} of {} admitted — aggregate {:.1} items/sec, arena peak {:.2} / {:.2} MiB",
        report.admitted(),
        report.jobs.len(),
        report.aggregate_items_per_sec(),
        report.arena_peak_bytes as f64 / MIB as f64,
        report.capacity_bytes as f64 / MIB as f64
    );

    // set-level wall-clock overlap: fold every admitted job's stage timers
    // so the trend key reflects the whole interleaved run, not one tenant
    let mut set_stages = mbs::metrics::StageTimers::default();
    for job in report.jobs.iter().filter_map(|j| j.report.as_ref()) {
        set_stages.merge(&job.stages);
    }
    let mut rep = BenchReport::new("jobs", "train");
    rep.uint("capacity_mib", capacity_mib)
        .str_field("set_class", jobs_set_class(&report))
        .uint("admitted", report.admitted() as u64)
        .num("aggregate_items_per_sec", report.aggregate_items_per_sec(), 3)
        // trend-tracked: fraction of lane upload wall time measured (by
        // thread timestamps) inside some job's device-execute window
        .num("wall_overlap_efficiency", set_stages.wall_overlap_efficiency(), 4)
        .num("arena_peak_mib", report.arena_peak_bytes as f64 / MIB as f64, 3)
        .num("total_wall_s", report.total_wall.as_secs_f64(), 6)
        .field(
            "resilience",
            bench_report::resilience_value(
                report.jobs.iter().map(|j| j.faults_injected).sum(),
                report.jobs.iter().map(|j| j.retries).sum(),
                report.jobs.iter().map(|j| j.recovered).sum(),
            ),
        )
        .field("jobs", jobs_train_value(&report));
    rep.write(&out)?;
    println!("[mbs] wrote {out}");
    trend_compare(args, &out)?;

    // a failed job must fail the process: the report records the eviction,
    // but scripts and CI key off the exit code
    let failed: Vec<&str> = report
        .jobs
        .iter()
        .filter(|j| j.outcome == JobOutcome::Failed)
        .map(|j| j.name.as_str())
        .collect();
    if !failed.is_empty() {
        return Err(MbsError::Runtime(format!(
            "{} job(s) failed: {}",
            failed.len(),
            failed.join(", ")
        )));
    }
    Ok(())
}

/// The set-level verdict folded from the per-job admissions.
fn jobs_set_class(report: &JobsReport) -> &'static str {
    frontier::SetFeasibility::from_outcomes(report.jobs.iter().map(|j| &j.admission))
        .class_name()
}

/// `mbs chaos` — the exhaustive fault-space sweep (see [`chaos`]): every
/// `(job, surface, step)` injection point the fault-plan schema can
/// express, run under short watchdog deadlines and classified against a
/// fault-free baseline. The process fails if any point hangs or diverges.
fn cmd_chaos(args: &Args) -> Result<(), MbsError> {
    let spec_path = args
        .get("spec")
        .ok_or_else(|| MbsError::Config("--spec jobs.json is required".into()))?;
    let dry_run = args.get_bool("dry-run");
    let out = args.get_or("out", "BENCH_chaos.json").to_string();
    let mut set = JobSet::load(spec_path)?;
    if let Some(mib) = args.get_parse::<u64>("capacity-mib").map_err(MbsError::Config)? {
        set.capacity_mib = Some(mib);
    }
    let capacity_mib = set.capacity_mib.ok_or_else(|| {
        MbsError::Config(
            "no shared capacity: set 'capacity_mib' in the spec or pass --capacity-mib".into(),
        )
    })?;
    if capacity_mib == 0 {
        return Err(MbsError::Config("capacity must be positive MiB".into()));
    }
    let capacity_bytes = capacity_mib * MIB;
    let cfg = chaos::ChaosCfg {
        deadline_ms: args.get_parse_or("deadline-ms", 250).map_err(MbsError::Config)?,
        steps: match args.get("steps") {
            Some(raw) => parse_list(raw, "--steps")?,
            None => vec![0, 3],
        },
        seed: args.get_parse_or("seed", 7).map_err(MbsError::Config)?,
    };
    let points = chaos::enumerate(&set, &cfg.steps);
    println!(
        "[mbs] chaos: {} injection point(s) over {} job(s) sharing {capacity_mib} MiB \
         (spec {spec_path}, deadline {} ms, steps {:?}, dry_run={dry_run})",
        points.len(),
        set.jobs.len(),
        cfg.deadline_ms,
        cfg.steps
    );

    if dry_run {
        // artifact-free half: prove every generated plan is a legal spec
        // file a user could have committed
        for point in &points {
            chaos::validate_point(point, &cfg)?;
        }
        let mut per: std::collections::BTreeMap<&'static str, u64> =
            std::collections::BTreeMap::new();
        for p in &points {
            *per.entry(p.injection.name()).or_default() += 1;
        }
        let mut table = Table::new(&["surface", "points"]);
        let mut surfaces: Vec<JsonValue> = Vec::new();
        for (surface, n) in &per {
            table.row(&[surface.to_string(), n.to_string()]);
            let mut j = JsonValue::obj();
            j.push("surface", JsonValue::Str(surface.to_string()));
            j.push("points", JsonValue::UInt(*n));
            surfaces.push(j);
        }
        println!("{}", table.render());
        println!(
            "[mbs] chaos: every generated plan survived the fault-spec round-trip"
        );
        let mut rep = BenchReport::new("chaos", "dry-run");
        rep.uint("capacity_mib", capacity_mib)
            .uint("points", points.len() as u64)
            .uint("deadline_ms", cfg.deadline_ms)
            .field("surfaces", JsonValue::Arr(surfaces));
        rep.write(&out)?;
        println!("[mbs] wrote {out}");
        return Ok(());
    }

    let manifest = Manifest::load(artifacts_dir(args))?;
    let mut engine = Engine::new(manifest)?;
    let report = chaos::run_sweep(&mut engine, &set, capacity_bytes, &cfg)?;

    let by = report.by_surface();
    let mut table = Table::new(&[
        "surface", "points", "clean", "recovered", "evicted", "hung", "diverged",
    ]);
    let mut surfaces: Vec<JsonValue> = Vec::new();
    for (surface, c) in &by {
        let n = c.clean + c.recovered + c.evicted + c.hung + c.diverged;
        table.row(&[
            surface.to_string(),
            n.to_string(),
            c.clean.to_string(),
            c.recovered.to_string(),
            c.evicted.to_string(),
            c.hung.to_string(),
            c.diverged.to_string(),
        ]);
        let mut j = JsonValue::obj();
        j.push("surface", JsonValue::Str(surface.to_string()));
        j.push("points", JsonValue::UInt(n));
        j.push("clean", JsonValue::UInt(c.clean));
        j.push("recovered", JsonValue::UInt(c.recovered));
        j.push("evicted", JsonValue::UInt(c.evicted));
        j.push("hung", JsonValue::UInt(c.hung));
        j.push("diverged", JsonValue::UInt(c.diverged));
        surfaces.push(j);
    }
    println!("{}", table.render());
    for p in &report.points {
        if let Some(detail) = &p.detail {
            println!(
                "[mbs] chaos: ({}, {}, {}) -> {}: {detail}",
                p.point.job,
                p.point.injection.name(),
                p.point.at,
                p.verdict.name()
            );
        }
    }
    let totals = report.totals();
    println!(
        "[mbs] chaos: {} point(s) — {} clean, {} recovered, {} evicted, {} hung, \
         {} diverged; recovered_fraction {:.3}",
        report.points.len(),
        totals.clean,
        totals.recovered,
        totals.evicted,
        totals.hung,
        totals.diverged,
        report.recovered_fraction()
    );

    let mut results: Vec<JsonValue> = Vec::new();
    for p in &report.points {
        let mut j = JsonValue::obj();
        j.push("job", JsonValue::Str(p.point.job.clone()));
        j.push("surface", JsonValue::Str(p.point.injection.name().to_string()));
        j.push("at", JsonValue::UInt(p.point.at));
        j.push("verdict", JsonValue::Str(p.verdict.name().to_string()));
        j.push("fired", JsonValue::UInt(p.fired));
        j.push("retries", JsonValue::UInt(p.retries));
        j.push("recovered", JsonValue::UInt(p.recovered));
        if let Some(detail) = &p.detail {
            j.push("detail", JsonValue::Str(detail.clone()));
        }
        results.push(j);
    }
    let mut rep = BenchReport::new("chaos", "sweep");
    rep.uint("capacity_mib", capacity_mib)
        .uint("points", report.points.len() as u64)
        .uint("deadline_ms", cfg.deadline_ms)
        .uint("fired_points", report.fired_points())
        // trend-tracked: recoveries over fired points
        .num("recovered_fraction", report.recovered_fraction(), 4)
        .uint("clean", totals.clean)
        .uint("recovered", totals.recovered)
        .uint("evicted", totals.evicted)
        .uint("hung", totals.hung)
        .uint("diverged", totals.diverged)
        .field("surfaces", JsonValue::Arr(surfaces))
        .field("results", JsonValue::Arr(results));
    rep.write(&out)?;
    println!("[mbs] wrote {out}");
    trend_compare(args, &out)?;

    if totals.hung > 0 || totals.diverged > 0 {
        return Err(MbsError::Runtime(format!(
            "chaos: invariant violated — {} hung, {} diverged (see {out})",
            totals.hung, totals.diverged
        )));
    }
    println!("[mbs] chaos: invariant holds — zero hung, zero diverged");
    Ok(())
}

/// Admission-only `mbs jobs --dry-run`: resolve each job's model entry
/// (synthetic task stand-ins need no artifacts), plan admission, print
/// the table + set verdict, and emit the dry-run `BENCH_jobs.json`.
fn jobs_dry_run(
    args: &Args,
    set: &JobSet,
    capacity_bytes: u64,
    out: &str,
    plan: Option<&FaultPlan>,
) -> Result<(), MbsError> {
    let manifest = if set.jobs.iter().any(|j| j.task.is_none()) {
        Some(Manifest::load(artifacts_dir(args))?)
    } else {
        None
    };
    let mut requests = Vec::with_capacity(set.jobs.len());
    for spec in &set.jobs {
        let entry = match &spec.task {
            Some(task) => frontier::synthetic_entry(task)?,
            None => manifest
                .as_ref()
                .expect("loaded above: some job names a model")
                .model(&spec.cfg.model)?
                .clone(),
        };
        requests.push(AdmissionRequest::from_spec(spec, entry));
    }
    let verdicts = tenancy::plan_admission(&requests, capacity_bytes);
    let set_class =
        frontier::SetFeasibility::from_outcomes(verdicts.iter().map(|v| &v.outcome));

    let mut table = Table::new(&[
        "job", "model", "batch", "admission", "mu", "solo mu", "n_smu", "reserved (MiB)",
    ]);
    for (req, v) in requests.iter().zip(&verdicts) {
        match &v.outcome {
            AdmissionOutcome::Admitted {
                resolution, solo_mu, resident_claim_bytes, ..
            } => {
                table.row(&[
                    v.name.clone(),
                    req.entry.name.clone(),
                    req.batch.to_string(),
                    v.outcome.label().to_string(),
                    resolution.mu.to_string(),
                    solo_mu.to_string(),
                    req.batch.div_ceil(resolution.mu).to_string(),
                    format!("{:.2}", *resident_claim_bytes as f64 / MIB as f64),
                ]);
            }
            AdmissionOutcome::Rejected { reason } => {
                table.row(&[
                    v.name.clone(),
                    req.entry.name.clone(),
                    req.batch.to_string(),
                    "reject".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                ]);
                println!("[mbs] jobs: '{}' rejected: {reason}", v.name);
            }
        }
    }
    println!("{}", table.render());
    println!("[mbs] jobs: set verdict: {}", set_class.class_name());
    println!(
        "(admit = solo mu kept; shrink-mu = co-residency forced a smaller micro-batch; \
         reject = the set cannot host this job)"
    );

    let mut rep = BenchReport::new("jobs", "dry-run");
    rep.uint("capacity_mib", capacity_bytes / MIB)
        .str_field("set_class", set_class.class_name())
        .field("jobs", jobs_admission_value(&requests, &verdicts, plan));
    rep.write(out)?;
    println!("[mbs] wrote {out}");
    Ok(())
}

/// The dry-run `jobs` array: one admission entry per job. With a fault
/// plan (`--faults`), each entry also records the planned outcome and how
/// many of the plan's fault specs target it — so CI can smoke-test a
/// committed fault spec without artifacts.
fn jobs_admission_value(
    requests: &[AdmissionRequest],
    verdicts: &[JobAdmission],
    plan: Option<&FaultPlan>,
) -> JsonValue {
    JsonValue::Arr(
        requests
            .iter()
            .zip(verdicts)
            .map(|(req, v)| {
                let mut j = JsonValue::obj();
                j.push("name", JsonValue::Str(v.name.clone()));
                j.push("model", JsonValue::Str(req.entry.name.clone()));
                j.push("batch", JsonValue::UInt(req.batch as u64));
                j.push("admission", JsonValue::Str(v.outcome.label().to_string()));
                let admitted = matches!(v.outcome, AdmissionOutcome::Admitted { .. });
                j.push(
                    "outcome",
                    JsonValue::Str(if admitted { "planned" } else { "rejected" }.into()),
                );
                if let Some(p) = plan {
                    j.push("faults_planned", JsonValue::UInt(p.entries_for(&v.name) as u64));
                }
                j.push(
                    "lane",
                    JsonValue::Str(if req.overlap { "async" } else { "serial" }.into()),
                );
                match &v.outcome {
                    AdmissionOutcome::Admitted {
                        resolution, solo_mu, resident_claim_bytes, staged_bytes, ..
                    } => {
                        j.push("mu", JsonValue::UInt(resolution.mu as u64));
                        j.push("solo_mu", JsonValue::UInt(*solo_mu as u64));
                        j.push(
                            "n_smu",
                            JsonValue::UInt(req.batch.div_ceil(resolution.mu) as u64),
                        );
                        j.push(
                            "resident_claim_mib",
                            JsonValue::fixed(*resident_claim_bytes as f64 / MIB as f64, 3),
                        );
                        j.push(
                            "staged_slot_mib",
                            JsonValue::fixed(*staged_bytes as f64 / MIB as f64, 3),
                        );
                    }
                    AdmissionOutcome::Rejected { reason } => {
                        j.push("reason", JsonValue::Str(reason.clone()));
                    }
                }
                j
            })
            .collect(),
    )
}

/// The train-mode `jobs` array: admission fields plus measured throughput
/// (shared measurement vocabulary: `stage_means_ms`, `pool`).
fn jobs_train_value(report: &JobsReport) -> JsonValue {
    JsonValue::Arr(
        report
            .jobs
            .iter()
            .map(|job| {
                let mut j = JsonValue::obj();
                j.push("name", JsonValue::Str(job.name.clone()));
                j.push("admission", JsonValue::Str(job.admission.label().to_string()));
                j.push("outcome", JsonValue::Str(job.outcome.as_str().to_string()));
                if let Some(err) = &job.error {
                    j.push("error", JsonValue::Str(err.clone()));
                }
                j.push("faults_injected", JsonValue::UInt(job.faults_injected));
                j.push("retries", JsonValue::UInt(job.retries));
                j.push("recovered", JsonValue::UInt(job.recovered));
                match (&job.report, &job.admission) {
                    (Some(r), AdmissionOutcome::Admitted { solo_mu, .. }) => {
                        let t = boundary_timing(r);
                        j.push("model", JsonValue::Str(r.model.clone()));
                        j.push("batch", JsonValue::UInt(r.batch as u64));
                        j.push("mu", JsonValue::UInt(r.mu as u64));
                        j.push("solo_mu", JsonValue::UInt(*solo_mu as u64));
                        j.push("n_smu", JsonValue::UInt(r.batch.div_ceil(r.mu) as u64));
                        j.push("items_per_sec", JsonValue::fixed(t.items_per_sec, 3));
                        j.push(
                            "epoch_wall_mean_s",
                            JsonValue::fixed(t.epoch_wall_mean_s, 6),
                        );
                        j.push("micro_steps", JsonValue::UInt(t.micro_steps));
                        j.push("updates", JsonValue::UInt(t.updates));
                        j.push("best_metric", JsonValue::fixed(r.best_metric(), 6));
                        j.push(
                            "wall_overlap_efficiency",
                            JsonValue::fixed(r.stages.wall_overlap_efficiency(), 4),
                        );
                        j.push(
                            "ledger_peak_mib",
                            JsonValue::fixed(r.ledger_peak_bytes as f64 / MIB as f64, 3),
                        );
                        j.push(
                            "stage_means_ms",
                            bench_report::stage_means_value(&t.stages, t.micro_steps, t.updates),
                        );
                        j.push("pool", bench_report::pool_value(&t.pool));
                    }
                    (_, AdmissionOutcome::Rejected { reason }) => {
                        j.push("reason", JsonValue::Str(reason.clone()));
                    }
                    _ => {}
                }
                j
            })
            .collect(),
    )
}

/// `bench` — measure the streaming hot path and emit machine-readable JSON
/// (`BENCH_streaming.json`): items/sec, per-stage means, pool hit rate.
///
/// Two modes:
///  * default: a full training run through `train()` (needs compiled
///    artifacts), reporting the real pipeline's stage breakdown;
///  * `--assemble-only`: the host-side streamer/pool path against the
///    synthetic datasets, with a fresh-allocation baseline arm — runs on a
///    clean checkout, which is what the CI smoke job uses.
///
/// `--compare prev.json` then trend-checks the fresh report against a
/// previous run's artifact: throughput keys (`*items_per_sec`,
/// `pooled_speedup`, `overlap_efficiency`) that drop more than
/// `--compare-threshold` (default 0.2 = 20%) are flagged; with
/// `--compare-strict=true` a regression also fails the command.
/// Threshold semantics: rust/docs/ARCHITECTURE.md.
fn cmd_bench(args: &Args) -> Result<(), MbsError> {
    let out = args.get_or("out", "BENCH_streaming.json").to_string();
    let report = if args.get_bool("assemble-only") {
        bench_assemble_only(args)?
    } else {
        bench_full(args)?
    };
    report.write(&out)?;
    println!("[mbs] wrote {out}");

    trend_compare(args, &out)
}

/// The shared `--compare prev.json` trend gate (used by `bench` and
/// `jobs`): diff the fresh report at `out` against a previous artifact,
/// flag throughput keys that dropped beyond `--compare-threshold`, and —
/// with `--compare-strict=true` — fail the command on any regression (or
/// on a comparison that could not be performed at all).
fn trend_compare(args: &Args, out: &str) -> Result<(), MbsError> {
    let Some(prev) = args.get("compare") else { return Ok(()) };
    let threshold: f64 =
        args.get_parse_or("compare-threshold", 0.2).map_err(MbsError::Config)?;
    match bench_report::compare_files(prev, out, threshold)? {
        None => {
            println!(
                "[mbs] trend: no comparable previous report at {prev} (first run or \
                 different bench/mode); skipping"
            );
            // a gate that silently skips is no gate: strict mode fails
            // when the requested comparison could not be performed
            if args.get_bool("compare-strict") {
                return Err(MbsError::Config(format!(
                    "--compare-strict: no comparable previous report at {prev} \
                     (missing file or bench/mode mismatch)"
                )));
            }
        }
        Some(outcome) => {
            let mut table =
                Table::new(&["metric", "previous", "current", "delta", "status"]);
            for row in &outcome.rows {
                table.row(&[
                    row.path.clone(),
                    format!("{:.3}", row.previous),
                    format!("{:.3}", row.current),
                    format!("{:+.1}%", 100.0 * row.delta),
                    if row.regressed { "REGRESSED".into() } else { "ok".into() },
                ]);
            }
            println!("[mbs] trend vs {prev} (threshold {:.0}%):", threshold * 100.0);
            println!("{}", table.render());
            for path in &outcome.missing_in_previous {
                println!("[mbs] trend: {path} is new (absent from previous report)");
            }
            let regressions = outcome.regressions();
            if regressions > 0 {
                println!("[mbs] trend: {regressions} metric(s) regressed beyond the threshold");
                if args.get_bool("compare-strict") {
                    return Err(MbsError::Config(format!(
                        "{regressions} bench metric(s) regressed more than {:.0}% vs {prev}",
                        threshold * 100.0
                    )));
                }
            } else {
                println!("[mbs] trend: no regressions beyond the threshold");
            }
        }
    }
    Ok(())
}

fn bench_full(args: &Args) -> Result<BenchReport, MbsError> {
    let cfg = build_config(args)?;
    let manifest = Manifest::load(artifacts_dir(args))?;
    let mut engine = Engine::new(manifest)?;
    println!(
        "[mbs] bench: full pipeline, {} batch={} streaming={} prefetch={} overlap={}",
        cfg.model,
        cfg.batch,
        cfg.streaming.name(),
        cfg.prefetch,
        if cfg.overlap { "on" } else { "off" }
    );
    let report: TrainReport = train(&mut engine, &cfg)?;
    let micro_steps: u64 = report.train_epochs.iter().map(|e| e.micro_steps as u64).sum();
    let samples: u64 = report.train_epochs.iter().map(|e| e.samples as u64).sum();
    let train_wall: f64 = report.train_epochs.iter().map(|e| e.wall.as_secs_f64()).sum();
    let items_per_sec = if train_wall > 0.0 { samples as f64 / train_wall } else { 0.0 };
    let mut rep = BenchReport::new("streaming", "train");
    rep.str_field("model", &report.model)
        .uint("batch", report.batch as u64)
        .uint("mu", report.mu as u64)
        .uint("epochs", report.train_epochs.len() as u64)
        .str_field("streaming", cfg.streaming.name())
        .str_field("overlap", if report.overlap { "on" } else { "off" })
        .str_field("lane", if report.overlap { "async" } else { "serial" })
        .uint("prefetch", report.prefetch as u64)
        .uint("updates", report.updates)
        .uint("micro_steps", micro_steps)
        .num("items_per_sec", items_per_sec, 3)
        .num("epoch_wall_mean_s", report.epoch_wall_mean.as_secs_f64(), 6)
        // the overlap-efficiency key: fraction of upload wall time the
        // pipeline hid behind execution (trend-tracked by --compare)
        .num("overlap_efficiency", report.stages.overlap_efficiency(), 4)
        // wall-clock overlap: the share of lane upload time whose thread
        // timestamps genuinely intersect a device-execute window — the
        // key `--compare` gates the async lane's real win on
        .num("wall_overlap_efficiency", report.stages.wall_overlap_efficiency(), 4)
        .field(
            "stage_means_ms",
            bench_report::stage_means_value(&report.stages, micro_steps, report.updates),
        )
        .field("pool", bench_report::pool_value(&report.pool));
    Ok(rep)
}

fn bench_flag<T: std::str::FromStr>(args: &Args, key: &str, default: T) -> Result<T, MbsError> {
    args.get_parse_or(key, default).map_err(MbsError::Config)
}

fn bench_assemble_only(args: &Args) -> Result<BenchReport, MbsError> {
    let task = args.get_or("task", "classification").to_string();
    // validated up front with the other flags — a bad value must fail
    // before the measurement arms run, not after
    let overlap = parse_overlap_flag(args)?;
    let size: usize = bench_flag(args, "size", 8)?;
    let batch: usize = bench_flag(args, "batch", 32)?;
    let mu: usize = bench_flag(args, "mu", 8)?;
    let prefetch: usize = bench_flag(args, "prefetch", 2)?;
    let dataset_len: usize = bench_flag(args, "dataset-len", 512)?;
    let epochs: usize = bench_flag(args, "epochs", 3)?;
    let seed: u64 = bench_flag(args, "seed", 0)?;
    if batch == 0 || mu == 0 || dataset_len == 0 || epochs == 0 {
        return Err(MbsError::Config(
            "bench needs positive batch, mu, dataset-len and epochs".into(),
        ));
    }
    let mut cfg = TrainConfig::default_for("assemble-bench");
    cfg.dataset_len = dataset_len;
    cfg.eval_len = 0;
    cfg.seed = seed;
    let (ds, _eval): (Arc<dyn Dataset>, _) = datasets_for(&task, size, &cfg)?;
    let planner = Planner::new(mu, false, NormalizationMode::Paper);
    println!(
        "[mbs] bench: assemble-only, task={task} size={size} batch={batch} mu={mu} \
         prefetch={prefetch} dataset-len={dataset_len} epochs={epochs}"
    );

    // arm 1: the fresh-allocation baseline (pre-pool hot path)
    let mut fresh_secs = 0f64;
    for epoch in 0..epochs {
        let plan = EpochPlan::new(dataset_len, batch, seed, epoch as u64);
        let t0 = Instant::now();
        for b in 0..plan.num_batches() {
            let indices = plan.batch_indices(b);
            let xplan = planner.plan_minibatch(indices.len());
            for jj in 0..xplan.n_smu() {
                let mb = loader::assemble(ds.as_ref(), indices, xplan.mu, jj);
                std::hint::black_box(&mb);
            }
        }
        fresh_secs += t0.elapsed().as_secs_f64();
    }

    // arms 2+3: the pooled streamer (sync = pure assemble-path comparison,
    // double-buffered = with copy/compute overlap); one shared warm pool
    let pool = Arc::new(BufPool::for_prefetch(prefetch));
    pool.warm(BufPool::buffers_for(prefetch), ds.as_ref(), mu);
    let run_streamed = |policy: StreamingPolicy| -> (f64, Duration, u64) {
        let mut secs = 0f64;
        let mut assemble = Duration::ZERO;
        let mut items = 0u64;
        for epoch in 0..epochs {
            let plan = EpochPlan::new(dataset_len, batch, seed, epoch as u64);
            let t0 = Instant::now();
            for item in
                stream_epoch(policy, ds.clone(), plan, planner.clone(), prefetch, pool.clone())
            {
                assemble += item.assemble;
                items += 1;
                std::hint::black_box(&item.mb);
                pool.give(item.mb);
            }
            secs += t0.elapsed().as_secs_f64();
        }
        (secs, assemble, items)
    };
    let (pooled_secs, pooled_assemble, micro_steps) =
        run_streamed(StreamingPolicy::Synchronous);
    let (overlap_secs, _, _) = run_streamed(StreamingPolicy::DoubleBuffered);

    // arm 4: the artifact-cache cold/warm micro-bench. A mock-backed
    // manager over a throwaway dir fetches a small mu ladder twice: the
    // cold pass compiles every variant, the warm pass must be pure cache
    // hits. `warm_hit_rate` is counter arithmetic (no wall clock), so the
    // --compare trend gate can hold it at 1.0 without machine noise.
    let cache_arm = bench_artifact_cache(&task, size, overlap)?;

    let total_items = (dataset_len * epochs) as f64;
    let rate = |secs: f64| if secs > 0.0 { total_items / secs } else { 0.0 };
    let fresh_rate = rate(fresh_secs);
    let pooled_rate = rate(pooled_secs);
    let overlap_rate = rate(overlap_secs);
    let stats = pool.stats();

    // no device in this mode, so --overlap cannot change the measurement;
    // it is recorded so the CI matrix (serial + overlap smokes) produces
    // self-describing artifacts either way
    let mut rep = BenchReport::new("streaming", "assemble-only");
    rep.str_field("task", &task)
        .str_field("overlap", if overlap { "on" } else { "off" })
        .uint("size", size as u64)
        .uint("batch", batch as u64)
        .uint("mu", mu as u64)
        .uint("prefetch", prefetch as u64)
        .uint("dataset_len", dataset_len as u64)
        .uint("epochs", epochs as u64)
        .uint("micro_steps", micro_steps)
        .num("fresh_items_per_sec", fresh_rate, 3)
        .num("pooled_items_per_sec", pooled_rate, 3)
        .num("overlapped_items_per_sec", overlap_rate, 3)
        .num(
            "pooled_speedup",
            if fresh_rate > 0.0 { pooled_rate / fresh_rate } else { 0.0 },
            4,
        )
        .num(
            "assemble_mean_ms",
            if micro_steps == 0 {
                0.0
            } else {
                pooled_assemble.as_secs_f64() * 1e3 / micro_steps as f64
            },
            6,
        )
        .field("pool", bench_report::pool_value(&stats))
        .field("artifact_cache", cache_arm);
    Ok(rep)
}

/// The assemble-only bench's artifact-cache arm: cold-fetch a mu ladder
/// through a mock-backed [`ArtifactManager`], re-fetch it warm, and report
/// the counters. Deterministic by construction — the mock compiler has no
/// latency and the hit accounting is integer — so `warm_hit_rate` is a
/// stable trend key (anything below 1.0 means the cache contract broke).
fn bench_artifact_cache(task: &str, size: usize, overlap: bool) -> Result<JsonValue, MbsError> {
    let cache = std::env::temp_dir().join(format!("mbs-bench-cache-{}", std::process::id()));
    std::fs::remove_dir_all(&cache).ok();
    let manager = ArtifactManager::new(&cache, Arc::new(MockCompiler::new()), 32)?;
    let mus = [1usize, 2, 4, 8, 16, 32];
    let key = |mu: usize| VariantKey {
        model: format!("bench-{task}"),
        size,
        mu,
        overlap,
    };
    // the manifest fingerprint is fixed: the bench measures the cache, not
    // a real export, and a constant keeps digests (and reports) stable
    let fingerprint = 0xbe7c_u64;
    for &mu in &mus {
        manager.fetch(&key(mu), fingerprint)?;
    }
    let cold = manager.stats();
    for &mu in &mus {
        manager.fetch(&key(mu), fingerprint)?;
    }
    let warm = manager.stats();
    let warm_hits = warm.hits - cold.hits;
    let warm_fetches = mus.len() as u64;
    std::fs::remove_dir_all(&cache).ok();

    let mut v = JsonValue::obj();
    v.push("variants", JsonValue::UInt(warm_fetches));
    v.push("cold_compiles", JsonValue::UInt(cold.compiles));
    v.push("warm_hits", JsonValue::UInt(warm_hits));
    v.push(
        "warm_hit_rate",
        JsonValue::fixed(warm_hits as f64 / warm_fetches as f64, 6),
    );
    Ok(v)
}

fn cmd_inspect(args: &Args) -> Result<(), MbsError> {
    let manifest = Manifest::load(artifacts_dir(args))?;
    let mut table = Table::new(&[
        "model", "task", "opt", "size", "mu", "params (KiB)", "act/sample (KiB)",
        "resident (MiB)", "step(mu) (MiB)",
    ]);
    for entry in manifest.models.values() {
        for v in &entry.variants {
            let fp = Footprint::from_manifest(entry, v);
            table.row(&[
                entry.name.clone(),
                entry.task.clone(),
                entry.optimizer.kind.clone(),
                v.size.to_string(),
                v.mu.to_string(),
                format!("{:.0}", entry.param_bytes as f64 / 1024.0),
                format!("{:.0}", v.activation_bytes_per_sample as f64 / 1024.0),
                format!("{:.1}", fp.resident_bytes() as f64 / MIB as f64),
                format!("{:.1}", fp.step_bytes(v.mu) as f64 / MIB as f64),
            ]);
        }
    }
    println!("{}", table.render());
    println!("(paper table 2 mapping: mini-batch = largest exported mu, u-batch = mini/2)");
    Ok(())
}

fn cmd_info(args: &Args) -> Result<(), MbsError> {
    let manifest = Manifest::load(artifacts_dir(args))?;
    let engine = Engine::new(manifest)?;
    println!("platform: {}", engine.platform());
    println!("models:   {}", engine.manifest().models.len());
    let variants: usize = engine.manifest().models.values().map(|m| m.variants.len()).sum();
    println!("variants: {variants}");
    Ok(())
}
