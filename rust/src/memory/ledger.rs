//! Allocation ledger for the simulated device.
//!
//! The [`MemoryModel`](super::MemoryModel) answers "does this step fit?";
//! the ledger additionally *tracks* live allocations so integration tests
//! can assert the coordinator's sequencing never exceeds capacity at any
//! instant (e.g. during the double-buffered streaming window, when two
//! micro-batch input buffers are briefly live at once).

use std::collections::BTreeMap;

use super::MIB;
use crate::error::{MbsError, Result};

/// Handle to a live allocation.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub struct AllocId(u64);

/// Bump-style allocation tracker for one simulated device.
#[derive(Debug)]
pub struct Ledger {
    capacity: u64,
    live: BTreeMap<AllocId, (String, u64)>,
    used: u64,
    next_id: u64,
    peak: u64,
}

impl Ledger {
    /// A fresh ledger for a device with `capacity` bytes.
    pub fn new(capacity: u64) -> Ledger {
        Ledger { capacity, live: BTreeMap::new(), used: 0, next_id: 0, peak: 0 }
    }

    /// A fresh ledger for a synthetic capacity given in MiB — a
    /// convenience for tests and callers that think in the CLI's
    /// `--capacity-mib` unit rather than bytes.
    pub fn with_mib(capacity_mib: u64) -> Ledger {
        Ledger::new(capacity_mib * MIB)
    }

    /// Allocate `bytes` under `tag`; fails with a structured OOM when the
    /// request does not fit.
    pub fn alloc(&mut self, tag: &str, bytes: u64) -> Result<AllocId> {
        if self.used + bytes > self.capacity {
            return Err(MbsError::Oom {
                needed_bytes: self.used + bytes,
                available_bytes: self.capacity - self.used,
                capacity_bytes: self.capacity,
                context: format!("ledger alloc '{tag}'"),
            });
        }
        let id = AllocId(self.next_id);
        self.next_id += 1;
        self.used += bytes;
        self.peak = self.peak.max(self.used);
        self.live.insert(id, (tag.to_string(), bytes));
        Ok(id)
    }

    /// Release a live allocation; freeing twice is a runtime error.
    pub fn free(&mut self, id: AllocId) -> Result<()> {
        match self.live.remove(&id) {
            Some((_, bytes)) => {
                self.used -= bytes;
                Ok(())
            }
            None => Err(MbsError::Runtime(format!("double free of {id:?}"))),
        }
    }

    /// Bytes currently allocated.
    pub fn used(&self) -> u64 {
        self.used
    }

    /// Bytes still available for allocation — the budget the micro-batch
    /// planner queries when deriving `mu` (paper Alg. 1: capacity minus
    /// whatever is already resident).
    pub fn remaining(&self) -> u64 {
        self.capacity - self.used
    }

    /// Would an allocation of `bytes` fit right now?
    pub fn admits(&self, bytes: u64) -> bool {
        bytes <= self.remaining()
    }

    /// High-water mark of [`used`](Ledger::used) over the ledger's life.
    pub fn peak(&self) -> u64 {
        self.peak
    }

    /// Total device capacity, bytes.
    pub fn capacity(&self) -> u64 {
        self.capacity
    }

    /// Number of live allocations.
    pub fn live_count(&self) -> usize {
        self.live.len()
    }

    /// Tag breakdown of live bytes, for diagnostics.
    pub fn by_tag(&self) -> BTreeMap<String, u64> {
        let mut out: BTreeMap<String, u64> = BTreeMap::new();
        for (tag, bytes) in self.live.values() {
            *out.entry(tag.clone()).or_default() += bytes;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_free_cycle() {
        let mut l = Ledger::new(100);
        let a = l.alloc("a", 60).unwrap();
        assert_eq!(l.used(), 60);
        assert!(l.alloc("b", 50).is_err()); // would exceed
        let b = l.alloc("b", 40).unwrap();
        assert_eq!(l.used(), 100);
        l.free(a).unwrap();
        assert_eq!(l.used(), 40);
        l.free(b).unwrap();
        assert_eq!(l.used(), 0);
        assert_eq!(l.peak(), 100);
    }

    #[test]
    fn with_mib_scales_capacity() {
        let l = Ledger::with_mib(3);
        assert_eq!(l.capacity(), 3 * MIB);
        assert_eq!(l.remaining(), 3 * MIB);
    }

    #[test]
    fn remaining_and_admits_track_allocations() {
        let mut l = Ledger::new(100);
        assert_eq!(l.remaining(), 100);
        assert!(l.admits(100) && !l.admits(101));
        let a = l.alloc("resident", 60).unwrap();
        assert_eq!(l.remaining(), 40);
        assert!(l.admits(40) && !l.admits(41));
        l.free(a).unwrap();
        assert_eq!(l.remaining(), 100);
    }

    #[test]
    fn double_free_rejected() {
        let mut l = Ledger::new(10);
        let a = l.alloc("a", 5).unwrap();
        l.free(a).unwrap();
        assert!(l.free(a).is_err());
    }

    #[test]
    fn tag_breakdown() {
        let mut l = Ledger::new(1000);
        l.alloc("params", 300).unwrap();
        l.alloc("input", 100).unwrap();
        l.alloc("input", 100).unwrap();
        let tags = l.by_tag();
        assert_eq!(tags["params"], 300);
        assert_eq!(tags["input"], 200);
    }

    mod properties {
        use super::*;
        use crate::util::prop::{ensure, forall};

        #[test]
        fn used_never_exceeds_capacity() {
            forall(
                "ledger bound",
                100,
                0xAB,
                |r| {
                    let ops: Vec<u64> = (0..50).map(|_| r.below(40)).collect();
                    ops
                },
                |ops| {
                    let mut l = Ledger::new(200);
                    let mut live = Vec::new();
                    for &sz in ops {
                        match l.alloc("x", sz) {
                            Ok(id) => live.push(id),
                            Err(_) => {
                                if let Some(id) = live.pop() {
                                    l.free(id).map_err(|e| e.to_string())?;
                                }
                            }
                        }
                        ensure(l.used() <= l.capacity(), "used > capacity")?;
                        ensure(
                            l.remaining() == l.capacity() - l.used(),
                            "remaining out of sync",
                        )?;
                    }
                    Ok(())
                },
            );
        }
    }
}
