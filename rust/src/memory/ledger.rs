//! Allocation ledger for the simulated device.
//!
//! The [`MemoryModel`](super::MemoryModel) answers "does this step fit?";
//! the ledger additionally *tracks* live allocations so integration tests
//! can assert the coordinator's sequencing never exceeds capacity at any
//! instant (e.g. during the double-buffered streaming window, when two
//! micro-batch input buffers are briefly live at once).
//!
//! Since the multi-tenant refactor, a `Ledger` is a per-tenant *view* over
//! a shared [`Arena`](super::Arena) core: [`Ledger::new`] builds a
//! one-tenant arena (the historical behaviour, API-identical), while
//! [`Arena::tenant`](super::Arena::tenant) hands out sibling ledgers that
//! charge the same capacity — which is how the interleaved multi-job
//! executor keeps every job's residency accountable against one device.
//! Per-ledger counters ([`used`](Ledger::used), [`peak`](Ledger::peak))
//! stay tenant-local; the *budget* queries
//! ([`remaining`](Ledger::remaining), [`admits`](Ledger::admits),
//! [`capacity`](Ledger::capacity)) are shared, so for a solo ledger both
//! views coincide exactly.

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::rc::Rc;

use super::arena::ArenaCore;
use super::MIB;
use crate::error::{MbsError, Result};

/// Handle to a live allocation.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub struct AllocId(u64);

/// Bump-style allocation tracker for one tenant of a simulated device.
#[derive(Debug)]
pub struct Ledger {
    core: Rc<RefCell<ArenaCore>>,
    tenant: String,
    live: BTreeMap<AllocId, (String, u64)>,
    used: u64,
    next_id: u64,
    peak: u64,
}

impl Ledger {
    /// A fresh ledger for a device with `capacity` bytes — a one-tenant
    /// [`Arena`](super::Arena).
    pub fn new(capacity: u64) -> Ledger {
        super::Arena::new(capacity).tenant("device")
    }

    /// A fresh ledger for a synthetic capacity given in MiB — a
    /// convenience for tests and callers that think in the CLI's
    /// `--capacity-mib` unit rather than bytes.
    pub fn with_mib(capacity_mib: u64) -> Ledger {
        Ledger::new(capacity_mib * MIB)
    }

    /// A tenant view over a shared arena core (via
    /// [`Arena::tenant`](super::Arena::tenant)).
    pub(super) fn tenant_view(core: Rc<RefCell<ArenaCore>>, tenant: &str) -> Ledger {
        Ledger {
            core,
            tenant: tenant.to_string(),
            live: BTreeMap::new(),
            used: 0,
            next_id: 0,
            peak: 0,
        }
    }

    /// The tenant name this ledger charges under.
    pub fn tenant(&self) -> &str {
        &self.tenant
    }

    /// The device label of the arena this ledger charges into (solo
    /// ledgers report the default `device0`).
    pub fn device(&self) -> String {
        self.core.borrow().device.clone()
    }

    /// Allocate `bytes` under `tag`; fails with a structured OOM when the
    /// request does not fit the *shared* capacity right now — with sibling
    /// tenants, their live bytes count too.
    pub fn alloc(&mut self, tag: &str, bytes: u64) -> Result<AllocId> {
        self.core.borrow_mut().charge(&self.tenant, tag, bytes)?;
        let id = AllocId(self.next_id);
        self.next_id += 1;
        self.used += bytes;
        self.peak = self.peak.max(self.used);
        self.live.insert(id, (tag.to_string(), bytes));
        Ok(id)
    }

    /// Release a live allocation; freeing twice is a runtime error (named
    /// with the device and tenant, like every arena error path).
    pub fn free(&mut self, id: AllocId) -> Result<()> {
        match self.live.remove(&id) {
            Some((_, bytes)) => {
                self.used -= bytes;
                self.core.borrow_mut().release(bytes);
                Ok(())
            }
            None => Err(MbsError::Runtime(format!(
                "double free of {id:?} (device={}, tenant={})",
                self.core.borrow().device,
                self.tenant
            ))),
        }
    }

    /// Bytes currently allocated *by this tenant*. For a solo ledger this
    /// equals the device total.
    pub fn used(&self) -> u64 {
        self.used
    }

    /// Bytes still available for allocation — the budget the micro-batch
    /// planner queries when deriving `mu` (paper Alg. 1: capacity minus
    /// whatever is already resident, across every tenant of the arena).
    pub fn remaining(&self) -> u64 {
        let c = self.core.borrow();
        c.capacity - c.used
    }

    /// Would an allocation of `bytes` fit right now?
    pub fn admits(&self, bytes: u64) -> bool {
        bytes <= self.remaining()
    }

    /// High-water mark of [`used`](Ledger::used) over this tenant's life.
    /// The cross-tenant peak lives on [`Arena::peak`](super::Arena::peak).
    pub fn peak(&self) -> u64 {
        self.peak
    }

    /// Total device capacity, bytes (shared across tenants).
    pub fn capacity(&self) -> u64 {
        self.core.borrow().capacity
    }

    /// Number of live allocations held by this tenant.
    pub fn live_count(&self) -> usize {
        self.live.len()
    }

    /// Arm a one-shot injected fault against *this tenant's* next charge
    /// ([`Arena::arm_fault`](super::Arena::arm_fault) with this ledger's
    /// tenant name) — the deterministic-fault-injection entry point the
    /// job executor uses.
    pub fn inject_charge_fault(&self, note: &str) {
        self.core.borrow_mut().fault = Some((self.tenant.clone(), note.to_string()));
    }

    /// Release every live allocation this tenant holds (recovery quiesce:
    /// a faulted job hands its whole residency — reservation and any
    /// leaked transients — back to the arena before re-planning).
    /// Returns the bytes released.
    pub fn release_all(&mut self) -> u64 {
        let released = self.used;
        if released > 0 {
            self.core.borrow_mut().release(released);
        }
        self.live.clear();
        self.used = 0;
        released
    }

    /// Tag breakdown of this tenant's live bytes, for diagnostics.
    pub fn by_tag(&self) -> BTreeMap<String, u64> {
        let mut out: BTreeMap<String, u64> = BTreeMap::new();
        for (tag, bytes) in self.live.values() {
            *out.entry(tag.clone()).or_default() += bytes;
        }
        out
    }
}

impl Drop for Ledger {
    /// A dropped tenant releases whatever it still holds, so a job that
    /// errors out mid-run hands its reservations back to the arena.
    fn drop(&mut self) {
        if self.used > 0 {
            self.core.borrow_mut().release(self.used);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_free_cycle() {
        let mut l = Ledger::new(100);
        let a = l.alloc("a", 60).unwrap();
        assert_eq!(l.used(), 60);
        assert!(l.alloc("b", 50).is_err()); // would exceed
        let b = l.alloc("b", 40).unwrap();
        assert_eq!(l.used(), 100);
        l.free(a).unwrap();
        assert_eq!(l.used(), 40);
        l.free(b).unwrap();
        assert_eq!(l.used(), 0);
        assert_eq!(l.peak(), 100);
    }

    #[test]
    fn with_mib_scales_capacity() {
        let l = Ledger::with_mib(3);
        assert_eq!(l.capacity(), 3 * MIB);
        assert_eq!(l.remaining(), 3 * MIB);
    }

    #[test]
    fn remaining_and_admits_track_allocations() {
        let mut l = Ledger::new(100);
        assert_eq!(l.remaining(), 100);
        assert!(l.admits(100) && !l.admits(101));
        let a = l.alloc("resident", 60).unwrap();
        assert_eq!(l.remaining(), 40);
        assert!(l.admits(40) && !l.admits(41));
        l.free(a).unwrap();
        assert_eq!(l.remaining(), 100);
    }

    #[test]
    fn double_free_rejected() {
        let mut l = Ledger::new(10);
        let a = l.alloc("a", 5).unwrap();
        l.free(a).unwrap();
        let err = l.free(a).unwrap_err();
        // pipeline misuse is attributable just like OOM
        let msg = err.to_string();
        assert!(msg.contains("device=device0"), "{msg}");
        assert!(msg.contains("tenant=device"), "{msg}");
    }

    #[test]
    fn ledger_reports_its_arena_device() {
        let arena = crate::memory::Arena::named("npu3", 64);
        let l = arena.tenant("job");
        assert_eq!(l.device(), "npu3");
        assert_eq!(Ledger::new(1).device(), "device0");
    }

    #[test]
    fn tag_breakdown() {
        let mut l = Ledger::new(1000);
        l.alloc("params", 300).unwrap();
        l.alloc("input", 100).unwrap();
        l.alloc("input", 100).unwrap();
        let tags = l.by_tag();
        assert_eq!(tags["params"], 300);
        assert_eq!(tags["input"], 200);
    }

    #[test]
    fn inject_charge_fault_is_tenant_scoped_and_one_shot() {
        let arena = crate::memory::Arena::new(100);
        let mut t = arena.tenant("victim");
        let mut s = arena.tenant("sibling");
        t.inject_charge_fault("simulated pressure");
        let sid = s.alloc("x", 10).unwrap(); // sibling unaffected
        let err = t.alloc("x", 10).unwrap_err();
        assert!(err.is_oom());
        assert!(err.to_string().contains("simulated pressure"), "{err}");
        assert_eq!(t.used(), 0, "a refused charge must not count as live");
        t.alloc("x", 10).unwrap(); // one-shot: retry passes
        s.free(sid).unwrap();
    }

    #[test]
    fn release_all_returns_everything_to_the_arena() {
        let arena = crate::memory::Arena::new(100);
        let mut t = arena.tenant("job");
        let a = t.alloc("resident", 40).unwrap();
        let _b = t.alloc("transient", 20).unwrap();
        assert_eq!(arena.used(), 60);
        assert_eq!(t.release_all(), 60);
        assert_eq!(t.used(), 0);
        assert_eq!(t.live_count(), 0);
        assert_eq!(arena.used(), 0);
        // freeing the stale ids after release_all is an error, not UB
        assert!(t.free(a).is_err());
        // the tenant is still usable afterwards (re-claim path)
        let c = t.alloc("resident", 40).unwrap();
        t.free(c).unwrap();
        assert_eq!(t.release_all(), 0, "idempotent when nothing is live");
    }

    #[test]
    fn dropped_tenant_releases_its_live_bytes() {
        let arena = crate::memory::Arena::new(100);
        {
            let mut t = arena.tenant("doomed");
            t.alloc("resident", 80).unwrap();
            assert_eq!(arena.used(), 80);
        }
        // the tenant died holding 80 bytes: the arena gets them back
        assert_eq!(arena.used(), 0);
        assert_eq!(arena.peak(), 80);
    }

    mod properties {
        use super::*;
        use crate::util::prop::{ensure, forall};

        #[test]
        fn used_never_exceeds_capacity() {
            forall(
                "ledger bound",
                100,
                0xAB,
                |r| {
                    let ops: Vec<u64> = (0..50).map(|_| r.below(40)).collect();
                    ops
                },
                |ops| {
                    let mut l = Ledger::new(200);
                    let mut live = Vec::new();
                    for &sz in ops {
                        match l.alloc("x", sz) {
                            Ok(id) => live.push(id),
                            Err(_) => {
                                if let Some(id) = live.pop() {
                                    l.free(id).map_err(|e| e.to_string())?;
                                }
                            }
                        }
                        ensure(l.used() <= l.capacity(), "used > capacity")?;
                        ensure(
                            l.remaining() == l.capacity() - l.used(),
                            "remaining out of sync",
                        )?;
                    }
                    Ok(())
                },
            );
        }
    }
}
