//! Shared-capacity arena: one simulated device, many tenants.
//!
//! MBS shrinks a job's transient working set from `N_B` samples to `mu`
//! (paper §3.3) — which is also what lets *several* training jobs
//! time-share one device that could not hold any two of them natively.
//! [`Arena`] is the shared side of that story: it owns the device
//! capacity and the cross-job usage/peak accounting, while every
//! [`Ledger`](super::Ledger) is a per-tenant *view* that charges its
//! allocations into the shared core. A solo [`Ledger::new`] is simply a
//! one-tenant arena, so the entire single-job API (and every assertion
//! built on it) survives unchanged.
//!
//! Single-threaded by design (`Rc<RefCell<..>>`): everything that touches
//! device residency already lives on the engine thread (the PJRT client is
//! `Rc`-backed), and the interleaved multi-job executor rotates tenants on
//! that same thread.

use std::cell::RefCell;
use std::rc::Rc;

use super::MIB;
use crate::error::{MbsError, Result};

/// The shared accounting every tenant ledger charges into.
#[derive(Debug)]
pub(super) struct ArenaCore {
    /// Device label naming this arena in every error path, so a fleet
    /// failure is attributable (`device=…, tenant=…`).
    pub(super) device: String,
    /// Total device capacity, bytes.
    pub(super) capacity: u64,
    /// Bytes currently allocated across every tenant.
    pub(super) used: u64,
    /// High-water mark of `used` over the arena's life — the cross-job
    /// peak the admission planner promises stays within capacity.
    pub(super) peak: u64,
    /// Tenant ledgers created so far (diagnostic).
    pub(super) tenants: usize,
    /// One-shot armed fault: the next charge by the named tenant fails
    /// with structured OOM even if it would fit (deterministic fault
    /// injection — [`crate::runtime::faults`]). `(tenant, note)`.
    pub(super) fault: Option<(String, String)>,
}

impl ArenaCore {
    /// Charge `bytes` against the shared capacity; fails with a structured
    /// OOM naming the device, tenant and `tag` when the request does not
    /// fit *right now* — this failure path IS the every-instant cross-job
    /// capacity assertion.
    pub(super) fn charge(&mut self, tenant: &str, tag: &str, bytes: u64) -> Result<()> {
        // armed injected fault: the match is per-tenant — sibling jobs'
        // charges pass through untouched. One-shot: firing disarms.
        let fault_hits = self.fault.as_ref().is_some_and(|(victim, _)| victim == tenant);
        if fault_hits {
            let (_, note) = self.fault.take().unwrap_or_default();
            return Err(MbsError::Oom {
                needed_bytes: self.used.saturating_add(bytes),
                available_bytes: self.capacity - self.used,
                capacity_bytes: self.capacity,
                context: format!(
                    "arena alloc '{tag}' (injected fault: {note}; device={}, tenant={tenant})",
                    self.device
                ),
            });
        }
        if self.used.saturating_add(bytes) > self.capacity {
            return Err(MbsError::Oom {
                needed_bytes: self.used.saturating_add(bytes),
                available_bytes: self.capacity - self.used,
                capacity_bytes: self.capacity,
                context: format!(
                    "arena alloc '{tag}' (device={}, tenant={tenant})",
                    self.device
                ),
            });
        }
        self.used += bytes;
        self.peak = self.peak.max(self.used);
        Ok(())
    }

    /// Release `bytes` previously charged.
    pub(super) fn release(&mut self, bytes: u64) {
        debug_assert!(self.used >= bytes, "arena release underflow");
        self.used = self.used.saturating_sub(bytes);
    }
}

/// One simulated device's capacity, shared by any number of tenant
/// [`Ledger`](super::Ledger)s.
///
/// Cloning an `Arena` clones the *handle*, not the device: all clones (and
/// all tenant ledgers) charge the same core, so `used()`/`peak()` always
/// report the cross-tenant totals.
///
/// ```
/// use mbs::memory::Arena;
///
/// let arena = Arena::new(100);
/// let mut a = arena.tenant("job-a");
/// let mut b = arena.tenant("job-b");
/// let ra = a.alloc("resident", 60).unwrap();
/// assert!(b.alloc("resident", 50).is_err()); // shared capacity is shared
/// let rb = b.alloc("resident", 40).unwrap();
/// assert_eq!(arena.used(), 100);
/// a.free(ra).unwrap();
/// b.free(rb).unwrap();
/// assert_eq!(arena.peak(), 100); // cross-job high-water mark
/// ```
#[derive(Debug, Clone)]
pub struct Arena {
    core: Rc<RefCell<ArenaCore>>,
}

impl Arena {
    /// A fresh arena for a device with `capacity` bytes, under the default
    /// device label `device0` (the solo-device story).
    pub fn new(capacity: u64) -> Arena {
        Arena::named("device0", capacity)
    }

    /// A fresh arena for a *named* device with `capacity` bytes — the
    /// fleet constructor. The name labels every error this arena raises
    /// (`device=…, tenant=…`), so multi-device failures are attributable.
    pub fn named(device: &str, capacity: u64) -> Arena {
        Arena {
            core: Rc::new(RefCell::new(ArenaCore {
                device: device.to_string(),
                capacity,
                used: 0,
                peak: 0,
                tenants: 0,
                fault: None,
            })),
        }
    }

    /// A fresh arena for a capacity given in MiB (the CLI's
    /// `--capacity-mib` unit).
    pub fn with_mib(capacity_mib: u64) -> Arena {
        Arena::new(capacity_mib * MIB)
    }

    /// The device label errors from this arena carry.
    pub fn device(&self) -> String {
        self.core.borrow().device.clone()
    }

    /// Create a per-tenant ledger view charging into this arena. The name
    /// labels the tenant's allocations in OOM contexts.
    pub fn tenant(&self, name: &str) -> super::Ledger {
        self.core.borrow_mut().tenants += 1;
        super::Ledger::tenant_view(self.core.clone(), name)
    }

    /// Total device capacity, bytes.
    pub fn capacity(&self) -> u64 {
        self.core.borrow().capacity
    }

    /// Bytes currently allocated across every tenant.
    pub fn used(&self) -> u64 {
        self.core.borrow().used
    }

    /// Bytes still unallocated across every tenant — the budget the
    /// admission planner hands each job's `auto_mu` after all residents
    /// are placed.
    pub fn remaining(&self) -> u64 {
        let c = self.core.borrow();
        c.capacity - c.used
    }

    /// Would an allocation of `bytes` fit across all tenants right now?
    pub fn admits(&self, bytes: u64) -> bool {
        bytes <= self.remaining()
    }

    /// Cross-tenant high-water mark of [`used`](Arena::used) — by
    /// construction never exceeds [`capacity`](Arena::capacity), because
    /// every charge that would is refused at the instant it happens.
    pub fn peak(&self) -> u64 {
        self.core.borrow().peak
    }

    /// Tenant ledgers created from this arena so far.
    pub fn tenants(&self) -> usize {
        self.core.borrow().tenants
    }

    /// Arm a one-shot injected fault: the *next* charge by `tenant` fails
    /// with the structured OOM arithmetic (context flagged
    /// `injected fault`), then the arm clears. Sibling tenants are
    /// unaffected. Re-arming before the fault fires replaces the note.
    pub fn arm_fault(&self, tenant: &str, note: &str) {
        self.core.borrow_mut().fault = Some((tenant.to_string(), note.to_string()));
    }

    /// Is a fault currently armed (diagnostic / tests)?
    pub fn fault_armed(&self) -> bool {
        self.core.borrow().fault.is_some()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tenants_share_one_capacity() {
        let arena = Arena::new(100);
        let mut a = arena.tenant("a");
        let mut b = arena.tenant("b");
        assert_eq!(arena.tenants(), 2);
        let ra = a.alloc("x", 70).unwrap();
        // tenant b sees the shared remaining budget
        assert_eq!(b.remaining(), 30);
        assert!(b.alloc("x", 31).is_err());
        let rb = b.alloc("x", 30).unwrap();
        assert_eq!(arena.used(), 100);
        assert_eq!(arena.remaining(), 0);
        // per-tenant usage stays separate; the arena sums it
        assert_eq!(a.used(), 70);
        assert_eq!(b.used(), 30);
        a.free(ra).unwrap();
        b.free(rb).unwrap();
        assert_eq!(arena.used(), 0);
        assert_eq!(arena.peak(), 100);
        // per-tenant peaks are the tenants' own high-water marks
        assert_eq!(a.peak(), 70);
        assert_eq!(b.peak(), 30);
    }

    #[test]
    fn oom_names_the_tenant() {
        let arena = Arena::new(10);
        let mut a = arena.tenant("job-a");
        let err = a.alloc("resident", 11).unwrap_err();
        assert!(err.is_oom());
        assert!(err.to_string().contains("job-a"), "{err}");
    }

    #[test]
    fn oom_names_the_device_and_tenant() {
        // fleet attribution: every capacity refusal carries the device
        // label alongside the tenant, so a multi-device failure pinpoints
        // *which* simulated device refused the charge
        let arena = Arena::named("gpu1", 10);
        assert_eq!(arena.device(), "gpu1");
        let mut a = arena.tenant("job-a");
        let err = a.alloc("resident", 11).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("device=gpu1"), "{msg}");
        assert!(msg.contains("tenant=job-a"), "{msg}");
        // the solo constructor keeps a stable default label
        let solo = Arena::new(10);
        assert_eq!(solo.device(), "device0");
        let err = solo.tenant("t").alloc("x", 11).unwrap_err();
        assert!(err.to_string().contains("device=device0"), "{err}");
    }

    #[test]
    fn armed_fault_fires_once_for_its_tenant_only() {
        let arena = Arena::new(100);
        let mut a = arena.tenant("job-a");
        let mut b = arena.tenant("job-b");
        arena.arm_fault("job-a", "test transient");
        assert!(arena.fault_armed());
        // the sibling passes through untouched while the fault is armed
        let rb = b.alloc("resident", 10).unwrap();
        assert!(arena.fault_armed());
        let err = a.alloc("resident", 10).unwrap_err();
        assert!(err.is_oom(), "injected arena fault must be structured OOM: {err}");
        assert!(err.recoverable());
        let msg = err.to_string();
        assert!(msg.contains("injected fault: test transient"), "{msg}");
        assert!(msg.contains("job-a"), "{msg}");
        // the OOM arithmetic reflects the real arena state at fire time
        match err {
            MbsError::Oom { needed_bytes, available_bytes, capacity_bytes, .. } => {
                assert_eq!(needed_bytes, 20); // 10 live + 10 requested
                assert_eq!(available_bytes, 90);
                assert_eq!(capacity_bytes, 100);
            }
            other => panic!("want Oom, got {other:?}"),
        }
        // one-shot: the retry succeeds, and nothing was charged by the miss
        assert!(!arena.fault_armed());
        let ra = a.alloc("resident", 10).unwrap();
        assert_eq!(arena.used(), 20);
        a.free(ra).unwrap();
        b.free(rb).unwrap();
    }

    #[test]
    fn with_mib_scales_capacity() {
        let arena = Arena::with_mib(3);
        assert_eq!(arena.capacity(), 3 * MIB);
        assert!(arena.admits(3 * MIB) && !arena.admits(3 * MIB + 1));
    }

    #[test]
    fn clone_is_a_handle_not_a_device() {
        let arena = Arena::new(50);
        let view = arena.clone();
        let mut t = arena.tenant("t");
        let id = t.alloc("x", 20).unwrap();
        assert_eq!(view.used(), 20);
        t.free(id).unwrap();
        assert_eq!(view.used(), 0);
        assert_eq!(view.peak(), 20);
    }

    mod properties {
        use super::*;
        use crate::util::prop::{ensure, forall};

        #[test]
        fn cross_tenant_peak_never_exceeds_capacity() {
            // the tentpole invariant: at EVERY instant, the sum of live
            // bytes across tenants stays within capacity, and the arena's
            // bookkeeping (used == sum of tenant useds) never drifts
            forall(
                "arena bound",
                100,
                0xA7E,
                |r| {
                    let ops: Vec<(u64, u64)> =
                        (0..60).map(|_| (r.below(3), r.below(50))).collect();
                    ops
                },
                |ops| {
                    let arena = Arena::new(200);
                    let mut tenants =
                        vec![arena.tenant("t0"), arena.tenant("t1"), arena.tenant("t2")];
                    let mut live: Vec<Vec<crate::memory::ledger::AllocId>> =
                        vec![Vec::new(), Vec::new(), Vec::new()];
                    for &(t, sz) in ops {
                        let t = t as usize;
                        match tenants[t].alloc("x", sz) {
                            Ok(id) => live[t].push(id),
                            Err(_) => {
                                if let Some(id) = live[t].pop() {
                                    tenants[t].free(id).map_err(|e| e.to_string())?;
                                }
                            }
                        }
                        ensure(arena.used() <= arena.capacity(), "used > capacity")?;
                        ensure(arena.peak() <= arena.capacity(), "peak > capacity")?;
                        let sum: u64 = tenants.iter().map(|l| l.used()).sum();
                        ensure(
                            sum == arena.used(),
                            format!("tenant sum {sum} != arena used {}", arena.used()),
                        )?;
                        ensure(
                            arena.remaining() == arena.capacity() - arena.used(),
                            "remaining out of sync",
                        )?;
                    }
                    Ok(())
                },
            );
        }
    }
}
