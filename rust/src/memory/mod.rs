//! Simulated device memory model — the substitution for the paper's
//! RTX 3090 (DESIGN.md "Hardware adaptation & substitutions").
//!
//! The paper's phenomenon is capacity arithmetic: a training step fits iff
//!
//!   resident_state + batch_footprint(batch) <= capacity
//!
//! where resident_state is everything that lives on the device for the whole
//! run (params + gradient accumulator + optimizer slots + framework fixed
//! pool) and batch_footprint covers inputs plus the forward activations kept
//! for the backward pass, which scale linearly with the number of samples on
//! the device at once. Without MBS that number is the full mini-batch N_B;
//! with MBS it is the micro-batch mu — that single substitution is the whole
//! paper.
//!
//! [`MemoryModel`] does the arithmetic and produces structured
//! [`MbsError::Oom`] errors (the tables' `Failed` cells); [`Ledger`] is a
//! bump-style allocation tracker whose `remaining()` budget drives the
//! micro-batch planner (paper Alg. 1) and which the epoch executor charges
//! per step, asserting that planned residency never exceeds capacity at
//! any instant. [`Arena`] is the multi-tenant generalization: one shared
//! capacity with per-job [`Ledger`] views, so several training jobs can
//! time-share the device with the same every-instant accountability
//! (`coordinator/tenancy` plans admission against it).

pub mod arena;
pub mod fleet;
pub mod ledger;

pub use arena::Arena;
pub use fleet::{DeviceSpec, Fleet, FleetSpec};
pub use ledger::Ledger;

use crate::error::{MbsError, Result};
use crate::manifest::{ModelEntry, Variant};

/// One mebibyte — the unit `--capacity-mib` and the frontier grids speak.
pub const MIB: u64 = 1 << 20;

/// Static footprint description for one (model, variant) pair.
#[derive(Debug, Clone)]
pub struct Footprint {
    /// Model parameters (f32 leaves).
    pub param_bytes: u64,
    /// Gradient accumulator (same layout as params).
    pub grad_bytes: u64,
    /// Optimizer slots (momentum / adam m,v), each param-sized.
    pub opt_slot_bytes: u64,
    /// Per-sample activation residency (fwd intermediates kept for bwd).
    pub activation_bytes_per_sample: u64,
    /// Per-sample input bytes (x + y + mask).
    pub input_bytes_per_sample: u64,
    /// Batch-independent workspace (XLA temporaries etc.).
    pub fixed_bytes: u64,
}

impl Footprint {
    /// Derive from manifest metadata.
    pub fn from_manifest(model: &ModelEntry, variant: &Variant) -> Footprint {
        let elems = |shape: &[usize]| shape.iter().product::<usize>() as u64;
        let per_sample_x = elems(&variant.x_shape) / variant.mu as u64;
        let per_sample_y = elems(&variant.y_shape) / variant.mu as u64;
        Footprint {
            param_bytes: model.param_bytes,
            grad_bytes: model.param_bytes,
            opt_slot_bytes: model.param_bytes * model.optimizer.slots as u64,
            activation_bytes_per_sample: variant.activation_bytes_per_sample,
            input_bytes_per_sample: (per_sample_x + per_sample_y + 1) * 4,
            fixed_bytes: variant.fixed_bytes,
        }
    }

    /// Bytes resident for the whole training run (model parameter space in
    /// the paper's fig. 2).
    pub fn resident_bytes(&self) -> u64 {
        self.param_bytes + self.grad_bytes + self.opt_slot_bytes + self.fixed_bytes
    }

    /// Bytes needed while `n` samples are being computed on the device
    /// (the paper's data space).
    pub fn batch_bytes(&self, n: usize) -> u64 {
        (self.activation_bytes_per_sample + self.input_bytes_per_sample) * n as u64
    }

    /// Bytes needed while `n` samples run a forward-only (eval) step: just
    /// the input buffers — no activations are kept for a backward pass.
    /// The planner admission-checks this occupancy alongside the training
    /// step.
    pub fn eval_bytes(&self, n: usize) -> u64 {
        self.input_bytes_per_sample * n as u64
    }

    /// Bytes of one *staged* micro-batch's input buffers (x + y + mask) —
    /// the second device input slot the overlapped pipeline keeps resident
    /// while the current step executes. The overlapped peak is therefore
    /// `step_bytes(n) + overlap_bytes(n)` for training and
    /// `resident_bytes() + eval_bytes(n) + overlap_bytes(n)` for eval,
    /// which is what the planner admits under `--overlap on`.
    pub fn overlap_bytes(&self, n: usize) -> u64 {
        self.input_bytes_per_sample * n as u64
    }

    /// Bytes of backward-pass activation residency alone for `n` samples —
    /// what an executing training step holds *beyond* its already-staged
    /// input slot ([`Footprint::batch_bytes`]` = activation_bytes +
    /// overlap_bytes`, asserted by tests). The overlapped executor charges
    /// the ledger in these two pieces so mid-pipeline residency is exact.
    pub fn activation_bytes(&self, n: usize) -> u64 {
        self.activation_bytes_per_sample * n as u64
    }

    /// Total for a step computing `n` samples at once.
    pub fn step_bytes(&self, n: usize) -> u64 {
        self.resident_bytes() + self.batch_bytes(n)
    }

    /// Largest per-device sample count that fits in `capacity`.
    pub fn max_samples(&self, capacity: u64) -> usize {
        let resident = self.resident_bytes();
        if capacity <= resident {
            return 0;
        }
        ((capacity - resident) / (self.activation_bytes_per_sample + self.input_bytes_per_sample))
            as usize
    }
}

/// The simulated device: capacity plus the footprint arithmetic.
#[derive(Debug, Clone)]
pub struct MemoryModel {
    /// Total device capacity, bytes.
    pub capacity_bytes: u64,
    /// Footprint of the (model, variant) the device would run.
    pub footprint: Footprint,
}

impl MemoryModel {
    /// A simulated device of `capacity_bytes` running `footprint`.
    pub fn new(capacity_bytes: u64, footprint: Footprint) -> MemoryModel {
        MemoryModel { capacity_bytes, footprint }
    }

    /// Check the resident state alone fits (model upload).
    pub fn check_resident(&self) -> Result<()> {
        let need = self.footprint.resident_bytes();
        if need > self.capacity_bytes {
            return Err(self.oom(need, "model + optimizer state upload"));
        }
        Ok(())
    }

    /// Check a step that keeps `n` samples on the device at once — `n = N_B`
    /// for the native baseline, `n = mu` for MBS.
    pub fn check_step(&self, n: usize, context: &str) -> Result<()> {
        let need = self.footprint.step_bytes(n);
        if need > self.capacity_bytes {
            return Err(self.oom(need, context));
        }
        Ok(())
    }

    /// Largest batch the native (non-MBS) path can train.
    pub fn native_max_batch(&self) -> usize {
        self.footprint.max_samples(self.capacity_bytes)
    }

    fn oom(&self, needed: u64, context: &str) -> MbsError {
        let available = self.capacity_bytes.saturating_sub(self.footprint.resident_bytes());
        MbsError::Oom {
            needed_bytes: needed,
            available_bytes: available,
            capacity_bytes: self.capacity_bytes,
            context: context.to_string(),
        }
    }

    /// Capacity that makes `want` the native max batch — used by the bench
    /// configs to scale the paper's RTX-3090 frontier (table 2) down to the
    /// micro models: e.g. choose capacity so microresnet18 fits 16 natively.
    pub fn capacity_for_native_max(footprint: &Footprint, want: usize) -> u64 {
        footprint.step_bytes(want)
            + (footprint.activation_bytes_per_sample + footprint.input_bytes_per_sample) / 2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fp() -> Footprint {
        Footprint {
            param_bytes: 1000,
            grad_bytes: 1000,
            opt_slot_bytes: 1000,
            activation_bytes_per_sample: 500,
            input_bytes_per_sample: 100,
            fixed_bytes: 200,
        }
    }

    #[test]
    fn resident_and_step_arithmetic() {
        let f = fp();
        assert_eq!(f.resident_bytes(), 3200);
        assert_eq!(f.batch_bytes(4), 2400);
        assert_eq!(f.step_bytes(4), 5600);
        // forward-only eval keeps no bwd activations: inputs only
        assert_eq!(f.eval_bytes(4), 400);
        assert!(f.eval_bytes(4) < f.batch_bytes(4));
        // the staged second input slot is input-only, and a step's batch
        // residency decomposes exactly into activations + inputs
        assert_eq!(f.overlap_bytes(4), 400);
        assert_eq!(f.activation_bytes(4), 2000);
        assert_eq!(f.activation_bytes(4) + f.overlap_bytes(4), f.batch_bytes(4));
    }

    #[test]
    fn oom_exactly_at_frontier() {
        let f = fp();
        let m = MemoryModel::new(f.step_bytes(8), f.clone());
        assert!(m.check_step(8, "t").is_ok());
        assert!(m.check_step(9, "t").unwrap_err().is_oom());
        assert_eq!(m.native_max_batch(), 8);
    }

    #[test]
    fn resident_overflow_detected() {
        let f = fp();
        let m = MemoryModel::new(1000, f);
        assert!(m.check_resident().unwrap_err().is_oom());
    }

    #[test]
    fn capacity_for_native_max_roundtrips() {
        let f = fp();
        for want in [1usize, 2, 7, 16, 100] {
            let cap = MemoryModel::capacity_for_native_max(&f, want);
            let m = MemoryModel::new(cap, f.clone());
            assert_eq!(m.native_max_batch(), want, "want={want}");
        }
    }

    #[test]
    fn max_samples_zero_when_model_does_not_fit() {
        let f = fp();
        assert_eq!(f.max_samples(100), 0);
    }

    #[test]
    fn mbs_fits_where_native_fails() {
        // the paper's headline: with capacity fitting only 16 samples,
        // a 1024 mini-batch fails natively but streams fine at mu=16
        let f = fp();
        let m = MemoryModel::new(f.step_bytes(16), f.clone());
        assert!(m.check_step(1024, "native N_B=1024").unwrap_err().is_oom());
        assert!(m.check_step(16, "mbs mu=16").is_ok());
    }

    mod properties {
        use super::*;
        use crate::util::prop::{ensure, forall};
        use crate::util::rng::Rng;

        fn rand_fp(r: &mut Rng) -> Footprint {
            Footprint {
                param_bytes: r.below(1 << 20) + 1,
                grad_bytes: r.below(1 << 20) + 1,
                opt_slot_bytes: r.below(1 << 20),
                activation_bytes_per_sample: r.below(1 << 16) + 1,
                input_bytes_per_sample: r.below(1 << 12) + 1,
                fixed_bytes: r.below(1 << 16),
            }
        }

        #[test]
        fn native_trains_iff_within_capacity() {
            // DESIGN.md invariant 3 (memory frontier), property form
            forall(
                "frontier",
                200,
                0xF00D,
                |r| {
                    let f = rand_fp(r);
                    let cap = f.resident_bytes() + r.below(1 << 22);
                    let n = (r.below(64) + 1) as usize;
                    (f, cap, n)
                },
                |(f, cap, n)| {
                    let m = MemoryModel::new(*cap, f.clone());
                    let fits = f.step_bytes(*n) <= *cap;
                    ensure(
                        m.check_step(*n, "p").is_ok() == fits,
                        format!("fits={fits} step={} cap={cap}", f.step_bytes(*n)),
                    )
                },
            );
        }

        #[test]
        fn native_max_batch_is_tight() {
            forall(
                "tight max",
                200,
                0xBEEF,
                |r| {
                    let f = rand_fp(r);
                    let cap = f.resident_bytes() + r.below(1 << 24);
                    (f, cap)
                },
                |(f, cap)| {
                    let m = MemoryModel::new(*cap, f.clone());
                    let k = m.native_max_batch();
                    ensure(
                        f.step_bytes(k) <= *cap && f.step_bytes(k + 1) > *cap,
                        format!("k={k} not tight"),
                    )
                },
            );
        }

        #[test]
        fn mbs_feasibility_independent_of_batch() {
            // if mu fits, ANY N_B streams (the paper's theoretical claim:
            // mini-batch up to the dataset size)
            forall(
                "mu independence",
                200,
                0xCAFE,
                |r| {
                    let f = rand_fp(r);
                    let mu = (r.below(32) + 1) as usize;
                    let cap = f.step_bytes(mu) + r.below(1 << 16);
                    let nb = (r.below(1 << 20) + 1) as usize;
                    (f, cap, mu, nb)
                },
                |(f, cap, mu, _nb)| {
                    let m = MemoryModel::new(*cap, f.clone());
                    // MBS checks mu, never N_B
                    ensure(m.check_step(*mu, "mbs").is_ok(), "mu step must fit")
                },
            );
        }
    }
}
