//! A fleet of simulated devices with heterogeneous capacities.
//!
//! The paper deliberately scopes MBS to one device; composing its
//! streaming with data parallelism needs the next rung of the memory
//! model: several [`Arena`]s — one per simulated device, each with its own
//! capacity and cross-tenant accounting — addressed by name. A
//! [`FleetSpec`] is the declarative side (parsed from a `fleet.json`
//! `"devices"` array or a `--devices` CLI list); [`Fleet`] materializes it
//! as named arenas whose error paths stay attributable
//! (`device=…, tenant=…` — see [`Arena::named`]).
//!
//! Like the single arena, a fleet is single-threaded by design: the
//! data-parallel *executor* keeps every device-facing operation on the
//! engine thread (the PJRT client is `Rc`-backed), so the fleet is
//! memory-accounting parallelism, not thread parallelism. The host-side
//! assembly benchmark (`mbs fleet --dry-run`) constructs one arena *per
//! worker thread* instead of sharing a `Fleet` across threads.

use crate::error::{MbsError, Result};
use crate::util::json::Json;

use super::{Arena, MIB};

/// One simulated device of a fleet: a name and a capacity.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DeviceSpec {
    /// Device label (unique within the fleet; names error paths).
    pub name: String,
    /// Device capacity, bytes.
    pub capacity_bytes: u64,
}

/// Declarative fleet description: an ordered list of named device
/// capacities. Order is load-bearing — placement searches devices in spec
/// order, and the data-parallel splitter assigns shard `d` to device `d`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FleetSpec {
    /// The devices, in spec order.
    pub devices: Vec<DeviceSpec>,
}

impl FleetSpec {
    /// A uniform fleet of `count` devices named `dev0..devN-1`, each with
    /// `capacity_bytes` — the shape the frontier's device-count axis and
    /// the bit-identity oracle sweep.
    pub fn uniform(count: usize, capacity_bytes: u64) -> FleetSpec {
        FleetSpec {
            devices: (0..count)
                .map(|d| DeviceSpec { name: format!("dev{d}"), capacity_bytes })
                .collect(),
        }
    }

    /// Parse a `--devices` CLI list of per-device MiB capacities:
    /// `"4,2,2"` (auto-named `dev0..`) or `"gpu0=4,gpu1=2"` (explicit
    /// names). Mixing the two spellings is allowed per entry.
    pub fn parse(raw: &str) -> Result<FleetSpec> {
        let mut devices = Vec::new();
        for (i, part) in raw.split(',').enumerate() {
            let part = part.trim();
            let (name, cap) = match part.split_once('=') {
                Some((n, c)) => (n.trim().to_string(), c.trim()),
                None => (format!("dev{i}"), part),
            };
            let capacity_mib: u64 = cap.parse().map_err(|_| {
                MbsError::Config(format!("--devices: bad capacity '{part}' (want MiB integer)"))
            })?;
            devices.push(DeviceSpec { name, capacity_bytes: capacity_mib * MIB });
        }
        let spec = FleetSpec { devices };
        spec.validate()?;
        Ok(spec)
    }

    /// Parse the `"devices"` array of a `fleet.json` document:
    ///
    /// ```json
    /// { "devices": [ {"name": "gpu0", "capacity_mib": 4},
    ///                {"name": "gpu1", "capacity_mib": 2} ] }
    /// ```
    pub fn from_json(root: &Json) -> Result<FleetSpec> {
        let arr = root
            .get("devices")
            .and_then(Json::as_arr)
            .ok_or_else(|| MbsError::Config("fleet spec: missing 'devices' array".into()))?;
        let mut devices = Vec::new();
        for (i, v) in arr.iter().enumerate() {
            let obj = v.as_obj().ok_or_else(|| {
                MbsError::Config(format!("fleet spec: device #{i} must be an object"))
            })?;
            let name = match obj.get("name").and_then(Json::as_str) {
                Some(n) => n.to_string(),
                None => format!("dev{i}"),
            };
            let capacity_mib = obj
                .get("capacity_mib")
                .and_then(Json::as_u64)
                .ok_or_else(|| {
                    MbsError::Config(format!(
                        "fleet spec: device '{name}' needs a positive integer 'capacity_mib'"
                    ))
                })?;
            devices.push(DeviceSpec { name, capacity_bytes: capacity_mib * MIB });
        }
        let spec = FleetSpec { devices };
        spec.validate()?;
        Ok(spec)
    }

    /// Structural checks: at least one device, unique names, positive
    /// capacities.
    pub fn validate(&self) -> Result<()> {
        if self.devices.is_empty() {
            return Err(MbsError::Config("fleet spec: needs at least one device".into()));
        }
        let mut seen = std::collections::BTreeSet::new();
        for d in &self.devices {
            if d.name.is_empty() {
                return Err(MbsError::Config("fleet spec: empty device name".into()));
            }
            if d.capacity_bytes == 0 {
                return Err(MbsError::Config(format!(
                    "fleet spec: device '{}' has zero capacity",
                    d.name
                )));
            }
            if !seen.insert(d.name.as_str()) {
                return Err(MbsError::Config(format!(
                    "fleet spec: duplicate device name '{}'",
                    d.name
                )));
            }
        }
        Ok(())
    }

    /// Number of devices.
    pub fn len(&self) -> usize {
        self.devices.len()
    }

    /// Is the fleet empty? (Never true for a validated spec.)
    pub fn is_empty(&self) -> bool {
        self.devices.is_empty()
    }

    /// Sum of every device's capacity, bytes.
    pub fn total_capacity(&self) -> u64 {
        self.devices.iter().map(|d| d.capacity_bytes).sum()
    }

    /// The smallest device capacity, bytes (0 for an empty spec). The
    /// data-parallel planner resolves `mu` against this: one global split
    /// plan must fit *every* device.
    pub fn min_capacity(&self) -> u64 {
        self.devices.iter().map(|d| d.capacity_bytes).min().unwrap_or(0)
    }

    /// Materialize the spec as live arenas.
    pub fn build(&self) -> Fleet {
        Fleet::new(self)
    }
}

/// A fleet of live, named [`Arena`]s — the runtime side of a
/// [`FleetSpec`].
///
/// ```
/// use mbs::memory::{FleetSpec, MIB};
///
/// let fleet = FleetSpec::parse("gpu0=4,gpu1=2").unwrap().build();
/// assert_eq!(fleet.len(), 2);
/// assert_eq!(fleet.arena(1).capacity(), 2 * MIB);
/// let mut t = fleet.arena(1).tenant("job");
/// let err = t.alloc("resident", 3 * MIB).unwrap_err();
/// assert!(err.to_string().contains("device=gpu1"));
/// ```
#[derive(Debug, Clone)]
pub struct Fleet {
    devices: Vec<(String, Arena)>,
}

impl Fleet {
    /// Build one named arena per device of the spec.
    pub fn new(spec: &FleetSpec) -> Fleet {
        Fleet {
            devices: spec
                .devices
                .iter()
                .map(|d| (d.name.clone(), Arena::named(&d.name, d.capacity_bytes)))
                .collect(),
        }
    }

    /// Number of devices.
    pub fn len(&self) -> usize {
        self.devices.len()
    }

    /// Is the fleet empty? (Never true when built from a validated spec.)
    pub fn is_empty(&self) -> bool {
        self.devices.is_empty()
    }

    /// Device arena by rank (panics out of range, like slice indexing).
    pub fn arena(&self, rank: usize) -> &Arena {
        &self.devices[rank].1
    }

    /// Device name by rank.
    pub fn name(&self, rank: usize) -> &str {
        &self.devices[rank].0
    }

    /// Device arena by name.
    pub fn by_name(&self, name: &str) -> Option<&Arena> {
        self.devices.iter().find(|(n, _)| n == name).map(|(_, a)| a)
    }

    /// Iterate `(name, arena)` in rank order.
    pub fn iter(&self) -> impl Iterator<Item = (&str, &Arena)> {
        self.devices.iter().map(|(n, a)| (n.as_str(), a))
    }

    /// Sum of every device's capacity, bytes.
    pub fn total_capacity(&self) -> u64 {
        self.devices.iter().map(|(_, a)| a.capacity()).sum()
    }

    /// Sum of live bytes across every device.
    pub fn total_used(&self) -> u64 {
        self.devices.iter().map(|(_, a)| a.used()).sum()
    }

    /// The largest per-device high-water mark — each device's peak never
    /// exceeds its own capacity by construction, so this is the fleet's
    /// "worst device pressure" diagnostic.
    pub fn max_device_peak(&self) -> u64 {
        self.devices.iter().map(|(_, a)| a.peak()).max().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_devices_list() {
        let spec = FleetSpec::parse("4,2,2").unwrap();
        assert_eq!(spec.len(), 3);
        assert_eq!(spec.devices[0], DeviceSpec { name: "dev0".into(), capacity_bytes: 4 * MIB });
        assert_eq!(spec.devices[2].name, "dev2");
        assert_eq!(spec.total_capacity(), 8 * MIB);
        assert_eq!(spec.min_capacity(), 2 * MIB);
    }

    #[test]
    fn parse_named_devices() {
        let spec = FleetSpec::parse("gpu0=4, gpu1=2").unwrap();
        assert_eq!(spec.devices[0].name, "gpu0");
        assert_eq!(spec.devices[1].capacity_bytes, 2 * MIB);
    }

    #[test]
    fn parse_rejects_garbage_and_duplicates() {
        assert!(FleetSpec::parse("4,x").is_err());
        assert!(FleetSpec::parse("a=4,a=2").is_err());
        assert!(FleetSpec::parse("0").is_err(), "zero capacity must be rejected");
        assert!(FleetSpec::parse("").is_err());
    }

    #[test]
    fn from_json_roundtrip() {
        let root = Json::parse(
            r#"{"devices": [{"name": "big", "capacity_mib": 8},
                            {"capacity_mib": 2}]}"#,
        )
        .unwrap();
        let spec = FleetSpec::from_json(&root).unwrap();
        assert_eq!(spec.devices[0].name, "big");
        // unnamed devices get rank names
        assert_eq!(spec.devices[1].name, "dev1");
        assert!(FleetSpec::from_json(&Json::parse("{}").unwrap()).is_err());
    }

    #[test]
    fn uniform_fleet_shape() {
        let spec = FleetSpec::uniform(4, MIB);
        assert_eq!(spec.len(), 4);
        assert!(spec.devices.iter().all(|d| d.capacity_bytes == MIB));
        assert_eq!(spec.devices[3].name, "dev3");
        spec.validate().unwrap();
    }

    #[test]
    fn fleet_arenas_are_independent_and_attributable() {
        let fleet = FleetSpec::parse("a=1,b=2").unwrap().build();
        assert_eq!(fleet.len(), 2);
        assert_eq!(fleet.total_capacity(), 3 * MIB);
        let mut ta = fleet.arena(0).tenant("job");
        let mut tb = fleet.by_name("b").unwrap().tenant("job");
        // capacities are per-device, not pooled: device a refuses what
        // device b admits
        assert!(ta.alloc("x", 2 * MIB).is_err());
        let id = tb.alloc("x", 2 * MIB).unwrap();
        assert_eq!(fleet.total_used(), 2 * MIB);
        assert_eq!(fleet.max_device_peak(), 2 * MIB);
        // the refusal names the refusing device
        let msg = ta.alloc("x", 2 * MIB).unwrap_err().to_string();
        assert!(msg.contains("device=a"), "{msg}");
        tb.free(id).unwrap();
        assert_eq!(fleet.total_used(), 0);
        assert_eq!(fleet.name(1), "b");
    }
}
