//! Typed training configuration + builder + file/CLI loading.
//!
//! Configs come from three layers, later overriding earlier:
//!   1. model defaults (manifest hyper-parameters, paper section 4.2.4)
//!   2. a flat `key = value` config file (`--config run.cfg`)
//!   3. CLI flags (`--batch 128 --mu 16 ...`)

use std::fmt;

use crate::coordinator::accumulator::NormalizationMode;
use crate::coordinator::streamer::StreamingPolicy;
use crate::error::{MbsError, Result};
use crate::memory::MIB;
use crate::util::cli::Args;
use crate::util::json::Json;

/// How the micro-batch size is chosen (paper Alg. 1).
///
/// The paper's point is that `mu` is *derived* from the memory remaining
/// after the model is resident — [`MicroBatchSpec::Auto`] asks the planner
/// ([`crate::coordinator::planner`]) to pick the largest exported variant
/// that fits the device; [`MicroBatchSpec::Fixed`] pins it by hand (the
/// pre-planner behaviour, still used by ablations and the benches).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MicroBatchSpec {
    /// Derive `mu` from the memory model: largest exported variant whose
    /// step fits `capacity - resident_bytes`.
    Auto,
    /// Use exactly this micro-batch size. Need not be exported: the
    /// artifact manager (`runtime/artifacts.rs`) compiles unexported
    /// variants on demand, so memory — not export coverage — is the
    /// binding constraint.
    Fixed(usize),
}

impl MicroBatchSpec {
    /// Parse `"auto"` or a positive integer (CLI `--mu` values).
    ///
    /// ```
    /// use mbs::MicroBatchSpec;
    /// assert_eq!(MicroBatchSpec::parse("auto"), Some(MicroBatchSpec::Auto));
    /// assert_eq!(MicroBatchSpec::parse("16"), Some(MicroBatchSpec::Fixed(16)));
    /// assert_eq!(MicroBatchSpec::parse("huge"), None);
    /// ```
    pub fn parse(s: &str) -> Option<MicroBatchSpec> {
        if s.eq_ignore_ascii_case("auto") {
            Some(MicroBatchSpec::Auto)
        } else {
            s.parse().ok().map(MicroBatchSpec::Fixed)
        }
    }

    /// The pinned size, if any.
    pub fn fixed(&self) -> Option<usize> {
        match self {
            MicroBatchSpec::Auto => None,
            MicroBatchSpec::Fixed(mu) => Some(*mu),
        }
    }

    /// Is this the planner-derived (`Auto`) spec?
    pub fn is_auto(&self) -> bool {
        matches!(self, MicroBatchSpec::Auto)
    }
}

impl fmt::Display for MicroBatchSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            MicroBatchSpec::Auto => write!(f, "auto"),
            MicroBatchSpec::Fixed(mu) => write!(f, "{mu}"),
        }
    }
}

/// Parse an on/off switch the way the CLI spells it (`--overlap on|off`,
/// with `true|false|1|0` accepted as aliases; case-insensitive, matching
/// `--prefetch auto`).
///
/// ```
/// use mbs::config::parse_on_off;
/// assert_eq!(parse_on_off("on"), Some(true));
/// assert_eq!(parse_on_off("OFF"), Some(false));
/// assert_eq!(parse_on_off("false"), Some(false));
/// assert_eq!(parse_on_off("maybe"), None);
/// ```
pub fn parse_on_off(s: &str) -> Option<bool> {
    match s.to_ascii_lowercase().as_str() {
        "on" | "true" | "1" => Some(true),
        "off" | "false" | "0" => Some(false),
        _ => None,
    }
}

/// Learning-rate schedule (the AmoebaNet recipe uses linear decay).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum LrSchedule {
    /// The base learning rate for the whole run.
    Constant,
    /// Linearly decay from the base LR to `final_frac * base` over training.
    LinearDecay {
        /// Fraction of the base LR reached at the final update.
        final_frac: f32,
    },
}

impl LrSchedule {
    /// Multiplier applied to the base LR at 0-based update `update`.
    pub fn factor(&self, update: u64, total_updates: u64) -> f32 {
        match self {
            LrSchedule::Constant => 1.0,
            LrSchedule::LinearDecay { final_frac } => {
                if total_updates <= 1 {
                    return 1.0;
                }
                let t = (update as f32 / (total_updates - 1) as f32).min(1.0);
                1.0 - t * (1.0 - final_frac)
            }
        }
    }
}

/// One training run's full configuration (model, geometry, memory, policy).
#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// Manifest model key (microresnet18 / microresnet34 / amoebacell /
    /// microunet / microformer).
    pub model: String,
    /// Image size or sequence length; `None` = manifest default.
    pub size: Option<usize>,
    /// Micro-batch size: planner-derived (`Auto`, the default — paper
    /// Alg. 1) or pinned (`Fixed`, compiled on demand when unexported).
    pub mu: MicroBatchSpec,
    /// Mini-batch size N_B.
    pub batch: usize,
    /// Training epochs (must be ≥ 1).
    pub epochs: usize,
    /// Training set size (synthetic, generated on the fly).
    pub dataset_len: usize,
    /// Held-out eval set size.
    pub eval_len: usize,
    /// Simulated device capacity; `None` = headroom for exactly the MBS
    /// step (mu samples) times two.
    pub capacity_mib: Option<u64>,
    /// Distinct classes the synthetic classification data actually uses.
    /// The exported heads are 102-wide (Flower-102), but at micro scale a
    /// 102-way problem does not move within a few epochs; 16 effective
    /// classes keeps the accuracy curves informative (paper fig. 3 shape)
    /// while exercising the same code path.
    pub num_classes: usize,
    /// Use MBS (true) or the native baseline (false). The native baseline
    /// computes the whole mini-batch in one step and OOMs past the memory
    /// frontier — the paper's "w/o MBS" column.
    pub use_mbs: bool,
    /// Loss-normalization policy (paper section 3.4).
    pub norm_mode: NormalizationMode,
    /// Assemble micro-batches inline or on an overlapped worker thread.
    pub streaming: StreamingPolicy,
    /// Micro-batches staged ahead of the one executing.
    pub prefetch: usize,
    /// Tune `prefetch` per epoch from `StageTimers` (`--prefetch auto`):
    /// grow while host assembly bounds the pipeline, capped at a small
    /// multiple of `N_Smu`; the chosen value lands in `TrainReport`.
    pub prefetch_auto: bool,
    /// Overlapped upload/execute pipeline (`--overlap on`/`async`, the
    /// default): a dedicated upload-lane thread stages micro-batch `j+1`
    /// in real wall-clock parallel with step `j`'s device execution, and
    /// the runtime double-buffers the device input slots. The ledger
    /// prices the extra staged slot, so the planner may derive a smaller
    /// `mu` than with `--overlap off`/`serial` — which stays available as
    /// the serial byte-identity oracle.
    pub overlap: bool,
    /// Seed for dataset generation and epoch shuffles.
    pub seed: u64,
    /// Learning-rate schedule applied across optimizer updates.
    pub lr_schedule: LrSchedule,
    /// Override the manifest's base learning rate.
    pub lr: Option<f32>,
    /// Skip the eval pass after each epoch (benches that only need timing).
    pub skip_eval: bool,
    /// Save a checkpoint every N optimizer updates (requires `checkpoint`).
    pub checkpoint_every: Option<u64>,
    /// Checkpoint path stem: the run writes `<stem>.bin` / `<stem>.json`
    /// periodically (`checkpoint_every`) and at the end of training.
    pub checkpoint: Option<String>,
    /// Resume from a checkpoint stem before training (skips the updates it
    /// already covers, then replays the rest of the schedule).
    pub resume: Option<String>,
    /// Deterministic fault-injection spec (JSON path) — arms the recovery
    /// state machine in [`crate::coordinator::trainer`].
    pub faults: Option<String>,
}

impl TrainConfig {
    /// Start a fluent [`TrainConfigBuilder`] from the model defaults.
    pub fn builder(model: &str) -> TrainConfigBuilder {
        TrainConfigBuilder { cfg: TrainConfig::default_for(model) }
    }

    /// The default configuration for a model key (paper section 4.2.4
    /// hyper-parameters come from the manifest at resolve time).
    pub fn default_for(model: &str) -> TrainConfig {
        TrainConfig {
            model: model.to_string(),
            size: None,
            mu: MicroBatchSpec::Auto,
            batch: 16,
            epochs: 3,
            dataset_len: 512,
            eval_len: 128,
            capacity_mib: None,
            num_classes: 16,
            use_mbs: true,
            norm_mode: NormalizationMode::Paper,
            streaming: StreamingPolicy::DoubleBuffered,
            prefetch: 2,
            prefetch_auto: false,
            overlap: true,
            seed: 0,
            lr_schedule: LrSchedule::Constant,
            lr: None,
            skip_eval: false,
            checkpoint_every: None,
            checkpoint: None,
            resume: None,
            faults: None,
        }
    }

    /// The pinned capacity in bytes, if `capacity_mib` is set.
    pub fn capacity_bytes(&self) -> Option<u64> {
        self.capacity_mib.map(|m| m * MIB)
    }

    /// Apply `key = value` overrides (config-file lines or CLI pairs).
    pub fn set(&mut self, key: &str, value: &str) -> Result<()> {
        let bad = |k: &str, v: &str| MbsError::Config(format!("invalid value {v:?} for {k}"));
        match key {
            "model" => self.model = value.to_string(),
            "size" => self.size = Some(value.parse().map_err(|_| bad(key, value))?),
            "mu" => {
                self.mu = MicroBatchSpec::parse(value).ok_or_else(|| bad(key, value))?
            }
            "batch" => self.batch = value.parse().map_err(|_| bad(key, value))?,
            "epochs" => self.epochs = value.parse().map_err(|_| bad(key, value))?,
            "dataset-len" | "dataset_len" => {
                self.dataset_len = value.parse().map_err(|_| bad(key, value))?
            }
            "eval-len" | "eval_len" => {
                self.eval_len = value.parse().map_err(|_| bad(key, value))?
            }
            "capacity-mib" | "capacity_mib" => {
                self.capacity_mib = Some(value.parse().map_err(|_| bad(key, value))?)
            }
            "num-classes" | "num_classes" => {
                self.num_classes = value.parse().map_err(|_| bad(key, value))?
            }
            "mbs" => self.use_mbs = value.parse().map_err(|_| bad(key, value))?,
            "norm" => {
                self.norm_mode =
                    NormalizationMode::parse(value).ok_or_else(|| bad(key, value))?
            }
            "streaming" => {
                self.streaming = StreamingPolicy::parse(value).ok_or_else(|| bad(key, value))?
            }
            "prefetch" => {
                if value.eq_ignore_ascii_case("auto") {
                    self.prefetch_auto = true;
                } else {
                    self.prefetch = value.parse().map_err(|_| bad(key, value))?;
                    self.prefetch_auto = false;
                }
            }
            // `async`/`serial` name the upload-lane modes directly: `async`
            // is the dedicated staging thread (same as `on`), `serial` the
            // inline byte-identity oracle (same as `off`)
            "overlap" => {
                self.overlap = match value.to_ascii_lowercase().as_str() {
                    "async" => true,
                    "serial" => false,
                    other => parse_on_off(other).ok_or_else(|| bad(key, value))?,
                }
            }
            "seed" => self.seed = value.parse().map_err(|_| bad(key, value))?,
            "lr" => self.lr = Some(value.parse().map_err(|_| bad(key, value))?),
            "lr-decay" | "lr_decay" => {
                self.lr_schedule = LrSchedule::LinearDecay {
                    final_frac: value.parse().map_err(|_| bad(key, value))?,
                }
            }
            "skip-eval" | "skip_eval" => {
                self.skip_eval = value.parse().map_err(|_| bad(key, value))?
            }
            "checkpoint-every" | "checkpoint_every" => {
                self.checkpoint_every = Some(value.parse().map_err(|_| bad(key, value))?)
            }
            "checkpoint" => self.checkpoint = Some(value.to_string()),
            "resume" => self.resume = Some(value.to_string()),
            "faults" => self.faults = Some(value.to_string()),
            other => return Err(MbsError::Config(format!("unknown config key '{other}'"))),
        }
        Ok(())
    }

    /// Apply a JSON value to a config key — how `jobs.json` job entries
    /// (`mbs jobs --spec`) reuse the exact flag/file parser: numbers
    /// render as integers when whole, booleans as `true`/`false`, strings
    /// pass through, anything structured is rejected.
    pub fn set_json(&mut self, key: &str, value: &Json) -> Result<()> {
        let rendered = match value {
            Json::Str(s) => s.clone(),
            Json::Bool(b) => b.to_string(),
            Json::Num(n) if n.fract() == 0.0 && n.abs() < 9e15 => format!("{}", *n as i64),
            Json::Num(n) => format!("{n}"),
            other => {
                return Err(MbsError::Config(format!(
                    "config key '{key}': expected a scalar JSON value, got {other:?}"
                )))
            }
        };
        self.set(key, &rendered)
    }

    /// Flat `key = value` config file ('#' comments, blank lines ok).
    pub fn load_file(&mut self, path: &str) -> Result<()> {
        let text = std::fs::read_to_string(path)?;
        for (lineno, line) in text.lines().enumerate() {
            let line = line.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let (k, v) = line.split_once('=').ok_or_else(|| {
                MbsError::Config(format!("{path}:{}: expected key = value", lineno + 1))
            })?;
            self.set(k.trim(), v.trim())?;
        }
        Ok(())
    }

    /// Overlay CLI flags (every config key doubles as a flag).
    pub fn apply_args(&mut self, args: &Args) -> Result<()> {
        for key in [
            "model", "size", "mu", "batch", "epochs", "dataset-len", "eval-len",
            "capacity-mib", "num-classes", "mbs", "norm", "streaming", "prefetch",
            "overlap", "seed", "lr", "lr-decay", "skip-eval", "checkpoint-every",
            "checkpoint", "resume", "faults",
        ] {
            if let Some(v) = args.get(key) {
                self.set(key, v)?;
            }
        }
        Ok(())
    }

    /// Every config key that doubles as a CLI flag (plus `config` itself).
    pub const ARG_KEYS: &'static [&'static str] = &[
        "model", "size", "mu", "batch", "epochs", "dataset-len", "eval-len",
        "capacity-mib", "num-classes", "mbs", "norm", "streaming", "prefetch",
        "overlap", "seed", "lr", "lr-decay", "skip-eval", "checkpoint-every",
        "checkpoint", "resume", "faults", "config",
    ];

    /// Reject configurations no run mode can execute.
    pub fn validate(&self) -> Result<()> {
        // epochs == 0 in particular must be rejected up front: downstream
        // reporting averages per-epoch wall times, and an empty run has no
        // meaningful mean (regression: zero_epochs_rejected).
        if self.batch == 0 || self.epochs == 0 {
            return Err(MbsError::Config("batch and epochs must be positive".into()));
        }
        if self.mu == MicroBatchSpec::Fixed(0) {
            return Err(MbsError::Config("mu must be positive (or 'auto')".into()));
        }
        if self.dataset_len == 0 {
            return Err(MbsError::Config("dataset-len must be positive".into()));
        }
        if self.checkpoint_every == Some(0) {
            return Err(MbsError::Config("checkpoint-every must be positive".into()));
        }
        if self.checkpoint_every.is_some() && self.checkpoint.is_none() {
            return Err(MbsError::Config(
                "checkpoint-every needs --checkpoint <path> to write to".into(),
            ));
        }
        Ok(())
    }
}

/// Fluent builder used by examples and benches.
pub struct TrainConfigBuilder {
    cfg: TrainConfig,
}

impl TrainConfigBuilder {
    /// Image size / sequence length (default: the manifest's).
    pub fn size(mut self, v: usize) -> Self {
        self.cfg.size = Some(v);
        self
    }
    /// Pin the micro-batch size (compiled on demand when unexported).
    pub fn mu(mut self, v: usize) -> Self {
        self.cfg.mu = MicroBatchSpec::Fixed(v);
        self
    }
    /// Let the planner derive the micro-batch size from remaining memory
    /// (the default; this resets an earlier `.mu(..)`).
    pub fn mu_auto(mut self) -> Self {
        self.cfg.mu = MicroBatchSpec::Auto;
        self
    }
    /// Mini-batch size `N_B`.
    pub fn batch(mut self, v: usize) -> Self {
        self.cfg.batch = v;
        self
    }
    /// Training epochs.
    pub fn epochs(mut self, v: usize) -> Self {
        self.cfg.epochs = v;
        self
    }
    /// Synthetic training-set size.
    pub fn dataset_len(mut self, v: usize) -> Self {
        self.cfg.dataset_len = v;
        self
    }
    /// Held-out eval-set size.
    pub fn eval_len(mut self, v: usize) -> Self {
        self.cfg.eval_len = v;
        self
    }
    /// Simulated device capacity in MiB.
    pub fn capacity_mib(mut self, v: u64) -> Self {
        self.cfg.capacity_mib = Some(v);
        self
    }
    /// Run the native "w/o MBS" baseline instead of MBS.
    pub fn baseline(mut self) -> Self {
        self.cfg.use_mbs = false;
        self
    }
    /// Loss-normalization policy.
    pub fn norm(mut self, m: NormalizationMode) -> Self {
        self.cfg.norm_mode = m;
        self
    }
    /// Streaming policy (overlapped vs synchronous assembly).
    pub fn streaming(mut self, p: StreamingPolicy) -> Self {
        self.cfg.streaming = p;
        self
    }
    /// Overlapped upload/execute pipeline on/off (`false` = the serial
    /// byte-identity oracle).
    pub fn overlap(mut self, on: bool) -> Self {
        self.cfg.overlap = on;
        self
    }
    /// Initial prefetch depth (micro-batches staged ahead).
    pub fn prefetch(mut self, n: usize) -> Self {
        self.cfg.prefetch = n;
        self
    }
    /// Tune the prefetch depth per epoch from `StageTimers`
    /// (`--prefetch auto`).
    pub fn prefetch_auto(mut self) -> Self {
        self.cfg.prefetch_auto = true;
        self
    }
    /// Run seed (datasets + shuffles).
    pub fn seed(mut self, s: u64) -> Self {
        self.cfg.seed = s;
        self
    }
    /// Override the manifest's base learning rate.
    pub fn lr(mut self, lr: f32) -> Self {
        self.cfg.lr = Some(lr);
        self
    }
    /// Linearly decay the LR to `final_frac * base` over the run.
    pub fn lr_decay(mut self, final_frac: f32) -> Self {
        self.cfg.lr_schedule = LrSchedule::LinearDecay { final_frac };
        self
    }
    /// Skip the per-epoch eval pass (timing-only benches).
    pub fn skip_eval(mut self) -> Self {
        self.cfg.skip_eval = true;
        self
    }
    /// Finish the builder.
    pub fn build(self) -> TrainConfig {
        self.cfg
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_defaults_and_overrides() {
        let c = TrainConfig::builder("microresnet18").batch(128).mu(16).epochs(2).build();
        assert_eq!(c.model, "microresnet18");
        assert_eq!(c.batch, 128);
        assert_eq!(c.mu, MicroBatchSpec::Fixed(16));
        assert!(c.use_mbs);
        c.validate().unwrap();
        // the default (and `.mu_auto()`) asks the planner to derive mu
        let d = TrainConfig::builder("microresnet18").build();
        assert_eq!(d.mu, MicroBatchSpec::Auto);
        let e = TrainConfig::builder("m").mu(8).mu_auto().build();
        assert!(e.mu.is_auto());
    }

    #[test]
    fn micro_batch_spec_parse_and_display() {
        assert_eq!(MicroBatchSpec::parse("auto"), Some(MicroBatchSpec::Auto));
        assert_eq!(MicroBatchSpec::parse("16"), Some(MicroBatchSpec::Fixed(16)));
        assert_eq!(MicroBatchSpec::parse("x"), None);
        assert_eq!(MicroBatchSpec::Auto.to_string(), "auto");
        assert_eq!(MicroBatchSpec::Fixed(8).to_string(), "8");
        assert_eq!(MicroBatchSpec::Fixed(8).fixed(), Some(8));
        assert_eq!(MicroBatchSpec::Auto.fixed(), None);
    }

    #[test]
    fn set_parses_all_keys() {
        let mut c = TrainConfig::default_for("m");
        c.set("mu", "auto").unwrap();
        assert_eq!(c.mu, MicroBatchSpec::Auto);
        c.set("mu", "32").unwrap();
        assert_eq!(c.mu, MicroBatchSpec::Fixed(32));
        assert!(c.set("mu", "huge").is_err());
        c.set("batch", "64").unwrap();
        c.set("norm", "exact").unwrap();
        c.set("streaming", "sync").unwrap();
        c.set("capacity-mib", "128").unwrap();
        c.set("mbs", "false").unwrap();
        c.set("lr-decay", "0.1").unwrap();
        assert_eq!(c.batch, 64);
        assert_eq!(c.norm_mode, NormalizationMode::Exact);
        assert_eq!(c.streaming, StreamingPolicy::Synchronous);
        assert_eq!(c.capacity_bytes(), Some(128 * MIB));
        assert!(!c.use_mbs);
        assert!(matches!(c.lr_schedule, LrSchedule::LinearDecay { .. }));
        assert!(c.set("bogus", "1").is_err());
        assert!(c.set("batch", "abc").is_err());
    }

    #[test]
    fn overlap_key_parses_on_off() {
        let mut c = TrainConfig::default_for("m");
        assert!(c.overlap, "overlap must default on");
        c.set("overlap", "off").unwrap();
        assert!(!c.overlap);
        c.set("overlap", "on").unwrap();
        assert!(c.overlap);
        c.set("overlap", "OFF").unwrap(); // case-insensitive like --prefetch auto
        assert!(!c.overlap);
        c.set("overlap", "false").unwrap();
        assert!(!c.overlap);
        // lane-mode spellings: async == on (staging thread), serial == off
        c.set("overlap", "async").unwrap();
        assert!(c.overlap);
        c.set("overlap", "serial").unwrap();
        assert!(!c.overlap);
        c.set("overlap", "ASYNC").unwrap();
        assert!(c.overlap);
        assert!(c.set("overlap", "sideways").is_err());
        // builder spelling
        let b = TrainConfig::builder("m").overlap(false).build();
        assert!(!b.overlap);
    }

    #[test]
    fn prefetch_key_accepts_auto_and_numbers() {
        let mut c = TrainConfig::default_for("m");
        assert!(!c.prefetch_auto);
        c.set("prefetch", "auto").unwrap();
        assert!(c.prefetch_auto);
        assert_eq!(c.prefetch, 2, "auto keeps the default as the starting depth");
        // an explicit number pins the depth and turns tuning back off
        c.set("prefetch", "5").unwrap();
        assert!(!c.prefetch_auto);
        assert_eq!(c.prefetch, 5);
        assert!(c.set("prefetch", "many").is_err());
        let b = TrainConfig::builder("m").prefetch(3).prefetch_auto().build();
        assert!(b.prefetch_auto);
        assert_eq!(b.prefetch, 3);
    }

    #[test]
    fn set_json_renders_scalars_through_the_flag_parser() {
        let mut c = TrainConfig::default_for("m");
        c.set_json("batch", &Json::Num(64.0)).unwrap();
        assert_eq!(c.batch, 64);
        c.set_json("mu", &Json::Str("auto".into())).unwrap();
        assert!(c.mu.is_auto());
        c.set_json("mu", &Json::Num(8.0)).unwrap();
        assert_eq!(c.mu, MicroBatchSpec::Fixed(8));
        c.set_json("skip-eval", &Json::Bool(true)).unwrap();
        assert!(c.skip_eval);
        c.set_json("lr", &Json::Num(0.25)).unwrap();
        assert_eq!(c.lr, Some(0.25));
        // structured values and unknown keys are rejected
        assert!(c.set_json("batch", &Json::Arr(vec![])).is_err());
        assert!(c.set_json("bogus", &Json::Num(1.0)).is_err());
    }

    #[test]
    fn config_file_roundtrip() {
        let path = std::env::temp_dir().join(format!("mbs-cfg-{}.cfg", std::process::id()));
        std::fs::write(&path, "# comment\nbatch = 256\nmu=32 # inline\n\nnorm = paper\n").unwrap();
        let mut c = TrainConfig::default_for("m");
        c.load_file(path.to_str().unwrap()).unwrap();
        assert_eq!(c.batch, 256);
        assert_eq!(c.mu, MicroBatchSpec::Fixed(32));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn invalid_configs_rejected() {
        let mut c = TrainConfig::default_for("m");
        c.mu = MicroBatchSpec::Fixed(0);
        assert!(c.validate().is_err());
    }

    #[test]
    fn zero_epochs_rejected() {
        // regression: epochs == 0 used to reach the reporting layer, where
        // an empty per-epoch wall list poisons the mean wall-time duration
        let mut c = TrainConfig::default_for("m");
        c.epochs = 0;
        let err = c.validate().unwrap_err();
        assert!(matches!(err, MbsError::Config(_)), "want Config error, got {err:?}");
        c.epochs = 1;
        c.skip_eval = true;
        c.validate().unwrap(); // skip-eval alone stays valid
    }

    #[test]
    fn checkpoint_and_fault_keys() {
        let mut c = TrainConfig::default_for("m");
        assert!(c.checkpoint.is_none() && c.resume.is_none() && c.faults.is_none());
        c.set("checkpoint", "/tmp/ck").unwrap();
        c.set("checkpoint-every", "8").unwrap();
        c.set("resume", "/tmp/old").unwrap();
        c.set("faults", "specs/faults.json").unwrap();
        assert_eq!(c.checkpoint.as_deref(), Some("/tmp/ck"));
        assert_eq!(c.checkpoint_every, Some(8));
        assert_eq!(c.resume.as_deref(), Some("/tmp/old"));
        assert_eq!(c.faults.as_deref(), Some("specs/faults.json"));
        c.validate().unwrap();
        assert!(c.set("checkpoint-every", "eight").is_err());
        // checkpoint-every without a path, or zero, is rejected up front
        let mut bad = TrainConfig::default_for("m");
        bad.checkpoint_every = Some(4);
        assert!(bad.validate().is_err());
        bad.checkpoint = Some("ck".into());
        bad.validate().unwrap();
        bad.checkpoint_every = Some(0);
        assert!(bad.validate().is_err());
    }

    #[test]
    fn lr_schedule_factors() {
        let s = LrSchedule::LinearDecay { final_frac: 0.0 };
        assert_eq!(s.factor(0, 11), 1.0);
        assert!((s.factor(10, 11) - 0.0).abs() < 1e-6);
        assert!((s.factor(5, 11) - 0.5).abs() < 1e-6);
        assert_eq!(LrSchedule::Constant.factor(7, 10), 1.0);
        assert_eq!(s.factor(0, 1), 1.0); // degenerate
    }
}
