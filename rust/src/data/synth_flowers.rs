//! SynthFlowers: class-conditioned procedural textures standing in for the
//! Flower-102 classification dataset.
//!
//! Each class owns a signature (two Gabor-like plane-wave components with
//! class-specific frequency/orientation/colour plus a radial blob); each item
//! renders its class signature with per-item phase jitter, translation and
//! additive noise. The signal-to-nuisance ratio is chosen so that a small
//! CNN needs several epochs to separate classes — accuracy curves move, like
//! the paper's fig. 3, rather than saturating instantly.

use crate::manifest::Dtype;
use crate::util::rng::Rng;

use super::{Dataset, SliceMut};

/// Class-conditioned procedural texture dataset (Flower-102 stand-in).
#[derive(Debug, Clone)]
pub struct SynthFlowers {
    size: usize,
    num_classes: usize,
    len: usize,
    seed: u64,
    noise: f32,
}

impl SynthFlowers {
    /// `len` items of `size`×`size`×3 images over `num_classes` classes.
    pub fn new(size: usize, num_classes: usize, len: usize, seed: u64) -> SynthFlowers {
        SynthFlowers { size, num_classes, len, seed, noise: 0.15 }
    }

    /// Override the additive-noise amplitude (default 0.15).
    pub fn with_noise(mut self, noise: f32) -> SynthFlowers {
        self.noise = noise;
        self
    }

    /// Distinct classes the labels actually use.
    pub fn num_classes(&self) -> usize {
        self.num_classes
    }

    fn class_of(&self, idx: usize) -> usize {
        // round-robin keeps classes balanced for any dataset length
        idx % self.num_classes
    }

    /// Deterministic per-class signature parameters.
    fn class_params(&self, class: usize) -> ClassSig {
        let mut r = Rng::new(self.seed ^ 0x5EED_C1A5).fork(class as u64);
        ClassSig {
            freq1: r.range_f32(2.0, 6.0),
            theta1: r.range_f32(0.0, std::f32::consts::PI),
            freq2: r.range_f32(4.0, 9.0),
            theta2: r.range_f32(0.0, std::f32::consts::PI),
            color: [r.range_f32(0.2, 1.0), r.range_f32(0.2, 1.0), r.range_f32(0.2, 1.0)],
            blob_r: r.range_f32(0.15, 0.4),
        }
    }
}

struct ClassSig {
    freq1: f32,
    theta1: f32,
    freq2: f32,
    theta2: f32,
    color: [f32; 3],
    blob_r: f32,
}

impl Dataset for SynthFlowers {
    fn len(&self) -> usize {
        self.len
    }

    fn x_elems(&self) -> usize {
        self.size * self.size * 3
    }

    fn y_elems(&self) -> usize {
        1
    }

    fn x_dtype(&self) -> Dtype {
        Dtype::F32
    }

    fn y_dtype(&self) -> Dtype {
        Dtype::I32
    }

    fn fill(&self, idx: usize, mut x: SliceMut<'_>, mut y: SliceMut<'_>) {
        let class = self.class_of(idx);
        let sig = self.class_params(class);
        let mut r = Rng::new(self.seed).fork(idx as u64);
        let phase1 = r.range_f32(0.0, std::f32::consts::TAU);
        let phase2 = r.range_f32(0.0, std::f32::consts::TAU);
        let cx = r.range_f32(0.3, 0.7);
        let cy = r.range_f32(0.3, 0.7);
        let out = x.f32();
        let s = self.size;
        let (c1, s1) = (sig.theta1.cos(), sig.theta1.sin());
        let (c2, s2) = (sig.theta2.cos(), sig.theta2.sin());
        for i in 0..s {
            for j in 0..s {
                let u = i as f32 / s as f32;
                let v = j as f32 / s as f32;
                let w1 = (std::f32::consts::TAU * sig.freq1 * (u * c1 + v * s1) + phase1).sin();
                let w2 = (std::f32::consts::TAU * sig.freq2 * (u * c2 + v * s2) + phase2).sin();
                let d2 = (u - cx) * (u - cx) + (v - cy) * (v - cy);
                let blob = (-d2 / (sig.blob_r * sig.blob_r)).exp();
                for ch in 0..3 {
                    let tex = 0.5 + 0.45 * w1 + 0.3 * w2;
                    let val =
                        tex * sig.color[ch] + 0.4 * blob * sig.color[2 - ch]
                            + self.noise * r.normal();
                    out[(i * s + j) * 3 + ch] = val.clamp(-1.0, 2.0);
                }
            }
        }
        y.i32()[0] = class as i32;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::fill_to_vecs;

    #[test]
    fn deterministic_per_item() {
        let ds = SynthFlowers::new(16, 102, 1000, 42);
        let (x1, y1) = fill_to_vecs(&ds, 17);
        let (x2, y2) = fill_to_vecs(&ds, 17);
        assert_eq!(x1, x2);
        assert_eq!(y1, y2);
    }

    #[test]
    fn items_differ() {
        let ds = SynthFlowers::new(16, 102, 1000, 42);
        let (x1, _) = fill_to_vecs(&ds, 0);
        let (x2, _) = fill_to_vecs(&ds, 102); // same class, different item
        assert_ne!(x1, x2);
    }

    #[test]
    fn labels_balanced_round_robin() {
        let ds = SynthFlowers::new(8, 10, 100, 1);
        let mut counts = [0usize; 10];
        for i in 0..100 {
            let (_, y) = fill_to_vecs(&ds, i);
            counts[y.as_i32().unwrap()[0] as usize] += 1;
        }
        assert!(counts.iter().all(|&c| c == 10));
    }

    #[test]
    fn same_class_items_correlate_more_than_cross_class() {
        // the learnable-signal sanity check: intra-class distance must be
        // smaller than inter-class distance on average
        let ds = SynthFlowers::new(16, 4, 400, 7).with_noise(0.1);
        let item = |i| fill_to_vecs(&ds, i).0.as_f32().unwrap().to_vec();
        let dist = |a: &[f32], b: &[f32]| -> f32 {
            a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum::<f32>()
        };
        let mut intra = 0.0;
        let mut inter = 0.0;
        let mut n = 0;
        for k in 0..8 {
            let a = item(k);
            let same = item(k + 4 * 3); // same class (stride num_classes)
            let diff = item(k + 1); // next class
            intra += dist(&a, &same);
            inter += dist(&a, &diff);
            n += 1;
        }
        assert!(intra < inter, "intra {intra} !< inter {inter}");
        let _ = n;
    }

    #[test]
    fn seed_changes_data() {
        let a = SynthFlowers::new(8, 10, 10, 1);
        let b = SynthFlowers::new(8, 10, 10, 2);
        assert_ne!(fill_to_vecs(&a, 3).0, fill_to_vecs(&b, 3).0);
    }

    #[test]
    fn values_bounded() {
        let ds = SynthFlowers::new(16, 102, 50, 9);
        for i in 0..50 {
            let (x, _) = fill_to_vecs(&ds, i);
            for &v in x.as_f32().unwrap() {
                assert!((-1.0..=2.0).contains(&v));
            }
        }
    }
}
