//! Epoch planning + micro-batch assembly.
//!
//! [`EpochPlan`] shuffles item indices once per epoch (seeded, reproducible)
//! and yields mini-batch index ranges; [`MicroBatchHost`] is the padded,
//! masked host-side tensor block for one micro-batch — the unit the streamer
//! uploads to the device (paper fig. 1, step 1).

use crate::util::rng::Rng;

use super::{Buf, Dataset};

/// Host tensors for one micro-batch: x/y padded to the static `mu` shape,
/// plus the 0/1 sample mask that zeroes padding in loss and metrics.
#[derive(Debug, Clone)]
pub struct MicroBatchHost {
    /// Inputs, padded to `mu` samples.
    pub x: Buf,
    /// Labels, padded to `mu` samples.
    pub y: Buf,
    /// Per-sample 0/1 mask zeroing the padding in loss and metrics.
    pub mask: Vec<f32>,
    /// Samples actually present (<= mu).
    pub actual: usize,
    /// Index of this micro-batch within its mini-batch.
    pub j: usize,
}

impl MicroBatchHost {
    /// A zero-capacity staging buffer — what [`crate::data::BufPool`] hands
    /// out on a cold miss; [`assemble_into`] sizes it on first use.
    pub fn empty() -> MicroBatchHost {
        MicroBatchHost {
            x: Buf::F32(Vec::new()),
            y: Buf::F32(Vec::new()),
            mask: Vec::new(),
            actual: 0,
            j: 0,
        }
    }
}

/// Assemble the `j`-th micro-batch of a mini-batch given by `indices` into
/// an existing staging buffer, reusing its capacity. This is the
/// allocation-free steady-state form: a correctly-sized `mb` (e.g. one
/// recycled through [`crate::data::BufPool`]) is re-zeroed and re-filled
/// without touching the heap, and the result is byte-identical to
/// [`assemble`].
pub fn assemble_into(
    mb: &mut MicroBatchHost,
    ds: &dyn Dataset,
    indices: &[usize],
    mu: usize,
    j: usize,
) {
    let lo = j * mu;
    let hi = ((j + 1) * mu).min(indices.len());
    assert!(lo < indices.len(), "micro-batch {j} out of range");
    let actual = hi - lo;
    let (xe, ye) = (ds.x_elems(), ds.y_elems());
    mb.x.reset_zeroed(&ds.x_dtype(), mu * xe);
    mb.y.reset_zeroed(&ds.y_dtype(), mu * ye);
    mb.mask.clear();
    mb.mask.resize(mu, 0.0);
    for (k, &idx) in indices[lo..hi].iter().enumerate() {
        ds.fill(idx, mb.x.slice_mut(k * xe, (k + 1) * xe), mb.y.slice_mut(k * ye, (k + 1) * ye));
        mb.mask[k] = 1.0;
    }
    mb.actual = actual;
    mb.j = j;
}

/// Assemble the `j`-th micro-batch of a mini-batch given by `indices` into
/// a freshly allocated buffer (thin wrapper over [`assemble_into`], kept
/// for tests and one-off callers).
pub fn assemble(
    ds: &dyn Dataset,
    indices: &[usize],
    mu: usize,
    j: usize,
) -> MicroBatchHost {
    let mut mb = MicroBatchHost::empty();
    assemble_into(&mut mb, ds, indices, mu, j);
    mb
}

/// Shuffled mini-batch index ranges for one epoch.
#[derive(Debug, Clone)]
pub struct EpochPlan {
    indices: Vec<usize>,
    batch: usize,
    /// Drop the ragged final mini-batch? (paper keeps it; Alg. 1 line 1-5
    /// handles non-uniform mini-batches, so the default is keep.)
    drop_last: bool,
}

impl EpochPlan {
    /// Shuffled plan: item order is seeded by `(seed, epoch)`, so every
    /// epoch reshuffles reproducibly.
    pub fn new(ds_len: usize, batch: usize, seed: u64, epoch: u64) -> EpochPlan {
        assert!(batch > 0, "batch size 0");
        let mut indices: Vec<usize> = (0..ds_len).collect();
        Rng::new(seed).fork(epoch).shuffle(&mut indices);
        EpochPlan { indices, batch, drop_last: false }
    }

    /// Unshuffled pass in dataset order — what evaluation uses, so the
    /// plan-driven executor reproduces the classic sequential eval sweep.
    pub fn sequential(ds_len: usize, batch: usize) -> EpochPlan {
        assert!(batch > 0, "batch size 0");
        EpochPlan { indices: (0..ds_len).collect(), batch, drop_last: false }
    }

    /// Drop (true) or keep (false, default) the ragged final mini-batch.
    pub fn drop_last(mut self, yes: bool) -> EpochPlan {
        self.drop_last = yes;
        self
    }

    /// Mini-batches this plan yields.
    pub fn num_batches(&self) -> usize {
        if self.drop_last {
            self.indices.len() / self.batch
        } else {
            self.indices.len().div_ceil(self.batch)
        }
    }

    /// Index slice for mini-batch `b`.
    pub fn batch_indices(&self, b: usize) -> &[usize] {
        let lo = b * self.batch;
        let hi = ((b + 1) * self.batch).min(self.indices.len());
        &self.indices[lo..hi]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SynthFlowers;

    #[test]
    fn plan_covers_every_item_once() {
        let plan = EpochPlan::new(103, 16, 7, 0);
        assert_eq!(plan.num_batches(), 7);
        let mut seen: Vec<usize> = (0..plan.num_batches())
            .flat_map(|b| plan.batch_indices(b).to_vec())
            .collect();
        seen.sort_unstable();
        assert_eq!(seen, (0..103).collect::<Vec<_>>());
    }

    #[test]
    fn ragged_final_batch() {
        let plan = EpochPlan::new(100, 16, 7, 0);
        assert_eq!(plan.batch_indices(6).len(), 4);
        let dropped = EpochPlan::new(100, 16, 7, 0).drop_last(true);
        assert_eq!(dropped.num_batches(), 6);
    }

    #[test]
    fn sequential_plan_is_identity_order() {
        let plan = EpochPlan::sequential(10, 10);
        assert_eq!(plan.num_batches(), 1);
        assert_eq!(plan.batch_indices(0), (0..10).collect::<Vec<_>>());
        // empty dataset: zero batches, nothing to iterate
        assert_eq!(EpochPlan::sequential(0, 4).num_batches(), 0);
    }

    #[test]
    fn epochs_shuffle_differently_but_deterministically() {
        let a0 = EpochPlan::new(50, 10, 3, 0);
        let a0b = EpochPlan::new(50, 10, 3, 0);
        let a1 = EpochPlan::new(50, 10, 3, 1);
        assert_eq!(a0.batch_indices(0), a0b.batch_indices(0));
        assert_ne!(a0.batch_indices(0), a1.batch_indices(0));
    }

    #[test]
    fn assemble_pads_and_masks_tail() {
        let ds = SynthFlowers::new(8, 10, 100, 1);
        let indices: Vec<usize> = (0..6).collect();
        let mb = assemble(&ds, &indices, 4, 1); // samples 4..6 -> 2 actual
        assert_eq!(mb.actual, 2);
        assert_eq!(mb.mask, vec![1.0, 1.0, 0.0, 0.0]);
        // padded x region must be zeros
        let x = mb.x.as_f32().unwrap();
        assert!(x[2 * ds.x_elems()..].iter().all(|&v| v == 0.0));
        // labels of padded region are 0
        assert_eq!(mb.y.as_i32().unwrap()[2..], [0, 0]);
    }

    #[test]
    fn assemble_fills_real_samples() {
        let ds = SynthFlowers::new(8, 10, 100, 1);
        let mb = assemble(&ds, &[5, 15, 25], 4, 0);
        assert_eq!(mb.actual, 3);
        let y = mb.y.as_i32().unwrap();
        assert_eq!(&y[..3], &[5, 5, 5]); // class = idx % 10
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn assemble_rejects_out_of_range() {
        let ds = SynthFlowers::new(8, 10, 100, 1);
        assemble(&ds, &[1, 2], 4, 1);
    }

    #[test]
    fn assemble_into_dirty_buffer_matches_fresh() {
        // a recycled buffer full of stale data (different micro-batch, and
        // a tail whose padding must be re-zeroed) reproduces the fresh path
        let ds = SynthFlowers::new(8, 10, 100, 1);
        let indices: Vec<usize> = (0..10).collect();
        let mut mb = assemble(&ds, &indices, 4, 0); // dirty: full 4 samples
        assemble_into(&mut mb, &ds, &indices, 4, 2); // tail: 2 actual
        let fresh = assemble(&ds, &indices, 4, 2);
        assert_eq!(mb.x, fresh.x);
        assert_eq!(mb.y, fresh.y);
        assert_eq!(mb.mask, fresh.mask);
        assert_eq!(mb.actual, fresh.actual);
        assert_eq!(mb.j, fresh.j);
    }

    #[test]
    fn assemble_into_adapts_mismatched_dtype_and_size() {
        // a buffer leased against a different dataset/mu still assembles
        // correctly: dtype mismatches are replaced, sizes are re-fit
        let ds = SynthFlowers::new(8, 10, 100, 1);
        let mut mb = MicroBatchHost {
            x: Buf::I32(vec![7; 3]), // wrong dtype and size
            y: Buf::F32(vec![1.5; 2]),
            mask: vec![9.0; 1],
            actual: 99,
            j: 99,
        };
        assemble_into(&mut mb, &ds, &[5, 15, 25], 4, 0);
        let fresh = assemble(&ds, &[5, 15, 25], 4, 0);
        assert_eq!(mb.x, fresh.x);
        assert_eq!(mb.y, fresh.y);
        assert_eq!(mb.mask, fresh.mask);
        assert_eq!(mb.actual, 3);
    }
}
