//! SynthCarvana: procedural foreground-object segmentation standing in for
//! the Carvana car-masking dataset.
//!
//! Each item renders a smooth background gradient plus a randomly placed,
//! randomly sized superellipse "vehicle" with a distinct colour and soft
//! shading; the target is the exact binary mask of the object. Object and
//! background colour distributions overlap enough that the model has to use
//! shape, not a colour threshold.

use crate::manifest::Dtype;
use crate::util::rng::Rng;

use super::{Dataset, SliceMut};

/// Procedural foreground-object segmentation dataset (Carvana stand-in).
#[derive(Debug, Clone)]
pub struct SynthCarvana {
    size: usize,
    len: usize,
    seed: u64,
}

impl SynthCarvana {
    /// `len` items of `size`×`size`×3 images with binary object masks.
    pub fn new(size: usize, len: usize, seed: u64) -> SynthCarvana {
        SynthCarvana { size, len, seed }
    }
}

impl Dataset for SynthCarvana {
    fn len(&self) -> usize {
        self.len
    }

    fn x_elems(&self) -> usize {
        self.size * self.size * 3
    }

    fn y_elems(&self) -> usize {
        self.size * self.size
    }

    fn x_dtype(&self) -> Dtype {
        Dtype::F32
    }

    fn y_dtype(&self) -> Dtype {
        Dtype::F32
    }

    fn fill(&self, idx: usize, mut x: SliceMut<'_>, mut y: SliceMut<'_>) {
        let mut r = Rng::new(self.seed ^ 0xCA2).fork(idx as u64);
        let s = self.size;
        // superellipse object: |((u-cx)/a)|^p + |((v-cy)/b)|^p < 1
        let cx = r.range_f32(0.3, 0.7);
        let cy = r.range_f32(0.3, 0.7);
        let a = r.range_f32(0.15, 0.35);
        let b = r.range_f32(0.12, 0.3);
        let p = r.range_f32(1.5, 4.0);
        let rot = r.range_f32(0.0, std::f32::consts::PI);
        let (cr, sr) = (rot.cos(), rot.sin());
        let obj_color = [r.range_f32(0.1, 0.9), r.range_f32(0.1, 0.9), r.range_f32(0.1, 0.9)];
        let bg_a = [r.range_f32(0.1, 0.9), r.range_f32(0.1, 0.9), r.range_f32(0.1, 0.9)];
        let bg_b = [r.range_f32(0.1, 0.9), r.range_f32(0.1, 0.9), r.range_f32(0.1, 0.9)];
        let grad_theta = r.range_f32(0.0, std::f32::consts::TAU);
        let (gc, gs) = (grad_theta.cos(), grad_theta.sin());
        let noise = 0.08;

        let img = x.f32();
        let mask = y.f32();
        for i in 0..s {
            for j in 0..s {
                let u = i as f32 / s as f32;
                let v = j as f32 / s as f32;
                // rotated object coordinates
                let du = u - cx;
                let dv = v - cy;
                let ru = (du * cr + dv * sr) / a;
                let rv = (-du * sr + dv * cr) / b;
                let inside = ru.abs().powf(p) + rv.abs().powf(p) < 1.0;
                mask[i * s + j] = if inside { 1.0 } else { 0.0 };
                let t = 0.5 + 0.5 * (u * gc + v * gs);
                for ch in 0..3 {
                    let bg = bg_a[ch] * (1.0 - t) + bg_b[ch] * t;
                    let val = if inside {
                        // soft shading toward the object boundary
                        let shade = 1.0 - 0.3 * (ru * ru + rv * rv).min(1.0);
                        obj_color[ch] * shade
                    } else {
                        bg
                    };
                    img[(i * s + j) * 3 + ch] = (val + noise * r.normal()).clamp(-0.5, 1.5);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::fill_to_vecs;

    #[test]
    fn deterministic() {
        let ds = SynthCarvana::new(24, 100, 3);
        assert_eq!(fill_to_vecs(&ds, 5), fill_to_vecs(&ds, 5));
    }

    #[test]
    fn mask_is_binary_and_nontrivial() {
        let ds = SynthCarvana::new(24, 100, 3);
        for i in 0..20 {
            let (_, y) = fill_to_vecs(&ds, i);
            let m = y.as_f32().unwrap();
            assert!(m.iter().all(|&v| v == 0.0 || v == 1.0));
            let fg: f32 = m.iter().sum();
            let frac = fg / m.len() as f32;
            assert!(
                (0.02..0.8).contains(&frac),
                "item {i}: degenerate foreground fraction {frac}"
            );
        }
    }

    #[test]
    fn mask_matches_object_extent() {
        // foreground pixels must be spatially contiguous-ish: the bounding
        // box of the mask should be much smaller than the whole image for a
        // mid-size object
        let ds = SynthCarvana::new(32, 10, 11);
        let (_, y) = fill_to_vecs(&ds, 0);
        let m = y.as_f32().unwrap();
        let s = 32;
        let (mut lo_i, mut hi_i) = (s, 0usize);
        for i in 0..s {
            for j in 0..s {
                if m[i * s + j] > 0.5 {
                    lo_i = lo_i.min(i);
                    hi_i = hi_i.max(i);
                }
            }
        }
        assert!(hi_i > lo_i);
        assert!(hi_i - lo_i < s - 2, "object spans the whole image");
    }

    #[test]
    fn items_differ() {
        let ds = SynthCarvana::new(24, 100, 3);
        assert_ne!(fill_to_vecs(&ds, 1).1, fill_to_vecs(&ds, 2).1);
    }
}
