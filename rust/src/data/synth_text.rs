//! SynthText: deterministic token sequences for the e2e transformer driver.
//!
//! Each sequence follows an affine recurrence t_{i+1} = (a*t_i + b) mod V
//! with (a, b) drawn per-sequence from a small family, interrupted by
//! occasional noise tokens. Next-token prediction is therefore learnable
//! (the model must infer the family from the prefix) but not trivial, so
//! LM loss curves show clear learning over a few hundred steps.

use crate::manifest::Dtype;
use crate::util::rng::Rng;

use super::{Dataset, SliceMut};

/// Deterministic affine-recurrence token sequences (LM stand-in).
#[derive(Debug, Clone)]
pub struct SynthText {
    vocab: usize,
    seq: usize,
    len: usize,
    seed: u64,
    /// number of distinct (a, b) families
    families: usize,
    noise_prob: f32,
}

impl SynthText {
    /// `len` sequences of `seq` tokens over a `vocab`-sized vocabulary.
    pub fn new(vocab: usize, seq: usize, len: usize, seed: u64) -> SynthText {
        SynthText { vocab, seq, len, seed, families: 16, noise_prob: 0.05 }
    }

    fn family(&self, f: usize) -> (i64, i64) {
        let mut r = Rng::new(self.seed ^ 0x7E47).fork(f as u64);
        // odd multiplier so the map is a bijection mod 2^k-ish vocab sizes
        let a = 2 * (r.below((self.vocab / 2) as u64 - 1) as i64) + 1;
        let b = r.below(self.vocab as u64) as i64;
        (a, b)
    }
}

impl Dataset for SynthText {
    fn len(&self) -> usize {
        self.len
    }

    fn x_elems(&self) -> usize {
        self.seq
    }

    fn y_elems(&self) -> usize {
        self.seq
    }

    fn x_dtype(&self) -> Dtype {
        Dtype::I32
    }

    fn y_dtype(&self) -> Dtype {
        Dtype::I32
    }

    fn fill(&self, idx: usize, mut x: SliceMut<'_>, mut y: SliceMut<'_>) {
        let mut r = Rng::new(self.seed).fork(idx as u64);
        let (a, b) = self.family(r.usize_below(self.families));
        let v = self.vocab as i64;
        let mut t = r.below(self.vocab as u64) as i64;
        let xs = x.i32();
        let ys = y.i32();
        for i in 0..self.seq {
            xs[i] = t as i32;
            let mut next = (a * t + b).rem_euclid(v);
            if r.f32() < self.noise_prob {
                next = r.below(self.vocab as u64) as i64;
            }
            ys[i] = next as i32; // next-token target
            t = next;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::fill_to_vecs;

    #[test]
    fn deterministic() {
        let ds = SynthText::new(512, 64, 100, 5);
        assert_eq!(fill_to_vecs(&ds, 9), fill_to_vecs(&ds, 9));
    }

    #[test]
    fn tokens_in_vocab() {
        let ds = SynthText::new(512, 64, 100, 5);
        for i in 0..20 {
            let (x, y) = fill_to_vecs(&ds, i);
            for &t in x.as_i32().unwrap().iter().chain(y.as_i32().unwrap()) {
                assert!((0..512).contains(&t));
            }
        }
    }

    #[test]
    fn target_is_shifted_input() {
        // y[i] must equal x[i+1] wherever no noise token intervened
        let ds = SynthText::new(512, 64, 100, 5);
        let (x, y) = fill_to_vecs(&ds, 3);
        let xs = x.as_i32().unwrap();
        let ys = y.as_i32().unwrap();
        let matches = (0..63).filter(|&i| ys[i] == xs[i + 1]).count();
        assert_eq!(matches, 63); // x is built from the same chain incl. noise
    }

    #[test]
    fn sequences_learnable_not_constant() {
        let ds = SynthText::new(512, 64, 100, 5);
        let (x, _) = fill_to_vecs(&ds, 0);
        let xs = x.as_i32().unwrap();
        let distinct: std::collections::BTreeSet<_> = xs.iter().collect();
        assert!(distinct.len() > 8, "sequence nearly constant");
    }
}
