//! Recycling pool of host staging buffers for the streaming hot path.
//!
//! `loader::assemble` heap-allocates fresh `x`/`y`/`mask` vectors for every
//! micro-batch — per-step host overhead that erodes exactly the throughput
//! the paper's pipeline exists to buy (fig. 1). [`BufPool`] removes it:
//! the streamer leases a [`MicroBatchHost`] before assembling into it
//! ([`loader::assemble_into`] reuses the vectors' capacity), and after the
//! executor has uploaded the micro-batch it hands the buffer back through
//! the pool's return channel. In steady state every lease is a hit and the
//! hot path performs **zero** host-buffer allocations — epoch N+1 runs
//! entirely on epoch N's allocations.
//!
//! Sizing: the double-buffered streamer keeps at most `max(prefetch, 1)`
//! assembled micro-batches in its channel, one more is being assembled by
//! the producer and one is held by the consumer, so
//! [`BufPool::buffers_for`]` = max(prefetch, 1) + 2` retained buffers
//! (each `mu` samples) bound the pool. [`BufPool::bounded`] caps retention
//! there; returns beyond the cap are dropped instead of growing the pool.
//!
//! All counters are monotonic, so callers can assert deltas across epoch
//! boundaries (the zero-allocation acceptance test does exactly that).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use super::loader::MicroBatchHost;
use super::{Buf, Dataset};

/// Monotonic counters describing pool traffic. `allocs` counts leases that
/// found the pool empty (the subsequent `assemble_into` must allocate);
/// `hits` counts leases satisfied from recycled buffers.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PoolStats {
    /// Total lease calls.
    pub leases: u64,
    /// Leases served from a recycled buffer (no allocation on the hot path).
    pub hits: u64,
    /// Leases that had to start from an empty buffer (cold misses).
    pub allocs: u64,
    /// Buffers handed back through the return channel.
    pub returns: u64,
    /// Returns dropped because the pool was already at its retention cap.
    pub dropped: u64,
    /// Buffers pre-allocated by [`BufPool::warm`].
    pub warmed: u64,
}

impl PoolStats {
    /// Fraction of leases served without allocating, in [0, 1].
    pub fn hit_rate(&self) -> f64 {
        if self.leases == 0 {
            0.0
        } else {
            self.hits as f64 / self.leases as f64
        }
    }
}

/// Thread-safe recycling pool of [`MicroBatchHost`] staging buffers.
///
/// The producing streamer thread calls [`lease`](BufPool::lease); the
/// consuming executor thread calls [`give`](BufPool::give) once the upload
/// is done. Shared via `Arc` so the same allocations survive across epochs.
///
/// ```
/// use mbs::data::BufPool;
///
/// let pool = BufPool::bounded(2);
/// let buf = pool.lease();     // cold miss: an empty buffer to assemble into
/// pool.give(buf);             // hand it back once the upload is done
/// let _again = pool.lease();  // steady state: a recycled allocation
/// assert_eq!(pool.stats().hits, 1);
/// ```
#[derive(Debug)]
pub struct BufPool {
    free: Mutex<Vec<MicroBatchHost>>,
    /// Max buffers retained across lease cycles; extra returns are dropped.
    max_retained: usize,
    leases: AtomicU64,
    hits: AtomicU64,
    allocs: AtomicU64,
    returns: AtomicU64,
    dropped: AtomicU64,
    warmed: AtomicU64,
}

impl BufPool {
    /// Pool retaining at most `max_retained` idle buffers.
    pub fn bounded(max_retained: usize) -> BufPool {
        BufPool {
            free: Mutex::new(Vec::with_capacity(max_retained)),
            max_retained,
            leases: AtomicU64::new(0),
            hits: AtomicU64::new(0),
            allocs: AtomicU64::new(0),
            returns: AtomicU64::new(0),
            dropped: AtomicU64::new(0),
            warmed: AtomicU64::new(0),
        }
    }

    /// Buffers one streaming pipeline can have outstanding at once: the
    /// channel (which holds at least one item even at `prefetch == 0`),
    /// plus one being assembled by the producer and one held by the
    /// executor. Warming a pool to this count guarantees every lease hits.
    pub fn buffers_for(prefetch: usize) -> usize {
        prefetch.max(1) + 2
    }

    /// Retention sized for one streaming pipeline ([`BufPool::buffers_for`]).
    pub fn for_prefetch(prefetch: usize) -> BufPool {
        BufPool::bounded(BufPool::buffers_for(prefetch))
    }

    fn free_list(&self) -> std::sync::MutexGuard<'_, Vec<MicroBatchHost>> {
        // a panicking holder cannot leave the Vec in a broken state (push /
        // pop are atomic wrt. its invariants), so poisoning is ignorable
        self.free.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Pre-fill the pool with `n` buffers sized for `mu`-sample
    /// micro-batches of `ds`, so even the first epoch's leases all hit.
    pub fn warm(&self, n: usize, ds: &dyn Dataset, mu: usize) {
        let mut free = self.free_list();
        while free.len() < n.min(self.max_retained) {
            free.push(MicroBatchHost {
                x: Buf::zeros(&ds.x_dtype(), mu * ds.x_elems()),
                y: Buf::zeros(&ds.y_dtype(), mu * ds.y_elems()),
                mask: vec![0.0; mu],
                actual: 0,
                j: 0,
            });
            self.warmed.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Take a staging buffer: a recycled one when available (hit), an empty
    /// one otherwise (the caller's `assemble_into` then allocates — counted
    /// as `allocs`).
    pub fn lease(&self) -> MicroBatchHost {
        self.leases.fetch_add(1, Ordering::Relaxed);
        match self.free_list().pop() {
            Some(mb) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                mb
            }
            None => {
                self.allocs.fetch_add(1, Ordering::Relaxed);
                MicroBatchHost::empty()
            }
        }
    }

    /// Return channel: hand a buffer back after its upload. Dropped (not
    /// retained) once `max_retained` idle buffers are already pooled.
    pub fn give(&self, mb: MicroBatchHost) {
        self.returns.fetch_add(1, Ordering::Relaxed);
        let mut free = self.free_list();
        if free.len() < self.max_retained {
            free.push(mb);
        } else {
            self.dropped.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Idle buffers currently retained.
    pub fn retained(&self) -> usize {
        self.free_list().len()
    }

    /// Snapshot of the traffic counters.
    pub fn stats(&self) -> PoolStats {
        PoolStats {
            leases: self.leases.load(Ordering::Relaxed),
            hits: self.hits.load(Ordering::Relaxed),
            allocs: self.allocs.load(Ordering::Relaxed),
            returns: self.returns.load(Ordering::Relaxed),
            dropped: self.dropped.load(Ordering::Relaxed),
            warmed: self.warmed.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{loader, SynthFlowers};

    #[test]
    fn lease_give_round_trip_counts() {
        let pool = BufPool::bounded(2);
        let a = pool.lease(); // cold miss
        let b = pool.lease(); // cold miss
        pool.give(a);
        pool.give(b);
        assert_eq!(pool.retained(), 2);
        let _c = pool.lease(); // hit
        let s = pool.stats();
        assert_eq!((s.leases, s.hits, s.allocs, s.returns), (3, 1, 2, 2));
        assert!((s.hit_rate() - 1.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn retention_cap_drops_excess_returns() {
        let pool = BufPool::bounded(1);
        let a = pool.lease();
        let b = pool.lease();
        pool.give(a);
        pool.give(b); // over cap: dropped
        assert_eq!(pool.retained(), 1);
        assert_eq!(pool.stats().dropped, 1);
    }

    #[test]
    fn warm_fills_to_cap_and_makes_first_lease_hit() {
        let ds = SynthFlowers::new(8, 10, 100, 1);
        // prefetch 0 still means a 1-deep channel: cap = 1 + 2 = 3
        assert_eq!(BufPool::buffers_for(0), 3);
        let pool = BufPool::for_prefetch(0);
        pool.warm(5, &ds, 4); // clamped to the cap
        assert_eq!(pool.retained(), 3);
        assert_eq!(pool.stats().warmed, 3);
        let mb = pool.lease();
        let s = pool.stats();
        assert_eq!((s.hits, s.allocs), (1, 0));
        // warmed buffers are full-size: assembling into them must not grow
        assert_eq!(mb.x.len(), 4 * ds.x_elems());
    }

    #[test]
    fn recycled_buffer_reassembles_byte_identical_without_growth() {
        let ds = SynthFlowers::new(8, 10, 100, 1);
        let indices: Vec<usize> = (0..6).collect();
        let pool = BufPool::bounded(1);
        pool.warm(1, &ds, 4);
        // epoch 1: assemble, use, return
        let mut mb = pool.lease();
        loader::assemble_into(&mut mb, &ds, &indices, 4, 0);
        let cap_before = (mb.x.capacity(), mb.y.capacity(), mb.mask.capacity());
        pool.give(mb);
        // epoch 2: the recycled (dirty) buffer must reproduce the fresh path
        let mut mb = pool.lease();
        loader::assemble_into(&mut mb, &ds, &indices, 4, 1);
        let fresh = loader::assemble(&ds, &indices, 4, 1);
        assert_eq!(mb.x, fresh.x);
        assert_eq!(mb.y, fresh.y);
        assert_eq!(mb.mask, fresh.mask);
        assert_eq!(mb.actual, fresh.actual);
        assert_eq!(mb.j, fresh.j);
        // capacity reused, not reallocated
        assert_eq!((mb.x.capacity(), mb.y.capacity(), mb.mask.capacity()), cap_before);
        let s = pool.stats();
        assert_eq!(s.allocs, 0, "steady state must not allocate");
        assert_eq!(s.hits, 2);
    }

    #[test]
    fn pool_is_shareable_across_threads() {
        let pool = std::sync::Arc::new(BufPool::bounded(4));
        let p2 = pool.clone();
        let h = std::thread::spawn(move || {
            for _ in 0..100 {
                let mb = p2.lease();
                p2.give(mb);
            }
        });
        for _ in 0..100 {
            let mb = pool.lease();
            pool.give(mb);
        }
        h.join().unwrap();
        let s = pool.stats();
        assert_eq!(s.leases, 200);
        assert_eq!(s.returns, 200);
        assert_eq!(s.leases, s.hits + s.allocs);
    }
}
