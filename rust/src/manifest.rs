//! Typed view of `artifacts/manifest.json` (written by `python -m compile.aot`).
//!
//! The manifest is the single contract between the build-time python layers
//! (L1/L2) and the runtime rust layer (L3): artifact file names, parameter
//! leaf order/offsets, IO shapes per (model x size x mu) variant, optimizer
//! slot counts, and the activation-memory estimates the simulated device
//! model feeds on.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::error::{MbsError, Result};
use crate::util::json::Json;

/// Element type of an artifact tensor (everything here is 4-byte).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Dtype {
    /// 32-bit float.
    F32,
    /// 32-bit signed integer.
    I32,
}

impl Dtype {
    fn parse(s: &str) -> Result<Dtype> {
        match s {
            "f32" => Ok(Dtype::F32),
            "i32" => Ok(Dtype::I32),
            other => Err(MbsError::Manifest(format!("unknown dtype {other}"))),
        }
    }

    /// Bytes per element (4 for both supported dtypes).
    pub fn bytes(&self) -> usize {
        4
    }
}

/// One parameter tensor's place in the flat params binary.
#[derive(Debug, Clone)]
pub struct ParamLeaf {
    /// Dotted pytree path of the leaf.
    pub name: String,
    /// Tensor shape ([] for scalars).
    pub shape: Vec<usize>,
    /// Byte offset into the params .bin file.
    pub offset: usize,
    /// Element count (product of `shape`, min 1).
    pub elems: usize,
}

/// Optimizer metadata: slot count and the hyper-parameter ABI.
#[derive(Debug, Clone)]
pub struct OptimizerInfo {
    /// Optimizer family ("sgdm", "adam").
    pub kind: String,
    /// Param-sized device slots the optimizer keeps (momentum, m/v, …).
    pub slots: usize,
    /// Hyper vector element names, in ABI order (index 0 is the LR).
    pub hyper_names: Vec<String>,
    /// Default hyper vector from the export recipe.
    pub hyper_defaults: Vec<f32>,
}

/// One exported (size, mu) executable pair of a model.
#[derive(Debug, Clone)]
pub struct Variant {
    /// Static micro-batch size of the exported executables.
    pub mu: usize,
    /// Image size (px) or sequence length.
    pub size: usize,
    /// Input tensor shape (leading dim is `mu`).
    pub x_shape: Vec<usize>,
    /// Input element type.
    pub x_dtype: Dtype,
    /// Label tensor shape.
    pub y_shape: Vec<usize>,
    /// Label element type.
    pub y_dtype: Dtype,
    /// HLO text artifact of the gradient-accumulation step.
    pub accum_hlo: String,
    /// HLO text artifact of the forward-only eval step.
    pub eval_hlo: String,
    /// Estimated per-sample activation residency (memory model input).
    pub activation_bytes_per_sample: u64,
    /// Batch-independent workspace estimate (XLA temporaries etc.).
    pub fixed_bytes: u64,
}

/// One model's full artifact contract.
#[derive(Debug, Clone)]
pub struct ModelEntry {
    /// Manifest key (also the CLI `--model` value).
    pub name: String,
    /// Task family ("classification" / "segmentation" / "lm").
    pub task: String,
    /// Optimizer metadata.
    pub optimizer: OptimizerInfo,
    /// Params binary file name (relative to the artifact dir).
    pub params_bin: String,
    /// Parameter leaves in binary order.
    pub param_leaves: Vec<ParamLeaf>,
    /// Total bytes of the params binary.
    pub param_bytes: u64,
    /// HLO text artifact of the optimizer-update executable.
    pub apply_hlo: String,
    /// Metric vector semantics (parsed by `MetricKind`).
    pub metric_semantics: String,
    /// Size used when the config does not pin one.
    pub default_size: usize,
    /// Exported (size, mu) variants.
    pub variants: Vec<Variant>,
}

/// Typed, validated view of `artifacts/manifest.json`.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Artifact directory the manifest was loaded from.
    pub dir: PathBuf,
    /// Export-time seed recorded by the python AOT step.
    pub seed: u64,
    /// Model entries keyed by manifest name.
    pub models: BTreeMap<String, ModelEntry>,
}

fn req<'a>(v: &'a Json, key: &str, ctx: &str) -> Result<&'a Json> {
    v.get(key)
        .ok_or_else(|| MbsError::Manifest(format!("{ctx}: missing field '{key}'")))
}

fn req_str(v: &Json, key: &str, ctx: &str) -> Result<String> {
    req(v, key, ctx)?
        .as_str()
        .map(str::to_string)
        .ok_or_else(|| MbsError::Manifest(format!("{ctx}: '{key}' not a string")))
}

fn req_u64(v: &Json, key: &str, ctx: &str) -> Result<u64> {
    req(v, key, ctx)?
        .as_u64()
        .ok_or_else(|| MbsError::Manifest(format!("{ctx}: '{key}' not a non-negative integer")))
}

fn req_usize_arr(v: &Json, key: &str, ctx: &str) -> Result<Vec<usize>> {
    req(v, key, ctx)?
        .as_arr()
        .ok_or_else(|| MbsError::Manifest(format!("{ctx}: '{key}' not an array")))?
        .iter()
        .map(|e| {
            e.as_u64()
                .map(|n| n as usize)
                .ok_or_else(|| MbsError::Manifest(format!("{ctx}: '{key}' element not integer")))
        })
        .collect()
}

impl Manifest {
    /// Load and validate `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            MbsError::Manifest(format!(
                "cannot read {} (run `make artifacts` first): {e}",
                path.display()
            ))
        })?;
        let root = Json::parse(&text)?;
        let seed = root.get("seed").and_then(Json::as_u64).unwrap_or(0);
        let models_json = req(&root, "models", "manifest")?
            .as_obj()
            .ok_or_else(|| MbsError::Manifest("'models' not an object".into()))?;

        let mut models = BTreeMap::new();
        for (name, m) in models_json {
            let ctx = format!("models.{name}");
            let opt = req(m, "optimizer", &ctx)?;
            let optimizer = OptimizerInfo {
                kind: req_str(opt, "kind", &ctx)?,
                slots: req_u64(opt, "slots", &ctx)? as usize,
                hyper_names: req(opt, "hyper_names", &ctx)?
                    .as_arr()
                    .unwrap_or(&[])
                    .iter()
                    .filter_map(|j| j.as_str().map(str::to_string))
                    .collect(),
                hyper_defaults: req(opt, "hyper_defaults", &ctx)?
                    .as_arr()
                    .unwrap_or(&[])
                    .iter()
                    .filter_map(|j| j.as_f64().map(|f| f as f32))
                    .collect(),
            };
            let mut param_leaves = Vec::new();
            for leaf in req(m, "param_leaves", &ctx)?.as_arr().unwrap_or(&[]) {
                param_leaves.push(ParamLeaf {
                    name: req_str(leaf, "name", &ctx)?,
                    shape: req_usize_arr(leaf, "shape", &ctx)?,
                    offset: req_u64(leaf, "offset", &ctx)? as usize,
                    elems: req_u64(leaf, "elems", &ctx)? as usize,
                });
            }
            // canonicalize leaf order by byte offset: the python
            // `--metadata-only` export (`shapes.param_index`) and the full
            // export (`shapes.dump_params`) can list multi-output models'
            // leaves in different orders, and cache keys / contiguity
            // validation must not depend on which path wrote the manifest
            param_leaves.sort_by(|a, b| a.offset.cmp(&b.offset));
            let mut variants = Vec::new();
            for v in req(m, "variants", &ctx)?.as_arr().unwrap_or(&[]) {
                variants.push(Variant {
                    mu: req_u64(v, "mu", &ctx)? as usize,
                    size: req_u64(v, "size", &ctx)? as usize,
                    x_shape: req_usize_arr(v, "x_shape", &ctx)?,
                    x_dtype: Dtype::parse(&req_str(v, "x_dtype", &ctx)?)?,
                    y_shape: req_usize_arr(v, "y_shape", &ctx)?,
                    y_dtype: Dtype::parse(&req_str(v, "y_dtype", &ctx)?)?,
                    accum_hlo: req_str(v, "accum_hlo", &ctx)?,
                    eval_hlo: req_str(v, "eval_hlo", &ctx)?,
                    activation_bytes_per_sample: req_u64(v, "activation_bytes_per_sample", &ctx)?,
                    fixed_bytes: req_u64(v, "fixed_bytes", &ctx)?,
                });
            }
            let entry = ModelEntry {
                name: name.clone(),
                task: req_str(m, "task", &ctx)?,
                optimizer,
                params_bin: req_str(m, "params_bin", &ctx)?,
                param_leaves,
                param_bytes: req_u64(m, "param_bytes", &ctx)?,
                apply_hlo: req_str(m, "apply_hlo", &ctx)?,
                metric_semantics: req_str(m, "metric_semantics", &ctx)?,
                default_size: req_u64(m, "default_size", &ctx)? as usize,
                variants,
            };
            entry.validate(&ctx)?;
            models.insert(name.clone(), entry);
        }
        Ok(Manifest { dir, seed, models })
    }

    /// Look up a model entry, with the available keys in the error.
    pub fn model(&self, name: &str) -> Result<&ModelEntry> {
        self.models.get(name).ok_or_else(|| {
            MbsError::Manifest(format!(
                "model '{name}' not in manifest (have: {})",
                self.models.keys().cloned().collect::<Vec<_>>().join(", ")
            ))
        })
    }

    /// Absolute path of an artifact file named by the manifest.
    pub fn path(&self, file: &str) -> PathBuf {
        self.dir.join(file)
    }
}

impl ModelEntry {
    fn validate(&self, ctx: &str) -> Result<()> {
        // leaf offsets must be contiguous and account for param_bytes
        let mut offset = 0usize;
        for leaf in &self.param_leaves {
            if leaf.offset != offset {
                return Err(MbsError::Manifest(format!(
                    "{ctx}: leaf {} offset {} != expected {offset}",
                    leaf.name, leaf.offset
                )));
            }
            let shape_elems: usize = leaf.shape.iter().product::<usize>().max(1);
            if shape_elems != leaf.elems {
                return Err(MbsError::Manifest(format!(
                    "{ctx}: leaf {} shape/elems mismatch",
                    leaf.name
                )));
            }
            offset += leaf.elems * 4;
        }
        if offset as u64 != self.param_bytes {
            return Err(MbsError::Manifest(format!(
                "{ctx}: param_bytes {} != leaf total {offset}",
                self.param_bytes
            )));
        }
        if self.variants.is_empty() {
            return Err(MbsError::Manifest(format!("{ctx}: no variants")));
        }
        for v in &self.variants {
            if v.x_shape.first() != Some(&v.mu) {
                return Err(MbsError::Manifest(format!(
                    "{ctx}: variant mu {} not leading dim of x_shape {:?}",
                    v.mu, v.x_shape
                )));
            }
        }
        Ok(())
    }

    /// Find the variant with this (size, mu).
    pub fn variant(&self, size: usize, mu: usize) -> Result<&Variant> {
        self.variants
            .iter()
            .find(|v| v.size == size && v.mu == mu)
            .ok_or_else(|| {
                MbsError::Manifest(format!(
                    "{}: no variant size={size} mu={mu} (have: {})",
                    self.name,
                    self.variants
                        .iter()
                        .map(|v| format!("s{}mu{}", v.size, v.mu))
                        .collect::<Vec<_>>()
                        .join(", ")
                ))
            })
    }

    /// Stable FNV-1a digest of the entry's export metadata — the
    /// manifest half of an artifact-cache key
    /// ([`crate::runtime::artifacts`]). Covers everything that changes
    /// what a compile of this model would produce (identity, optimizer
    /// ABI, parameter layout), over leaves in canonical (byte-offset)
    /// order so the digest is identical whichever export path —
    /// `--metadata-only` or full — wrote the manifest.
    pub fn fingerprint(&self) -> u64 {
        let mut leaves: Vec<&ParamLeaf> = self.param_leaves.iter().collect();
        leaves.sort_by(|a, b| a.offset.cmp(&b.offset));
        let mut text = format!(
            "{}|{}|{}:{}|{}|{}",
            self.name, self.task, self.optimizer.kind, self.optimizer.slots,
            self.param_bytes, self.default_size
        );
        for leaf in leaves {
            text.push_str(&format!("|{}@{}x{}:{:?}", leaf.name, leaf.offset, leaf.elems, leaf.shape));
        }
        crate::util::hash::fnv1a64(text.as_bytes())
    }

    /// The variant for `(size, mu)`, synthesized from an exported sibling
    /// when `mu` itself was never exported. A variant's memory metadata is
    /// mu-independent (`activation_bytes_per_sample` is per sample,
    /// `fixed_bytes` batch-free) and its IO shapes only carry `mu` in the
    /// leading dim, so any exported variant at the same `size` is a valid
    /// template; the HLO file names follow the `compile.aot` convention
    /// (`<model>_s<size>_mu<mu>.{accum,eval}.hlo.txt`) and are compiled on
    /// demand by the artifact manager when absent on disk. Admission may
    /// therefore propose *any* positive mu at an exported size — only an
    /// unexported size (no shape template) remains a manifest error.
    pub fn derive_variant(&self, size: usize, mu: usize) -> Result<Variant> {
        if let Ok(v) = self.variant(size, mu) {
            return Ok(v.clone());
        }
        if mu == 0 {
            return Err(MbsError::Manifest(format!("{}: mu must be positive", self.name)));
        }
        let template = self
            .variants
            .iter()
            .find(|v| v.size == size)
            .ok_or_else(|| {
                MbsError::Manifest(format!(
                    "{}: no exported variant at size={size} to derive mu={mu} from \
                     (have sizes: {:?})",
                    self.name,
                    self.sizes()
                ))
            })?;
        let relead = |shape: &[usize]| -> Vec<usize> {
            let mut s = shape.to_vec();
            if s.first() == Some(&template.mu) {
                s[0] = mu;
            }
            s
        };
        let tag = format!("{}_s{size}_mu{mu}", self.name);
        Ok(Variant {
            mu,
            size,
            x_shape: relead(&template.x_shape),
            x_dtype: template.x_dtype.clone(),
            y_shape: relead(&template.y_shape),
            y_dtype: template.y_dtype.clone(),
            accum_hlo: format!("{tag}.accum.hlo.txt"),
            eval_hlo: format!("{tag}.eval.hlo.txt"),
            activation_bytes_per_sample: template.activation_bytes_per_sample,
            fixed_bytes: template.fixed_bytes,
        })
    }

    /// Largest exported mu for a given size — the "native maximum" micro-batch.
    pub fn max_mu(&self, size: usize) -> Option<usize> {
        self.variants.iter().filter(|v| v.size == size).map(|v| v.mu).max()
    }

    /// All sizes this model was exported at.
    pub fn sizes(&self) -> Vec<usize> {
        let mut s: Vec<usize> = self.variants.iter().map(|v| v.size).collect();
        s.sort_unstable();
        s.dedup();
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn art_dir() -> Option<PathBuf> {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.json").exists().then_some(dir)
    }

    #[test]
    fn loads_real_manifest() {
        let Some(dir) = art_dir() else { return };
        let man = Manifest::load(&dir).unwrap();
        assert!(man.models.contains_key("microresnet18"));
        let rn = man.model("microresnet18").unwrap();
        assert_eq!(rn.task, "classification");
        assert_eq!(rn.optimizer.kind, "sgdm");
        assert_eq!(rn.optimizer.slots, 1);
        let v = rn.variant(16, 8).unwrap();
        assert_eq!(v.x_shape, vec![8, 16, 16, 3]);
        assert_eq!(v.x_dtype, Dtype::F32);
        assert!(v.activation_bytes_per_sample > 0);
        assert!(man.path(&v.accum_hlo).exists());
        assert!(man.path(&rn.params_bin).exists());
    }

    #[test]
    fn missing_model_is_error() {
        let Some(dir) = art_dir() else { return };
        let man = Manifest::load(&dir).unwrap();
        assert!(man.model("nonexistent").is_err());
        assert!(man.model("microresnet18").unwrap().variant(999, 1).is_err());
    }

    #[test]
    fn max_mu_and_sizes() {
        let Some(dir) = art_dir() else { return };
        let man = Manifest::load(&dir).unwrap();
        let rn = man.model("microresnet18").unwrap();
        assert_eq!(rn.max_mu(16), Some(16));
        assert!(rn.sizes().contains(&32));
    }

    /// A minimal two-leaf manifest document with the leaves listed in the
    /// given order (offsets stay truthful, only the listing order moves —
    /// the `--metadata-only` vs full-export disagreement).
    fn two_leaf_doc(leaves_json: &str) -> String {
        format!(
            r#"{{"seed": 1, "models": {{"m": {{
                "task": "classification",
                "optimizer": {{"kind": "sgdm", "slots": 1,
                               "hyper_names": ["lr"], "hyper_defaults": [0.01]}},
                "params_bin": "m.params.bin",
                "param_leaves": [{leaves_json}],
                "param_bytes": 24,
                "apply_hlo": "m.apply.hlo.txt",
                "metric_semantics": "classification",
                "default_size": 16,
                "variants": [{{"mu": 4, "size": 16,
                    "x_shape": [4, 16, 16, 3], "x_dtype": "f32",
                    "y_shape": [4], "y_dtype": "i32",
                    "accum_hlo": "m_s16_mu4.accum.hlo.txt",
                    "eval_hlo": "m_s16_mu4.eval.hlo.txt",
                    "activation_bytes_per_sample": 1000, "fixed_bytes": 64}}]
            }}}}}}"#
        )
    }

    fn load_doc(doc: &str, tag: &str) -> Result<Manifest> {
        let dir = std::env::temp_dir().join(format!("mbs-man-{tag}-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), doc).unwrap();
        let out = Manifest::load(&dir);
        std::fs::remove_dir_all(&dir).ok();
        out
    }

    const LEAF_A: &str = r#"{"name": "dense.w", "shape": [2, 2], "offset": 0, "elems": 4}"#;
    const LEAF_B: &str = r#"{"name": "dense.b", "shape": [2], "offset": 16, "elems": 2}"#;

    #[test]
    fn leaf_order_round_trips_across_export_paths() {
        // the full export lists [A, B]; --metadata-only may list [B, A];
        // both must load (contiguity is validated post-canonicalization)
        // and agree on leaf order and on the cache-key fingerprint
        let in_order = load_doc(&two_leaf_doc(&format!("{LEAF_A}, {LEAF_B}")), "ord").unwrap();
        let permuted = load_doc(&two_leaf_doc(&format!("{LEAF_B}, {LEAF_A}")), "perm").unwrap();
        let a = in_order.model("m").unwrap();
        let b = permuted.model("m").unwrap();
        let names = |e: &ModelEntry| -> Vec<String> {
            e.param_leaves.iter().map(|l| l.name.clone()).collect()
        };
        assert_eq!(names(a), vec!["dense.w", "dense.b"], "canonical = offset order");
        assert_eq!(names(a), names(b), "both export paths canonicalize identically");
        assert_eq!(a.fingerprint(), b.fingerprint(), "cache keys stable across paths");
    }

    #[test]
    fn fingerprint_tracks_export_metadata() {
        let base = load_doc(&two_leaf_doc(&format!("{LEAF_A}, {LEAF_B}")), "fp").unwrap();
        let moved = load_doc(
            &two_leaf_doc(&format!(
                "{LEAF_A}, {}",
                LEAF_B.replace("dense.b", "dense.bias")
            )),
            "fp2",
        )
        .unwrap();
        assert_ne!(
            base.model("m").unwrap().fingerprint(),
            moved.model("m").unwrap().fingerprint(),
            "renamed leaf must change the fingerprint"
        );
    }

    #[test]
    fn derive_variant_synthesizes_unexported_mus() {
        let man = load_doc(&two_leaf_doc(&format!("{LEAF_A}, {LEAF_B}")), "dv").unwrap();
        let m = man.model("m").unwrap();
        // exported mu: the derived variant IS the exported one
        let exact = m.derive_variant(16, 4).unwrap();
        assert_eq!(exact.accum_hlo, "m_s16_mu4.accum.hlo.txt");
        // unexported mu: shapes re-lead, memory metadata carries over,
        // file names follow the compile.aot convention
        let d = m.derive_variant(16, 6).unwrap();
        assert_eq!(d.mu, 6);
        assert_eq!(d.x_shape, vec![6, 16, 16, 3]);
        assert_eq!(d.y_shape, vec![6]);
        assert_eq!(d.accum_hlo, "m_s16_mu6.accum.hlo.txt");
        assert_eq!(d.eval_hlo, "m_s16_mu6.eval.hlo.txt");
        assert_eq!(d.activation_bytes_per_sample, 1000);
        assert_eq!(d.fixed_bytes, 64);
        // unexported size: no shape template, still a manifest error
        assert!(m.derive_variant(99, 4).is_err());
        assert!(m.derive_variant(16, 0).is_err());
    }

    #[test]
    fn rejects_bad_manifest() {
        let dir = std::env::temp_dir().join(format!("mbs-man-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), "{\"models\": 3}").unwrap();
        assert!(Manifest::load(&dir).is_err());
        std::fs::write(dir.join("manifest.json"), "not json").unwrap();
        assert!(Manifest::load(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
