//! Metric semantics + epoch aggregation + CSV/table emission.
//!
//! The exported step functions return a fixed `f32[4]` metric vector whose
//! meaning depends on the task (python/compile/losses.py):
//!   classification: [correct, valid, 0, 0]          -> accuracy
//!   segmentation:   [inter, union, 2|A.B|, |A|+|B|] -> IoU + Dice
//!   lm:             [correct_tokens, tokens, 0, 0]  -> token accuracy

pub mod bench_report;

use std::fmt::Write as _;
use std::time::Duration;

use crate::coordinator::accumulator::Accumulation;
use crate::error::{MbsError, Result};

// Historical home of the table renderer; it now lives in `util` so every
// CLI table (sweep, frontier, inspect, --compare) shares one helper.
pub use crate::util::table::Table;

/// Which task family a model's `f32[4]` metric vector belongs to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MetricKind {
    /// `[correct, valid, 0, 0]` — accuracy.
    Classification,
    /// `[inter, union, 2|A∩B|, |A|+|B|]` — IoU + Dice.
    Segmentation,
    /// `[correct_tokens, tokens, 0, 0]` — token accuracy.
    Lm,
}

impl MetricKind {
    /// Parse a manifest `metric_semantics` string.
    pub fn parse(s: &str) -> Result<MetricKind> {
        match s {
            "classification" => Ok(MetricKind::Classification),
            "segmentation" => Ok(MetricKind::Segmentation),
            "lm" => Ok(MetricKind::Lm),
            other => Err(MbsError::Manifest(format!("unknown metric semantics {other}"))),
        }
    }

    /// Primary headline metric in [0, 1]: accuracy / IoU / token accuracy.
    pub fn primary(&self, m: &[f64; 4]) -> f64 {
        match self {
            MetricKind::Classification | MetricKind::Lm => safe_div(m[0], m[1]),
            MetricKind::Segmentation => safe_div(m[0], m[1]),
        }
    }

    /// Secondary metric: Dice for segmentation, None otherwise.
    pub fn secondary(&self, m: &[f64; 4]) -> Option<f64> {
        match self {
            MetricKind::Segmentation => Some(safe_div(m[2], m[3])),
            _ => None,
        }
    }

    /// CSV/report column name of the primary metric.
    pub fn primary_name(&self) -> &'static str {
        match self {
            MetricKind::Classification => "accuracy",
            MetricKind::Segmentation => "iou",
            MetricKind::Lm => "token_accuracy",
        }
    }
}

fn safe_div(a: f64, b: f64) -> f64 {
    if b == 0.0 {
        0.0
    } else {
        a / b
    }
}

/// Cumulative wall time per pipeline stage (fig. 1 instrumentation):
/// host-side micro-batch assembly, host→device upload, device execution,
/// device→host download of step scalars (plus any tupled-state round
/// trip), and the optimizer-update executable. Accumulated monotonically
/// by the runtime and the streamer; epoch deltas land in [`EpochStats`].
///
/// `upload_hidden` is a *subset* of `upload`, not a sixth stage: the part
/// of the upload time spent staging a micro-batch into the idle device
/// input slot while another micro-batch was already in flight — the time
/// an asynchronous device would hide behind execution (the synchronous
/// PJRT CPU client serializes the calls, so here it measures pipeline
/// structure rather than a wall-clock saving). Serial (`--overlap off`)
/// runs keep it at zero.
///
/// `upload_concurrent` is the *wall-clock* counterpart: the portion of the
/// upload-lane thread's staging windows that genuinely overlapped (by
/// `Instant` interval intersection) an execute window on the engine
/// thread. Unlike `upload_hidden` it cannot be earned by structure alone —
/// two threads must actually have been busy at the same time — so it is
/// the honest numerator of [`StageTimers::wall_overlap_efficiency`].
/// Serial runs keep it at zero; like `upload_hidden` it is excluded from
/// [`StageTimers::total`].
///
/// ```
/// use mbs::metrics::StageTimers;
/// use std::time::Duration;
///
/// let mut run = StageTimers::default();
/// let step = StageTimers { execute: Duration::from_millis(5), ..Default::default() };
/// run.merge(&step);
/// assert_eq!(run.total(), Duration::from_millis(5));
/// assert_eq!(run.minus(&step).execute, Duration::ZERO);
/// ```
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct StageTimers {
    /// Host-side micro-batch assembly (streamer thread).
    pub assemble: Duration,
    /// Host→device input upload (x/y, ragged-tail masks, scales).
    pub upload: Duration,
    /// Portion of `upload` issued while another micro-batch was in flight
    /// (hidden behind execution by the overlapped pipeline).
    pub upload_hidden: Duration,
    /// Wall-clock portion of the upload-lane thread's staging windows that
    /// overlapped an execute window on the engine thread (thread-timestamp
    /// interval intersection, not pipeline structure).
    pub upload_concurrent: Duration,
    /// Device execution of the accum/eval executables.
    pub execute: Duration,
    /// Device→host download of step scalars (and any tupled-state round trip).
    pub download: Duration,
    /// The optimizer-update executable (per update, not per micro-step).
    pub apply: Duration,
}

impl StageTimers {
    /// Add another timer set stage-by-stage (epoch totals into run totals).
    pub fn merge(&mut self, other: &StageTimers) {
        self.assemble += other.assemble;
        self.upload += other.upload;
        self.upload_hidden += other.upload_hidden;
        self.upload_concurrent += other.upload_concurrent;
        self.execute += other.execute;
        self.download += other.download;
        self.apply += other.apply;
    }

    /// Per-stage delta against an earlier snapshot of the same monotonic
    /// counters (saturating, so a stale snapshot can never underflow).
    pub fn minus(&self, earlier: &StageTimers) -> StageTimers {
        StageTimers {
            assemble: self.assemble.saturating_sub(earlier.assemble),
            upload: self.upload.saturating_sub(earlier.upload),
            upload_hidden: self.upload_hidden.saturating_sub(earlier.upload_hidden),
            upload_concurrent: self.upload_concurrent.saturating_sub(earlier.upload_concurrent),
            execute: self.execute.saturating_sub(earlier.execute),
            download: self.download.saturating_sub(earlier.download),
            apply: self.apply.saturating_sub(earlier.apply),
        }
    }

    /// Total instrumented time across all stages. Under double-buffered
    /// streaming this exceeds wall time (assembly overlaps execution) —
    /// that surplus is exactly the overlap the pipeline buys.
    /// `upload_hidden` and `upload_concurrent` are excluded: both are
    /// subsets of `upload`, not additional stages.
    pub fn total(&self) -> Duration {
        self.assemble + self.upload + self.execute + self.download + self.apply
    }

    /// Fraction of upload wall time issued inside another step's in-flight
    /// window, in [0, 1] — the overlap-efficiency key `mbs bench` reports
    /// and `--compare` trend-tracks. Zero when nothing was uploaded (or
    /// overlap is off). On the synchronous PJRT CPU client this measures
    /// pipeline *structure* (steady state sits at `(n-1)/n`): it is the
    /// fraction an asynchronous backend would genuinely hide, not a
    /// wall-clock saving on this device.
    pub fn overlap_efficiency(&self) -> f64 {
        if self.upload.is_zero() {
            0.0
        } else {
            (self.upload_hidden.as_secs_f64() / self.upload.as_secs_f64()).clamp(0.0, 1.0)
        }
    }

    /// Wall-clock overlap efficiency in [0, 1]: the fraction of upload time
    /// the dedicated upload-lane thread spent genuinely concurrent with an
    /// execute window, from `Instant` interval intersections. Where
    /// [`StageTimers::overlap_efficiency`] measures pipeline *structure*
    /// (and saturates even on a synchronous client), this one is zero
    /// unless two threads were really busy at the same instant — it is the
    /// key `mbs bench --compare` gates for a genuine wall-clock win.
    pub fn wall_overlap_efficiency(&self) -> f64 {
        if self.upload.is_zero() {
            0.0
        } else {
            (self.upload_concurrent.as_secs_f64() / self.upload.as_secs_f64()).clamp(0.0, 1.0)
        }
    }
}

/// Aggregated result of one epoch (train or eval pass).
#[derive(Debug, Clone)]
pub struct EpochStats {
    /// 0-based epoch index.
    pub epoch: usize,
    /// Mean per-sample loss over the epoch.
    pub mean_loss: f64,
    /// Headline metric in [0,1] (accuracy / IoU / token accuracy).
    pub primary_metric: f64,
    /// Dice for segmentation, `None` for the other tasks.
    pub secondary_metric: Option<f64>,
    /// Samples processed.
    pub samples: usize,
    /// Micro-batch steps executed.
    pub micro_steps: usize,
    /// Cumulative optimizer updates at the end of the epoch.
    pub updates: u64,
    /// Wall-clock time of the epoch.
    pub wall: Duration,
    /// Where this epoch's wall time went, stage by stage.
    pub stages: StageTimers,
}

impl EpochStats {
    /// Assemble epoch stats from the executor's [`Accumulation`].
    pub fn from_accumulation(
        epoch: usize,
        kind: MetricKind,
        acc: &Accumulation,
        updates: u64,
        wall: Duration,
        stages: StageTimers,
    ) -> EpochStats {
        EpochStats {
            epoch,
            mean_loss: acc.mean_loss(),
            primary_metric: kind.primary(&acc.metric),
            secondary_metric: kind.secondary(&acc.metric),
            samples: acc.samples,
            micro_steps: acc.micro_steps,
            updates,
            wall,
            stages,
        }
    }
}

/// CSV emitter for loss/metric curves (fig. 3 reproduction artifacts).
#[derive(Debug, Default)]
pub struct CurveWriter {
    rows: Vec<(String, EpochStats)>,
}

impl CurveWriter {
    /// Append one epoch of a named series ("train", "eval", …).
    pub fn push(&mut self, series: &str, stats: EpochStats) {
        self.rows.push((series.to_string(), stats));
    }

    /// Render all pushed rows as CSV (header included).
    pub fn to_csv(&self) -> String {
        let mut out = String::from(
            "series,epoch,mean_loss,primary_metric,secondary_metric,samples,micro_steps,updates,wall_secs\n",
        );
        for (series, s) in &self.rows {
            let _ = writeln!(
                out,
                "{series},{},{:.6},{:.6},{},{},{},{},{:.3}",
                s.epoch,
                s.mean_loss,
                s.primary_metric,
                s.secondary_metric.map(|d| format!("{d:.6}")).unwrap_or_default(),
                s.samples,
                s.micro_steps,
                s.updates,
                s.wall.as_secs_f64(),
            );
        }
        out
    }

    /// Write [`CurveWriter::to_csv`] to `path`.
    pub fn write_file(&self, path: &std::path::Path) -> Result<()> {
        std::fs::write(path, self.to_csv())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn classification_primary() {
        let k = MetricKind::Classification;
        assert_eq!(k.primary(&[30.0, 40.0, 0.0, 0.0]), 0.75);
        assert_eq!(k.secondary(&[30.0, 40.0, 0.0, 0.0]), None);
        assert_eq!(k.primary(&[0.0, 0.0, 0.0, 0.0]), 0.0); // no div-by-zero
    }

    #[test]
    fn segmentation_iou_and_dice() {
        let k = MetricKind::Segmentation;
        let m = [1.0, 3.0, 2.0, 4.0];
        assert_eq!(k.primary(&m), 1.0 / 3.0);
        assert_eq!(k.secondary(&m), Some(0.5));
        assert_eq!(k.primary_name(), "iou");
    }

    #[test]
    fn parse_kinds() {
        assert!(MetricKind::parse("classification").is_ok());
        assert!(MetricKind::parse("segmentation").is_ok());
        assert!(MetricKind::parse("lm").is_ok());
        assert!(MetricKind::parse("other").is_err());
    }

    #[test]
    fn csv_output_shape() {
        let mut w = CurveWriter::default();
        w.push(
            "mbs",
            EpochStats {
                epoch: 0,
                mean_loss: 1.5,
                primary_metric: 0.25,
                secondary_metric: None,
                samples: 100,
                micro_steps: 13,
                updates: 7,
                wall: Duration::from_millis(1500),
                stages: StageTimers::default(),
            },
        );
        let csv = w.to_csv();
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines.len(), 2);
        assert!(lines[0].starts_with("series,epoch"));
        assert!(lines[1].starts_with("mbs,0,1.500000,0.250000,,100,13,7,1.500"));
    }

    #[test]
    fn stage_timers_merge_minus_total() {
        let mut a = StageTimers {
            assemble: Duration::from_millis(10),
            upload: Duration::from_millis(20),
            upload_hidden: Duration::from_millis(15),
            upload_concurrent: Duration::from_millis(12),
            execute: Duration::from_millis(30),
            download: Duration::from_millis(40),
            apply: Duration::from_millis(50),
        };
        let snapshot = a;
        a.merge(&StageTimers {
            execute: Duration::from_millis(5),
            upload_concurrent: Duration::from_millis(2),
            ..Default::default()
        });
        assert_eq!(a.execute, Duration::from_millis(35));
        assert_eq!(a.upload_concurrent, Duration::from_millis(14));
        let delta = a.minus(&snapshot);
        assert_eq!(delta.execute, Duration::from_millis(5));
        assert_eq!(delta.upload_concurrent, Duration::from_millis(2));
        assert_eq!(delta.assemble, Duration::ZERO);
        // upload_hidden / upload_concurrent are subsets of upload, never
        // extra stages
        assert_eq!(a.total(), Duration::from_millis(155));
        // saturating: a stale (larger) snapshot clamps to zero, no panic
        assert_eq!(snapshot.minus(&a).execute, Duration::ZERO);
    }

    #[test]
    fn overlap_efficiency_is_hidden_fraction() {
        let t = StageTimers {
            upload: Duration::from_millis(20),
            upload_hidden: Duration::from_millis(15),
            ..Default::default()
        };
        assert!((t.overlap_efficiency() - 0.75).abs() < 1e-12);
        // nothing uploaded: defined as zero, not NaN
        assert_eq!(StageTimers::default().overlap_efficiency(), 0.0);
        // clamped even if counters drift past the whole (defensive)
        let odd = StageTimers {
            upload: Duration::from_millis(1),
            upload_hidden: Duration::from_millis(2),
            ..Default::default()
        };
        assert_eq!(odd.overlap_efficiency(), 1.0);
    }

    #[test]
    fn wall_overlap_efficiency_is_concurrent_fraction() {
        let t = StageTimers {
            upload: Duration::from_millis(20),
            upload_hidden: Duration::from_millis(18),
            upload_concurrent: Duration::from_millis(5),
            ..Default::default()
        };
        // structural vs wall-clock: the two numerators are independent
        assert!((t.overlap_efficiency() - 0.9).abs() < 1e-12);
        assert!((t.wall_overlap_efficiency() - 0.25).abs() < 1e-12);
        // nothing uploaded: defined as zero, not NaN
        assert_eq!(StageTimers::default().wall_overlap_efficiency(), 0.0);
        // clamped even if counters drift past the whole (defensive)
        let odd = StageTimers {
            upload: Duration::from_millis(1),
            upload_concurrent: Duration::from_millis(2),
            ..Default::default()
        };
        assert_eq!(odd.wall_overlap_efficiency(), 1.0);
    }
}
