//! Shared machine-readable bench schema + trend comparison.
//!
//! Every `BENCH_*.json` artifact the CLI emits (`BENCH_streaming.json` from
//! `mbs bench`, `BENCH_frontier.json` from `mbs frontier`) is built through
//! [`BenchReport`], so they share one envelope:
//!
//! ```json
//! {
//!   "bench": "<suite name>",      // "streaming" | "frontier"
//!   "mode":  "<suite mode>",      // e.g. "assemble-only" | "dry-run"
//!   ...suite-specific fields...
//! }
//! ```
//!
//! and one vocabulary for the measurement sub-objects: throughput keys end
//! in `items_per_sec`, per-stage means live under `stage_means_ms`
//! ([`stage_means_value`]) and pool traffic under `pool` ([`pool_value`]).
//! The schemas are documented field-by-field in `rust/docs/ARCHITECTURE.md`.
//!
//! [`compare`] implements the `--compare <prev.json>` trend check: numeric
//! leaves whose key ends in `items_per_sec` (or is `pooled_speedup`) are
//! treated as higher-is-better and flagged as regressions when the current
//! value drops more than the threshold fraction below the previous one.

use std::fmt::Write as _;
use std::path::Path;

use crate::data::PoolStats;
use crate::error::Result;
use crate::metrics::StageTimers;
use crate::util::json::Json;

/// A JSON value with *ordered* object fields, so emitted reports keep a
/// stable, human-diffable key order (the parser side — [`Json`] — is
/// order-insensitive, as JSON requires).
#[derive(Debug, Clone, PartialEq)]
pub enum JsonValue {
    /// A number rendered with a fixed decimal precision.
    Fixed(String),
    /// An unsigned integer.
    UInt(u64),
    /// A string (rendered with minimal escaping).
    Str(String),
    /// An array of values.
    Arr(Vec<JsonValue>),
    /// An object whose fields render in insertion order.
    Obj(Vec<(String, JsonValue)>),
}

impl JsonValue {
    /// A float with `decimals` digits after the point. Non-finite values
    /// (which JSON cannot represent) are clamped to 0.
    pub fn fixed(v: f64, decimals: usize) -> JsonValue {
        let v = if v.is_finite() { v } else { 0.0 };
        JsonValue::Fixed(format!("{v:.decimals$}"))
    }

    /// An empty ordered object to fill with [`JsonValue::push`].
    pub fn obj() -> JsonValue {
        JsonValue::Obj(Vec::new())
    }

    /// Append a field to an object value; panics on non-objects.
    pub fn push(&mut self, key: &str, value: JsonValue) {
        match self {
            JsonValue::Obj(fields) => fields.push((key.to_string(), value)),
            other => panic!("push on non-object JsonValue {other:?}"),
        }
    }

    fn render_into(&self, out: &mut String, indent: usize) {
        let pad = "  ".repeat(indent);
        let pad_in = "  ".repeat(indent + 1);
        match self {
            JsonValue::Fixed(s) => out.push_str(s),
            JsonValue::UInt(n) => {
                let _ = write!(out, "{n}");
            }
            JsonValue::Str(s) => {
                out.push('"');
                for ch in s.chars() {
                    match ch {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            JsonValue::Arr(items) => {
                if items.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push_str("[\n");
                for (i, item) in items.iter().enumerate() {
                    out.push_str(&pad_in);
                    item.render_into(out, indent + 1);
                    out.push_str(if i + 1 < items.len() { ",\n" } else { "\n" });
                }
                out.push_str(&pad);
                out.push(']');
            }
            JsonValue::Obj(fields) => {
                if fields.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push_str("{\n");
                for (i, (k, v)) in fields.iter().enumerate() {
                    let _ = write!(out, "{pad_in}\"{k}\": ");
                    v.render_into(out, indent + 1);
                    out.push_str(if i + 1 < fields.len() { ",\n" } else { "\n" });
                }
                out.push_str(&pad);
                out.push('}');
            }
        }
    }

    /// Render as pretty-printed JSON text.
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.render_into(&mut out, 0);
        out
    }
}

/// Builder for one `BENCH_*.json` document: the shared envelope
/// (`bench` + `mode`) followed by suite-specific fields in insertion order.
#[derive(Debug, Clone)]
pub struct BenchReport {
    root: JsonValue,
}

impl BenchReport {
    /// Start a report for suite `bench` running in `mode`.
    pub fn new(bench: &str, mode: &str) -> BenchReport {
        let mut root = JsonValue::obj();
        root.push("bench", JsonValue::Str(bench.to_string()));
        root.push("mode", JsonValue::Str(mode.to_string()));
        BenchReport { root }
    }

    /// Append a string field.
    pub fn str_field(&mut self, key: &str, v: &str) -> &mut Self {
        self.root.push(key, JsonValue::Str(v.to_string()));
        self
    }

    /// Append an unsigned integer field.
    pub fn uint(&mut self, key: &str, v: u64) -> &mut Self {
        self.root.push(key, JsonValue::UInt(v));
        self
    }

    /// Append a fixed-precision float field.
    pub fn num(&mut self, key: &str, v: f64, decimals: usize) -> &mut Self {
        self.root.push(key, JsonValue::fixed(v, decimals));
        self
    }

    /// Append an arbitrary pre-built value (arrays, nested objects).
    pub fn field(&mut self, key: &str, v: JsonValue) -> &mut Self {
        self.root.push(key, v);
        self
    }

    /// Render the document as pretty-printed JSON (trailing newline
    /// included, so artifacts diff cleanly).
    pub fn to_json(&self) -> String {
        let mut s = self.root.render();
        s.push('\n');
        s
    }

    /// Write the rendered document to `path`.
    pub fn write(&self, path: &str) -> Result<()> {
        std::fs::write(path, self.to_json())?;
        Ok(())
    }
}

/// The shared `pool` measurement object (schema: ARCHITECTURE.md).
pub fn pool_value(p: &PoolStats) -> JsonValue {
    let mut v = JsonValue::obj();
    v.push("leases", JsonValue::UInt(p.leases));
    v.push("hits", JsonValue::UInt(p.hits));
    v.push("allocs", JsonValue::UInt(p.allocs));
    v.push("returns", JsonValue::UInt(p.returns));
    v.push("dropped", JsonValue::UInt(p.dropped));
    v.push("warmed", JsonValue::UInt(p.warmed));
    v.push("hit_rate", JsonValue::fixed(p.hit_rate(), 6));
    v
}

/// The shared `stage_means_ms` measurement object: mean milliseconds per
/// event for each pipeline stage (`apply` is per optimizer update, the
/// rest per micro-step). `upload_hidden` is the mean *hidden* portion of
/// `upload` — what the overlapped pipeline buries behind execution — so
/// the visible upload cost per micro-step is `upload - upload_hidden`.
/// `upload_concurrent` is the mean *wall-clock* portion of `upload` that
/// the dedicated upload lane genuinely ran alongside an execute window
/// (thread timestamps, not pipeline structure).
pub fn stage_means_value(stages: &StageTimers, micro_steps: u64, updates: u64) -> JsonValue {
    let per = |d: std::time::Duration, n: u64| {
        if n == 0 {
            0.0
        } else {
            d.as_secs_f64() * 1e3 / n as f64
        }
    };
    let mut v = JsonValue::obj();
    v.push("assemble", JsonValue::fixed(per(stages.assemble, micro_steps), 6));
    v.push("upload", JsonValue::fixed(per(stages.upload, micro_steps), 6));
    v.push("upload_hidden", JsonValue::fixed(per(stages.upload_hidden, micro_steps), 6));
    v.push(
        "upload_concurrent",
        JsonValue::fixed(per(stages.upload_concurrent, micro_steps), 6),
    );
    v.push("execute", JsonValue::fixed(per(stages.execute, micro_steps), 6));
    v.push("download", JsonValue::fixed(per(stages.download, micro_steps), 6));
    v.push("apply", JsonValue::fixed(per(stages.apply, updates), 6));
    v
}

/// The shared `resilience` measurement object (schema: ARCHITECTURE.md):
/// per-job fault-injection counters from the recovery state machine —
/// faults the plan actually fired, recovery attempts consumed, and
/// recoveries that completed (checkpoint restored, job resumed).
pub fn resilience_value(faults_injected: u64, retries: u64, recovered: u64) -> JsonValue {
    let mut v = JsonValue::obj();
    v.push("faults_injected", JsonValue::UInt(faults_injected));
    v.push("retries", JsonValue::UInt(retries));
    v.push("recovered", JsonValue::UInt(recovered));
    v
}

/// One compared metric in a trend check.
#[derive(Debug, Clone, PartialEq)]
pub struct CompareRow {
    /// Dot-joined path of the numeric leaf (e.g. `pooled_items_per_sec`).
    pub path: String,
    /// Value in the previous report.
    pub previous: f64,
    /// Value in the current report.
    pub current: f64,
    /// Relative change, `(current - previous) / previous`.
    pub delta: f64,
    /// Did the metric drop more than the threshold fraction?
    pub regressed: bool,
}

/// Result of comparing two bench reports ([`compare`]).
#[derive(Debug, Clone, Default)]
pub struct CompareOutcome {
    /// Every trend-tracked metric present in both reports.
    pub rows: Vec<CompareRow>,
    /// Paths tracked in the current report but absent from the previous
    /// one (schema drift, not regressions).
    pub missing_in_previous: Vec<String>,
}

impl CompareOutcome {
    /// Number of regressed metrics.
    pub fn regressions(&self) -> usize {
        self.rows.iter().filter(|r| r.regressed).count()
    }
}

/// Is this leaf key a trend-tracked, higher-is-better metric?
///
/// Only throughput-shaped keys are compared: wall-time and per-stage
/// latency keys are too machine-noise-sensitive for a hard threshold (see
/// ARCHITECTURE.md "Trend checks"). `overlap_efficiency` — the fraction of
/// upload time the overlapped pipeline hides — is a ratio of co-measured
/// times on the same machine, so it *is* stable enough to gate, and
/// `wall_overlap_efficiency` — the upload-lane thread's *wall-clock*
/// overlap with execution — is the key that finally gates a genuine
/// concurrency win rather than pipeline structure. The `items_per_sec`
/// suffix rule deliberately covers `BENCH_jobs.json`'s
/// `aggregate_items_per_sec` (and every per-job `items_per_sec` leaf), so
/// `mbs bench --compare` gates the multi-tenant aggregate throughput the
/// same way it gates the solo pipeline's. `warm_hit_rate` — the artifact
/// cache's warm-pass hit fraction under the deterministic mock backend
/// (`BENCH_streaming.json`'s `artifact_cache` object) — is pure counter
/// arithmetic (hits / fetches), machine-noise-free, and gates the cache
/// contract itself: a drop means fetches started recompiling.
/// `recovered_fraction` — `BENCH_chaos.json`'s recovered-over-fired ratio
/// from the fault-space sweep — is likewise counter arithmetic and gates
/// the recovery contract: a drop means injection points that used to
/// replay cleanly started evicting (or worse). `fleet_scaling_efficiency`
/// — `BENCH_fleet.json`'s aggregate throughput over `devices ×` the solo
/// arm's, both measured in the same process on the same machine — is a
/// co-measured ratio like `overlap_efficiency`, and gates the
/// data-parallel scaling story: a drop means adding simulated devices
/// stopped buying host-side assembly throughput.
pub fn is_trend_key(key: &str) -> bool {
    key.ends_with("items_per_sec")
        || key == "pooled_speedup"
        || key == "overlap_efficiency"
        || key == "wall_overlap_efficiency"
        || key == "warm_hit_rate"
        || key == "recovered_fraction"
        || key == "fleet_scaling_efficiency"
}

fn collect_numeric(prefix: &str, v: &Json, out: &mut Vec<(String, f64)>) {
    match v {
        Json::Num(n) => out.push((prefix.to_string(), *n)),
        Json::Obj(map) => {
            for (k, child) in map {
                let path =
                    if prefix.is_empty() { k.clone() } else { format!("{prefix}.{k}") };
                collect_numeric(&path, child, out);
            }
        }
        Json::Arr(items) => {
            for (i, child) in items.iter().enumerate() {
                collect_numeric(&format!("{prefix}[{i}]"), child, out);
            }
        }
        _ => {}
    }
}

/// Compare `current` against `previous`: every numeric leaf whose final key
/// segment is trend-tracked ([`is_trend_key`]) and that exists in both
/// documents becomes a [`CompareRow`]; a row regresses when
/// `current < previous * (1 - threshold)`.
pub fn compare(previous: &Json, current: &Json, threshold: f64) -> CompareOutcome {
    let mut prev_leaves = Vec::new();
    let mut cur_leaves = Vec::new();
    collect_numeric("", previous, &mut prev_leaves);
    collect_numeric("", current, &mut cur_leaves);
    let leaf_key = |path: &str| -> String {
        path.rsplit('.').next().unwrap_or(path).to_string()
    };
    let mut outcome = CompareOutcome::default();
    for (path, cur) in &cur_leaves {
        if !is_trend_key(&leaf_key(path)) {
            continue;
        }
        match prev_leaves.iter().find(|(p, _)| p == path) {
            Some((_, prev)) => {
                let delta = if *prev != 0.0 { (cur - prev) / prev } else { 0.0 };
                let regressed = *prev > 0.0 && *cur < prev * (1.0 - threshold);
                outcome.rows.push(CompareRow {
                    path: path.clone(),
                    previous: *prev,
                    current: *cur,
                    delta,
                    regressed,
                });
            }
            None => outcome.missing_in_previous.push(path.clone()),
        }
    }
    outcome
}

/// [`compare`] over two report files. Returns `Ok(None)` when the previous
/// report does not exist (first run: nothing to compare), or when the two
/// reports are from different suites/modes (comparing them would be
/// meaningless, e.g. `assemble-only` vs a full `train` run).
pub fn compare_files(
    previous_path: &str,
    current_path: &str,
    threshold: f64,
) -> Result<Option<CompareOutcome>> {
    if !Path::new(previous_path).exists() {
        return Ok(None);
    }
    let prev = Json::parse(&std::fs::read_to_string(previous_path)?)?;
    let cur = Json::parse(&std::fs::read_to_string(current_path)?)?;
    let tag = |j: &Json, k: &str| -> String {
        j.get(k).and_then(Json::as_str).unwrap_or_default().to_string()
    };
    if tag(&prev, "bench") != tag(&cur, "bench") || tag(&prev, "mode") != tag(&cur, "mode") {
        return Ok(None);
    }
    Ok(Some(compare(&prev, &cur, threshold)))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_renders_parseable_ordered_json() {
        let mut rep = BenchReport::new("streaming", "assemble-only");
        rep.uint("batch", 32)
            .num("pooled_items_per_sec", 1234.5678, 3)
            .str_field("task", "classification");
        let mut nested = JsonValue::obj();
        nested.push("hit_rate", JsonValue::fixed(0.5, 6));
        rep.field("pool", nested);
        let text = rep.to_json();
        // envelope keys come first and the text round-trips through the parser
        assert!(text.starts_with("{\n  \"bench\": \"streaming\",\n  \"mode\": \"assemble-only\","));
        let parsed = Json::parse(&text).unwrap();
        assert_eq!(parsed.get("batch").and_then(Json::as_u64), Some(32));
        assert_eq!(
            parsed.get("pool").and_then(|p| p.get("hit_rate")).and_then(Json::as_f64),
            Some(0.5)
        );
    }

    #[test]
    fn fixed_clamps_non_finite() {
        assert_eq!(JsonValue::fixed(f64::NAN, 3), JsonValue::Fixed("0.000".into()));
        assert_eq!(JsonValue::fixed(f64::INFINITY, 1), JsonValue::Fixed("0.0".into()));
    }

    #[test]
    fn pool_and_stage_values_carry_schema_keys() {
        let pool = pool_value(&PoolStats { leases: 4, hits: 3, ..Default::default() });
        let parsed = Json::parse(&pool.render()).unwrap();
        assert_eq!(parsed.get("leases").and_then(Json::as_u64), Some(4));
        assert!((parsed.get("hit_rate").and_then(Json::as_f64).unwrap() - 0.75).abs() < 1e-9);
        let stages = stage_means_value(
            &StageTimers {
                execute: std::time::Duration::from_millis(10),
                upload: std::time::Duration::from_millis(10),
                upload_hidden: std::time::Duration::from_millis(5),
                upload_concurrent: std::time::Duration::from_millis(2),
                ..Default::default()
            },
            5,
            0,
        );
        let parsed = Json::parse(&stages.render()).unwrap();
        assert!((parsed.get("execute").and_then(Json::as_f64).unwrap() - 2.0).abs() < 1e-6);
        assert!(
            (parsed.get("upload_hidden").and_then(Json::as_f64).unwrap() - 1.0).abs() < 1e-6
        );
        assert!(
            (parsed.get("upload_concurrent").and_then(Json::as_f64).unwrap() - 0.4).abs()
                < 1e-6
        );
        assert_eq!(parsed.get("apply").and_then(Json::as_f64), Some(0.0)); // zero updates: no div
    }

    #[test]
    fn compare_flags_only_threshold_breaches() {
        let prev = Json::parse(
            r#"{"bench":"streaming","pooled_items_per_sec": 1000.0,
                "nested": {"items_per_sec": 100.0}, "assemble_mean_ms": 5.0}"#,
        )
        .unwrap();
        let cur = Json::parse(
            r#"{"bench":"streaming","pooled_items_per_sec": 950.0,
                "nested": {"items_per_sec": 10.0}, "assemble_mean_ms": 50.0}"#,
        )
        .unwrap();
        let out = compare(&prev, &cur, 0.2);
        // latency keys are not trend-tracked
        assert_eq!(out.rows.len(), 2);
        let top = out.rows.iter().find(|r| r.path == "pooled_items_per_sec").unwrap();
        assert!(!top.regressed, "5% drop is within a 20% threshold");
        assert!((top.delta + 0.05).abs() < 1e-9);
        let nested = out.rows.iter().find(|r| r.path == "nested.items_per_sec").unwrap();
        assert!(nested.regressed, "90% drop must regress");
        assert_eq!(out.regressions(), 1);
    }

    #[test]
    fn compare_reports_schema_drift() {
        let prev = Json::parse(r#"{"a": 1.0}"#).unwrap();
        let cur = Json::parse(r#"{"fresh_items_per_sec": 10.0}"#).unwrap();
        let out = compare(&prev, &cur, 0.1);
        assert!(out.rows.is_empty());
        assert_eq!(out.missing_in_previous, vec!["fresh_items_per_sec".to_string()]);
    }

    #[test]
    fn compare_files_handles_missing_and_mismatched() {
        let dir = std::env::temp_dir();
        let cur_path = dir.join(format!("mbs-bench-cur-{}.json", std::process::id()));
        let prev_path = dir.join(format!("mbs-bench-prev-{}.json", std::process::id()));
        std::fs::write(&cur_path, r#"{"bench": "streaming", "mode": "assemble-only"}"#)
            .unwrap();
        // missing previous: first run, nothing to compare
        let out = compare_files("/nonexistent/prev.json", cur_path.to_str().unwrap(), 0.1)
            .unwrap();
        assert!(out.is_none());
        // suite mismatch: skip rather than compare apples to oranges
        std::fs::write(&prev_path, r#"{"bench": "frontier", "mode": "dry-run"}"#).unwrap();
        let out = compare_files(
            prev_path.to_str().unwrap(),
            cur_path.to_str().unwrap(),
            0.1,
        )
        .unwrap();
        assert!(out.is_none());
        std::fs::remove_file(&cur_path).ok();
        std::fs::remove_file(&prev_path).ok();
    }

    #[test]
    fn trend_keys() {
        assert!(is_trend_key("pooled_items_per_sec"));
        assert!(is_trend_key("items_per_sec"));
        assert!(is_trend_key("pooled_speedup"));
        assert!(is_trend_key("overlap_efficiency"));
        assert!(is_trend_key("wall_overlap_efficiency"));
        // the multi-tenant aggregate (and per-job throughput leaves) ride
        // the same suffix rule — BENCH_jobs.json is gated like the rest
        assert!(is_trend_key("aggregate_items_per_sec"));
        // the artifact cache's warm-pass hit fraction gates; its raw
        // counters (compiles, evictions) are not throughput-shaped
        assert!(is_trend_key("warm_hit_rate"));
        // the chaos sweep's recovered-over-fired ratio gates the recovery
        // contract; its raw per-surface counters are not trend keys
        assert!(is_trend_key("recovered_fraction"));
        // the fleet bench's aggregate-over-(devices x solo) ratio gates the
        // data-parallel scaling story; device counts and peaks do not
        assert!(is_trend_key("fleet_scaling_efficiency"));
        assert!(!is_trend_key("devices"));
        assert!(!is_trend_key("recovered"));
        assert!(!is_trend_key("hung"));
        assert!(!is_trend_key("cold_compiles"));
        assert!(!is_trend_key("assemble_mean_ms"));
        assert!(!is_trend_key("epoch_wall_mean_s"));
        assert!(!is_trend_key("upload_hidden"));
        assert!(!is_trend_key("upload_concurrent"));
        assert!(!is_trend_key("arena_peak_mib"));
    }

    #[test]
    fn compare_gates_jobs_aggregate_throughput() {
        // a BENCH_jobs.json pair: the aggregate and the per-job leaves are
        // compared, the admission labels and peaks are not
        let prev = Json::parse(
            r#"{"bench":"jobs","mode":"train","aggregate_items_per_sec": 100.0,
                "arena_peak_mib": 3.0,
                "jobs": [{"name": "a", "items_per_sec": 50.0}]}"#,
        )
        .unwrap();
        let cur = Json::parse(
            r#"{"bench":"jobs","mode":"train","aggregate_items_per_sec": 10.0,
                "arena_peak_mib": 9.0,
                "jobs": [{"name": "a", "items_per_sec": 49.0}]}"#,
        )
        .unwrap();
        let out = compare(&prev, &cur, 0.2);
        assert_eq!(out.rows.len(), 2);
        let agg =
            out.rows.iter().find(|r| r.path == "aggregate_items_per_sec").unwrap();
        assert!(agg.regressed, "90% aggregate drop must regress");
        let per_job =
            out.rows.iter().find(|r| r.path == "jobs[0].items_per_sec").unwrap();
        assert!(!per_job.regressed, "2% drop is within the threshold");
    }

    #[test]
    fn compare_gates_artifact_cache_hit_rate() {
        // the nested artifact_cache object in BENCH_streaming.json: the
        // warm hit rate rides the trend gate, the raw counters do not
        let prev = Json::parse(
            r#"{"bench":"streaming","mode":"assemble-only",
                "artifact_cache": {"warm_hit_rate": 1.0, "cold_compiles": 3.0}}"#,
        )
        .unwrap();
        let cur = Json::parse(
            r#"{"bench":"streaming","mode":"assemble-only",
                "artifact_cache": {"warm_hit_rate": 0.5, "cold_compiles": 9.0}}"#,
        )
        .unwrap();
        let out = compare(&prev, &cur, 0.2);
        assert_eq!(out.rows.len(), 1, "only the hit rate is trend-tracked");
        assert_eq!(out.rows[0].path, "artifact_cache.warm_hit_rate");
        assert!(out.rows[0].regressed, "a cache that stopped hitting must gate");
    }
}
