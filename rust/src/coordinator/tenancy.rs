//! Multi-tenant admission planning: several training jobs, one device.
//!
//! MBS shrinks a job's transient working set from `N_B` samples to `mu`
//! (paper §3.3). The same mechanism lets *heterogeneous* (model, batch)
//! jobs time-share one device that could not hold any two of them
//! natively — the serving-scale story (You et al. and McCandlish et al.
//! both treat batch size as a per-workload knob, so a shared device must
//! admit workloads against one capacity rather than plan them in
//! isolation). This module is the admission side:
//!
//!  * [`JobSpec`] / [`JobSet`] — a named job (its [`TrainConfig`]) and a
//!    set of them plus the shared `--capacity-mib`, parsed from a
//!    `jobs.json` spec file;
//!  * [`plan_admission`] — the deterministic two-phase planner. Phase 1
//!    places every job's **resident reservation** (params + gradient
//!    accumulator + optimizer slots + fixed workspace; the conservative
//!    claim uses the largest exported variant's `fixed_bytes`) into the
//!    shared [`Arena`](crate::memory::Arena) budget, in spec order.
//!    Phase 2 then runs the micro-batch planner per job against what
//!    remains *after all residents are placed*
//!    ([`auto_mu_transient`](crate::coordinator::planner::auto_mu_transient)):
//!    transients time-share that one budget because the interleaved
//!    executor (`trainer::train_jobs`) runs exactly one job's micro-step
//!    at a time. Each job is **admitted** (at its solo micro-batch),
//!    admitted with a **shrunk mu** (co-residency cost it capacity), or
//!    **rejected** (resident reservation does not fit, the job is not
//!    even solo-feasible, or no exported variant's transient fits).
//!    A rejection releases its reservation for *later* jobs in spec
//!    order — first-fit, so the outcome is a pure function of the input.
//!
//! Jobs running the overlapped upload/execute pipeline add a third
//! durable term: their staged second input slot stays resident *across
//! other jobs' turns* (the async upload lane keeps each job's ping-pong
//! slot warm), so admission prices the **sum** of every overlapped
//! tenant's staged slot ([`staged_slot_bytes`]) alongside the resident
//! claims — not the time-shared max the transients enjoy. Because the
//! in-order pass only sees *earlier* jobs' staged slots, a phase-3
//! reconciliation re-checks every admitted job against the final staged
//! sum, shrinking `mu` (never growing it) or rejecting until the set is
//! stable — still a pure, deterministic function of the request list.
//!
//! The planner is pure capacity arithmetic over manifest metadata — no
//! artifacts, no training — which is what lets `mbs jobs --dry-run` and
//! the co-residency classifier
//! ([`frontier::classify_set`](crate::coordinator::frontier::classify_set))
//! run on a clean checkout.

use crate::config::{MicroBatchSpec, TrainConfig};
use crate::error::{MbsError, Result};
use crate::manifest::ModelEntry;
use crate::memory::Footprint;
use crate::util::json::Json;

use super::planner::{self, Resolution};

/// One tenant's requested workload: a name plus the full training config
/// it would run solo.
#[derive(Debug, Clone)]
pub struct JobSpec {
    /// Unique job name (labels arena charges, tables and reports).
    pub name: String,
    /// Synthetic task stand-in ("classification" | "segmentation" | "lm")
    /// for artifact-free dry runs; `None` when `cfg.model` names a real
    /// manifest entry.
    pub task: Option<String>,
    /// The job's training configuration (model, batch, epochs, seed, …).
    pub cfg: TrainConfig,
}

impl JobSpec {
    /// Parse one entry of a `jobs.json` `"jobs"` array: `"name"` plus
    /// either `"model"` (manifest key) or `"task"` (synthetic stand-in),
    /// with every other key applied as a [`TrainConfig`] override
    /// (`"batch": 64`, `"seed": 3`, `"mu": "auto"`, …).
    pub fn from_json(v: &Json) -> Result<JobSpec> {
        let obj = v
            .as_obj()
            .ok_or_else(|| MbsError::Config("jobs spec: each job must be an object".into()))?;
        let name = obj
            .get("name")
            .and_then(Json::as_str)
            .ok_or_else(|| MbsError::Config("jobs spec: job missing 'name'".into()))?
            .to_string();
        let model = obj.get("model").and_then(Json::as_str);
        let task = obj.get("task").and_then(Json::as_str);
        let model_name = match (model, task) {
            (Some(m), None) => m.to_string(),
            (None, Some(t)) => format!("synthetic-{t}"),
            (Some(_), Some(_)) => {
                return Err(MbsError::Config(format!(
                    "jobs spec: job '{name}' names both 'model' and 'task' — pick one"
                )))
            }
            (None, None) => {
                return Err(MbsError::Config(format!(
                    "jobs spec: job '{name}' needs 'model' (manifest key) or 'task' \
                     (synthetic stand-in)"
                )))
            }
        };
        let mut cfg = TrainConfig::default_for(&model_name);
        for (key, val) in obj {
            if matches!(key.as_str(), "name" | "model" | "task") {
                continue;
            }
            cfg.set_json(key, val).map_err(|e| {
                MbsError::Config(format!("jobs spec: job '{name}': {e}"))
            })?;
        }
        cfg.validate()?;
        Ok(JobSpec { name, task: task.map(str::to_string), cfg })
    }
}

/// A set of jobs sharing one device capacity — what `mbs jobs --spec`
/// loads.
#[derive(Debug, Clone)]
pub struct JobSet {
    /// Shared device capacity in MiB; `None` when the spec file defers to
    /// the CLI's `--capacity-mib`.
    pub capacity_mib: Option<u64>,
    /// The jobs, in spec order (admission order is spec order).
    pub jobs: Vec<JobSpec>,
}

impl JobSet {
    /// Parse a `jobs.json` document:
    ///
    /// ```json
    /// {
    ///   "capacity_mib": 4,
    ///   "jobs": [
    ///     {"name": "cls", "task": "classification", "batch": 64, "seed": 1},
    ///     {"name": "seg", "task": "segmentation", "batch": 32, "seed": 2}
    ///   ]
    /// }
    /// ```
    pub fn from_json_str(text: &str) -> Result<JobSet> {
        let root = Json::parse(text)
            .map_err(|e| MbsError::Config(format!("jobs spec: {e}")))?;
        let capacity_mib = match root.get("capacity_mib") {
            None => None,
            Some(j) => Some(j.as_u64().ok_or_else(|| {
                MbsError::Config("jobs spec: 'capacity_mib' must be a non-negative integer".into())
            })?),
        };
        let jobs_json = root
            .get("jobs")
            .and_then(Json::as_arr)
            .ok_or_else(|| MbsError::Config("jobs spec: missing 'jobs' array".into()))?;
        let jobs = jobs_json.iter().map(JobSpec::from_json).collect::<Result<Vec<_>>>()?;
        let set = JobSet { capacity_mib, jobs };
        set.validate()?;
        Ok(set)
    }

    /// Load a `jobs.json` spec file.
    pub fn load(path: &str) -> Result<JobSet> {
        JobSet::from_json_str(&std::fs::read_to_string(path)?)
    }

    /// Reject sets no executor can run: empty sets, duplicate names, or
    /// native-arm jobs (the shared arena admits streamed MBS jobs only —
    /// a native job is just `mu >= batch`, which `"mu": N` can pin).
    pub fn validate(&self) -> Result<()> {
        if self.jobs.is_empty() {
            return Err(MbsError::Config("jobs spec: at least one job required".into()));
        }
        for (i, job) in self.jobs.iter().enumerate() {
            if !job.cfg.use_mbs {
                return Err(MbsError::Config(format!(
                    "jobs spec: job '{}' sets mbs=false — the shared arena runs MBS \
                     jobs only (pin \"mu\" >= batch for single-step execution)",
                    job.name
                )));
            }
            if self.jobs[..i].iter().any(|other| other.name == job.name) {
                return Err(MbsError::Config(format!(
                    "jobs spec: duplicate job name '{}'",
                    job.name
                )));
            }
        }
        Ok(())
    }
}

/// One job's admission inputs, resolved to manifest metadata (pure data —
/// no engine, no artifacts).
#[derive(Debug, Clone)]
pub struct AdmissionRequest {
    /// Job name (labels verdicts and arena charges).
    pub name: String,
    /// The manifest (or synthetic) model entry the job trains.
    pub entry: ModelEntry,
    /// Image size / sequence length of the exported variants to consider.
    pub size: usize,
    /// Mini-batch size `N_B`.
    pub batch: usize,
    /// Eval-set occupancy admission must cover (0 = train-only).
    pub eval_len: usize,
    /// Pinned or planner-derived micro-batch size.
    pub mu: MicroBatchSpec,
    /// Does the job run the overlapped (async upload lane) pipeline? If
    /// so its staged input slot is a durable cross-tenant charge, summed
    /// over all overlapped tenants.
    pub overlap: bool,
}

impl AdmissionRequest {
    /// Build the admission inputs for a job spec against its resolved
    /// model entry.
    pub fn from_spec(spec: &JobSpec, entry: ModelEntry) -> AdmissionRequest {
        let size = spec.cfg.size.unwrap_or(entry.default_size);
        AdmissionRequest {
            name: spec.name.clone(),
            entry,
            size,
            batch: spec.cfg.batch,
            eval_len: spec.cfg.eval_len,
            mu: spec.cfg.mu,
            overlap: spec.cfg.overlap,
        }
    }
}

/// The planner's verdict for one job of a set.
#[derive(Debug, Clone)]
pub enum AdmissionOutcome {
    /// The job runs in the shared arena.
    Admitted {
        /// The variant it executes (its `mu` may be smaller than solo).
        resolution: Resolution,
        /// The micro-batch the job would get alone on the whole device.
        solo_mu: usize,
        /// Did co-residency force a smaller `mu` than the solo plan?
        shrunk: bool,
        /// Bytes reserved durably for the job's resident state (the
        /// conservative claim admission placed in phase 1).
        resident_claim_bytes: u64,
        /// Durable cross-tenant staged residency (the warm ping-pong
        /// input slot an overlapped job holds across other jobs' turns);
        /// 0 for serial jobs.
        staged_bytes: u64,
    },
    /// The job cannot run in this set (reason is human-readable).
    Rejected {
        /// Why admission refused the job.
        reason: String,
    },
}

impl AdmissionOutcome {
    /// Did the job get in?
    pub fn is_admitted(&self) -> bool {
        matches!(self, AdmissionOutcome::Admitted { .. })
    }

    /// The admitted micro-batch size, if any.
    pub fn mu(&self) -> Option<usize> {
        match self {
            AdmissionOutcome::Admitted { resolution, .. } => Some(resolution.mu),
            AdmissionOutcome::Rejected { .. } => None,
        }
    }

    /// Table cell label: `admit` / `shrink-mu` / `reject`.
    pub fn label(&self) -> &'static str {
        match self {
            AdmissionOutcome::Admitted { shrunk: false, .. } => "admit",
            AdmissionOutcome::Admitted { shrunk: true, .. } => "shrink-mu",
            AdmissionOutcome::Rejected { .. } => "reject",
        }
    }
}

/// One job's admission verdict, by name.
#[derive(Debug, Clone)]
pub struct JobAdmission {
    /// The job this verdict is for.
    pub name: String,
    /// Admit / shrink-mu / reject.
    pub outcome: AdmissionOutcome,
}

/// Conservative durable reservation for a job's resident state: params +
/// gradient accumulator + optimizer slots (entry-level) plus the largest
/// exported variant's fixed workspace at `size`. The variant admission
/// later picks can only need less, so a reservation that fits guarantees
/// the actual resident fits.
pub fn resident_claim(entry: &ModelEntry, size: usize) -> Result<u64> {
    // the variant with the largest fixed workspace bounds every variant's
    // resident state; pricing goes through Footprint so the claim can
    // never drift from the memory model's arithmetic
    let variant = entry
        .variants
        .iter()
        .filter(|v| v.size == size)
        .max_by_key(|v| v.fixed_bytes)
        .ok_or_else(|| {
            MbsError::Manifest(format!(
                "{}: no exported variants at size {size} (have sizes: {:?})",
                entry.name,
                entry.sizes()
            ))
        })?;
    Ok(Footprint::from_manifest(entry, variant).resident_bytes())
}

/// Transient peak a resolved job holds *beyond* its resident state while
/// one of its steps executes (training step or eval sweep, whichever is
/// larger) — the quantity phase 2 admits against the shared leftover.
pub fn transient_bytes(
    fp: &Footprint,
    mu: usize,
    batch: usize,
    eval_len: usize,
    overlap: bool,
) -> u64 {
    planner::peak_bytes(fp, mu, batch, eval_len, overlap).saturating_sub(fp.resident_bytes())
}

/// Durable staged residency an admitted *overlapped* job holds while
/// parked between its turns: one staged input slot at the largest sample
/// count any of its phases stages (train steps stage `min(mu, batch)`
/// samples, eval sweeps `min(mu, eval_len)`). Serial jobs hold none —
/// their ledger is flat between turns.
pub fn staged_slot_bytes(fp: &Footprint, mu: usize, batch: usize, eval_len: usize) -> u64 {
    fp.overlap_bytes(mu.min(batch).max(mu.min(eval_len)))
}

/// The deterministic admission planner (module docs tell the full
/// story): resident reservations, then per-job transient planning in
/// spec order, then the cross-tenant staged-residency reconciliation for
/// overlapped jobs. Outcomes are in request order; the result is a pure
/// function of `(reqs, capacity_bytes)` — each request carries its own
/// `overlap` flag.
pub fn plan_admission(reqs: &[AdmissionRequest], capacity_bytes: u64) -> Vec<JobAdmission> {
    // phase 1: place every job's resident reservation, in spec order
    let mut claims: Vec<Option<u64>> = Vec::with_capacity(reqs.len());
    let mut early: Vec<Option<String>> = Vec::with_capacity(reqs.len());
    let mut reserved = 0u64;
    for req in reqs {
        match resident_claim(&req.entry, req.size) {
            Err(e) => {
                claims.push(None);
                early.push(Some(e.to_string()));
            }
            Ok(claim) if reserved.saturating_add(claim) > capacity_bytes => {
                claims.push(None);
                early.push(Some(format!(
                    "resident reservation needs {claim} B but only {} B of {} B remain",
                    capacity_bytes - reserved,
                    capacity_bytes
                )));
            }
            Ok(claim) => {
                reserved += claim;
                claims.push(Some(claim));
                early.push(None);
            }
        }
    }

    // phase 2: per-job micro-batch planning against the shared leftover
    // (a rejection releases its reservation for later jobs only). For an
    // overlapped job `reserved` also grows by its durable staged slot —
    // later jobs plan against the staged sum, not a time-shared max.
    let mut out = Vec::with_capacity(reqs.len());
    for (i, req) in reqs.iter().enumerate() {
        if let Some(reason) = early[i].take() {
            out.push(JobAdmission { name: req.name.clone(), outcome: AdmissionOutcome::Rejected { reason } });
            continue;
        }
        let Some(claim) = claims[i] else {
            // phase 1 and phase 2 disagreeing is an internal bug; reject
            // this job with a structured reason rather than panicking the
            // whole admission pass (the survivors still get verdicts)
            out.push(JobAdmission {
                name: req.name.clone(),
                outcome: AdmissionOutcome::Rejected {
                    reason: "internal: admission phase-1 claim missing".into(),
                },
            });
            continue;
        };
        // solo feasibility gate: a job the whole device cannot run alone is
        // never admitted to a shared one (admitted-set ⊆ solo-feasible set)
        let solo = match solo_resolution(req, capacity_bytes) {
            Ok(s) => s,
            Err(e) => {
                reserved -= claim;
                out.push(JobAdmission {
                    name: req.name.clone(),
                    outcome: AdmissionOutcome::Rejected {
                        reason: format!("not solo-feasible: {e}"),
                    },
                });
                continue;
            }
        };
        let transient_budget = capacity_bytes - reserved;
        let shared = match req.mu {
            MicroBatchSpec::Auto => planner::auto_mu_transient(
                &req.entry,
                req.size,
                req.batch,
                req.eval_len,
                transient_budget,
                req.overlap,
            ),
            MicroBatchSpec::Fixed(mu) => fixed_resolution(req, mu).and_then(|res| {
                let need =
                    transient_bytes(&res.footprint, mu, req.batch, req.eval_len, req.overlap);
                if need <= transient_budget {
                    Ok(res)
                } else {
                    Err(MbsError::Oom {
                        needed_bytes: need,
                        available_bytes: transient_budget,
                        capacity_bytes: transient_budget,
                        context: format!("pinned mu={mu} transient in shared arena"),
                    })
                }
            }),
        };
        match shared {
            Ok(resolution) => {
                let staged = if req.overlap {
                    staged_slot_bytes(&resolution.footprint, resolution.mu, req.batch, req.eval_len)
                } else {
                    0
                };
                reserved += staged;
                let shrunk = resolution.mu < solo.mu;
                out.push(JobAdmission {
                    name: req.name.clone(),
                    outcome: AdmissionOutcome::Admitted {
                        solo_mu: solo.mu,
                        shrunk,
                        resident_claim_bytes: claim,
                        staged_bytes: staged,
                        resolution,
                    },
                });
            }
            Err(e) => {
                reserved -= claim;
                out.push(JobAdmission {
                    name: req.name.clone(),
                    outcome: AdmissionOutcome::Rejected {
                        reason: format!("shared transient budget: {e}"),
                    },
                });
            }
        }
    }

    // phase 3: cross-tenant staged-residency reconciliation. The in-order
    // pass charged each job only for *earlier* tenants' staged slots; now
    // every admitted job must fit its beyond-staged transient next to the
    // FULL durable sum (claims + all staged slots). Violators shrink mu
    // against what the other tenants leave — never grow — or are
    // rejected; each round strictly shrinks a mu or rejects a job, so the
    // loop terminates.
    loop {
        let durable: u64 = out
            .iter()
            .map(|v| match &v.outcome {
                AdmissionOutcome::Admitted { resident_claim_bytes, staged_bytes, .. } => {
                    resident_claim_bytes + staged_bytes
                }
                AdmissionOutcome::Rejected { .. } => 0,
            })
            .sum();
        let mut changed = false;
        for (i, req) in reqs.iter().enumerate() {
            let (mu, claim, staged, solo_mu, residual) = match &out[i].outcome {
                AdmissionOutcome::Admitted {
                    resolution,
                    resident_claim_bytes,
                    staged_bytes,
                    solo_mu,
                    ..
                } => {
                    let transient = transient_bytes(
                        &resolution.footprint,
                        resolution.mu,
                        req.batch,
                        req.eval_len,
                        req.overlap,
                    );
                    (
                        resolution.mu,
                        *resident_claim_bytes,
                        *staged_bytes,
                        *solo_mu,
                        transient.saturating_sub(*staged_bytes),
                    )
                }
                AdmissionOutcome::Rejected { .. } => continue,
            };
            if durable.saturating_add(residual) <= capacity_bytes {
                continue;
            }
            // this job no longer fits next to the set's staged slots
            let others = durable - claim - staged;
            let budget = capacity_bytes.saturating_sub(others).saturating_sub(claim);
            let replanned = match req.mu {
                MicroBatchSpec::Auto => planner::auto_mu_transient(
                    &req.entry,
                    req.size,
                    req.batch,
                    req.eval_len,
                    budget,
                    req.overlap,
                )
                .ok(),
                // a pinned mu cannot shrink
                MicroBatchSpec::Fixed(_) => None,
            };
            out[i].outcome = match replanned {
                Some(res) if res.mu < mu => {
                    let new_staged = if req.overlap {
                        staged_slot_bytes(&res.footprint, res.mu, req.batch, req.eval_len)
                    } else {
                        0
                    };
                    AdmissionOutcome::Admitted {
                        solo_mu,
                        shrunk: res.mu < solo_mu,
                        resident_claim_bytes: claim,
                        staged_bytes: new_staged,
                        resolution: res,
                    }
                }
                _ => AdmissionOutcome::Rejected {
                    reason: format!(
                        "cross-tenant staged residency: mu={mu} transient no longer fits \
                         next to the set's staged input slots ({} B durable of {} B)",
                        durable, capacity_bytes
                    ),
                },
            };
            changed = true;
            break; // durable sum moved: recompute before checking the rest
        }
        if !changed {
            break;
        }
    }
    out
}

/// The job's full-device resolution: the micro-batch it would get alone.
fn solo_resolution(req: &AdmissionRequest, capacity_bytes: u64) -> Result<Resolution> {
    match req.mu {
        MicroBatchSpec::Auto => planner::auto_mu(
            &req.entry,
            req.size,
            req.batch,
            req.eval_len,
            capacity_bytes,
            req.overlap,
        ),
        MicroBatchSpec::Fixed(mu) => {
            let res = fixed_resolution(req, mu)?;
            let need =
                planner::peak_bytes(&res.footprint, mu, req.batch, req.eval_len, req.overlap);
            if need <= capacity_bytes {
                Ok(res)
            } else {
                Err(MbsError::Oom {
                    needed_bytes: need,
                    available_bytes: capacity_bytes
                        .saturating_sub(res.footprint.resident_bytes()),
                    capacity_bytes,
                    context: format!("pinned mu={mu} solo step"),
                })
            }
        }
    }
}

/// Resolve a pinned `mu` to a variant + footprint. Derived, not looked
/// up: the artifact manager (runtime/artifacts.rs) compiles unexported
/// variants on demand, so admission may propose *any* mu at an exported
/// size — memory, not export coverage, is the binding constraint.
fn fixed_resolution(req: &AdmissionRequest, mu: usize) -> Result<Resolution> {
    let variant = req.entry.derive_variant(req.size, mu)?;
    let footprint = Footprint::from_manifest(&req.entry, &variant);
    Ok(Resolution { mu, variant, footprint })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::manifest::{Dtype, OptimizerInfo, Variant};

    /// Synthetic manifest entry exporting one variant per `mu` (mirrors
    /// the planner's fixture: uniform linear footprints).
    fn entry_with_mus(
        mus: &[usize],
        act_per_sample: u64,
        fixed: u64,
        param_bytes: u64,
    ) -> ModelEntry {
        ModelEntry {
            name: "synthetic".into(),
            task: "classification".into(),
            optimizer: OptimizerInfo {
                kind: "sgdm".into(),
                slots: 1,
                hyper_names: vec!["lr".into()],
                hyper_defaults: vec![0.01],
            },
            params_bin: "params.bin".into(),
            param_leaves: Vec::new(),
            param_bytes,
            apply_hlo: "apply.hlo".into(),
            metric_semantics: "classification".into(),
            default_size: 16,
            variants: mus
                .iter()
                .map(|&mu| Variant {
                    mu,
                    size: 16,
                    x_shape: vec![mu, 4],
                    x_dtype: Dtype::F32,
                    y_shape: vec![mu],
                    y_dtype: Dtype::I32,
                    accum_hlo: String::new(),
                    eval_hlo: String::new(),
                    activation_bytes_per_sample: act_per_sample,
                    fixed_bytes: fixed,
                })
                .collect(),
        }
    }

    fn req(name: &str, entry: &ModelEntry, batch: usize) -> AdmissionRequest {
        AdmissionRequest {
            name: name.into(),
            entry: entry.clone(),
            size: 16,
            batch,
            eval_len: 0,
            mu: MicroBatchSpec::Auto,
            overlap: false,
        }
    }

    fn req_overlap(name: &str, entry: &ModelEntry, batch: usize) -> AdmissionRequest {
        AdmissionRequest { overlap: true, ..req(name, entry, batch) }
    }

    #[test]
    fn resident_claim_matches_footprint_arithmetic() {
        let entry = entry_with_mus(&[2, 4], 1000, 64, 100);
        // params 100 * (1 + 1 grad + 1 slot) + fixed 64
        assert_eq!(resident_claim(&entry, 16).unwrap(), 364);
        assert!(resident_claim(&entry, 99).is_err());
        let fp = Footprint::from_manifest(&entry, &entry.variants[0]);
        assert_eq!(resident_claim(&entry, 16).unwrap(), fp.resident_bytes());
    }

    #[test]
    fn co_residency_shrinks_mu() {
        // capacity sized so one job alone plans mu=8 but two residents +
        // one mu=8 transient do not fit together -> both shrink to mu=4
        let entry = entry_with_mus(&[2, 4, 8], 1000, 0, 100);
        let fp = Footprint::from_manifest(&entry, &entry.variants[0]);
        let resident = fp.resident_bytes();
        let capacity = 2 * resident + fp.batch_bytes(8) - 1;
        // sanity: solo planning at this capacity still picks mu=8
        assert_eq!(
            planner::auto_mu(&entry, 16, 64, 0, capacity, false).unwrap().mu,
            8
        );
        let verdicts = plan_admission(&[req("a", &entry, 64), req("b", &entry, 64)], capacity);
        for v in &verdicts {
            match &v.outcome {
                AdmissionOutcome::Admitted { resolution, solo_mu, shrunk, .. } => {
                    assert_eq!(resolution.mu, 4, "job {} got mu {}", v.name, resolution.mu);
                    assert_eq!(*solo_mu, 8);
                    assert!(*shrunk);
                    assert_eq!(v.outcome.label(), "shrink-mu");
                }
                other => panic!("job {} should be admitted, got {other:?}", v.name),
            }
        }
        // roomier device: both keep their solo mu
        let roomy = 2 * resident + fp.batch_bytes(8);
        let verdicts = plan_admission(&[req("a", &entry, 64), req("b", &entry, 64)], roomy);
        for v in &verdicts {
            assert_eq!(v.outcome.mu(), Some(8));
            assert_eq!(v.outcome.label(), "admit");
        }
    }

    #[test]
    fn rejection_frees_reservation_for_later_jobs() {
        // resident-dominated model (params >> data space) so reservations
        // are what the device runs out of
        let entry = entry_with_mus(&[2, 4], 10, 0, 10_000);
        let fp = Footprint::from_manifest(&entry, &entry.variants[0]);
        assert_eq!(fp.resident_bytes(), 30_000);
        // phase-1 rejection: two residents + one mu=2 transient fit, the
        // third resident does not — c is rejected, a and b still train
        let capacity = 2 * fp.resident_bytes() + fp.batch_bytes(2);
        let verdicts = plan_admission(
            &[req("a", &entry, 64), req("b", &entry, 64), req("c", &entry, 64)],
            capacity,
        );
        assert!(verdicts[0].outcome.is_admitted());
        assert!(verdicts[1].outcome.is_admitted());
        match &verdicts[2].outcome {
            AdmissionOutcome::Rejected { reason } => {
                assert!(reason.contains("resident reservation"), "{reason}");
            }
            other => panic!("job c should be rejected, got {other:?}"),
        }
        // phase-2 rejection also frees room: with THREE residents placed
        // no transient fits, so the first job (planned against the
        // tightest budget) is rejected — and its freed reservation lets
        // b and c through
        let capacity = 3 * fp.resident_bytes() + fp.batch_bytes(2) - 1;
        let verdicts = plan_admission(
            &[req("a", &entry, 64), req("b", &entry, 64), req("c", &entry, 64)],
            capacity,
        );
        match &verdicts[0].outcome {
            AdmissionOutcome::Rejected { reason } => {
                assert!(reason.contains("shared transient budget"), "{reason}");
            }
            other => panic!("tightest-budget job should be rejected, got {other:?}"),
        }
        assert!(verdicts[1].outcome.is_admitted());
        assert!(verdicts[2].outcome.is_admitted());
    }

    #[test]
    fn solo_infeasible_jobs_never_admitted() {
        // a batch the device cannot run even alone (smallest variant's
        // step exceeds capacity) is rejected with the solo-feasibility
        // reason — not admitted against the shared budget
        let entry = entry_with_mus(&[2, 4], 1000, 0, 100);
        let fp = Footprint::from_manifest(&entry, &entry.variants[0]);
        let capacity = fp.step_bytes(2) - 1;
        // resident fits (phase 1 passes) but no step ever fits solo…
        assert!(planner::auto_mu(&entry, 16, 64, 0, capacity, false).is_err());
        let verdicts = plan_admission(&[req("solo-oom", &entry, 64)], capacity);
        match &verdicts[0].outcome {
            AdmissionOutcome::Rejected { reason } => {
                assert!(reason.contains("not solo-feasible"), "{reason}");
            }
            other => panic!("want solo-feasibility rejection, got {other:?}"),
        }
    }

    #[test]
    fn pinned_mu_is_admitted_exactly_or_rejected() {
        let entry = entry_with_mus(&[2, 4, 8], 1000, 0, 100);
        let fp = Footprint::from_manifest(&entry, &entry.variants[0]);
        let mut pinned = req("pin", &entry, 64);
        pinned.mu = MicroBatchSpec::Fixed(4);
        // exactly resident + the mu=4 transient: admitted, not shrunk
        let capacity = fp.resident_bytes() + fp.batch_bytes(4);
        let verdicts = plan_admission(&[pinned.clone()], capacity);
        match &verdicts[0].outcome {
            AdmissionOutcome::Admitted { resolution, shrunk, solo_mu, .. } => {
                assert_eq!(resolution.mu, 4);
                assert_eq!(*solo_mu, 4);
                assert!(!shrunk);
            }
            other => panic!("want pinned admission, got {other:?}"),
        }
        // one byte less: a pinned mu cannot shrink, so the job is rejected
        let verdicts = plan_admission(&[pinned], capacity - 1);
        match &verdicts[0].outcome {
            AdmissionOutcome::Rejected { reason } => {
                assert!(reason.contains("mu=4"), "{reason}");
            }
            other => panic!("want pinned rejection, got {other:?}"),
        }
    }

    #[test]
    fn overlapped_tenants_staged_slots_price_as_a_sum() {
        // two overlapped jobs: each holds its staged input slot durably
        // across the other's turns, so capacity must cover BOTH slots plus
        // one executing transient — a sum, not a time-shared max
        let entry = entry_with_mus(&[2, 4, 8], 1000, 0, 100);
        let fp = Footprint::from_manifest(&entry, &entry.variants[0]);
        let res = fp.resident_bytes();
        let exact = 2 * res + 2 * fp.overlap_bytes(8) + fp.batch_bytes(8);
        let verdicts =
            plan_admission(&[req_overlap("a", &entry, 64), req_overlap("b", &entry, 64)], exact);
        for v in &verdicts {
            assert_eq!(v.outcome.mu(), Some(8), "{}: {:?}", v.name, v.outcome);
        }
        // one byte less: the later tenant's slot no longer fits at mu=8
        let verdicts = plan_admission(
            &[req_overlap("a", &entry, 64), req_overlap("b", &entry, 64)],
            exact - 1,
        );
        assert_eq!(verdicts[0].outcome.mu(), Some(8));
        assert_eq!(verdicts[1].outcome.mu(), Some(4));
        // …while serial jobs time-share that transient and both keep mu=8
        let verdicts =
            plan_admission(&[req("a", &entry, 64), req("b", &entry, 64)], exact - 1);
        for v in &verdicts {
            assert_eq!(v.outcome.mu(), Some(8), "serial {}: {:?}", v.name, v.outcome);
        }
    }

    #[test]
    fn reconciliation_shrinks_earlier_tenant_for_later_staged_slot() {
        // the in-order pass charges each job only for EARLIER tenants'
        // staged slots; here the later (small) job's slot is what pushes
        // the first job over — phase 3 must walk the first job down
        let entry = entry_with_mus(&[2, 4, 8], 1000, 0, 100);
        let fp = Footprint::from_manifest(&entry, &entry.variants[0]);
        let res = fp.resident_bytes();
        let capacity =
            2 * res + fp.batch_bytes(8) + fp.overlap_bytes(8) + fp.overlap_bytes(2) - 1;
        let verdicts = plan_admission(
            &[req_overlap("big", &entry, 64), req_overlap("small", &entry, 2)],
            capacity,
        );
        assert_eq!(verdicts[0].outcome.mu(), Some(4), "{:?}", verdicts[0].outcome);
        assert_eq!(verdicts[1].outcome.mu(), Some(2));
        // without the small tenant, the big job keeps mu=8 at the same
        // capacity — its shrink is purely the cross-tenant staged charge
        let solo = plan_admission(&[req_overlap("big", &entry, 64)], capacity);
        assert_eq!(solo[0].outcome.mu(), Some(8));
    }

    #[test]
    fn job_set_json_round_trip() {
        let text = r#"{
            "capacity_mib": 4,
            "jobs": [
                {"name": "cls", "task": "classification", "batch": 64, "seed": 1,
                 "epochs": 2, "dataset_len": 128, "eval_len": 32},
                {"name": "seg", "task": "segmentation", "batch": 32, "mu": "auto"}
            ]
        }"#;
        let set = JobSet::from_json_str(text).unwrap();
        assert_eq!(set.capacity_mib, Some(4));
        assert_eq!(set.jobs.len(), 2);
        let cls = &set.jobs[0];
        assert_eq!(cls.name, "cls");
        assert_eq!(cls.task.as_deref(), Some("classification"));
        assert_eq!(cls.cfg.model, "synthetic-classification");
        assert_eq!(cls.cfg.batch, 64);
        assert_eq!(cls.cfg.seed, 1);
        assert_eq!(cls.cfg.epochs, 2);
        assert_eq!(cls.cfg.dataset_len, 128);
        assert_eq!(cls.cfg.eval_len, 32);
        assert!(set.jobs[1].cfg.mu.is_auto());
    }

    #[test]
    fn job_set_rejects_malformed_specs() {
        // missing jobs array
        assert!(JobSet::from_json_str(r#"{"capacity_mib": 4}"#).is_err());
        // a job needs a name and a model/task
        assert!(JobSet::from_json_str(r#"{"jobs": [{"task": "lm"}]}"#).is_err());
        assert!(JobSet::from_json_str(r#"{"jobs": [{"name": "x"}]}"#).is_err());
        // model and task are mutually exclusive
        assert!(JobSet::from_json_str(
            r#"{"jobs": [{"name": "x", "model": "m", "task": "lm"}]}"#
        )
        .is_err());
        // duplicate names
        assert!(JobSet::from_json_str(
            r#"{"jobs": [{"name": "x", "task": "lm"}, {"name": "x", "task": "lm"}]}"#
        )
        .is_err());
        // native jobs are refused up front
        assert!(JobSet::from_json_str(
            r#"{"jobs": [{"name": "x", "task": "lm", "mbs": false}]}"#
        )
        .is_err());
        // unknown config keys surface the offending job
        let err = JobSet::from_json_str(r#"{"jobs": [{"name": "x", "task": "lm", "bogus": 1}]}"#)
            .unwrap_err();
        assert!(err.to_string().contains("'x'"), "{err}");
    }

    mod properties {
        use super::*;
        use crate::util::prop::{ensure, forall};
        use crate::util::rng::Rng;

        fn rand_entry(r: &mut Rng) -> ModelEntry {
            let k = (r.below(5) + 1) as usize;
            let mus: Vec<usize> = (0..k).map(|i| 1usize << i).collect();
            entry_with_mus(
                &mus,
                r.below(1 << 12) + 1,
                r.below(1 << 10),
                r.below(1 << 14) + 1,
            )
        }

        fn rand_reqs(r: &mut Rng) -> Vec<AdmissionRequest> {
            let n = (r.below(4) + 1) as usize;
            (0..n)
                .map(|i| {
                    let entry = rand_entry(r);
                    AdmissionRequest {
                        name: format!("job-{i}"),
                        entry,
                        size: 16,
                        batch: (r.below(512) + 1) as usize,
                        eval_len: r.below(64) as usize,
                        mu: MicroBatchSpec::Auto,
                        overlap: r.below(2) == 1,
                    }
                })
                .collect()
        }

        #[test]
        fn admission_is_order_deterministic() {
            forall(
                "admission deterministic",
                100,
                0xD37,
                |r| (rand_reqs(r), r.below(1 << 22)),
                |(reqs, capacity)| {
                    let a = plan_admission(reqs, *capacity);
                    let b = plan_admission(reqs, *capacity);
                    ensure(a.len() == b.len(), "length diverged")?;
                    for (x, y) in a.iter().zip(&b) {
                        ensure(x.name == y.name, "order diverged")?;
                        ensure(
                            x.outcome.mu() == y.outcome.mu()
                                && x.outcome.label() == y.outcome.label(),
                            format!("verdict diverged for {}", x.name),
                        )?;
                    }
                    Ok(())
                },
            );
        }

        #[test]
        fn admitted_set_is_solo_feasible_and_fits_at_every_instant() {
            // the set-level guarantees the interleaved executor leans on:
            // (1) every admitted job could also run alone on the full
            // device, at a mu no smaller than the shared one; (2) the sum
            // of admitted reservations AND every overlapped tenant's
            // staged input slot, plus ANY single admitted job's remaining
            // (beyond-staged) transient, stays within capacity — the
            // worst instantaneous residency one-micro-step-at-a-time with
            // warm cross-tenant slots can reach
            forall(
                "admitted ⊆ solo-feasible, durable sum + peak ≤ capacity",
                150,
                0xD38,
                |r| (rand_reqs(r), r.below(1 << 22)),
                |(reqs, capacity)| {
                    let verdicts = plan_admission(reqs, *capacity);
                    let durable: u64 = verdicts
                        .iter()
                        .filter_map(|v| match &v.outcome {
                            AdmissionOutcome::Admitted {
                                resident_claim_bytes,
                                staged_bytes,
                                ..
                            } => Some(resident_claim_bytes + staged_bytes),
                            _ => None,
                        })
                        .sum();
                    ensure(durable <= *capacity, "durable reservations exceed capacity")?;
                    for (req, v) in reqs.iter().zip(&verdicts) {
                        let AdmissionOutcome::Admitted {
                            resolution, solo_mu, staged_bytes, ..
                        } = &v.outcome
                        else {
                            continue;
                        };
                        let solo = planner::auto_mu(
                            &req.entry,
                            16,
                            req.batch,
                            req.eval_len,
                            *capacity,
                            req.overlap,
                        )
                        .map_err(|e| format!("admitted but not solo-feasible: {e}"))?;
                        ensure(solo.mu == *solo_mu, "solo mu mismatch")?;
                        ensure(
                            resolution.mu <= solo.mu,
                            format!("shared mu {} > solo mu {}", resolution.mu, solo.mu),
                        )?;
                        let staged_want = if req.overlap {
                            staged_slot_bytes(
                                &resolution.footprint,
                                resolution.mu,
                                req.batch,
                                req.eval_len,
                            )
                        } else {
                            0
                        };
                        ensure(
                            *staged_bytes == staged_want,
                            format!("staged charge {} != {}", staged_bytes, staged_want),
                        )?;
                        let residual = transient_bytes(
                            &resolution.footprint,
                            resolution.mu,
                            req.batch,
                            req.eval_len,
                            req.overlap,
                        )
                        .saturating_sub(*staged_bytes);
                        ensure(
                            durable + residual <= *capacity,
                            format!(
                                "instantaneous peak {} exceeds capacity {capacity}",
                                durable + residual
                            ),
                        )?;
                    }
                    Ok(())
                },
            );
        }
    }
}
