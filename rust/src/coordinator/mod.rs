//! L3 coordinator — the paper's system contribution.
//!
//! * [`planner`] — memory-driven micro-batch planning (Alg. 1 driven by
//!   the `MemoryModel`): resolves `MicroBatchSpec` to an exported variant
//!   and stamps every mini-batch with an [`ExecutionPlan`]
//! * [`splitter`] — mini -> micro batch split plan (Alg. 1 lines 1-6)
//! * [`streamer`] — the stream-based pipeline (section 3.1, fig. 1),
//!   streaming plan-tagged micro-batches
//! * [`accumulator`] — loss-normalization policy (section 3.4, eq. 14-17)
//! * [`scheduler`] — update points + LR schedules (section 3.3 step 5)
//! * [`trainer`] — the single plan-driven epoch executor (MBS, the native
//!   "w/o MBS" baseline and eval are all parameterizations of it), the
//!   round-robin interleaved multi-job executor ([`train_jobs`]), and the
//!   data-parallel fleet executor ([`train_fleet`]: per-device arenas and
//!   upload lanes, global-order execution — bit-identical to solo)
//! * [`tenancy`] — multi-tenant admission planning: `jobs.json` specs and
//!   the deterministic admit / shrink-mu / reject planner over the shared
//!   [`Arena`](crate::memory::Arena)
//! * [`placement`] — fleet placement planning: admission generalized to
//!   *assignment* of a job set across a [`FleetSpec`](crate::memory::FleetSpec)
//!   of heterogeneous devices (deterministic first-fit-decreasing with
//!   shrink-mu fallback, tenancy as the per-device feasibility oracle)
//! * [`frontier`] — capacity × batch feasibility sweeps: the planner made
//!   grid-callable, classifying every point as Native / MBS(mu) / OOM
//!   (the paper's headline figure as an instrument), plus the
//!   co-residency classifier for job *sets* ([`classify_set`])
//! * [`chaos`] — the exhaustive fault-space sweep (`mbs chaos`): every
//!   `(job, surface, step)` injection point run under a one-entry fault
//!   plan with short watchdog deadlines, classified against a fault-free
//!   baseline (recovered / evicted / hung / diverged; the sweep's
//!   invariant is `hung == 0` and `diverged == 0`)

pub mod accumulator;
pub mod chaos;
pub mod frontier;
pub mod placement;
pub mod planner;
pub mod scheduler;
pub mod splitter;
pub mod streamer;
pub mod tenancy;
pub mod trainer;

pub use accumulator::{Accumulation, NormalizationMode};
pub use chaos::{
    run_sweep, ChaosCfg, ChaosReport, Injection, InjectionPoint, PointResult, SurfaceCounts,
    Verdict,
};
pub use frontier::{
    classify, classify_set, DeviceAxis, DevicePoint, Feasibility, FrontierGrid, GridPoint,
    SetFeasibility,
};
pub use placement::{plan_placement, JobPlacement, PlacementPlan};
pub use planner::{
    auto_mu, auto_mu_transient, default_capacity, ExecutionPlan, Planner, Resolution,
};
pub use scheduler::UpdateScheduler;
pub use splitter::{MicroRange, ShardPlan, SplitPlan};
pub use streamer::{stream_epoch, EpochStream, StreamingPolicy};
pub use tenancy::{
    plan_admission, AdmissionOutcome, AdmissionRequest, JobAdmission, JobSet, JobSpec,
};
pub use trainer::{
    datasets_for, evaluate, evaluate_pooled, evaluate_with, train, train_fleet, train_jobs,
    train_jobs_faulted, DeviceReport, FleetReport, JobOutcome, JobRun, JobsReport, TrainReport,
};
