//! L3 coordinator — the paper's system contribution.
//!
//! * [`splitter`] — mini -> micro batch split plan (Alg. 1 lines 1-6)
//! * [`streamer`] — the stream-based pipeline (section 3.1, fig. 1)
//! * [`accumulator`] — loss-normalization policy (section 3.4, eq. 14-17)
//! * [`scheduler`] — update points + LR schedules (section 3.3 step 5)
//! * [`trainer`] — the MBS training loop and the native "w/o MBS" baseline

pub mod accumulator;
pub mod scheduler;
pub mod splitter;
pub mod streamer;
pub mod trainer;

pub use accumulator::{Accumulation, NormalizationMode};
pub use scheduler::UpdateScheduler;
pub use splitter::{MicroRange, SplitPlan};
pub use streamer::{stream_epoch, EpochStream, StreamingPolicy};
pub use trainer::{datasets_for, evaluate, train, TrainReport};
