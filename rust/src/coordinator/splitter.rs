//! Mini-batch -> micro-batch split plan (paper section 3.2 + Alg. 1 lines 1-6).
//!
//! Given a mini-batch of `n_b` samples and a configured micro-batch size
//! `n_mu`, the plan is `N_Smu = ceil(n_b / n_mu)` contiguous ranges; if the
//! mini-batch is smaller than the micro-batch, the micro-batch size clamps
//! down to it (Alg. 1 lines 2-4). The ranges partition the mini-batch
//! exactly (eq. 1-3) — a tested property.

/// One micro-batch: samples `[lo, hi)` of the mini-batch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MicroRange {
    /// Micro-batch index within the mini-batch.
    pub j: usize,
    /// First sample index (inclusive).
    pub lo: usize,
    /// Last sample index (exclusive).
    pub hi: usize,
}

impl MicroRange {
    /// Samples in this micro-batch.
    pub fn len(&self) -> usize {
        self.hi - self.lo
    }

    /// Is the range empty? (Never true for ranges a [`SplitPlan`] builds.)
    pub fn is_empty(&self) -> bool {
        self.lo == self.hi
    }
}

/// Split plan for one mini-batch.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SplitPlan {
    /// Mini-batch size `N_B`.
    pub n_b: usize,
    /// Effective micro-batch size after the Alg. 1 clamp.
    pub n_mu: usize,
    /// The contiguous ranges partitioning the mini-batch.
    pub ranges: Vec<MicroRange>,
}

impl SplitPlan {
    /// Alg. 1 lines 1-6.
    pub fn new(n_b: usize, n_mu: usize) -> SplitPlan {
        assert!(n_b > 0, "empty mini-batch");
        assert!(n_mu > 0, "zero micro-batch size");
        let n_mu = n_mu.min(n_b); // lines 2-4
        let n_smu = n_b.div_ceil(n_mu); // line 5 (round-up)
        let ranges = (0..n_smu)
            .map(|j| MicroRange { j, lo: j * n_mu, hi: ((j + 1) * n_mu).min(n_b) })
            .collect();
        SplitPlan { n_b, n_mu, ranges }
    }

    /// `N_Smu`, the number of micro-batches.
    pub fn n_smu(&self) -> usize {
        self.ranges.len()
    }

    /// True if every micro-batch has the full `n_mu` samples (no ragged tail).
    pub fn is_even(&self) -> bool {
        self.n_b % self.n_mu == 0
    }
}

/// Assignment of one mini-batch's micro-batches to data-parallel devices.
///
/// Device `d` owns a *contiguous block* of micro-batch indices, blocks are
/// balanced to within one micro-batch, and block order follows device rank
/// order. Contiguity in global `j` order is what lets the fleet executor
/// replay the exact solo execution sequence (and therefore stay
/// bit-identical to it): streaming the blocks in rank order IS the global
/// order, so the cross-device gradient combine is an *ordered* fold with
/// the same floating-point association as the single-device run.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardPlan {
    /// Number of devices shards were cut for.
    pub devices: usize,
    /// Owning device rank for each micro-batch index `j`.
    pub owners: Vec<usize>,
}

impl ShardPlan {
    /// Cut `n_smu` micro-batches into contiguous per-device blocks:
    /// `q = n_smu / devices` each, with the first `n_smu % devices`
    /// devices taking one extra. Devices beyond `n_smu` own empty blocks
    /// (a 4-device fleet streaming a 2-micro-batch mini-batch leaves two
    /// devices idle for that mini-batch).
    pub fn new(n_smu: usize, devices: usize) -> ShardPlan {
        assert!(devices > 0, "zero devices");
        let q = n_smu / devices;
        let r = n_smu % devices;
        let mut owners = Vec::with_capacity(n_smu);
        for d in 0..devices {
            let len = q + usize::from(d < r);
            owners.extend((0..len).map(|_| d));
        }
        ShardPlan { devices, owners }
    }

    /// Owning device rank of micro-batch `j`.
    pub fn owner(&self, j: usize) -> usize {
        self.owners[j]
    }

    /// Number of micro-batches device `d` owns.
    pub fn count(&self, d: usize) -> usize {
        self.owners.iter().filter(|&&o| o == d).count()
    }

    /// The contiguous `[lo, hi)` micro-batch block of device `d`
    /// (`lo == hi` when the device is idle this mini-batch).
    pub fn block(&self, d: usize) -> (usize, usize) {
        let lo = self.owners.iter().position(|&o| o == d);
        match lo {
            Some(lo) => (lo, lo + self.count(d)),
            None => (self.owners.len(), self.owners.len()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{ensure, forall};

    #[test]
    fn even_split() {
        let p = SplitPlan::new(16, 8);
        assert_eq!(p.n_smu(), 2);
        assert!(p.is_even());
        assert_eq!(p.ranges[0], MicroRange { j: 0, lo: 0, hi: 8 });
        assert_eq!(p.ranges[1], MicroRange { j: 1, lo: 8, hi: 16 });
    }

    #[test]
    fn ragged_tail() {
        let p = SplitPlan::new(10, 4);
        assert_eq!(p.n_smu(), 3);
        assert!(!p.is_even());
        assert_eq!(p.ranges[2].len(), 2);
    }

    #[test]
    fn clamp_when_minibatch_smaller() {
        // Alg. 1 lines 2-4: N_mu <- N_B
        let p = SplitPlan::new(3, 8);
        assert_eq!(p.n_mu, 3);
        assert_eq!(p.n_smu(), 1);
        assert_eq!(p.ranges[0].len(), 3);
    }

    #[test]
    fn single_sample() {
        let p = SplitPlan::new(1, 16);
        assert_eq!(p.n_smu(), 1);
        assert_eq!(p.n_mu, 1);
    }

    #[test]
    #[should_panic(expected = "empty mini-batch")]
    fn rejects_empty() {
        SplitPlan::new(0, 4);
    }

    // DESIGN.md invariant 1 as properties
    #[test]
    fn union_is_exact_partition() {
        forall(
            "partition",
            500,
            0x5EED,
            |r| ((r.below(2048) + 1) as usize, (r.below(64) + 1) as usize),
            |&(n_b, n_mu)| {
                let p = SplitPlan::new(n_b, n_mu);
                ensure(p.n_smu() == n_b.div_ceil(p.n_mu), "count != ceil")?;
                let mut covered = 0usize;
                for (i, r) in p.ranges.iter().enumerate() {
                    ensure(r.j == i, "j misnumbered")?;
                    ensure(r.lo == covered, "gap or overlap")?;
                    ensure(r.len() >= 1 && r.len() <= p.n_mu, "range size out of bounds")?;
                    covered = r.hi;
                }
                ensure(covered == n_b, "union != mini-batch")?;
                // eq. 3: mu size <= mini size
                ensure(p.n_mu <= n_b, "mu > n_b after clamp")
            },
        );
    }

    #[test]
    fn shard_blocks_are_contiguous_balanced_and_exhaustive() {
        forall(
            "shard plan",
            400,
            0xF1EE7,
            |r| ((r.below(64) + 1) as usize, (r.below(8) + 1) as usize),
            |&(n_smu, devices)| {
                let s = ShardPlan::new(n_smu, devices);
                ensure(s.owners.len() == n_smu, "owner per micro-batch")?;
                // rank order + contiguity: owners are non-decreasing
                ensure(s.owners.windows(2).all(|w| w[0] <= w[1]), "blocks out of rank order")?;
                let counts: Vec<usize> = (0..devices).map(|d| s.count(d)).collect();
                ensure(counts.iter().sum::<usize>() == n_smu, "blocks must partition")?;
                let (min, max) =
                    (counts.iter().min().unwrap(), counts.iter().max().unwrap());
                ensure(max - min <= 1, "imbalance > 1 micro-batch")?;
                for d in 0..devices {
                    let (lo, hi) = s.block(d);
                    ensure(hi - lo == s.count(d), "block length != count")?;
                    for j in lo..hi {
                        ensure(s.owner(j) == d, "block indexes another device")?;
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn shard_examples() {
        let s = ShardPlan::new(5, 2);
        assert_eq!(s.owners, vec![0, 0, 0, 1, 1]);
        assert_eq!(s.block(0), (0, 3));
        assert_eq!(s.block(1), (3, 5));
        // more devices than micro-batches: tail devices idle
        let s = ShardPlan::new(2, 4);
        assert_eq!(s.owners, vec![0, 1]);
        assert_eq!(s.count(3), 0);
        assert_eq!(s.block(3), (2, 2));
    }

    #[test]
    fn only_last_range_is_short() {
        forall(
            "tail",
            300,
            0xD00D,
            |r| ((r.below(1024) + 1) as usize, (r.below(64) + 1) as usize),
            |&(n_b, n_mu)| {
                let p = SplitPlan::new(n_b, n_mu);
                for r in &p.ranges[..p.n_smu() - 1] {
                    ensure(r.len() == p.n_mu, "non-tail range short")?;
                }
                Ok(())
            },
        );
    }
}
