//! Mini-batch -> micro-batch split plan (paper section 3.2 + Alg. 1 lines 1-6).
//!
//! Given a mini-batch of `n_b` samples and a configured micro-batch size
//! `n_mu`, the plan is `N_Smu = ceil(n_b / n_mu)` contiguous ranges; if the
//! mini-batch is smaller than the micro-batch, the micro-batch size clamps
//! down to it (Alg. 1 lines 2-4). The ranges partition the mini-batch
//! exactly (eq. 1-3) — a tested property.

/// One micro-batch: samples `[lo, hi)` of the mini-batch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MicroRange {
    /// Micro-batch index within the mini-batch.
    pub j: usize,
    /// First sample index (inclusive).
    pub lo: usize,
    /// Last sample index (exclusive).
    pub hi: usize,
}

impl MicroRange {
    /// Samples in this micro-batch.
    pub fn len(&self) -> usize {
        self.hi - self.lo
    }

    /// Is the range empty? (Never true for ranges a [`SplitPlan`] builds.)
    pub fn is_empty(&self) -> bool {
        self.lo == self.hi
    }
}

/// Split plan for one mini-batch.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SplitPlan {
    /// Mini-batch size `N_B`.
    pub n_b: usize,
    /// Effective micro-batch size after the Alg. 1 clamp.
    pub n_mu: usize,
    /// The contiguous ranges partitioning the mini-batch.
    pub ranges: Vec<MicroRange>,
}

impl SplitPlan {
    /// Alg. 1 lines 1-6.
    pub fn new(n_b: usize, n_mu: usize) -> SplitPlan {
        assert!(n_b > 0, "empty mini-batch");
        assert!(n_mu > 0, "zero micro-batch size");
        let n_mu = n_mu.min(n_b); // lines 2-4
        let n_smu = n_b.div_ceil(n_mu); // line 5 (round-up)
        let ranges = (0..n_smu)
            .map(|j| MicroRange { j, lo: j * n_mu, hi: ((j + 1) * n_mu).min(n_b) })
            .collect();
        SplitPlan { n_b, n_mu, ranges }
    }

    /// `N_Smu`, the number of micro-batches.
    pub fn n_smu(&self) -> usize {
        self.ranges.len()
    }

    /// True if every micro-batch has the full `n_mu` samples (no ragged tail).
    pub fn is_even(&self) -> bool {
        self.n_b % self.n_mu == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{ensure, forall};

    #[test]
    fn even_split() {
        let p = SplitPlan::new(16, 8);
        assert_eq!(p.n_smu(), 2);
        assert!(p.is_even());
        assert_eq!(p.ranges[0], MicroRange { j: 0, lo: 0, hi: 8 });
        assert_eq!(p.ranges[1], MicroRange { j: 1, lo: 8, hi: 16 });
    }

    #[test]
    fn ragged_tail() {
        let p = SplitPlan::new(10, 4);
        assert_eq!(p.n_smu(), 3);
        assert!(!p.is_even());
        assert_eq!(p.ranges[2].len(), 2);
    }

    #[test]
    fn clamp_when_minibatch_smaller() {
        // Alg. 1 lines 2-4: N_mu <- N_B
        let p = SplitPlan::new(3, 8);
        assert_eq!(p.n_mu, 3);
        assert_eq!(p.n_smu(), 1);
        assert_eq!(p.ranges[0].len(), 3);
    }

    #[test]
    fn single_sample() {
        let p = SplitPlan::new(1, 16);
        assert_eq!(p.n_smu(), 1);
        assert_eq!(p.n_mu, 1);
    }

    #[test]
    #[should_panic(expected = "empty mini-batch")]
    fn rejects_empty() {
        SplitPlan::new(0, 4);
    }

    // DESIGN.md invariant 1 as properties
    #[test]
    fn union_is_exact_partition() {
        forall(
            "partition",
            500,
            0x5EED,
            |r| ((r.below(2048) + 1) as usize, (r.below(64) + 1) as usize),
            |&(n_b, n_mu)| {
                let p = SplitPlan::new(n_b, n_mu);
                ensure(p.n_smu() == n_b.div_ceil(p.n_mu), "count != ceil")?;
                let mut covered = 0usize;
                for (i, r) in p.ranges.iter().enumerate() {
                    ensure(r.j == i, "j misnumbered")?;
                    ensure(r.lo == covered, "gap or overlap")?;
                    ensure(r.len() >= 1 && r.len() <= p.n_mu, "range size out of bounds")?;
                    covered = r.hi;
                }
                ensure(covered == n_b, "union != mini-batch")?;
                // eq. 3: mu size <= mini size
                ensure(p.n_mu <= n_b, "mu > n_b after clamp")
            },
        );
    }

    #[test]
    fn only_last_range_is_short() {
        forall(
            "tail",
            300,
            0xD00D,
            |r| ((r.below(1024) + 1) as usize, (r.below(64) + 1) as usize),
            |&(n_b, n_mu)| {
                let p = SplitPlan::new(n_b, n_mu);
                for r in &p.ranges[..p.n_smu() - 1] {
                    ensure(r.len() == p.n_mu, "non-tail range short")?;
                }
                Ok(())
            },
        );
    }
}
