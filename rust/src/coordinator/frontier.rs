//! Capacity × batch feasibility sweeps — the paper's headline figure as an
//! instrument.
//!
//! The paper's core claim is that MBS lets a fixed-memory device train at
//! mini-batch sizes far beyond its native capacity; this module maps the
//! *shape* of that trade. Given a grid of simulated device capacities and
//! global batch sizes, [`FrontierGrid::sweep`] calls the PR 1 planner at
//! every `(capacity, batch)` point — **without training** — and classifies
//! it:
//!
//!  * [`Feasibility::Native`] — an exported executable covers the whole
//!    mini-batch and the single `N_B`-sample step fits: the "w/o MBS" arm
//!    trains here too.
//!  * [`Feasibility::Mbs`] — the native step does not fit (or no exported
//!    executable is that large), but the planner derives a micro-batch
//!    `mu < batch` whose streamed step does: the paper's headline region.
//!  * [`Feasibility::Oom`] — even the smallest exported variant's step
//!    exceeds capacity: the tables' "Failed" cells.
//!
//! This frames the same (capacity × batch) frontier as You et al. ("The
//! Limit of the Batch Size", 2020) and McCandlish et al. ("An Empirical
//! Model of Large-Batch Training", 2018), driven by the simulated memory
//! model instead of a GPU farm. The `mbs frontier` CLI subcommand renders
//! the grid as a terminal table and a `BENCH_frontier.json` artifact
//! (schema shared with `BENCH_streaming.json` via
//! [`bench_report`](crate::metrics::bench_report)), and can attach measured
//! throughput to the feasibility boundary by running short timed epochs.
//!
//! Classification is pure capacity arithmetic over the manifest metadata,
//! so it needs no compiled artifacts: [`synthetic_entry`] provides a
//! task-shaped stand-in model for clean checkouts (`--dry-run` in CI).

use crate::data::PoolStats;
use crate::error::{MbsError, Result};
use crate::manifest::{Dtype, ModelEntry, OptimizerInfo, Variant};
use crate::memory::{Footprint, Ledger, MIB};
use crate::metrics::bench_report::{self, BenchReport, JsonValue};
use crate::metrics::StageTimers;
use crate::util::table::Table;

use super::planner;
use super::tenancy::{self, AdmissionOutcome, AdmissionRequest};

/// How one `(capacity, batch)` grid point trains, per the planner.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Feasibility {
    /// The whole mini-batch fits in one step ("w/o MBS" also trains).
    Native {
        /// Static batch dimension of the covering executable (≥ batch).
        mu: usize,
    },
    /// Trains only by streaming planner-sized micro-batches.
    Mbs {
        /// Planner-derived micro-batch size (paper Alg. 1).
        mu: usize,
        /// Accumulation steps per mini-batch, `ceil(batch / mu)`.
        n_smu: usize,
    },
    /// Does not train: even the smallest exported variant exceeds capacity.
    Oom {
        /// Bytes the smallest variant's step would have needed.
        needed_bytes: u64,
    },
}

impl Feasibility {
    /// Does this point train at all (natively or via MBS)?
    pub fn is_feasible(&self) -> bool {
        !matches!(self, Feasibility::Oom { .. })
    }

    /// The micro-batch size the point would execute with, if feasible.
    pub fn mu(&self) -> Option<usize> {
        match self {
            Feasibility::Native { mu } | Feasibility::Mbs { mu, .. } => Some(*mu),
            Feasibility::Oom { .. } => None,
        }
    }

    /// Machine-readable class name (`native` / `mbs` / `oom`).
    pub fn class_name(&self) -> &'static str {
        match self {
            Feasibility::Native { .. } => "native",
            Feasibility::Mbs { .. } => "mbs",
            Feasibility::Oom { .. } => "oom",
        }
    }

    /// Terminal-table cell label.
    pub fn label(&self) -> String {
        match self {
            Feasibility::Native { .. } => "native".to_string(),
            Feasibility::Mbs { mu, n_smu } => format!("mu={mu} x{n_smu}"),
            Feasibility::Oom { .. } => "OOM".to_string(),
        }
    }
}

/// Throughput measured by a short timed run at a boundary point.
#[derive(Debug, Clone)]
pub struct BoundaryTiming {
    /// Training samples per second over the timed epochs.
    pub items_per_sec: f64,
    /// Mean wall-clock per training epoch, seconds.
    pub epoch_wall_mean_s: f64,
    /// Micro-batch steps executed across the timed epochs.
    pub micro_steps: u64,
    /// Optimizer updates applied.
    pub updates: u64,
    /// Per-stage time totals across the timed epochs.
    pub stages: StageTimers,
    /// Staging-buffer pool traffic of the timed run.
    pub pool: PoolStats,
}

/// One classified grid point.
#[derive(Debug, Clone)]
pub struct GridPoint {
    /// Simulated device capacity, bytes.
    pub capacity_bytes: u64,
    /// Global (mini-)batch size `N_B`.
    pub batch: usize,
    /// The planner's verdict for this point.
    pub feasibility: Feasibility,
    /// Measured throughput, when a timed boundary run was attached.
    pub timing: Option<BoundaryTiming>,
}

/// A classified capacity × batch grid for one model.
#[derive(Debug, Clone)]
pub struct FrontierGrid {
    /// Model key the grid was swept for.
    pub model: String,
    /// Image size / sequence length of the swept variants.
    pub size: usize,
    /// Eval-set occupancy the admission check covered (0 = train-only).
    pub eval_len: usize,
    /// Was the overlapped pipeline's second in-flight input slot priced
    /// into every classification? (A point can legitimately flip
    /// `MBS(mu)` → `MBS(mu/2)` — or to OOM — when it is.)
    pub overlap: bool,
    /// Capacity axis, bytes, as given.
    pub capacities_bytes: Vec<u64>,
    /// Batch axis, as given.
    pub batches: Vec<usize>,
    /// Points in row-major order: for each capacity, every batch.
    pub points: Vec<GridPoint>,
}

/// Classify one `(capacity, batch)` point against the ledger's remaining
/// budget — the same budget-driven arithmetic `planner::resolve` runs at
/// admission time, made grid-callable.
///
/// A point is [`Feasibility::Native`] when some exported variant covers the
/// whole batch *and* the single `N_B`-sample step (plus the forward-only
/// eval sweep, if `eval_len > 0`) fits; otherwise the planner's
/// [`auto_mu`](crate::coordinator::planner::auto_mu) either derives a
/// streaming micro-batch ([`Feasibility::Mbs`]) or reports the structured
/// OOM ([`Feasibility::Oom`]). With `overlap` every check additionally
/// prices the pipeline's second staged input slot
/// ([`Footprint::overlap_bytes`]) — keeping classification in lock-step
/// with what `auto_mu` admits (the classify == auto_mu property).
pub fn classify(
    entry: &ModelEntry,
    size: usize,
    batch: usize,
    eval_len: usize,
    ledger: &Ledger,
    overlap: bool,
) -> Result<Feasibility> {
    let budget = ledger.remaining();
    // native arm: the smallest exported executable covering the whole batch
    // (least padding), admission-checked exactly like `resolve`'s native path
    let covering = entry
        .variants
        .iter()
        .filter(|v| v.size == size && v.mu >= batch)
        .min_by_key(|v| v.mu);
    if let Some(v) = covering {
        let fp = Footprint::from_manifest(entry, v);
        // the planner's own peak formula (v.mu >= batch, so the training
        // term is the whole N_B-sample step) — shared so classification
        // can never drift from admission
        if planner::peak_bytes(&fp, v.mu, batch, eval_len, overlap) <= budget {
            return Ok(Feasibility::Native { mu: v.mu });
        }
    }
    match planner::auto_mu(entry, size, batch, eval_len, budget, overlap) {
        // a manifest with non-uniform per-variant footprints can admit a
        // *different* covering variant than the one checked above; a single
        // step covering the whole batch is native execution, not streaming
        Ok(res) if res.mu >= batch => Ok(Feasibility::Native { mu: res.mu }),
        Ok(res) => Ok(Feasibility::Mbs { mu: res.mu, n_smu: batch.div_ceil(res.mu) }),
        Err(MbsError::Oom { needed_bytes, .. }) => Ok(Feasibility::Oom { needed_bytes }),
        Err(e) => Err(e),
    }
}

/// Co-residency verdict for a job *set* sharing one device — the
/// multi-tenant analogue of the per-point [`Feasibility`] classes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SetFeasibility {
    /// Every job is admitted at the micro-batch it would get alone on the
    /// whole device — co-residency costs the set nothing.
    CoResident,
    /// Every job is admitted, but at least one shrank its `mu` below its
    /// solo plan to fit the shared arena (the set-level `Mbs` region:
    /// only streaming smaller micro-batches makes the set fit).
    CoResidentMbs,
    /// At least one job cannot be admitted (resident reservation does not
    /// fit, the job is not even solo-feasible, or no exported variant's
    /// transient fits the shared leftover).
    Reject,
}

impl SetFeasibility {
    /// Fold per-job admission verdicts into the set-level class — the ONE
    /// place the admit/shrink/reject → set-class mapping lives (shared by
    /// [`classify_set`] and the `mbs jobs` report writers).
    pub fn from_outcomes<'a, I>(outcomes: I) -> SetFeasibility
    where
        I: IntoIterator<Item = &'a AdmissionOutcome>,
    {
        let mut shrunk_any = false;
        for outcome in outcomes {
            match outcome {
                AdmissionOutcome::Rejected { .. } => return SetFeasibility::Reject,
                AdmissionOutcome::Admitted { shrunk, .. } => shrunk_any |= *shrunk,
            }
        }
        if shrunk_any {
            SetFeasibility::CoResidentMbs
        } else {
            SetFeasibility::CoResident
        }
    }

    /// Does every job of the set train?
    pub fn is_feasible(&self) -> bool {
        !matches!(self, SetFeasibility::Reject)
    }

    /// Machine-readable class name
    /// (`co-resident` / `co-resident-mbs` / `reject`).
    pub fn class_name(&self) -> &'static str {
        match self {
            SetFeasibility::CoResident => "co-resident",
            SetFeasibility::CoResidentMbs => "co-resident-mbs",
            SetFeasibility::Reject => "reject",
        }
    }
}

/// Classify a job set against one shared capacity: run the deterministic
/// admission planner ([`tenancy::plan_admission`]) and label the *set* —
/// [`SetFeasibility::CoResident`] when sharing is free,
/// [`SetFeasibility::CoResidentMbs`] when it forces smaller micro-batches,
/// [`SetFeasibility::Reject`] when any job cannot be admitted. Pure
/// capacity arithmetic over manifest metadata, like [`classify`]; the
/// `mbs jobs --dry-run` table is this function rendered per job. Each
/// request carries its own lane mode ([`AdmissionRequest::overlap`]), so a
/// mixed async/serial set prices exactly what it would hold: the durable
/// staged input slots of the async jobs sum across tenants.
pub fn classify_set(requests: &[AdmissionRequest], capacity_bytes: u64) -> SetFeasibility {
    let verdicts = tenancy::plan_admission(requests, capacity_bytes);
    SetFeasibility::from_outcomes(verdicts.iter().map(|v| &v.outcome))
}

impl FrontierGrid {
    /// Classify every point of `capacities_bytes` × `batches` for
    /// `entry` at `size`. Each capacity is materialized as a fresh
    /// [`Ledger`] so the classification exercises the same remaining-budget
    /// query the training path uses. `overlap` prices the pipeline's
    /// second in-flight input slot at every point (`--overlap on`, the
    /// CLI default).
    pub fn sweep(
        entry: &ModelEntry,
        size: usize,
        eval_len: usize,
        capacities_bytes: &[u64],
        batches: &[usize],
        overlap: bool,
    ) -> Result<FrontierGrid> {
        if capacities_bytes.is_empty() || batches.is_empty() {
            return Err(MbsError::Config("frontier needs ≥1 capacity and ≥1 batch".into()));
        }
        if batches.contains(&0) {
            return Err(MbsError::Config("frontier batches must be positive".into()));
        }
        let mut points = Vec::with_capacity(capacities_bytes.len() * batches.len());
        for &capacity in capacities_bytes {
            let ledger = Ledger::new(capacity);
            for &batch in batches {
                let feasibility = classify(entry, size, batch, eval_len, &ledger, overlap)?;
                points.push(GridPoint {
                    capacity_bytes: capacity,
                    batch,
                    feasibility,
                    timing: None,
                });
            }
        }
        Ok(FrontierGrid {
            model: entry.name.clone(),
            size,
            eval_len,
            overlap,
            capacities_bytes: capacities_bytes.to_vec(),
            batches: batches.to_vec(),
            points,
        })
    }

    /// Mutable point lookup by `(capacity, batch)`.
    pub fn point_mut(&mut self, capacity_bytes: u64, batch: usize) -> Option<&mut GridPoint> {
        self.points
            .iter_mut()
            .find(|p| p.capacity_bytes == capacity_bytes && p.batch == batch)
    }

    /// Every feasible `(capacity, batch)` point in grid order — what
    /// `mbs frontier --time-all` pays timed runs for, filling the paper's
    /// fig.-3-style throughput surface over the whole feasible region
    /// instead of just its [`boundary`](FrontierGrid::boundary).
    pub fn feasible_points(&self) -> Vec<(u64, usize)> {
        self.points
            .iter()
            .filter(|p| p.feasibility.is_feasible())
            .map(|p| (p.capacity_bytes, p.batch))
            .collect()
    }

    /// The feasibility boundary: for each capacity (in grid order), the
    /// `(capacity, batch)` of the largest feasible batch, if any. These are
    /// the points worth paying a timed run for — the frontier itself.
    pub fn boundary(&self) -> Vec<(u64, usize)> {
        self.capacities_bytes
            .iter()
            .filter_map(|&c| {
                self.points
                    .iter()
                    .filter(|p| p.capacity_bytes == c && p.feasibility.is_feasible())
                    .max_by_key(|p| p.batch)
                    .map(|p| (c, p.batch))
            })
            .collect()
    }

    /// Render the grid as an aligned terminal table: one row per capacity,
    /// one column per batch, cells labelled native / `mu=K xN` / OOM (plus
    /// measured items/sec on timed points).
    pub fn render_table(&self) -> Table {
        let batch_headers: Vec<String> =
            self.batches.iter().map(|b| format!("N_B={b}")).collect();
        let mut header: Vec<&str> = vec!["capacity (MiB)"];
        header.extend(batch_headers.iter().map(|s| s.as_str()));
        let mut table = Table::new(&header);
        for &c in &self.capacities_bytes {
            let mut row = vec![format!("{:.1}", c as f64 / MIB as f64)];
            for &b in &self.batches {
                let cell = self
                    .points
                    .iter()
                    .find(|p| p.capacity_bytes == c && p.batch == b)
                    .map(|p| match &p.timing {
                        Some(t) => {
                            format!("{} ({:.0}/s)", p.feasibility.label(), t.items_per_sec)
                        }
                        None => p.feasibility.label(),
                    })
                    .unwrap_or_else(|| "-".to_string());
                row.push(cell);
            }
            table.row(&row);
        }
        table
    }

    /// Build the `BENCH_frontier.json` document (shared bench envelope;
    /// schema documented in `rust/docs/ARCHITECTURE.md`).
    pub fn to_report(&self, dry_run: bool) -> BenchReport {
        let mut rep = BenchReport::new("frontier", if dry_run { "dry-run" } else { "timed" });
        rep.str_field("model", &self.model)
            .uint("size", self.size as u64)
            .uint("eval_len", self.eval_len as u64)
            .str_field("overlap", if self.overlap { "on" } else { "off" })
            .str_field("lane", if self.overlap { "async" } else { "serial" })
            .field(
                "capacities_mib",
                JsonValue::Arr(
                    self.capacities_bytes
                        .iter()
                        .map(|&c| JsonValue::fixed(c as f64 / MIB as f64, 3))
                        .collect(),
                ),
            )
            .field(
                "batches",
                JsonValue::Arr(
                    self.batches.iter().map(|&b| JsonValue::UInt(b as u64)).collect(),
                ),
            );
        let grid: Vec<JsonValue> = self
            .points
            .iter()
            .map(|p| {
                let mut v = JsonValue::obj();
                v.push("capacity_mib", JsonValue::fixed(p.capacity_bytes as f64 / MIB as f64, 3));
                v.push("batch", JsonValue::UInt(p.batch as u64));
                v.push("class", JsonValue::Str(p.feasibility.class_name().to_string()));
                match p.feasibility {
                    Feasibility::Native { mu } => {
                        v.push("mu", JsonValue::UInt(mu as u64));
                        v.push("n_smu", JsonValue::UInt(1));
                    }
                    Feasibility::Mbs { mu, n_smu } => {
                        v.push("mu", JsonValue::UInt(mu as u64));
                        v.push("n_smu", JsonValue::UInt(n_smu as u64));
                    }
                    Feasibility::Oom { needed_bytes } => {
                        v.push("needed_bytes", JsonValue::UInt(needed_bytes));
                    }
                }
                if let Some(t) = &p.timing {
                    let mut timing = JsonValue::obj();
                    timing.push("items_per_sec", JsonValue::fixed(t.items_per_sec, 3));
                    timing.push("epoch_wall_mean_s", JsonValue::fixed(t.epoch_wall_mean_s, 6));
                    timing.push("micro_steps", JsonValue::UInt(t.micro_steps));
                    timing.push("updates", JsonValue::UInt(t.updates));
                    timing.push(
                        "overlap_efficiency",
                        JsonValue::fixed(t.stages.overlap_efficiency(), 4),
                    );
                    timing.push(
                        "stage_means_ms",
                        bench_report::stage_means_value(&t.stages, t.micro_steps, t.updates),
                    );
                    timing.push("pool", bench_report::pool_value(&t.pool));
                    v.push("timing", timing);
                }
                v
            })
            .collect();
        rep.field("grid", JsonValue::Arr(grid));
        rep
    }
}

/// One row of the frontier's device-count axis: how far the largest
/// feasible (and largest *native*) global batch moves when a uniform
/// fleet of `devices` devices shares the load.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DevicePoint {
    /// Per-device capacity, bytes (uniform across the fleet).
    pub capacity_bytes: u64,
    /// Number of data-parallel devices.
    pub devices: usize,
    /// Largest batch on the axis whose per-device share is feasible
    /// (`None` when even the smallest batch OOMs every device).
    pub max_feasible_batch: Option<usize>,
    /// Largest batch on the axis whose per-device share trains natively —
    /// the axis along which adding devices visibly buys batch size.
    pub max_native_batch: Option<usize>,
    /// Micro-batch size of the per-device share at
    /// [`max_feasible_batch`](DevicePoint::max_feasible_batch).
    pub mu: Option<usize>,
}

/// The frontier's device-count axis: for each `(per-device capacity,
/// device count)` pair of a *uniform* fleet, the largest feasible global
/// batch from a batch axis.
///
/// A global batch `B` on `D` devices is classified by its **per-device
/// share** `ceil(B / D)` — the largest sample count any single device
/// owns under the balanced contiguous sharding of
/// [`ShardPlan`](crate::coordinator::splitter::ShardPlan) — against one
/// device's capacity via [`classify`]. Per-device feasibility of the
/// share is exactly fleet feasibility: every device holds its own full
/// resident replica (data parallelism), and the shared split plan's
/// micro-step must fit the busiest device. Because feasibility is
/// monotone in batch (a tested [`FrontierGrid`] property) and the share
/// is non-increasing in `D`, both frontier batches are **non-decreasing
/// in device count** — the tested device-axis law.
#[derive(Debug, Clone)]
pub struct DeviceAxis {
    /// Model key the axis was swept for.
    pub model: String,
    /// Image size / sequence length of the swept variants.
    pub size: usize,
    /// Eval-set occupancy priced into every classification.
    pub eval_len: usize,
    /// Was the overlapped pipeline's staged input slot priced in?
    pub overlap: bool,
    /// Per-device capacity axis, bytes.
    pub capacities_bytes: Vec<u64>,
    /// Device-count axis.
    pub device_counts: Vec<usize>,
    /// Global batch axis the maxima were searched over.
    pub batches: Vec<usize>,
    /// Points in row-major order: for each capacity, every device count.
    pub points: Vec<DevicePoint>,
}

impl DeviceAxis {
    /// Sweep the device-count axis (see the type docs for the
    /// classification rule).
    pub fn sweep(
        entry: &ModelEntry,
        size: usize,
        eval_len: usize,
        capacities_bytes: &[u64],
        device_counts: &[usize],
        batches: &[usize],
        overlap: bool,
    ) -> Result<DeviceAxis> {
        if capacities_bytes.is_empty() || device_counts.is_empty() || batches.is_empty() {
            return Err(MbsError::Config(
                "device axis needs ≥1 capacity, ≥1 device count and ≥1 batch".into(),
            ));
        }
        if device_counts.contains(&0) || batches.contains(&0) {
            return Err(MbsError::Config(
                "device axis device counts and batches must be positive".into(),
            ));
        }
        let mut points = Vec::with_capacity(capacities_bytes.len() * device_counts.len());
        for &capacity in capacities_bytes {
            let ledger = Ledger::new(capacity);
            for &devices in device_counts {
                let mut point = DevicePoint {
                    capacity_bytes: capacity,
                    devices,
                    max_feasible_batch: None,
                    max_native_batch: None,
                    mu: None,
                };
                for &batch in batches {
                    let share = batch.div_ceil(devices);
                    let class = classify(entry, size, share, eval_len, &ledger, overlap)?;
                    if class.is_feasible()
                        && point.max_feasible_batch.map_or(true, |b| batch > b)
                    {
                        point.max_feasible_batch = Some(batch);
                        point.mu = class.mu();
                    }
                    if matches!(class, Feasibility::Native { .. })
                        && point.max_native_batch.map_or(true, |b| batch > b)
                    {
                        point.max_native_batch = Some(batch);
                    }
                }
                points.push(point);
            }
        }
        Ok(DeviceAxis {
            model: entry.name.clone(),
            size,
            eval_len,
            overlap,
            capacities_bytes: capacities_bytes.to_vec(),
            device_counts: device_counts.to_vec(),
            batches: batches.to_vec(),
            points,
        })
    }

    /// Render the axis as an aligned terminal table: one row per
    /// `(capacity, devices)` pair.
    pub fn render_table(&self) -> Table {
        let mut table =
            Table::new(&["capacity (MiB)", "devices", "max feasible N_B", "max native N_B", "mu"]);
        for p in &self.points {
            let cell = |v: Option<usize>| {
                v.map(|b| b.to_string()).unwrap_or_else(|| "-".to_string())
            };
            table.row(&[
                format!("{:.1}", p.capacity_bytes as f64 / MIB as f64),
                p.devices.to_string(),
                cell(p.max_feasible_batch),
                cell(p.max_native_batch),
                cell(p.mu),
            ]);
        }
        table
    }

    /// The axis as a JSON array for the `device_axis` field of
    /// `BENCH_frontier.json` (schema in `rust/docs/ARCHITECTURE.md`).
    pub fn to_json_value(&self) -> JsonValue {
        JsonValue::Arr(
            self.points
                .iter()
                .map(|p| {
                    let mut v = JsonValue::obj();
                    v.push(
                        "capacity_mib",
                        JsonValue::fixed(p.capacity_bytes as f64 / MIB as f64, 3),
                    );
                    v.push("devices", JsonValue::UInt(p.devices as u64));
                    if let Some(b) = p.max_feasible_batch {
                        v.push("max_feasible_batch", JsonValue::UInt(b as u64));
                    }
                    if let Some(b) = p.max_native_batch {
                        v.push("max_native_batch", JsonValue::UInt(b as u64));
                    }
                    if let Some(mu) = p.mu {
                        v.push("mu", JsonValue::UInt(mu as u64));
                    }
                    v
                })
                .collect(),
        )
    }
}

/// A task-shaped stand-in [`ModelEntry`] for artifact-free (`--dry-run`)
/// sweeps: one exported variant per power-of-two `mu` up to 64, with
/// footprints sized so single-digit-MiB capacities produce all three
/// feasibility classes.
///
/// The arithmetic (sgdm keeps one optimizer slot, so resident state is
/// `3 * param_bytes + fixed_bytes`):
///
/// | task           | params  | fixed   | act/sample | resident |
/// |----------------|---------|---------|------------|----------|
/// | classification | 256 KiB | 256 KiB | 64 KiB     | 1 MiB    |
/// | segmentation   | 256 KiB | 256 KiB | 128 KiB    | 1 MiB    |
/// | lm             | 512 KiB | 256 KiB | 32 KiB     | 1.75 MiB |
///
/// e.g. classification at 2 MiB capacity leaves ~1 MiB of data space →
/// the planner settles on `mu = 8`; at 8 MiB batches ≤ 64 are native.
pub fn synthetic_entry(task: &str) -> Result<ModelEntry> {
    const KIB: u64 = 1024;
    let size = 16usize;
    // (param_bytes, act/sample, x_elems, x_dtype, y_elems, y_dtype)
    let (param_bytes, act_per_sample, x_elems, x_dtype, y_elems, y_dtype) = match task {
        "classification" => (256 * KIB, 64 * KIB, size * size * 3, Dtype::F32, 1, Dtype::I32),
        "segmentation" => {
            (256 * KIB, 128 * KIB, size * size * 3, Dtype::F32, size * size, Dtype::I32)
        }
        "lm" => (512 * KIB, 32 * KIB, size, Dtype::I32, size, Dtype::I32),
        other => {
            return Err(MbsError::Config(format!(
                "unknown frontier task '{other}' (classification | segmentation | lm)"
            )))
        }
    };
    let variants = [1usize, 2, 4, 8, 16, 32, 64]
        .iter()
        .map(|&mu| Variant {
            mu,
            size,
            x_shape: vec![mu, x_elems],
            x_dtype: x_dtype.clone(),
            y_shape: vec![mu, y_elems],
            y_dtype: y_dtype.clone(),
            accum_hlo: String::new(),
            eval_hlo: String::new(),
            activation_bytes_per_sample: act_per_sample,
            fixed_bytes: 256 * KIB,
        })
        .collect();
    Ok(ModelEntry {
        name: format!("synthetic-{task}"),
        task: task.to_string(),
        optimizer: OptimizerInfo {
            kind: "sgdm".into(),
            slots: 1,
            hyper_names: vec!["lr".into()],
            hyper_defaults: vec![0.01],
        },
        params_bin: String::new(),
        param_leaves: Vec::new(),
        param_bytes,
        apply_hlo: String::new(),
        metric_semantics: task.to_string(),
        default_size: size,
        variants,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{ensure, forall};
    use crate::util::rng::Rng;

    /// Synthetic manifest entry exporting one variant per `mu`, with simple
    /// linear footprints so capacities are easy to reason about (mirrors
    /// the planner's test fixture).
    fn entry_with_mus(
        mus: &[usize],
        act_per_sample: u64,
        fixed: u64,
        param_bytes: u64,
    ) -> ModelEntry {
        ModelEntry {
            name: "synthetic".into(),
            task: "classification".into(),
            optimizer: OptimizerInfo {
                kind: "sgdm".into(),
                slots: 1,
                hyper_names: vec!["lr".into()],
                hyper_defaults: vec![0.01],
            },
            params_bin: "params.bin".into(),
            param_leaves: Vec::new(),
            param_bytes,
            apply_hlo: "apply.hlo".into(),
            metric_semantics: "classification".into(),
            default_size: 16,
            variants: mus
                .iter()
                .map(|&mu| Variant {
                    mu,
                    size: 16,
                    x_shape: vec![mu, 4],
                    x_dtype: Dtype::F32,
                    y_shape: vec![mu],
                    y_dtype: Dtype::I32,
                    accum_hlo: String::new(),
                    eval_hlo: String::new(),
                    activation_bytes_per_sample: act_per_sample,
                    fixed_bytes: fixed,
                })
                .collect(),
        }
    }

    #[test]
    fn oom_boundary_matches_hand_computed_ledger() {
        // per-sample input: x = 4 elems, y = 1 elem, +1 mask slot, 4 B each
        // => 24 B; act 1000 B/sample; resident = 3*100 params + 0 fixed = 300
        let entry = entry_with_mus(&[2, 4], 1000, 0, 100);
        let step_mu2 = 300 + 2 * (1000 + 24); // 2348: smallest variant's step
        // exactly at the frontier: the smallest variant streams any batch
        let at = Ledger::new(step_mu2);
        match classify(&entry, 16, 64, 0, &at, false).unwrap() {
            Feasibility::Mbs { mu, n_smu } => {
                assert_eq!(mu, 2);
                assert_eq!(n_smu, 32);
            }
            other => panic!("want Mbs at the boundary, got {other:?}"),
        }
        // one byte below: structured OOM carrying the hand-computed need
        let below = Ledger::new(step_mu2 - 1);
        match classify(&entry, 16, 64, 0, &below, false).unwrap() {
            Feasibility::Oom { needed_bytes } => assert_eq!(needed_bytes, step_mu2),
            other => panic!("want Oom below the boundary, got {other:?}"),
        }
        // a batch the small variant covers natively at the same capacity
        let native = Ledger::new(step_mu2);
        assert_eq!(
            classify(&entry, 16, 2, 0, &native, false).unwrap(),
            Feasibility::Native { mu: 2 }
        );
        // charging the ledger moves the frontier: pinned bytes shrink
        // remaining() below the mu=2 step
        let mut charged = Ledger::new(step_mu2);
        charged.alloc("pinned", 1).unwrap();
        assert!(matches!(
            classify(&entry, 16, 64, 0, &charged, false).unwrap(),
            Feasibility::Oom { .. }
        ));
    }

    #[test]
    fn native_requires_covering_variant() {
        // plenty of capacity, but no exported executable covers batch 64:
        // the point is MBS, not native (matches `resolve`'s coverage rule)
        let entry = entry_with_mus(&[2, 4, 8], 1000, 0, 100);
        let roomy = Ledger::new(1 << 30);
        match classify(&entry, 16, 64, 0, &roomy, false).unwrap() {
            Feasibility::Mbs { mu, n_smu } => {
                assert_eq!(mu, 8);
                assert_eq!(n_smu, 8);
            }
            other => panic!("want Mbs without coverage, got {other:?}"),
        }
        // batch 8 is covered and fits: native
        assert_eq!(
            classify(&entry, 16, 8, 0, &roomy, false).unwrap(),
            Feasibility::Native { mu: 8 }
        );
    }

    #[test]
    fn cheaper_covering_variant_classifies_native_not_single_step_mbs() {
        // non-uniform footprints: the smallest covering variant (mu=8) is
        // expensive, but a larger covering variant (mu=16) fits — the point
        // executes as ONE covering step, so it must be labelled Native,
        // never "Mbs x1"
        let mut entry = entry_with_mus(&[8, 16], 1000, 0, 100);
        entry.variants[0].activation_bytes_per_sample = 10_000;
        let fp16 = Footprint::from_manifest(&entry, &entry.variants[1]);
        let budget = fp16.step_bytes(8); // fits mu=16's 8-sample step only
        let class = classify(&entry, 16, 8, 0, &Ledger::new(budget), false).unwrap();
        assert_eq!(class, Feasibility::Native { mu: 16 });
        // and a genuine streaming point always carries at least two steps
        let budget = fp16.step_bytes(16); // fits the full mu=16 step
        match classify(&entry, 16, 64, 0, &Ledger::new(budget), false).unwrap() {
            Feasibility::Mbs { mu, n_smu } => {
                assert_eq!(mu, 16);
                assert_eq!(n_smu, 4);
            }
            other => panic!("want streaming Mbs, got {other:?}"),
        }
    }

    #[test]
    fn eval_occupancy_shifts_the_native_frontier() {
        // input-dominated model: a large eval set makes the forward sweep
        // the binding constraint, exactly as in planner admission
        let entry = entry_with_mus(&[16], 1, 0, 100);
        let fp = Footprint::from_manifest(&entry, &entry.variants[0]);
        let eval_need = fp.resident_bytes() + fp.eval_bytes(16);
        let train_need = fp.step_bytes(4);
        assert!(eval_need > train_need, "fixture must be eval-bound");
        let tight = Ledger::new(eval_need - 1);
        // without eval occupancy the batch-4 step is native...
        assert!(matches!(
            classify(&entry, 16, 4, 0, &tight, false).unwrap(),
            Feasibility::Native { .. }
        ));
        // ...but admitting a 64-item eval sweep tips it over
        assert!(matches!(
            classify(&entry, 16, 4, 64, &tight, false).unwrap(),
            Feasibility::Oom { .. }
        ));
    }

    #[test]
    fn sweep_grid_shape_boundary_and_report() {
        let entry = synthetic_entry("classification").unwrap();
        let caps: Vec<u64> = [1u64, 2, 8].iter().map(|&m| m * MIB).collect();
        let batches = [8usize, 64, 256];
        let grid = FrontierGrid::sweep(&entry, 16, 0, &caps, &batches, false).unwrap();
        assert_eq!(grid.points.len(), 9);
        // 1 MiB == resident state: every batch OOMs, so no boundary entry
        for p in grid.points.iter().filter(|p| p.capacity_bytes == MIB) {
            assert!(!p.feasibility.is_feasible(), "1 MiB must OOM, got {p:?}");
        }
        // 8 MiB: batch 8 and 64 native (covered by mu=64), 256 streams
        let at = |c: u64, b: usize| {
            grid.points
                .iter()
                .find(|p| p.capacity_bytes == c && p.batch == b)
                .unwrap()
                .feasibility
        };
        assert!(matches!(at(8 * MIB, 8), Feasibility::Native { .. }));
        assert!(matches!(at(8 * MIB, 64), Feasibility::Native { mu: 64 }));
        assert!(matches!(at(8 * MIB, 256), Feasibility::Mbs { .. }));
        // 2 MiB streams everything it fits
        assert!(matches!(at(2 * MIB, 256), Feasibility::Mbs { .. }));
        // boundary: largest feasible batch per capacity that has one
        let boundary = grid.boundary();
        assert_eq!(boundary, vec![(2 * MIB, 256), (8 * MIB, 256)]);
        // table renders one row per capacity
        let rendered = grid.render_table().render();
        assert_eq!(rendered.lines().count(), 2 + caps.len());
        assert!(rendered.contains("OOM"));
        assert!(rendered.contains("native"));
        // report round-trips through the JSON parser with the shared envelope
        let json = grid.to_report(true).to_json();
        let parsed = crate::util::json::Json::parse(&json).unwrap();
        assert_eq!(
            parsed.get("bench").and_then(crate::util::json::Json::as_str),
            Some("frontier")
        );
        assert_eq!(
            parsed.get("mode").and_then(crate::util::json::Json::as_str),
            Some("dry-run")
        );
        assert_eq!(
            parsed.get("grid").and_then(crate::util::json::Json::as_arr).map(|a| a.len()),
            Some(9)
        );
    }

    #[test]
    fn overlap_residency_flips_points_and_is_reported() {
        // ISSUE 4: a budget sized exactly for the serial mu=4 step has no
        // room for the staged second input slot, so pricing overlap flips
        // the point MBS(4) -> MBS(2) without touching serial results
        let entry = entry_with_mus(&[2, 4], 1000, 0, 100);
        let fp4 = Footprint::from_manifest(&entry, entry.variant(16, 4).unwrap());
        let budget = fp4.step_bytes(4);
        let serial = classify(&entry, 16, 64, 0, &Ledger::new(budget), false).unwrap();
        assert_eq!(serial, Feasibility::Mbs { mu: 4, n_smu: 16 });
        let overlapped = classify(&entry, 16, 64, 0, &Ledger::new(budget), true).unwrap();
        assert_eq!(overlapped, Feasibility::Mbs { mu: 2, n_smu: 32 });
        // and the grid records which pricing produced it
        let grid =
            FrontierGrid::sweep(&entry, 16, 0, &[budget], &[64], true).unwrap();
        assert!(grid.overlap);
        let json = grid.to_report(true).to_json();
        let parsed = crate::util::json::Json::parse(&json).unwrap();
        assert_eq!(
            parsed.get("overlap").and_then(crate::util::json::Json::as_str),
            Some("on")
        );
        // the report names the upload-lane mode the pricing corresponds to
        assert_eq!(
            parsed.get("lane").and_then(crate::util::json::Json::as_str),
            Some("async")
        );
        let serial_grid =
            FrontierGrid::sweep(&entry, 16, 0, &[budget], &[64], false).unwrap();
        let parsed =
            crate::util::json::Json::parse(&serial_grid.to_report(true).to_json()).unwrap();
        assert_eq!(
            parsed.get("lane").and_then(crate::util::json::Json::as_str),
            Some("serial")
        );
    }

    #[test]
    fn classify_set_labels_all_three_regions() {
        use crate::config::MicroBatchSpec;
        let entry = entry_with_mus(&[2, 4, 8], 1000, 0, 100);
        let fp = Footprint::from_manifest(&entry, &entry.variants[0]);
        let req = |name: &str| AdmissionRequest {
            name: name.into(),
            entry: entry.clone(),
            size: 16,
            batch: 64,
            eval_len: 0,
            mu: MicroBatchSpec::Auto,
            overlap: false,
        };
        let pair = [req("a"), req("b")];
        // roomy: two residents + one mu=8 transient -> both keep solo mu
        let roomy = 2 * fp.resident_bytes() + fp.batch_bytes(8);
        assert_eq!(classify_set(&pair, roomy), SetFeasibility::CoResident);
        // one byte less: the shared transient budget forces mu=4
        let verdict = classify_set(&pair, roomy - 1);
        assert_eq!(verdict, SetFeasibility::CoResidentMbs);
        assert!(verdict.is_feasible());
        assert_eq!(verdict.class_name(), "co-resident-mbs");
        // two residents but not even a mu=2 transient: the set is rejected
        let tiny = 2 * fp.resident_bytes() + fp.batch_bytes(2) - 1;
        assert_eq!(classify_set(&pair, tiny), SetFeasibility::Reject);
        // a single job at the roomy capacity is trivially co-resident, and
        // agrees with the per-point classifier's feasibility
        assert_eq!(classify_set(&pair[..1], roomy), SetFeasibility::CoResident);
        assert!(classify(&entry, 16, 64, 0, &Ledger::new(roomy), false)
            .unwrap()
            .is_feasible());
        // async-lane tenants price their durable staged slots on top: the
        // capacity that is exactly CoResident for serial jobs shrinks an
        // overlapped pair (the sum of staged slots no longer fits for free)
        let async_pair = [
            AdmissionRequest { overlap: true, ..req("a") },
            AdmissionRequest { overlap: true, ..req("b") },
        ];
        assert_eq!(classify_set(&async_pair, roomy), SetFeasibility::CoResidentMbs);
        let roomier = roomy + 2 * fp.overlap_bytes(8) + fp.overlap_bytes(8);
        assert_eq!(classify_set(&async_pair, roomier), SetFeasibility::CoResident);
    }

    #[test]
    fn feasible_points_cover_the_whole_region() {
        let entry = synthetic_entry("classification").unwrap();
        let caps: Vec<u64> = [1u64, 2, 8].iter().map(|&m| m * MIB).collect();
        let batches = [8usize, 64, 256];
        let grid = FrontierGrid::sweep(&entry, 16, 0, &caps, &batches, false).unwrap();
        let all = grid.feasible_points();
        // every feasible grid point is listed, in grid order…
        assert_eq!(
            all.len(),
            grid.points.iter().filter(|p| p.feasibility.is_feasible()).count()
        );
        // …and the boundary (largest batch per capacity) is a subset
        for b in grid.boundary() {
            assert!(all.contains(&b), "boundary point {b:?} missing from feasible set");
        }
        assert!(all.len() > grid.boundary().len(), "fixture should have interior points");
    }

    #[test]
    fn empty_axes_rejected() {
        let entry = synthetic_entry("classification").unwrap();
        assert!(FrontierGrid::sweep(&entry, 16, 0, &[], &[8], false).is_err());
        assert!(FrontierGrid::sweep(&entry, 16, 0, &[MIB], &[], false).is_err());
        assert!(FrontierGrid::sweep(&entry, 16, 0, &[MIB], &[0], false).is_err());
        assert!(DeviceAxis::sweep(&entry, 16, 0, &[MIB], &[], &[8], false).is_err());
        assert!(DeviceAxis::sweep(&entry, 16, 0, &[MIB], &[0], &[8], false).is_err());
        assert!(DeviceAxis::sweep(&entry, 16, 0, &[MIB], &[1], &[], false).is_err());
    }

    #[test]
    fn device_axis_grows_the_native_frontier() {
        // synthetic classification at 8 MiB: one device trains N_B <= 64
        // natively (the largest exported variant); two devices halve the
        // per-device share, so 128 goes native; four devices push 256
        let entry = synthetic_entry("classification").unwrap();
        let batches = [8usize, 64, 128, 256];
        let axis =
            DeviceAxis::sweep(&entry, 16, 0, &[8 * MIB], &[1, 2, 4], &batches, false).unwrap();
        assert_eq!(axis.points.len(), 3);
        let native: Vec<Option<usize>> =
            axis.points.iter().map(|p| p.max_native_batch).collect();
        assert_eq!(native, vec![Some(64), Some(128), Some(256)]);
        // MBS keeps every axis batch feasible at this capacity regardless
        // of fleet size — the paper's point, restated per device
        assert!(axis.points.iter().all(|p| p.max_feasible_batch == Some(256)));
        // a capacity equal to the resident state OOMs at every count:
        // data parallelism replicates the resident state, it cannot shrink it
        let starved =
            DeviceAxis::sweep(&entry, 16, 0, &[MIB], &[1, 2, 4], &batches, false).unwrap();
        assert!(starved.points.iter().all(|p| p.max_feasible_batch.is_none()));
        // rendering + JSON shape
        let rendered = axis.render_table().render();
        assert_eq!(rendered.lines().count(), 2 + 3);
        let mut rep = BenchReport::new("frontier", "dry-run");
        rep.field("device_axis", axis.to_json_value());
        let parsed = crate::util::json::Json::parse(&rep.to_json()).unwrap();
        let rows = parsed
            .get("device_axis")
            .and_then(crate::util::json::Json::as_arr)
            .expect("device_axis array");
        assert_eq!(rows.len(), 3);
        assert_eq!(
            rows[1].get("max_native_batch").and_then(crate::util::json::Json::as_u64),
            Some(128)
        );
    }

    #[test]
    fn synthetic_entries_cover_all_tasks() {
        for task in ["classification", "segmentation", "lm"] {
            let e = synthetic_entry(task).unwrap();
            assert_eq!(e.task, task);
            assert!(!e.variants.is_empty());
            assert_eq!(e.max_mu(16), Some(64));
        }
        assert!(synthetic_entry("bogus").is_err());
    }

    mod properties {
        use super::*;

        fn rand_entry(r: &mut Rng) -> ModelEntry {
            let k = (r.below(5) + 1) as usize;
            let mus: Vec<usize> = (0..k).map(|i| 1usize << i).collect();
            entry_with_mus(
                &mus,
                r.below(1 << 12) + 1,
                r.below(1 << 10),
                r.below(1 << 14) + 1,
            )
        }

        fn feasible_in(
            entry: &ModelEntry,
            batch: usize,
            capacity: u64,
            eval_len: usize,
            overlap: bool,
        ) -> bool {
            classify(entry, 16, batch, eval_len, &Ledger::new(capacity), overlap)
                .unwrap()
                .is_feasible()
        }

        #[test]
        fn feasibility_is_monotone_in_capacity_and_batch() {
            // if batch B fits at capacity C, then B fits at every C' > C,
            // and every B' < B fits at C — the property that makes the
            // frontier a *boundary* rather than a scatter
            forall(
                "frontier monotone",
                200,
                0xF05,
                |r| {
                    let entry = rand_entry(r);
                    let capacity = r.below(1 << 22);
                    let extra = r.below(1 << 20) + 1;
                    let batch = (r.below(512) + 1) as usize;
                    let smaller = (r.below(batch as u64) + 1) as usize;
                    let eval_len = r.below(64) as usize;
                    let overlap = r.below(2) == 1;
                    (entry, capacity, extra, batch, smaller, eval_len, overlap)
                },
                |(entry, capacity, extra, batch, smaller, eval_len, overlap)| {
                    if !feasible_in(entry, *batch, *capacity, *eval_len, *overlap) {
                        return Ok(()); // nothing to propagate
                    }
                    ensure(
                        feasible_in(entry, *batch, *capacity + *extra, *eval_len, *overlap),
                        format!("batch {batch} fits at {capacity} but not at more capacity"),
                    )?;
                    ensure(
                        feasible_in(entry, *smaller, *capacity, *eval_len, *overlap),
                        format!("batch {batch} fits but smaller batch {smaller} does not"),
                    )?;
                    // overlap residency can only shrink the feasible region
                    if *overlap {
                        ensure(
                            feasible_in(entry, *batch, *capacity, *eval_len, false),
                            format!("batch {batch} fits WITH overlap but not without"),
                        )?;
                    }
                    Ok(())
                },
            );
        }

        #[test]
        fn device_axis_is_monotone_in_device_count() {
            // satellite property: for a uniform fleet, the largest feasible
            // (and largest native) global batch never shrinks when devices
            // are added — the share each device carries only gets lighter
            forall(
                "device axis monotone",
                150,
                0xF07,
                |r| {
                    let entry = rand_entry(r);
                    let capacity = r.below(1 << 22);
                    let batches: Vec<usize> =
                        (0..4).map(|_| (r.below(512) + 1) as usize).collect();
                    let counts: Vec<usize> = (1..=4).collect();
                    let eval_len = r.below(64) as usize;
                    let overlap = r.below(2) == 1;
                    (entry, capacity, counts, batches, eval_len, overlap)
                },
                |(entry, capacity, counts, batches, eval_len, overlap)| {
                    let axis = DeviceAxis::sweep(
                        entry, 16, *eval_len, &[*capacity], counts, batches, *overlap,
                    )
                    .map_err(|e| e.to_string())?;
                    for w in axis.points.windows(2) {
                        ensure(
                            w[1].max_feasible_batch.unwrap_or(0)
                                >= w[0].max_feasible_batch.unwrap_or(0),
                            format!(
                                "feasible frontier shrank from {:?} ({} devices) to {:?} ({})",
                                w[0].max_feasible_batch,
                                w[0].devices,
                                w[1].max_feasible_batch,
                                w[1].devices
                            ),
                        )?;
                        ensure(
                            w[1].max_native_batch.unwrap_or(0)
                                >= w[0].max_native_batch.unwrap_or(0),
                            format!(
                                "native frontier shrank from {:?} ({} devices) to {:?} ({})",
                                w[0].max_native_batch,
                                w[0].devices,
                                w[1].max_native_batch,
                                w[1].devices
                            ),
                        )?;
                    }
                    Ok(())
                },
            );
        }

        #[test]
        fn classification_agrees_with_planner_feasibility() {
            // a point is feasible exactly when auto_mu resolves (or a
            // covering native step fits — which implies auto_mu resolves
            // too, since the same variant admits a clamped step) — and the
            // property must survive overlap residency being priced into
            // BOTH sides (ISSUE 4: classify == auto_mu stays intact)
            forall(
                "classify == planner",
                200,
                0xF06,
                |r| {
                    let entry = rand_entry(r);
                    let capacity = r.below(1 << 22);
                    let batch = (r.below(512) + 1) as usize;
                    let overlap = r.below(2) == 1;
                    (entry, capacity, batch, overlap)
                },
                |(entry, capacity, batch, overlap)| {
                    let class =
                        classify(entry, 16, *batch, 0, &Ledger::new(*capacity), *overlap)
                            .unwrap();
                    let planner_fits =
                        planner::auto_mu(entry, 16, *batch, 0, *capacity, *overlap).is_ok();
                    ensure(
                        class.is_feasible() == planner_fits,
                        format!(
                            "classify {class:?} disagrees with planner \
                             (fits={planner_fits}, overlap={overlap})"
                        ),
                    )?;
                    // and whenever both classify, the chosen mu agrees
                    if let (Some(mu), Ok(res)) = (
                        class.mu(),
                        planner::auto_mu(entry, 16, *batch, 0, *capacity, *overlap),
                    ) {
                        ensure(
                            mu == res.mu || matches!(class, Feasibility::Native { .. }),
                            format!("classify mu={mu} != planner mu={}", res.mu),
                        )?;
                    }
                    Ok(())
                },
            );
        }
    }
}
