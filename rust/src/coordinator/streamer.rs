//! Stream-based pipeline (paper section 3.1, fig. 1): micro-batches are
//! assembled on the host and streamed to the device in sequence.
//!
//! Policies:
//!  * [`StreamingPolicy::DoubleBuffered`] — a worker thread assembles the
//!    next micro-batch(es) while the runtime thread executes the current
//!    one, over a bounded channel (the CUDA-stream copy/compute overlap of
//!    the paper, expressed with std threads since the device here is the
//!    PJRT CPU client).
//!  * [`StreamingPolicy::Synchronous`] — assemble inline on the runtime
//!    thread; the ablation baseline (A2) that quantifies what the overlap
//!    buys.
//!
//! Every item carries the [`ExecutionPlan`] of its mini-batch (computed
//! once, on the producing side, by the [`Planner`]) so the consumer never
//! re-derives split geometry or normalization scales — the plan is the
//! single source of truth shared across the thread boundary.
//!
//! The bounded channel *is* the memory backpressure: at most `prefetch`
//! assembled micro-batches exist beyond the one executing, so host staging
//! memory is bounded by `(prefetch + 1) * mu * sample_bytes`.
//!
//! Staging buffers are leased from a shared [`BufPool`] and assembled
//! in-place (`loader::assemble_into`); the consumer hands each buffer back
//! through the pool's return channel after upload, so steady-state
//! streaming performs zero host-buffer allocations — the same
//! `max(prefetch, 1) + 2` buffers circulate for the whole run (the channel
//! is 1-deep even at `prefetch == 0`). Every item also carries how long
//! its assembly took, feeding the per-stage pipeline instrumentation.

use std::sync::mpsc;
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use crate::data::{loader, BufPool, Dataset, EpochPlan, MicroBatchHost};

use super::planner::{ExecutionPlan, Planner};

/// Where micro-batch assembly happens relative to execution.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StreamingPolicy {
    /// Assemble on a worker thread, overlapped with execution (default).
    DoubleBuffered,
    /// Assemble inline on the runtime thread (the A2 ablation baseline).
    Synchronous,
}

impl StreamingPolicy {
    /// Parse a CLI `--streaming` value (`double-buffered` / `sync` / …).
    pub fn parse(s: &str) -> Option<StreamingPolicy> {
        match s {
            "double-buffered" | "double_buffered" | "async" => {
                Some(StreamingPolicy::DoubleBuffered)
            }
            "synchronous" | "sync" => Some(StreamingPolicy::Synchronous),
            _ => None,
        }
    }

    /// CLI/report name of the policy.
    pub fn name(&self) -> &'static str {
        match self {
            StreamingPolicy::DoubleBuffered => "double-buffered",
            StreamingPolicy::Synchronous => "synchronous",
        }
    }
}

/// One streamed micro-batch, tagged with its mini-batch's execution plan.
#[derive(Debug)]
pub struct StreamItem {
    /// Mini-batch index within the epoch.
    pub batch: usize,
    /// The plan governing this micro-batch's mini-batch (shared across all
    /// of its micro-batches).
    pub plan: Arc<ExecutionPlan>,
    /// The assembled (padded, masked) host tensors, leased from the pool.
    pub mb: MicroBatchHost,
    /// Host-side assembly time for this micro-batch (stage instrumentation;
    /// measured on whichever thread assembled it).
    pub assemble: Duration,
}

/// Iterator over every micro-batch of an epoch under a streaming policy.
pub enum EpochStream {
    /// Double-buffered: a producer thread assembles ahead over a bounded
    /// channel.
    Buffered {
        /// `Some` until dropped; taken (disconnecting the producer) before
        /// the join in `Drop`.
        rx: Option<mpsc::Receiver<StreamItem>>,
        /// The producer thread, joined on drop.
        handle: Option<thread::JoinHandle<()>>,
    },
    /// Synchronous: assemble lazily in [`Iterator::next`].
    Sync {
        /// Dataset items are assembled from.
        ds: Arc<dyn Dataset>,
        /// Mini-batch index ranges for the epoch.
        plan: EpochPlan,
        /// Stamps each mini-batch's [`ExecutionPlan`].
        planner: Planner,
        /// Staging-buffer pool leases come from.
        pool: Arc<BufPool>,
        /// Plan of the mini-batch currently being split.
        current: Option<Arc<ExecutionPlan>>,
        /// Current mini-batch index.
        batch: usize,
        /// Current micro-batch index within the mini-batch.
        j: usize,
    },
}

/// Lease a staging buffer from `pool`, assemble micro-batch `j` into it and
/// time the assembly — the one hot-path assembly point both policies share.
fn assemble_pooled(
    pool: &BufPool,
    ds: &dyn Dataset,
    indices: &[usize],
    mu: usize,
    j: usize,
) -> (MicroBatchHost, Duration) {
    let t0 = Instant::now();
    let mut mb = pool.lease();
    loader::assemble_into(&mut mb, ds, indices, mu, j);
    (mb, t0.elapsed())
}

/// Start streaming an epoch: every mini-batch of `plan`, stamped with the
/// `planner`'s [`ExecutionPlan`] and split into micro-batches accordingly.
/// Staging buffers come from `pool`; the consumer is expected to
/// [`BufPool::give`] each one back once it is done with the payload.
pub fn stream_epoch(
    policy: StreamingPolicy,
    ds: Arc<dyn Dataset>,
    plan: EpochPlan,
    planner: Planner,
    prefetch: usize,
    pool: Arc<BufPool>,
) -> EpochStream {
    match policy {
        StreamingPolicy::DoubleBuffered => {
            let (tx, rx) = mpsc::sync_channel(prefetch.max(1));
            let handle = thread::Builder::new()
                .name("mbs-streamer".into())
                .spawn(move || {
                    'outer: for b in 0..plan.num_batches() {
                        let indices = plan.batch_indices(b);
                        let xplan = Arc::new(planner.plan_minibatch(indices.len()));
                        for j in 0..xplan.n_smu() {
                            // pad to the plan's static mu
                            let (mb, assemble) =
                                assemble_pooled(&pool, ds.as_ref(), indices, xplan.mu, j);
                            let item =
                                StreamItem { batch: b, plan: xplan.clone(), mb, assemble };
                            if tx.send(item).is_err() {
                                break 'outer; // consumer dropped early
                            }
                        }
                    }
                })
                .expect("spawn streamer thread");
            EpochStream::Buffered { rx: Some(rx), handle: Some(handle) }
        }
        StreamingPolicy::Synchronous => {
            EpochStream::Sync { ds, plan, planner, pool, current: None, batch: 0, j: 0 }
        }
    }
}

impl Iterator for EpochStream {
    type Item = StreamItem;

    fn next(&mut self) -> Option<StreamItem> {
        match self {
            EpochStream::Buffered { rx, .. } => rx.as_ref()?.recv().ok(),
            EpochStream::Sync { ds, plan, planner, pool, current, batch, j } => {
                if *batch >= plan.num_batches() {
                    return None;
                }
                let indices = plan.batch_indices(*batch);
                let xplan = current
                    .get_or_insert_with(|| Arc::new(planner.plan_minibatch(indices.len())))
                    .clone();
                // pad to the plan's static mu
                let (mb, assemble) =
                    assemble_pooled(pool, ds.as_ref(), indices, xplan.mu, *j);
                let item = StreamItem { batch: *batch, plan: xplan.clone(), mb, assemble };
                *j += 1;
                if *j >= xplan.n_smu() {
                    *j = 0;
                    *batch += 1;
                    *current = None;
                }
                Some(item)
            }
        }
    }
}

impl Drop for EpochStream {
    fn drop(&mut self) {
        if let EpochStream::Buffered { rx, handle } = self {
            // Drop the receiver FIRST: this disconnects the channel, so a
            // producer parked on a full `send` (or about to send) errors out
            // and exits instead of racing a drain loop that can fill back
            // up between the last `try_recv` and the join.
            drop(rx.take());
            if let Some(h) = handle.take() {
                let _ = h.join();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::accumulator::NormalizationMode;
    use crate::coordinator::splitter::SplitPlan;
    use crate::data::SynthFlowers;

    fn planner(mu: usize) -> Planner {
        Planner::new(mu, false, NormalizationMode::Paper)
    }

    fn pool() -> Arc<BufPool> {
        Arc::new(BufPool::for_prefetch(2))
    }

    fn collect(
        policy: StreamingPolicy,
        ds_len: usize,
        batch: usize,
        mu: usize,
    ) -> Vec<(usize, usize, usize)> {
        let ds: Arc<dyn Dataset> = Arc::new(SynthFlowers::new(8, 10, ds_len, 3));
        let plan = EpochPlan::new(ds_len, batch, 1, 0);
        stream_epoch(policy, ds, plan, planner(mu), 2, pool())
            .map(|item| (item.batch, item.mb.j, item.mb.actual))
            .collect()
    }

    #[test]
    fn policies_yield_identical_streams() {
        let a = collect(StreamingPolicy::DoubleBuffered, 50, 16, 8);
        let b = collect(StreamingPolicy::Synchronous, 50, 16, 8);
        assert_eq!(a, b);
    }

    #[test]
    fn covers_all_microbatches_with_ragged_tail() {
        // 50 items, batch 16 -> batches of 16,16,16,2; mu=8 ->
        // 2+2+2+1 = 7 micro-batches; final one has 2 actual samples
        let items = collect(StreamingPolicy::Synchronous, 50, 16, 8);
        assert_eq!(items.len(), 7);
        assert_eq!(items[6], (3, 0, 2));
        let total: usize = items.iter().map(|&(_, _, a)| a).sum();
        assert_eq!(total, 50);
    }

    #[test]
    fn payloads_identical_across_policies() {
        let ds: Arc<dyn Dataset> = Arc::new(SynthFlowers::new(8, 10, 40, 3));
        let plan = EpochPlan::new(40, 12, 1, 0);
        let a: Vec<_> = stream_epoch(
            StreamingPolicy::DoubleBuffered,
            ds.clone(),
            plan.clone(),
            planner(8),
            2,
            pool(),
        )
        .collect();
        let b: Vec<_> =
            stream_epoch(StreamingPolicy::Synchronous, ds.clone(), plan.clone(), planner(8), 2, pool())
                .collect();
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.mb.x, y.mb.x);
            assert_eq!(x.mb.y, y.mb.y);
            assert_eq!(x.mb.mask, y.mb.mask);
            assert_eq!(x.plan, y.plan);
        }
        // and the pooled stream is byte-identical to the fresh-allocation
        // path (`loader::assemble`), dirty recycled buffers included
        for item in &a {
            let indices = plan.batch_indices(item.batch);
            let fresh = loader::assemble(ds.as_ref(), indices, item.plan.mu, item.mb.j);
            assert_eq!(item.mb.x, fresh.x);
            assert_eq!(item.mb.y, fresh.y);
            assert_eq!(item.mb.mask, fresh.mask);
            assert_eq!(item.mb.actual, fresh.actual);
        }
    }

    #[test]
    fn recycled_epoch_allocates_nothing_and_stays_identical() {
        // epoch 1 warms the pool; epoch 2 must run entirely on recycled
        // buffers (allocs delta == 0) and still yield identical payloads.
        // The consumer mirrors the executor: each buffer goes back through
        // the return channel as soon as its payload has been consumed.
        let ds: Arc<dyn Dataset> = Arc::new(SynthFlowers::new(8, 10, 40, 3));
        let plan = EpochPlan::new(40, 12, 1, 0);
        let shared = pool();
        let run = |p: &Arc<BufPool>| -> Vec<MicroBatchHost> {
            let mut out = Vec::new();
            for item in stream_epoch(
                StreamingPolicy::Synchronous,
                ds.clone(),
                plan.clone(),
                planner(8),
                2,
                p.clone(),
            ) {
                out.push(item.mb.clone());
                p.give(item.mb);
            }
            out
        };
        let payload1 = run(&shared);
        let after_epoch1 = shared.stats();
        assert!(after_epoch1.allocs > 0, "cold epoch must have allocated");
        let payload2 = run(&shared);
        let after_epoch2 = shared.stats();
        assert_eq!(
            after_epoch2.allocs, after_epoch1.allocs,
            "steady-state epoch performed host-buffer allocations"
        );
        assert_eq!(
            after_epoch2.hits - after_epoch1.hits,
            payload2.len() as u64,
            "every steady-state lease must be a pool hit"
        );
        assert_eq!(payload1.len(), payload2.len());
        for (a, b) in payload1.iter().zip(&payload2) {
            assert_eq!(a.x, b.x);
            assert_eq!(a.y, b.y);
            assert_eq!(a.mask, b.mask);
            assert_eq!(a.actual, b.actual);
            assert_eq!(a.j, b.j);
        }
    }

    #[test]
    fn fixed_plan_stream_matches_legacy_assembly() {
        // the plan-driven stream must be byte-identical to the pre-planner
        // loop: SplitPlan::new per mini-batch + assemble(.., mu, j)
        let (ds_len, batch, mu) = (50usize, 16usize, 8usize);
        let ds: Arc<dyn Dataset> = Arc::new(SynthFlowers::new(8, 10, ds_len, 3));
        let plan = EpochPlan::new(ds_len, batch, 1, 0);
        let streamed: Vec<_> = stream_epoch(
            StreamingPolicy::Synchronous,
            ds.clone(),
            plan.clone(),
            planner(mu),
            2,
            pool(),
        )
        .collect();
        let mut legacy = Vec::new();
        for b in 0..plan.num_batches() {
            let indices = plan.batch_indices(b);
            let split = SplitPlan::new(indices.len(), mu);
            for j in 0..split.n_smu() {
                legacy.push((b, split.clone(), loader::assemble(ds.as_ref(), indices, mu, j)));
            }
        }
        assert_eq!(streamed.len(), legacy.len());
        for (item, (b, split, mb)) in streamed.iter().zip(&legacy) {
            assert_eq!(item.batch, *b);
            assert_eq!(&item.plan.split, split);
            assert_eq!(item.mb.x, mb.x);
            assert_eq!(item.mb.y, mb.y);
            assert_eq!(item.mb.mask, mb.mask);
            assert_eq!(item.mb.actual, mb.actual);
            assert_eq!(item.mb.j, mb.j);
        }
    }

    #[test]
    fn early_drop_does_not_hang() {
        let ds: Arc<dyn Dataset> = Arc::new(SynthFlowers::new(8, 10, 1000, 3));
        let plan = EpochPlan::new(1000, 32, 1, 0);
        let mut s = stream_epoch(StreamingPolicy::DoubleBuffered, ds, plan, planner(16), 2, pool());
        let _ = s.next();
        drop(s); // must join cleanly, not deadlock
    }

    #[test]
    fn early_drop_with_producer_blocked_on_full_channel_does_not_hang() {
        // prefetch=1 bounds the channel at one item; with nothing consumed
        // the producer fills it and parks inside `send` — dropping the
        // stream must disconnect and join rather than deadlock
        let ds: Arc<dyn Dataset> = Arc::new(SynthFlowers::new(8, 10, 1000, 3));
        let plan = EpochPlan::new(1000, 32, 1, 0);
        let s = stream_epoch(StreamingPolicy::DoubleBuffered, ds, plan, planner(16), 1, pool());
        // give the producer time to fill the channel and block on the next send
        std::thread::sleep(std::time::Duration::from_millis(50));
        drop(s);
    }

    #[test]
    fn early_drop_with_outstanding_leases_joins_cleanly() {
        // the consumer still holds leased buffers (never returned) when the
        // stream is dropped mid-epoch: the producer — possibly parked on a
        // full channel, leasing from a now-starved pool — must still exit,
        // and late returns after the join must not corrupt the pool
        let ds: Arc<dyn Dataset> = Arc::new(SynthFlowers::new(8, 10, 1000, 3));
        let plan = EpochPlan::new(1000, 32, 1, 0);
        let p = pool();
        let mut s =
            stream_epoch(StreamingPolicy::DoubleBuffered, ds, plan, planner(16), 1, p.clone());
        let held: Vec<_> = (0..2).filter_map(|_| s.next()).collect();
        drop(s); // must join, not deadlock, despite outstanding leases
        let before = p.stats();
        assert_eq!(before.returns, 0);
        for item in held {
            p.give(item.mb); // returning after the stream died is fine
        }
        let after = p.stats();
        assert_eq!(after.returns, 2);
        assert!(p.retained() >= 2);
    }
}
