//! Stream-based pipeline (paper section 3.1, fig. 1): micro-batches are
//! assembled on the host and streamed to the device in sequence.
//!
//! Policies:
//!  * [`StreamingPolicy::DoubleBuffered`] — a worker thread assembles the
//!    next micro-batch(es) while the runtime thread executes the current
//!    one, over a bounded channel (the CUDA-stream copy/compute overlap of
//!    the paper, expressed with std threads since the device here is the
//!    PJRT CPU client).
//!  * [`StreamingPolicy::Synchronous`] — assemble inline on the runtime
//!    thread; the ablation baseline (A2) that quantifies what the overlap
//!    buys.
//!
//! Every item carries the [`ExecutionPlan`] of its mini-batch (computed
//! once, on the producing side, by the [`Planner`]) so the consumer never
//! re-derives split geometry or normalization scales — the plan is the
//! single source of truth shared across the thread boundary.
//!
//! The bounded channel *is* the memory backpressure: at most `prefetch`
//! assembled micro-batches exist beyond the one executing, so host staging
//! memory is bounded by `(prefetch + 1) * mu * sample_bytes`.

use std::sync::mpsc;
use std::sync::Arc;
use std::thread;

use crate::data::{loader, Dataset, EpochPlan, MicroBatchHost};

use super::planner::{ExecutionPlan, Planner};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StreamingPolicy {
    DoubleBuffered,
    Synchronous,
}

impl StreamingPolicy {
    pub fn parse(s: &str) -> Option<StreamingPolicy> {
        match s {
            "double-buffered" | "double_buffered" | "async" => {
                Some(StreamingPolicy::DoubleBuffered)
            }
            "synchronous" | "sync" => Some(StreamingPolicy::Synchronous),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            StreamingPolicy::DoubleBuffered => "double-buffered",
            StreamingPolicy::Synchronous => "synchronous",
        }
    }
}

/// One streamed micro-batch, tagged with its mini-batch's execution plan.
#[derive(Debug)]
pub struct StreamItem {
    /// Mini-batch index within the epoch.
    pub batch: usize,
    /// The plan governing this micro-batch's mini-batch (shared across all
    /// of its micro-batches).
    pub plan: Arc<ExecutionPlan>,
    pub mb: MicroBatchHost,
}

/// Iterator over every micro-batch of an epoch under a streaming policy.
pub enum EpochStream {
    Buffered {
        /// `Some` until dropped; taken (disconnecting the producer) before
        /// the join in `Drop`.
        rx: Option<mpsc::Receiver<StreamItem>>,
        handle: Option<thread::JoinHandle<()>>,
    },
    Sync {
        ds: Arc<dyn Dataset>,
        plan: EpochPlan,
        planner: Planner,
        current: Option<Arc<ExecutionPlan>>,
        batch: usize,
        j: usize,
    },
}

/// Start streaming an epoch: every mini-batch of `plan`, stamped with the
/// `planner`'s [`ExecutionPlan`] and split into micro-batches accordingly.
pub fn stream_epoch(
    policy: StreamingPolicy,
    ds: Arc<dyn Dataset>,
    plan: EpochPlan,
    planner: Planner,
    prefetch: usize,
) -> EpochStream {
    match policy {
        StreamingPolicy::DoubleBuffered => {
            let (tx, rx) = mpsc::sync_channel(prefetch.max(1));
            let handle = thread::Builder::new()
                .name("mbs-streamer".into())
                .spawn(move || {
                    'outer: for b in 0..plan.num_batches() {
                        let indices = plan.batch_indices(b);
                        let xplan = Arc::new(planner.plan_minibatch(indices.len()));
                        for j in 0..xplan.n_smu() {
                            // pad to the plan's static mu
                            let mb = loader::assemble(ds.as_ref(), indices, xplan.mu, j);
                            let item = StreamItem { batch: b, plan: xplan.clone(), mb };
                            if tx.send(item).is_err() {
                                break 'outer; // consumer dropped early
                            }
                        }
                    }
                })
                .expect("spawn streamer thread");
            EpochStream::Buffered { rx: Some(rx), handle: Some(handle) }
        }
        StreamingPolicy::Synchronous => {
            EpochStream::Sync { ds, plan, planner, current: None, batch: 0, j: 0 }
        }
    }
}

impl Iterator for EpochStream {
    type Item = StreamItem;

    fn next(&mut self) -> Option<StreamItem> {
        match self {
            EpochStream::Buffered { rx, .. } => rx.as_ref()?.recv().ok(),
            EpochStream::Sync { ds, plan, planner, current, batch, j } => {
                if *batch >= plan.num_batches() {
                    return None;
                }
                let indices = plan.batch_indices(*batch);
                let xplan = current
                    .get_or_insert_with(|| Arc::new(planner.plan_minibatch(indices.len())))
                    .clone();
                // pad to the plan's static mu
                let mb = loader::assemble(ds.as_ref(), indices, xplan.mu, *j);
                let item = StreamItem { batch: *batch, plan: xplan.clone(), mb };
                *j += 1;
                if *j >= xplan.n_smu() {
                    *j = 0;
                    *batch += 1;
                    *current = None;
                }
                Some(item)
            }
        }
    }
}

impl Drop for EpochStream {
    fn drop(&mut self) {
        if let EpochStream::Buffered { rx, handle } = self {
            // Drop the receiver FIRST: this disconnects the channel, so a
            // producer parked on a full `send` (or about to send) errors out
            // and exits instead of racing a drain loop that can fill back
            // up between the last `try_recv` and the join.
            drop(rx.take());
            if let Some(h) = handle.take() {
                let _ = h.join();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::accumulator::NormalizationMode;
    use crate::coordinator::splitter::SplitPlan;
    use crate::data::SynthFlowers;

    fn planner(mu: usize) -> Planner {
        Planner::new(mu, false, NormalizationMode::Paper)
    }

    fn collect(
        policy: StreamingPolicy,
        ds_len: usize,
        batch: usize,
        mu: usize,
    ) -> Vec<(usize, usize, usize)> {
        let ds: Arc<dyn Dataset> = Arc::new(SynthFlowers::new(8, 10, ds_len, 3));
        let plan = EpochPlan::new(ds_len, batch, 1, 0);
        stream_epoch(policy, ds, plan, planner(mu), 2)
            .map(|item| (item.batch, item.mb.j, item.mb.actual))
            .collect()
    }

    #[test]
    fn policies_yield_identical_streams() {
        let a = collect(StreamingPolicy::DoubleBuffered, 50, 16, 8);
        let b = collect(StreamingPolicy::Synchronous, 50, 16, 8);
        assert_eq!(a, b);
    }

    #[test]
    fn covers_all_microbatches_with_ragged_tail() {
        // 50 items, batch 16 -> batches of 16,16,16,2; mu=8 ->
        // 2+2+2+1 = 7 micro-batches; final one has 2 actual samples
        let items = collect(StreamingPolicy::Synchronous, 50, 16, 8);
        assert_eq!(items.len(), 7);
        assert_eq!(items[6], (3, 0, 2));
        let total: usize = items.iter().map(|&(_, _, a)| a).sum();
        assert_eq!(total, 50);
    }

    #[test]
    fn payloads_identical_across_policies() {
        let ds: Arc<dyn Dataset> = Arc::new(SynthFlowers::new(8, 10, 40, 3));
        let plan = EpochPlan::new(40, 12, 1, 0);
        let a: Vec<_> =
            stream_epoch(StreamingPolicy::DoubleBuffered, ds.clone(), plan.clone(), planner(8), 2)
                .collect();
        let b: Vec<_> =
            stream_epoch(StreamingPolicy::Synchronous, ds, plan, planner(8), 2).collect();
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.mb.x, y.mb.x);
            assert_eq!(x.mb.y, y.mb.y);
            assert_eq!(x.mb.mask, y.mb.mask);
            assert_eq!(x.plan, y.plan);
        }
    }

    #[test]
    fn fixed_plan_stream_matches_legacy_assembly() {
        // the plan-driven stream must be byte-identical to the pre-planner
        // loop: SplitPlan::new per mini-batch + assemble(.., mu, j)
        let (ds_len, batch, mu) = (50usize, 16usize, 8usize);
        let ds: Arc<dyn Dataset> = Arc::new(SynthFlowers::new(8, 10, ds_len, 3));
        let plan = EpochPlan::new(ds_len, batch, 1, 0);
        let streamed: Vec<_> =
            stream_epoch(StreamingPolicy::Synchronous, ds.clone(), plan.clone(), planner(mu), 2)
                .collect();
        let mut legacy = Vec::new();
        for b in 0..plan.num_batches() {
            let indices = plan.batch_indices(b);
            let split = SplitPlan::new(indices.len(), mu);
            for j in 0..split.n_smu() {
                legacy.push((b, split.clone(), loader::assemble(ds.as_ref(), indices, mu, j)));
            }
        }
        assert_eq!(streamed.len(), legacy.len());
        for (item, (b, split, mb)) in streamed.iter().zip(&legacy) {
            assert_eq!(item.batch, *b);
            assert_eq!(&item.plan.split, split);
            assert_eq!(item.mb.x, mb.x);
            assert_eq!(item.mb.y, mb.y);
            assert_eq!(item.mb.mask, mb.mask);
            assert_eq!(item.mb.actual, mb.actual);
            assert_eq!(item.mb.j, mb.j);
        }
    }

    #[test]
    fn early_drop_does_not_hang() {
        let ds: Arc<dyn Dataset> = Arc::new(SynthFlowers::new(8, 10, 1000, 3));
        let plan = EpochPlan::new(1000, 32, 1, 0);
        let mut s = stream_epoch(StreamingPolicy::DoubleBuffered, ds, plan, planner(16), 2);
        let _ = s.next();
        drop(s); // must join cleanly, not deadlock
    }

    #[test]
    fn early_drop_with_producer_blocked_on_full_channel_does_not_hang() {
        // prefetch=1 bounds the channel at one item; with nothing consumed
        // the producer fills it and parks inside `send` — dropping the
        // stream must disconnect and join rather than deadlock
        let ds: Arc<dyn Dataset> = Arc::new(SynthFlowers::new(8, 10, 1000, 3));
        let plan = EpochPlan::new(1000, 32, 1, 0);
        let s = stream_epoch(StreamingPolicy::DoubleBuffered, ds, plan, planner(16), 1);
        // give the producer time to fill the channel and block on the next send
        std::thread::sleep(std::time::Duration::from_millis(50));
        drop(s);
    }
}
