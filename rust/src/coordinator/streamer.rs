//! Stream-based pipeline (paper section 3.1, fig. 1): micro-batches are
//! assembled on the host and streamed to the device in sequence.
//!
//! Policies:
//!  * [`StreamingPolicy::DoubleBuffered`] — a worker thread assembles the
//!    next micro-batch(es) while the runtime thread executes the current
//!    one, over a bounded channel (the CUDA-stream copy/compute overlap of
//!    the paper, expressed with std threads since the device here is the
//!    PJRT CPU client).
//!  * [`StreamingPolicy::Synchronous`] — assemble inline on the runtime
//!    thread; the ablation baseline (A2) that quantifies what the overlap
//!    buys.
//!
//! The bounded channel *is* the memory backpressure: at most `prefetch`
//! assembled micro-batches exist beyond the one executing, so host staging
//! memory is bounded by `(prefetch + 1) * mu * sample_bytes`.

use std::sync::mpsc;
use std::sync::Arc;
use std::thread;

use crate::data::{loader, Dataset, EpochPlan, MicroBatchHost};

use super::splitter::SplitPlan;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StreamingPolicy {
    DoubleBuffered,
    Synchronous,
}

impl StreamingPolicy {
    pub fn parse(s: &str) -> Option<StreamingPolicy> {
        match s {
            "double-buffered" | "double_buffered" | "async" => {
                Some(StreamingPolicy::DoubleBuffered)
            }
            "synchronous" | "sync" => Some(StreamingPolicy::Synchronous),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            StreamingPolicy::DoubleBuffered => "double-buffered",
            StreamingPolicy::Synchronous => "synchronous",
        }
    }
}

/// One streamed micro-batch, tagged with its position in the epoch.
#[derive(Debug)]
pub struct StreamItem {
    /// Mini-batch index within the epoch.
    pub batch: usize,
    /// Mini-batch sample count (for split-plan reconstruction).
    pub n_b: usize,
    pub mb: MicroBatchHost,
}

/// Iterator over every micro-batch of an epoch under a streaming policy.
pub enum EpochStream {
    Buffered {
        rx: mpsc::Receiver<StreamItem>,
        handle: Option<thread::JoinHandle<()>>,
    },
    Sync {
        ds: Arc<dyn Dataset>,
        plan: EpochPlan,
        mu: usize,
        batch: usize,
        j: usize,
    },
}

/// Start streaming an epoch: every mini-batch of `plan`, split into
/// micro-batches of (at most) `mu`, in order.
pub fn stream_epoch(
    policy: StreamingPolicy,
    ds: Arc<dyn Dataset>,
    plan: EpochPlan,
    mu: usize,
    prefetch: usize,
) -> EpochStream {
    match policy {
        StreamingPolicy::DoubleBuffered => {
            let (tx, rx) = mpsc::sync_channel(prefetch.max(1));
            let handle = thread::Builder::new()
                .name("mbs-streamer".into())
                .spawn(move || {
                    'outer: for b in 0..plan.num_batches() {
                        let indices = plan.batch_indices(b);
                        let split = SplitPlan::new(indices.len(), mu);
                        for j in 0..split.n_smu() {
                            let mb = loader::assemble(ds.as_ref(), indices, mu, j); // pad to static mu
                            let item = StreamItem { batch: b, n_b: indices.len(), mb };
                            if tx.send(item).is_err() {
                                break 'outer; // consumer dropped early
                            }
                        }
                    }
                })
                .expect("spawn streamer thread");
            EpochStream::Buffered { rx, handle: Some(handle) }
        }
        StreamingPolicy::Synchronous => {
            EpochStream::Sync { ds, plan, mu, batch: 0, j: 0 }
        }
    }
}

impl Iterator for EpochStream {
    type Item = StreamItem;

    fn next(&mut self) -> Option<StreamItem> {
        match self {
            EpochStream::Buffered { rx, .. } => rx.recv().ok(),
            EpochStream::Sync { ds, plan, mu, batch, j } => {
                if *batch >= plan.num_batches() {
                    return None;
                }
                let indices = plan.batch_indices(*batch);
                let split = SplitPlan::new(indices.len(), *mu);
                let mb = loader::assemble(ds.as_ref(), indices, *mu, *j); // pad to static mu
                let item = StreamItem { batch: *batch, n_b: indices.len(), mb };
                *j += 1;
                if *j >= split.n_smu() {
                    *j = 0;
                    *batch += 1;
                }
                Some(item)
            }
        }
    }
}

impl Drop for EpochStream {
    fn drop(&mut self) {
        if let EpochStream::Buffered { rx, handle } = self {
            // unblock the producer if the consumer stopped early
            while rx.try_recv().is_ok() {}
            drop(std::mem::replace(rx, mpsc::sync_channel(1).1));
            if let Some(h) = handle.take() {
                let _ = h.join();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SynthFlowers;

    fn collect(policy: StreamingPolicy, ds_len: usize, batch: usize, mu: usize) -> Vec<(usize, usize, usize)> {
        let ds: Arc<dyn Dataset> = Arc::new(SynthFlowers::new(8, 10, ds_len, 3));
        let plan = EpochPlan::new(ds_len, batch, 1, 0);
        stream_epoch(policy, ds, plan, mu, 2)
            .map(|item| (item.batch, item.mb.j, item.mb.actual))
            .collect()
    }

    #[test]
    fn policies_yield_identical_streams() {
        let a = collect(StreamingPolicy::DoubleBuffered, 50, 16, 8);
        let b = collect(StreamingPolicy::Synchronous, 50, 16, 8);
        assert_eq!(a, b);
    }

    #[test]
    fn covers_all_microbatches_with_ragged_tail() {
        // 50 items, batch 16 -> batches of 16,16,16,2; mu=8 ->
        // 2+2+2+1 = 7 micro-batches; final one has 2 actual samples
        let items = collect(StreamingPolicy::Synchronous, 50, 16, 8);
        assert_eq!(items.len(), 7);
        assert_eq!(items[6], (3, 0, 2));
        let total: usize = items.iter().map(|&(_, _, a)| a).sum();
        assert_eq!(total, 50);
    }

    #[test]
    fn payloads_identical_across_policies() {
        let ds: Arc<dyn Dataset> = Arc::new(SynthFlowers::new(8, 10, 40, 3));
        let plan = EpochPlan::new(40, 12, 1, 0);
        let a: Vec<_> =
            stream_epoch(StreamingPolicy::DoubleBuffered, ds.clone(), plan.clone(), 8, 2).collect();
        let b: Vec<_> = stream_epoch(StreamingPolicy::Synchronous, ds, plan, 8, 2).collect();
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.mb.x, y.mb.x);
            assert_eq!(x.mb.y, y.mb.y);
            assert_eq!(x.mb.mask, y.mb.mask);
        }
    }

    #[test]
    fn early_drop_does_not_hang() {
        let ds: Arc<dyn Dataset> = Arc::new(SynthFlowers::new(8, 10, 1000, 3));
        let plan = EpochPlan::new(1000, 32, 1, 0);
        let mut s = stream_epoch(StreamingPolicy::DoubleBuffered, ds, plan, 16, 2);
        let _ = s.next();
        drop(s); // must join cleanly, not deadlock
    }
}
