//! The unified, plan-driven epoch executor (paper fig. 2).
//!
//! There is exactly ONE epoch loop: `run_epoch` consumes
//! [`ExecutionPlan`](super::planner::ExecutionPlan)-tagged micro-batches
//! from the streamer and drives the runtime. The three historical variants
//! are all parameterizations of it:
//!
//!   MBS    ("w/ MBS") : N_Smu accumulation steps of mu samples, loss-
//!                       normalized, optimizer update after the last one
//!   native ("w/o MBS"): the degenerate plan — one step with N_B samples
//!                       (`N_Smu = 1`); OOMs past the memory frontier
//!   eval              : the same streamed sweep with `eval_step` and no
//!                       updates
//!
//! That identity is what makes the with/without comparison of the paper's
//! tables apples-to-apples, and it is what the grad-equivalence integration
//! test checks end-to-end. The memory [`Ledger`] is charged for every step
//! the executor runs, so a plan that would exceed capacity fails loudly at
//! the exact step — not just at admission time.
//!
//! With `overlap` on (the default) the loop runs as a two-stage pipeline:
//! each arriving micro-batch is *staged* (uploaded into the runtime's idle
//! ping-pong slot, its staging buffer returned to the pool at
//! upload-completion) before the previously staged one executes, so the
//! upload of step `j+1` rides in the in-flight window of step `j` and is
//! attributed to `StageTimers::upload_hidden`. The host half of that
//! staging runs on a dedicated [`UploadLane`] thread: each micro-batch is
//! submitted to the lane immediately before the previous step's execute,
//! so the lane's pinned-staging copy rides *inside* the execute window in
//! real wall-clock time — the lane's `Instant` windows are intersected
//! with the runtime's execute windows and attributed to
//! `StageTimers::upload_concurrent` (the numerator of
//! `wall_overlap_efficiency`). The ledger carries the second staged input
//! slot as its own allocation ([`Footprint::overlap_bytes`]), so
//! mid-pipeline residency is asserted exactly. `--overlap off` keeps the
//! serial loop as the byte-identity oracle — both orders run the
//! identical device-op sequence, so losses and metrics match bit for bit.
//!
//! Solo [`train`] is the one-tenant special case of the interleaved
//! multi-job executor: it builds a single [`JobExec`] over a one-slot
//! arena and drives it to completion, so solo/interleaved bit-identity is
//! structural (one state machine) rather than an oracle-checked accident.

use std::collections::{BTreeMap, VecDeque};
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::config::TrainConfig;
use crate::data::{BufPool, Dataset, EpochPlan, PoolStats, SynthCarvana, SynthFlowers, SynthText};
use crate::error::{MbsError, Result};
use crate::manifest::ModelEntry;
use crate::memory::ledger::AllocId;
use crate::memory::{Arena, FleetSpec, Footprint, Ledger, MemoryModel};
use crate::metrics::{EpochStats, MetricKind, StageTimers};
use crate::runtime::{
    Engine, FaultHooks, FaultKind, FaultPlan, LaneJob, ModelRuntime, StallSurface, Surface,
    UploadLane, Watchdog,
};
use crate::util::hash::{fnv1a64, fraction};

use super::accumulator::{Accumulation, NormalizationMode};
use super::planner::{self, ExecutionPlan, Planner, Resolution};
use super::scheduler::UpdateScheduler;
use super::splitter::ShardPlan;
use super::streamer::{stream_epoch, EpochStream, StreamItem, StreamingPolicy};
use super::tenancy::{self, AdmissionOutcome, AdmissionRequest, JobSet, JobSpec};

/// Everything a finished run reports (feeds the tables and figures).
#[derive(Debug, Clone)]
pub struct TrainReport {
    /// Model key the run trained.
    pub model: String,
    /// Whether the MBS arm (true) or the native baseline (false) ran.
    pub use_mbs: bool,
    /// Mini-batch size `N_B`.
    pub batch: usize,
    /// The micro-batch size the run executed with — planner-derived under
    /// `MicroBatchSpec::Auto`, the pinned value under `Fixed`.
    pub mu: usize,
    /// Per-epoch training stats, in order.
    pub train_epochs: Vec<EpochStats>,
    /// Per-epoch eval stats (empty when `skip_eval` is set).
    pub eval_epochs: Vec<EpochStats>,
    /// The last (or only) eval pass.
    pub final_eval: EpochStats,
    /// Wall-clock for the whole run.
    pub total_wall: Duration,
    /// Mean wall-clock per training epoch (the paper's "training time" column).
    pub epoch_wall_mean: Duration,
    /// Largest batch the native path could have trained at this capacity.
    pub native_max_batch: usize,
    /// Simulated device capacity the run was admitted against.
    pub capacity_bytes: u64,
    /// PJRT output convention detected at runtime (diagnostic).
    pub output_mode: String,
    /// Optimizer updates applied.
    pub updates: u64,
    /// Per-stage time summed over the training epochs (each epoch's own
    /// breakdown lives in its [`EpochStats::stages`]); under overlap,
    /// `stages.overlap_efficiency()` is the fraction of upload time the
    /// pipeline hid behind execution.
    pub stages: StageTimers,
    /// Host staging-buffer pool traffic for the whole run — `allocs` stays
    /// at the warm-up count when the hot path is allocation-free.
    pub pool: PoolStats,
    /// Did the run use the overlapped upload/execute pipeline?
    pub overlap: bool,
    /// The prefetch depth the run ended on: the configured value, or —
    /// under `--prefetch auto` — the `StageTimers`-tuned choice.
    pub prefetch: usize,
    /// High-water mark of simulated device residency over the whole run
    /// (resident state + in-flight inputs + executing step), bytes.
    pub ledger_peak_bytes: u64,
}

impl TrainReport {
    /// Best (max) eval primary metric across epochs — the paper reports
    /// "maximum accuracy/IoU".
    pub fn best_metric(&self) -> f64 {
        self.eval_epochs
            .iter()
            .map(|e| e.primary_metric)
            .fold(self.final_eval.primary_metric, f64::max)
    }
}

/// Build the task-appropriate synthetic datasets for a config.
pub fn datasets_for(
    task: &str,
    size: usize,
    cfg: &TrainConfig,
) -> Result<(Arc<dyn Dataset>, Arc<dyn Dataset>)> {
    let train_seed = cfg.seed.wrapping_mul(2).wrapping_add(1);
    let eval_seed = cfg.seed.wrapping_mul(2).wrapping_add(2);
    Ok(match task {
        "classification" => (
            Arc::new(SynthFlowers::new(size, cfg.num_classes, cfg.dataset_len, train_seed)),
            Arc::new(SynthFlowers::new(size, cfg.num_classes, cfg.eval_len, eval_seed)),
        ),
        "segmentation" => (
            Arc::new(SynthCarvana::new(size, cfg.dataset_len, train_seed)),
            Arc::new(SynthCarvana::new(size, cfg.eval_len, eval_seed)),
        ),
        "lm" => (
            Arc::new(SynthText::new(512, size, cfg.dataset_len, train_seed)),
            Arc::new(SynthText::new(512, size, cfg.eval_len, eval_seed)),
        ),
        other => return Err(MbsError::Config(format!("unknown task '{other}'"))),
    })
}

/// How many staging copies the upload lane may hold in flight. The
/// pipeline keeps at most one micro-batch in the lane between turns (the
/// one submitted right before each execute), so 2 leaves slack without
/// letting the lane run ahead of the ledger's two-input-slot budget.
const LANE_DEPTH: usize = 2;

/// What one pass through the data does with each micro-batch.
#[derive(Clone, Copy)]
enum Pass<'a> {
    /// Accumulate gradients; optimizer update after each mini-batch's last
    /// micro-batch (fig. 2 step 5).
    Train { sched: &'a UpdateScheduler },
    /// Masked, padded metric sweep; never touches gradients or params.
    Eval,
}

/// How the epoch executor moves data: streaming policy + prefetch depth on
/// the host side, upload/execute overlap on the device side.
#[derive(Clone, Copy)]
struct PipelineCfg {
    /// Assemble inline or on the streamer worker thread.
    policy: StreamingPolicy,
    /// Micro-batches staged ahead in the streamer channel.
    prefetch: usize,
    /// Two-stage upload/execute pipeline (device double-buffer) on/off.
    overlap: bool,
}

/// A staged-but-not-executed micro-batch in the overlapped pipeline: its
/// plan position plus the ledger allocation covering its device input slot.
struct InFlight {
    plan: Arc<ExecutionPlan>,
    j: usize,
    actual: usize,
    inputs: AllocId,
}

/// Execute one serially-fused micro-batch (stage + execute in one call,
/// one input slot live at a time): charge the ledger for the step's
/// residency, run it, fold the result into `acc`, recycle the staging
/// buffer, and fire the optimizer update when this was its mini-batch's
/// last micro-batch. Shared by the serial arm of [`run_epoch`] and the
/// interleaved multi-job executor ([`train_jobs`]), so the two paths can
/// never drift — which is what makes per-job reports bit-identical to
/// solo runs.
fn exec_serial_item(
    rt: &mut ModelRuntime,
    ledger: &mut Ledger,
    fp: &Footprint,
    pass: Pass<'_>,
    acc: &mut Accumulation,
    pool: &BufPool,
    item: StreamItem,
) -> Result<()> {
    let StreamItem { plan, mb, .. } = item;
    // training holds activations for the backward pass; eval is
    // forward-only and holds just the input buffers
    let (tag, bytes) = match pass {
        Pass::Train { .. } => ("train step", fp.batch_bytes(plan.device_samples())),
        Pass::Eval => ("eval step", fp.eval_bytes(plan.device_samples())),
    };
    let step = ledger.alloc(tag, bytes)?;
    let out = match pass {
        Pass::Train { .. } => rt.accum_step(&mb, plan.scales[mb.j])?,
        Pass::Eval => rt.eval_step(&mb)?,
    };
    ledger.free(step)?;
    acc.add(&out, mb.actual);
    let update_due = matches!(pass, Pass::Train { .. }) && plan.is_last(mb.j);
    // upload done: recycle the staging buffer before the (potentially
    // long) optimizer update
    pool.give(mb);
    if update_due {
        if let Pass::Train { sched } = pass {
            rt.apply(&sched.hyper_for(rt.updates))?;
        }
    }
    Ok(())
}

/// Execute the oldest staged micro-batch: charge the ledger for what the
/// step holds *beyond* its already-live input slot (backward-pass
/// activations; eval holds inputs only), run it, release both residencies,
/// fold the result into `acc`, and fire the optimizer update when this was
/// its mini-batch's last micro-batch.
fn step_in_flight(
    rt: &mut ModelRuntime,
    ledger: &mut Ledger,
    fp: &Footprint,
    pass: Pass<'_>,
    acc: &mut Accumulation,
    current: InFlight,
) -> Result<()> {
    let out = match pass {
        Pass::Train { .. } => {
            let act = ledger.alloc(
                "train step activations",
                fp.activation_bytes(current.plan.device_samples()),
            )?;
            let out = rt.accum_staged()?;
            ledger.free(act)?;
            out
        }
        Pass::Eval => rt.eval_staged()?,
    };
    ledger.free(current.inputs)?;
    acc.add(&out, current.actual);
    if let Pass::Train { sched } = pass {
        if current.plan.is_last(current.j) {
            rt.apply(&sched.hyper_for(rt.updates))?;
        }
    }
    Ok(())
}

/// Hand one stream item to the upload-lane thread. Called immediately
/// before the previous step's execute, so the lane's pinned-staging copy
/// runs while the device works — that concurrency is what
/// `StageTimers::upload_concurrent` measures. The plan rides a host-side
/// FIFO (the lane only sees host buffers); [`place_staged`] re-pairs it
/// with the staged copy by position.
#[allow(clippy::too_many_arguments)]
fn submit_to_lane(
    lane: &mut UploadLane,
    queue: &mut VecDeque<Arc<ExecutionPlan>>,
    seq: &mut u64,
    pass: Pass<'_>,
    item: StreamItem,
    fault: Option<String>,
    stall: Option<Duration>,
) -> Result<()> {
    let StreamItem { plan, mb, .. } = item;
    let scale = match pass {
        Pass::Train { .. } => Some(plan.scales[mb.j]),
        Pass::Eval => None,
    };
    lane.submit(LaneJob { seq: *seq, mb, scale, fault, stall })?;
    *seq += 1;
    queue.push_back(plan);
    Ok(())
}

/// The overlap invariant, stated as an error instead of a panic: outside
/// the recovery quiesce window, an overlap-mode job always owns a lane. A
/// violation means pipeline state desynced — fail the job, not the process.
fn lane_desync() -> MbsError {
    MbsError::Runtime("overlap pipeline lost its upload lane (recovery desync)".into())
}

/// Receive one completed staging from the lane and place it into the idle
/// device slot: credit the lane thread's wall-clock window against the
/// runtime's execute windows (`upload_concurrent`), charge the ledger for
/// the input-slot residency, upload, and recycle the staging copy. Any
/// staging error the lane hit surfaces here — at the step that would have
/// consumed the slot. The wait is bounded by the watchdog's lane-recv
/// deadline: a lane that never completes its staging surfaces as a
/// recoverable [`MbsError::Deadline`] instead of hanging the executor.
#[allow(clippy::too_many_arguments)]
fn place_staged(
    rt: &mut ModelRuntime,
    ledger: &mut Ledger,
    fp: &Footprint,
    pool: &BufPool,
    lane: &mut UploadLane,
    queue: &mut VecDeque<Arc<ExecutionPlan>>,
    deadline: Duration,
) -> Result<InFlight> {
    let staged = lane.recv_deadline(deadline)?;
    let plan = queue.pop_front().ok_or_else(|| {
        MbsError::Runtime("upload lane completed a staging with no queued plan".into())
    })?;
    rt.credit_lane_window(staged.started, staged.finished);
    let inputs = ledger.alloc("in-flight inputs", fp.overlap_bytes(plan.device_samples()))?;
    rt.stage_inputs(&staged.mb, staged.scale)?;
    let current = InFlight { plan, j: staged.mb.j, actual: staged.mb.actual, inputs };
    // upload-completion: the staging copy recycles now — the pipeline
    // holds device slots, not host buffers
    pool.give(staged.mb);
    Ok(current)
}

/// THE epoch loop. Streams plan-tagged micro-batches and executes them,
/// charging the ledger for every step so planned residency is asserted
/// against capacity at the moment it would be live on the device. Staging
/// buffers are leased from `pool` by the streamer and handed back through
/// its return channel right after each upload — the steady-state hot path
/// allocates nothing. Returns the epoch's accumulation plus its per-stage
/// time breakdown (assemble from the stream items, the device stages as
/// deltas of the runtime's monotonic timers).
///
/// Serial (`overlap: false`): stage + execute fused per item, one input
/// slot live at a time — the byte-identity oracle. Overlapped: each item
/// is staged into the idle device slot (ledger: "in-flight inputs")
/// *before* the previously staged item executes, so the pipeline holds two
/// input slots across every execute — the residency the planner admitted.
/// The device-op order (and therefore every loss/metric bit) is identical
/// in both modes; only the upload issue points move.
#[allow(clippy::too_many_arguments)]
fn run_epoch(
    rt: &mut ModelRuntime,
    ledger: &mut Ledger,
    fp: &Footprint,
    pipe: &PipelineCfg,
    pool: &Arc<BufPool>,
    ds: &Arc<dyn Dataset>,
    epoch_plan: EpochPlan,
    planner: &Planner,
    pass: Pass<'_>,
) -> Result<(Accumulation, StageTimers)> {
    let mut acc = Accumulation::default();
    let mut assemble = Duration::ZERO;
    let rt_before = rt.timers();
    let stream = stream_epoch(
        pipe.policy,
        ds.clone(),
        epoch_plan,
        planner.clone(),
        pipe.prefetch,
        pool.clone(),
    );
    if pipe.overlap {
        // the lane pipeline: stage j (copied by the lane during the
        // previous execute) into the idle slot, hand j+1 to the lane, then
        // execute j-1 — the lane copies j+1 *during* that execute. The
        // device-op order (stage, then execute the older step) is identical
        // to the pre-lane pipeline, so every loss/metric bit is preserved;
        // only the host half of staging moved onto the lane thread.
        let label = {
            let l = rt.label();
            if l.is_empty() { "solo".to_string() } else { l.to_string() }
        };
        let mut lane = UploadLane::spawn(pool.clone(), LANE_DEPTH, &label)?;
        let mut queue: VecDeque<Arc<ExecutionPlan>> = VecDeque::new();
        let mut seq = 0u64;
        let mut pending: Option<InFlight> = None;
        // standalone epochs (eval entry points) run under the default
        // deadlines — generous enough to never fire on a healthy run, but a
        // wedged lane still converts to a structured fault, not a hang
        let lane_deadline = Watchdog::default().deadline(Surface::LaneRecv);
        for item in stream {
            assemble += item.assemble;
            let placed = if queue.is_empty() {
                None
            } else {
                Some(place_staged(rt, ledger, fp, pool, &mut lane, &mut queue, lane_deadline)?)
            };
            submit_to_lane(&mut lane, &mut queue, &mut seq, pass, item, None, None)?;
            if let Some(current) = pending.take() {
                step_in_flight(rt, ledger, fp, pass, &mut acc, current)?;
            }
            if let Some(next) = placed {
                pending = Some(next);
            }
        }
        // drain: the lane still holds the final submission, the device
        // slot the one before it
        while !queue.is_empty() {
            let placed = place_staged(rt, ledger, fp, pool, &mut lane, &mut queue, lane_deadline)?;
            if let Some(current) = pending.take() {
                step_in_flight(rt, ledger, fp, pass, &mut acc, current)?;
            }
            pending = Some(placed);
        }
        if let Some(current) = pending.take() {
            step_in_flight(rt, ledger, fp, pass, &mut acc, current)?;
        }
        // lane drops here: joins its thread, returning any leases first
    } else {
        for item in stream {
            assemble += item.assemble;
            exec_serial_item(rt, ledger, fp, pass, &mut acc, pool, item)?;
        }
    }
    let mut stages = rt.timers().minus(&rt_before);
    stages.assemble = assemble;
    Ok((acc, stages))
}

/// One eval sweep through the executor: the whole set as a single
/// sequential mini-batch, split by the runtime's static mu and streamed
/// under the run's configured policy.
#[allow(clippy::too_many_arguments)]
fn eval_epoch(
    rt: &mut ModelRuntime,
    ledger: &mut Ledger,
    fp: &Footprint,
    pipe: &PipelineCfg,
    pool: &Arc<BufPool>,
    kind: MetricKind,
    ds: &Arc<dyn Dataset>,
    epoch: usize,
) -> Result<EpochStats> {
    let t0 = Instant::now();
    let len = ds.len();
    let (acc, stages) = if len == 0 {
        // empty eval set: zero samples, zero stats
        (Accumulation::default(), StageTimers::default())
    } else {
        let planner = Planner::new(rt.variant.mu, false, NormalizationMode::Exact);
        run_epoch(
            rt,
            ledger,
            fp,
            pipe,
            pool,
            ds,
            EpochPlan::sequential(len, len),
            &planner,
            Pass::Eval,
        )?
    };
    Ok(EpochStats::from_accumulation(epoch, kind, &acc, rt.updates, t0.elapsed(), stages))
}

/// Masked, padded eval pass reusing a caller-owned staging pool — the
/// repeat-eval entry point (eval loops, benches): the pool is warmed once
/// by the caller and every subsequent eval circulates the same host
/// buffers instead of re-warming per call. Admission (a fresh ledger sized
/// to one serial eval step) is still checked per call; the sweep itself
/// runs serially (`overlap` staging is a training-run concern — `train`
/// drives its evals through its own pipeline config).
pub fn evaluate_pooled(
    rt: &mut ModelRuntime,
    kind: MetricKind,
    ds: &Arc<dyn Dataset>,
    epoch: usize,
    policy: StreamingPolicy,
    prefetch: usize,
    pool: &Arc<BufPool>,
) -> Result<EpochStats> {
    let fp = Footprint::from_manifest(&rt.entry, &rt.variant);
    let mut ledger = Ledger::new(fp.step_bytes(rt.variant.mu));
    ledger.alloc("resident state", fp.resident_bytes())?;
    let pipe = PipelineCfg { policy, prefetch, overlap: false };
    eval_epoch(rt, &mut ledger, &fp, &pipe, pool, kind, ds, epoch)
}

/// Masked, padded eval pass over a dataset under an explicit streaming
/// policy (the standalone entry point for benches and tests; `train` runs
/// the same executor with its own ledger and pool). Builds and warms a
/// one-shot pool — callers that evaluate repeatedly should hold a pool and
/// use [`evaluate_pooled`] instead.
pub fn evaluate_with(
    rt: &mut ModelRuntime,
    kind: MetricKind,
    ds: &Arc<dyn Dataset>,
    epoch: usize,
    policy: StreamingPolicy,
    prefetch: usize,
) -> Result<EpochStats> {
    let pool = Arc::new(BufPool::for_prefetch(prefetch));
    pool.warm(BufPool::buffers_for(prefetch), ds.as_ref(), rt.variant.mu);
    evaluate_pooled(rt, kind, ds, epoch, policy, prefetch, &pool)
}

/// [`evaluate_with`] under the synchronous policy — the historical
/// signature, kept for one-off callers.
pub fn evaluate(
    rt: &mut ModelRuntime,
    kind: MetricKind,
    ds: &Arc<dyn Dataset>,
    epoch: usize,
) -> Result<EpochStats> {
    evaluate_with(rt, kind, ds, epoch, StreamingPolicy::Synchronous, 0)
}

/// Mean per-epoch wall time, guarded so an empty or degenerate list can
/// never feed a non-finite value into `Duration::from_secs_f64` (which
/// panics on NaN).
fn mean_epoch_wall(walls: &[f64]) -> Duration {
    let m = crate::util::stats::mean(walls);
    if m.is_finite() && m >= 0.0 {
        Duration::from_secs_f64(m)
    } else {
        Duration::ZERO
    }
}

/// Cap for `--prefetch auto`: a small multiple of the accumulation-step
/// count — staging further ahead than ~2 mini-batches of micro-batches
/// cannot help (the device consumes them in order), it only holds more
/// host memory.
fn prefetch_cap(n_smu: usize) -> usize {
    (2 * n_smu.max(1)).clamp(2, 16)
}

/// `StageTimers`-driven prefetch tuning (`--prefetch auto`): after an
/// epoch, grow the prefetch window while host assembly bounds the pipeline
/// (its per-micro-step mean exceeds the *visible* device time — upload
/// minus its hidden part, plus execute and download), shrink it when the
/// device dominates by 4x or more, and otherwise hold. Pure arithmetic so
/// the policy is unit-testable without artifacts.
///
/// Known limitation: the per-step means barely move with the channel
/// depth (one assembly worker either keeps up or doesn't), so on a
/// steadily host-bound run this ratchets to the cap and on a
/// device-bound one it settles at 1 — it finds the right *regime*, and
/// the `prefetch_cap` bound is what keeps the host-memory cost of the
/// ratchet small.
fn tune_prefetch(prefetch: usize, stages: &StageTimers, micro_steps: u64, cap: usize) -> usize {
    if micro_steps == 0 {
        return prefetch;
    }
    let per = |d: Duration| d.as_secs_f64() / micro_steps as f64;
    let assemble = per(stages.assemble);
    let device = per(stages.upload) - per(stages.upload_hidden)
        + per(stages.execute)
        + per(stages.download);
    if assemble > device {
        (prefetch.max(1) * 2).min(cap)
    } else if prefetch > 1 && assemble * 4.0 < device {
        (prefetch / 2).max(1)
    } else {
        prefetch.min(cap)
    }
}

/// Train according to `cfg`, returning the full report. Returns
/// [`MbsError::Oom`] when the configuration does not fit the simulated
/// device — the paper tables' "Failed" cells.
///
/// Solo training is the one-tenant special case of the interleaved
/// multi-job executor: admission + planning here, then a single
/// [`JobExec`] over a one-slot [`Arena`] driven to completion. Solo and
/// interleaved runs therefore share every line of execution code, which
/// is what makes their per-job reports bit-identical by construction.
pub fn train(engine: &mut Engine, cfg: &TrainConfig) -> Result<TrainReport> {
    cfg.validate()?;
    let entry = engine.manifest().model(&cfg.model)?.clone();
    let size = cfg.size.unwrap_or(entry.default_size);

    // deterministic fault injection + recovery (`--faults spec.json`):
    // solo runs are the one-tenant special case of the same state machine
    let plan = match &cfg.faults {
        Some(path) => Some(FaultPlan::load(path)?),
        None => None,
    };
    // compile faults live on the engine (the compile seam is shared across
    // tenants, not per-job): arm them for this run, or clear a previous
    // run's hooks so plans never leak across entry points
    match &plan {
        Some(p) if p.has_compile_entries() => engine.arm_compile_faults(p.compile_hooks()),
        _ => engine.disarm_compile_faults(),
    }

    // ------------------------------------------------------------------
    // memory admission + planning (paper section 1 + Alg. 1): the ledger's
    // remaining budget drives the micro-batch choice; the resident state is
    // then charged for the whole run
    // ------------------------------------------------------------------
    let capacity = match cfg.capacity_bytes() {
        Some(c) => c,
        None => planner::default_capacity(&entry, size, &cfg.mu)?,
    };
    let resolution = planner::resolve(&entry, size, cfg, &Ledger::new(capacity))?;

    // the solo claim is the exact resident footprint (admission's
    // cross-variant conservative claim is a multi-tenant concern), so the
    // solo ledger peak matches the historical "resident state" accounting
    let arena = Arena::new(capacity);
    let spec = JobSpec { name: cfg.model.clone(), task: None, cfg: cfg.clone() };
    let recovery = plan.as_ref().map(|p| RecoveryCfg::from_plan(p, &spec.name));
    let mut exec = JobExec::new(
        engine,
        &spec,
        &resolution,
        resolution.footprint.resident_bytes(),
        &arena,
        recovery,
    )?;
    loop {
        match exec.step() {
            Ok(true) => {}
            Ok(false) => break,
            // recoverable fault with retries left: checkpoint-based replay
            // (quiesce → release → re-plan → restore); anything else — or
            // an exhausted budget — propagates as the run's error
            Err(e) if exec.can_recover(&e) => {
                exec.note_retry(&e);
                exec.recover(engine)?;
            }
            Err(e) => {
                exec.cleanup_snapshot();
                return Err(e);
            }
        }
    }
    exec.into_report(capacity)
}

// ---------------------------------------------------------------------
// Multi-tenant interleaved execution (the shared-arena serving story)
// ---------------------------------------------------------------------

/// Where one tenant's run currently is inside the interleaved executor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum JobPhase {
    /// Training epoch `epoch`.
    Train {
        /// 0-based epoch index.
        epoch: usize,
    },
    /// Post-epoch eval sweep of `epoch` (absent under `skip_eval`).
    Eval {
        /// The training epoch this sweep follows.
        epoch: usize,
    },
    /// The one final eval sweep a `skip_eval` run still performs.
    FinalEval,
    /// All phases complete.
    Done,
}

/// Per-job recovery policy: deterministic fault hooks plus the retry
/// budget and backoff, derived from a [`FaultPlan`]. Absent (no plan),
/// the executor behaves exactly as before — no snapshots, no retries.
struct RecoveryCfg {
    hooks: FaultHooks,
    max_retries: u32,
    backoff_ms: u64,
    /// Plan seed, reused for the deterministic backoff-jitter draw.
    seed: u64,
    /// Wall-clock deadlines for every blocking surface — the plan's
    /// `watchdog` overrides, or the generous defaults.
    watchdog: Watchdog,
}

impl RecoveryCfg {
    fn from_plan(plan: &FaultPlan, job: &str) -> RecoveryCfg {
        RecoveryCfg {
            hooks: plan.hooks_for(job),
            max_retries: plan.max_retries,
            backoff_ms: plan.backoff_ms,
            seed: plan.seed,
            watchdog: plan.watchdog.map(Watchdog::new).unwrap_or_default(),
        }
    }
}

/// Seeded retry-backoff jitter: keep the linear base but draw the actual
/// sleep uniformly from `[base/2, base]` via an FNV hash of
/// `"{seed}:{job}:backoff:{attempt}"`. Co-resident jobs that fault on the
/// same turn desynchronize their retries instead of thundering together,
/// and the draw is a pure function of the plan — same spec, same sleeps.
fn backoff_with_jitter(base_ms: u64, seed: u64, job: &str, attempt: u32) -> u64 {
    if base_ms == 0 {
        return 0;
    }
    let f = fraction(fnv1a64(format!("{seed}:{job}:backoff:{attempt}").as_bytes()));
    base_ms / 2 + (f * (base_ms / 2 + 1) as f64) as u64
}

/// One tenant's live execution state: everything the solo [`train`] loop
/// keeps on its stack, reified so the round-robin can advance jobs one
/// micro-step at a time. Every job owns its runtime, accumulator, update
/// scheduler, staging pool, stage timers and arena sub-ledger — nothing
/// numeric is shared, which is what makes per-job reports bit-identical
/// to solo runs.
struct JobExec {
    name: String,
    cfg: TrainConfig,
    kind: MetricKind,
    rt: ModelRuntime,
    /// Tenant sub-ledger charging into the shared arena (holds the
    /// durable resident reservation; steps charge transiently).
    ledger: Ledger,
    fp: Footprint,
    planner: Planner,
    sched: UpdateScheduler,
    pool: Arc<BufPool>,
    train_ds: Arc<dyn Dataset>,
    eval_ds: Arc<dyn Dataset>,
    prefetch: usize,
    n_smu_full: usize,
    phase: JobPhase,
    stream: Option<EpochStream>,
    /// Dedicated host-staging thread (overlap mode only). One lane per
    /// job, alive for the job's whole run — it stays warm across other
    /// jobs' turns, exactly like the staged device slot below.
    lane: Option<UploadLane>,
    /// Plans for micro-batches submitted to the lane, FIFO (re-paired
    /// with staged copies by position).
    lane_queue: VecDeque<Arc<ExecutionPlan>>,
    lane_seq: u64,
    /// The staged-but-unexecuted micro-batch: the warm ping-pong slot.
    /// Its "in-flight inputs" ledger charge persists across other jobs'
    /// turns — the cross-tenant staged residency that admission prices as
    /// a durable sum, not a transient max.
    pending: Option<InFlight>,
    acc: Accumulation,
    assemble: Duration,
    rt_before: StageTimers,
    phase_t0: Instant,
    train_epochs: Vec<EpochStats>,
    eval_epochs: Vec<EpochStats>,
    final_eval: Option<EpochStats>,
    stage_totals: StageTimers,
    run_start: Instant,
    mu: usize,
    /// The manifest entry + size the job resolved against — kept so
    /// recovery can re-run the micro-batch planner (paper Alg. 1) against
    /// the transient budget that is actually free at replay time.
    entry: ModelEntry,
    size: usize,
    /// The durable resident reservation admission placed. Released during
    /// recovery quiesce and re-claimed before replay; `None` only inside
    /// that window.
    reservation: Option<AllocId>,
    claim_bytes: u64,
    /// Deterministic fault hooks for this job (never fire without a plan).
    hooks: FaultHooks,
    /// Monotonic micro-step attempt counter. Deliberately NOT reset by
    /// recovery, so `at-step` faults fire exactly once and the replayed
    /// steps run fault-free — the recovery identity oracle depends on it.
    step_attempts: u64,
    retries_left: u32,
    retries_used: u32,
    /// Completed recoveries (quiesce → release → re-plan → replay).
    recovered: u64,
    backoff_ms: u64,
    /// Plan seed for the deterministic backoff-jitter draw.
    fault_seed: u64,
    /// Wall-clock watchdog: bounds every blocking surface (lane recv,
    /// micro-step execute, checkpoint save/load) and converts expiry into
    /// a recoverable [`MbsError::Deadline`] — a hang becomes a fault the
    /// recovery state machine already knows how to absorb.
    watchdog: Watchdog,
    /// Monotonic snapshot-save attempt counter (the `checkpoint` fault
    /// axis). Like `step_attempts`, deliberately NOT reset by recovery so
    /// at-step checkpoint faults fire exactly once.
    ckpt_attempts: u64,
    /// Phase-start snapshot base path; the recovery state machine is
    /// enabled iff this is set.
    snapshot: Option<PathBuf>,
    /// Update counter at the last `--checkpoint-every` save.
    last_ckpt: u64,
    /// Guard so the final `--checkpoint` save happens exactly once.
    ckpt_done: bool,
    /// Optimizer updates a `--resume` checkpoint already applied within
    /// the first replayed epoch — consumed (skipped) when that epoch's
    /// stream opens.
    resume_skip: u64,
}

impl JobExec {
    fn new(
        engine: &mut Engine,
        spec: &JobSpec,
        res: &Resolution,
        claim_bytes: u64,
        arena: &Arena,
        recovery: Option<RecoveryCfg>,
    ) -> Result<JobExec> {
        let cfg = spec.cfg.clone();
        let entry = engine.manifest().model(&cfg.model)?.clone();
        let size = cfg.size.unwrap_or(entry.default_size);
        let kind = MetricKind::parse(&entry.metric_semantics)?;
        // the durable per-job reservation admission placed (conservative:
        // covers the resident state of any exported variant at this size)
        let mut ledger = arena.tenant(&spec.name);
        let reservation = ledger.alloc("resident reservation", claim_bytes)?;
        let mut rt = engine.load_model(&cfg.model, size, res.mu)?;
        rt.set_overlap(cfg.overlap);
        rt.set_label(&spec.name);
        // `--resume`: restore params/slots/updates before the first phase
        // opens, then fast-forward the state machine to the phase the
        // checkpoint's update counter sits in (any partial epoch's already
        // -applied updates are skipped when its stream opens)
        if let Some(path) = &cfg.resume {
            rt.load_checkpoint(Path::new(path))?;
        }
        let batches_per_epoch = cfg.dataset_len.div_ceil(cfg.batch);
        let (phase0, resume_skip) = if rt.updates == 0 {
            (JobPhase::Train { epoch: 0 }, 0)
        } else {
            let bpe = batches_per_epoch as u64;
            let full = (rt.updates / bpe) as usize;
            if full >= cfg.epochs {
                (JobPhase::FinalEval, 0)
            } else {
                (JobPhase::Train { epoch: full }, rt.updates % bpe)
            }
        };
        let (train_ds, eval_ds) = datasets_for(&entry.task, size, &cfg)?;
        let total_updates = (batches_per_epoch * cfg.epochs) as u64;
        let sched = UpdateScheduler::new(&entry.optimizer, &cfg, total_updates);
        let n_smu_full = if cfg.use_mbs { cfg.batch.div_ceil(res.mu) } else { 1 };
        let max_prefetch = if cfg.prefetch_auto {
            cfg.prefetch.max(prefetch_cap(n_smu_full))
        } else {
            cfg.prefetch
        };
        // overlap adds the lane's working set on top of the streamer's
        // (staging copies in flight + originals in transit): size and warm
        // the pool for both so the hot path stays allocation-free
        let lane_extra = if cfg.overlap { UploadLane::extra_buffers(LANE_DEPTH) } else { 0 };
        let retained = BufPool::buffers_for(max_prefetch) + lane_extra;
        let pool = Arc::new(BufPool::bounded(retained));
        pool.warm(retained, train_ds.as_ref(), res.mu);
        let lane = if cfg.overlap {
            Some(UploadLane::spawn(pool.clone(), LANE_DEPTH, &spec.name)?)
        } else {
            None
        };
        let planner = Planner::new(res.mu, !cfg.use_mbs, cfg.norm_mode);
        let recovery_on = recovery.is_some();
        let (hooks, max_retries, backoff_ms, fault_seed, watchdog) = match recovery {
            Some(r) => (r.hooks, r.max_retries, r.backoff_ms, r.seed, r.watchdog),
            None => (FaultHooks::none(), 0, 0, 0, Watchdog::default()),
        };
        // phase-start snapshots live in the OS temp dir, one pair per
        // (process, job) — cleaned up when the job reaches a terminal state
        let snapshot = recovery_on.then(|| {
            let safe: String = spec
                .name
                .chars()
                .map(|c| if c.is_ascii_alphanumeric() || c == '-' || c == '_' { c } else { '-' })
                .collect();
            std::env::temp_dir().join(format!("mbs-recovery-{}-{safe}", std::process::id()))
        });
        let now = Instant::now();
        Ok(JobExec {
            name: spec.name.clone(),
            kind,
            rt,
            ledger,
            fp: res.footprint.clone(),
            planner,
            sched,
            pool,
            train_ds,
            eval_ds,
            prefetch: cfg.prefetch,
            n_smu_full,
            phase: phase0,
            stream: None,
            lane,
            lane_queue: VecDeque::new(),
            lane_seq: 0,
            pending: None,
            acc: Accumulation::default(),
            assemble: Duration::ZERO,
            rt_before: StageTimers::default(),
            phase_t0: now,
            train_epochs: Vec::with_capacity(cfg.epochs),
            eval_epochs: Vec::with_capacity(cfg.epochs),
            final_eval: None,
            stage_totals: StageTimers::default(),
            run_start: now,
            mu: res.mu,
            entry,
            size,
            reservation: Some(reservation),
            claim_bytes,
            hooks,
            step_attempts: 0,
            retries_left: max_retries,
            retries_used: 0,
            recovered: 0,
            backoff_ms,
            fault_seed,
            watchdog,
            ckpt_attempts: 0,
            snapshot,
            last_ckpt: 0,
            ckpt_done: false,
            resume_skip,
            cfg,
        })
    }

    /// Open the stream for the phase the job is parked on. Returns false
    /// when the phase completed immediately (empty eval set) — the caller
    /// advances and retries.
    fn begin_phase(&mut self) -> Result<bool> {
        self.phase_t0 = Instant::now();
        self.rt_before = self.rt.timers();
        self.acc = Accumulation::default();
        self.assemble = Duration::ZERO;
        // recovery enabled: every phase start is an update boundary, so
        // snapshot here — a mid-phase fault replays the phase from scratch
        // and lands bit-identical to an uninterrupted run
        if let Some(snap) = self.snapshot.clone() {
            let attempt = self.ckpt_attempts;
            self.ckpt_attempts += 1;
            // an injected checkpoint stall lands inside the timed window,
            // so a short watchdog deadline converts it into a recoverable
            // Deadline fault — the hang-to-fault contract for this surface
            let t0 = Instant::now();
            if let Some(d) = self.hooks.check_stall(StallSurface::Checkpoint, attempt) {
                std::thread::sleep(d);
            }
            self.rt.save_checkpoint(&snap)?;
            self.watchdog.observe(Surface::CheckpointSave, t0.elapsed())?;
            // the torn-write fault fires AFTER the atomic save: the on-disk
            // snapshot is valid and current, so the recovery this error
            // triggers replays from it bit-identically
            if let Some(note) = self.hooks.check(FaultKind::Checkpoint, attempt) {
                return Err(MbsError::Fault(note));
            }
        }
        match self.phase {
            JobPhase::Train { epoch } => {
                let plan = EpochPlan::new(
                    self.train_ds.len().min(self.cfg.dataset_len),
                    self.cfg.batch,
                    self.cfg.seed,
                    epoch as u64,
                );
                let mut stream = stream_epoch(
                    self.cfg.streaming,
                    self.train_ds.clone(),
                    plan,
                    self.planner.clone(),
                    self.prefetch,
                    self.pool.clone(),
                );
                // `--resume` fast-forward: recycle the micro-batches whose
                // updates the checkpoint already applied — from here on the
                // device-op sequence matches the uninterrupted run's
                while self.resume_skip > 0 {
                    match stream.next() {
                        Some(item) => {
                            let update_done = item.plan.is_last(item.mb.j);
                            self.pool.give(item.mb);
                            if update_done {
                                self.resume_skip -= 1;
                            }
                        }
                        None => break,
                    }
                }
                self.stream = Some(stream);
                Ok(true)
            }
            JobPhase::Eval { .. } | JobPhase::FinalEval => {
                let len = self.eval_ds.len();
                if len == 0 {
                    // empty eval set: zero samples, zero stats (mirrors
                    // the solo eval_epoch short-circuit)
                    self.finish_phase();
                    return Ok(false);
                }
                // the same sweep solo eval_epoch runs: the whole set as
                // one sequential mini-batch, exact normalization
                let planner = Planner::new(self.rt.variant.mu, false, NormalizationMode::Exact);
                self.stream = Some(stream_epoch(
                    self.cfg.streaming,
                    self.eval_ds.clone(),
                    EpochPlan::sequential(len, len),
                    planner,
                    self.prefetch,
                    self.pool.clone(),
                ));
                Ok(true)
            }
            JobPhase::Done => Ok(false),
        }
    }

    /// Close out the active phase: fold its stats in and advance the
    /// state machine, mirroring the solo [`train`] loop's sequencing
    /// (train epoch → eval sweep → … → final eval) exactly.
    fn finish_phase(&mut self) {
        self.stream = None;
        let wall = self.phase_t0.elapsed();
        let mut stages = self.rt.timers().minus(&self.rt_before);
        stages.assemble = self.assemble;
        let acc = std::mem::take(&mut self.acc);
        match self.phase {
            JobPhase::Train { epoch } => {
                self.stage_totals.merge(&stages);
                if self.cfg.prefetch_auto {
                    self.prefetch = tune_prefetch(
                        self.prefetch,
                        &stages,
                        acc.micro_steps as u64,
                        prefetch_cap(self.n_smu_full),
                    );
                }
                self.train_epochs.push(EpochStats::from_accumulation(
                    epoch,
                    self.kind,
                    &acc,
                    self.rt.updates,
                    wall,
                    stages,
                ));
                self.phase = if !self.cfg.skip_eval {
                    JobPhase::Eval { epoch }
                } else if epoch + 1 < self.cfg.epochs {
                    JobPhase::Train { epoch: epoch + 1 }
                } else {
                    JobPhase::FinalEval
                };
            }
            JobPhase::Eval { epoch } => {
                self.eval_epochs.push(EpochStats::from_accumulation(
                    epoch,
                    self.kind,
                    &acc,
                    self.rt.updates,
                    wall,
                    stages,
                ));
                self.phase = if epoch + 1 < self.cfg.epochs {
                    JobPhase::Train { epoch: epoch + 1 }
                } else {
                    self.final_eval = self.eval_epochs.last().cloned();
                    JobPhase::Done
                };
            }
            JobPhase::FinalEval => {
                self.final_eval = Some(EpochStats::from_accumulation(
                    self.cfg.epochs.saturating_sub(1),
                    self.kind,
                    &acc,
                    self.rt.updates,
                    wall,
                    stages,
                ));
                self.phase = JobPhase::Done;
            }
            JobPhase::Done => {}
        }
    }

    /// Advance the job by exactly one micro-step — the round-robin turn
    /// unit. Phase boundaries (stream exhausted, next stream opened) are
    /// crossed within the turn, and under overlap the pipeline warm-up
    /// (first items submitted to the lane before anything can execute)
    /// also completes within the turn — so every turn that returns true
    /// executed at most one device step, and the job's staged slot + lane
    /// submission stay warm across other jobs' turns. Returns false once
    /// every phase is complete.
    fn step(&mut self) -> Result<bool> {
        self.maybe_checkpoint()?;
        loop {
            if self.phase == JobPhase::Done {
                self.final_checkpoint()?;
                return Ok(false);
            }
            if self.stream.is_none() && !self.begin_phase()? {
                continue; // phase completed immediately (empty eval set)
            }
            let mut item = self.stream.as_mut().expect("phase begun").next();
            // per-attempt fault checks, before the turn touches the
            // pipeline: a step fault surfaces right here (recycling the
            // item's staging buffer); a lane note rides the submission
            // below; an arena fault armed here fires at this turn's charge
            let (lane_fault, stall) = if item.is_some() {
                match self.check_faults() {
                    Ok(f) => f,
                    Err(e) => {
                        if let Some(it) = item.take() {
                            self.pool.give(it.mb);
                        }
                        return Err(e);
                    }
                }
            } else {
                (None, None)
            };
            let pass = match self.phase {
                JobPhase::Train { .. } => Pass::Train { sched: &self.sched },
                _ => Pass::Eval,
            };
            if !self.cfg.overlap {
                match item {
                    Some(item) => {
                        self.assemble += item.assemble;
                        // an injected step stall sleeps inside the timed
                        // window, so the watchdog sees it as a wedged step
                        let t0 = Instant::now();
                        if let Some(d) = stall {
                            std::thread::sleep(d);
                        }
                        exec_serial_item(
                            &mut self.rt,
                            &mut self.ledger,
                            &self.fp,
                            pass,
                            &mut self.acc,
                            &self.pool,
                            item,
                        )?;
                        self.watchdog.observe(Surface::Step, t0.elapsed())?;
                        return Ok(true);
                    }
                    None => self.finish_phase(),
                }
                continue;
            }
            // overlap: the same stage-then-execute pipeline as the solo
            // epoch loop, unrolled to one device step per turn
            match item {
                Some(item) => {
                    self.assemble += item.assemble;
                    let placed = if self.lane_queue.is_empty() {
                        None
                    } else {
                        Some(place_staged(
                            &mut self.rt,
                            &mut self.ledger,
                            &self.fp,
                            &self.pool,
                            self.lane.as_mut().ok_or_else(lane_desync)?,
                            &mut self.lane_queue,
                            self.watchdog.deadline(Surface::LaneRecv),
                        )?)
                    };
                    submit_to_lane(
                        self.lane.as_mut().ok_or_else(lane_desync)?,
                        &mut self.lane_queue,
                        &mut self.lane_seq,
                        pass,
                        item,
                        lane_fault,
                        stall,
                    )?;
                    let executed = if let Some(current) = self.pending.take() {
                        let t0 = Instant::now();
                        step_in_flight(
                            &mut self.rt,
                            &mut self.ledger,
                            &self.fp,
                            pass,
                            &mut self.acc,
                            current,
                        )?;
                        self.watchdog.observe(Surface::Step, t0.elapsed())?;
                        true
                    } else {
                        false
                    };
                    if let Some(next) = placed {
                        self.pending = Some(next);
                    }
                    if executed {
                        return Ok(true);
                    }
                    // warm-up: nothing could execute yet — keep feeding
                    // the pipeline within this turn
                }
                None => {
                    // stream dry: drain the lane, then the staged slot
                    if !self.lane_queue.is_empty() {
                        let placed = place_staged(
                            &mut self.rt,
                            &mut self.ledger,
                            &self.fp,
                            &self.pool,
                            self.lane.as_mut().ok_or_else(lane_desync)?,
                            &mut self.lane_queue,
                            self.watchdog.deadline(Surface::LaneRecv),
                        )?;
                        if let Some(current) = self.pending.take() {
                            let t0 = Instant::now();
                            step_in_flight(
                                &mut self.rt,
                                &mut self.ledger,
                                &self.fp,
                                pass,
                                &mut self.acc,
                                current,
                            )?;
                            self.watchdog.observe(Surface::Step, t0.elapsed())?;
                            self.pending = Some(placed);
                            return Ok(true);
                        }
                        self.pending = Some(placed);
                        continue;
                    }
                    if let Some(current) = self.pending.take() {
                        let t0 = Instant::now();
                        step_in_flight(
                            &mut self.rt,
                            &mut self.ledger,
                            &self.fp,
                            pass,
                            &mut self.acc,
                            current,
                        )?;
                        self.watchdog.observe(Surface::Step, t0.elapsed())?;
                        return Ok(true);
                    }
                    self.finish_phase();
                }
            }
        }
    }

    /// Run the per-attempt fault checks for one arriving micro-batch.
    /// Consumes one attempt number (monotonic across recoveries). A `step`
    /// fault surfaces as [`MbsError::Fault`] right here; an `arena` fault
    /// arms the tenant's next ledger charge; a `lane` fault note rides the
    /// upload-lane submission (overlap mode only). The second element is
    /// an injected `stall` delay for this turn: under overlap it rides the
    /// lane job (and trips the lane-recv deadline), serially it lands
    /// inside the step's timed window (and trips the step deadline).
    fn check_faults(&mut self) -> Result<(Option<String>, Option<Duration>)> {
        let attempt = self.step_attempts;
        self.step_attempts += 1;
        if self.hooks.is_empty() {
            return Ok((None, None));
        }
        if let Some(note) = self.hooks.check(FaultKind::Step, attempt) {
            return Err(MbsError::Fault(note));
        }
        if let Some(note) = self.hooks.check(FaultKind::Arena, attempt) {
            self.ledger.inject_charge_fault(&note);
        }
        if self.cfg.overlap {
            let note = self.hooks.check(FaultKind::Lane, attempt);
            let stall = self.hooks.check_stall(StallSurface::Lane, attempt);
            Ok((note, stall))
        } else {
            let stall = self.hooks.check_stall(StallSurface::Step, attempt);
            Ok((None, stall))
        }
    }

    /// Can the recovery state machine absorb this error? Requires the
    /// machine to be enabled (snapshots exist), the error to be transient
    /// by contract ([`MbsError::recoverable`]), and retries to remain.
    fn can_recover(&self, err: &MbsError) -> bool {
        self.snapshot.is_some() && err.recoverable() && self.retries_left > 0
    }

    /// Retry bookkeeping + the per-job backoff that precedes a recovery
    /// attempt: linear in the retry count, with a seeded jitter draw so
    /// co-faulting tenants desynchronize ([`backoff_with_jitter`]).
    fn note_retry(&mut self, err: &MbsError) {
        self.retries_left -= 1;
        self.retries_used += 1;
        eprintln!(
            "[mbs] job '{}': recoverable fault ({err}); recovery attempt {} ({} left)",
            self.name, self.retries_used, self.retries_left
        );
        if self.backoff_ms > 0 {
            let base = self.backoff_ms * self.retries_used as u64;
            let ms = backoff_with_jitter(base, self.fault_seed, &self.name, self.retries_used);
            std::thread::sleep(Duration::from_millis(ms));
        }
    }

    /// The recovery state machine (rust/docs/ARCHITECTURE.md): quiesce →
    /// release → re-claim → re-plan → replay. Called between turns by the
    /// driving loops after a recoverable fault, never mid-step. On return
    /// the job is parked exactly at its current phase's start with a clean
    /// pipeline; the next turn re-opens the phase's stream and the replay
    /// is bit-identical to an uninterrupted run (the identity oracle).
    fn recover(&mut self, engine: &mut Engine) -> Result<()> {
        let snap = self.snapshot.clone().ok_or_else(|| {
            MbsError::Runtime(format!("job '{}': recovery requested but not enabled", self.name))
        })?;
        // 1. quiesce: stop the lane (joins its thread, returning leases),
        //    drain the stream recycling every staging buffer, drop the
        //    staged slot, reset the device double-buffer
        self.lane = None;
        self.lane_queue.clear();
        self.pending = None;
        if let Some(stream) = self.stream.take() {
            for item in stream {
                self.pool.give(item.mb);
            }
        }
        self.rt.reset_pipeline();
        // 2. release every arena charge this tenant holds — reservation,
        //    in-flight inputs, anything a mid-step abort left live — so
        //    the shared capacity is whole while we re-plan
        self.ledger.release_all();
        self.reservation = None;
        // 3. re-claim the durable reservation; if even that no longer
        //    fits, the job fails terminally (structured OOM — the caller's
        //    graceful-degradation path) while siblings keep their bytes
        self.reservation = Some(self.ledger.alloc("resident reservation", self.claim_bytes)?);
        // 4. re-run the micro-batch planner (paper Alg. 1) against the
        //    transient budget that is actually free now: genuine pressure
        //    shrinks mu; a transient injected fault re-picks the same one.
        //    The re-planned mu need not be exported — adopt_resolution
        //    resolves it through the engine's artifact manager
        //    (runtime/artifacts.rs), which serves the cache or compiles
        //    the variant on demand instead of failing the recovery
        if self.cfg.mu.is_auto() {
            let res = planner::auto_mu_transient(
                &self.entry,
                self.size,
                self.cfg.batch,
                self.cfg.eval_len,
                self.ledger.remaining(),
                self.cfg.overlap,
            )?;
            if res.mu != self.mu {
                eprintln!(
                    "[mbs] job '{}': recovery re-planned mu {} -> {}",
                    self.name, self.mu, res.mu
                );
                self.adopt_resolution(engine, &res)?;
            }
        }
        // 5. replay: restore the phase-start snapshot and let the next
        //    turn re-open the phase's stream from its beginning; the load
        //    is watchdog-bounded like every other blocking surface
        let t0 = Instant::now();
        self.rt.load_checkpoint(&snap)?;
        self.watchdog.observe(Surface::CheckpointLoad, t0.elapsed())?;
        if self.cfg.overlap {
            self.lane = Some(UploadLane::spawn(self.pool.clone(), LANE_DEPTH, &self.name)?);
        }
        self.stream = None;
        self.recovered += 1;
        Ok(())
    }

    /// Swap the job onto a re-planned resolution (shrink-mu recovery):
    /// new runtime variant, footprint, planner and accumulation-step
    /// count, plus a staging pool re-warmed for the new micro-batch size.
    /// The update scheduler survives — it is a function of the config and
    /// the restored update counter, not of mu.
    fn adopt_resolution(&mut self, engine: &mut Engine, res: &Resolution) -> Result<()> {
        let mut rt = engine.load_model(&self.cfg.model, self.size, res.mu)?;
        rt.set_overlap(self.cfg.overlap);
        rt.set_label(&self.name);
        self.rt = rt;
        self.fp = res.footprint.clone();
        self.planner = Planner::new(res.mu, !self.cfg.use_mbs, self.cfg.norm_mode);
        self.n_smu_full = if self.cfg.use_mbs { self.cfg.batch.div_ceil(res.mu) } else { 1 };
        let max_prefetch = if self.cfg.prefetch_auto {
            self.cfg.prefetch.max(prefetch_cap(self.n_smu_full))
        } else {
            self.cfg.prefetch
        };
        let lane_extra = if self.cfg.overlap { UploadLane::extra_buffers(LANE_DEPTH) } else { 0 };
        let retained = BufPool::buffers_for(max_prefetch) + lane_extra;
        let pool = Arc::new(BufPool::bounded(retained));
        pool.warm(retained, self.train_ds.as_ref(), res.mu);
        self.pool = pool;
        self.mu = res.mu;
        Ok(())
    }

    /// `--checkpoint-every`: save to the configured checkpoint path when
    /// the update counter has crossed the interval since the last save.
    fn maybe_checkpoint(&mut self) -> Result<()> {
        let (Some(every), Some(path)) = (self.cfg.checkpoint_every, self.cfg.checkpoint.clone())
        else {
            return Ok(());
        };
        if self.rt.updates > self.last_ckpt && self.rt.updates % every == 0 {
            let t0 = Instant::now();
            self.rt.save_checkpoint(Path::new(&path))?;
            self.watchdog.observe(Surface::CheckpointSave, t0.elapsed())?;
            self.last_ckpt = self.rt.updates;
        }
        Ok(())
    }

    /// The final `--checkpoint` save when the run completes (covers the
    /// tail `--checkpoint-every` missed), exactly once.
    fn final_checkpoint(&mut self) -> Result<()> {
        if self.ckpt_done {
            return Ok(());
        }
        self.ckpt_done = true;
        if let Some(path) = self.cfg.checkpoint.clone() {
            let t0 = Instant::now();
            self.rt.save_checkpoint(Path::new(&path))?;
            self.watchdog.observe(Surface::CheckpointSave, t0.elapsed())?;
            self.last_ckpt = self.rt.updates;
        }
        Ok(())
    }

    /// Delete the phase-start snapshot pair (best-effort): the job reached
    /// a terminal state and recovery is over.
    fn cleanup_snapshot(&self) {
        if let Some(snap) = &self.snapshot {
            std::fs::remove_file(snap.with_extension("bin")).ok();
            std::fs::remove_file(snap.with_extension("json")).ok();
        }
    }

    /// `(faults_injected, retries, recovered)` — the per-job resilience
    /// counters the multi-tenant report surfaces.
    fn fault_counters(&self) -> (u64, u64, u64) {
        (self.hooks.injected(), self.retries_used as u64, self.recovered)
    }

    /// Assemble the job's [`TrainReport`] — field-for-field what the solo
    /// [`train`] path reports, so the identity oracle can compare them.
    fn into_report(self, capacity_bytes: u64) -> Result<TrainReport> {
        self.cleanup_snapshot();
        let final_eval = self.final_eval.ok_or_else(|| {
            MbsError::Runtime(format!("job '{}' finished without a final eval", self.name))
        })?;
        let epoch_walls: Vec<f64> =
            self.train_epochs.iter().map(|e| e.wall.as_secs_f64()).collect();
        let mem = MemoryModel::new(capacity_bytes, self.fp.clone());
        Ok(TrainReport {
            model: self.cfg.model.clone(),
            use_mbs: self.cfg.use_mbs,
            batch: self.cfg.batch,
            mu: self.mu,
            train_epochs: self.train_epochs,
            eval_epochs: self.eval_epochs,
            final_eval,
            total_wall: self.run_start.elapsed(),
            epoch_wall_mean: mean_epoch_wall(&epoch_walls),
            native_max_batch: mem.native_max_batch(),
            capacity_bytes,
            output_mode: self.rt.output_mode_name().to_string(),
            updates: self.rt.updates,
            stages: self.stage_totals,
            pool: self.pool.stats(),
            overlap: self.cfg.overlap,
            prefetch: self.prefetch,
            ledger_peak_bytes: self.ledger.peak(),
        })
    }
}

/// A job's terminal verdict inside a multi-tenant run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobOutcome {
    /// Trained to completion (possibly after recoveries).
    Completed,
    /// Admitted but died mid-run: retries exhausted on a recoverable
    /// fault, or a fatal error — evicted so the survivors keep running.
    Failed,
    /// Admission refused the job; it never ran.
    Rejected,
}

impl JobOutcome {
    /// The `outcome` key written to `BENCH_jobs.json`.
    pub fn as_str(self) -> &'static str {
        match self {
            JobOutcome::Completed => "completed",
            JobOutcome::Failed => "failed",
            JobOutcome::Rejected => "rejected",
        }
    }
}

/// One job's outcome inside a multi-tenant run.
#[derive(Debug, Clone)]
pub struct JobRun {
    /// Job name from the spec.
    pub name: String,
    /// Admission verdict (admit / shrink-mu / reject) with its arithmetic.
    pub admission: AdmissionOutcome,
    /// The full per-job training report — `None` for rejected and failed
    /// jobs.
    pub report: Option<TrainReport>,
    /// Terminal verdict (completed / failed / rejected).
    pub outcome: JobOutcome,
    /// The terminal error for failed jobs, rendered via `Display` — a
    /// retry-exhausted OOM keeps its structured arithmetic here.
    pub error: Option<String>,
    /// Deterministic faults injected into this job by the fault plan.
    pub faults_injected: u64,
    /// Recovery attempts this job consumed.
    pub retries: u64,
    /// Recoveries that completed (quiesce → release → re-plan → replay).
    pub recovered: u64,
}

/// Everything a finished multi-tenant run reports (`mbs jobs`).
#[derive(Debug, Clone)]
pub struct JobsReport {
    /// Shared arena capacity, bytes.
    pub capacity_bytes: u64,
    /// Cross-job residency high-water mark over the whole run — within
    /// capacity by construction (every arena charge that would exceed it
    /// fails at the instant it happens).
    pub arena_peak_bytes: u64,
    /// Per-job outcomes, in spec order.
    pub jobs: Vec<JobRun>,
    /// Wall-clock of the whole interleaved run.
    pub total_wall: Duration,
}

impl JobsReport {
    /// Aggregate training throughput: samples trained across every
    /// admitted job per wall second of the interleaved run — the
    /// trend-tracked `aggregate_items_per_sec` key of `BENCH_jobs.json`.
    pub fn aggregate_items_per_sec(&self) -> f64 {
        let samples: u64 = self
            .jobs
            .iter()
            .filter_map(|j| j.report.as_ref())
            .flat_map(|r| r.train_epochs.iter())
            .map(|e| e.samples as u64)
            .sum();
        let secs = self.total_wall.as_secs_f64();
        if secs > 0.0 { samples as f64 / secs } else { 0.0 }
    }

    /// How many jobs were admitted and trained.
    pub fn admitted(&self) -> usize {
        self.jobs.iter().filter(|j| j.report.is_some()).count()
    }
}

/// Run a [`JobSet`] as co-resident tenants of one shared-capacity device:
/// admission first ([`tenancy::plan_admission`] — admit / shrink-mu /
/// reject in spec order), then a round-robin interleaved executor that
/// advances each admitted job by exactly one micro-step per turn. Every
/// job keeps its own accumulator, [`UpdateScheduler`], staging pool and
/// [`StageTimers`], and charges residency into the shared [`Arena`]
/// through its tenant sub-ledger — so per-job [`TrainReport`]s are
/// bit-identical to the same configuration's solo [`train`] run (the
/// correctness oracle, `tests/jobs.rs`, mirroring PR 4's overlap oracle)
/// while the arena asserts the cross-job peak stays within capacity at
/// every allocation instant.
pub fn train_jobs(
    engine: &mut Engine,
    set: &JobSet,
    capacity_bytes: u64,
) -> Result<JobsReport> {
    train_jobs_faulted(engine, set, capacity_bytes, None)
}

/// [`train_jobs`] with an optional deterministic [`FaultPlan`]
/// (`mbs jobs --faults spec.json`). With a plan, the per-job recovery
/// state machine is armed (phase-start snapshots, bounded retries with
/// backoff, shrink-mu re-planning) and job failures degrade gracefully:
/// a retry-exhausted or fatally-errored job is evicted — its arena
/// residency released, its [`JobRun`] marked [`JobOutcome::Failed`] with
/// the terminal error — while the surviving tenants keep training.
/// Without a plan the historical contract holds: the first job error
/// aborts the whole run.
pub fn train_jobs_faulted(
    engine: &mut Engine,
    set: &JobSet,
    capacity_bytes: u64,
    plan: Option<&FaultPlan>,
) -> Result<JobsReport> {
    set.validate()?;
    // resolve each job against the manifest and run admission (pure
    // capacity arithmetic — nothing is loaded yet)
    let mut requests = Vec::with_capacity(set.jobs.len());
    for spec in &set.jobs {
        if spec.task.is_some() {
            return Err(MbsError::Config(format!(
                "job '{}' names a synthetic task — training needs a real manifest model \
                 (synthetic stand-ins are for `mbs jobs --dry-run`)",
                spec.name
            )));
        }
        spec.cfg.validate()?;
        let entry = engine.manifest().model(&spec.cfg.model)?.clone();
        requests.push(AdmissionRequest::from_spec(spec, entry));
    }
    let verdicts = tenancy::plan_admission(&requests, capacity_bytes);

    // compile faults live on the engine — the compile seam is shared
    // across tenants, so the hooks are armed once here (or cleared, so a
    // previous run's plan never leaks into this one)
    match plan {
        Some(p) if p.has_compile_entries() => engine.arm_compile_faults(p.compile_hooks()),
        _ => engine.disarm_compile_faults(),
    }

    // materialize the admitted jobs as tenants of one arena
    let isolate = plan.is_some();
    let arena = Arena::new(capacity_bytes);
    let n = set.jobs.len();
    let mut execs: Vec<Option<JobExec>> = Vec::with_capacity(n);
    let mut failures: Vec<Option<String>> = vec![None; n];
    for (i, (spec, verdict)) in set.jobs.iter().zip(&verdicts).enumerate() {
        match &verdict.outcome {
            AdmissionOutcome::Admitted { resolution, resident_claim_bytes, .. } => {
                let recovery = plan.map(|p| RecoveryCfg::from_plan(p, &spec.name));
                match JobExec::new(engine, spec, resolution, *resident_claim_bytes, &arena, recovery)
                {
                    Ok(exec) => execs.push(Some(exec)),
                    // graceful degradation (fault plans only): a job that
                    // cannot even materialize — e.g. an injected compile
                    // fault at model load — is evicted, not fatal to its
                    // siblings; its tenant ledger drop frees every arena
                    // byte the partial materialization claimed
                    Err(e) if isolate => {
                        eprintln!(
                            "[mbs] job '{}': failed to materialize, evicting: {e}",
                            spec.name
                        );
                        failures[i] = Some(e.to_string());
                        execs.push(None);
                    }
                    Err(e) => return Err(e),
                }
            }
            AdmissionOutcome::Rejected { .. } => execs.push(None),
        }
    }

    // the round-robin: one micro-step per live job per turn until every
    // job drains; any step that would exceed the shared capacity fails
    // inside the arena at the exact instant (that failure path IS the
    // every-step cross-job assertion)
    let run_start = Instant::now();
    let mut live: Vec<bool> = execs.iter().map(Option::is_some).collect();
    let mut counters: Vec<(u64, u64, u64)> = vec![(0, 0, 0); n];
    loop {
        let mut progressed = false;
        for i in 0..n {
            if !live[i] {
                continue;
            }
            let Some(exec) = execs[i].as_mut() else {
                live[i] = false;
                continue;
            };
            let err = match exec.step() {
                Ok(true) => {
                    progressed = true;
                    continue;
                }
                Ok(false) => {
                    live[i] = false;
                    continue;
                }
                Err(e) => e,
            };
            // recoverable fault with retries left: run the recovery state
            // machine between turns; its own failure is terminal
            let err = if exec.can_recover(&err) {
                exec.note_retry(&err);
                match exec.recover(engine) {
                    Ok(()) => {
                        progressed = true;
                        continue;
                    }
                    Err(re) => re,
                }
            } else {
                err
            };
            if !isolate {
                return Err(err);
            }
            // graceful degradation: evict the job — harvest its counters,
            // drop its exec so every arena byte it held frees for the
            // survivors — and keep the round-robin running
            eprintln!("[mbs] job '{}': failed terminally, evicting: {err}", exec.name);
            counters[i] = exec.fault_counters();
            exec.cleanup_snapshot();
            failures[i] = Some(err.to_string());
            execs[i] = None;
            live[i] = false;
        }
        debug_assert!(arena.peak() <= arena.capacity(), "arena accounting broke");
        if !progressed {
            break;
        }
    }
    let total_wall = run_start.elapsed();

    let mut jobs = Vec::with_capacity(set.jobs.len());
    for (i, verdict) in verdicts.into_iter().enumerate() {
        let (report, outcome, error) = match execs[i].take() {
            Some(exec) => {
                counters[i] = exec.fault_counters();
                (Some(exec.into_report(capacity_bytes)?), JobOutcome::Completed, None)
            }
            None => match failures[i].take() {
                Some(msg) => (None, JobOutcome::Failed, Some(msg)),
                None => (None, JobOutcome::Rejected, None),
            },
        };
        let (faults_injected, retries, recovered) = counters[i];
        jobs.push(JobRun {
            name: verdict.name,
            admission: verdict.outcome,
            report,
            outcome,
            error,
            faults_injected,
            retries,
            recovered,
        });
    }
    Ok(JobsReport {
        capacity_bytes,
        arena_peak_bytes: arena.peak(),
        jobs,
        total_wall,
    })
}

// ---------------------------------------------------------------------
// Data-parallel fleet execution (multi-device large-batch streaming)
// ---------------------------------------------------------------------

/// One device's share of a fleet run.
#[derive(Debug, Clone)]
pub struct DeviceReport {
    /// Device name from the [`FleetSpec`].
    pub name: String,
    /// Device capacity, bytes.
    pub capacity_bytes: u64,
    /// Micro-batch steps this device executed.
    pub micro_steps: u64,
    /// Training + eval samples routed through this device.
    pub samples: u64,
    /// High-water mark of this device's residency (resident replica +
    /// staged inputs + executing step), bytes — within the device's own
    /// capacity by construction.
    pub ledger_peak_bytes: u64,
}

/// Everything a finished fleet run reports (`mbs fleet`).
#[derive(Debug, Clone)]
pub struct FleetReport {
    /// Per-device shares, in rank order.
    pub devices: Vec<DeviceReport>,
    /// The combined run report. Its numeric stats (losses, metrics,
    /// samples, micro-steps, updates) are **bit-identical** to the same
    /// configuration's solo [`train`] run at the fleet's min per-device
    /// capacity — the fleet-identity oracle (`tests/fleet.rs`).
    pub report: TrainReport,
}

impl FleetReport {
    /// Number of devices the run spanned.
    pub fn device_count(&self) -> usize {
        self.devices.len()
    }
}

/// Per-device pipeline state of the fleet executor: the device's arena
/// tenant (holding its resident replica for the whole run), its dedicated
/// upload lane (overlap mode), and its share counters. The runtime,
/// accumulator, scheduler and stream stay *global* — see [`train_fleet`].
struct ShardExec {
    name: String,
    capacity_bytes: u64,
    ledger: Ledger,
    lane: Option<UploadLane>,
    lane_seq: u64,
    micro_steps: u64,
    samples: u64,
}

/// Owning device of micro-batch `j` within a mini-batch of `n_smu`
/// micro-batches, via the cached balanced-contiguous [`ShardPlan`] (the
/// ragged final mini-batch of an epoch gets its own, smaller plan).
fn shard_owner(plans: &mut BTreeMap<usize, ShardPlan>, devices: usize, n_smu: usize, j: usize) -> usize {
    plans.entry(n_smu).or_insert_with(|| ShardPlan::new(n_smu, devices)).owner(j)
}

/// Hand one stream item to its owner device's upload lane (the fleet
/// counterpart of [`submit_to_lane`]: same lane protocol, per-device
/// lanes, a global FIFO remembering which device each plan went to).
fn fleet_submit(
    shards: &mut [ShardExec],
    d: usize,
    queue: &mut VecDeque<(Arc<ExecutionPlan>, usize)>,
    pass: Pass<'_>,
    item: StreamItem,
) -> Result<()> {
    let StreamItem { plan, mb, .. } = item;
    let scale = match pass {
        Pass::Train { .. } => Some(plan.scales[mb.j]),
        Pass::Eval => None,
    };
    let shard = &mut shards[d];
    let lane = shard.lane.as_mut().ok_or_else(lane_desync)?;
    lane.submit(LaneJob { seq: shard.lane_seq, mb, scale, fault: None, stall: None })?;
    shard.lane_seq += 1;
    queue.push_back((plan, d));
    Ok(())
}

/// Receive the oldest staging fleet-wide — from the lane of whichever
/// device the global FIFO says submitted first — and place it into the
/// shared runtime's idle slot, charging the *owner device's* ledger for
/// the in-flight input residency. Device order inside the FIFO is global
/// micro-batch order, so the runtime sees exactly the solo pipeline's
/// op sequence.
fn fleet_place_staged(
    rt: &mut ModelRuntime,
    shards: &mut [ShardExec],
    fp: &Footprint,
    pool: &Arc<BufPool>,
    queue: &mut VecDeque<(Arc<ExecutionPlan>, usize)>,
    deadline: Duration,
) -> Result<(InFlight, usize)> {
    let (plan, d) = queue.pop_front().ok_or_else(|| {
        MbsError::Runtime("fleet pipeline completed a staging with no queued plan".into())
    })?;
    let shard = &mut shards[d];
    let lane = shard.lane.as_mut().ok_or_else(lane_desync)?;
    let staged = lane.recv_deadline(deadline)?;
    rt.credit_lane_window(staged.started, staged.finished);
    let inputs =
        shard.ledger.alloc("in-flight inputs", fp.overlap_bytes(plan.device_samples()))?;
    rt.stage_inputs(&staged.mb, staged.scale)?;
    let current = InFlight { plan, j: staged.mb.j, actual: staged.mb.actual, inputs };
    pool.give(staged.mb);
    Ok((current, d))
}

/// The fleet epoch loop: the solo [`run_epoch`] with every per-step
/// ledger charge routed to the micro-batch's **owner device** (balanced
/// contiguous [`ShardPlan`] blocks) and, under overlap, per-device upload
/// lanes. Execution stays in strict global micro-batch order through the
/// ONE shared runtime, so the cross-device gradient combine is an
/// *ordered* fold with the same floating-point association as the solo
/// run — micro-grads stream into the runtime's accumulator in rank order
/// (paper Alg. 2 scales from the global plan), and losses/metrics fold
/// into one shared [`Accumulation`] in the same order. That is the whole
/// bit-identity argument: identical op sequence, identical bits.
#[allow(clippy::too_many_arguments)]
fn fleet_epoch(
    rt: &mut ModelRuntime,
    shards: &mut [ShardExec],
    fp: &Footprint,
    pipe: &PipelineCfg,
    pool: &Arc<BufPool>,
    ds: &Arc<dyn Dataset>,
    epoch_plan: EpochPlan,
    planner: &Planner,
    pass: Pass<'_>,
) -> Result<(Accumulation, StageTimers)> {
    let devices = shards.len();
    let mut acc = Accumulation::default();
    let mut assemble = Duration::ZERO;
    let rt_before = rt.timers();
    let stream = stream_epoch(
        pipe.policy,
        ds.clone(),
        epoch_plan,
        planner.clone(),
        pipe.prefetch,
        pool.clone(),
    );
    let mut plans: BTreeMap<usize, ShardPlan> = BTreeMap::new();
    if pipe.overlap {
        let lane_deadline = Watchdog::default().deadline(Surface::LaneRecv);
        let mut queue: VecDeque<(Arc<ExecutionPlan>, usize)> = VecDeque::new();
        let mut pending: Option<(InFlight, usize)> = None;
        for item in stream {
            assemble += item.assemble;
            let placed = if queue.is_empty() {
                None
            } else {
                Some(fleet_place_staged(rt, shards, fp, pool, &mut queue, lane_deadline)?)
            };
            let d = shard_owner(&mut plans, devices, item.plan.n_smu(), item.mb.j);
            fleet_submit(shards, d, &mut queue, pass, item)?;
            if let Some((current, owner)) = pending.take() {
                let samples = current.actual as u64;
                step_in_flight(rt, &mut shards[owner].ledger, fp, pass, &mut acc, current)?;
                shards[owner].micro_steps += 1;
                shards[owner].samples += samples;
            }
            if let Some(next) = placed {
                pending = Some(next);
            }
        }
        // drain: the lanes still hold the final submission, the device
        // slot the one before it — same tail as the solo pipeline
        while !queue.is_empty() {
            let placed = fleet_place_staged(rt, shards, fp, pool, &mut queue, lane_deadline)?;
            if let Some((current, owner)) = pending.take() {
                let samples = current.actual as u64;
                step_in_flight(rt, &mut shards[owner].ledger, fp, pass, &mut acc, current)?;
                shards[owner].micro_steps += 1;
                shards[owner].samples += samples;
            }
            pending = Some(placed);
        }
        if let Some((current, owner)) = pending.take() {
            let samples = current.actual as u64;
            step_in_flight(rt, &mut shards[owner].ledger, fp, pass, &mut acc, current)?;
            shards[owner].micro_steps += 1;
            shards[owner].samples += samples;
        }
    } else {
        for item in stream {
            assemble += item.assemble;
            let d = shard_owner(&mut plans, devices, item.plan.n_smu(), item.mb.j);
            let samples = item.mb.actual as u64;
            exec_serial_item(rt, &mut shards[d].ledger, fp, pass, &mut acc, pool, item)?;
            shards[d].micro_steps += 1;
            shards[d].samples += samples;
        }
    }
    let mut stages = rt.timers().minus(&rt_before);
    stages.assemble = assemble;
    Ok((acc, stages))
}

/// One fleet eval sweep — the fleet counterpart of the solo `eval_epoch`:
/// the whole set as a single sequential mini-batch under exact
/// normalization, its micro-batches sharded across the devices.
#[allow(clippy::too_many_arguments)]
fn fleet_eval_epoch(
    rt: &mut ModelRuntime,
    shards: &mut [ShardExec],
    fp: &Footprint,
    pipe: &PipelineCfg,
    pool: &Arc<BufPool>,
    kind: MetricKind,
    ds: &Arc<dyn Dataset>,
    epoch: usize,
) -> Result<EpochStats> {
    let t0 = Instant::now();
    let len = ds.len();
    let (acc, stages) = if len == 0 {
        (Accumulation::default(), StageTimers::default())
    } else {
        let planner = Planner::new(rt.variant.mu, false, NormalizationMode::Exact);
        fleet_epoch(
            rt,
            shards,
            fp,
            pipe,
            pool,
            ds,
            EpochPlan::sequential(len, len),
            &planner,
            Pass::Eval,
        )?
    };
    Ok(EpochStats::from_accumulation(epoch, kind, &acc, rt.updates, t0.elapsed(), stages))
}

/// Train one configuration data-parallel across a fleet of simulated
/// devices, returning per-device shares plus a combined [`TrainReport`]
/// **bit-identical** in its numeric stats to the solo [`train`] run of
/// the same configuration at the fleet's min per-device capacity.
///
/// The design that makes the identity structural rather than accidental:
///
/// * **One global split plan.** `mu` is resolved against the *smallest*
///   device with the *global* batch (exactly the solo planner at that
///   capacity), so every device streams the same micro-batch size and
///   the Alg. 2 scales come from the global plan.
/// * **Per-device memory, global execution.** Every device holds its own
///   full resident replica and is charged for exactly the steps it owns
///   (balanced contiguous [`ShardPlan`] blocks — rank order IS global
///   order), but the micro-batches flow through ONE shared runtime in
///   strict global order. Floating-point addition is not associative;
///   streaming per-device blocks in rank order is an ordered cross-device
///   gradient combine with the solo run's exact association.
/// * **Per-device pipelines.** Under overlap each device owns an upload
///   lane and its staged-slot residency; the global FIFO interleaves
///   their completions back into global order.
///
/// Device capacities come from the [`FleetSpec`] (`cfg.capacity_mib` is
/// not consulted). Fault plans, checkpointing and resume are solo/jobs
/// features and are rejected here.
pub fn train_fleet(
    engine: &mut Engine,
    cfg: &TrainConfig,
    spec: &FleetSpec,
) -> Result<FleetReport> {
    cfg.validate()?;
    spec.validate()?;
    if cfg.faults.is_some() || cfg.resume.is_some() || cfg.checkpoint.is_some() {
        return Err(MbsError::Config(
            "fleet runs do not support --faults / --resume / --checkpoint".into(),
        ));
    }
    let entry = engine.manifest().model(&cfg.model)?.clone();
    let size = cfg.size.unwrap_or(entry.default_size);
    let kind = MetricKind::parse(&entry.metric_semantics)?;
    // one global split plan must fit every device: resolve against the
    // smallest capacity — the solo planner's arithmetic, unchanged
    let min_cap = spec.min_capacity();
    let resolution = planner::resolve(&entry, size, cfg, &Ledger::new(min_cap))?;
    let fp = resolution.footprint.clone();

    // per-device state: each device's arena tenant holds a full resident
    // replica for the whole run (data parallelism replicates the model)
    let fleet = spec.build();
    let mut shards = Vec::with_capacity(spec.devices.len());
    for (rank, dev) in spec.devices.iter().enumerate() {
        let mut ledger = fleet.arena(rank).tenant(&cfg.model);
        ledger.alloc("resident state", fp.resident_bytes())?;
        shards.push(ShardExec {
            name: dev.name.clone(),
            capacity_bytes: dev.capacity_bytes,
            ledger,
            lane: None,
            lane_seq: 0,
            micro_steps: 0,
            samples: 0,
        });
    }

    let mut rt = engine.load_model(&cfg.model, size, resolution.mu)?;
    rt.set_overlap(cfg.overlap);
    rt.set_label(&cfg.model);
    let (train_ds, eval_ds) = datasets_for(&entry.task, size, cfg)?;
    let batches_per_epoch = cfg.dataset_len.div_ceil(cfg.batch);
    let total_updates = (batches_per_epoch * cfg.epochs) as u64;
    let sched = UpdateScheduler::new(&entry.optimizer, cfg, total_updates);
    let n_smu_full = if cfg.use_mbs { cfg.batch.div_ceil(resolution.mu) } else { 1 };
    let mut prefetch = cfg.prefetch;
    let max_prefetch = if cfg.prefetch_auto {
        cfg.prefetch.max(prefetch_cap(n_smu_full))
    } else {
        cfg.prefetch
    };
    // one shared host pool (staging buffers are host memory, not device
    // memory), sized for the streamer plus every device's lane
    let lane_extra = if cfg.overlap {
        UploadLane::extra_buffers(LANE_DEPTH) * shards.len()
    } else {
        0
    };
    let retained = BufPool::buffers_for(max_prefetch) + lane_extra;
    let pool = Arc::new(BufPool::bounded(retained));
    pool.warm(retained, train_ds.as_ref(), resolution.mu);
    if cfg.overlap {
        for shard in &mut shards {
            shard.lane = Some(UploadLane::spawn(pool.clone(), LANE_DEPTH, &shard.name)?);
        }
    }

    let planner_train = Planner::new(resolution.mu, !cfg.use_mbs, cfg.norm_mode);
    let run_start = Instant::now();
    let mut train_epochs = Vec::with_capacity(cfg.epochs);
    let mut eval_epochs = Vec::with_capacity(cfg.epochs);
    let mut stage_totals = StageTimers::default();
    for epoch in 0..cfg.epochs {
        let pipe =
            PipelineCfg { policy: cfg.streaming, prefetch, overlap: cfg.overlap };
        let t0 = Instant::now();
        let plan = EpochPlan::new(
            train_ds.len().min(cfg.dataset_len),
            cfg.batch,
            cfg.seed,
            epoch as u64,
        );
        let (acc, stages) = fleet_epoch(
            &mut rt,
            &mut shards,
            &fp,
            &pipe,
            &pool,
            &train_ds,
            plan,
            &planner_train,
            Pass::Train { sched: &sched },
        )?;
        stage_totals.merge(&stages);
        if cfg.prefetch_auto {
            prefetch = tune_prefetch(
                prefetch,
                &stages,
                acc.micro_steps as u64,
                prefetch_cap(n_smu_full),
            );
        }
        train_epochs.push(EpochStats::from_accumulation(
            epoch,
            kind,
            &acc,
            rt.updates,
            t0.elapsed(),
            stages,
        ));
        if !cfg.skip_eval {
            let pipe =
                PipelineCfg { policy: cfg.streaming, prefetch, overlap: cfg.overlap };
            eval_epochs.push(fleet_eval_epoch(
                &mut rt, &mut shards, &fp, &pipe, &pool, kind, &eval_ds, epoch,
            )?);
        }
    }
    // a skip-eval run still performs the one final sweep, like solo
    let final_eval = match eval_epochs.last() {
        Some(e) => e.clone(),
        None => {
            let pipe =
                PipelineCfg { policy: cfg.streaming, prefetch, overlap: cfg.overlap };
            fleet_eval_epoch(
                &mut rt,
                &mut shards,
                &fp,
                &pipe,
                &pool,
                kind,
                &eval_ds,
                cfg.epochs.saturating_sub(1),
            )?
        }
    };

    let epoch_walls: Vec<f64> =
        train_epochs.iter().map(|e| e.wall.as_secs_f64()).collect();
    let mem = MemoryModel::new(min_cap, fp.clone());
    let devices = shards
        .iter()
        .map(|s| DeviceReport {
            name: s.name.clone(),
            capacity_bytes: s.capacity_bytes,
            micro_steps: s.micro_steps,
            samples: s.samples,
            ledger_peak_bytes: s.ledger.peak(),
        })
        .collect();
    let report = TrainReport {
        model: cfg.model.clone(),
        use_mbs: cfg.use_mbs,
        batch: cfg.batch,
        mu: resolution.mu,
        train_epochs,
        eval_epochs,
        final_eval,
        total_wall: run_start.elapsed(),
        epoch_wall_mean: mean_epoch_wall(&epoch_walls),
        native_max_batch: mem.native_max_batch(),
        capacity_bytes: min_cap,
        output_mode: rt.output_mode_name().to_string(),
        updates: rt.updates,
        stages: stage_totals,
        pool: pool.stats(),
        overlap: cfg.overlap,
        prefetch,
        ledger_peak_bytes: shards.iter().map(|s| s.ledger.peak()).max().unwrap_or(0),
    };
    Ok(FleetReport { devices, report })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stages_ms(assemble: u64, upload: u64, hidden: u64, execute: u64) -> StageTimers {
        StageTimers {
            assemble: Duration::from_millis(assemble),
            upload: Duration::from_millis(upload),
            upload_hidden: Duration::from_millis(hidden),
            execute: Duration::from_millis(execute),
            ..Default::default()
        }
    }

    #[test]
    fn prefetch_cap_is_a_small_multiple_of_n_smu() {
        assert_eq!(prefetch_cap(1), 2);
        assert_eq!(prefetch_cap(4), 8);
        assert_eq!(prefetch_cap(100), 16); // clamped
        assert_eq!(prefetch_cap(0), 2); // degenerate: native / tiny runs
    }

    #[test]
    fn tune_prefetch_grows_while_assembly_bounds_the_pipeline() {
        // assembly 10ms/step vs 3ms visible device time: double, up to cap
        let s = stages_ms(100, 20, 0, 10);
        assert_eq!(tune_prefetch(2, &s, 10, 8), 4);
        assert_eq!(tune_prefetch(4, &s, 10, 8), 8);
        assert_eq!(tune_prefetch(8, &s, 10, 8), 8); // capped
        // prefetch 0 still means a 1-deep channel; growing starts from 1
        assert_eq!(tune_prefetch(0, &s, 10, 8), 2);
    }

    #[test]
    fn tune_prefetch_shrinks_when_the_device_dominates() {
        // assembly 1ms/step vs 10ms visible device time: halve, floor 1
        let s = stages_ms(10, 20, 0, 80);
        assert_eq!(tune_prefetch(8, &s, 10, 8), 4);
        assert_eq!(tune_prefetch(1, &s, 10, 8), 1);
        // in between (device ahead but < 4x): hold steady
        let balanced = stages_ms(50, 20, 0, 60);
        assert_eq!(tune_prefetch(4, &balanced, 10, 8), 4);
    }

    #[test]
    fn tune_prefetch_counts_hidden_upload_as_free() {
        // upload 30ms/step but 26ms hidden behind execute: visible device
        // time is 4 + 4 = 8ms < 10ms assembly -> assembly still bounds
        let s = stages_ms(100, 300, 260, 40);
        assert_eq!(tune_prefetch(2, &s, 10, 8), 4);
        // the same run without the overlap credit holds instead of growing
        // (visible device time 30 + 4 = 34ms dominates assembly)
        let serial = stages_ms(100, 300, 0, 40);
        assert_eq!(tune_prefetch(2, &serial, 10, 8), 2);
    }

    #[test]
    fn tune_prefetch_ignores_empty_epochs() {
        assert_eq!(tune_prefetch(3, &StageTimers::default(), 0, 8), 3);
    }

    #[test]
    fn backoff_jitter_is_seeded_bounded_and_job_decorrelated() {
        // zero base (the smoke specs' `backoff_ms: 0`) stays exactly zero
        assert_eq!(backoff_with_jitter(0, 7, "cls-64", 1), 0);
        for attempt in 1..=8u32 {
            let ms = backoff_with_jitter(100, 7, "cls-64", attempt);
            assert!((50..=100).contains(&ms), "attempt {attempt}: {ms}ms outside [base/2, base]");
            // pure function of (base, seed, job, attempt): reproducible
            assert_eq!(ms, backoff_with_jitter(100, 7, "cls-64", attempt));
        }
        // co-faulting jobs draw different sleeps — that is the point
        let a: Vec<u64> = (1..=8).map(|i| backoff_with_jitter(100, 7, "cls-64", i)).collect();
        let b: Vec<u64> = (1..=8).map(|i| backoff_with_jitter(100, 7, "seg-32", i)).collect();
        assert_ne!(a, b);
    }

    #[test]
    fn mean_epoch_wall_guards_degenerate_inputs() {
        // regression: an empty wall list (epochs == 0 reaching the report
        // layer) or a NaN mean must not panic Duration::from_secs_f64
        assert_eq!(mean_epoch_wall(&[]), Duration::ZERO);
        assert_eq!(mean_epoch_wall(&[f64::NAN]), Duration::ZERO);
        assert_eq!(mean_epoch_wall(&[-1.0]), Duration::ZERO);
        assert_eq!(mean_epoch_wall(&[1.0, 3.0]), Duration::from_secs(2));
    }
}
