//! The unified, plan-driven epoch executor (paper fig. 2).
//!
//! There is exactly ONE epoch loop: `run_epoch` consumes
//! [`ExecutionPlan`](super::planner::ExecutionPlan)-tagged micro-batches
//! from the streamer and drives the runtime. The three historical variants
//! are all parameterizations of it:
//!
//!   MBS    ("w/ MBS") : N_Smu accumulation steps of mu samples, loss-
//!                       normalized, optimizer update after the last one
//!   native ("w/o MBS"): the degenerate plan — one step with N_B samples
//!                       (`N_Smu = 1`); OOMs past the memory frontier
//!   eval              : the same streamed sweep with `eval_step` and no
//!                       updates
//!
//! That identity is what makes the with/without comparison of the paper's
//! tables apples-to-apples, and it is what the grad-equivalence integration
//! test checks end-to-end. The memory [`Ledger`] is charged for every step
//! the executor runs, so a plan that would exceed capacity fails loudly at
//! the exact step — not just at admission time.

use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::config::TrainConfig;
use crate::data::{BufPool, Dataset, EpochPlan, PoolStats, SynthCarvana, SynthFlowers, SynthText};
use crate::error::{MbsError, Result};
use crate::memory::{Footprint, Ledger, MemoryModel};
use crate::metrics::{EpochStats, MetricKind, StageTimers};
use crate::runtime::{Engine, ModelRuntime};

use super::accumulator::{Accumulation, NormalizationMode};
use super::planner::{self, Planner};
use super::scheduler::UpdateScheduler;
use super::streamer::{stream_epoch, StreamItem, StreamingPolicy};

/// Everything a finished run reports (feeds the tables and figures).
#[derive(Debug, Clone)]
pub struct TrainReport {
    /// Model key the run trained.
    pub model: String,
    /// Whether the MBS arm (true) or the native baseline (false) ran.
    pub use_mbs: bool,
    /// Mini-batch size `N_B`.
    pub batch: usize,
    /// The micro-batch size the run executed with — planner-derived under
    /// `MicroBatchSpec::Auto`, the pinned value under `Fixed`.
    pub mu: usize,
    /// Per-epoch training stats, in order.
    pub train_epochs: Vec<EpochStats>,
    /// Per-epoch eval stats (empty when `skip_eval` is set).
    pub eval_epochs: Vec<EpochStats>,
    /// The last (or only) eval pass.
    pub final_eval: EpochStats,
    /// Wall-clock for the whole run.
    pub total_wall: Duration,
    /// Mean wall-clock per training epoch (the paper's "training time" column).
    pub epoch_wall_mean: Duration,
    /// Largest batch the native path could have trained at this capacity.
    pub native_max_batch: usize,
    /// Simulated device capacity the run was admitted against.
    pub capacity_bytes: u64,
    /// PJRT output convention detected at runtime (diagnostic).
    pub output_mode: String,
    /// Optimizer updates applied.
    pub updates: u64,
    /// Per-stage time summed over the training epochs (each epoch's own
    /// breakdown lives in its [`EpochStats::stages`]).
    pub stages: StageTimers,
    /// Host staging-buffer pool traffic for the whole run — `allocs` stays
    /// at the warm-up count when the hot path is allocation-free.
    pub pool: PoolStats,
}

impl TrainReport {
    /// Best (max) eval primary metric across epochs — the paper reports
    /// "maximum accuracy/IoU".
    pub fn best_metric(&self) -> f64 {
        self.eval_epochs
            .iter()
            .map(|e| e.primary_metric)
            .fold(self.final_eval.primary_metric, f64::max)
    }
}

/// Build the task-appropriate synthetic datasets for a config.
pub fn datasets_for(
    task: &str,
    size: usize,
    cfg: &TrainConfig,
) -> Result<(Arc<dyn Dataset>, Arc<dyn Dataset>)> {
    let train_seed = cfg.seed.wrapping_mul(2).wrapping_add(1);
    let eval_seed = cfg.seed.wrapping_mul(2).wrapping_add(2);
    Ok(match task {
        "classification" => (
            Arc::new(SynthFlowers::new(size, cfg.num_classes, cfg.dataset_len, train_seed)),
            Arc::new(SynthFlowers::new(size, cfg.num_classes, cfg.eval_len, eval_seed)),
        ),
        "segmentation" => (
            Arc::new(SynthCarvana::new(size, cfg.dataset_len, train_seed)),
            Arc::new(SynthCarvana::new(size, cfg.eval_len, eval_seed)),
        ),
        "lm" => (
            Arc::new(SynthText::new(512, size, cfg.dataset_len, train_seed)),
            Arc::new(SynthText::new(512, size, cfg.eval_len, eval_seed)),
        ),
        other => return Err(MbsError::Config(format!("unknown task '{other}'"))),
    })
}

/// What one pass through the data does with each micro-batch.
#[derive(Clone, Copy)]
enum Pass<'a> {
    /// Accumulate gradients; optimizer update after each mini-batch's last
    /// micro-batch (fig. 2 step 5).
    Train { sched: &'a UpdateScheduler },
    /// Masked, padded metric sweep; never touches gradients or params.
    Eval,
}

/// THE epoch loop. Streams plan-tagged micro-batches and executes them,
/// charging the ledger for every step so planned residency is asserted
/// against capacity at the moment it would be live on the device. Staging
/// buffers are leased from `pool` by the streamer and handed back through
/// its return channel right after each step — the steady-state hot path
/// allocates nothing. Returns the epoch's accumulation plus its per-stage
/// time breakdown (assemble from the stream items, the device stages as
/// deltas of the runtime's monotonic timers).
#[allow(clippy::too_many_arguments)]
fn run_epoch(
    rt: &mut ModelRuntime,
    ledger: &mut Ledger,
    fp: &Footprint,
    policy: StreamingPolicy,
    prefetch: usize,
    pool: &Arc<BufPool>,
    ds: &Arc<dyn Dataset>,
    epoch_plan: EpochPlan,
    planner: &Planner,
    pass: Pass<'_>,
) -> Result<(Accumulation, StageTimers)> {
    let mut acc = Accumulation::default();
    let mut assemble = Duration::ZERO;
    let rt_before = rt.timers();
    let stream =
        stream_epoch(policy, ds.clone(), epoch_plan, planner.clone(), prefetch, pool.clone());
    for item in stream {
        assemble += item.assemble;
        let StreamItem { plan, mb, .. } = item;
        // training holds activations for the backward pass; eval is
        // forward-only and holds just the input buffers
        let (tag, bytes) = match pass {
            Pass::Train { .. } => ("train step", fp.batch_bytes(plan.device_samples())),
            Pass::Eval => ("eval step", fp.eval_bytes(plan.device_samples())),
        };
        let step = ledger.alloc(tag, bytes)?;
        let out = match pass {
            Pass::Train { .. } => rt.accum_step(&mb, plan.scales[mb.j])?,
            Pass::Eval => rt.eval_step(&mb)?,
        };
        ledger.free(step)?;
        acc.add(&out, mb.actual);
        let update_due = matches!(pass, Pass::Train { .. }) && plan.is_last(mb.j);
        // upload done: recycle the staging buffer before the (potentially
        // long) optimizer update
        pool.give(mb);
        if update_due {
            if let Pass::Train { sched } = pass {
                rt.apply(&sched.hyper_for(rt.updates))?;
            }
        }
    }
    let mut stages = rt.timers().minus(&rt_before);
    stages.assemble = assemble;
    Ok((acc, stages))
}

/// One eval sweep through the executor: the whole set as a single
/// sequential mini-batch, split by the runtime's static mu and streamed
/// under the run's configured policy.
#[allow(clippy::too_many_arguments)]
fn eval_epoch(
    rt: &mut ModelRuntime,
    ledger: &mut Ledger,
    fp: &Footprint,
    policy: StreamingPolicy,
    prefetch: usize,
    pool: &Arc<BufPool>,
    kind: MetricKind,
    ds: &Arc<dyn Dataset>,
    epoch: usize,
) -> Result<EpochStats> {
    let t0 = Instant::now();
    let len = ds.len();
    let (acc, stages) = if len == 0 {
        // empty eval set: zero samples, zero stats
        (Accumulation::default(), StageTimers::default())
    } else {
        let planner = Planner::new(rt.variant.mu, false, NormalizationMode::Exact);
        run_epoch(
            rt,
            ledger,
            fp,
            policy,
            prefetch,
            pool,
            ds,
            EpochPlan::sequential(len, len),
            &planner,
            Pass::Eval,
        )?
    };
    Ok(EpochStats::from_accumulation(epoch, kind, &acc, rt.updates, t0.elapsed(), stages))
}

/// Masked, padded eval pass over a dataset under an explicit streaming
/// policy (the standalone entry point for benches and tests; `train` runs
/// the same executor with its own ledger and pool).
pub fn evaluate_with(
    rt: &mut ModelRuntime,
    kind: MetricKind,
    ds: &Arc<dyn Dataset>,
    epoch: usize,
    policy: StreamingPolicy,
    prefetch: usize,
) -> Result<EpochStats> {
    let fp = Footprint::from_manifest(&rt.entry, &rt.variant);
    let mut ledger = Ledger::new(fp.step_bytes(rt.variant.mu));
    ledger.alloc("resident state", fp.resident_bytes())?;
    let pool = Arc::new(BufPool::for_prefetch(prefetch));
    pool.warm(BufPool::buffers_for(prefetch), ds.as_ref(), rt.variant.mu);
    eval_epoch(rt, &mut ledger, &fp, policy, prefetch, &pool, kind, ds, epoch)
}

/// [`evaluate_with`] under the synchronous policy — the historical
/// signature, kept for one-off callers.
pub fn evaluate(
    rt: &mut ModelRuntime,
    kind: MetricKind,
    ds: &Arc<dyn Dataset>,
    epoch: usize,
) -> Result<EpochStats> {
    evaluate_with(rt, kind, ds, epoch, StreamingPolicy::Synchronous, 0)
}

/// Mean per-epoch wall time, guarded so an empty or degenerate list can
/// never feed a non-finite value into `Duration::from_secs_f64` (which
/// panics on NaN).
fn mean_epoch_wall(walls: &[f64]) -> Duration {
    let m = crate::util::stats::mean(walls);
    if m.is_finite() && m >= 0.0 {
        Duration::from_secs_f64(m)
    } else {
        Duration::ZERO
    }
}

/// Train according to `cfg`, returning the full report. Returns
/// [`MbsError::Oom`] when the configuration does not fit the simulated
/// device — the paper tables' "Failed" cells.
pub fn train(engine: &mut Engine, cfg: &TrainConfig) -> Result<TrainReport> {
    cfg.validate()?;
    let entry = engine.manifest().model(&cfg.model)?.clone();
    let size = cfg.size.unwrap_or(entry.default_size);
    let kind = MetricKind::parse(&entry.metric_semantics)?;

    // ------------------------------------------------------------------
    // memory admission + planning (paper section 1 + Alg. 1): the ledger's
    // remaining budget drives the micro-batch choice; the resident state is
    // then charged for the whole run
    // ------------------------------------------------------------------
    let capacity = match cfg.capacity_bytes() {
        Some(c) => c,
        None => planner::default_capacity(&entry, size, &cfg.mu)?,
    };
    let mut ledger = Ledger::new(capacity);
    let resolution = planner::resolve(&entry, size, cfg, &ledger)?;
    let mem = MemoryModel::new(capacity, resolution.footprint.clone());
    ledger.alloc("resident state", resolution.footprint.resident_bytes())?;
    let planner = Planner::new(resolution.mu, !cfg.use_mbs, cfg.norm_mode);

    // ------------------------------------------------------------------
    // runtime + data
    // ------------------------------------------------------------------
    let mut rt: ModelRuntime = engine.load_model(&cfg.model, size, resolution.mu)?;
    let (train_ds, eval_ds) = datasets_for(&entry.task, size, cfg)?;

    let batches_per_epoch = cfg.dataset_len.div_ceil(cfg.batch);
    let total_updates = (batches_per_epoch * cfg.epochs) as u64;
    let sched = UpdateScheduler::new(&entry.optimizer, cfg, total_updates);

    // one staging-buffer pool for the whole run: warmed once, every epoch
    // (train and eval alike) circulates the same host allocations
    let pool = Arc::new(BufPool::for_prefetch(cfg.prefetch));
    pool.warm(BufPool::buffers_for(cfg.prefetch), train_ds.as_ref(), resolution.mu);

    let mut train_epochs = Vec::with_capacity(cfg.epochs);
    let mut eval_epochs = Vec::with_capacity(cfg.epochs);
    let mut stage_totals = StageTimers::default();
    let run_start = Instant::now();

    for epoch in 0..cfg.epochs {
        let t0 = Instant::now();
        let epoch_plan = EpochPlan::new(
            train_ds.len().min(cfg.dataset_len),
            cfg.batch,
            cfg.seed,
            epoch as u64,
        );
        let (acc, stages) = run_epoch(
            &mut rt,
            &mut ledger,
            &resolution.footprint,
            cfg.streaming,
            cfg.prefetch,
            &pool,
            &train_ds,
            epoch_plan,
            &planner,
            Pass::Train { sched: &sched },
        )?;
        let wall = t0.elapsed();
        stage_totals.merge(&stages);
        train_epochs
            .push(EpochStats::from_accumulation(epoch, kind, &acc, rt.updates, wall, stages));

        if !cfg.skip_eval {
            eval_epochs.push(eval_epoch(
                &mut rt,
                &mut ledger,
                &resolution.footprint,
                cfg.streaming,
                cfg.prefetch,
                &pool,
                kind,
                &eval_ds,
                epoch,
            )?);
        }
    }
    let total_wall = run_start.elapsed();
    let final_eval = if cfg.skip_eval {
        eval_epoch(
            &mut rt,
            &mut ledger,
            &resolution.footprint,
            cfg.streaming,
            cfg.prefetch,
            &pool,
            kind,
            &eval_ds,
            cfg.epochs.saturating_sub(1),
        )?
    } else {
        eval_epochs.last().cloned().ok_or_else(|| MbsError::Config("zero epochs".into()))?
    };

    let epoch_walls: Vec<f64> = train_epochs.iter().map(|e| e.wall.as_secs_f64()).collect();
    let epoch_wall_mean = mean_epoch_wall(&epoch_walls);

    Ok(TrainReport {
        model: cfg.model.clone(),
        use_mbs: cfg.use_mbs,
        batch: cfg.batch,
        mu: resolution.mu,
        train_epochs,
        eval_epochs,
        final_eval,
        total_wall,
        epoch_wall_mean,
        native_max_batch: mem.native_max_batch(),
        capacity_bytes: capacity,
        output_mode: rt.output_mode_name().to_string(),
        updates: rt.updates,
        stages: stage_totals,
        pool: pool.stats(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_epoch_wall_guards_degenerate_inputs() {
        // regression: an empty wall list (epochs == 0 reaching the report
        // layer) or a NaN mean must not panic Duration::from_secs_f64
        assert_eq!(mean_epoch_wall(&[]), Duration::ZERO);
        assert_eq!(mean_epoch_wall(&[f64::NAN]), Duration::ZERO);
        assert_eq!(mean_epoch_wall(&[-1.0]), Duration::ZERO);
        assert_eq!(mean_epoch_wall(&[1.0, 3.0]), Duration::from_secs(2));
    }
}
