//! The MBS training loop (paper fig. 2) and the native baseline.
//!
//! Both paths run the *identical* arithmetic through the same `accum_step`
//! executable; they differ only in (a) how many samples sit on the device
//! at once — which the memory model checks — and (b) how many accumulation
//! steps precede each optimizer update:
//!
//!   native ("w/o MBS"): one step with N_B samples; OOMs past the frontier
//!   MBS    ("w/ MBS") : N_Smu steps with mu samples, loss-normalized
//!
//! That identity is what makes the with/without comparison of the paper's
//! tables apples-to-apples, and it is what the grad-equivalence integration
//! test checks end-to-end.

use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::config::TrainConfig;
use crate::data::{loader, Dataset, EpochPlan, SynthCarvana, SynthFlowers, SynthText};
use crate::error::{MbsError, Result};
use crate::memory::{Footprint, MemoryModel};
use crate::metrics::{EpochStats, MetricKind};
use crate::runtime::{Engine, ModelRuntime};

use super::accumulator::Accumulation;
use super::scheduler::UpdateScheduler;
use super::splitter::SplitPlan;
use super::streamer::stream_epoch;

/// Everything a finished run reports (feeds the tables and figures).
#[derive(Debug, Clone)]
pub struct TrainReport {
    pub model: String,
    pub use_mbs: bool,
    pub batch: usize,
    pub mu: usize,
    pub train_epochs: Vec<EpochStats>,
    pub eval_epochs: Vec<EpochStats>,
    pub final_eval: EpochStats,
    pub total_wall: Duration,
    /// Mean wall-clock per training epoch (the paper's "training time" column).
    pub epoch_wall_mean: Duration,
    pub native_max_batch: usize,
    pub capacity_bytes: u64,
    pub output_mode: String,
    pub updates: u64,
}

impl TrainReport {
    /// Best (max) eval primary metric across epochs — the paper reports
    /// "maximum accuracy/IoU".
    pub fn best_metric(&self) -> f64 {
        self.eval_epochs
            .iter()
            .map(|e| e.primary_metric)
            .fold(self.final_eval.primary_metric, f64::max)
    }
}

/// Build the task-appropriate synthetic datasets for a config.
pub fn datasets_for(
    task: &str,
    size: usize,
    cfg: &TrainConfig,
) -> Result<(Arc<dyn Dataset>, Arc<dyn Dataset>)> {
    let train_seed = cfg.seed.wrapping_mul(2).wrapping_add(1);
    let eval_seed = cfg.seed.wrapping_mul(2).wrapping_add(2);
    Ok(match task {
        "classification" => (
            Arc::new(SynthFlowers::new(size, cfg.num_classes, cfg.dataset_len, train_seed)),
            Arc::new(SynthFlowers::new(size, cfg.num_classes, cfg.eval_len, eval_seed)),
        ),
        "segmentation" => (
            Arc::new(SynthCarvana::new(size, cfg.dataset_len, train_seed)),
            Arc::new(SynthCarvana::new(size, cfg.eval_len, eval_seed)),
        ),
        "lm" => (
            Arc::new(SynthText::new(512, size, cfg.dataset_len, train_seed)),
            Arc::new(SynthText::new(512, size, cfg.eval_len, eval_seed)),
        ),
        other => return Err(MbsError::Config(format!("unknown task '{other}'"))),
    })
}

/// Train according to `cfg`, returning the full report. Returns
/// [`MbsError::Oom`] when the configuration does not fit the simulated
/// device — the paper tables' "Failed" cells.
pub fn train(engine: &mut Engine, cfg: &TrainConfig) -> Result<TrainReport> {
    cfg.validate()?;
    let entry = engine.manifest().model(&cfg.model)?.clone();
    let size = cfg.size.unwrap_or(entry.default_size);
    let variant = entry.variant(size, cfg.mu)?.clone();
    let kind = MetricKind::parse(&entry.metric_semantics)?;

    // ------------------------------------------------------------------
    // memory admission (paper section 1: "the mini-batch cannot be
    // allocated ... and the model cannot be trained")
    // ------------------------------------------------------------------
    let footprint = Footprint::from_manifest(&entry, &variant);
    let capacity = cfg
        .capacity_bytes()
        .unwrap_or_else(|| MemoryModel::capacity_for_native_max(&footprint, 2 * cfg.mu));
    let mem = MemoryModel::new(capacity, footprint);
    mem.check_resident()?;
    let samples_on_device = if cfg.use_mbs { cfg.mu.min(cfg.batch) } else { cfg.batch };
    let label = if cfg.use_mbs {
        format!("MBS step mu={samples_on_device}")
    } else {
        format!("native step N_B={samples_on_device}")
    };
    mem.check_step(samples_on_device, &label)?;
    if !cfg.use_mbs && cfg.batch > variant.mu {
        // capacity admits it but no executable was exported that large —
        // configs keep native-max == exported max so this is a config error
        return Err(MbsError::Config(format!(
            "native baseline needs an exported variant with batch {} (max exported mu is {})",
            cfg.batch, variant.mu
        )));
    }

    // ------------------------------------------------------------------
    // runtime + data
    // ------------------------------------------------------------------
    let mut rt: ModelRuntime = engine.load_model(&cfg.model, size, cfg.mu)?;
    let (train_ds, eval_ds) = datasets_for(&entry.task, size, cfg)?;

    let batches_per_epoch = cfg.dataset_len.div_ceil(cfg.batch);
    let total_updates = (batches_per_epoch * cfg.epochs) as u64;
    let sched = UpdateScheduler::new(&entry.optimizer, cfg, total_updates);

    let mut train_epochs = Vec::with_capacity(cfg.epochs);
    let mut eval_epochs = Vec::with_capacity(cfg.epochs);
    let run_start = Instant::now();

    for epoch in 0..cfg.epochs {
        let t0 = Instant::now();
        let acc = if cfg.use_mbs {
            train_epoch_mbs(&mut rt, cfg, &train_ds, &sched, epoch)?
        } else {
            train_epoch_native(&mut rt, cfg, &train_ds, &sched, epoch)?
        };
        let wall = t0.elapsed();
        train_epochs.push(EpochStats::from_accumulation(epoch, kind, &acc, rt.updates, wall));

        if !cfg.skip_eval {
            eval_epochs.push(evaluate(&mut rt, kind, &eval_ds, epoch)?);
        }
    }
    let total_wall = run_start.elapsed();
    let final_eval = if cfg.skip_eval {
        evaluate(&mut rt, kind, &eval_ds, cfg.epochs.saturating_sub(1))?
    } else {
        eval_epochs.last().cloned().ok_or_else(|| MbsError::Config("zero epochs".into()))?
    };

    let epoch_walls: Vec<f64> = train_epochs.iter().map(|e| e.wall.as_secs_f64()).collect();
    let epoch_wall_mean = Duration::from_secs_f64(crate::util::stats::mean(&epoch_walls));

    Ok(TrainReport {
        model: cfg.model.clone(),
        use_mbs: cfg.use_mbs,
        batch: cfg.batch,
        mu: cfg.mu,
        train_epochs,
        eval_epochs,
        final_eval,
        total_wall,
        epoch_wall_mean,
        native_max_batch: mem.native_max_batch(),
        capacity_bytes: capacity,
        output_mode: rt.output_mode_name().to_string(),
        updates: rt.updates,
    })
}

/// One MBS epoch: stream micro-batches, accumulate, update at mini-batch
/// boundaries (fig. 2 steps 1-5).
fn train_epoch_mbs(
    rt: &mut ModelRuntime,
    cfg: &TrainConfig,
    ds: &Arc<dyn Dataset>,
    sched: &UpdateScheduler,
    epoch: usize,
) -> Result<Accumulation> {
    let plan = EpochPlan::new(ds.len().min(cfg.dataset_len), cfg.batch, cfg.seed, epoch as u64);
    let mut epoch_acc = Accumulation::default();
    let mut current_split: Option<SplitPlan> = None;
    let stream = stream_epoch(cfg.streaming, ds.clone(), plan, cfg.mu, cfg.prefetch);
    for item in stream {
        let split = current_split
            .take()
            .filter(|s: &SplitPlan| s.n_b == item.n_b)
            .unwrap_or_else(|| SplitPlan::new(item.n_b, cfg.mu));
        let scale = cfg.norm_mode.scale(&split, item.mb.j);
        let out = rt.accum_step(&item.mb, scale)?;
        epoch_acc.add(&out, item.mb.actual);
        if item.mb.j + 1 == split.n_smu() {
            // last micro-batch of the mini-batch: optimizer update (step 5)
            rt.apply(&sched.hyper_for(rt.updates))?;
        } else {
            current_split = Some(split);
        }
    }
    Ok(epoch_acc)
}

/// One native epoch: the whole mini-batch as a single accumulation step
/// (N_Smu = 1) followed by the update — the paper's "w/o MBS" arm. The
/// memory model has already admitted N_B samples on the device; execution
/// uses the exported mu-shaped step with padding when N_B < mu.
fn train_epoch_native(
    rt: &mut ModelRuntime,
    cfg: &TrainConfig,
    ds: &Arc<dyn Dataset>,
    sched: &UpdateScheduler,
    epoch: usize,
) -> Result<Accumulation> {
    let plan = EpochPlan::new(ds.len().min(cfg.dataset_len), cfg.batch, cfg.seed, epoch as u64);
    let mut epoch_acc = Accumulation::default();
    for b in 0..plan.num_batches() {
        let indices = plan.batch_indices(b);
        // single "micro"-batch covering the entire mini-batch
        let mb = loader::assemble(ds.as_ref(), indices, rt.variant.mu, 0);
        let n = indices.len().min(rt.variant.mu);
        let scale = 1.0 / n as f32;
        let out = rt.accum_step(&mb, scale)?;
        epoch_acc.add(&out, mb.actual);
        rt.apply(&sched.hyper_for(rt.updates))?;
    }
    Ok(epoch_acc)
}

/// Masked, padded eval pass over a dataset.
pub fn evaluate(
    rt: &mut ModelRuntime,
    kind: MetricKind,
    ds: &Arc<dyn Dataset>,
    epoch: usize,
) -> Result<EpochStats> {
    let t0 = Instant::now();
    let mu = rt.variant.mu;
    let indices: Vec<usize> = (0..ds.len()).collect();
    let split = SplitPlan::new(indices.len(), mu);
    let mut acc = Accumulation::default();
    for j in 0..split.n_smu() {
        let mb = loader::assemble(ds.as_ref(), &indices, mu, j); // pad to static mu
        let out = rt.eval_step(&mb)?;
        acc.add(&out, mb.actual);
    }
    Ok(EpochStats::from_accumulation(epoch, kind, &acc, rt.updates, t0.elapsed()))
}
